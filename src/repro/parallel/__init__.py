from . import sharding
from .sharding import (set_active_mesh, active_mesh, use_mesh, constrain,
                       resolve_pspec, named_sharding, tree_pspecs,
                       tree_shardings, DEFAULT_RULES)
