"""Logical-axis sharding: MaxText-style rules mapping logical tensor axes to
mesh axes, with divisibility-aware resolution.

Mesh axes (launch/mesh.py):
  single-pod : ("data", "model")           = (16, 16)   -> 256 chips
  multi-pod  : ("pod", "data", "model")    = (2, 16, 16) -> 512 chips

Parallelism mapping:
  DP   : batch over ("pod", "data")
  FSDP : weight "embed" axis over "data" (ZeRO-style fully-sharded params +
         optimizer state; GSPMD inserts the all-gathers)
  TP   : heads / mlp / vocab over "model"
  EP   : experts over "model"
  SP   : long-context sequence over "data" when batch == 1; attention
         batch-split over ("data","model") when heads don't divide "model"

JAX requires divisible shardings (uneven sharding is rejected at jit time),
so resolution drops any mesh axis that does not divide the dimension.

Model code never receives a mesh argument; the launcher installs the active
mesh via :func:`set_active_mesh` and the model constrains activations through
:func:`constrain`, which is a no-op when no mesh is active (single-device
smoke tests).
"""
from __future__ import annotations

import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Each logical axis maps to a mesh axis (or tuple of axes, or None).
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "batch_split": ("pod", "data", "model"),  # attention batch-split fallback
    "seq": None,
    "seq_sp": ("data",),        # sequence-parallel (long-context, batch==1)
    "kv_seq": None,             # decode KV cache sequence (un-sharded default)
    "kv_seq_mp": ("model",),    # decode KV cache sharded over model (flash-decode)
    "embed": ("data",),         # FSDP axis on parameters
    "act_embed": None,          # activations' d_model stays unsharded
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "mlp": ("model",),
    "experts": ("model",),
    "expert_mlp": None,
    "layers": None,
    "lru": ("model",),
    "lru_blocks": ("model",),
    "conv": None,
    "stack": None,
}


class _MeshState(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[dict] = None


_STATE = _MeshState()


def set_active_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Install the mesh used by :func:`constrain` (launcher / dry-run only)."""
    _STATE.mesh = mesh
    _STATE.rules = rules


def active_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


def active_rules() -> dict:
    return getattr(_STATE, "rules", None) or DEFAULT_RULES


class use_mesh:
    """Context manager combining ``set_active_mesh`` + ``with mesh:``."""

    def __init__(self, mesh: Mesh, rules: Optional[dict] = None):
        self.mesh, self.rules = mesh, rules

    def __enter__(self):
        set_active_mesh(self.mesh, self.rules)
        self.mesh.__enter__()
        return self.mesh

    def __exit__(self, *exc):
        set_active_mesh(None, None)
        return self.mesh.__exit__(*exc)


def resolve_pspec(logical: Sequence[Optional[str]], mesh: Mesh,
                  rules: Optional[dict] = None,
                  shape: Optional[Sequence[int]] = None) -> P:
    """Map logical axis names to a PartitionSpec on ``mesh``.

    Rules whose mesh axes are absent from the mesh are dropped (the same
    logical spec works on the 2D and 3D meshes).  A mesh axis is used at most
    once; later logical axes that would reuse it are left unsharded.  If
    ``shape`` is given, any mesh axis that does not evenly divide the
    dimension is dropped (JAX rejects uneven shardings).
    """
    rules = rules or active_rules()
    used: set[str] = set()
    out = []
    for i, name in enumerate(logical):
        if name is None:
            out.append(None)
            continue
        target = rules.get(name)
        if target is None:
            out.append(None)
            continue
        axes = (target,) if isinstance(target, str) else tuple(target)
        axes = tuple(a for a in axes if a in mesh.shape and a not in used)
        if shape is not None:
            keep = []
            dim = shape[i]
            for a in axes:
                if dim % mesh.shape[a] == 0 and dim >= mesh.shape[a]:
                    keep.append(a)
                    dim //= mesh.shape[a]
            axes = tuple(keep)
        if not axes:
            out.append(None)
            continue
        used.update(axes)
        out.append(axes[0] if len(axes) == 1 else axes)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named_sharding(logical: Sequence[Optional[str]], mesh: Mesh,
                   rules: Optional[dict] = None,
                   shape: Optional[Sequence[int]] = None) -> NamedSharding:
    return NamedSharding(mesh, resolve_pspec(logical, mesh, rules, shape))


def constrain(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without active mesh."""
    mesh = active_mesh()
    if mesh is None:
        return x
    spec = resolve_pspec(logical, mesh, active_rules(), x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def can_shard(dim: int, logical_name: str) -> bool:
    """True if ``dim`` would actually be sharded under the active mesh."""
    mesh = active_mesh()
    if mesh is None:
        return False
    spec = resolve_pspec((logical_name,), mesh, active_rules(), (dim,))
    return len(spec) > 0 and spec[0] is not None


def tree_pspecs(spec_tree, mesh: Mesh, rules: Optional[dict] = None):
    """Map a tree of ParamSpec-like leaves (with .logical/.shape) to
    PartitionSpecs."""
    return jax.tree.map(
        lambda s: resolve_pspec(s.logical, mesh, rules, s.shape), spec_tree,
        is_leaf=lambda s: hasattr(s, "logical"))


def tree_shardings(spec_tree, mesh: Mesh, rules: Optional[dict] = None):
    return jax.tree.map(
        lambda s: named_sharding(s.logical, mesh, rules, s.shape), spec_tree,
        is_leaf=lambda s: hasattr(s, "logical"))
