"""Fingerprint cache: quantized request keys with a certified tolerance.

This lifts the ``sim/cache.py`` idea (pay for a computation once per
*equivalence class*, not once per call) one level up, from compiled
programs to solved answers.  Two requests whose platform parameters
round to the same point of a logarithmic lattice share one cache entry;
the entry stores the EXACT solve of the lattice representative, so every
request mapping to a fingerprint receives bit-identical numbers whether
it hits or misses.

Lattice
-------
Positive scale parameters (C, R, D, mu, P_*) are rounded in log space
with relative step ``rel`` (each parameter moves by at most a factor
``(1 + rel)^(1/2)``); the bounded mixing parameters (omega, q) are
rounded on a linear grid of step ``absolute``.  ``T_base`` is excluded
(both objectives are degree-1 homogeneous in it — see ``serve.schema``)
and ``objective`` is excluded (one entry stores both optima).

Tolerance contract (the sandwich lemma)
---------------------------------------
Let ``J_p(T)`` be the served objective (expected makespan or energy) on
platform ``p``, ``p^`` the lattice representative of ``p``'s cell, and

    ``T* = argmin J_p``,   ``T^ = argmin J_{p^}`` (the cached answer).

Suppose every platform in the cell satisfies the two-sided ratio bound
``J_{p'}(T) <= e^L * J_{p''}(T)`` for all ``T`` in ``{T^, T*}`` and all
cell members ``p', p''``.  Then serving ``T^`` instead of ``T*`` costs

    ``J_p(T^) <= e^L J_{p^}(T^) <= e^L J_{p^}(T*) <= e^{2L} J_p(T*)``,

i.e. a relative degradation of at most ``e^{2L} - 1`` — the middle
inequality is just the optimality of ``T^`` for ``p^``.  The bound needs
NO smoothness of the argmin itself, only of the objective's value, which
is why it survives the flat-valley regions where the argmin moves a lot.

``certified_bound`` computes, per cache entry, a conservative ``L``:
for each parameter it perturbs the representative to both edges of its
cell (holding ``T^`` fixed), measures the worst log-change of the
objective with the exact closed form, and sums over parameters; the sum
is doubled (``_CELL_SAFETY``) to cover cross terms and the fact that the
request sits up to a full half-step from the representative in every
coordinate simultaneously.  The service compares ``expm1(2 * L)``
against the documented tolerance and falls back to an exact per-request
solve whenever the certificate fails — so the contract

    served objective  <=  (1 + tol) * exact optimum

holds for every answer the cache is allowed to serve, and the property
suite (``tests/test_advisor.py``) checks it against brute-force exact
solves.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import numpy as np

from ..sim import sweep as _sweep
from .schema import AdviceRequest, StoreTier

#: safety factor on the per-cell log-ratio ``L``: the axis sweep measures
#: one coordinate at a time; doubling covers simultaneous perturbation of
#: all coordinates plus curvature beyond first order.
_CELL_SAFETY = 2.0


@dataclasses.dataclass(frozen=True)
class Quantization:
    """Cache lattice knobs.

    ``rel``      — relative log-space step for positive scale params.
    ``absolute`` — linear step for omega / q in [0, 1].
    ``tol``      — documented relative-degradation tolerance: entries
                   whose certified bound exceeds it are not served from
                   the lattice (the request is solved exactly instead).

    The defaults certify well under ``tol`` on the paper's platform
    ranges; pass ``rel=0.0`` to disable quantization entirely (the
    fingerprint then only merges bit-identical requests).
    """

    rel: float = 1e-3
    absolute: float = 1e-3
    tol: float = 1e-2

    def __post_init__(self):
        if self.rel < 0.0 or self.absolute < 0.0 or self.tol < 0.0:
            raise ValueError("quantization steps must be >= 0")


def _qlog(x: float, rel: float) -> float:
    """Round ``x > 0`` to the nearest point of the log-lattice."""
    if rel <= 0.0 or x <= 0.0:
        return float(x)
    step = math.log1p(rel)
    return float(math.exp(round(math.log(x) / step) * step))


def _qlin(x: float, step: float) -> float:
    """Round ``x`` to the nearest multiple of ``step`` (clipped to [0,1])."""
    if step <= 0.0:
        return float(x)
    return float(min(1.0, max(0.0, round(x / step) * step)))


def _qtier(t: StoreTier, q: Quantization) -> StoreTier:
    return StoreTier(name=t.name, C=_qlog(t.C, q.rel), R=_qlog(t.R, q.rel),
                     D=_qlog(t.D, q.rel), P_io=_qlog(t.P_io, q.rel),
                     q=_qlin(t.q, q.absolute))


def quantize_request(req: AdviceRequest, q: Quantization) -> AdviceRequest:
    """The lattice representative of ``req``'s cell.

    Canonicalized to ``T_base = 1`` (homogeneity) — the objective and the
    tier names are carried through untouched (they don't enter the solve).
    """
    return dataclasses.replace(
        req,
        mu=_qlog(req.mu, q.rel),
        tiers=tuple(_qtier(t, q) for t in req.tiers),
        omega=_qlin(req.omega, q.absolute),
        omega2=(None if req.omega2 is None
                else _qlin(req.omega2, q.absolute)),
        P_static=_qlog(req.P_static, q.rel),
        P_cal=_qlog(req.P_cal, q.rel),
        P_down=_qlog(req.P_down, q.rel),
        T_base=1.0,
        process_param=_qlog(req.process_param, q.rel),
    )


def fingerprint(req: AdviceRequest, q: Quantization) -> Tuple:
    """Hashable cache key of ``req``'s cell (quantize + key)."""
    return quantized_key(quantize_request(req, q))


def quantized_key(qr: AdviceRequest) -> Tuple:
    """Cache key of an ALREADY-QUANTIZED request.

    Built from the quantized numeric fields; excludes ``objective`` (one
    entry serves both), ``T_base`` (homogeneity) and tier names (labels,
    not physics).  Two-tier keys include ``max_deep_every`` because it
    caps the cadence search space and can change the answer.
    """
    tiers = tuple((t.C, t.R, t.D, t.P_io, t.q) for t in qr.tiers)
    key = ("2l" if qr.is_multilevel else "1l", qr.mu, tiers, qr.omega,
           qr.P_static, qr.P_cal, qr.P_down, qr.process, qr.process_param)
    if qr.is_multilevel:
        # the effective deep-flush overlap enters the solve, so it enters
        # the key (w2 == omega for requests without an async split).
        key = key + (qr.max_deep_every, qr.w2)
    return key


def exact_fingerprint(req: AdviceRequest) -> Tuple:
    """Zero-width cache key: merges only bit-identical platforms.

    Used for entries whose lattice cell failed certification — repeats of
    the same request still hit, but nothing is shared across a cell.
    """
    tiers = tuple((t.C, t.R, t.D, t.P_io, t.q) for t in req.tiers)
    key = ("exact", "2l" if req.is_multilevel else "1l", req.mu, tiers,
           req.omega, req.P_static, req.P_cal, req.P_down, req.process,
           req.process_param)
    if req.is_multilevel:
        key = key + (req.max_deep_every, req.w2)
    return key


# ---------------------------------------------------------------------------
# Certified bound: axis-edge sweep of the exact closed forms (host numpy).
# ---------------------------------------------------------------------------

_SINGLE_LOG_FIELDS = ("C", "R", "D", "mu", "P_static", "P_cal", "P_io",
                      "P_down")
_SINGLE_LIN_FIELDS = ("omega",)
_ML_LOG_FIELDS = ("C1", "R1", "D1", "C2", "R2", "D2", "mu", "P_static",
                  "P_cal", "P_io1", "P_io2", "P_down")
# the objectives read the per-level overlaps, not the shared ``omega``
# (omega1 carries the buddy overlap, omega2 the deep flush), so those are
# the axes the certificate must sweep.
_ML_LIN_FIELDS = ("omega1", "omega2", "q")


def _log_span(objective, fields: dict, q: Quantization, log_fields,
              lin_fields) -> np.ndarray:
    """Per-point worst-case sum of axis log-ratios ``L`` (vectorized).

    ``objective(p)`` maps a param dict (numpy arrays) to the objective
    value at the (fixed) served operating point.  Points where any
    perturbed evaluation leaves the model's domain (objective <= 0 or
    non-finite) get ``L = inf`` — the certificate fails closed.
    """
    J0 = np.asarray(objective(fields), dtype=np.float64)
    bad = ~np.isfinite(J0) | (J0 <= 0.0)
    logJ0 = np.log(np.where(bad, 1.0, J0))
    L = np.zeros_like(logJ0)
    half_log = 0.5 * math.log1p(q.rel)
    for name in log_fields:
        if q.rel <= 0.0:
            break
        span = np.zeros_like(logJ0)
        for s in (half_log, -half_log):
            p = dict(fields)
            p[name] = fields[name] * math.exp(s)
            J = np.asarray(objective(p), dtype=np.float64)
            ok = np.isfinite(J) & (J > 0.0)
            bad |= ~ok
            span = np.maximum(span,
                              np.abs(np.log(np.where(ok, J, 1.0)) - logJ0))
        L += span
    for name in lin_fields:
        if q.absolute <= 0.0:
            break
        span = np.zeros_like(logJ0)
        for s in (0.5 * q.absolute, -0.5 * q.absolute):
            p = dict(fields)
            p[name] = np.clip(fields[name] + s, 0.0, 1.0)
            J = np.asarray(objective(p), dtype=np.float64)
            ok = np.isfinite(J) & (J > 0.0)
            bad |= ~ok
            span = np.maximum(span,
                              np.abs(np.log(np.where(ok, J, 1.0)) - logJ0))
        L += span
    return np.where(bad, np.inf, L)


def certified_bound_single(fields: dict, T_time: np.ndarray,
                           T_energy: np.ndarray,
                           q: Quantization) -> np.ndarray:
    """Per-point certified degradation bound for single-level entries.

    ``fields`` holds the QUANTIZED platform arrays (the 9 ``ParamGrid``
    fields, numpy float64); ``T_time``/``T_energy`` the served optima at
    ``T_base = 1``.  Returns ``expm1(2 * safety * L)`` with ``L`` the
    worse of the two objectives' axis spans — one number certifying the
    entry for BOTH objectives.
    """
    T_time = np.asarray(T_time, dtype=np.float64)
    T_energy = np.asarray(T_energy, dtype=np.float64)
    L_t = _log_span(lambda p: _sweep.time_final_batched(T_time, p),
                    fields, q, _SINGLE_LOG_FIELDS, _SINGLE_LIN_FIELDS)
    L_e = _log_span(lambda p: _sweep.energy_final_batched(T_energy, p),
                    fields, q, _SINGLE_LOG_FIELDS, _SINGLE_LIN_FIELDS)
    L = np.maximum(L_t, L_e)
    with np.errstate(over="ignore"):
        return np.where(np.isfinite(L),
                        np.expm1(2.0 * _CELL_SAFETY * L), np.inf)


def certified_bound_multilevel(fields: dict, T_time: np.ndarray,
                               m_time: np.ndarray, T_energy: np.ndarray,
                               m_energy: np.ndarray,
                               q: Quantization) -> np.ndarray:
    """Per-point certified bound for two-tier ``(T, m)`` entries.

    Same sandwich argument with the operating point ``(T^, m^)`` held
    fixed; the cadence is discrete and identical on both sides of every
    comparison, so only the objective's parameter sensitivity enters.
    """
    T_time = np.asarray(T_time, dtype=np.float64)
    T_energy = np.asarray(T_energy, dtype=np.float64)
    m_t = np.asarray(m_time, dtype=np.float64)
    m_e = np.asarray(m_energy, dtype=np.float64)
    L_t = _log_span(lambda p: _sweep.ml_time_final_batched(T_time, m_t, p),
                    fields, q, _ML_LOG_FIELDS, _ML_LIN_FIELDS)
    L_e = _log_span(
        lambda p: _sweep.ml_energy_final_batched(T_energy, m_e, p),
        fields, q, _ML_LOG_FIELDS, _ML_LIN_FIELDS)
    L = np.maximum(L_t, L_e)
    with np.errstate(over="ignore"):
        return np.where(np.isfinite(L),
                        np.expm1(2.0 * _CELL_SAFETY * L), np.inf)
