"""Checkpoint-advisor serving subsystem (ROADMAP item 1).

Turns the batched solver stack into a query-serving path: running jobs
ask "what period / how many levels / which store" and get the paper's
AlgoT/AlgoE answer (single- or two-level) from an in-process service
that admission-batches concurrent requests into ONE dispatched grid
solve and fronts it with a fingerprint cache whose quantization error is
certified against a documented tolerance.

    schema      — AdviceRequest / Advice / StoreTier dataclasses.
    fingerprint — quantized cache keys + the sandwich-lemma certificate.
    batcher     — heterogeneous requests -> ParamGrid/MultilevelParamGrid.
    service     — AdvisorService (sync) and ThreadedAdvisor (batching).
    loadgen     — synthetic open-loop load generator + LoadReport.

See ``docs/serving.md`` for the serving contract and knobs.
"""
from .schema import (DEFAULT_MAX_DEEP_EVERY, Advice, AdviceRequest,
                     StoreTier, store_recommendation)
from .fingerprint import (Quantization, certified_bound_multilevel,
                          certified_bound_single, exact_fingerprint,
                          fingerprint, quantize_request, quantized_key)
from .batcher import BatchPlan, multilevel_grid, plan_batch, single_grid
from .service import (FINGERPRINT_CACHE_SIZE, AdvisorService,
                      ThreadedAdvisor)
from .loadgen import LoadReport, run_open_loop, synthetic_requests
