"""Synthetic open-loop load generator for the advisor service.

Open loop means arrivals follow a fixed schedule (``rate_hz``) that does
NOT slow down when the server lags — the honest way to measure a serving
path, since closed-loop generators hide queueing collapse by waiting for
the previous answer before issuing the next request.  Latency of request
``i`` is measured from its SCHEDULED arrival time to its future's
completion, so schedule slip shows up as latency, not as a lower rate.

``synthetic_requests`` draws platforms log-uniformly around the paper's
ranges (MTBFs from minutes to days, checkpoint costs seconds to tens of
minutes, the rho sweep of power envelopes), with knobs for the two-tier
fraction and for a repeated-workload fraction that exercises the
fingerprint cache's hit path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import numpy as np

from .schema import AdviceRequest, StoreTier
from .service import ThreadedAdvisor


def synthetic_requests(n: int, seed: int = 0, two_tier_frac: float = 0.5,
                       repeat_frac: float = 0.0,
                       objectives: Sequence[str] = ("time", "energy"),
                       ) -> List[AdviceRequest]:
    """Draw ``n`` requests; deterministic in ``seed``.

    ``repeat_frac`` of the requests (after the first) duplicate an
    earlier draw's platform — the cache-hit knob of the load benchmark.
    Duplicates may still differ in ``objective`` and ``T_base``, which
    the fingerprint ignores (that's the point).
    """
    rng = np.random.default_rng(seed)
    reqs: List[AdviceRequest] = []
    for i in range(n):
        if reqs and rng.random() < repeat_frac:
            src = reqs[int(rng.integers(len(reqs)))]
            reqs.append(dataclasses.replace(
                src, objective=str(rng.choice(objectives)),
                T_base=float(rng.uniform(0.5, 50.0))))
            continue
        mu = float(np.exp(rng.uniform(np.log(600.0), np.log(172800.0))))
        # deep-tier checkpoint cost: seconds to tens of minutes, kept
        # clear of the degenerate C ~ mu regime so most draws are valid.
        C2 = float(np.exp(rng.uniform(np.log(5.0),
                                      np.log(min(1800.0, mu / 12.0)))))
        omega = float(rng.uniform(0.0, 1.0))
        rho = float(rng.uniform(0.2, 1.0))
        P_static, P_cal = 10.0, 10.0
        P_io2 = P_cal / rho
        deep = StoreTier(name="pfs", C=C2, R=C2 * float(rng.uniform(0.8, 1.5)),
                         D=C2 * float(rng.uniform(0.0, 0.5)), P_io=P_io2)
        two = rng.random() < two_tier_frac
        if two:
            ratio = float(rng.uniform(0.02, 0.5))   # buddy write / PFS write
            C1 = C2 * ratio
            fast = StoreTier(name="buddy", C=C1,
                             R=C1 * float(rng.uniform(0.8, 1.5)),
                             D=C1 * float(rng.uniform(0.0, 0.5)),
                             P_io=P_io2 * float(rng.uniform(0.3, 1.0)),
                             q=float(rng.uniform(0.0, 0.2)))
            tiers = (fast, deep)
        else:
            tiers = (deep,)
        reqs.append(AdviceRequest(
            mu=mu, tiers=tiers, omega=omega, P_static=P_static,
            P_cal=P_cal, P_down=float(rng.choice([0.0, P_static])),
            objective=str(rng.choice(objectives)),
            T_base=float(rng.uniform(0.5, 50.0))))
    return reqs


@dataclasses.dataclass(frozen=True)
class LoadReport:
    """One open-loop run's measurements (latencies in milliseconds)."""

    n: int
    duration_s: float
    rps: float
    p50_ms: float
    p99_ms: float
    mean_ms: float
    max_ms: float
    hit_rate: float
    windows: int
    mean_window: float

    def summary(self) -> dict:
        return dataclasses.asdict(self)


def run_open_loop(advisor: ThreadedAdvisor,
                  requests: Sequence[AdviceRequest],
                  rate_hz: float,
                  warmup: Optional[Sequence[AdviceRequest]] = None,
                  ) -> LoadReport:
    """Drive ``advisor`` with a fixed-rate schedule; measure rps + tails.

    ``warmup`` requests (if any) are served first, outside the measured
    window — use them to pay one-time JIT compiles, or to pre-populate
    the cache for hit-regime measurements.
    """
    if rate_hz <= 0.0:
        raise ValueError("rate_hz must be > 0")
    if warmup:
        advisor.service.advise_many(list(warmup))
    m0 = advisor.metrics()
    done = [0.0] * len(requests)
    futs = []
    start = time.monotonic()
    sched = [start + i / rate_hz for i in range(len(requests))]

    def _mark(i):
        def cb(_fut):
            done[i] = time.monotonic()
        return cb

    for i, req in enumerate(requests):
        delay = sched[i] - time.monotonic()
        if delay > 0.0:
            time.sleep(delay)
        fut = advisor.submit(req)
        fut.add_done_callback(_mark(i))
        futs.append(fut)
    for fut in futs:
        fut.result()                    # re-raises worker errors
    end = time.monotonic()
    m1 = advisor.metrics()

    lat_ms = 1e3 * (np.array(done) - np.array(sched))
    lookups = (m1["fingerprint_cache"]["lookups"]
               - m0["fingerprint_cache"]["lookups"])
    hits = (m1["fingerprint_cache"]["hits"]
            - m0["fingerprint_cache"]["hits"])
    windows = m1["windows"] - m0["windows"]
    duration = end - start
    return LoadReport(
        n=len(requests), duration_s=duration,
        rps=len(requests) / duration if duration > 0 else float("inf"),
        p50_ms=float(np.percentile(lat_ms, 50)),
        p99_ms=float(np.percentile(lat_ms, 99)),
        mean_ms=float(lat_ms.mean()), max_ms=float(lat_ms.max()),
        hit_rate=hits / lookups if lookups else 0.0,
        windows=windows,
        mean_window=len(requests) / windows if windows else 0.0)
