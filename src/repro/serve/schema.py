"""Request/response schema of the checkpoint-advisor service.

An :class:`AdviceRequest` describes one running job's platform — its MTBF,
the checkpoint storage tiers it can write to, its power envelope, and a
failure-process hint — plus what it wants optimized ("time" or "energy").
The service answers with an :class:`Advice`: the checkpoint period, the
deep-checkpoint cadence, which store tier(s) to use, and the predicted
makespan/energy at that operating point.

Two shapes of request:

one tier
    Single-level checkpointing (the paper's model): the advisor returns
    the AlgoT/AlgoE period for that tier's (C, R, D, P_io).

two tiers (fast -> deep)
    Buddy + PFS hierarchy (the VELOC shape): every period ends with a
    fast-tier write, every ``m``-th one with a deep write; the advisor
    jointly optimizes (T, m) and recommends whether the hierarchy
    actually beats deep-only on this platform.

Unit contract: all durations (C, R, D, mu, T_base and the returned
period) share one time unit; powers share one power unit — exactly the
``core.params`` convention.

``T_base`` never changes the recommendation: both objectives are
homogeneous of degree 1 in ``T_base`` (every term of T_final and E_final
scales linearly with the amount of work), so the optimal (T, m) is
``T_base``-invariant and the service solves at ``T_base = 1`` and scales
the predicted totals.  This is also why ``T_base`` is excluded from the
cache fingerprint (see ``serve.fingerprint``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from ..core.params import (CheckpointParams, MultilevelCheckpointParams,
                           MultilevelPowerParams, PowerParams)

#: default cap on the deep-checkpoint cadence candidates for two-tier
#: requests (matches ``sim.evaluate_multilevel_grid``'s default range).
DEFAULT_MAX_DEEP_EVERY = 12

_OBJECTIVES = ("time", "energy")


@dataclasses.dataclass(frozen=True)
class StoreTier:
    """One checkpoint storage tier offered to the advisor.

    ``C``/``R``: write/read duration; ``D``: downtime after a failure
    recovered from this tier; ``P_io``: I/O overhead power while
    writing/reading it; ``q``: probability a failure also destroys this
    tier's copy (only meaningful for the FAST tier of a two-tier request
    — e.g. both nodes of a buddy pair dying; the deep tier is assumed
    durable).
    """

    name: str
    C: float
    R: float
    D: float
    P_io: float
    q: float = 0.0

    def __post_init__(self):
        for f in ("C", "R", "D", "P_io"):
            v = getattr(self, f)
            if not (isinstance(v, (int, float)) and math.isfinite(v)
                    and v >= 0.0):
                raise ValueError(f"tier {self.name!r}: {f} must be a finite "
                                 f"number >= 0, got {v!r}")
        if not (0.0 <= self.q <= 1.0):
            raise ValueError(f"tier {self.name!r}: q must be in [0,1], "
                             f"got {self.q!r}")


@dataclasses.dataclass(frozen=True)
class AdviceRequest:
    """One "what period / how many levels / which store" query.

    ``tiers`` is ordered fast -> deep; one tier means single-level
    checkpointing, two means a buddy+PFS hierarchy whose deep cadence
    ``m`` the advisor chooses (up to ``max_deep_every``).

    ``process``/``process_param`` is the failure-process hint
    (``"exponential"``, ``"weibull"`` with shape, ``"lognormal"`` with
    sigma).  The served periods are the exponential closed forms — the
    hint is part of the cache identity and is echoed back with
    ``Advice.closed_form_exact`` so callers know when the answer carries
    the (small, quantified) non-exponential model bias; re-solving under
    a fitted process posterior is the online-adaptation roadmap item.
    """

    mu: float
    tiers: Tuple[StoreTier, ...]
    omega: float = 0.5
    #: deep-flush overlap factor of a two-tier request (VELOC async
    #: flush); None -> the shared ``omega`` applies to both tiers.
    omega2: Optional[float] = None
    P_static: float = 10.0
    P_cal: float = 10.0
    P_down: float = 0.0
    objective: str = "energy"
    T_base: float = 1.0
    process: str = "exponential"
    process_param: float = 1.0
    max_deep_every: int = DEFAULT_MAX_DEEP_EVERY

    def __post_init__(self):
        object.__setattr__(self, "tiers", tuple(self.tiers))
        if not 1 <= len(self.tiers) <= 2:
            raise ValueError(f"need 1 (single-level) or 2 (buddy+deep) "
                             f"tiers, got {len(self.tiers)}")
        if self.objective not in _OBJECTIVES:
            raise ValueError(f"objective must be one of {_OBJECTIVES}, "
                             f"got {self.objective!r}")
        if not (math.isfinite(self.mu) and self.mu > 0.0):
            raise ValueError(f"mu must be > 0, got {self.mu!r}")
        if not (0.0 <= self.omega <= 1.0):
            raise ValueError(f"omega must be in [0,1], got {self.omega!r}")
        if self.omega2 is not None and not (0.0 <= self.omega2 <= 1.0):
            raise ValueError(f"omega2 must be in [0,1] or None, "
                             f"got {self.omega2!r}")
        if not (math.isfinite(self.T_base) and self.T_base > 0.0):
            raise ValueError(f"T_base must be > 0, got {self.T_base!r}")
        if self.P_static <= 0.0:
            raise ValueError("P_static must be > 0")
        if min(self.P_cal, self.P_down) < 0.0:
            raise ValueError("powers must be >= 0")
        if not 1 <= self.max_deep_every <= DEFAULT_MAX_DEEP_EVERY:
            # The advisor's cadence candidate set is fixed at
            # 1..DEFAULT_MAX_DEEP_EVERY so batch composition never
            # changes a lane's compiled program (see serve.batcher);
            # caps act through the per-lane m_max mask only.
            raise ValueError(f"max_deep_every must be in "
                             f"[1, {DEFAULT_MAX_DEEP_EVERY}], "
                             f"got {self.max_deep_every}")

    # -- shape ----------------------------------------------------------------
    @property
    def is_multilevel(self) -> bool:
        return len(self.tiers) == 2

    @property
    def fast(self) -> StoreTier:
        return self.tiers[0]

    @property
    def deep(self) -> StoreTier:
        return self.tiers[-1]

    @property
    def w2(self) -> float:
        """Effective deep-flush overlap (``omega2``, defaulting to
        ``omega`` — mirrors ``MultilevelCheckpointParams.w2``)."""
        return self.omega if self.omega2 is None else self.omega2

    # -- conversions to the core parameter objects ---------------------------
    def single_params(self) -> Tuple[CheckpointParams, PowerParams]:
        """The (ckpt, power) pair of a one-tier request."""
        t = self.tiers[0]
        return (CheckpointParams(C=t.C, R=t.R, D=t.D, mu=self.mu,
                                 omega=self.omega),
                PowerParams(P_static=self.P_static, P_cal=self.P_cal,
                            P_io=t.P_io, P_down=self.P_down))

    def multilevel_params(self) -> Tuple[MultilevelCheckpointParams,
                                         MultilevelPowerParams]:
        """The two-level (ckpt, power) pair of a two-tier request."""
        t1, t2 = self.tiers
        return (MultilevelCheckpointParams(
                    C1=t1.C, R1=t1.R, D1=t1.D, C2=t2.C, R2=t2.R, D2=t2.D,
                    mu=self.mu, q=t1.q, omega=self.omega,
                    omega2=self.omega2),
                MultilevelPowerParams(P_static=self.P_static,
                                      P_cal=self.P_cal, P_io1=t1.P_io,
                                      P_io2=t2.P_io, P_down=self.P_down))

    # -- constructors ---------------------------------------------------------
    @classmethod
    def from_params(cls, ckpt: CheckpointParams, power: PowerParams,
                    tier_name: str = "pfs", **kwargs) -> "AdviceRequest":
        """Single-level request from the core parameter objects."""
        return cls(mu=ckpt.mu, omega=ckpt.omega,
                   tiers=(StoreTier(name=tier_name, C=ckpt.C, R=ckpt.R,
                                    D=ckpt.D, P_io=power.P_io),),
                   P_static=power.P_static, P_cal=power.P_cal,
                   P_down=power.P_down, **kwargs)

    @classmethod
    def from_multilevel_params(cls, ckpt: MultilevelCheckpointParams,
                               power: MultilevelPowerParams,
                               fast_name: str = "buddy",
                               deep_name: str = "pfs",
                               **kwargs) -> "AdviceRequest":
        """Two-tier request from the core multilevel parameter objects."""
        return cls(mu=ckpt.mu, omega=ckpt.w1,
                   omega2=None if ckpt.w2 == ckpt.w1 else ckpt.w2,
                   tiers=(StoreTier(name=fast_name, C=ckpt.C1, R=ckpt.R1,
                                    D=ckpt.D1, P_io=power.P_io1, q=ckpt.q),
                          StoreTier(name=deep_name, C=ckpt.C2, R=ckpt.R2,
                                    D=ckpt.D2, P_io=power.P_io2)),
                   P_static=power.P_static, P_cal=power.P_cal,
                   P_down=power.P_down, **kwargs)


@dataclasses.dataclass(frozen=True)
class Advice:
    """The served recommendation for one :class:`AdviceRequest`.

    ``period``/``deep_every``/``store`` are the operating point for the
    request's objective; the cross-objective optima (``T_time``,
    ``T_energy`` and their cadences) ride along so a caller can price the
    switch without a second request.  ``predicted_wall`` and
    ``predicted_energy`` are the model expectations AT the served point,
    scaled to the request's ``T_base``.

    ``cert_bound`` is the certified quantization-degradation bound of the
    fingerprint cache (see ``serve.fingerprint``): the served objective
    value is within ``cert_bound`` (relatively) of the request's exact
    optimum, and the service guarantees ``cert_bound <= tol`` (requests
    whose cell cannot be certified are solved exactly; ``exact=True``,
    ``cert_bound=0``).

    ``valid=False`` marks degenerate platforms (no usable period: C of
    the order of the MTBF even for the best tier); the served period then
    follows the sweep convention (T = C, ratios 1) and the predictions
    are NaN.
    """

    objective: str
    period: float
    deep_every: int
    store: str
    predicted_wall: float
    predicted_energy: float
    T_time: float
    T_energy: float
    m_time: int
    m_energy: int
    vs_single: float
    valid: bool
    cache_hit: bool
    cert_bound: float
    exact: bool
    closed_form_exact: bool
    process: str = "exponential"

    @property
    def wall_overhead(self) -> float:
        """Predicted makespan inflation over failure-free execution."""
        return self.predicted_wall  # already in units of T_base-scaled time


def store_recommendation(req: AdviceRequest, deep_every: int) -> str:
    """Human-readable store recommendation string.

    For two-tier requests, ``deep_every == 1`` means every checkpoint is
    deep — the fast tier is never the recovery source and the honest
    recommendation is the deep tier alone.
    """
    if not req.is_multilevel:
        return req.tiers[0].name
    if deep_every == 1:
        return req.deep.name
    return f"{req.fast.name}+{req.deep.name}:deep_every={deep_every}"
