"""Admission batching: many heterogeneous requests -> one grid solve.

The batcher turns a set of QUANTIZED requests (cache misses of one
admission window) into the struct-of-arrays grids the sweep layer
consumes, so a whole burst is answered by one dispatched
``evaluate_grid`` call (single-level group) plus at most one
``evaluate_multilevel_grid`` call (two-tier group).

Heterogeneity is handled in two ways:

dedup
    Requests sharing a fingerprint collapse to one grid lane; the plan
    records the lane index of every fingerprint.

cadence masking (two-tier)
    Two-tier requests may cap the deep cadence differently
    (``max_deep_every``).  The group always solves the FIXED candidate
    set ``1..DEFAULT_MAX_DEEP_EVERY`` in one compiled program and masks
    each lane down to its own cap via the sweep layer's per-point
    ``m_max`` argument — no per-cap program splits, and (because the
    mask is an array input, not a compile-shape change) each lane's
    answer is bit-identical to the solve it would have gotten alone.

Lane order is the first-seen order of fingerprints, which together with
the dispatch layer's lane-padding quantum makes batch composition a
bit-exact no-op: a request's lane sees the same values whether it is
solved alone or inside any burst.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..sim.scenarios import MultilevelParamGrid, ParamGrid
from .schema import DEFAULT_MAX_DEEP_EVERY, AdviceRequest

_SINGLE_FIELDS = ("C", "R", "D", "mu", "omega", "P_static", "P_cal",
                  "P_io", "P_down")
_ML_FIELDS = ("C1", "R1", "D1", "C2", "R2", "D2", "mu", "omega", "q",
              "P_static", "P_cal", "P_io1", "P_io2", "P_down",
              "omega1", "omega2")


def _single_row(req: AdviceRequest) -> Tuple[float, ...]:
    t = req.tiers[0]
    return (t.C, t.R, t.D, req.mu, req.omega, req.P_static, req.P_cal,
            t.P_io, req.P_down)


def _ml_row(req: AdviceRequest) -> Tuple[float, ...]:
    t1, t2 = req.tiers
    return (t1.C, t1.R, t1.D, t2.C, t2.R, t2.D, req.mu, req.omega, t1.q,
            req.P_static, req.P_cal, t1.P_io, t2.P_io, req.P_down,
            req.omega, req.w2)


def single_grid(reqs: Sequence[AdviceRequest]) -> ParamGrid:
    """1-D :class:`ParamGrid` with one lane per request, in order."""
    rows = np.array([_single_row(r) for r in reqs], dtype=np.float64)
    return ParamGrid(**{f: rows[:, i]
                        for i, f in enumerate(_SINGLE_FIELDS)})


def multilevel_grid(reqs: Sequence[AdviceRequest]) -> Tuple[
        MultilevelParamGrid, Tuple[int, ...], np.ndarray]:
    """1-D two-level grid + union cadence set + per-lane cadence cap.

    Returns ``(grid, m_values, m_max)`` ready for
    ``evaluate_multilevel_grid(grid, m_values=m_values, m_max=m_max)``.
    """
    rows = np.array([_ml_row(r) for r in reqs], dtype=np.float64)
    grid = MultilevelParamGrid(**{f: rows[:, i]
                                  for i, f in enumerate(_ML_FIELDS)})
    caps = np.array([r.max_deep_every for r in reqs], dtype=np.int64)
    # The candidate set is FIXED at 1..DEFAULT_MAX_DEEP_EVERY (the schema
    # bounds every request's cap by it); per-request caps act only
    # through the m_max mask — an array input, not a compile-shape
    # change — so a lane's answer is bit-identical whether it is solved
    # alone or inside any mix of cadence budgets.
    m_values = tuple(range(1, DEFAULT_MAX_DEEP_EVERY + 1))
    return grid, m_values, caps


@dataclasses.dataclass
class BatchPlan:
    """Deduped solve plan of one admission window.

    ``single_lanes`` / ``ml_lanes`` map each distinct fingerprint to its
    grid lane; ``single_reqs`` / ``ml_reqs`` hold the lane-ordered
    quantized representatives the grids were built from.
    """

    single_lanes: Dict[Tuple, int]
    single_reqs: List[AdviceRequest]
    ml_lanes: Dict[Tuple, int]
    ml_reqs: List[AdviceRequest]

    @property
    def n_lanes(self) -> int:
        return len(self.single_reqs) + len(self.ml_reqs)

    def grids(self) -> Tuple[Optional[ParamGrid],
                             Optional[MultilevelParamGrid],
                             Tuple[int, ...], Optional[np.ndarray]]:
        pg = single_grid(self.single_reqs) if self.single_reqs else None
        if self.ml_reqs:
            mg, m_values, m_max = multilevel_grid(self.ml_reqs)
        else:
            mg, m_values, m_max = None, (), None
        return pg, mg, m_values, m_max


def plan_batch(keyed_reqs: Sequence[Tuple[Tuple, AdviceRequest]]
               ) -> BatchPlan:
    """Dedup ``(fingerprint, quantized request)`` pairs into a solve plan.

    Lane order is first-seen fingerprint order, independently for the
    single-level and two-tier groups.
    """
    plan = BatchPlan(single_lanes={}, single_reqs=[], ml_lanes={},
                     ml_reqs=[])
    for fp, qr in keyed_reqs:
        if qr.is_multilevel:
            if fp not in plan.ml_lanes:
                plan.ml_lanes[fp] = len(plan.ml_reqs)
                plan.ml_reqs.append(qr)
        else:
            if fp not in plan.single_lanes:
                plan.single_lanes[fp] = len(plan.single_reqs)
                plan.single_reqs.append(qr)
    return plan
