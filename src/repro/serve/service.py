"""The advisor: fingerprint cache in front of micro-batched grid solves.

:class:`AdvisorService` is the in-process query engine.  One
``advise_many`` call is one admission window: every request is
fingerprinted (``serve.fingerprint``), hits are answered from the cache,
and ALL misses collapse into one dispatched ``evaluate_grid`` call (plus
at most one ``evaluate_multilevel_grid`` call when the window contains
two-tier requests) through ``sim/dispatch.py`` — the solve cost of a
window is bounded by the number of DISTINCT platforms in it, not the
number of requests.

Answer semantics (what the tests pin down):

* Every cache entry is the exact solve of its cell's lattice
  representative, so all requests sharing a fingerprint get bit-identical
  numbers — hit or miss, batched or sequential, any batch composition
  (the dispatch layer's lane-padding quantum makes batch shape a
  bit-exact no-op).
* An entry is only served if its certified degradation bound (the
  sandwich lemma of ``serve.fingerprint``) is within ``quant.tol``;
  otherwise the request is solved on its EXACT parameters (one more
  batched call per window, shared by all fallback requests) and cached
  under a zero-width key.  Degenerate/uncertifiable cells therefore
  always get exact-parameter answers.

:class:`ThreadedAdvisor` wraps a service with a submission queue and a
worker thread that admission-batches concurrent callers behind a small
batch window — the serving shape the open-loop load generator
(``serve.loadgen``) drives.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from concurrent.futures import Future
from time import monotonic
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..sim import dispatch as _dispatch
from ..sim import sweep as _sweep
from . import batcher as _batcher
from .fingerprint import (Quantization, certified_bound_multilevel,
                          certified_bound_single, exact_fingerprint,
                          quantize_request, quantized_key)
from .schema import Advice, AdviceRequest, store_recommendation

#: default fingerprint-cache capacity (entries are a few hundred bytes).
FINGERPRINT_CACHE_SIZE = 4096


@dataclasses.dataclass(frozen=True)
class _Entry:
    """One cached answer (always at ``T_base = 1``)."""

    valid: bool
    certified: bool
    exact: bool
    cert_bound: float
    T_time: float
    T_energy: float
    m_time: int
    m_energy: int
    Tf_time: float
    Tf_energy: float
    E_time: float
    E_energy: float
    vs_single_time: float
    vs_single_energy: float


class AdvisorService:
    """In-process checkpoint advisor (see module docstring).

    ``quantization`` sets the cache lattice and tolerance
    (:class:`~repro.serve.fingerprint.Quantization`); ``dispatch`` is the
    execution config threaded to the sweep layer (None = environment
    defaults); ``precision`` is the sweep precision policy (None resolves
    via the dispatch config / ``$REPRO_PRECISION`` / backend default, so
    CPU services stay on the bit-exact f64 oracle) — a non-exact policy's
    ``objective_tol`` is folded into every certified bound; ``cache_name``
    registers the fingerprint cache with
    ``sim.cache_stats`` (one registry slot per name — the last service
    created under a name owns the slot).

    Thread-safe: ``advise_many`` holds an internal lock, so concurrent
    direct callers serialize.  For concurrency WITH admission batching,
    front it with :class:`ThreadedAdvisor`.
    """

    def __init__(self, quantization: Optional[Quantization] = None,
                 cache_size: int = FINGERPRINT_CACHE_SIZE,
                 dispatch=None, precision=None,
                 cache_name: Optional[str] = "serve.fingerprints"):
        self.quant = quantization if quantization is not None \
            else Quantization()
        self.cache = _dispatch.LRUCache(cache_size, name=cache_name)
        self.dispatch = dispatch
        # Resolved once at construction so every solve this service issues
        # runs under ONE policy (entries cache objective values; mixing
        # policies across windows would mix tolerances in the cache).
        self.precision = _dispatch.resolve_precision(dispatch, precision)
        self._lock = threading.Lock()
        self._counters = {
            "requests": 0,          # requests answered
            "batches": 0,           # advise_many admission windows
            "dispatched_solves": 0,  # batched sweep calls issued
            "solved_lanes": 0,      # grid lanes across those calls
            "fallback_requests": 0,  # requests served via the exact path
        }

    # -- public API ----------------------------------------------------------
    def advise(self, req: AdviceRequest) -> Advice:
        """Answer one request (a batch of one)."""
        return self.advise_many([req])[0]

    def advise_many(self, reqs: Sequence[AdviceRequest]) -> List[Advice]:
        """Answer a whole admission window; one batched solve per shape."""
        with self._lock:
            return self._advise_many(list(reqs))

    def metrics(self) -> Dict:
        """Service counters + fingerprint/runner cache statistics."""
        with self._lock:
            out = dict(self._counters)
        out["fingerprint_cache"] = dict(self.cache.stats.snapshot(),
                                        size=len(self.cache),
                                        maxsize=self.cache.maxsize)
        out["caches"] = _dispatch.cache_stats()
        out["precision_policy"] = self.precision.name
        return out

    # -- pipeline ------------------------------------------------------------
    def _advise_many(self, reqs: List[AdviceRequest]) -> List[Advice]:
        self._counters["requests"] += len(reqs)
        self._counters["batches"] += 1
        quant = self.quant

        # Phase 1 — fingerprint + cache lookup.  resolution[i] is either
        # (entry, cache_hit) or None (pending a solve this window).
        resolution: List[Optional[Tuple[_Entry, bool]]] = [None] * len(reqs)
        miss: Dict[Tuple, AdviceRequest] = {}   # fp -> quantized rep
        miss_of: List[Optional[Tuple]] = [None] * len(reqs)
        exact_idx: List[int] = []
        for i, r in enumerate(reqs):
            qr = quantize_request(r, quant)
            fp = quantized_key(qr)
            if fp in miss:                  # same cell, earlier this window
                miss_of[i] = fp
                continue
            e = self.cache.get(fp)
            if e is None:
                miss[fp] = qr
                miss_of[i] = fp
            elif e.certified:
                resolution[i] = (e, True)
            else:                           # known-uncertifiable cell
                exact_idx.append(i)

        # Phase 2 — ONE batched solve per request shape for all misses.
        if miss:
            solved = self._solve(list(miss.items()), exact=False)
            for i, fp in enumerate(miss_of):
                if fp is None or resolution[i] is not None:
                    continue
                e = solved[fp]
                if e.certified:
                    resolution[i] = (e, False)
                else:
                    exact_idx.append(i)

        # Phase 3 — exact-parameter path for uncertifiable cells.
        if exact_idx:
            self._counters["fallback_requests"] += len(exact_idx)
            need: Dict[Tuple, AdviceRequest] = {}
            for i in exact_idx:
                efp = exact_fingerprint(reqs[i])
                e = self.cache.get(efp)
                if e is not None:
                    resolution[i] = (e, True)
                elif efp not in need:
                    need[efp] = dataclasses.replace(reqs[i], T_base=1.0)
            if need:
                solved = self._solve(list(need.items()), exact=True)
                for i in exact_idx:
                    if resolution[i] is None:
                        resolution[i] = (solved[exact_fingerprint(reqs[i])],
                                         False)

        return [self._advice(r, *resolution[i])
                for i, r in enumerate(reqs)]

    def _solve(self, keyed: List[Tuple[Tuple, AdviceRequest]],
               exact: bool) -> Dict[Tuple, _Entry]:
        """Solve deduped (key, request) pairs; insert + return entries."""
        plan = _batcher.plan_batch(keyed)
        pg, mg, m_values, m_max = plan.grids()
        self._counters["solved_lanes"] += plan.n_lanes
        out: Dict[Tuple, _Entry] = {}

        if pg is not None:
            res = _sweep.evaluate_grid(pg, T_base=1.0,
                                       dispatch=self.dispatch,
                                       precision=self.precision)
            self._counters["dispatched_solves"] += 1
            if exact:
                cert = np.zeros(pg.size)
            else:
                cert = certified_bound_single(
                    pg.fields(), res.T_time, res.T_energy, self.quant)
                # A reduced-precision solve can misplace the optimum by
                # up to objective_tol (relative); fold that into the
                # certified bound so certification TIGHTENS under f32
                # instead of silently eroding.
                cert = cert + self.precision.objective_tol
            for fp, lane in plan.single_lanes.items():
                out[fp] = self._entry_single(res, lane, float(cert[lane]),
                                             exact)
        if mg is not None:
            res = _sweep.evaluate_multilevel_grid(
                mg, m_values=m_values, T_base=1.0,
                dispatch=self.dispatch, m_max=m_max,
                precision=self.precision)
            self._counters["dispatched_solves"] += 1
            if exact:
                cert = np.zeros(mg.size)
            else:
                cert = certified_bound_multilevel(
                    mg.fields(), res.T_time, res.m_time, res.T_energy,
                    res.m_energy, self.quant)
                cert = cert + self.precision.objective_tol
            for fp, lane in plan.ml_lanes.items():
                out[fp] = self._entry_ml(res, lane, float(cert[lane]),
                                         exact)
        for fp, e in out.items():
            self.cache.put(fp, e)
        return out

    def _entry_single(self, res, i: int, cert: float,
                      exact: bool) -> _Entry:
        valid = bool(res.valid[i])
        return _Entry(
            valid=valid,
            certified=exact or (valid and cert <= self.quant.tol),
            exact=exact, cert_bound=0.0 if exact else cert,
            T_time=float(res.T_time[i]), T_energy=float(res.T_energy[i]),
            m_time=1, m_energy=1,
            Tf_time=float(res.Tf_time[i]),
            Tf_energy=float(res.Tf_energy[i]),
            E_time=float(res.E_time[i]), E_energy=float(res.E_energy[i]),
            vs_single_time=float("nan"), vs_single_energy=float("nan"))

    def _entry_ml(self, res, i: int, cert: float, exact: bool) -> _Entry:
        valid = bool(res.valid[i])
        return _Entry(
            valid=valid,
            certified=exact or (valid and cert <= self.quant.tol),
            exact=exact, cert_bound=0.0 if exact else cert,
            T_time=float(res.T_time[i]), T_energy=float(res.T_energy[i]),
            m_time=int(res.m_time[i]), m_energy=int(res.m_energy[i]),
            Tf_time=float(res.Tf_time[i]),
            Tf_energy=float(res.Tf_energy[i]),
            E_time=float(res.E_time[i]), E_energy=float(res.E_energy[i]),
            vs_single_time=float(res.time_vs_single[i]),
            vs_single_energy=float(res.energy_vs_single[i]))

    def _advice(self, req: AdviceRequest, e: _Entry,
                cache_hit: bool) -> Advice:
        if req.objective == "time":
            T, m, vs = e.T_time, e.m_time, e.vs_single_time
        else:
            T, m, vs = e.T_energy, e.m_energy, e.vs_single_energy
        return Advice(
            objective=req.objective, period=T, deep_every=m,
            store=store_recommendation(req, m),
            predicted_wall=e.Tf_time * req.T_base
            if req.objective == "time" else e.Tf_energy * req.T_base,
            predicted_energy=e.E_time * req.T_base
            if req.objective == "time" else e.E_energy * req.T_base,
            T_time=e.T_time, T_energy=e.T_energy,
            m_time=e.m_time, m_energy=e.m_energy,
            vs_single=vs, valid=e.valid, cache_hit=cache_hit,
            cert_bound=e.cert_bound, exact=e.exact,
            closed_form_exact=(req.process == "exponential"),
            process=req.process)


_SENTINEL = object()


class ThreadedAdvisor:
    """Queue + worker front-end adding admission batching to a service.

    Callers :meth:`submit` requests and get ``Future``s; the worker
    drains the queue for up to ``batch_window_s`` after the first request
    arrives (or until ``max_batch`` requests are pending) and answers the
    whole window with one ``advise_many`` call.  The window trades a
    bounded latency floor for solve sharing — the load generator measures
    exactly this trade.
    """

    def __init__(self, service: AdvisorService,
                 batch_window_s: float = 0.002, max_batch: int = 512):
        if batch_window_s < 0.0:
            raise ValueError("batch_window_s must be >= 0")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.service = service
        self.batch_window_s = float(batch_window_s)
        self.max_batch = int(max_batch)
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._windows = 0
        self._windowed_requests = 0
        self._closed = False
        self._thread = threading.Thread(target=self._worker,
                                        name="advisor-worker", daemon=True)
        self._thread.start()

    # -- public API ----------------------------------------------------------
    def submit(self, req: AdviceRequest) -> "Future[Advice]":
        """Enqueue one request; resolves to its :class:`Advice`."""
        if self._closed:
            raise RuntimeError("advisor is closed")
        fut: "Future[Advice]" = Future()
        self._q.put((req, fut))
        return fut

    def advise(self, req: AdviceRequest) -> Advice:
        """Blocking convenience wrapper around :meth:`submit`."""
        return self.submit(req).result()

    def metrics(self) -> Dict:
        out = self.service.metrics()
        out["windows"] = self._windows
        out["mean_window"] = (self._windowed_requests / self._windows
                              if self._windows else 0.0)
        return out

    def close(self):
        """Drain outstanding work and stop the worker thread."""
        if not self._closed:
            self._closed = True
            self._q.put(_SENTINEL)
            self._thread.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- worker --------------------------------------------------------------
    def _worker(self):
        while True:
            item = self._q.get()
            if item is _SENTINEL:
                return
            batch = [item]
            stop = False
            deadline = monotonic() + self.batch_window_s
            while len(batch) < self.max_batch:
                remaining = deadline - monotonic()
                if remaining <= 0.0:
                    break
                try:
                    nxt = self._q.get(timeout=remaining)
                except queue.Empty:
                    break
                if nxt is _SENTINEL:
                    stop = True
                    break
                batch.append(nxt)
            self._windows += 1
            self._windowed_requests += len(batch)
            try:
                advices = self.service.advise_many([r for r, _ in batch])
            except BaseException as err:  # propagate to every caller
                for _, fut in batch:
                    fut.set_exception(err)
            else:
                for (_, fut), adv in zip(batch, advices):
                    fut.set_result(adv)
            if stop:
                return
