"""Core: the paper's analytical checkpoint time/energy model."""
from .params import (CheckpointParams, PowerParams, EXASCALE_POWER_RHO55,
                     EXASCALE_POWER_RHO7, MU_IND_JAGUAR_MIN,
                     fig12_checkpoint, fig3_checkpoint)
from .model import (time_final, time_fault_free, time_lost_per_failure,
                    phase_times, energy_final, energy_breakdown,
                    K_factor, K_dE_dT)
from .optimal import (t_opt_time, t_opt_time_numeric, t_opt_energy,
                      t_opt_energy_numeric, t_young, t_daly, t_msk_energy,
                      energy_quadratic_coefficients,
                      paper_printed_coefficients, period_for, STRATEGIES,
                      golden_section)
from .tradeoff import (TradeoffPoint, evaluate, sweep_rho, sweep_mu_rho,
                       sweep_nodes)
from .simulator import simulate, simulate_once, SimResult
from .policy import CheckpointPolicy, PolicyConfig
