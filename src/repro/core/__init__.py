"""Core: the paper's analytical checkpoint time/energy model."""
from .params import (CheckpointParams, MultilevelCheckpointParams,
                     MultilevelPowerParams, PowerParams,
                     EXASCALE_POWER_RHO55, EXASCALE_POWER_RHO7,
                     EXASCALE_ML_POWER, MU_IND_JAGUAR_MIN,
                     fig12_checkpoint, fig3_checkpoint)
from .failures import (FailureProcess, Exponential, Weibull, LogNormal,
                       TraceReplay, get_process, as_process)
from .model import (time_final, time_fault_free, time_lost_per_failure,
                    phase_times, energy_final, energy_breakdown,
                    K_factor, K_dE_dT,
                    ml_time_final, ml_phase_times, ml_energy_final,
                    ml_energy_breakdown, ml_energy_final_prime,
                    ml_K_factor, ml_K_dE_dT)
from .optimal import (t_opt_time, t_opt_time_ex, PeriodResult,
                      t_opt_time_numeric, t_opt_energy,
                      t_opt_energy_numeric, t_young, t_daly, t_msk_energy,
                      energy_quadratic_coefficients,
                      paper_printed_coefficients, period_for, STRATEGIES,
                      golden_section,
                      MCSurrogate, t_opt_time_mc, t_opt_energy_mc,
                      mc_evaluate_periods,
                      t_opt_time_multilevel, t_opt_energy_multilevel,
                      ml_energy_quadratic_coefficients, DEFAULT_M_MAX)
from .tradeoff import (TradeoffPoint, MultilevelTradeoffPoint,
                       RobustnessPoint, evaluate, evaluate_multilevel,
                       evaluate_robustness, sweep_rho, sweep_mu_rho,
                       sweep_nodes, sweep_buddy_ratio)
from .simulator import simulate, simulate_once, SimResult
from .policy import CheckpointPolicy, PolicyConfig
