"""Checkpoint-period policy: the paper's formulas as a runtime decision.

The :class:`CheckpointPolicy` is the bridge between the analytical core and
the fault-tolerant trainer:

 * the trainer feeds it *measurements* (step time, per-level checkpoint
   durations C1/C2, overlap factor omega, per-level recovery times R1/R2,
   downtimes, observed failure times);
 * the policy maintains EWMA estimates, re-solves the chosen strategy
   (AlgoT / AlgoE / Young / Daly / MSK / fixed, or the joint multilevel
   ``algo_t_ml`` / ``algo_e_ml`` solvers) when estimates drift beyond
   ``drift_threshold``, and exposes the decision as "checkpoint every k
   steps" plus "write the deep (PFS) level every m-th checkpoint".

All policy times are SECONDS (the trainer's unit); the analytical model is
unit-agnostic so no conversion is needed beyond consistency.

Step conversion semantics: the model's period T is *wall* time per period,
of which ``a = (1-omega) * C`` is the checkpoint's critical-path share and
``T - a`` is work.  Training steps carry only the work, and the trainer
charges the checkpoint's ``(1-omega)*C`` wall cost separately, so for the
model-driven strategies ``period_steps`` budgets ``(T - a) / step_time``
steps per period — making the *realized* wall period equal the solved T.
The ``fixed`` strategy keeps the literal interpretation (checkpoint every
``fixed_period_s`` seconds of stepping).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from . import model, optimal
from .params import (CheckpointParams, MultilevelCheckpointParams,
                     MultilevelPowerParams, PowerParams)

#: Joint (T, m) strategies: solve period AND deep-write cadence together.
ML_STRATEGIES = ("algo_t_ml", "algo_e_ml")


@dataclasses.dataclass
class _Ewma:
    """Exponentially-weighted mean with a drift detector."""

    alpha: float = 0.3
    value: Optional[float] = None

    def update(self, x: float) -> None:
        self.value = x if self.value is None else (
            self.alpha * x + (1.0 - self.alpha) * self.value)

    def get(self, default: float) -> float:
        return default if self.value is None else self.value


@dataclasses.dataclass
class PolicyConfig:
    strategy: str = "algo_t"          # optimal.STRATEGIES, ML_STRATEGIES
    fixed_period_s: float = 600.0     # used when strategy == "fixed"
    # Priors (used until enough measurements arrive).  C_s/R_s/D_s are the
    # deep (PFS, level-2) costs — for single-level strategies every
    # checkpoint is deep, so they are simply THE costs.
    C_s: float = 60.0
    R_s: float = 60.0
    D_s: float = 6.0
    mu_s: float = 24 * 3600.0         # platform MTBF prior
    omega: float = 0.5
    #: deep-flush overlap prior (VELOC async flush); None -> the shared
    #: ``omega`` applies to both levels.  Only read by the *_ml
    #: strategies, as ``MultilevelCheckpointParams.omega2``.
    omega2: Optional[float] = None
    # Multilevel (buddy, level-1) priors — only read by the *_ml strategies:
    C1_s: float = 6.0
    R1_s: float = 6.0
    D1_s: Optional[float] = None      # None -> D_s
    q: float = 0.1                    # P[failure also loses the buddy copy]
    m_max: int = optimal.DEFAULT_M_MAX
    # Re-solve when an estimate moves by more than this fraction:
    drift_threshold: float = 0.10
    min_period_steps: int = 1
    #: blend observed failure gaps into the MTBF estimate.  Disable when the
    #: platform MTBF is known (e.g. scaled-time validation runs) so the
    #: solved period is a pure function of the configured scenario.
    mu_from_observations: bool = True


class CheckpointPolicy:
    """Online period selection driven by the paper's model."""

    def __init__(self, config: PolicyConfig, power: PowerParams,
                 ml_power: Optional[MultilevelPowerParams] = None):
        self.config = config
        self.power = power
        #: per-level I/O powers for the *_ml energy solver; defaults to
        #: degenerate levels (buddy draws PFS power).
        self.ml_power = (ml_power if ml_power is not None
                         else MultilevelPowerParams.from_power(power))
        self._C = _Ewma()             # deep (level-2) checkpoint duration
        self._R = _Ewma()
        self._D = _Ewma()
        self._C1 = _Ewma()            # buddy (level-1) checkpoint duration
        self._R1 = _Ewma()
        self._D1 = _Ewma()
        self._omega = _Ewma()
        self._step_time = _Ewma(alpha=0.1)
        self._failure_gaps: list[float] = []
        self._last_failure_t: Optional[float] = None
        #: deep (PFS) tier health, driven by the checkpoint manager's
        #: degrade/heal FSM; False re-solves at the buddy-only tier.
        self._deep_available = True
        # (param values, (strategy, deep_available), T, m) of last solve
        self._cached: Optional[tuple] = None

    # ---- measurement intake ------------------------------------------------
    def observe_step_time(self, seconds: float) -> None:
        self._step_time.update(seconds)
        # step time changes do not invalidate the period (seconds-based).

    def observe_checkpoint(self, *, duration_s: float,
                           slowdown_work_fraction: float | None = None,
                           level: int = 2) -> None:
        """Record a completed checkpoint.

        ``level`` is the deepest level written: 2 for a deep (PFS) write,
        1 for a buddy-only write (the ``pfs_every`` cadence's cheap
        checkpoints).  ``slowdown_work_fraction`` is the measured omega:
        fraction of a normal step's work that still progressed per unit
        time while the checkpoint was in flight (1.0 = fully overlapped).
        """
        (self._C if level >= 2 else self._C1).update(duration_s)
        if slowdown_work_fraction is not None:
            self._omega.update(min(max(slowdown_work_fraction, 0.0), 1.0))

    def observe_recovery(self, *, recovery_s: float, downtime_s: float,
                         level: int = 2) -> None:
        """``level`` is the level the recovery read from (1 = buddy)."""
        (self._R if level >= 2 else self._R1).update(recovery_s)
        (self._D if level >= 2 else self._D1).update(downtime_s)

    def observe_failure(self, wall_time_s: float) -> None:
        if self._last_failure_t is not None:
            gap = wall_time_s - self._last_failure_t
            if gap > 0:
                self._failure_gaps.append(gap)
        self._last_failure_t = wall_time_s

    # ---- estimates ---------------------------------------------------------
    @property
    def is_multilevel(self) -> bool:
        return self.config.strategy in ML_STRATEGIES

    @property
    def mu_estimate_s(self) -> float:
        """MLE of the exponential MTBF from observed gaps, blended with the
        prior (the prior acts as one pseudo-observation); the prior alone
        when ``mu_from_observations`` is off."""
        cfg = self.config
        if not self._failure_gaps or not cfg.mu_from_observations:
            return cfg.mu_s
        n = len(self._failure_gaps)
        return (sum(self._failure_gaps) + cfg.mu_s) / (n + 1)

    def checkpoint_params(self) -> CheckpointParams:
        cfg = self.config
        return CheckpointParams(
            C=self._C.get(cfg.C_s),
            R=self._R.get(cfg.R_s),
            D=self._D.get(cfg.D_s),
            mu=self.mu_estimate_s,
            omega=self._omega.get(cfg.omega),
        )

    def checkpoint_params_ml(self) -> MultilevelCheckpointParams:
        cfg = self.config
        d1 = cfg.D_s if cfg.D1_s is None else cfg.D1_s
        return MultilevelCheckpointParams(
            C1=self._C1.get(cfg.C1_s), R1=self._R1.get(cfg.R1_s),
            C2=self._C.get(cfg.C_s), R2=self._R.get(cfg.R_s),
            D1=self._D1.get(d1), D2=self._D.get(cfg.D_s),
            mu=self.mu_estimate_s, q=cfg.q,
            omega=self._omega.get(cfg.omega),
            omega2=cfg.omega2,
        )

    def overlap_for(self, level: int) -> float:
        """The effective overlap factor of a level-``level`` write: the
        buddy's w1 / the deep flush's w2 under the *_ml strategies, the
        shared omega otherwise — what the trainer uses to split a write
        into its critical-path stall and its in-flight flush window."""
        if self.is_multilevel:
            ck = self.checkpoint_params_ml()
            return ck.w1 if level <= 1 else ck.w2
        return self.checkpoint_params().omega

    # ---- deep-tier health (driven by the manager's degrade/heal FSM) -------
    @property
    def deep_available(self) -> bool:
        return self._deep_available

    def set_deep_available(self, available: bool) -> None:
        """Flip the deep (PFS) tier's availability.  While unavailable the
        *_ml strategies re-solve the buddy-only single-level problem, so
        the period re-anchors at the degraded tier (and back on heal)."""
        if bool(available) != self._deep_available:
            self._deep_available = bool(available)
            self._cached = None

    # ---- decision ----------------------------------------------------------
    def _param_values(self) -> tuple:
        """The estimate tuple whose drift invalidates the cached solve."""
        if self.is_multilevel:
            ck = self.checkpoint_params_ml()
            return (ck.C1, ck.R1, ck.D1, ck.C2, ck.R2, ck.D2, ck.mu)
        ck = self.checkpoint_params()
        return (ck.C, ck.R, ck.D, ck.mu)

    def _solve(self) -> tuple[float, int]:
        cfg = self.config
        if self.is_multilevel and not self._deep_available:
            # Degraded tier: the deep store is down, every checkpoint is
            # buddy-only — solve the single-level problem at the buddy's
            # (C1, R1, D1, w1) and its I/O power.
            ck = self.checkpoint_params_ml().buddy_only()
            if cfg.strategy == "algo_e_ml":
                mp = self.ml_power
                buddy_power = PowerParams(P_static=mp.P_static,
                                          P_cal=mp.P_cal, P_io=mp.P_io1,
                                          P_down=mp.P_down)
                return optimal.t_opt_energy(ck, buddy_power), 1
            return optimal.t_opt_time(ck), 1
        if cfg.strategy == "algo_t_ml":
            T, m = optimal.t_opt_time_multilevel(self.checkpoint_params_ml(),
                                                 m_max=cfg.m_max)
            return T, m
        if cfg.strategy == "algo_e_ml":
            T, m = optimal.t_opt_energy_multilevel(
                self.checkpoint_params_ml(), self.ml_power, m_max=cfg.m_max)
            return T, m
        return optimal.period_for(cfg.strategy, self.checkpoint_params(),
                                  self.power), 1

    def _decision(self) -> tuple[float, int]:
        """(period T seconds, deep-write cadence m), cached across calls and
        re-solved only when an estimate drifts beyond the threshold."""
        cfg = self.config
        if cfg.strategy == "fixed":
            return cfg.fixed_period_s, 1
        if not math.isfinite(self.mu_estimate_s):   # no failures expected
            return float("inf"), 1
        vals = self._param_values()
        key = (cfg.strategy, self._deep_available)
        if self._cached is not None:
            ovals, okey, operiod, om = self._cached

            def drift(new, old):
                return abs(new - old) > cfg.drift_threshold * max(old, 1e-9)
            if (okey == key and len(vals) == len(ovals)
                    and not any(drift(n, o) for n, o in zip(vals, ovals))):
                return operiod, om
        T, m = self._solve()
        self._cached = (vals, key, T, m)
        return T, m

    def period_seconds(self) -> float:
        return self._decision()[0]

    def deep_every(self) -> int:
        """The model's m: write the deep (PFS) level every m-th checkpoint.
        1 for every single-level strategy."""
        return self._decision()[1]

    def _critical_path_a(self, m: int) -> float:
        """The checkpoint's expected critical-path wall share per period,
        a = (1-omega) * C_mean(m)."""
        if m > 1 or self.is_multilevel:
            return self.checkpoint_params_ml().a(m)
        return self.checkpoint_params().a

    def period_steps(self) -> int:
        """The decision in trainer units: checkpoint every k steps.

        Steps carry the period's *work* share ``T - a`` (see module
        docstring); the ``fixed`` strategy keeps the literal ``T``.
        """
        st = self._step_time.get(1.0)
        T, m = self._decision()
        if not math.isfinite(T):       # infinite MTBF: never checkpoint
            return 10 ** 9
        work = T if self.config.strategy == "fixed" \
            else T - self._critical_path_a(m)
        k = int(round(work / max(st, 1e-9)))
        return max(k, self.config.min_period_steps)

    def operating_point(self, m: Optional[int] = None) -> dict:
        """The decision as actually executed by the trainer: k steps per
        period plus the checkpoint's wall share, at deep cadence ``m``
        (defaults to the policy's own; pass the manager's when its
        ``pfs_every`` was hand-set)."""
        T, m_pol = self._decision()
        m_eff = m_pol if m is None else m
        k = self.period_steps()
        s = self._step_time.get(1.0)
        realized = (float("inf") if not math.isfinite(T)
                    else k * s + self._critical_path_a(m_eff))
        return {"strategy": self.config.strategy,
                "period_solved_s": T, "deep_every": m_eff,
                "period_steps": k, "step_s": s,
                "period_realized_s": realized}

    # ---- reporting ---------------------------------------------------------
    def report(self) -> dict:
        ck = self.checkpoint_params()
        out = {
            "strategy": self.config.strategy,
            "C_s": ck.C, "R_s": ck.R, "D_s": ck.D, "mu_s": ck.mu,
            "omega": ck.omega,
            "period_s": self.period_seconds(),
            "period_steps": self.period_steps(),
            "deep_every": self.deep_every(),
            "step_time_s": self._step_time.get(float("nan")),
            "n_failures_observed": len(self._failure_gaps),
        }
        if not math.isfinite(ck.mu):
            return out
        if self.is_multilevel:
            mlck = self.checkpoint_params_ml()
            out.update({"C1_s": mlck.C1, "R1_s": mlck.R1, "D1_s": mlck.D1,
                        "q": mlck.q, "omega2": mlck.w2,
                        "deep_available": self._deep_available})
            try:
                tt, mt = optimal.t_opt_time_multilevel(
                    mlck, m_max=self.config.m_max)
                te, me = optimal.t_opt_energy_multilevel(
                    mlck, self.ml_power, m_max=self.config.m_max)
                out["algo_t_ml_period_s"], out["algo_t_ml_m"] = tt, mt
                out["algo_e_ml_period_s"], out["algo_e_ml_m"] = te, me
                out["predicted_time_ratio"] = float(
                    model.ml_time_final(te, me, mlck)
                    / model.ml_time_final(tt, mt, mlck))
                out["predicted_energy_ratio"] = float(
                    model.ml_energy_final(tt, mt, mlck, self.ml_power)
                    / model.ml_energy_final(te, me, mlck, self.ml_power))
            except (ValueError, AssertionError):
                pass
            return out
        try:
            tt = optimal.t_opt_time(ck)
            te = optimal.t_opt_energy(ck, self.power)
            out["algo_t_period_s"] = tt
            out["algo_e_period_s"] = te
            out["predicted_time_ratio"] = float(
                model.time_final(te, ck) / model.time_final(tt, ck))
            out["predicted_energy_ratio"] = float(
                model.energy_final(tt, ck, self.power)
                / model.energy_final(te, ck, self.power))
        except (ValueError, AssertionError):
            pass
        return out
