"""Checkpoint-period policy: the paper's formulas as a runtime decision.

The :class:`CheckpointPolicy` is the bridge between the analytical core and
the distributed trainer:

 * the trainer feeds it *measurements* (step time, checkpoint duration C,
   overlap factor omega, recovery time R, downtime D, observed failure times);
 * the policy maintains EWMA estimates, re-solves the chosen strategy
   (AlgoT / AlgoE / Young / Daly / MSK / fixed) when estimates drift, and
   exposes the decision as "checkpoint every k steps".

All policy times are SECONDS (the trainer's unit); the analytical model is
unit-agnostic so no conversion is needed beyond consistency.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from . import model, optimal
from .params import CheckpointParams, PowerParams


@dataclasses.dataclass
class _Ewma:
    """Exponentially-weighted mean with a drift detector."""

    alpha: float = 0.3
    value: Optional[float] = None

    def update(self, x: float) -> None:
        self.value = x if self.value is None else (
            self.alpha * x + (1.0 - self.alpha) * self.value)

    def get(self, default: float) -> float:
        return default if self.value is None else self.value


@dataclasses.dataclass
class PolicyConfig:
    strategy: str = "algo_t"          # one of optimal.STRATEGIES or "fixed"
    fixed_period_s: float = 600.0     # used when strategy == "fixed"
    # Priors (used until enough measurements arrive):
    C_s: float = 60.0
    R_s: float = 60.0
    D_s: float = 6.0
    mu_s: float = 24 * 3600.0         # platform MTBF prior
    omega: float = 0.5
    # Re-solve when an estimate moves by more than this fraction:
    drift_threshold: float = 0.10
    min_period_steps: int = 1


class CheckpointPolicy:
    """Online period selection driven by the paper's model."""

    def __init__(self, config: PolicyConfig, power: PowerParams):
        self.config = config
        self.power = power
        self._C = _Ewma()
        self._R = _Ewma()
        self._D = _Ewma()
        self._omega = _Ewma()
        self._step_time = _Ewma(alpha=0.1)
        self._failure_gaps: list[float] = []
        self._last_failure_t: Optional[float] = None
        self._cached_period: Optional[float] = None
        self._cached_inputs: Optional[tuple] = None

    # ---- measurement intake ------------------------------------------------
    def observe_step_time(self, seconds: float) -> None:
        self._step_time.update(seconds)
        # step time changes do not invalidate the period (seconds-based).

    def observe_checkpoint(self, *, duration_s: float,
                           slowdown_work_fraction: float | None = None) -> None:
        """Record a completed checkpoint.

        ``slowdown_work_fraction`` is the measured omega: fraction of a normal
        step's work that still progressed per unit time while the checkpoint
        was in flight (1.0 = fully overlapped).
        """
        self._C.update(duration_s)
        if slowdown_work_fraction is not None:
            self._omega.update(min(max(slowdown_work_fraction, 0.0), 1.0))

    def observe_recovery(self, *, recovery_s: float, downtime_s: float) -> None:
        self._R.update(recovery_s)
        self._D.update(downtime_s)

    def observe_failure(self, wall_time_s: float) -> None:
        if self._last_failure_t is not None:
            gap = wall_time_s - self._last_failure_t
            if gap > 0:
                self._failure_gaps.append(gap)
        self._last_failure_t = wall_time_s

    # ---- estimates ---------------------------------------------------------
    @property
    def mu_estimate_s(self) -> float:
        """MLE of the exponential MTBF from observed gaps, blended with the
        prior (the prior acts as one pseudo-observation)."""
        cfg = self.config
        if not self._failure_gaps:
            return cfg.mu_s
        n = len(self._failure_gaps)
        return (sum(self._failure_gaps) + cfg.mu_s) / (n + 1)

    def checkpoint_params(self) -> CheckpointParams:
        cfg = self.config
        return CheckpointParams(
            C=self._C.get(cfg.C_s),
            R=self._R.get(cfg.R_s),
            D=self._D.get(cfg.D_s),
            mu=self.mu_estimate_s,
            omega=self._omega.get(cfg.omega),
        )

    # ---- decision ----------------------------------------------------------
    def period_seconds(self) -> float:
        cfg = self.config
        if cfg.strategy == "fixed":
            return cfg.fixed_period_s
        ck = self.checkpoint_params()
        if not math.isfinite(ck.mu):       # no failures expected: never ckpt
            return float("inf")
        key = (round(ck.C, 6), round(ck.R, 6), round(ck.D, 6),
               round(ck.mu, 3), round(ck.omega, 4), cfg.strategy)
        if self._cached_inputs is not None and self._cached_period is not None:
            # Only re-solve on drift beyond the threshold.
            oC, oR, oD, omu, _, ostrat = self._cached_inputs
            def drift(new, old):
                return abs(new - old) > cfg.drift_threshold * max(old, 1e-9)
            if (ostrat == cfg.strategy and not any(
                    (drift(ck.C, oC), drift(ck.R, oR), drift(ck.D, oD),
                     drift(ck.mu, omu)))):
                return self._cached_period
        period = optimal.period_for(cfg.strategy, ck, self.power)
        self._cached_inputs = key
        self._cached_period = period
        return period

    def period_steps(self) -> int:
        """The decision in trainer units: checkpoint every k steps."""
        st = self._step_time.get(1.0)
        period = self.period_seconds()
        if not math.isfinite(period):      # infinite MTBF: never checkpoint
            return 10 ** 9
        k = int(round(period / max(st, 1e-9)))
        return max(k, self.config.min_period_steps)

    # ---- reporting ---------------------------------------------------------
    def report(self) -> dict:
        ck = self.checkpoint_params()
        out = {
            "strategy": self.config.strategy,
            "C_s": ck.C, "R_s": ck.R, "D_s": ck.D, "mu_s": ck.mu,
            "omega": ck.omega,
            "period_s": self.period_seconds(),
            "period_steps": self.period_steps(),
            "step_time_s": self._step_time.get(float("nan")),
            "n_failures_observed": len(self._failure_gaps),
        }
        if not math.isfinite(ck.mu):
            return out
        try:
            tt = optimal.t_opt_time(ck)
            te = optimal.t_opt_energy(ck, self.power)
            out["algo_t_period_s"] = tt
            out["algo_e_period_s"] = te
            out["predicted_time_ratio"] = float(
                model.time_final(te, ck) / model.time_final(tt, ck))
            out["predicted_energy_ratio"] = float(
                model.energy_final(tt, ck, self.power)
                / model.energy_final(te, ck, self.power))
        except (ValueError, AssertionError):
            pass
        return out
