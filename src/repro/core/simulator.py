"""Discrete-event Monte-Carlo simulator of periodic non-blocking checkpointing.

Validates the paper's closed-form expectations (``model.time_final`` /
``model.energy_final``) by direct simulation: execution alternates compute
phases (length T - C, work rate 1) and checkpoint phases (length C, work rate
omega, I/O active).  A checkpoint *commits* the state as of the beginning of
its phase — the paper's semantics: the omega*C work done concurrently with a
checkpoint is only protected by the NEXT completed checkpoint.

Failure handling: downtime D (no progress), recovery R (I/O active), rollback
to the last committed state.

Failure schedule: the simulator maintains ``next_fail`` as an *absolute*
wall-clock time, fed from a schedule of inter-failure gaps under the renewal
convention shared with the batched engine (``repro.core.failures``): gap i
runs from the end of recovery i-1 (or t = 0) to failure i.  The schedule
comes from one of

  * ``gaps=...`` — a pre-sampled gap array (the batched engine's format;
    bit-identical trajectories for *every* distribution when both consume
    the same array),
  * ``process=...`` — any :class:`repro.core.failures.FailureProcess`,
    sampled lazily from ``rng`` (the default ``Exponential`` reproduces the
    legacy ``rng.exponential(mu)`` stream bit-for-bit),
  * a replaying ``rng`` such as :class:`repro.sim.engine.ScheduledRNG`
    (kept for backward compatibility).

A schedule that runs dry before the trajectory completes would silently
simulate the tail failure-free (biased); the simulator raises instead,
mirroring the batched engine's ``gaps_exhausted`` error.  Likewise the event
budget: exceeding ``max_events`` raises rather than returning a partial
trajectory.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from .failures import FailureProcess, as_process
from .params import CheckpointParams, PowerParams


@dataclasses.dataclass
class SimResult:
    wall_time: float          # == paper's T_final
    energy: float             # == paper's E_final
    n_failures: int
    work_executed: float      # == paper's T_cal
    io_time: float            # == paper's T_io
    down_time: float          # == paper's T_down
    n_checkpoints: int


class _GapSource:
    """Uniform draw interface over the three schedule flavours above."""

    def __init__(self, rng, mu: float, process: Optional[FailureProcess],
                 gaps: Optional[Sequence] = None):
        self.exhausted = False
        if gaps is not None:
            self._gaps = np.asarray(gaps, dtype=np.float64).ravel()
            self._i = 0
            self._draw = self._from_array
        elif getattr(rng, "replays_schedule", False):
            # ScheduledRNG and friends: the schedule is already materialized
            # in the rng; `scale` is ignored by contract (gaps replay
            # verbatim), and exhaustion is reported via rng.exhausted.
            self._rng = rng
            self._mu = mu
            self._draw = self._from_replaying_rng
        else:
            # iter_gaps keeps sequential semantics per process (cyclic for
            # TraceReplay, lazy i.i.d. draws otherwise — one legacy-
            # identical rng call per gap for the exponential default).
            self._iter = as_process(process).iter_gaps(rng, mean=mu)
            self._draw = self._from_process

    def _from_array(self) -> float:
        if self._i >= self._gaps.size:
            self.exhausted = True
            return math.inf
        g = float(self._gaps[self._i])
        self._i += 1
        return g

    def _from_replaying_rng(self) -> float:
        g = float(self._rng.exponential(self._mu))
        if getattr(self._rng, "exhausted", False):
            self.exhausted = True
        return g

    def _from_process(self) -> float:
        return next(self._iter)

    def __call__(self) -> float:
        return self._draw()


def simulate_once(T: float, ckpt: CheckpointParams, power: PowerParams,
                  T_base: float, rng: np.random.Generator,
                  process: Optional[FailureProcess] = None,
                  gaps: Optional[Sequence] = None,
                  max_events: Optional[int] = None) -> SimResult:
    """One trajectory of the checkpointed execution.

    ``process`` selects the inter-failure distribution (None = the paper's
    exponential, sampled from ``rng`` exactly as the legacy code did);
    ``gaps`` overrides it with a pre-sampled schedule (the parity path).
    Raises ``RuntimeError`` when the event budget or a finite failure
    schedule is exhausted before ``T_base`` work completes — a partial or
    failure-free-tail trajectory is never silently returned as complete.
    """
    C, R, D, mu, omega = ckpt.C, ckpt.R, ckpt.D, ckpt.mu, ckpt.omega
    if T <= (1.0 - omega) * C:
        raise ValueError("period too short: no work progress per period")

    wall = 0.0
    committed = 0.0        # work protected by the last completed checkpoint
    live = 0.0             # work executed since (not yet all committed)
    work_exec = 0.0        # total CPU work units executed (incl. re-exec)
    io_time = 0.0
    down_time = 0.0
    n_fail = 0
    n_ckpt = 0

    draw_gap = _GapSource(rng, mu, process, gaps)
    next_fail = draw_gap()          # absolute: first renewal starts at t=0

    # Phase machine: 'compute' (duration T - C) or 'checkpoint' (duration C).
    phase = "compute"
    phase_left = T - C
    ckpt_snapshot = 0.0    # work value being written by the in-flight ckpt

    if max_events is None:
        max_events = int(50 * (T_base / max(T - (1 - omega) * C, 1e-9)
                               + T_base / mu + 100))
    for _ in range(max_events):
        if live >= T_base - 1e-12:
            break
        rate = 1.0 if phase == "compute" else omega
        # Work left until done mid-phase?
        t_done = ((T_base - live) / rate) if rate > 0 else math.inf
        t_next = min(phase_left, t_done)

        if wall + t_next < next_fail:
            # Phase segment completes without failure.
            wall += t_next
            live += rate * t_next
            work_exec += rate * t_next
            if phase == "checkpoint":
                io_time += t_next
            phase_left -= t_next
            if live >= T_base - 1e-12:
                break
            if phase_left <= 1e-12:
                if phase == "compute":
                    phase = "checkpoint"
                    phase_left = C
                    ckpt_snapshot = live     # state at ckpt start is written
                else:
                    committed = ckpt_snapshot
                    n_ckpt += 1
                    phase = "compute"
                    phase_left = T - C
        else:
            # Failure strikes mid-phase.
            dt = next_fail - wall
            wall = next_fail
            live += rate * dt
            work_exec += rate * dt
            if phase == "checkpoint":
                io_time += dt            # partially-written ckpt I/O is wasted
            n_fail += 1
            # Downtime + recovery; the failure clock renews at recovery end
            # (no failures strike during D/R — the convention both engines
            # share, exact for memoryless processes and the documented
            # schedule semantics for all others).
            wall += D
            down_time += D
            wall += R
            io_time += R
            live = committed
            phase = "compute"
            phase_left = T - C
            next_fail = wall + draw_gap()
    else:
        raise RuntimeError(
            f"simulator exceeded its event budget ({max_events} events) "
            f"before completing T_base={T_base} work — partial trajectories "
            f"are not returned (check params, or raise max_events)")

    if draw_gap.exhausted:
        raise RuntimeError(
            "failure schedule exhausted before the trajectory completed "
            "(tail would be simulated failure-free); provide a longer gaps "
            "schedule — mirrors the batched engine's gaps_exhausted error")

    energy = (power.P_static * wall + power.P_cal * work_exec
              + power.P_io * io_time + power.P_down * down_time)
    return SimResult(wall_time=wall, energy=energy, n_failures=n_fail,
                     work_executed=work_exec, io_time=io_time,
                     down_time=down_time, n_checkpoints=n_ckpt)


def simulate(T: float, ckpt: CheckpointParams, power: PowerParams,
             T_base: float, n_trials: int = 200,
             seed: int = 0,
             process: Optional[FailureProcess] = None) -> dict:
    """Monte-Carlo estimate (mean over trials) with standard errors."""
    # reprolint: disable=RPL001 (the scalar oracle is host-only reference code; engine parity checks feed it the engine's presampled schedule via ScheduledRNG)
    rng = np.random.default_rng(seed)
    walls, energies, fails = [], [], []
    cals, ios, downs = [], [], []
    for _ in range(n_trials):
        r = simulate_once(T, ckpt, power, T_base, rng, process=process)
        walls.append(r.wall_time)
        energies.append(r.energy)
        fails.append(r.n_failures)
        cals.append(r.work_executed)
        ios.append(r.io_time)
        downs.append(r.down_time)
    walls, energies = np.asarray(walls), np.asarray(energies)

    def mean_se(x):
        x = np.asarray(x, dtype=np.float64)
        return float(x.mean()), float(x.std(ddof=1) / math.sqrt(len(x)))

    out = {}
    for k, v in (("T_final", walls), ("E_final", energies), ("T_cal", cals),
                 ("T_io", ios), ("T_down", downs), ("n_failures", fails)):
        m, se = mean_se(v)
        out[k] = m
        out[k + "_se"] = se
    return out
