"""Discrete-event Monte-Carlo simulator of periodic non-blocking checkpointing.

Validates the paper's closed-form expectations (``model.time_final`` /
``model.energy_final``) by direct simulation: failures are a Poisson process
with rate 1/mu over wall-clock time; execution alternates compute phases
(length T - C, work rate 1) and checkpoint phases (length C, work rate omega,
I/O active).  A checkpoint *commits* the state as of the beginning of its
phase — the paper's semantics: the omega*C work done concurrently with a
checkpoint is only protected by the NEXT completed checkpoint.

Failure handling: downtime D (no progress), recovery R (I/O active), rollback
to the last committed state.  Failures can also strike during D and R
(second-order effect the first-order model ignores — tests use D + R << mu).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from .params import CheckpointParams, PowerParams


@dataclasses.dataclass
class SimResult:
    wall_time: float          # == paper's T_final
    energy: float             # == paper's E_final
    n_failures: int
    work_executed: float      # == paper's T_cal
    io_time: float            # == paper's T_io
    down_time: float          # == paper's T_down
    n_checkpoints: int


def simulate_once(T: float, ckpt: CheckpointParams, power: PowerParams,
                  T_base: float, rng: np.random.Generator) -> SimResult:
    """One trajectory of the checkpointed execution."""
    C, R, D, mu, omega = ckpt.C, ckpt.R, ckpt.D, ckpt.mu, ckpt.omega
    if T <= (1.0 - omega) * C:
        raise ValueError("period too short: no work progress per period")

    wall = 0.0
    committed = 0.0        # work protected by the last completed checkpoint
    live = 0.0             # work executed since (not yet all committed)
    work_exec = 0.0        # total CPU work units executed (incl. re-exec)
    io_time = 0.0
    down_time = 0.0
    n_fail = 0
    n_ckpt = 0

    next_fail = rng.exponential(mu)

    # Phase machine: 'compute' (duration T - C) or 'checkpoint' (duration C).
    phase = "compute"
    phase_left = T - C
    ckpt_snapshot = 0.0    # work value being written by the in-flight ckpt

    max_events = int(50 * (T_base / max(T - (1 - omega) * C, 1e-9)
                           + T_base / mu + 100))
    for _ in range(max_events):
        if live >= T_base - 1e-12:
            break
        rate = 1.0 if phase == "compute" else omega
        # Work left until done mid-phase?
        t_done = ((T_base - live) / rate) if rate > 0 else math.inf
        t_next = min(phase_left, t_done)

        if wall + t_next < next_fail:
            # Phase segment completes without failure.
            wall += t_next
            live += rate * t_next
            work_exec += rate * t_next
            if phase == "checkpoint":
                io_time += t_next
            phase_left -= t_next
            if live >= T_base - 1e-12:
                break
            if phase_left <= 1e-12:
                if phase == "compute":
                    phase = "checkpoint"
                    phase_left = C
                    ckpt_snapshot = live     # state at ckpt start is written
                else:
                    committed = ckpt_snapshot
                    n_ckpt += 1
                    phase = "compute"
                    phase_left = T - C
        else:
            # Failure strikes mid-phase.
            dt = next_fail - wall
            wall = next_fail
            live += rate * dt
            work_exec += rate * dt
            if phase == "checkpoint":
                io_time += dt            # partially-written ckpt I/O is wasted
            n_fail += 1
            # Downtime (failures during D/R just restart the D+R sequence —
            # approximated by re-sampling; keeps the process memoryless).
            wall += D
            down_time += D
            wall += R
            io_time += R
            live = committed
            phase = "compute"
            phase_left = T - C
            next_fail = wall + rng.exponential(mu)
    else:
        raise RuntimeError("simulator exceeded event budget (check params)")

    energy = (power.P_static * wall + power.P_cal * work_exec
              + power.P_io * io_time + power.P_down * down_time)
    return SimResult(wall_time=wall, energy=energy, n_failures=n_fail,
                     work_executed=work_exec, io_time=io_time,
                     down_time=down_time, n_checkpoints=n_ckpt)


def simulate(T: float, ckpt: CheckpointParams, power: PowerParams,
             T_base: float, n_trials: int = 200,
             seed: int = 0) -> dict:
    """Monte-Carlo estimate (mean over trials) with standard errors."""
    rng = np.random.default_rng(seed)
    walls, energies, fails = [], [], []
    cals, ios, downs = [], [], []
    for _ in range(n_trials):
        r = simulate_once(T, ckpt, power, T_base, rng)
        walls.append(r.wall_time)
        energies.append(r.energy)
        fails.append(r.n_failures)
        cals.append(r.work_executed)
        ios.append(r.io_time)
        downs.append(r.down_time)
    walls, energies = np.asarray(walls), np.asarray(energies)

    def mean_se(x):
        x = np.asarray(x, dtype=np.float64)
        return float(x.mean()), float(x.std(ddof=1) / math.sqrt(len(x)))

    out = {}
    for k, v in (("T_final", walls), ("E_final", energies), ("T_cal", cals),
                 ("T_io", ios), ("T_down", downs), ("n_failures", fails)):
        m, se = mean_se(v)
        out[k] = m
        out[k + "_se"] = se
    return out
