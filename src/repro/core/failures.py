"""Failure-process abstraction: renewal processes of i.i.d. inter-failure gaps.

The paper (and the seed code) hardwires exponential (memoryless) failures,
but field studies of HPC failure logs consistently fit Weibull with shape
< 1 (infant-mortality clustering) and sometimes log-normal.  This module
makes the inter-failure distribution a first-class object that every
simulation layer consumes:

  * :class:`Exponential` — the paper's Poisson process (the default
    everywhere; reproduces the legacy sampling stream *bit-for-bit*).
  * :class:`Weibull` — shape ``k`` (k < 1: decreasing hazard, clustered
    failures; k = 1 is exponential; k > 1: wear-out).
  * :class:`LogNormal` — multiplicative-error gap model (non-monotone
    hazard).
  * :class:`TraceReplay` — replay of an empirical gap log (cyclically, from
    a random per-trajectory phase), preserving the trace's autocorrelation.

Semantics shared with both simulators (the *renewal convention*): gap ``i``
is the time from the end of recovery ``i-1`` (or from t = 0) to failure
``i`` — the machine's clock of the failure process restarts when it comes
back up.  A pre-sampled gap array therefore defines an absolute-time
failure schedule once the recovery ends are known, and the same array fed
to the scalar oracle (:func:`repro.core.simulator.simulate_once` with
``gaps=...``) and the batched engine produces bit-identical trajectories
for *every* distribution.

Parameterization: every process targets a mean gap ``mu`` (the platform
MTBF).  Constructors accept ``mu=None``, in which case the caller (the
engine / the scalar simulator) supplies the mean at sampling time — this is
how one process instance serves a whole :class:`~repro.sim.scenarios.ParamGrid`
of MTBFs.  Shape parameters may be *arrays* broadcasting against the grid's
leading axes (batched sampling over distribution-parameter grids); use
:meth:`FailureProcess.ravel` next to ``ParamGrid.ravel``.

Two sampling backends share the same distributions:

  * :meth:`FailureProcess.sample` — host numpy, from an
    ``np.random.Generator`` (the legacy streams; the CRN solvers pre-sample
    here so one schedule set can be replayed for every candidate period).
  * :meth:`FailureProcess.sample_gaps` — jax-native inverse-CDF sampling
    from a threefry key, device-resident end to end.  The batched engine's
    default path; erases the host presample tensors and their per-call
    host->device transfers.  The two backends draw from the same
    distribution but NOT the same stream (threefry vs PCG64).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Union

import numpy as np

ArrayLike = Union[float, np.ndarray]


def _lead(x: ArrayLike, size: tuple) -> np.ndarray:
    """Align an array-valued parameter with the *leading* axes of ``size``.

    A ``(B,)`` parameter sampled at ``size=(B, n_trials, capacity)`` becomes
    ``(B, 1, 1)`` so numpy broadcasting pairs grid points with their own
    parameter instead of the trailing-axis default.
    """
    x = np.asarray(x, dtype=np.float64)
    if x.ndim == 0 or size is None:
        return x
    extra = len(size) - x.ndim
    if extra < 0:
        raise ValueError(f"parameter of shape {x.shape} cannot broadcast "
                         f"against sample size {size}")
    return x.reshape(x.shape + (1,) * extra)


def _param_token(x) -> tuple:
    """Hashable identity of a (possibly array-valued) parameter — used to
    key jit caches of the device samplers."""
    if x is None:
        return (None,)
    arr = np.asarray(x, dtype=np.float64)
    return (arr.shape, arr.tobytes())


def _lead_j(x, size: tuple):
    """jnp counterpart of :func:`_lead` (accepts traced arrays)."""
    import jax.numpy as jnp
    x = jnp.asarray(x, dtype=jnp.float64)
    if x.ndim == 0 or size is None:
        return x
    extra = len(size) - x.ndim
    if extra < 0:
        raise ValueError(f"parameter of shape {x.shape} cannot broadcast "
                         f"against sample size {size}")
    return x.reshape(x.shape + (1,) * extra)


class FailureProcess:
    """A renewal process of i.i.d. inter-failure gaps (see module docstring).

    Subclasses implement :meth:`sample` (and usually :meth:`hazard`); the
    base class provides mean resolution and the variance scaling the engine
    uses to size pre-sampled schedules.
    """

    name: str = "process"
    #: declared mean gap, or None when the caller supplies it per sample.
    mu: Optional[ArrayLike] = None

    # -- mean plumbing -------------------------------------------------------
    def resolve_mean(self, mean: Optional[ArrayLike] = None) -> np.ndarray:
        """The mean gap to sample at: the caller's ``mean`` unless the
        process pins its own ``mu``."""
        m = self.mu if self.mu is not None else mean
        if m is None:
            raise ValueError(f"{self.name}: no mean gap — construct with "
                             f"mu=... or pass mean= when sampling")
        return np.asarray(m, dtype=np.float64)

    def gap_cv(self) -> ArrayLike:
        """Coefficient of variation (std/mean) of one gap — sizes the
        pre-sampled schedule capacity; 1.0 for exponential."""
        return 1.0

    # -- sampling / hazard ---------------------------------------------------
    def sample(self, rng: np.random.Generator, size=None,
               mean: Optional[ArrayLike] = None):
        """Draw inter-failure gaps of the given shape (mean ``mean``)."""
        raise NotImplementedError

    def sample_gaps(self, key, size: tuple,
                    mean: Optional[ArrayLike] = None):
        """jax-native gap sampler: draw ``size`` inter-failure gaps on device
        from threefry ``key`` (inverse-CDF / standard-normal transforms; no
        host round-trip).  Distribution parameters are baked in as
        constants; ``mean`` may be a traced array (one mean per grid
        point, ``_lead``-aligned by the caller or broadcastable).

        The engine's auto-sampling ladder: :meth:`traced_sampler` (the
        fused per-(point, trial) dispatch path) first, then this bulk
        sampler (one whole-grid device draw), then host numpy.
        Subclasses without any jax sampler inherit this
        ``NotImplementedError`` and the engine falls back to host numpy
        sampling — new processes work immediately, just without the
        on-device fast paths.
        """
        raise NotImplementedError(f"{self.name}: no device sampler")

    def cache_token(self) -> tuple:
        """Hashable identity of the process (class + parameters) — keys the
        engine's jit cache of compiled device samplers."""
        return (type(self).__name__, _param_token(self.mu))

    def traced_sampler(self):
        """``(token, params, fn)``: the device sampler with every
        distribution parameter TRACED instead of baked as a constant.

        ``params`` is a tuple of per-grid-point parameter arrays (each
        broadcastable against the raveled grid) and ``fn(key, size, mean,
        params)`` draws ``size`` gaps on device where ``mean`` and every
        element of ``params`` may be traced scalars (the engine vmaps
        ``fn`` over grid points).  Because the parameter values enter as
        arguments, one compiled program serves every chunk/shard slice of
        a grid — this is what makes the dispatch layer's chunking free of
        per-chunk recompiles for array-parameterized processes.

        ``token`` is the hashable identity of the *static* part of the
        sampler (class + non-array configuration) — the jit cache key.
        Subclasses without a jax sampler inherit this
        ``NotImplementedError`` and the engine falls back to host numpy
        sampling.
        """
        raise NotImplementedError(f"{self.name}: no device sampler")

    def hazard(self, t: ArrayLike, mean: Optional[ArrayLike] = None):
        """Instantaneous failure rate h(t) at gap-age ``t``."""
        raise NotImplementedError(f"{self.name}: no analytic hazard")

    def _device_mean(self, mean, size):
        """``resolve_mean`` for the device samplers: keeps traced (jnp)
        means intact instead of forcing them through numpy."""
        m = self.mu if self.mu is not None else mean
        if m is None:
            raise ValueError(f"{self.name}: no mean gap — construct with "
                             f"mu=... or pass mean= when sampling")
        return _lead_j(m, size)

    def ravel(self) -> "FailureProcess":
        """Flatten array-valued shape parameters (``ParamGrid.ravel``'s
        counterpart); the default has none."""
        return self

    def iter_gaps(self, rng: np.random.Generator,
                  mean: Optional[ArrayLike] = None):
        """Infinite iterator of gaps for ONE trajectory (the scalar
        simulator's lazy draw path).

        The default yields i.i.d. draws — correct for every i.i.d.-renewal
        process; :class:`TraceReplay` overrides it to keep its cyclic
        ordering.  For the exponential default each ``next()`` performs
        exactly one ``rng.exponential(scale=mean)`` call, preserving the
        legacy stream bit-for-bit.
        """
        while True:
            yield float(self.sample(rng, mean=mean))

    @property
    def is_exponential(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class Exponential(FailureProcess):
    """The paper's Poisson process: constant hazard 1/mu.

    ``sample`` forwards to ``rng.exponential(scale=mean, size=size)`` —
    the *exact* call the legacy code made — so an ``Exponential()`` instance
    reproduces today's sampling streams bit-for-bit (parity-tested).
    """

    mu: Optional[ArrayLike] = None
    name: str = "exponential"

    def sample(self, rng, size=None, mean=None):
        return rng.exponential(scale=_lead(self.resolve_mean(mean), size),
                               size=size)

    def sample_gaps(self, key, size, mean=None):
        import jax
        import jax.numpy as jnp
        m = self._device_mean(mean, size)
        return m * jax.random.exponential(key, size, dtype=jnp.float64)

    def traced_sampler(self):
        import jax
        import jax.numpy as jnp

        def fn(key, size, mean, params):
            return mean * jax.random.exponential(key, size,
                                                 dtype=jnp.float64)
        return ("exponential",), (), fn

    def ravel(self) -> "Exponential":
        return dataclasses.replace(
            self, mu=None if self.mu is None else np.ravel(self.mu))

    def hazard(self, t, mean=None):
        return np.broadcast_to(1.0 / self.resolve_mean(mean),
                               np.shape(t)).astype(np.float64)

    @property
    def is_exponential(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class Weibull(FailureProcess):
    """Weibull(shape k, scale lam) gaps with mean ``lam * Gamma(1 + 1/k)``.

    ``shape`` may be an array (one k per grid point).  The scale is derived
    from the target mean, so a Weibull process at a platform's MTBF is
    directly comparable to the exponential model at the same mu.
    """

    shape: ArrayLike = 0.7
    mu: Optional[ArrayLike] = None
    name: str = "weibull"

    def __post_init__(self):
        if np.any(np.asarray(self.shape) <= 0):
            raise ValueError(f"Weibull shape must be > 0, got {self.shape}")

    def _scale(self, mean, size=None):
        k = _lead(self.shape, size)
        return _lead(self.resolve_mean(mean), size) / _gamma1p(1.0 / k), k

    def sample(self, rng, size=None, mean=None):
        lam, k = self._scale(mean, size)
        return lam * rng.weibull(k, size=size)

    def sample_gaps(self, key, size, mean=None):
        # Inverse CDF through the standard exponential: X = lam * E^(1/k)
        # with E ~ Exp(1) (so -log U never sees U == 0).
        import jax
        import jax.numpy as jnp
        k = _lead_j(self.shape, size)
        lam = self._device_mean(mean, size) / _lead_j(
            _gamma1p(1.0 / np.asarray(self.shape, dtype=np.float64)), size)
        e = jax.random.exponential(key, size, dtype=jnp.float64)
        return lam * e ** (1.0 / k)

    def cache_token(self):
        return (type(self).__name__, _param_token(self.shape),
                _param_token(self.mu))

    def traced_sampler(self):
        import jax
        import jax.numpy as jnp
        k = np.asarray(self.shape, dtype=np.float64)
        inv_gamma = 1.0 / np.asarray(_gamma1p(1.0 / k), dtype=np.float64)

        def fn(key, size, mean, params):
            kk, ig = params
            e = jax.random.exponential(key, size, dtype=jnp.float64)
            return mean * ig * e ** (1.0 / kk)
        return ("weibull",), (k, inv_gamma), fn

    def gap_cv(self):
        k = np.asarray(self.shape, dtype=np.float64)
        g1 = _gamma1p(1.0 / k)
        g2 = _gamma1p(2.0 / k)
        return np.sqrt(np.maximum(g2 / g1**2 - 1.0, 0.0))

    def hazard(self, t, mean=None):
        lam, k = self._scale(mean)
        t = np.asarray(t, dtype=np.float64)
        return (k / lam) * (t / lam) ** (k - 1.0)

    def ravel(self) -> "Weibull":
        return dataclasses.replace(
            self, shape=np.ravel(self.shape),
            mu=None if self.mu is None else np.ravel(self.mu))


@dataclasses.dataclass(frozen=True)
class LogNormal(FailureProcess):
    """Log-normal gaps: exp(N(m, sigma^2)) with m chosen so the mean is mu.

    ``sigma`` is the shape parameter (log-space std); the hazard rises then
    falls — a common fit for repair-induced failure clustering.
    """

    sigma: ArrayLike = 1.0
    mu: Optional[ArrayLike] = None
    name: str = "lognormal"

    def __post_init__(self):
        if np.any(np.asarray(self.sigma) <= 0):
            raise ValueError(f"LogNormal sigma must be > 0, got {self.sigma}")

    def sample(self, rng, size=None, mean=None):
        s = _lead(self.sigma, size)
        m = np.log(_lead(self.resolve_mean(mean), size)) - 0.5 * s * s
        return rng.lognormal(mean=m, sigma=s, size=size)

    def sample_gaps(self, key, size, mean=None):
        import jax
        import jax.numpy as jnp
        s = _lead_j(self.sigma, size)
        m = jnp.log(self._device_mean(mean, size)) - 0.5 * s * s
        z = jax.random.normal(key, size, dtype=jnp.float64)
        return jnp.exp(m + s * z)

    def cache_token(self):
        return (type(self).__name__, _param_token(self.sigma),
                _param_token(self.mu))

    def traced_sampler(self):
        import jax
        import jax.numpy as jnp
        sigma = np.asarray(self.sigma, dtype=np.float64)

        def fn(key, size, mean, params):
            (s,) = params
            z = jax.random.normal(key, size, dtype=jnp.float64)
            return jnp.exp(jnp.log(mean) - 0.5 * s * s + s * z)
        return ("lognormal",), (sigma,), fn

    def gap_cv(self):
        s = np.asarray(self.sigma, dtype=np.float64)
        return np.sqrt(np.expm1(s * s))

    def hazard(self, t, mean=None):
        s = np.asarray(self.sigma, dtype=np.float64)
        m = np.log(self.resolve_mean(mean)) - 0.5 * s * s
        t = np.asarray(t, dtype=np.float64)
        z = (np.log(t) - m) / s
        pdf = np.exp(-0.5 * z * z) / (t * s * math.sqrt(2.0 * math.pi))
        sf = 0.5 * _erfc(z / math.sqrt(2.0))
        return pdf / sf

    def ravel(self) -> "LogNormal":
        return dataclasses.replace(
            self, sigma=np.ravel(self.sigma),
            mu=None if self.mu is None else np.ravel(self.mu))


@dataclasses.dataclass(frozen=True)
class TraceReplay(FailureProcess):
    """Replay an empirical inter-failure gap log.

    Each trajectory replays the trace *cyclically from a uniformly random
    starting offset*, so trajectories differ in phase but preserve the
    trace's gap ordering (and hence its clustering / autocorrelation —
    exactly what i.i.d. resampling would destroy).  When the caller
    supplies a target mean (a grid's mu), gaps are rescaled by
    ``mean / trace_mean``; construct with ``rescale=False`` to forbid that
    and always replay the raw trace.
    """

    gaps: tuple = ()
    rescale: bool = True
    name: str = "trace"

    def __post_init__(self):
        g = np.asarray(self.gaps, dtype=np.float64).ravel()
        if g.size == 0:
            raise ValueError("TraceReplay needs at least one gap")
        if np.any(g <= 0) or not np.all(np.isfinite(g)):
            raise ValueError("trace gaps must be finite and > 0")
        object.__setattr__(self, "gaps", tuple(float(x) for x in g))

    @property
    def mu(self):  # type: ignore[override]
        return float(np.mean(self.gaps))

    def resolve_mean(self, mean=None):
        if mean is None or not self.rescale:
            return np.asarray(self.mu, dtype=np.float64)
        return np.asarray(mean, dtype=np.float64)

    def gap_cv(self):
        g = np.asarray(self.gaps)
        return float(g.std() / g.mean()) if g.size > 1 else 1.0

    def sample(self, rng, size=None, mean=None):
        trace = np.asarray(self.gaps, dtype=np.float64)
        n = trace.size
        if size is None:
            # A single draw cannot carry the trace's ordering — use
            # iter_gaps for sequential scalar draws (the simulator does).
            return float(trace[int(rng.integers(n))]) \
                * float(self.resolve_mean(mean) / self.mu)
        size = tuple(size)
        start = rng.integers(n, size=size[:-1] + (1,))
        idx = (start + np.arange(size[-1])) % n
        out = trace[idx] * (_lead(self.resolve_mean(mean), size) / self.mu)
        return np.broadcast_to(out, size).copy()

    def sample_gaps(self, key, size, mean=None):
        """Device replay: one uniform starting offset per leading index
        (trajectory), then a cyclic gather — mirrors :meth:`sample`."""
        import jax
        import jax.numpy as jnp
        trace = jnp.asarray(self.gaps, dtype=jnp.float64)
        n = len(self.gaps)
        start = jax.random.randint(key, size[:-1] + (1,), 0, n)
        idx = (start + jnp.arange(size[-1], dtype=start.dtype)) % n
        scale = (_lead_j(mean, size) / self.mu
                 if (mean is not None and self.rescale) else 1.0)
        return jnp.broadcast_to(trace[idx] * scale, size)

    def cache_token(self):
        return (type(self).__name__, self.gaps, self.rescale)

    def traced_sampler(self):
        import jax
        import jax.numpy as jnp
        trace = np.asarray(self.gaps, dtype=np.float64)
        n = trace.size
        trace_mu = float(self.mu)
        rescale = self.rescale

        def fn(key, size, mean, params):
            tr = jnp.asarray(trace, dtype=jnp.float64)
            start = jax.random.randint(key, size[:-1] + (1,), 0, n)
            idx = (start + jnp.arange(size[-1], dtype=start.dtype)) % n
            # mean arrives pre-resolved (resolve_mean), so with
            # rescale=False it already equals the trace mean and the
            # static 1.0 below is exact, not an approximation.
            scale = mean / trace_mu if rescale else 1.0
            return jnp.broadcast_to(tr[idx] * scale, size)
        return ("trace", self.gaps, self.rescale), (), fn

    def iter_gaps(self, rng, mean=None):
        """Cyclic replay from one uniformly random starting offset — the
        scalar counterpart of the per-trajectory ``sample`` rows, keeping
        the trace's ordering/autocorrelation (i.i.d. draws would not)."""
        trace = np.asarray(self.gaps, dtype=np.float64)
        scale = float(self.resolve_mean(mean) / self.mu)
        i = int(rng.integers(trace.size))
        while True:
            yield float(trace[i]) * scale
            i = (i + 1) % trace.size


# ---------------------------------------------------------------------------
# Registry / coercion
# ---------------------------------------------------------------------------

PROCESSES = {
    "exponential": Exponential,
    "weibull": Weibull,
    "lognormal": LogNormal,
    "trace": TraceReplay,
}


def get_process(name: str, **kwargs) -> FailureProcess:
    """Build a process by name (``weibull``, ``lognormal``, ...)."""
    try:
        cls = PROCESSES[name]
    except KeyError:
        raise KeyError(f"unknown failure process {name!r}; "
                       f"one of {sorted(PROCESSES)}") from None
    return cls(**kwargs)


def as_process(p) -> FailureProcess:
    """Coerce None (-> Exponential), a name, or a process instance."""
    if p is None:
        return Exponential()
    if isinstance(p, str):
        return get_process(p)
    if isinstance(p, FailureProcess):
        return p
    raise TypeError(f"not a failure process: {p!r}")


# ---------------------------------------------------------------------------
# Scalar helpers (math.gamma / erfc vectorized over small parameter arrays)
# ---------------------------------------------------------------------------

_vgamma = np.vectorize(math.gamma, otypes=[np.float64])
_verfc = np.vectorize(math.erfc, otypes=[np.float64])


def _gamma1p(x):
    """Gamma(1 + x), elementwise (scipy-free)."""
    out = _vgamma(1.0 + np.asarray(x, dtype=np.float64))
    return out if out.ndim else float(out)


def _erfc(x):
    out = _verfc(np.asarray(x, dtype=np.float64))
    return out if out.ndim else float(out)
