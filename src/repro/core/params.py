"""Model parameters for the Aupy et al. checkpoint time/energy model.

All durations share one time unit (the paper uses minutes; the runtime uses
seconds — the model is unit-agnostic as long as C, R, D, mu, T agree).
Powers share one power unit (the paper normalizes to milliwatt/node).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

MINUTE = 1.0  # canonical paper unit; runtime converts seconds -> minutes


@dataclasses.dataclass(frozen=True)
class CheckpointParams:
    """Resilience parameters (paper §2.1).

    C   : checkpoint duration.
    R   : recovery (read back) duration.
    D   : downtime (reboot / spare swap-in).
    mu  : *platform* MTBF.  If built from per-component MTBF ``mu_ind`` and
          ``n`` components, ``mu = mu_ind / n`` (probabilistic amplification).
    omega : slow-down factor in [0,1] — work performed during a checkpoint is
          ``omega*C`` work units.  omega=0 -> fully blocking, omega=1 -> fully
          overlapped.
    """

    C: float
    R: float
    D: float
    mu: float
    omega: float = 0.0

    def __post_init__(self) -> None:
        if not (0.0 <= self.omega <= 1.0):
            raise ValueError(f"omega must be in [0,1], got {self.omega}")
        for name in ("C", "R", "D"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0")
        if self.mu <= 0:
            raise ValueError("mu must be > 0")
        # First-order validity regime (paper §2.1): C, D, R small vs mu.
        # Not enforced — the experiments deliberately push C ~ mu (Fig. 3).

    # -- derived quantities (paper §3.1) ------------------------------------
    @property
    def a(self) -> float:
        """a = (1-omega) C : work units lost to checkpoint jitter per period."""
        return (1.0 - self.omega) * self.C

    @property
    def b(self) -> float:
        """b = 1 - (D + R + omega*C)/mu."""
        return 1.0 - (self.D + self.R + self.omega * self.C) / self.mu

    def valid_period_range(self) -> tuple[float, float]:
        """Open interval of T where T_final is positive/finite.

        Requires T > a (positive work per period) and T < 2*mu*b (expected
        failure overhead per unit time < 1).
        """
        lo = max(self.a, self.C)  # a period must at least contain a checkpoint
        hi = 2.0 * self.mu * self.b
        return lo, hi

    @classmethod
    def from_platform(
        cls,
        *,
        n_nodes: int,
        mu_ind: float,
        C: float,
        R: float,
        D: float,
        omega: float = 0.0,
    ) -> "CheckpointParams":
        """Platform MTBF from per-node MTBF: mu = mu_ind / N (paper §2.1)."""
        return cls(C=C, R=R, D=D, mu=mu_ind / float(n_nodes), omega=omega)


@dataclasses.dataclass(frozen=True)
class PowerParams:
    """Power parameters (paper §2.2), in a common power unit.

    P_static : base power when the platform is on.
    P_cal    : CPU overhead power while computing.
    P_io     : I/O overhead power while checkpointing / recovering.
    P_down   : overhead while a machine is down (paper uses 0).
    """

    P_static: float
    P_cal: float
    P_io: float
    P_down: float = 0.0

    def __post_init__(self) -> None:
        if self.P_static <= 0:
            raise ValueError("P_static must be > 0 (alpha/beta/gamma undefined)")

    # -- normalized overheads (paper §3.2) -----------------------------------
    @property
    def alpha(self) -> float:
        return self.P_cal / self.P_static

    @property
    def beta(self) -> float:
        return self.P_io / self.P_static

    @property
    def gamma(self) -> float:
        return self.P_down / self.P_static

    @property
    def rho(self) -> float:
        """rho = (P_static + P_io)/(P_static + P_cal) = (1+beta)/(1+alpha).

        Paper Eq. (2) — the key experimental knob.
        """
        return (1.0 + self.beta) / (1.0 + self.alpha)

    @classmethod
    def from_ratios(
        cls, *, alpha: float, beta: float, gamma: float = 0.0, P_static: float = 1.0
    ) -> "PowerParams":
        return cls(
            P_static=P_static,
            P_cal=alpha * P_static,
            P_io=beta * P_static,
            P_down=gamma * P_static,
        )

    @classmethod
    def from_rho(
        cls, *, rho: float, alpha: float = 1.0, gamma: float = 0.0,
        P_static: float = 1.0,
    ) -> "PowerParams":
        """Build powers achieving a target rho at fixed alpha (Fig. 1 sweep)."""
        beta = rho * (1.0 + alpha) - 1.0
        if beta < 0:
            raise ValueError(f"rho={rho} with alpha={alpha} needs beta<0")
        return cls.from_ratios(alpha=alpha, beta=beta, gamma=gamma,
                               P_static=P_static)


# --- Paper §4 reference scenarios -------------------------------------------

#: Exascale power scenario #1: 20 MW / 1e6 nodes = 20 mW/node, half static.
#: rho = 5.5.
EXASCALE_POWER_RHO55 = PowerParams(P_static=10.0, P_cal=10.0, P_io=100.0,
                                   P_down=0.0)

#: Exascale power scenario #2: P_static = 5 mW, same overheads.  rho = 7.
EXASCALE_POWER_RHO7 = PowerParams(P_static=5.0, P_cal=10.0, P_io=100.0,
                                  P_down=0.0)

#: Jaguar-derived per-processor MTBF: 45,208 procs, ~1 fault/day ->
#: mu_ind = 45208/365 years ~ 125 years (paper §4), in minutes.
MU_IND_JAGUAR_MIN = 125.0 * 365.0 * 24.0 * 60.0

#: Figures 1-2 resilience scenario: C = R = 10 min, D = 1 min, omega = 1/2.
def fig12_checkpoint(mu_min: float) -> CheckpointParams:
    return CheckpointParams(C=10.0, R=10.0, D=1.0, mu=mu_min, omega=0.5)

#: Figure 3 scalability scenario: C = R = 1 min, D = 0.1 min, omega = 1/2,
#: MTBF 120 min at 1e6 nodes scaling ~ 1/N.
def fig3_checkpoint(n_nodes: float) -> CheckpointParams:
    mu = 120.0 * (1.0e6 / float(n_nodes))
    return CheckpointParams(C=1.0, R=1.0, D=0.1, mu=mu, omega=0.5)
