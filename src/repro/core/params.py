"""Model parameters for the Aupy et al. checkpoint time/energy model.

All durations share one time unit (the paper uses minutes; the runtime uses
seconds — the model is unit-agnostic as long as C, R, D, mu, T agree).
Powers share one power unit (the paper normalizes to milliwatt/node).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

MINUTE = 1.0  # canonical paper unit; runtime converts seconds -> minutes


@dataclasses.dataclass(frozen=True)
class CheckpointParams:
    """Resilience parameters (paper §2.1).

    C   : checkpoint duration.
    R   : recovery (read back) duration.
    D   : downtime (reboot / spare swap-in).
    mu  : *platform* MTBF.  If built from per-component MTBF ``mu_ind`` and
          ``n`` components, ``mu = mu_ind / n`` (probabilistic amplification).
    omega : slow-down factor in [0,1] — work performed during a checkpoint is
          ``omega*C`` work units.  omega=0 -> fully blocking, omega=1 -> fully
          overlapped.
    """

    C: float
    R: float
    D: float
    mu: float
    omega: float = 0.0

    def __post_init__(self) -> None:
        if not (0.0 <= self.omega <= 1.0):
            raise ValueError(f"omega must be in [0,1], got {self.omega}")
        for name in ("C", "R", "D"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0")
        if self.mu <= 0:
            raise ValueError("mu must be > 0")
        # First-order validity regime (paper §2.1): C, D, R small vs mu.
        # Not enforced — the experiments deliberately push C ~ mu (Fig. 3).

    # -- derived quantities (paper §3.1) ------------------------------------
    @property
    def a(self) -> float:
        """a = (1-omega) C : work units lost to checkpoint jitter per period."""
        return (1.0 - self.omega) * self.C

    @property
    def b(self) -> float:
        """b = 1 - (D + R + omega*C)/mu."""
        return 1.0 - (self.D + self.R + self.omega * self.C) / self.mu

    def valid_period_range(self) -> tuple[float, float]:
        """Open interval of T where T_final is positive/finite.

        Requires T > a (positive work per period) and T < 2*mu*b (expected
        failure overhead per unit time < 1).
        """
        lo = max(self.a, self.C)  # a period must at least contain a checkpoint
        hi = 2.0 * self.mu * self.b
        return lo, hi

    @classmethod
    def from_platform(
        cls,
        *,
        n_nodes: int,
        mu_ind: float,
        C: float,
        R: float,
        D: float,
        omega: float = 0.0,
    ) -> "CheckpointParams":
        """Platform MTBF from per-node MTBF: mu = mu_ind / N (paper §2.1)."""
        return cls(C=C, R=R, D=D, mu=mu_ind / float(n_nodes), omega=omega)


@dataclasses.dataclass(frozen=True)
class PowerParams:
    """Power parameters (paper §2.2), in a common power unit.

    P_static : base power when the platform is on.
    P_cal    : CPU overhead power while computing.
    P_io     : I/O overhead power while checkpointing / recovering.
    P_down   : overhead while a machine is down (paper uses 0).
    """

    P_static: float
    P_cal: float
    P_io: float
    P_down: float = 0.0

    def __post_init__(self) -> None:
        if self.P_static <= 0:
            raise ValueError("P_static must be > 0 (alpha/beta/gamma undefined)")

    # -- normalized overheads (paper §3.2) -----------------------------------
    @property
    def alpha(self) -> float:
        return self.P_cal / self.P_static

    @property
    def beta(self) -> float:
        return self.P_io / self.P_static

    @property
    def gamma(self) -> float:
        return self.P_down / self.P_static

    @property
    def rho(self) -> float:
        """rho = (P_static + P_io)/(P_static + P_cal) = (1+beta)/(1+alpha).

        Paper Eq. (2) — the key experimental knob.
        """
        return (1.0 + self.beta) / (1.0 + self.alpha)

    @classmethod
    def from_ratios(
        cls, *, alpha: float, beta: float, gamma: float = 0.0, P_static: float = 1.0
    ) -> "PowerParams":
        return cls(
            P_static=P_static,
            P_cal=alpha * P_static,
            P_io=beta * P_static,
            P_down=gamma * P_static,
        )

    @classmethod
    def from_rho(
        cls, *, rho: float, alpha: float = 1.0, gamma: float = 0.0,
        P_static: float = 1.0,
    ) -> "PowerParams":
        """Build powers achieving a target rho at fixed alpha (Fig. 1 sweep)."""
        beta = rho * (1.0 + alpha) - 1.0
        if beta < 0:
            raise ValueError(f"rho={rho} with alpha={alpha} needs beta<0")
        return cls.from_ratios(alpha=alpha, beta=beta, gamma=gamma,
                               P_static=P_static)


# --------------------------------------------------------------------------
# Multilevel (buddy + PFS) extension
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MultilevelCheckpointParams:
    """Two-level (buddy + PFS) resilience parameters.

    The execution takes a checkpoint at the end of every period of length T.
    Level 1 ("buddy": RAM-to-RAM replication, paper refs [12,14]) is cheap;
    every ``m``-th checkpoint instead writes the deep level 2 ("PFS"), which
    refreshes *both* recovery levels (VELOC semantics: the local/buddy copy
    is always current, the PFS flush is the every-m-th deepening).

    A failure destroys the buddy copy too with probability ``q`` (e.g. both
    nodes of a buddy pair die, or a rack loss): recovery then reads the last
    PFS checkpoint, losing up to ``m`` periods of work.  With probability
    ``1-q`` the buddy survives and recovery is shallow.

    C1, R1, D1 : level-1 checkpoint / recovery / downtime durations.
    C2, R2, D2 : level-2 (deep) durations; typically C2 >> C1.
    mu         : platform MTBF (all failures, both kinds).
    q          : P[failure also loses the level-1 copy] in [0, 1].
    omega      : shared checkpoint overlap factor (work rate during a write).
    omega1     : buddy-write overlap factor; None -> ``omega``.
    omega2     : deep-flush overlap factor; None -> ``omega``.  This is the
                 VELOC knob: the PFS write occupies a *flush-in-flight*
                 interval of wall length C2 during which compute progresses
                 at rate ``omega2`` and the in-flight generation is NOT yet
                 committed — a failure inside the window loses it and rolls
                 back to the previous surviving level.  ``omega2 -> 1``
                 removes the flush from the critical path entirely while
                 keeping the hazard-during-flush loss term.

    ``m`` is a *decision variable* (like T), not a parameter: the per-``m``
    derived quantities below are methods.  With degenerate levels
    (C1 == C2, R1 == R2, D1 == D2) and ``m = 1`` every formula reduces
    bit-for-bit to the single-level :class:`CheckpointParams` model; with
    ``omega1 == omega2`` every formula reduces bit-for-bit to the shared-
    omega form (the per-level branches re-use the exact old expressions).
    """

    C1: float
    R1: float
    C2: float
    R2: float
    D1: float
    D2: float
    mu: float
    q: float = 0.1
    omega: float = 0.0
    omega1: Optional[float] = None
    omega2: Optional[float] = None

    def __post_init__(self) -> None:
        if not (0.0 <= self.omega <= 1.0):
            raise ValueError(f"omega must be in [0,1], got {self.omega}")
        for name in ("omega1", "omega2"):
            w = getattr(self, name)
            if w is not None and not (0.0 <= w <= 1.0):
                raise ValueError(f"{name} must be in [0,1], got {w}")
        if not (0.0 <= self.q <= 1.0):
            raise ValueError(f"q must be in [0,1], got {self.q}")
        for name in ("C1", "R1", "C2", "R2", "D1", "D2"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0")
        if self.mu <= 0:
            raise ValueError("mu must be > 0")

    # -- per-level overlap ---------------------------------------------------
    @property
    def w1(self) -> float:
        """Effective buddy-write overlap (omega1, defaulting to omega)."""
        return self.omega if self.omega1 is None else self.omega1

    @property
    def w2(self) -> float:
        """Effective deep-flush overlap (omega2, defaulting to omega)."""
        return self.omega if self.omega2 is None else self.omega2

    @property
    def _shared_omega(self) -> bool:
        """True when both levels share one overlap factor — the formulas
        below then use the exact pre-async expressions (bit-for-bit)."""
        return self.w1 == self.w2

    # -- per-m derived quantities (multilevel analogue of §3.1) --------------
    def C_mean(self, m: int) -> float:
        """Mean checkpoint cost per period: ((m-1) C1 + C2) / m."""
        return ((m - 1) * self.C1 + self.C2) / m

    def C_omega_mean(self, m: int) -> float:
        """Mean *overlapped* checkpoint cost per period,
        ((m-1) w1 C1 + w2 C2) / m — the work done during a write that is
        unprotected until the write commits (flush-in-flight loss)."""
        if self._shared_omega:
            return self.w1 * self.C_mean(m)
        return ((m - 1) * self.w1 * self.C1 + self.w2 * self.C2) / m

    def a(self, m: int) -> float:
        """a_m = mean critical-path share of the per-period checkpoint:
        ((m-1)(1-w1) C1 + (1-w2) C2) / m."""
        if self._shared_omega:
            return (1.0 - self.w1) * self.C_mean(m)
        return ((m - 1) * (1.0 - self.w1) * self.C1
                + (1.0 - self.w2) * self.C2) / m

    def flush_window(self, m: int) -> float:
        """Wall length of the deep flush-in-flight interval beyond its
        critical-path stall: ``w2 * C2`` (0 for a fully blocking write).
        A failure landing inside it loses the in-flight deep generation."""
        del m  # per-superperiod window; independent of m
        return self.w2 * self.C2

    def expected_fixed_loss(self, m: int) -> float:
        """E[D + R + w*C_lag per failure], mixing soft/hard with q.

        Written as ``soft + q*(hard - soft)`` so degenerate levels reduce
        exactly (the difference is exactly 0.0, no (1-q)x + qx rounding).
        The ``w*C`` terms are the hazard-during-flush loss: work performed
        while the previous write was in flight is uncommitted until the
        write ends, so a failure re-executes it.
        """
        soft = self.D1 + self.R1 + self.C_omega_mean(m)
        hard = self.D2 + self.R2 + self.w2 * self.C2
        return soft + self.q * (hard - soft)

    def S2(self, m: int) -> float:
        """E[C_k^2] over the period types: ((m-1) C1^2 + C2^2) / m."""
        return ((m - 1) * self.C1**2 + self.C2**2) / m

    def S2_omega(self, m: int) -> float:
        """E[w_k C_k^2] over the period types (the overlapped share of the
        quadratic in-flight I/O loss): ((m-1) w1 C1^2 + w2 C2^2) / m."""
        if self._shared_omega:
            return self.w1 * self.S2(m)
        return ((m - 1) * self.w1 * self.C1**2
                + self.w2 * self.C2**2) / m

    def b(self, m: int) -> float:
        """b_m = 1 - expected_fixed_loss(m) / mu."""
        return 1.0 - self.expected_fixed_loss(m) / self.mu

    def mu_eff(self, m: int) -> float:
        """Effective MTBF for the T/2 re-execution term.

        A hard failure loses ~m*T/2 instead of T/2, so the T-proportional
        loss scales by 1 + q(m-1): mu_eff = mu / (1 + q(m-1)).
        """
        return self.mu / (1.0 + self.q * (m - 1))

    def valid_period_range(self, m: int) -> tuple[float, float]:
        """Open interval of T where the multilevel T_final is positive."""
        lo = max(self.a(m), self.C1, self.C2)
        hi = 2.0 * self.mu_eff(m) * self.b(m)
        return lo, hi

    # -- conversions ---------------------------------------------------------
    def single_level(self) -> CheckpointParams:
        """The PFS-only comparator: every checkpoint deep, no buddy (the
        deep level's overlap factor applies — w2 == omega when unset)."""
        return CheckpointParams(C=self.C2, R=self.R2, D=self.D2, mu=self.mu,
                                omega=self.w2)

    def buddy_only(self) -> CheckpointParams:
        """The degraded-tier comparator: PFS unavailable, every checkpoint
        a buddy write (C1/R1/D1 at the buddy overlap).  The policy re-solves
        on this while the deep store is down."""
        return CheckpointParams(C=self.C1, R=self.R1, D=self.D1, mu=self.mu,
                                omega=self.w1)

    @classmethod
    def from_single(cls, ckpt: CheckpointParams, *,
                    C1: Optional[float] = None, R1: Optional[float] = None,
                    D1: Optional[float] = None,
                    q: float = 0.0) -> "MultilevelCheckpointParams":
        """Lift a single-level parameter set; levels default to degenerate
        (C1=C2 etc.), the exact-reduction construction used by parity tests."""
        return cls(C1=ckpt.C if C1 is None else C1,
                   R1=ckpt.R if R1 is None else R1,
                   C2=ckpt.C, R2=ckpt.R,
                   D1=ckpt.D if D1 is None else D1, D2=ckpt.D,
                   mu=ckpt.mu, q=q, omega=ckpt.omega)


@dataclasses.dataclass(frozen=True)
class MultilevelPowerParams:
    """Power parameters with per-level I/O overheads.

    P_io1 : overhead while writing/reading the buddy level (NIC + remote RAM
            — materially lower than PFS draw, cf. Moran et al.'s per-level
            energy characterization).
    P_io2 : overhead while writing/reading the deep (PFS) level.
    """

    P_static: float
    P_cal: float
    P_io1: float
    P_io2: float
    P_down: float = 0.0

    def __post_init__(self) -> None:
        if self.P_static <= 0:
            raise ValueError("P_static must be > 0")

    @property
    def alpha(self) -> float:
        return self.P_cal / self.P_static

    @property
    def beta1(self) -> float:
        return self.P_io1 / self.P_static

    @property
    def beta2(self) -> float:
        return self.P_io2 / self.P_static

    @property
    def gamma(self) -> float:
        return self.P_down / self.P_static

    @property
    def rho2(self) -> float:
        """Deep-level rho = (P_static + P_io2) / (P_static + P_cal)."""
        return (self.P_static + self.P_io2) / (self.P_static + self.P_cal)

    def single_level(self) -> PowerParams:
        """PFS-only comparator powers (P_io = P_io2)."""
        return PowerParams(P_static=self.P_static, P_cal=self.P_cal,
                           P_io=self.P_io2, P_down=self.P_down)

    @classmethod
    def from_power(cls, power: PowerParams,
                   P_io1: Optional[float] = None) -> "MultilevelPowerParams":
        """Lift single-level powers; P_io1 defaults to degenerate (= P_io)."""
        return cls(P_static=power.P_static, P_cal=power.P_cal,
                   P_io1=power.P_io if P_io1 is None else P_io1,
                   P_io2=power.P_io, P_down=power.P_down)


# --- Paper §4 reference scenarios -------------------------------------------

#: Exascale power scenario #1: 20 MW / 1e6 nodes = 20 mW/node, half static.
#: rho = 5.5.
EXASCALE_POWER_RHO55 = PowerParams(P_static=10.0, P_cal=10.0, P_io=100.0,
                                   P_down=0.0)

#: Exascale power scenario #2: P_static = 5 mW, same overheads.  rho = 7.
EXASCALE_POWER_RHO7 = PowerParams(P_static=5.0, P_cal=10.0, P_io=100.0,
                                  P_down=0.0)

#: Exascale two-level power scenario: PFS I/O at the paper's 100 mW overhead,
#: buddy (NIC + remote RAM) at 20 mW — the per-level split of scenario #1.
EXASCALE_ML_POWER = MultilevelPowerParams(P_static=10.0, P_cal=10.0,
                                          P_io1=20.0, P_io2=100.0,
                                          P_down=0.0)

#: Jaguar-derived per-processor MTBF: 45,208 procs, ~1 fault/day ->
#: mu_ind = 45208/365 years ~ 125 years (paper §4), in minutes.
MU_IND_JAGUAR_MIN = 125.0 * 365.0 * 24.0 * 60.0

#: Figures 1-2 resilience scenario: C = R = 10 min, D = 1 min, omega = 1/2.
def fig12_checkpoint(mu_min: float) -> CheckpointParams:
    return CheckpointParams(C=10.0, R=10.0, D=1.0, mu=mu_min, omega=0.5)

#: Figure 3 scalability scenario: C = R = 1 min, D = 0.1 min, omega = 1/2,
#: MTBF 120 min at 1e6 nodes scaling ~ 1/N.
def fig3_checkpoint(n_nodes: float) -> CheckpointParams:
    mu = 120.0 * (1.0e6 / float(n_nodes))
    return CheckpointParams(C=1.0, R=1.0, D=0.1, mu=mu, omega=0.5)
