"""Time/energy trade-off sweeps — the quantities plotted in Figures 1-3.

All ratios follow the paper's conventions:
  time_ratio   = T_final(AlgoE) / T_final(AlgoT)   (>= 1; "loss in time")
  energy_ratio = E_final(AlgoT) / E_final(AlgoE)   (>= 1; "gain in energy")

``evaluate`` is the scalar reference path (one point, exact solvers from
``optimal``).  The sweep functions delegate to the batched ``repro.sim``
subsystem by default — the whole grid is solved in a few jitted float64
calls — and return the same ``TradeoffPoint`` lists as before; pass
``engine="scalar"`` to force the per-point reference loop (used by the
parity tests and the sweep benchmark).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from . import model, optimal
from .params import (CheckpointParams, MultilevelCheckpointParams,
                     MultilevelPowerParams, PowerParams, fig12_checkpoint,
                     fig3_checkpoint)


@dataclasses.dataclass(frozen=True)
class TradeoffPoint:
    ckpt: CheckpointParams
    power: PowerParams
    T_time: float              # AlgoT period
    T_energy: float            # AlgoE period
    time_ratio: float          # T_final(AlgoE)/T_final(AlgoT)
    energy_ratio: float        # E_final(AlgoT)/E_final(AlgoE)

    @property
    def energy_saving(self) -> float:
        """Fraction of energy saved by AlgoE vs AlgoT (paper: 'gain')."""
        return 1.0 - 1.0 / self.energy_ratio

    @property
    def time_overhead(self) -> float:
        """Fractional slowdown of AlgoE vs AlgoT (paper: 'loss')."""
        return self.time_ratio - 1.0


def evaluate(ckpt: CheckpointParams, power: PowerParams) -> TradeoffPoint:
    lo, hi = ckpt.valid_period_range()
    if hi <= lo * (1.0 + 1e-9):
        # Degenerate regime (paper §4, Fig. 3 right edge): C is of the order
        # of the MTBF, both strategies collapse to the minimum period ~ C and
        # the time/energy ratios converge to 1.
        return TradeoffPoint(ckpt=ckpt, power=power, T_time=ckpt.C,
                             T_energy=ckpt.C, time_ratio=1.0,
                             energy_ratio=1.0)
    Tt = optimal.t_opt_time(ckpt)
    Te = optimal.t_opt_energy(ckpt, power)
    t_ratio = float(model.time_final(Te, ckpt) / model.time_final(Tt, ckpt))
    e_ratio = float(model.energy_final(Tt, ckpt, power)
                    / model.energy_final(Te, ckpt, power))
    return TradeoffPoint(ckpt=ckpt, power=power, T_time=Tt, T_energy=Te,
                         time_ratio=t_ratio, energy_ratio=e_ratio)


def _points_from_grid(res) -> np.ndarray:
    """GridResult -> object array of TradeoffPoint with the grid's shape."""
    grid = res.grid
    out = np.empty(grid.shape, dtype=object)
    for idx in np.ndindex(grid.shape):
        out[idx] = TradeoffPoint(
            ckpt=grid.ckpt_at(idx), power=grid.power_at(idx),
            T_time=float(res.T_time[idx]), T_energy=float(res.T_energy[idx]),
            time_ratio=float(res.time_ratio[idx]),
            energy_ratio=float(res.energy_ratio[idx]))
    return out


# ----------------------------------------------------------------------
# Multilevel (buddy + PFS) trade-off
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MultilevelTradeoffPoint:
    """Jointly optimal (T, m) for AlgoT and AlgoE on a two-level platform,
    plus the same ratio conventions as :class:`TradeoffPoint` and the
    overhead comparison against the PFS-only single-level scheme."""

    ckpt: MultilevelCheckpointParams
    power: MultilevelPowerParams
    T_time: float              # AlgoT period
    m_time: int                # AlgoT PFS cadence (deep ckpt every m-th)
    T_energy: float            # AlgoE period
    m_energy: int
    time_ratio: float          # T_final(AlgoE)/T_final(AlgoT)
    energy_ratio: float        # E_final(AlgoT)/E_final(AlgoE)
    time_vs_single: float      # T_final(AlgoT, 2-level)/T_final(AlgoT, PFS-only)
    energy_vs_single: float    # E_final(AlgoE, 2-level)/E_final(AlgoE, PFS-only)

    @property
    def energy_saving(self) -> float:
        return 1.0 - 1.0 / self.energy_ratio

    @property
    def time_overhead(self) -> float:
        return self.time_ratio - 1.0


def evaluate_multilevel(ck: MultilevelCheckpointParams,
                        power: MultilevelPowerParams,
                        m_max: int = optimal.DEFAULT_M_MAX,
                        ) -> MultilevelTradeoffPoint:
    """Scalar reference evaluation of one two-level operating point."""
    Tt, mt = optimal.t_opt_time_multilevel(ck, m_max)
    Te, me = optimal.t_opt_energy_multilevel(ck, power, m_max)
    tf_t = float(model.ml_time_final(Tt, mt, ck))
    tf_e = float(model.ml_time_final(Te, me, ck))
    e_t = float(model.ml_energy_final(Tt, mt, ck, power))
    e_e = float(model.ml_energy_final(Te, me, ck, power))

    # PFS-only comparator (the seed single-level model on C2/R2/D2).  When
    # the comparator has no valid period at all — the buddy level rescuing
    # an otherwise infeasible platform — the vs-single ratios are NaN.
    sl_ck, sl_pw = ck.single_level(), power.single_level()
    lo, hi = sl_ck.valid_period_range()
    if hi <= lo * (1.0 + 1e-9):
        tvs = evs = float("nan")
    else:
        single = evaluate(sl_ck, sl_pw)
        tvs = tf_t / float(model.time_final(single.T_time, sl_ck))
        evs = e_e / float(model.energy_final(single.T_energy, sl_ck, sl_pw))
    return MultilevelTradeoffPoint(
        ckpt=ck, power=power, T_time=Tt, m_time=mt, T_energy=Te, m_energy=me,
        time_ratio=tf_e / tf_t, energy_ratio=e_t / e_e,
        time_vs_single=tvs, energy_vs_single=evs)


def sweep_buddy_ratio(ratios: Sequence[float], qs: Sequence[float],
                      mu_minutes: float = 300.0,
                      m_max: int = optimal.DEFAULT_M_MAX,
                      engine: str = "batched"):
    """Exascale two-level sweep: buddy cost ratio x buddy-loss probability.

    Returns a (len(ratios), len(qs)) nested list of
    :class:`MultilevelTradeoffPoint`.  The batched path solves the whole
    grid in one jitted call (``sim.evaluate_multilevel_grid``).
    """
    if engine == "scalar":
        from ..sim.scenarios import get_scenario
        out = []
        for r in ratios:
            row = []
            for q in qs:
                sc = get_scenario("multilevel_exascale", mu_min=mu_minutes,
                                  buddy_ratio=float(r), q=float(q))
                row.append(evaluate_multilevel(sc.ckpt, sc.power, m_max))
            out.append(row)
        return out
    from .. import sim
    res = sim.evaluate_multilevel_grid(
        sim.buddy_ratio_grid(ratios, qs, mu_min=mu_minutes),
        m_values=tuple(range(1, m_max + 1)))
    return [[res.point_at((i, j)) for j in range(len(qs))]
            for i in range(len(ratios))]


# ----------------------------------------------------------------------
# Robustness: what does assuming exponential failures cost?
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RobustnessPoint:
    """Time/energy penalty of exponential-assumption periods under a
    non-exponential failure process.

    The ``T_exp_*`` periods come from the paper's closed forms (which assume
    memoryless failures); ``T_mc_*`` are the true optima under ``process``
    (MC surrogate).  Penalties are ratios >= ~1: the factor by which wall
    time / energy exceeds the process-optimal value when the wrong period
    is used — all evaluated under common random numbers, so small
    differences are meaningful.
    """

    ckpt: CheckpointParams
    power: PowerParams
    process: object                  # FailureProcess
    T_exp_time: float                # AlgoT closed form (exponential model)
    T_exp_energy: float              # AlgoE quadratic root
    T_young: float
    T_daly: float
    T_mc_time: float                 # process-optimal (MC surrogate)
    T_mc_energy: float
    time_penalty_exp: float          # wall(T_exp_time) / wall(T_mc_time)
    energy_penalty_exp: float        # E(T_exp_energy) / E(T_mc_energy)
    time_penalty_young: float
    time_penalty_daly: float
    energy_penalty_young: float
    energy_penalty_daly: float

    @property
    def time_left_on_table(self) -> float:
        """Fractional extra wall time from trusting the exponential T*."""
        return self.time_penalty_exp - 1.0

    @property
    def energy_left_on_table(self) -> float:
        return self.energy_penalty_exp - 1.0


def evaluate_robustness(ckpt: CheckpointParams, power: PowerParams,
                        process=None, T_base: float | None = None,
                        n_trials: int = 160, seed: int = 0,
                        ) -> RobustnessPoint:
    """Scalar reference evaluation of one (platform, process) point.

    Builds one CRN MC surrogate (``optimal.MCSurrogate``), solves the
    process-optimal periods on it, and evaluates every candidate period on
    the *same* pre-sampled failure schedules.
    """
    from .failures import as_process
    process = as_process(process)
    sur = optimal.MCSurrogate(ckpt, power, process, T_base=T_base,
                              n_trials=n_trials, seed=seed)
    T_mc_t = sur.argmin("time")
    T_mc_e = sur.argmin("energy")
    Tt = optimal.t_opt_time(ckpt)
    Te = optimal.t_opt_energy(ckpt, power)
    Ty = optimal.t_young(ckpt)
    Td = optimal.t_daly(ckpt)
    # Baselines may leave the surrogate's safe search range on extreme
    # platforms; clip so the evaluation stays within the sampled budget.
    cands = np.clip([T_mc_t, T_mc_e, Tt, Te, Ty, Td], sur.lo, sur.hi)
    vals = sur(cands)
    wall, energy = vals["time"], vals["energy"]
    return RobustnessPoint(
        ckpt=ckpt, power=power, process=process,
        T_exp_time=Tt, T_exp_energy=Te, T_young=Ty, T_daly=Td,
        T_mc_time=T_mc_t, T_mc_energy=T_mc_e,
        time_penalty_exp=float(wall[2] / wall[0]),
        energy_penalty_exp=float(energy[3] / energy[1]),
        time_penalty_young=float(wall[4] / wall[0]),
        time_penalty_daly=float(wall[5] / wall[0]),
        energy_penalty_young=float(energy[4] / energy[1]),
        energy_penalty_daly=float(energy[5] / energy[1]))


# ----------------------------------------------------------------------
# Figure 1: ratios as a function of rho, for several mu
# ----------------------------------------------------------------------

def sweep_rho(rhos: Sequence[float], mu_minutes: float,
              alpha: float = 1.0,
              engine: str = "batched") -> list[TradeoffPoint]:
    """C=R=10, D=1, omega=1/2 (paper Fig. 1); rho swept at fixed alpha."""
    if engine == "scalar":
        ck = fig12_checkpoint(mu_minutes)
        return [evaluate(ck, PowerParams.from_rho(rho=r, alpha=alpha))
                for r in rhos]
    from .. import sim
    res = sim.sweep_rho_grid(rhos, mu_minutes, alpha)
    return list(_points_from_grid(res)[0])


# ----------------------------------------------------------------------
# Figure 2: ratio surfaces over (mu, rho)
# ----------------------------------------------------------------------

def sweep_mu_rho(mus: Sequence[float],
                 rhos: Sequence[float],
                 alpha: float = 1.0,
                 engine: str = "batched") -> list[list[TradeoffPoint]]:
    if engine == "scalar":
        return [[evaluate(fig12_checkpoint(mu),
                          PowerParams.from_rho(rho=r, alpha=alpha))
                 for r in rhos] for mu in mus]
    from .. import sim
    res = sim.sweep_mu_rho_grid(mus, rhos, alpha)
    return [list(row) for row in _points_from_grid(res)]


# ----------------------------------------------------------------------
# Figure 3: scalability in the number of nodes
# ----------------------------------------------------------------------

def sweep_nodes(n_nodes: Sequence[float],
                power: PowerParams,
                engine: str = "batched") -> list[TradeoffPoint]:
    """C=R=1, D=0.1, omega=1/2, mu = 120 min at 1e6 nodes, ~ 1/N."""
    if engine == "scalar":
        return [evaluate(fig3_checkpoint(n), power) for n in n_nodes]
    from .. import sim
    res = sim.sweep_nodes_grid(n_nodes, power)
    return list(_points_from_grid(res))
