"""Time/energy trade-off sweeps — the quantities plotted in Figures 1-3.

All ratios follow the paper's conventions:
  time_ratio   = T_final(AlgoE) / T_final(AlgoT)   (>= 1; "loss in time")
  energy_ratio = E_final(AlgoT) / E_final(AlgoE)   (>= 1; "gain in energy")
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from . import model, optimal
from .params import (CheckpointParams, PowerParams, fig12_checkpoint,
                     fig3_checkpoint)


@dataclasses.dataclass(frozen=True)
class TradeoffPoint:
    ckpt: CheckpointParams
    power: PowerParams
    T_time: float              # AlgoT period
    T_energy: float            # AlgoE period
    time_ratio: float          # T_final(AlgoE)/T_final(AlgoT)
    energy_ratio: float        # E_final(AlgoT)/E_final(AlgoE)

    @property
    def energy_saving(self) -> float:
        """Fraction of energy saved by AlgoE vs AlgoT (paper: 'gain')."""
        return 1.0 - 1.0 / self.energy_ratio

    @property
    def time_overhead(self) -> float:
        """Fractional slowdown of AlgoE vs AlgoT (paper: 'loss')."""
        return self.time_ratio - 1.0


def evaluate(ckpt: CheckpointParams, power: PowerParams) -> TradeoffPoint:
    lo, hi = ckpt.valid_period_range()
    if hi <= lo * (1.0 + 1e-9):
        # Degenerate regime (paper §4, Fig. 3 right edge): C is of the order
        # of the MTBF, both strategies collapse to the minimum period ~ C and
        # the time/energy ratios converge to 1.
        return TradeoffPoint(ckpt=ckpt, power=power, T_time=ckpt.C,
                             T_energy=ckpt.C, time_ratio=1.0,
                             energy_ratio=1.0)
    Tt = optimal.t_opt_time(ckpt)
    Te = optimal.t_opt_energy(ckpt, power)
    t_ratio = float(model.time_final(Te, ckpt) / model.time_final(Tt, ckpt))
    e_ratio = float(model.energy_final(Tt, ckpt, power)
                    / model.energy_final(Te, ckpt, power))
    return TradeoffPoint(ckpt=ckpt, power=power, T_time=Tt, T_energy=Te,
                         time_ratio=t_ratio, energy_ratio=e_ratio)


# ----------------------------------------------------------------------
# Figure 1: ratios as a function of rho, for several mu
# ----------------------------------------------------------------------

def sweep_rho(rhos: Sequence[float], mu_minutes: float,
              alpha: float = 1.0) -> list[TradeoffPoint]:
    """C=R=10, D=1, omega=1/2 (paper Fig. 1); rho swept at fixed alpha."""
    ck = fig12_checkpoint(mu_minutes)
    return [evaluate(ck, PowerParams.from_rho(rho=r, alpha=alpha))
            for r in rhos]


# ----------------------------------------------------------------------
# Figure 2: ratio surfaces over (mu, rho)
# ----------------------------------------------------------------------

def sweep_mu_rho(mus: Sequence[float],
                 rhos: Sequence[float],
                 alpha: float = 1.0) -> list[list[TradeoffPoint]]:
    return [[evaluate(fig12_checkpoint(mu), PowerParams.from_rho(rho=r,
                                                                 alpha=alpha))
             for r in rhos] for mu in mus]


# ----------------------------------------------------------------------
# Figure 3: scalability in the number of nodes
# ----------------------------------------------------------------------

def sweep_nodes(n_nodes: Sequence[float],
                power: PowerParams) -> list[TradeoffPoint]:
    """C=R=1, D=0.1, omega=1/2, mu = 120 min at 1e6 nodes, ~ 1/N."""
    return [evaluate(fig3_checkpoint(n), power) for n in n_nodes]
