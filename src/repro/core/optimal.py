"""Optimal checkpoint periods: AlgoT, AlgoE, and literature baselines.

AlgoT  — closed form  T_opt = sqrt(2 a b mu)  (paper Eq. (1)).
AlgoE  — unique positive root of the exact quadratic K(T)*E'(T); coefficients
         recovered by exact polynomial interpolation of the analytic product
         (3 points determine a quadratic; a 4th verifies the residual),
         sidestepping the paper's inconsistent printed algebra.  Cross-checked
         against a direct golden-section minimization of E_final.
Young  — T = sqrt(2 C mu) + C                      [Young 1974]
Daly   — T = sqrt(2 C (mu + D + R)) + C            [Daly 2004]
MSK    — Meneses–Sarood–Kalé energy model, reconstructed exactly as the
         paper's §3.2 side note describes (omega = 0; per-failure re-exec
         energy (T-2C)/2 * P_cal; per-failure I/O energy C * P_io).
"""
from __future__ import annotations

import dataclasses
import logging
import math
from typing import Callable, Optional, Sequence, Tuple

import numpy as np

from . import model
from .failures import FailureProcess, as_process
from .params import (CheckpointParams, MultilevelCheckpointParams,
                     MultilevelPowerParams, PowerParams)

_GOLDEN = (math.sqrt(5.0) - 1.0) / 2.0

logger = logging.getLogger(__name__)


# --------------------------------------------------------------------------
# Generic scalar minimizer (golden-section; unimodal objectives)
# --------------------------------------------------------------------------

def golden_section(f: Callable[[float], float], lo: float, hi: float,
                   tol: float = 1e-10, max_iter: int = 200) -> float:
    """Minimize unimodal ``f`` on [lo, hi] to relative tolerance ``tol``."""
    a, b = float(lo), float(hi)
    c = b - _GOLDEN * (b - a)
    d = a + _GOLDEN * (b - a)
    fc, fd = f(c), f(d)
    for _ in range(max_iter):
        if abs(b - a) <= tol * (abs(a) + abs(b)):
            break
        if fc < fd:
            b, d, fd = d, c, fc
            c = b - _GOLDEN * (b - a)
            fc = f(c)
        else:
            a, c, fc = c, d, fd
            d = a + _GOLDEN * (b - a)
            fd = f(d)
    return 0.5 * (a + b)


def _bracket(ckpt: CheckpointParams) -> Tuple[float, float]:
    """Valid open interval for T, slightly shrunk for numerical safety."""
    lo, hi = ckpt.valid_period_range()
    if hi <= lo:
        # The actual lower bound is lo = max(a, C) with a = (1-omega)*C,
        # not bare C — report what was really compared.
        raise ValueError(
            f"No valid period: need lower bound max(a={ckpt.a}, C={ckpt.C})"
            f"={lo} < 2*mu*b={hi}; platform MTBF mu={ckpt.mu} too small for "
            f"these checkpoint costs.")
    span = hi - lo
    return lo + 1e-9 * span + 1e-12, hi - 1e-9 * span


# --------------------------------------------------------------------------
# AlgoT — time-optimal period
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PeriodResult:
    """A solved period plus provenance: whether the closed form was clamped
    into the valid bracket (a boundary answer, not a stationary point) and
    which method produced it."""

    T: float
    clamped: bool = False
    method: str = "closed_form"      # "closed_form" | "numeric"


def t_opt_time_ex(ckpt: CheckpointParams) -> PeriodResult:
    """AlgoT with provenance (see :class:`PeriodResult`)."""
    val = 2.0 * ckpt.a * ckpt.b * ckpt.mu
    if val <= 0:
        # omega == 1 (a == 0) or mu too small: the closed form degenerates.
        # Fall back to numeric optimization on the exact objective.
        return PeriodResult(T=t_opt_time_numeric(ckpt), method="numeric")
    t = math.sqrt(val)
    lo, hi = _bracket(ckpt)
    t_clamped = float(min(max(t, lo), hi))
    return PeriodResult(T=t_clamped, clamped=t_clamped != t)


def t_opt_time(ckpt: CheckpointParams) -> float:
    """Paper Eq. (1): T_opt = sqrt(2 (1-omega) C (mu - (D + R + omega C))).

    Logs a warning when the closed form lands outside the valid bracket and
    is clamped to its edge (the answer is then a boundary optimum, not the
    closed form); use :func:`t_opt_time_ex` to get that flag programmatically.
    """
    res = t_opt_time_ex(ckpt)
    if res.clamped:
        logger.warning(
            "t_opt_time: closed form sqrt(2*a*b*mu) fell outside the valid "
            "period bracket and was clamped to %g (ckpt=%r); treat as a "
            "boundary answer", res.T, ckpt)
    return res.T


def t_opt_time_numeric(ckpt: CheckpointParams, T_base: float = 1.0) -> float:
    """Golden-section argmin of the exact T_final (validation path)."""
    lo, hi = _bracket(ckpt)
    return golden_section(lambda t: float(model.time_final(t, ckpt, T_base)),
                          lo, hi)


# --------------------------------------------------------------------------
# AlgoE — energy-optimal period
# --------------------------------------------------------------------------

def energy_quadratic_coefficients(
        ckpt: CheckpointParams, power: PowerParams,
) -> Tuple[float, float, float]:
    """Coefficients (c2, c1, c0) of the exact quadratic Q(T) = K(T) * E'(T).

    Q is an exact degree-2 polynomial (the paper's §3.2 cancellation); we
    recover it by interpolation at 3 points of the *analytic* product and
    verify the claim at a 4th point.
    """
    lo, hi = _bracket(ckpt)
    # Interpolation nodes well inside the valid range.
    ts = np.array([lo + 0.2 * (hi - lo), lo + 0.45 * (hi - lo),
                   lo + 0.7 * (hi - lo)])
    qs = model.K_dE_dT(ts, ckpt, power)
    # Solve the 3x3 Vandermonde system exactly.
    V = np.vander(ts, 3)            # columns: t^2, t, 1
    c2, c1, c0 = np.linalg.solve(V, qs)

    # Verify "quadratic-ness" at an independent 4th point.
    t4 = lo + 0.9 * (hi - lo)
    q4 = float(model.K_dE_dT(t4, ckpt, power))
    q4_poly = c2 * t4**2 + c1 * t4 + c0
    scale = max(abs(q4), abs(q4_poly), abs(c0), 1e-300)
    if not abs(q4 - q4_poly) <= 1e-6 * scale:
        raise AssertionError(
            f"K*E' deviates from a quadratic: {q4} vs {q4_poly} "
            f"(paper §3.2 cancellation violated — formula bug?)")
    return float(c2), float(c1), float(c0)


def derived_coefficients(
        ckpt: CheckpointParams, power: PowerParams,
) -> Tuple[float, float, float]:
    """Corrected closed-form quadratic coefficients (this reproduction).

    With P = alpha*omega*C + beta*R + gamma*D and Q = (beta - alpha(1-omega))C^2:

        c2 = 1/(2mu) + P/(2mu^2) + alpha*b/(2mu) + (alpha*a - beta*C)/(4mu^2)
        c1 = (beta*C - alpha*a) b / mu + Q/(2mu^2)
        c0 = -a b (P + mu)/mu - beta*C*b^2 - Q (b/(2mu) + a/(4mu^2))

    The paper's printed display omits the alpha factors on the b/(2mu) and
    a/(4mu^2) terms of c2 and on the a*b/mu term of c1 — correct only when
    alpha = 1 (its rho=5.5 scenario), wrong for rho=7 (alpha=2).  Verified
    against exact interpolation of K(T)E'(T) and JAX autodiff in tests.
    """
    C, mu = ckpt.C, ckpt.mu
    a, b, omega = ckpt.a, ckpt.b, ckpt.omega
    al, be, ga = power.alpha, power.beta, power.gamma
    P = al * omega * C + be * ckpt.R + ga * ckpt.D
    Q = (be - al * (1.0 - omega)) * C**2
    c2 = (1 / (2 * mu) + P / (2 * mu**2) + al * b / (2 * mu)
          + (al * a - be * C) / (4 * mu**2))
    c1 = (be * C - al * a) * b / mu + Q / (2 * mu**2)
    c0 = (-a * b * (P + mu) / mu - be * C * b**2
          - Q * (b / (2 * mu) + a / (4 * mu**2)))
    return float(c2), float(c1), float(c0)


def paper_printed_coefficients(
        ckpt: CheckpointParams, power: PowerParams,
) -> Tuple[float, float, float]:
    """The paper's FINAL displayed quadratic coefficients (verbatim).

    Kept for the erratum comparison in benchmarks/tests — the printed constant
    term disagrees with the exact interpolated quadratic (see DESIGN.md).
    """
    C, R, D, mu = ckpt.C, ckpt.R, ckpt.D, ckpt.mu
    a, b, omega = ckpt.a, ckpt.b, ckpt.omega
    al, be, ga = power.alpha, power.beta, power.gamma
    c2 = ((al * omega * C + be * R + ga * D) / (2 * mu**2)
          + b / (2 * mu) + (a - be * C) / (4 * mu**2) + 1 / (2 * mu))
    c1 = ((be * C - a) * b / mu
          - 2 * (al * (1 - omega) - be) * C**2 / (4 * mu**2))
    c0 = (-a * b * (al * omega * C + be * R + ga * D + mu) / mu
          - be * C * b**2
          + (b / (2 * mu) + a / (4 * mu**2)) * (al * (1 - omega) - be) * C**2)
    return float(c2), float(c1), float(c0)


def _pick_energy_root(c2: float, c1: float, c0: float, lo: float, hi: float,
                      energy: Callable[[float], float],
                      numeric: Callable[[], float]) -> float:
    """Shared AlgoE root selection on a quadratic Q = K*E' (single- and
    multilevel paths).

    Falls back to the numeric argmin when the quadratic has no root inside
    the valid range (e.g. the minimum sits on the bracket boundary), when
    the in-bracket root is a *maximum* of E (E'' < 0 there — E' = Q/K with
    K > 0, so sign(E'') at a root equals sign(Q')), or when the numeric
    argmin finds strictly lower energy than the chosen root.
    """
    roots = np.roots([c2, c1, c0]) if abs(c2) > 0 else np.array(
        [-c0 / c1] if abs(c1) > 0 else [])
    cands = [float(r.real) for r in np.atleast_1d(roots)
             if abs(r.imag) < 1e-9 * max(1.0, abs(r.real))
             and lo < r.real < hi]
    if not cands:
        return numeric()
    # Pick the root where E is smallest (E' sign change - to +).
    es = [energy(t) for t in cands]
    t_best = cands[int(np.argmin(es))]
    if len(cands) == 1 and 2.0 * c2 * t_best + c1 > 0.0:
        # Unique in-bracket root satisfying the minimum condition (E' = Q/K
        # with K > 0, so sign(E'') at the root equals sign(Q')): E' crosses
        # - to + exactly once, this is the interior minimum.
        return t_best
    # Otherwise (maximum-branch root, or several roots where a boundary
    # minimum may win) cross-check against the numeric argmin and prefer it
    # on disagreement.
    t_num = numeric()
    e_num = energy(t_num)
    if 2.0 * c2 * t_best + c1 <= 0.0 or e_num < min(es) * (1.0 - 1e-12):
        return t_num
    return t_best


def t_opt_energy(ckpt: CheckpointParams, power: PowerParams) -> float:
    """AlgoE: the positive root of the exact quadratic K(T) E'(T) = 0,
    guarded by ``_pick_energy_root`` (numeric fallback semantics there)."""
    lo, hi = _bracket(ckpt)
    try:
        c2, c1, c0 = energy_quadratic_coefficients(ckpt, power)
    except AssertionError:
        return t_opt_energy_numeric(ckpt, power)
    return _pick_energy_root(
        c2, c1, c0, lo, hi,
        energy=lambda t: float(model.energy_final(t, ckpt, power)),
        numeric=lambda: t_opt_energy_numeric(ckpt, power))


def t_opt_energy_numeric(ckpt: CheckpointParams, power: PowerParams,
                         T_base: float = 1.0) -> float:
    """Golden-section argmin of the exact E_final (validation path)."""
    lo, hi = _bracket(ckpt)
    return golden_section(
        lambda t: float(model.energy_final(t, ckpt, power, T_base)), lo, hi)


# --------------------------------------------------------------------------
# Multilevel (buddy + PFS) joint (T, m) solvers
# --------------------------------------------------------------------------

DEFAULT_M_MAX = 12


def _ml_bracket(ck: MultilevelCheckpointParams,
                m: int) -> Optional[Tuple[float, float]]:
    """Shrunk valid (lo, hi) for period T at a given m; None if degenerate."""
    lo, hi = ck.valid_period_range(m)
    if hi <= lo * (1.0 + 1e-9):
        return None
    span = hi - lo
    return lo + 1e-9 * span + 1e-12, hi - 1e-9 * span


def t_opt_time_multilevel(ck: MultilevelCheckpointParams,
                          m_max: int = DEFAULT_M_MAX) -> Tuple[float, int]:
    """Jointly time-optimal (T, m): per-m closed form, argmin over m.

    T_final(T, m) keeps the paper's rational form with (a_m, b_m, mu_m), so
    Eq. (1) survives per m: T*(m) = sqrt(2 a_m b_m mu_m).  The async-flush
    extension (per-level ``omega1``/``omega2``, hazard-during-flush) only
    changes the *constants* a_m and b_m, never the rational shape, so the
    same closed form prices asynchronous deep writes exactly — only
    non-exponential hazards need the MC-surrogate solvers below.
    """
    best = None
    for m in range(1, m_max + 1):
        br = _ml_bracket(ck, m)
        if br is None:
            continue
        lo, hi = br
        val = 2.0 * ck.a(m) * ck.b(m) * ck.mu_eff(m)
        if val > 0:
            t = float(min(max(math.sqrt(val), lo), hi))
        else:  # omega == 1 degenerates the closed form: numeric fallback
            t = golden_section(
                lambda x: float(model.ml_time_final(x, m, ck)), lo, hi)
        tf = float(model.ml_time_final(t, m, ck))
        if best is None or tf < best[0]:
            best = (tf, t, m)
    if best is None:
        raise ValueError(
            f"No valid (T, m): deep checkpoint C2={ck.C2} too large for "
            f"platform MTBF mu={ck.mu} at every m <= {m_max}.")
    return best[1], best[2]


def ml_energy_quadratic_coefficients(
        ck: MultilevelCheckpointParams, power: MultilevelPowerParams,
        m: int) -> Tuple[float, float, float]:
    """Coefficients of the exact quadratic Q_m(T) = K_m(T) * E'(T), recovered
    by 3-point interpolation of the analytic product + 4th-point check
    (mirrors ``energy_quadratic_coefficients``)."""
    br = _ml_bracket(ck, m)
    if br is None:
        raise ValueError(f"no valid period at m={m}")
    lo, hi = br
    ts = np.array([lo + 0.2 * (hi - lo), lo + 0.45 * (hi - lo),
                   lo + 0.7 * (hi - lo)])
    qs = model.ml_K_dE_dT(ts, m, ck, power)
    V = np.vander(ts, 3)
    c2, c1, c0 = np.linalg.solve(V, qs)

    t4 = lo + 0.9 * (hi - lo)
    q4 = float(model.ml_K_dE_dT(t4, m, ck, power))
    q4_poly = c2 * t4**2 + c1 * t4 + c0
    scale = max(abs(q4), abs(q4_poly), abs(c0), 1e-300)
    if not abs(q4 - q4_poly) <= 1e-6 * scale:
        raise AssertionError(
            f"K_m*E' deviates from a quadratic at m={m}: {q4} vs {q4_poly} "
            f"(multilevel §3.2 cancellation violated — formula bug?)")
    return float(c2), float(c1), float(c0)


def _t_opt_energy_ml_at(ck: MultilevelCheckpointParams,
                        power: MultilevelPowerParams, m: int) -> float:
    """Energy-optimal T at fixed m (quadratic root + shared guard)."""
    lo, hi = _ml_bracket(ck, m)

    def numeric() -> float:
        return golden_section(
            lambda t: float(model.ml_energy_final(t, m, ck, power)), lo, hi)

    try:
        c2, c1, c0 = ml_energy_quadratic_coefficients(ck, power, m)
    except AssertionError:
        return numeric()
    return _pick_energy_root(
        c2, c1, c0, lo, hi,
        energy=lambda t: float(model.ml_energy_final(t, m, ck, power)),
        numeric=numeric)


def t_opt_energy_multilevel(ck: MultilevelCheckpointParams,
                            power: MultilevelPowerParams,
                            m_max: int = DEFAULT_M_MAX) -> Tuple[float, int]:
    """Jointly energy-optimal (T, m): per-m quadratic root, argmin over m."""
    best = None
    for m in range(1, m_max + 1):
        if _ml_bracket(ck, m) is None:
            continue
        t = _t_opt_energy_ml_at(ck, power, m)
        e = float(model.ml_energy_final(t, m, ck, power))
        if best is None or e < best[0]:
            best = (e, t, m)
    if best is None:
        raise ValueError(
            f"No valid (T, m): deep checkpoint C2={ck.C2} too large for "
            f"platform MTBF mu={ck.mu} at every m <= {m_max}.")
    return best[1], best[2]


# --------------------------------------------------------------------------
# MC-surrogate solvers for non-exponential failure processes
# --------------------------------------------------------------------------
#
# For Weibull / log-normal / trace failures no closed form exists, so the
# optimal period is found numerically on a Monte-Carlo *surrogate*: one set
# of pre-sampled failure schedules (common random numbers) is reused for
# every candidate T, which makes the objective a deterministic, nearly
# smooth function of T — differences between candidate periods are then
# estimated on identical failure realizations, cancelling most of the MC
# variance.  A coarse grid scan localizes the argmin basin; golden-section
# on the surrogate polishes it.


class MCSurrogate:
    """CRN Monte-Carlo objective E[T_final] / E[E_final] as a function of T.

    Built once per (ckpt, power, process); every evaluation replays the
    same pre-sampled failure schedules through the batched engine
    (``repro.sim.engine.simulate_trajectories``), so calls are deterministic
    and comparable across T (common random numbers).
    """

    def __init__(self, ckpt: CheckpointParams, power: PowerParams,
                 process: Optional[FailureProcess] = None,
                 T_base: Optional[float] = None, n_trials: int = 160,
                 seed: int = 0, engine_kind: Optional[str] = None,
                 dispatch=None):
        from ..sim import engine as _engine
        from ..sim.scenarios import ParamGrid
        self.ckpt, self.power = ckpt, power
        self.process = as_process(process)
        engine_kind = _engine.resolve_engine_kind(engine_kind)
        self.engine_kind = engine_kind
        #: sim.dispatch.DispatchConfig routing every engine call (None =
        #: environment defaults); with several local devices the candidate
        #: axis of each evaluation is sharded across them.
        self.dispatch = dispatch
        lo, hi = _bracket(ckpt)
        t_ref = t_opt_time_ex(ckpt).T
        # Search range: generous decades around the exponential optimum, but
        # clear of the bracket edges where E[T_final] diverges and the event
        # budget with it.
        self.lo = max(lo * 1.02, t_ref / 10.0)
        self.hi = min(hi * 0.9, t_ref * 10.0)
        if T_base is None:
            # Long enough to amortize many periods and failures per
            # trajectory; short enough to keep the scan budget sane.
            T_base = max(30.0 * t_ref, 10.0 * ckpt.mu)
        self.T_base = float(T_base)
        self.n_trials = int(n_trials)

        self._grid1 = ParamGrid.from_params(ckpt, power).reshape((1,))
        probes = np.linspace(self.lo, self.hi, 9)
        cap = _engine.default_fail_capacity(probes, self._grid1,
                                            self.T_base,
                                            process=self.process)
        self._n_steps = (None if engine_kind in _engine._EVENT_LIKE else
                         _engine.default_step_budget(
                             probes, self._grid1, self.T_base,
                             process=self.process))
        # Host-sampled once (replayable numpy streams), then parked on
        # device once — every candidate evaluation reuses the resident
        # schedule through the candidate-axis vmap, with no per-call
        # host->device transfer and no (M, B, trials, cap) tiling.
        gaps = _engine.presample_gaps(self._grid1, self.n_trials, cap,
                                      seed=seed, process=self.process)
        with _engine.enable_x64():
            self._gaps = _engine.jnp.asarray(gaps,
                                             dtype=_engine.jnp.float64)
        self._engine = _engine
        self._first_evals: dict = {}   # initial argmin grid, shared by keys

    def __call__(self, Ts) -> dict:
        """Mean wall time / energy (+ standard errors) at each candidate T.

        All candidates share the pre-sampled schedules (CRN), evaluated in
        one jitted candidate-vmapped call.
        """
        Ts = np.atleast_1d(np.asarray(Ts, dtype=np.float64))
        tb = self._engine.simulate_candidates(
            Ts, self._grid1, self.T_base, gaps=self._gaps,
            n_steps=self._n_steps, engine_kind=self.engine_kind,
            dispatch=self.dispatch)
        if tb.truncated.any():
            raise RuntimeError("MC surrogate: scan budget exceeded — "
                               "candidate period too close to the bracket "
                               "edge for this failure process")
        if tb.gaps_exhausted.any():
            raise RuntimeError("MC surrogate: failure schedule exhausted — "
                               "increase the pre-sample capacity")
        wall = tb.wall_time[:, 0, :]
        energy = tb.energy[:, 0, :]
        n = wall.shape[-1]
        se = lambda a: a.std(axis=-1, ddof=1) / math.sqrt(n)
        return {"time": wall.mean(axis=-1), "energy": energy.mean(axis=-1),
                "time_se": se(wall), "energy_se": se(energy)}

    def argmin(self, key: str, rounds: int = 3, pts: int = 17) -> float:
        """Coarse-to-fine grid localization + golden-section polish of the
        surrogate argmin for ``key`` in {"time", "energy"}."""
        lo, hi = self.lo, self.hi
        xs = np.geomspace(lo, hi, pts)
        for rnd in range(rounds):
            if rnd == 0:
                # The first (geomspace) grid is identical for the "time"
                # and "energy" argmins — evaluate it once per surrogate.
                if pts not in self._first_evals:
                    self._first_evals[pts] = self(xs)
                ys = self._first_evals[pts][key]
            else:
                ys = self(xs)[key]
            i = int(np.argmin(ys))
            lo, hi = xs[max(i - 1, 0)], xs[min(i + 1, pts - 1)]
            xs = np.linspace(lo, hi, pts)
        return golden_section(lambda t: float(self([t])[key][0]), lo, hi,
                              tol=1e-6, max_iter=40)


def t_opt_time_mc(ckpt: CheckpointParams,
                  process: Optional[FailureProcess] = None,
                  power: Optional[PowerParams] = None,
                  T_base: Optional[float] = None, n_trials: int = 160,
                  seed: int = 0, engine_kind: Optional[str] = None,
                  dispatch=None) -> float:
    """Time-optimal period under an arbitrary failure process (MC surrogate).

    With the default exponential process this converges to AlgoT's closed
    form (within MC resolution) — the cross-check the tests pin.
    """
    power = power or PowerParams(P_static=1.0, P_cal=0.0, P_io=0.0)
    return MCSurrogate(ckpt, power, process, T_base, n_trials, seed,
                       engine_kind=engine_kind,
                       dispatch=dispatch).argmin("time")


def t_opt_energy_mc(ckpt: CheckpointParams, power: PowerParams,
                    process: Optional[FailureProcess] = None,
                    T_base: Optional[float] = None, n_trials: int = 160,
                    seed: int = 0, engine_kind: Optional[str] = None,
                    dispatch=None) -> float:
    """Energy-optimal period under an arbitrary failure process."""
    return MCSurrogate(ckpt, power, process, T_base, n_trials, seed,
                       engine_kind=engine_kind,
                       dispatch=dispatch).argmin("energy")


def mc_evaluate_periods(Ts: Sequence[float], ckpt: CheckpointParams,
                        power: PowerParams,
                        process: Optional[FailureProcess] = None,
                        T_base: Optional[float] = None, n_trials: int = 160,
                        seed: int = 0, engine_kind: Optional[str] = None,
                        dispatch=None) -> dict:
    """Mean wall time / energy at each candidate period under ``process``
    (one CRN schedule set shared by all candidates — fair comparisons)."""
    return MCSurrogate(ckpt, power, process, T_base, n_trials, seed,
                       engine_kind=engine_kind, dispatch=dispatch)(Ts)


# --------------------------------------------------------------------------
# Literature baselines
# --------------------------------------------------------------------------

def t_young(ckpt: CheckpointParams) -> float:
    """Young 1974: T = sqrt(2 C mu) + C (blocking model)."""
    return math.sqrt(2.0 * ckpt.C * ckpt.mu) + ckpt.C


def t_daly(ckpt: CheckpointParams) -> float:
    """Daly 2004 (first-order form): T = sqrt(2 C (mu + D + R)) + C."""
    return math.sqrt(2.0 * ckpt.C * (ckpt.mu + ckpt.D + ckpt.R)) + ckpt.C


def _msk_energy(T, ckpt: CheckpointParams, power: PowerParams,
                T_base: float = 1.0):
    """MSK energy objective, reconstructed per the paper's side note.

    omega is forced to 0 (MSK analyse blocking checkpoints only); relative to
    our model the per-failure re-exec work is (T - 2C)/2 and the per-failure
    I/O is a FULL checkpoint C (instead of C^2/(2T)).
    """
    ck0 = CheckpointParams(C=ckpt.C, R=ckpt.R, D=ckpt.D, mu=ckpt.mu, omega=0.0)
    T = np.asarray(T, dtype=np.float64)
    Tf = model.time_final(T, ck0, T_base)
    nf = Tf / ck0.mu
    T_cal = T_base + nf * (T - 2.0 * ck0.C) / 2.0
    T_io = T_base * ck0.C / (T - ck0.C) + nf * (ck0.R + ck0.C)
    T_down = nf * ck0.D
    return (T_cal * power.P_cal + T_io * power.P_io
            + T_down * power.P_down + Tf * power.P_static)


def t_msk_energy(ckpt: CheckpointParams, power: PowerParams) -> float:
    """Energy-optimal period under the MSK approximation (numeric argmin)."""
    ck0 = CheckpointParams(C=ckpt.C, R=ckpt.R, D=ckpt.D, mu=ckpt.mu, omega=0.0)
    lo, hi = _bracket(ck0)
    lo = max(lo, 2.0 * ck0.C + 1e-12)  # MSK re-exec term needs T > 2C
    return golden_section(lambda t: float(_msk_energy(t, ck0, power)), lo, hi)


STRATEGIES = ("algo_t", "algo_e", "young", "daly", "msk_energy")


def period_for(strategy: str, ckpt: CheckpointParams,
               power: PowerParams | None = None) -> float:
    """Uniform entry point used by the runtime policy and benchmarks."""
    if strategy == "algo_t":
        return t_opt_time(ckpt)
    if strategy == "algo_e":
        assert power is not None, "algo_e needs PowerParams"
        return t_opt_energy(ckpt, power)
    if strategy == "young":
        return t_young(ckpt)
    if strategy == "daly":
        return t_daly(ckpt)
    if strategy == "msk_energy":
        assert power is not None, "msk_energy needs PowerParams"
        return t_msk_energy(ckpt, power)
    raise ValueError(f"unknown strategy {strategy!r}; one of {STRATEGIES}")
