"""Exact expectation formulas of the paper (§3.1 time, §3.2 energy).

The analytical core is evaluated in numpy float64 — these are scalar closed
forms where precision matters more than jit.  ``K_dE_dT_autodiff`` provides an
independent JAX-autodiff cross-check (used by tests) under the local
``jax.experimental.enable_x64`` context so global JAX dtype state is untouched
(the neural-net stack wants f32/bf16 defaults).

All functions accept scalars or broadcastable numpy arrays for ``T``.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from .params import (CheckpointParams, MultilevelCheckpointParams,
                     MultilevelPowerParams, PowerParams)


# --------------------------------------------------------------------------
# §3.1 — execution time
# --------------------------------------------------------------------------

def time_fault_free(T, ckpt: CheckpointParams, T_base: float = 1.0):
    """T_ff = T_base * T / (T - (1-omega) C)."""
    T = np.asarray(T, dtype=np.float64)
    return T_base * T / (T - ckpt.a)


def time_lost_per_failure(T, ckpt: CheckpointParams):
    """Expected time lost per failure = D + R + omega*C + T/2 (paper §3.1)."""
    T = np.asarray(T, dtype=np.float64)
    return ckpt.D + ckpt.R + ckpt.omega * ckpt.C + T / 2.0


def time_final(T, ckpt: CheckpointParams, T_base: float = 1.0):
    """Expected total execution time (paper §3.1):

        T_final = T_base * T / ((T - a)(b - T/(2 mu)))

    Valid on a < T < 2*mu*b; outside, the model diverges (returned as-is,
    possibly negative — callers should restrict to the valid range).
    """
    T = np.asarray(T, dtype=np.float64)
    a, b, mu = ckpt.a, ckpt.b, ckpt.mu
    return T_base * T / ((T - a) * (b - T / (2.0 * mu)))


def time_final_prime(T, ckpt: CheckpointParams, T_base: float = 1.0):
    """dT_final/dT = T_base (-ab + T^2/2mu) / ((T-a)^2 (b - T/2mu)^2)."""
    T = np.asarray(T, dtype=np.float64)
    a, b, mu = ckpt.a, ckpt.b, ckpt.mu
    num = -a * b + T**2 / (2.0 * mu)
    den = (T - a) ** 2 * (b - T / (2.0 * mu)) ** 2
    return T_base * num / den


def expected_failures(T, ckpt: CheckpointParams, T_base: float = 1.0):
    """E[#failures] = T_final / mu."""
    return time_final(T, ckpt, T_base) / ckpt.mu


# --------------------------------------------------------------------------
# §3.2 — energy
# --------------------------------------------------------------------------

class PhaseTimes(NamedTuple):
    """Expected cumulative phase durations over the whole execution."""

    T_final: np.ndarray   # wall clock
    T_cal: np.ndarray     # CPU-busy time (power overhead P_cal)
    T_io: np.ndarray      # I/O-busy time (power overhead P_io)
    T_down: np.ndarray    # downtime (power overhead P_down)


def _re_exec(T, ckpt: CheckpointParams):
    """Expected work re-executed per failure (paper §3.2)."""
    C, omega = ckpt.C, ckpt.omega
    return omega * C + (T**2 - C**2) / (2.0 * T) + omega * C**2 / (2.0 * T)


def _io_per_failure(T, ckpt: CheckpointParams):
    """Expected extra I/O time per failure: R + C^2/(2T)."""
    return ckpt.R + ckpt.C**2 / (2.0 * T)


def phase_times(T, ckpt: CheckpointParams, T_base: float = 1.0) -> PhaseTimes:
    """All phase expectations of §3.2.

    Note (paper): T_final != T_cal + T_io + T_down unless omega == 0, because
    CPU and I/O overlap during non-blocking checkpoints.
    """
    T = np.asarray(T, dtype=np.float64)
    C, R, D, mu, omega = ckpt.C, ckpt.R, ckpt.D, ckpt.mu, ckpt.omega

    Tf = time_final(T, ckpt, T_base)
    n_fail = Tf / mu

    T_cal = T_base + n_fail * _re_exec(T, ckpt)
    ckpt_io = T_base * C / (T - (1.0 - omega) * C)
    T_io = ckpt_io + n_fail * _io_per_failure(T, ckpt)
    T_down = n_fail * D

    return PhaseTimes(T_final=Tf, T_cal=T_cal, T_io=T_io, T_down=T_down)


def energy_final(T, ckpt: CheckpointParams, power: PowerParams,
                 T_base: float = 1.0):
    """E_final = T_cal P_cal + T_io P_io + T_down P_down + T_final P_static."""
    ph = phase_times(T, ckpt, T_base)
    return (ph.T_cal * power.P_cal
            + ph.T_io * power.P_io
            + ph.T_down * power.P_down
            + ph.T_final * power.P_static)


def energy_breakdown(T, ckpt: CheckpointParams, power: PowerParams,
                     T_base: float = 1.0) -> dict:
    """Per-component energy dict (for reports and tests)."""
    ph = phase_times(T, ckpt, T_base)
    comp = {
        "E_cal": float(ph.T_cal * power.P_cal),
        "E_io": float(ph.T_io * power.P_io),
        "E_down": float(ph.T_down * power.P_down),
        "E_static": float(ph.T_final * power.P_static),
    }
    comp["E_final"] = sum(comp.values())
    comp["T_final"] = float(ph.T_final)
    return comp


def energy_final_prime(T, ckpt: CheckpointParams, power: PowerParams,
                       T_base: float = 1.0):
    """Analytic dE_final/dT.

    With W(T) = P_cal*re(T) + P_io*io(T) + P_down*D:

        E' = P_static T_final' - P_io T_base C / (T-a)^2
             + (T_final'/mu) W(T) + (T_final/mu) W'(T)

    re'(T) = 1/2 + (1-omega) C^2 / (2 T^2);  io'(T) = -C^2/(2 T^2).
    """
    T = np.asarray(T, dtype=np.float64)
    C, mu, omega = ckpt.C, ckpt.mu, ckpt.omega
    a = ckpt.a

    Tf = time_final(T, ckpt, T_base)
    Tfp = time_final_prime(T, ckpt, T_base)

    W = (power.P_cal * _re_exec(T, ckpt)
         + power.P_io * _io_per_failure(T, ckpt)
         + power.P_down * ckpt.D)
    Wp = (power.P_cal * (0.5 + (1.0 - omega) * C**2 / (2.0 * T**2))
          - power.P_io * C**2 / (2.0 * T**2))

    return (power.P_static * Tfp
            - power.P_io * T_base * C / (T - a) ** 2
            + Tfp / mu * W
            + Tf / mu * Wp)


# --------------------------------------------------------------------------
# K(T) * dE/dT — the paper's quadratic
# --------------------------------------------------------------------------

def K_factor(T, ckpt: CheckpointParams, power: PowerParams,
             T_base: float = 1.0):
    """K = (T-a)^2 (b - T/2mu)^2 / (P_static * T_base)  (paper §3.2)."""
    T = np.asarray(T, dtype=np.float64)
    a, b, mu = ckpt.a, ckpt.b, ckpt.mu
    return (T - a) ** 2 * (b - T / (2.0 * mu)) ** 2 / (power.P_static * T_base)


def K_dE_dT(T, ckpt: CheckpointParams, power: PowerParams,
            T_base: float = 1.0):
    """K(T) * E'(T) — an exact quadratic polynomial in T (paper §3.2).

    The paper's printed coefficient displays are inconsistent (see DESIGN.md
    erratum); downstream code recovers the quadratic by interpolating THIS
    exact product instead of trusting the printed algebra.
    """
    return K_factor(T, ckpt, power, T_base) * energy_final_prime(
        T, ckpt, power, T_base)


# --------------------------------------------------------------------------
# Multilevel (buddy + PFS) model — first-order extension of §3.1 / §3.2
# --------------------------------------------------------------------------
#
# Period pattern: every period of length T ends with a checkpoint; periods
# 1..m-1 of a superperiod write the cheap buddy level (C1), period m writes
# the deep level (C2) and refreshes both recovery points.  Failures lose the
# buddy copy with probability q; a soft failure recovers the last committed
# checkpoint (R1, lost work ~ T/2), a hard failure the last deep one
# (R2, lost work ~ m*T/2 plus the re-executed intermediate buddy writes).
#
# With a_m = E[(1-w_k) C_k], b_m = 1 - E[fixed loss]/mu and
# mu_m = mu/(1+q(m-1)), the expected makespan keeps the paper's form
#
#     T_final(T, m) = T_base * T / ((T - a_m)(b_m - T/(2 mu_m)))
#
# so AlgoT's closed form survives per m, and K(T) * dE/dT remains an exact
# quadratic in T (verified by interpolation, exactly like the single-level
# path).  All formulas reduce bit-for-bit to the single-level model for
# degenerate levels (C1=C2, R1=R2, D1=D2) at m=1 — see params docstring.
#
# Async flush (per-level overlap, VELOC semantics): omega1/omega2 split the
# shared overlap factor per level.  The deep write's flush-in-flight
# interval — wall length C2 at compute rate w2, commit at the END of the
# interval — only moves constants: a_m mixes the per-level critical-path
# shares, the hazard-during-flush loss E[w_k C_k] replaces omega*C_mean in
# b_m, and the W coefficients pick up the per-level overlapped quadratic
# S2_omega.  The T-dependence (const + T + 1/T inside W) is unchanged, so
# the rational normal form — and with it both closed-form solvers —
# survives the async term for exponential failures.  It does NOT survive
# non-exponential hazards; those route through the CRN MC-surrogate
# solvers (``optimal.t_opt_time_mc`` / ``t_opt_energy_mc``), same as the
# shared-omega model.  With omega1 == omega2 every expression below
# evaluates the exact shared-omega formula (bit-for-bit).


class MultilevelPhaseTimes(NamedTuple):
    """Expected cumulative phase durations, split per I/O level."""

    T_final: np.ndarray
    T_cal: np.ndarray
    T_io1: np.ndarray    # buddy-level I/O (writes + soft recoveries)
    T_io2: np.ndarray    # deep-level I/O (writes + hard recoveries)
    T_down: np.ndarray


def ml_time_final(T, m: int, ck: MultilevelCheckpointParams,
                  T_base: float = 1.0):
    """Expected makespan of the two-level scheme at period T, PFS every m."""
    T = np.asarray(T, dtype=np.float64)
    a, b, mu_m = ck.a(m), ck.b(m), ck.mu_eff(m)
    return T_base * T / ((T - a) * (b - T / (2.0 * mu_m)))


def ml_phase_times(T, m: int, ck: MultilevelCheckpointParams,
                   T_base: float = 1.0) -> MultilevelPhaseTimes:
    """Per-phase expectations of the two-level scheme (§3.2 analogue)."""
    T = np.asarray(T, dtype=np.float64)
    m = int(m)
    C1, R1, D1 = ck.C1, ck.R1, ck.D1
    C2, R2, D2 = ck.C2, ck.R2, ck.D2
    mu, q = ck.mu, ck.q
    w1, w2 = ck.w1, ck.w2
    a = ck.a(m)

    Tf = ml_time_final(T, m, ck, T_base)
    nf = Tf / mu

    # Re-executed work per failure.  E[C_k^2] over period types:
    S2 = ck.S2(m)
    # mean-over-types of the in-period lost work (paper's E_w generalized);
    # the overlapped share S2_omega is the hazard-during-flush quadratic:
    Ew = (T**2 - S2) / (2.0 * T) + ck.S2_omega(m) / (2.0 * T)
    w_soft = ck.C_omega_mean(m) + Ew
    w_hard = w2 * C2 + (m - 1) * (T - (1.0 - w1) * C1) / 2.0 + Ew
    T_cal = T_base + nf * (w_soft + q * (w_hard - w_soft))

    # Fault-free checkpoint I/O, split per level.
    ck_io1 = T_base * ((m - 1) * C1 / m) / (T - a)
    ck_io2 = T_base * (C2 / m) / (T - a)
    # Per-failure I/O: wasted in-flight write + recovery read + (hard only)
    # the (m-1)/2 re-executed buddy writes of the rolled-back periods.
    io1_pf = ((m - 1) / m) * C1**2 / (2.0 * T) + (1.0 - q) * R1 \
        + q * (m - 1) * C1 / 2.0
    io2_pf = C2**2 / (2.0 * m * T) + q * R2
    T_io1 = ck_io1 + nf * io1_pf
    T_io2 = ck_io2 + nf * io2_pf

    T_down = nf * (D1 + q * (D2 - D1))
    return MultilevelPhaseTimes(T_final=Tf, T_cal=T_cal, T_io1=T_io1,
                                T_io2=T_io2, T_down=T_down)


def ml_energy_final(T, m: int, ck: MultilevelCheckpointParams,
                    power: MultilevelPowerParams, T_base: float = 1.0):
    """E_final with per-level I/O powers."""
    ph = ml_phase_times(T, m, ck, T_base)
    return (ph.T_cal * power.P_cal
            + ph.T_io1 * power.P_io1
            + ph.T_io2 * power.P_io2
            + ph.T_down * power.P_down
            + ph.T_final * power.P_static)


def ml_energy_breakdown(T, m: int, ck: MultilevelCheckpointParams,
                        power: MultilevelPowerParams,
                        T_base: float = 1.0) -> dict:
    """Per-component energy dict (reports and tests)."""
    ph = ml_phase_times(T, m, ck, T_base)
    comp = {
        "E_cal": float(ph.T_cal * power.P_cal),
        "E_io1": float(ph.T_io1 * power.P_io1),
        "E_io2": float(ph.T_io2 * power.P_io2),
        "E_down": float(ph.T_down * power.P_down),
        "E_static": float(ph.T_final * power.P_static),
    }
    comp["E_final"] = sum(comp.values())
    comp["T_final"] = float(ph.T_final)
    return comp


def _ml_W_coefficients(m: int, ck: MultilevelCheckpointParams,
                       power: MultilevelPowerParams):
    """(W0, W1, Wm, J) with E = Pc*Tb + Ps*Tf + (Tf/mu)(W0 + W1*T + Wm/T)
    + J*Tb/(T - a_m) — the rational normal form of ``ml_energy_final``."""
    C1, R1, D1 = ck.C1, ck.R1, ck.D1
    C2, R2, D2 = ck.C2, ck.R2, ck.D2
    q, w1, w2 = ck.q, ck.w1, ck.w2
    Cw = ck.C_omega_mean(m)
    Pc, P1, P2, Pd = power.P_cal, power.P_io1, power.P_io2, power.P_down
    S2 = ck.S2(m)

    W0 = (Pc * (Cw + q * (w2 * C2 - Cw
                          - (m - 1) * (1.0 - w1) * C1 / 2.0))
          + P1 * ((1.0 - q) * R1 + q * (m - 1) * C1 / 2.0)
          + P2 * q * R2
          + Pd * (D1 + q * (D2 - D1)))
    W1 = Pc * (1.0 + q * (m - 1)) / 2.0
    Wm = (Pc * (ck.S2_omega(m) - S2) / 2.0
          + P1 * (m - 1) * C1**2 / (2.0 * m)
          + P2 * C2**2 / (2.0 * m))
    J = P1 * (m - 1) * C1 / m + P2 * C2 / m
    return W0, W1, Wm, J


def ml_energy_final_prime(T, m: int, ck: MultilevelCheckpointParams,
                          power: MultilevelPowerParams, T_base: float = 1.0):
    """Analytic dE_final/dT of the two-level model (W normal form)."""
    T = np.asarray(T, dtype=np.float64)
    a, b, mu_m = ck.a(m), ck.b(m), ck.mu_eff(m)
    W0, W1, Wm, J = _ml_W_coefficients(m, ck, power)

    Tf = ml_time_final(T, m, ck, T_base)
    Tfp = T_base * (-a * b + T**2 / (2.0 * mu_m)) \
        / ((T - a) ** 2 * (b - T / (2.0 * mu_m)) ** 2)
    W = W0 + W1 * T + Wm / T
    Wp = W1 - Wm / T**2
    return (power.P_static * Tfp
            + Tfp / ck.mu * W
            + Tf / ck.mu * Wp
            - J * T_base / (T - a) ** 2)


def ml_K_factor(T, m: int, ck: MultilevelCheckpointParams,
                power: MultilevelPowerParams, T_base: float = 1.0):
    """K_m = (T-a_m)^2 (b_m - T/2mu_m)^2 / (P_static * T_base)."""
    T = np.asarray(T, dtype=np.float64)
    a, b, mu_m = ck.a(m), ck.b(m), ck.mu_eff(m)
    return (T - a) ** 2 * (b - T / (2.0 * mu_m)) ** 2 \
        / (power.P_static * T_base)


def ml_K_dE_dT(T, m: int, ck: MultilevelCheckpointParams,
               power: MultilevelPowerParams, T_base: float = 1.0):
    """K_m(T) * E'(T) — an exact quadratic in T (same cancellation as the
    single-level §3.2 product; recovered by interpolation downstream)."""
    return ml_K_factor(T, m, ck, power, T_base) * ml_energy_final_prime(
        T, m, ck, power, T_base)


def K_dE_dT_autodiff(T, ckpt: CheckpointParams, power: PowerParams,
                     T_base: float = 1.0):
    """Independent cross-check of ``K_dE_dT`` via JAX autodiff (float64 via
    the local enable_x64 context; global JAX dtype state untouched)."""
    import jax
    import jax.numpy as jnp
    try:  # newer jax re-exports the x64 context at top level
        from jax import enable_x64
    except ImportError:
        from jax.experimental import enable_x64

    C, R, D, mu, omega = ckpt.C, ckpt.R, ckpt.D, ckpt.mu, ckpt.omega
    a, b = ckpt.a, ckpt.b
    Pc, Pi, Pd, Ps = power.P_cal, power.P_io, power.P_down, power.P_static

    def e_final(t):
        tf = T_base * t / ((t - a) * (b - t / (2.0 * mu)))
        nf = tf / mu
        t_cal = T_base + nf * (omega * C + (t**2 - C**2) / (2 * t)
                               + omega * C**2 / (2 * t))
        t_io = (T_base * C / (t - (1 - omega) * C)
                + nf * (R + C**2 / (2 * t)))
        t_down = nf * D
        return t_cal * Pc + t_io * Pi + t_down * Pd + tf * Ps

    with enable_x64():
        tv = jnp.atleast_1d(jnp.asarray(T, dtype=jnp.float64))
        g = jax.vmap(jax.grad(e_final))(tv)
        k = (tv - a) ** 2 * (b - tv / (2 * mu)) ** 2 / (Ps * T_base)
        out = np.asarray(k * g, dtype=np.float64)
    return out.reshape(np.shape(T))
