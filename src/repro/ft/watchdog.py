"""Straggler watchdog: EWMA step-time tracking with sigma-threshold flags.

At 1000+ nodes, slow hosts (thermal throttling, failing NICs) stretch every
synchronous step.  The watchdog flags step-time excursions; the trainer's
mitigation hook can rebalance microbatches or evict the host (simulated —
the decision logic is what we exercise here)."""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional


@dataclasses.dataclass
class WatchdogConfig:
    ewma_alpha: float = 0.1
    sigma_threshold: float = 4.0    # flag if step > mean + k*std
    min_samples: int = 8
    consecutive_to_escalate: int = 3


class StepTimeWatchdog:
    def __init__(self, config: Optional[WatchdogConfig] = None,
                 on_straggler: Optional[Callable[[dict], None]] = None):
        # NOTE: built per instance — a dataclass default argument would be
        # one shared WatchdogConfig across watchdogs.
        self.cfg = config if config is not None else WatchdogConfig()
        self.on_straggler = on_straggler
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.n: int = 0
        self.consecutive: int = 0
        self.events: list = []

    def observe(self, step: int, duration_s: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        a = self.cfg.ewma_alpha
        if self.mean is None:
            self.mean, self.var = duration_s, 0.0
            self.n = 1
            return False
        flagged = False
        std = math.sqrt(max(self.var, 1e-18))
        if (self.n >= self.cfg.min_samples
                and duration_s > self.mean + self.cfg.sigma_threshold * std
                and duration_s > 1.5 * self.mean):
            flagged = True
            self.consecutive += 1
            event = {"step": step, "duration_s": duration_s,
                     "mean_s": self.mean, "std_s": std,
                     "escalate": (self.consecutive
                                  >= self.cfg.consecutive_to_escalate)}
            self.events.append(event)
            if self.on_straggler:
                self.on_straggler(event)
        else:
            self.consecutive = 0
            # only non-flagged samples update the baseline (else stragglers
            # poison the statistics)
            delta = duration_s - self.mean
            self.mean += a * delta
            self.var = (1 - a) * (self.var + a * delta * delta)
        self.n += 1
        return flagged
