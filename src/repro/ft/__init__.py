from .failures import FailureInjector, FailureModel
from .watchdog import StepTimeWatchdog, WatchdogConfig
from .elastic import ElasticPlan, plan_reshard, build_mesh, reshard_tree
from .trainer import FaultTolerantTrainer, TrainerConfig
from .tracker import (Tracker, NullTracker, MemoryTracker, StdoutTracker,
                      JsonlTracker, CompositeTracker)
from .run import RunSpec, execute as execute_run
