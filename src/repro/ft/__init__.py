from .failures import FailureInjector, FailureModel
from .watchdog import StepTimeWatchdog, WatchdogConfig
from .elastic import ElasticPlan, plan_reshard, build_mesh, reshard_tree
from .trainer import FaultTolerantTrainer, TrainerConfig
