"""Pluggable metrics trackers for the fault-tolerant trainer.

The Levanter-shaped seam: the trainer emits structured records through a
tiny :class:`Tracker` protocol instead of printing or hoarding them, so
runs can stream metrics to a jsonl file, stdout, an in-memory buffer, or
all three — without the trainer knowing which.

Record kinds emitted by the trainer (the ``kind`` field):

``step``        per training step: step index, loss, virtual step seconds;
``checkpoint``  per completed checkpoint: level (1 buddy / 2 deep), C_s;
``failure``     per injected failure: hard?, downtime, recovery level/secs,
                rollback target step;
``summary``     once at run end: wall/energy/policy/prediction report.

Every record carries ``t`` — the trainer's virtual clock (seconds).
"""
from __future__ import annotations

import json
from typing import Iterable, Optional, Protocol, runtime_checkable


@runtime_checkable
class Tracker(Protocol):
    """Anything with ``log(record: dict) -> None`` and ``close()``."""

    def log(self, record: dict) -> None: ...

    def close(self) -> None: ...


class NullTracker:
    """Discards everything (the trainer default)."""

    def log(self, record: dict) -> None:
        pass

    def close(self) -> None:
        pass


class MemoryTracker:
    """Keeps records in a list — the test/benchmark backend."""

    def __init__(self):
        self.records: list = []

    def log(self, record: dict) -> None:
        self.records.append(record)

    def close(self) -> None:
        pass

    def of_kind(self, kind: str) -> list:
        return [r for r in self.records if r.get("kind") == kind]


class StdoutTracker:
    """Human-readable one-liners; ``kinds`` filters what is printed."""

    def __init__(self, kinds: Optional[Iterable[str]] = None):
        self.kinds = None if kinds is None else set(kinds)

    def log(self, record: dict) -> None:
        kind = record.get("kind", "?")
        if self.kinds is not None and kind not in self.kinds:
            return
        body = " ".join(f"{k}={_fmt(v)}" for k, v in record.items()
                        if k != "kind")
        print(f"[{kind}] {body}")

    def close(self) -> None:
        pass


class JsonlTracker:
    """One JSON object per line; the machine-readable run log."""

    def __init__(self, path):
        self.path = path
        self._fh = open(path, "w")

    def log(self, record: dict) -> None:
        self._fh.write(json.dumps(record, default=_jsonable) + "\n")

    def close(self) -> None:
        self._fh.flush()
        self._fh.close()


class CompositeTracker:
    """Fan a record out to several backends."""

    def __init__(self, *trackers: Tracker):
        self.trackers = list(trackers)

    def log(self, record: dict) -> None:
        for t in self.trackers:
            t.log(record)

    def close(self) -> None:
        for t in self.trackers:
            t.close()


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return v


def _jsonable(v):
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)
