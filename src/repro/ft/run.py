"""Config-driven fault-tolerant runs: one spec, a built trainer, and the
model's predictions next to the measurements.

The Levanter-shaped entry point: a :class:`RunSpec` dataclass fully
describes a run (architecture, policy strategy, failure scenario, power
profile, scaled-time world), :func:`build` assembles the components, and
:func:`execute` runs it and attaches a ``predicted`` block — the paper's
``time_final`` / ``energy_final`` (``ml_*`` for two-level runs) evaluated
at the period the run actually executed — so every run is a
predicted-vs-measured experiment by construction.

Scaled-time methodology: when ``step_s`` is set, ALL durations are virtual
— steps, per-level checkpoint costs (C1/C2), recoveries (R1/R2) and
downtimes (D1/D2) — so the run inhabits one consistent virtual-time world
whose parameters equal the analytical scenario's exactly, and the failure
schedule is the only randomness.  ``benchmarks/validate_runtime.py`` and
``tests/test_runtime_validation.py`` build on this.
"""
from __future__ import annotations

import dataclasses
import tempfile
from typing import Optional

from ..core import model as core_model
from ..core.failures import get_process
from ..core.params import MultilevelCheckpointParams
from ..core.policy import ML_STRATEGIES, CheckpointPolicy, PolicyConfig
from ..energy import (PAPER_EXASCALE_ML_PROFILE, PAPER_EXASCALE_PROFILE,
                      TPU_V5E_HOST_PROFILE, EnergyMeter)
from ..ckpt import CheckpointManager, ManagerConfig, ShardedStore, StoreConfig
from .failures import FailureInjector, FailureModel
from .tracker import Tracker
from .trainer import FaultTolerantTrainer, TrainerConfig
from .watchdog import StepTimeWatchdog, WatchdogConfig

PROFILES = {"paper": PAPER_EXASCALE_PROFILE,
            "paper_ml": PAPER_EXASCALE_ML_PROFILE,
            "v5e": TPU_V5E_HOST_PROFILE}


@dataclasses.dataclass
class RunSpec:
    """Everything a fault-tolerant training run needs, as data."""

    # -- model / data --------------------------------------------------------
    arch: str = "xlstm-125m"
    reduce: bool = True               # reduced same-family config (CPU-sized)
    layers: int = 2
    d_model: int = 64
    n_heads: int = 4
    batch: int = 4
    seq: int = 32
    lr: float = 3e-4
    seed: int = 0
    total_steps: int = 200

    # -- policy --------------------------------------------------------------
    strategy: str = "algo_t"          # optimal.STRATEGIES + ML_STRATEGIES
    fixed_period_s: float = 600.0
    #: deep-storage cadence; None = policy-chosen (the (T, m) solver's m
    #: under the *_ml strategies, 1 otherwise).
    pfs_every: Optional[int] = None
    use_buddy: bool = True
    #: learn mu from observed gaps (True) or trust the scenario (False —
    #: the validation default: predictions need the configured mu).
    mu_from_observations: bool = False

    # -- failure scenario (virtual-time world) -------------------------------
    #: virtual seconds per training step; None = measured wall time (the
    #: scaled-time machinery below then stays off).
    step_s: Optional[float] = 1.0
    mu_s: float = float("inf")        # inf = no failure injection
    C_s: float = 0.5                  # deep (PFS, level-2) checkpoint cost
    R_s: float = 0.5
    D_s: float = 0.1
    C1_s: Optional[float] = None      # buddy (level-1) costs; None = deep's
    R1_s: Optional[float] = None
    D1_s: Optional[float] = None
    q: float = 0.0                    # P[failure also loses the buddy]
    omega: float = 0.0                # checkpoint overlap factor
    #: deep-flush overlap (VELOC async flush); None -> shared ``omega``.
    #: At omega2 > 0 the deep write stays in flight for ``omega2 * C``
    #: after its stall — a failure inside that window aborts the flush
    #: and rolls back to the previous surviving generation.
    omega2: Optional[float] = None
    process: str = "exponential"      # core.failures.PROCESSES name
    process_kwargs: dict = dataclasses.field(default_factory=dict)

    # -- accounting / storage ------------------------------------------------
    profile: str = "paper"            # PROFILES name
    ckpt_dir: Optional[str] = None    # None = fresh tempdir
    compress: bool = False
    checkpoint_at_start: bool = True
    max_failures: int = 10_000

    # -- derived -------------------------------------------------------------
    @property
    def scaled_time(self) -> bool:
        return self.step_s is not None

    @property
    def inject(self) -> bool:
        import math
        return self.mu_s > 0 and math.isfinite(self.mu_s)

    def level1(self) -> tuple[float, float, float]:
        """(C1, R1, D1), defaulting to degenerate levels."""
        return (self.C_s if self.C1_s is None else self.C1_s,
                self.R_s if self.R1_s is None else self.R1_s,
                self.D_s if self.D1_s is None else self.D1_s)

    def ml_params(self) -> MultilevelCheckpointParams:
        """The scenario as the two-level model's parameters (degenerate
        levels + m=1 reduce bit-for-bit to the single-level model)."""
        C1, R1, D1 = self.level1()
        return MultilevelCheckpointParams(
            C1=C1, R1=R1, D1=D1, C2=self.C_s, R2=self.R_s, D2=self.D_s,
            mu=self.mu_s, q=self.q, omega=self.omega, omega2=self.omega2)


def build(spec: RunSpec, tracker: Optional[Tracker] = None,
          ) -> FaultTolerantTrainer:
    """Assemble the full trainer stack from a spec."""
    import jax

    from ..configs import get_config, reduced
    from ..data import for_arch
    from ..models import build as build_model
    from ..optim import adamw

    cfg = get_config(spec.arch)
    if spec.reduce:
        cfg = reduced(cfg, n_layers=spec.layers, d_model=spec.d_model,
                      n_heads=spec.n_heads)
    model = build_model(cfg)
    ocfg = adamw.AdamWConfig(lr=spec.lr, warmup_steps=20,
                             total_steps=spec.total_steps)
    params = model.init(jax.random.key(spec.seed))
    opt = adamw.init_state(params, ocfg)

    profile = PROFILES[spec.profile]
    C1, R1, D1 = spec.level1()
    policy = CheckpointPolicy(
        PolicyConfig(strategy=spec.strategy,
                     fixed_period_s=spec.fixed_period_s,
                     C_s=spec.C_s, R_s=spec.R_s, D_s=spec.D_s,
                     C1_s=C1, R1_s=R1, D1_s=D1, q=spec.q,
                     mu_s=spec.mu_s, omega=spec.omega, omega2=spec.omega2,
                     mu_from_observations=spec.mu_from_observations),
        profile.power_params(), ml_power=profile.ml_power_params())

    ckpt_dir = spec.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    store = ShardedStore(StoreConfig(root=ckpt_dir, compress=spec.compress))
    manager = CheckpointManager(store, policy, ManagerConfig(
        async_write=True, use_buddy=spec.use_buddy,
        pfs_every=spec.pfs_every,
        virtual_C1_s=C1 if spec.scaled_time else None,
        virtual_C2_s=spec.C_s if spec.scaled_time else None))

    # Without a buddy level every recovery is deep: downtime is D2 flat.
    soft_D = D1 if spec.use_buddy else spec.D_s
    injector = FailureInjector(FailureModel(
        mu_s=spec.mu_s if spec.inject else float("inf"),
        downtime_s=soft_D if spec.scaled_time else spec.D_s,
        downtime_hard_s=spec.D_s if spec.scaled_time else None,
        recovery_buddy_s=R1 if spec.scaled_time else None,
        recovery_deep_s=spec.R_s if spec.scaled_time else None,
        buddy_loss_prob=spec.q if spec.use_buddy else 0.0,
        seed=spec.seed,
        process=(None if spec.process == "exponential"
                 else get_process(spec.process, **spec.process_kwargs))))

    data = for_arch(cfg, batch=spec.batch, seq_len=spec.seq, seed=spec.seed)
    step_fn = jax.jit(model.make_train_step(ocfg))
    # Straggler watchdog + manager alarms both surface through the
    # trainer's Tracker (events: "straggler", "alarm") and run report.
    watchdog = StepTimeWatchdog(WatchdogConfig())
    return FaultTolerantTrainer(
        train_step=step_fn, state=(params, opt), data=data, policy=policy,
        manager=manager, meter=EnergyMeter(profile), failures=injector,
        tracker=tracker, watchdog=watchdog,
        config=TrainerConfig(total_steps=spec.total_steps,
                             sim_seconds_per_step=spec.step_s,
                             checkpoint_at_start=spec.checkpoint_at_start,
                             max_failures=spec.max_failures))


def predictions(spec: RunSpec, report: dict) -> dict:
    """The paper's expected wall time and energy at the period the run
    actually executed (the operating point's realized T and the manager's
    effective m), against a base work of ``total_steps * step_s``."""
    if not (spec.scaled_time and spec.inject):
        return {}
    op = report["operating_point"]
    T_used, m = op["period_realized_s"], int(op["deep_every"])
    T_base = spec.total_steps * spec.step_s
    ck = spec.ml_params()
    power = PROFILES[spec.profile].ml_power_params()
    out = {"T_used_s": T_used, "m": m, "T_base_s": T_base,
           "wall_s": float(core_model.ml_time_final(T_used, m, ck,
                                                    T_base=T_base)),
           "energy_j": float(core_model.ml_energy_final(T_used, m, ck, power,
                                                        T_base=T_base))}
    meas_wall = report["wall_s"]
    meas_energy = report["energy"]["E_total_j"]
    out["wall_ratio"] = meas_wall / out["wall_s"]
    out["energy_ratio"] = meas_energy / out["energy_j"]
    return out


def execute(spec: RunSpec, tracker: Optional[Tracker] = None) -> dict:
    """Build, run, and attach the ``predicted`` block to the report."""
    trainer = build(spec, tracker=tracker)
    report = trainer.run()
    report["spec"] = dataclasses.asdict(spec)
    report["predicted"] = predictions(spec, report)
    return report


__all__ = ["RunSpec", "build", "execute", "predictions", "PROFILES",
           "ML_STRATEGIES"]
