"""Failure injection for the fault-tolerant trainer: schedules failures over
wall-clock time with platform MTBF mu = mu_ind / N (paper §2.1), plus
downtime/recovery duration models.

The inter-failure distribution is pluggable (``FailureModel.process``, any
:class:`repro.core.failures.FailureProcess`); the default remains the
paper's exponential and reproduces the legacy sampling stream bit-for-bit.

Two-level severity: with probability ``buddy_loss_prob`` (the multilevel
model's ``q``) a failure is *hard* — it takes the in-memory buddy copy
down with it, forcing recovery from the deep (PFS) level.  Hardness draws
come from a *separate* RNG stream so enabling q does not perturb the
failure-time schedule (same gaps with q=0 and q=0.5 at a given seed).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.failures import FailureProcess, as_process


@dataclasses.dataclass
class FailureModel:
    mu_s: float                 # platform MTBF (seconds)
    downtime_s: float = 2.0     # D: reboot / spare swap-in
    recovery_extra_s: float = 0.0  # added to the measured restore time (R)
    seed: int = 0
    #: inter-failure distribution; None = exponential (legacy behavior).
    process: Optional[FailureProcess] = None
    #: P[failure also loses the buddy copy] — the multilevel model's q.
    buddy_loss_prob: float = 0.0
    #: downtime after a *hard* failure (D2); None = same as downtime_s.
    downtime_hard_s: Optional[float] = None
    #: scaled-time per-level recovery overrides: when set, the trainer
    #: charges this instead of the measured restore time (R1 = buddy,
    #: R2 = deep); None = measure + recovery_extra_s.
    recovery_buddy_s: Optional[float] = None
    recovery_deep_s: Optional[float] = None

    @classmethod
    def from_platform(cls, *, n_nodes: int, mu_ind_s: float, **kw):
        return cls(mu_s=mu_ind_s / n_nodes, **kw)


class FailureInjector:
    """Schedules failure times from the model's process; the trainer polls
    ``check``.

    Scheduling semantics: with the default exponential process the next
    failure is drawn from the *poll* time (`now`), as the legacy code did —
    distributionally exact for a memoryless process.  For any other process
    the renewal clock must not drift with polling latency, so the next
    failure is scheduled from the previous failure's actual time instead
    (an absolute-time schedule).
    """

    def __init__(self, model: FailureModel, start_time: float = 0.0):
        self.model = model
        self.rng = np.random.default_rng(model.seed)
        # independent stream: hardness draws must not disturb the gap draws
        self._hard_rng = np.random.default_rng((model.seed, 0x6b75))
        self.enabled = model.mu_s > 0 and np.isfinite(model.mu_s)
        self._exponential = model.process is None
        self._gap_iter = None if self._exponential else \
            as_process(model.process).iter_gaps(self.rng,
                                                mean=model.mu_s)
        self._next = (start_time + self._draw() if self.enabled else np.inf)
        self.n_failures = 0
        self.n_hard = 0
        self.failure_times: list = []
        #: severity of the most recent failure returned by ``check``.
        self.last_was_hard = False

    def _draw(self) -> float:
        if self._exponential:
            return self.rng.exponential(self.model.mu_s)
        return next(self._gap_iter)

    @property
    def next_failure_time(self) -> float:
        return self._next

    def check(self, now: float) -> bool:
        """True exactly once per scheduled failure at/after its time."""
        if not self.enabled or now < self._next:
            return False
        self.n_failures += 1
        self.failure_times.append(self._next)
        q = self.model.buddy_loss_prob
        self.last_was_hard = bool(q > 0.0
                                  and self._hard_rng.random() < q)
        self.n_hard += int(self.last_was_hard)
        origin = now if self._exponential else self._next
        self._next = origin + self._draw()
        return True

    def downtime_for(self, hard: bool) -> float:
        m = self.model
        if hard and m.downtime_hard_s is not None:
            return m.downtime_hard_s
        return m.downtime_s

    def mtbf_estimate(self) -> Optional[float]:
        if len(self.failure_times) < 2:
            return None
        gaps = np.diff(self.failure_times)
        return float(np.mean(gaps))
