"""Failure injection: a Poisson process over wall-clock time with platform
MTBF mu = mu_ind / N (paper §2.1), plus downtime/recovery duration models."""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class FailureModel:
    mu_s: float                 # platform MTBF (seconds)
    downtime_s: float = 2.0     # D: reboot / spare swap-in
    recovery_extra_s: float = 0.0  # added to the measured restore time (R)
    seed: int = 0

    @classmethod
    def from_platform(cls, *, n_nodes: int, mu_ind_s: float, **kw):
        return cls(mu_s=mu_ind_s / n_nodes, **kw)


class FailureInjector:
    """Schedules exponential failure times; the trainer polls ``check``."""

    def __init__(self, model: FailureModel, start_time: float = 0.0):
        self.model = model
        self.rng = np.random.default_rng(model.seed)
        self.enabled = model.mu_s > 0 and np.isfinite(model.mu_s)
        self._next = (start_time + self.rng.exponential(model.mu_s)
                      if self.enabled else np.inf)
        self.n_failures = 0
        self.failure_times: list = []

    @property
    def next_failure_time(self) -> float:
        return self._next

    def check(self, now: float) -> bool:
        """True exactly once per scheduled failure at/after its time."""
        if not self.enabled or now < self._next:
            return False
        self.n_failures += 1
        self.failure_times.append(self._next)
        self._next = now + self.rng.exponential(self.model.mu_s)
        return True

    def mtbf_estimate(self) -> Optional[float]:
        if len(self.failure_times) < 2:
            return None
        gaps = np.diff(self.failure_times)
        return float(np.mean(gaps))
