"""Elastic reconfiguration: shrink/regrow the data axis after host loss.

The checkpoint format is mesh-agnostic (whole logical arrays restored through
``device_put`` with the NEW mesh's shardings), so elasticity reduces to:
  1. pick the largest viable data-axis size for the surviving hosts,
  2. rebuild the mesh,
  3. restore the last checkpoint under the new shardings,
  4. rescale the data pipeline (global batch keeps its size by growing the
     per-host microbatch, or shrinks if configured).
"""
from __future__ import annotations

import dataclasses

import jax

try:  # jax >= 0.5 exposes explicit axis types on the mesh
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto on every axis
    AxisType = None

from ..parallel import sharding as shd


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_shape: dict
    new_shape: dict
    lost_hosts: int
    batch_policy: str          # "keep_global" | "shrink"
    note: str = ""


def plan_reshard(mesh, n_failed_hosts: int, devices_per_host: int = 4,
                 batch_policy: str = "keep_global") -> ElasticPlan:
    """Largest data-axis size that fits the surviving device count while
    keeping the model axis intact (TP degree is architectural)."""
    old = dict(mesh.shape)
    model = old.get("model", 1)
    pod = old.get("pod", 1)
    total = 1
    for v in old.values():
        total *= v
    surviving = total - n_failed_hosts * devices_per_host
    new_data = surviving // (model * pod)
    if new_data < 1:
        raise RuntimeError("not enough devices for one data replica")
    new = dict(old)
    new["data"] = new_data
    return ElasticPlan(old_shape=old, new_shape=new,
                       lost_hosts=n_failed_hosts,
                       batch_policy=batch_policy,
                       note=f"{surviving}/{total} devices")


def build_mesh(plan: ElasticPlan):
    names = tuple(plan.new_shape.keys())
    shape = tuple(plan.new_shape.values())
    need = 1
    for s in shape:
        need *= s
    devs = jax.devices()[:need]
    if AxisType is not None:
        return jax.make_mesh(shape, names,
                             devices=devs,
                             axis_types=(AxisType.Auto,) * len(shape))
    return jax.make_mesh(shape, names, devices=devs)


def reshard_tree(tree, spec_tree, new_mesh, rules=None):
    """device_put every leaf under the new mesh's shardings."""
    from ..models.spec import is_spec

    def put(s, x):
        sh = shd.named_sharding(s.logical, new_mesh, rules, s.shape)
        return jax.device_put(x, sh)
    return jax.tree.map(put, spec_tree, tree, is_leaf=is_spec)
