"""Fault-tolerant trainer: the paper's closed loop.

Wires together:
  model train_step  <-  repro.models
  data pipeline     <-  repro.data.synthetic (checkpointable)
  period policy     <-  repro.core.policy (AlgoT / AlgoE / Young / Daly / ...)
  checkpointing     <-  repro.ckpt (async snapshot -> sharded store -> buddy)
  failure injection <-  repro.ft.failures (Poisson @ platform MTBF)
  straggler watch   <-  repro.ft.watchdog
  energy accounting <-  repro.energy (phase powers -> joules, alpha/beta/rho)

Time can be real (wall clock) or *scaled*: ``sim_seconds_per_step`` lets a
CPU-sized model emulate production step times so that MTBF/periods exercise
realistic regimes in seconds of test time.  Failures roll the run back to the
last committed checkpoint — data stream included — so a failure-free run and
a failure+resume run produce IDENTICAL final parameters (property-tested).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from ..core.policy import CheckpointPolicy
from ..energy import EnergyMeter, Phase
from .failures import FailureInjector, FailureModel
from .watchdog import StepTimeWatchdog


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    sim_seconds_per_step: Optional[float] = None  # None -> measured wall time
    checkpoint_at_start: bool = True
    max_failures: int = 1000


class FaultTolerantTrainer:
    def __init__(self, *, train_step: Callable, state: Any, data,
                 policy: CheckpointPolicy, manager, meter: EnergyMeter,
                 failures: FailureInjector,
                 watchdog: Optional[StepTimeWatchdog] = None,
                 config: TrainerConfig = TrainerConfig()):
        self.train_step = train_step
        self.state = state          # (params, opt_state)
        self.data = data
        self.policy = policy
        self.manager = manager
        self.meter = meter
        self.failures = failures
        self.watchdog = watchdog or StepTimeWatchdog()
        self.cfg = config
        # virtual clock (seconds since run start)
        self.now = 0.0
        self.step = 0
        self.log: list = []
        self.n_rollbacks = 0

    # ---------------------------------------------------------------- helpers
    def _full_state(self) -> dict:
        return {"model": self.state, "data": self.data.state(),
                "step": np.asarray(self.step, np.int64)}

    def _advance(self, seconds: float, phase: Phase, *,
                 overlapped_compute: float = 0.0) -> None:
        self.now += seconds
        self.meter.add(phase, seconds)
        if overlapped_compute:
            self.meter.add(Phase.COMPUTE, overlapped_compute,
                           advances_wall=False)

    # ---------------------------------------------------------------- failure
    def _handle_failure(self):
        self.n_rollbacks += 1
        self.policy.observe_failure(self.now)
        # downtime D
        D = self.failures.model.downtime_s
        self._advance(D, Phase.DOWN)
        # recovery R: restore the last committed checkpoint (measured)
        t0 = time.perf_counter()
        like = self._full_state()
        restored, ck_step, source = self.manager.restore(like)
        r_measured = time.perf_counter() - t0
        R = r_measured + self.failures.model.recovery_extra_s
        self._advance(R, Phase.RECOVERY_IO)
        self.policy.observe_recovery(recovery_s=R, downtime_s=D)
        if restored is None:
            # no checkpoint yet: restart from step 0 state (kept by caller)
            raise RuntimeError(
                "failure before first checkpoint and no initial snapshot")
        self.state = restored["model"]
        self.data.restore(jax.tree.map(np.asarray, restored["data"]))
        self.step = int(restored["step"])
        self.log.append({"event": "rollback", "to_step": self.step,
                         "source": source, "t": self.now})

    # ------------------------------------------------------------------- run
    def run(self) -> dict:
        cfg = self.cfg
        if cfg.checkpoint_at_start:
            self.manager.checkpoint(self.step, self._full_state(), block=True)

        losses = []
        while self.step < cfg.total_steps:
            if self.failures.check(self.now):
                self._handle_failure()
                continue

            batch = self.data.peek()
            t0 = time.perf_counter()
            params, opt, metrics = self.train_step(self.state[0],
                                                   self.state[1], batch)
            jax.block_until_ready(metrics["loss"])
            wall = time.perf_counter() - t0
            step_s = (cfg.sim_seconds_per_step
                      if cfg.sim_seconds_per_step is not None else wall)

            self.state = (params, opt)
            next(self.data)          # consume the batch
            self.step += 1
            self._advance(step_s, Phase.COMPUTE)
            self.policy.observe_step_time(step_s)
            self.watchdog.observe(self.step, step_s)
            losses.append(float(metrics["loss"]))

            # policy-driven non-blocking checkpoint
            if self.manager.maybe_checkpoint(self.step, self._full_state()):
                C = self.manager.measured_C_s or 0.0
                ck = self.policy.checkpoint_params()
                # non-blocking: I/O time C overlaps omega*C of useful work
                self._advance(C * (1.0 - ck.omega), Phase.CHECKPOINT_IO)
                self.meter.add(Phase.CHECKPOINT_IO, C * ck.omega,
                               advances_wall=False)
                self.meter.add(Phase.COMPUTE, C * ck.omega,
                               advances_wall=False)

            if self.failures.n_failures > cfg.max_failures:
                raise RuntimeError("failure budget exceeded")

        self.manager.wait()
        report = {
            "final_step": self.step,
            "losses": losses,
            "n_failures": self.failures.n_failures,
            "n_rollbacks": self.n_rollbacks,
            "wall_s": self.now,
            "energy": self.meter.report(),
            "policy": self.policy.report(),
            "straggler_events": len(self.watchdog.events),
            "checkpoints": list(self.manager.stats),
        }
        return report
