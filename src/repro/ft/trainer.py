"""Fault-tolerant trainer: the paper's closed loop.

Wires together:
  model train_step  <-  repro.models
  data pipeline     <-  repro.data.synthetic (checkpointable)
  period policy     <-  repro.core.policy (AlgoT / AlgoE / ... / algo_t_ml)
  checkpointing     <-  repro.ckpt (async snapshot -> sharded store -> buddy)
  failure injection <-  repro.ft.failures (any FailureProcess @ platform MTBF)
  straggler watch   <-  repro.ft.watchdog
  energy accounting <-  repro.energy (phase powers -> joules, alpha/beta/rho)
  metrics           <-  repro.ft.tracker (jsonl / stdout / memory backends)

Time can be real (wall clock) or *scaled*: ``sim_seconds_per_step`` lets a
CPU-sized model emulate production step times so that MTBF/periods exercise
realistic regimes in seconds of test time.  For validation runs, the
checkpoint/recovery/downtime durations are virtual too (manager
``virtual_C*_s``, failure-model ``recovery_*_s`` / ``downtime_*_s``), so
the whole run lives in one consistent virtual-time world whose parameters
are exactly the analytical scenario's — the failure schedule is then the
only randomness, and measured wall/energy converge to the model's
``time_final`` / ``energy_final`` (``ml_*`` for two-level runs) over seeds.

Overlap accounting mirrors the model: a non-blocking checkpoint of cost C
advances the wall by its critical-path share ``(1-omega)*C`` while the I/O
device is busy for the full C (the remaining ``omega*C`` is metered
off-wall, overlapped under later compute).  Compute time is the steps
alone — the overlapped work is already inside them.

Failures roll the run back to the last committed checkpoint — data stream
included — so a failure-free run and a failure+resume run produce IDENTICAL
final parameters (property-tested).  *Hard* failures (probability q) drop
the buddy replica first, forcing a deep (PFS) restore at R2/D2 cost.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from ..core.policy import CheckpointPolicy
from ..energy import EnergyMeter, Phase
from .failures import FailureInjector
from .tracker import NullTracker, Tracker
from .watchdog import StepTimeWatchdog


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    sim_seconds_per_step: Optional[float] = None  # None -> measured wall time
    checkpoint_at_start: bool = True
    max_failures: int = 1000


class FaultTolerantTrainer:
    def __init__(self, *, train_step: Callable, state: Any, data,
                 policy: CheckpointPolicy, manager, meter: EnergyMeter,
                 failures: FailureInjector,
                 watchdog: Optional[StepTimeWatchdog] = None,
                 tracker: Optional[Tracker] = None,
                 config: Optional[TrainerConfig] = None):
        self.train_step = train_step
        self.state = state          # (params, opt_state)
        self.data = data
        self.policy = policy
        self.manager = manager
        self.meter = meter
        self.failures = failures
        self.watchdog = watchdog or StepTimeWatchdog()
        if self.watchdog.on_straggler is None:
            self.watchdog.on_straggler = self._on_straggler
        self.tracker = tracker or NullTracker()
        # NOTE: built per instance — a dataclass default argument would be
        # one shared TrainerConfig across trainers.
        self.cfg = config if config is not None else TrainerConfig()
        if getattr(self.manager, "on_alarm", False) is None:
            self.manager.on_alarm = self._on_alarm
        # virtual clock (seconds since run start)
        self.now = 0.0
        self.step = 0
        self.log: list = []
        self.n_rollbacks = 0
        self.n_flush_aborts = 0
        #: in-flight deep/buddy flush of the newest checkpoint: committed
        #: only once the virtual clock passes ``commit_at`` — a failure
        #: inside the window loses the generation (the model's
        #: hazard-during-flush).
        self._pending_flush: Optional[dict] = None

    def _on_straggler(self, event: dict) -> None:
        self.tracker.log({"kind": "straggler", "t": self.now, **event})

    def _on_alarm(self, alarm: dict) -> None:
        self.tracker.log({"kind": "alarm", "t": self.now, **alarm})

    # ---------------------------------------------------------------- helpers
    def _full_state(self) -> dict:
        return {"model": self.state, "data": self.data.state(),
                "step": np.asarray(self.step, np.int64)}

    def _advance(self, seconds: float, phase: Phase) -> None:
        self.now += seconds
        self.meter.add(phase, seconds)

    # ---------------------------------------------------------------- failure
    def _handle_failure(self):
        self.n_rollbacks += 1
        self.policy.observe_failure(self.now)
        # A failure inside the flush window interrupts the in-flight
        # write: abort the flush thread, reject the torn generation, and
        # revert the buddy — restore then falls back to the previous
        # surviving generation/level (the model's flush-window loss).
        pend, self._pending_flush = self._pending_flush, None
        if pend is not None and self.now < pend["commit_at"]:
            self.manager.discard_in_flight(pend["step"], pend["level"])
            self.n_flush_aborts += 1
            self.tracker.log({"kind": "flush_aborted", "t": self.now,
                              "step": pend["step"],
                              "level": pend["level"]})
        hard = self.failures.last_was_hard
        if hard:
            self.manager.drop_buddy()
        # downtime D (D2 for hard failures when configured)
        D = self.failures.downtime_for(hard)
        self._advance(D, Phase.DOWN)
        # recovery R: restore the last *surviving* checkpoint (measured,
        # or the scenario's virtual per-level cost in scaled time)
        t0 = time.perf_counter()
        like = self._full_state()
        restored, ck_step, source = self.manager.restore(like)
        r_measured = time.perf_counter() - t0
        fm = self.failures.model
        # Recovery level follows failure *severity*, not the manager's
        # tie-breaking: a soft failure with a buddy level reads the (always
        # freshest) buddy copy at R1 cost, exactly the model's q-mixing.
        level = 1 if (not hard and self.manager.buddy is not None) else 2
        virt = fm.recovery_buddy_s if level == 1 else fm.recovery_deep_s
        R = (r_measured + fm.recovery_extra_s) if virt is None else virt
        self._advance(R, Phase.RECOVERY_IO_BUDDY if level == 1
                      else Phase.RECOVERY_IO)
        self.policy.observe_recovery(recovery_s=R, downtime_s=D, level=level)
        if restored is None:
            # no checkpoint yet: restart from step 0 state (kept by caller)
            raise RuntimeError(
                "failure before first checkpoint and no initial snapshot")
        self.state = restored["model"]
        self.data.restore(jax.tree.map(np.asarray, restored["data"]))
        self.step = int(restored["step"])
        self.log.append({"event": "rollback", "to_step": self.step,
                         "source": source, "t": self.now})
        self.tracker.log({"kind": "failure", "t": self.now, "hard": hard,
                          "downtime_s": D, "recovery_s": R,
                          "level": level, "source": source,
                          "to_step": self.step})

    # ------------------------------------------------------------------- run
    def run(self) -> dict:
        cfg = self.cfg
        if cfg.checkpoint_at_start:
            self.manager.checkpoint(self.step, self._full_state(), block=True)

        losses = []
        while self.step < cfg.total_steps:
            pend = self._pending_flush
            if pend is not None and self.now >= pend["commit_at"]:
                self._pending_flush = None     # flush window closed: committed
            if self.failures.check(self.now):
                self._handle_failure()
                continue

            batch = self.data.peek()
            t0 = time.perf_counter()
            params, opt, metrics = self.train_step(self.state[0],
                                                   self.state[1], batch)
            jax.block_until_ready(metrics["loss"])
            wall = time.perf_counter() - t0
            step_s = (cfg.sim_seconds_per_step
                      if cfg.sim_seconds_per_step is not None else wall)

            # A failure scheduled inside this step interrupts it: the
            # partial compute is wasted wall time and the step's results
            # never commit (a crashed node checkpoints nothing) — without
            # this, work would "outrun" the failure to the next poll.
            nf = self.failures.next_failure_time
            if nf < self.now + step_s:
                self.meter.add(Phase.COMPUTE, max(nf - self.now, 0.0))
                self.now = nf       # loop-top check fires exactly here
                continue

            self.state = (params, opt)
            next(self.data)          # consume the batch
            self.step += 1
            self._advance(step_s, Phase.COMPUTE)
            self.policy.observe_step_time(step_s)
            self.watchdog.observe(self.step, step_s)
            losses.append(float(metrics["loss"]))
            self.tracker.log({"kind": "step", "t": self.now,
                              "step": self.step, "step_s": step_s,
                              "loss": float(metrics["loss"])})

            # policy-driven non-blocking checkpoint (level 2 = deep/PFS,
            # level 1 = buddy-only on the every-m-th cadence)
            level = self.manager.due(self.step)
            if level:
                omega = self.policy.overlap_for(level)
                C_est = self.manager.expected_cost(level) or 0.0
                phase = (Phase.CHECKPOINT_IO if level >= 2
                         else Phase.CHECKPOINT_IO_BUDDY)
                nf = self.failures.next_failure_time
                if nf < self.now + C_est * (1.0 - omega):
                    # failure mid-write: the partial I/O is wasted wall
                    # time and the checkpoint never commits (torn write)
                    self.meter.add(phase, max(nf - self.now, 0.0))
                    self.now = nf
                    self.tracker.log({"kind": "checkpoint_aborted",
                                      "t": self.now, "step": self.step,
                                      "level": level})
                    continue
                level = self.manager.checkpoint(self.step,
                                                self._full_state())
                omega = self.policy.overlap_for(level)
                phase = (Phase.CHECKPOINT_IO if level >= 2
                         else Phase.CHECKPOINT_IO_BUDDY)
                virt = self.manager.expected_virtual_cost(level)
                if virt is not None:
                    # scaled-time world: charge the scenario's cost and
                    # leave the flush IN FLIGHT for omega*C more wall —
                    # a failure inside that window aborts it.
                    C = virt
                else:
                    # measured mode: drain the write and read its cost
                    # (the pre-async behavior).
                    last = self.manager.last_checkpoint()
                    C = last["C_s"] if last else C_est
                # non-blocking: only (1-omega)*C hits the wall; the I/O
                # device is busy the full C (rest overlaps later compute)
                self._advance(C * (1.0 - omega), phase)
                self.meter.add(phase, C * omega, advances_wall=False)
                if virt is not None and omega > 0.0:
                    self._pending_flush = {"step": self.step,
                                           "level": level,
                                           "commit_at": self.now + C * omega}
                self.tracker.log({"kind": "checkpoint", "t": self.now,
                                  "step": self.step, "level": level,
                                  "C_s": C})

            if self.failures.n_failures > cfg.max_failures:
                raise RuntimeError("failure budget exceeded")

        self.manager.wait()
        report = {
            "final_step": self.step,
            "losses": losses,
            "n_failures": self.failures.n_failures,
            "n_hard_failures": self.failures.n_hard,
            "n_rollbacks": self.n_rollbacks,
            "wall_s": self.now,
            "energy": self.meter.report(),
            "policy": self.policy.report(),
            "operating_point": self.policy.operating_point(
                self.manager.deep_every()),
            "straggler_events": len(self.watchdog.events),
            "straggler_escalations": sum(1 for e in self.watchdog.events
                                         if e.get("escalate")),
            "flush_aborts": self.n_flush_aborts,
            "flush_errors": len(getattr(self.manager, "flush_errors", ())),
            "pfs_degraded": getattr(self.manager, "degraded", False),
            "alarms": list(getattr(self.manager, "alarms", ())),
            "checkpoints": list(self.manager.stats),
        }
        self.tracker.log({"kind": "summary", "t": self.now,
                          "final_step": self.step,
                          "n_failures": self.failures.n_failures,
                          "n_rollbacks": self.n_rollbacks,
                          "wall_s": self.now,
                          "energy_total_j": report["energy"]["E_total_j"]})
        self.tracker.close()
        return report
