from . import adamw, grad_compress
from .adamw import AdamWConfig, AdamWState, init_state, state_spec, \
    apply_updates, schedule, global_norm, clip_by_global_norm
