"""AdamW with decoupled weight decay, global-norm clipping and schedules.

Functional, pytree-based (no optax dependency).  Optimizer slots inherit the
parameter sharding (FSDP over "data"), so optimizer state is ZeRO-sharded for
free.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # At-scale memory options (used for the 20B+ archs on 16 GiB/chip):
    factored_second_moment: bool = False   # Adafactor-style row/col v (>=2D)
    momentum_dtype: str = "float32"        # "bfloat16" halves m
    master_weights: bool = True            # f32 master when params are bf16


class FactoredV(NamedTuple):
    """Adafactor-style factored second moment for a >=2D tensor: row/col
    means over the trailing two axes (leading stack axes kept)."""
    row: jax.Array    # shape[:-1]           (mean over last axis)
    col: jax.Array    # shape[:-2] + last    (mean over second-to-last)


class AdamWState(NamedTuple):
    step: jax.Array      # int32 scalar
    m: object            # pytree like params (momentum_dtype)
    v: object            # pytree: f32 like params, or FactoredV
    master: object       # f32 master weights when params are bf16, else None


def _wants_factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 8 and shape[-2] >= 8


def init_state(params, cfg: "AdamWConfig" = None) -> AdamWState:
    """Mixed precision: if params are not f32, keep an f32 master copy in the
    optimizer state (ZeRO-sharded like everything else); the bf16 working
    copy is what FSDP all-gathers — halving gather bytes."""
    cfg = cfg or AdamWConfig()
    mdt = jnp.dtype(cfg.momentum_dtype)
    m = jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)

    def mk_v(p):
        if cfg.factored_second_moment and _wants_factored(p.shape):
            return FactoredV(row=jnp.zeros(p.shape[:-1], jnp.float32),
                             col=jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                           jnp.float32))
        return jnp.zeros(p.shape, jnp.float32)
    v = jax.tree.map(mk_v, params)
    needs_master = cfg.master_weights and any(
        x.dtype != jnp.float32 for x in jax.tree.leaves(params))
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if needs_master else None)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=m, v=v, master=master)


def state_spec(param_spec_tree, cfg: "AdamWConfig" = None):
    """ParamSpec tree for the optimizer state (mirrors parameter sharding)."""
    from ..models.spec import ParamSpec, is_spec
    cfg = cfg or AdamWConfig()

    def clone(s, dtype="float32"):
        return ParamSpec(s.shape, s.logical, dtype, init="zeros")
    m = jax.tree.map(lambda s: clone(s, cfg.momentum_dtype),
                     param_spec_tree, is_leaf=is_spec)

    def mk_v(s):
        if cfg.factored_second_moment and _wants_factored(s.shape):
            return FactoredV(
                row=ParamSpec(s.shape[:-1], s.logical[:-1], "float32",
                              init="zeros"),
                col=ParamSpec(s.shape[:-2] + s.shape[-1:],
                              s.logical[:-2] + s.logical[-1:], "float32",
                              init="zeros"))
        return clone(s)
    v = jax.tree.map(mk_v, param_spec_tree, is_leaf=is_spec)
    needs_master = cfg.master_weights and any(
        s.dtype != "float32" for s in jax.tree.leaves(
            param_spec_tree, is_leaf=is_spec))
    master = (jax.tree.map(clone, param_spec_tree, is_leaf=is_spec)
              if needs_master else None)
    return AdamWState(step=ParamSpec((), (), "int32", init="zeros"), m=m,
                      v=v, master=master)


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(
        g.dtype), grads), norm


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState):
    """One AdamW step.  Returns (new_params, new_state, metrics).

    Global-norm clipping is folded into the per-leaf update as a scalar
    multiply (no whole-tree clipped-gradient materialization).
    """
    gnorm = global_norm(grads)
    clip_scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, w):
        """w = f32 master (or the f32 param itself)."""
        gf = g.astype(jnp.float32) * clip_scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        if isinstance(v, FactoredV):
            g2 = gf * gf
            row_new = b2 * v.row + (1 - b2) * jnp.mean(g2, axis=-1)
            col_new = b2 * v.col + (1 - b2) * jnp.mean(g2, axis=-2)
            # rank-1 reconstruction (Adafactor): V ~ row x col / mean(row)
            denom = jnp.maximum(jnp.mean(row_new, axis=-1, keepdims=True),
                                1e-30)
            vh = (row_new[..., None] * col_new[..., None, :]
                  / denom[..., None]) / bc2
            v_new = FactoredV(row=row_new, col=col_new)
        else:
            v_full = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            vh = v_full / bc2
            v_new = v_full
        mh = m_new / bc1
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:   # decay matrices only (norms/bias exempt)
            delta = delta + cfg.weight_decay * w
        w_new = w - lr * delta
        return w_new.astype(p.dtype), m_new.astype(m.dtype), v_new, w_new

    is_v_leaf = lambda x: isinstance(x, FactoredV)
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.flatten(state.v, is_leaf=is_v_leaf)[0]
    has_master = state.master is not None
    flat_w = (jax.tree.leaves(state.master) if has_master
              else [p.astype(jnp.float32) for p in flat_p])
    out = [upd(p, g, m, v, w) for p, g, m, v, w in
           zip(flat_p, flat_g, flat_m, flat_v, flat_w)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_master = (jax.tree.unflatten(treedef, [o[3] for o in out])
                  if has_master else None)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v,
                             master=new_master), metrics
