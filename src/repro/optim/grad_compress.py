"""Int8 blockwise gradient compression with error feedback.

At 1000+ nodes the cross-pod gradient reduction is bandwidth-bound; blockwise
int8 halves-to-quarters the exchanged bytes (uses the ``quant_blockwise``
Pallas kernel). Quantization error is carried in a per-leaf **error-feedback
buffer** (Seide et al. / 1-bit SGD lineage): the residual from step t is
added to the gradient at t+1, so compression noise behaves like delayed —
not lost — signal, and SGD/Adam convergence is preserved.

The compress/decompress pair simulates the wire format locally (this
container has one process); the trainer-side semantics (what the optimizer
sees) are exactly what a compressed all-reduce would deliver, and the unit
tests property-check the error-feedback telescoping.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..kernels import ops as kops


class CompressState(NamedTuple):
    error: object          # pytree like grads (f32 residuals)


def init_state(grads_like) -> CompressState:
    return CompressState(error=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def _roundtrip(x: jax.Array, force_interpret: Optional[bool] = None):
    """Quantize -> dequantize (the wire)."""
    if x.size < 1024:                   # tiny leaves ride uncompressed
        return x.astype(jnp.float32), 0, x.size * 4
    q, s, pad = kops.quantize_array(x.astype(jnp.float32),
                                    force_interpret=force_interpret)
    wire = q.nbytes + s.nbytes
    back = kops.dequantize_array(q, s, shape=x.shape, dtype="float32",
                                 pad=pad, force_interpret=force_interpret)
    return back, wire, x.size * 4


def compress_grads(grads, state: CompressState,
                   force_interpret: Optional[bool] = None):
    """Returns (decompressed grads as the receiver sees them, new state,
    stats dict)."""
    leaves, treedef = jax.tree.flatten(grads)
    errs = jax.tree.leaves(state.error)
    out, new_err = [], []
    wire_bytes = 0
    raw_bytes = 0
    for g, e in zip(leaves, errs):
        target = g.astype(jnp.float32) + e        # error feedback
        back, wire, raw = _roundtrip(target, force_interpret)
        out.append(back.astype(g.dtype))
        new_err.append(target - back)             # residual for next step
        wire_bytes += wire
        raw_bytes += raw
    stats = {"wire_bytes": wire_bytes, "raw_bytes": raw_bytes,
             "ratio": wire_bytes / max(raw_bytes, 1)}
    return (jax.tree.unflatten(treedef, out),
            CompressState(error=jax.tree.unflatten(treedef, new_err)),
            stats)
