"""Serving drivers.

Model path (default): prefill a batch of prompts, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b \\
        --reduce --batch 4 --prompt-len 64 --new-tokens 16 --kv-cache int8

Advisor path: drive the checkpoint-advisor service (``repro.serve``)
with a synthetic open-loop workload and print throughput/latency/cache
statistics.  ``--smoke`` runs the short self-checking workload CI uses.

    PYTHONPATH=src python -m repro.launch.serve advisor --requests 512 \\
        --rate 2000 --repeat-frac 0.5 --batch-window-ms 2
    PYTHONPATH=src python -m repro.launch.serve advisor --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import time


def build_parser() -> argparse.ArgumentParser:
    """Model-serving CLI (kept separate so tests can parse without jax)."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="starcoder2-3b")
    # BooleanOptionalAction gives --reduce/--no-reduce; the old
    # store_true + default=True form made the flag impossible to disable.
    ap.add_argument("--reduce", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--kv-cache", default="bfloat16",
                    choices=["bfloat16", "int8"])
    ap.add_argument("--waves", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def build_advisor_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.serve advisor",
        description="Open-loop load run against the checkpoint advisor.")
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--rate", type=float, default=2000.0,
                    help="open-loop arrival rate (requests/s)")
    ap.add_argument("--batch-window-ms", type=float, default=2.0)
    ap.add_argument("--max-batch", type=int, default=512)
    ap.add_argument("--two-tier-frac", type=float, default=0.5)
    ap.add_argument("--repeat-frac", type=float, default=0.0)
    ap.add_argument("--warmup", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="short self-checking run (used by CI)")
    return ap


def advisor_main(argv=None):
    from ..serve import (AdvisorService, ThreadedAdvisor, run_open_loop,
                         synthetic_requests)

    args = build_advisor_parser().parse_args(argv)
    if args.smoke:
        return _advisor_smoke()

    reqs = synthetic_requests(args.requests, seed=args.seed,
                              two_tier_frac=args.two_tier_frac,
                              repeat_frac=args.repeat_frac)
    warm = synthetic_requests(args.warmup, seed=args.seed + 1,
                              two_tier_frac=args.two_tier_frac)
    with ThreadedAdvisor(AdvisorService(),
                         batch_window_s=args.batch_window_ms * 1e-3,
                         max_batch=args.max_batch) as advisor:
        rep = run_open_loop(advisor, reqs, rate_hz=args.rate, warmup=warm)
        metrics = advisor.metrics()
    print(f"served {rep.n} requests in {rep.duration_s:.3f}s "
          f"-> {rep.rps:.0f} rps")
    print(f"latency p50={rep.p50_ms:.2f}ms p99={rep.p99_ms:.2f}ms "
          f"max={rep.max_ms:.2f}ms")
    print(f"cache hit rate {rep.hit_rate:.1%}; "
          f"{rep.windows} windows, mean size {rep.mean_window:.1f}")
    print(f"dispatched solves: {metrics['dispatched_solves']} "
          f"({metrics['solved_lanes']} lanes), "
          f"exact fallbacks: {metrics['fallback_requests']}")
    return rep


def _advisor_smoke():
    """CI leg: throughput > 0, hits on repeats, batched == unbatched."""
    from ..serve import (AdvisorService, ThreadedAdvisor, run_open_loop,
                         synthetic_requests)

    reqs = synthetic_requests(48, seed=7, two_tier_frac=0.5,
                              repeat_frac=0.5)

    # batched answers == unbatched single-request answers, bit for bit
    batched = AdvisorService(cache_name=None).advise_many(reqs)
    solo_svc = AdvisorService(cache_name=None)
    for req, a in zip(reqs, batched):
        b = solo_svc.advise(req)
        same = (a.period == b.period and a.deep_every == b.deep_every
                and (a.predicted_energy == b.predicted_energy
                     or (a.predicted_energy != a.predicted_energy
                         and b.predicted_energy != b.predicted_energy)))
        if not same:
            raise SystemExit(f"FAIL: batched != unbatched for {req}")
    print("PASS batched == unbatched (48 requests, bit-identical)")

    with ThreadedAdvisor(AdvisorService(cache_name=None),
                         batch_window_s=2e-3) as advisor:
        rep = run_open_loop(advisor, reqs, rate_hz=2000.0,
                            warmup=synthetic_requests(8, seed=8))
    if not rep.rps > 0.0:
        raise SystemExit("FAIL: zero throughput")
    print(f"PASS open loop: {rep.rps:.0f} rps, p50={rep.p50_ms:.2f}ms, "
          f"p99={rep.p99_ms:.2f}ms")
    if not rep.hit_rate > 0.0:
        raise SystemExit("FAIL: no cache hits on repeated workload")
    print(f"PASS cache hit rate {rep.hit_rate:.1%} on repeated workload")
    return rep


def model_main(args):
    import jax
    import jax.numpy as jnp

    from ..configs import get_config, reduced
    from ..models import build

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, kv_cache_dtype=args.kv_cache,
                              prefill_waves=args.waves)
    model = build(cfg)
    params = model.init(jax.random.key(args.seed))

    key = jax.random.key(args.seed + 1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.is_encoder_decoder:
        batch["frames"] = 0.02 * jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model))
    if cfg.n_prefix_tokens:
        batch["prefix"] = 0.02 * jax.random.normal(
            key, (args.batch, cfg.n_prefix_tokens, cfg.d_model))

    total = args.prompt_len + (cfg.n_prefix_tokens or 0) + args.new_tokens
    t0 = time.perf_counter()
    logits, cache = jax.block_until_ready(
        model.prefill(params, batch, max_cache_seq=total))
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for _ in range(args.new_tokens - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} kv_cache={args.kv_cache} waves={args.waves}")
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in "
          f"{t_prefill*1e3:.1f} ms")
    print(f"decode : {args.new_tokens} steps x {args.batch} seqs in "
          f"{t_decode*1e3:.1f} ms "
          f"({t_decode/max(args.new_tokens-1,1)*1e3:.1f} ms/step)")
    print("generated token ids (first sequence):",
          [int(t) for t in gen[0][:16]])
    return gen


def main(argv=None):
    import sys
    argv = list(sys.argv[1:]) if argv is None else list(argv)
    if argv and argv[0] == "advisor":
        return advisor_main(argv[1:])
    return model_main(build_parser().parse_args(argv))


if __name__ == "__main__":
    main()
