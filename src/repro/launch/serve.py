"""Batched serving driver: prefill a batch of prompts, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b \\
        --reduce --batch 4 --prompt-len 64 --new-tokens 16 --kv-cache int8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, reduced
import dataclasses

from ..models import build


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--reduce", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--kv-cache", default="bfloat16",
                    choices=["bfloat16", "int8"])
    ap.add_argument("--waves", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, kv_cache_dtype=args.kv_cache,
                              prefill_waves=args.waves)
    model = build(cfg)
    params = model.init(jax.random.key(args.seed))

    key = jax.random.key(args.seed + 1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.is_encoder_decoder:
        batch["frames"] = 0.02 * jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model))
    if cfg.n_prefix_tokens:
        batch["prefix"] = 0.02 * jax.random.normal(
            key, (args.batch, cfg.n_prefix_tokens, cfg.d_model))

    total = args.prompt_len + (cfg.n_prefix_tokens or 0) + args.new_tokens
    t0 = time.perf_counter()
    logits, cache = jax.block_until_ready(
        model.prefill(params, batch, max_cache_seq=total))
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for _ in range(args.new_tokens - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} kv_cache={args.kv_cache} waves={args.waves}")
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in "
          f"{t_prefill*1e3:.1f} ms")
    print(f"decode : {args.new_tokens} steps x {args.batch} seqs in "
          f"{t_decode*1e3:.1f} ms "
          f"({t_decode/max(args.new_tokens-1,1)*1e3:.1f} ms/step)")
    print("generated token ids (first sequence):",
          [int(t) for t in gen[0][:16]])
    return gen


if __name__ == "__main__":
    main()
