"""Fault-tolerant training driver (the paper's technique end-to-end).

A thin CLI over :class:`repro.ft.run.RunSpec`: builds an architecture
(full or reduced), wires the FT trainer with the checkpoint-period policy
(single-level AlgoT/AlgoE/... or the joint multilevel ``algo_t_ml`` /
``algo_e_ml`` which also chooses the buddy/PFS cadence m), injects
failures from any renewal process (exponential / weibull / lognormal),
runs in scaled virtual time, and prints the measured report next to the
model's predictions (``ml_time_final`` / ``ml_energy_final`` at the
executed operating point).

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --reduce \\
        --steps 300 --strategy algo_e --mtbf 120
    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \\
        --strategy algo_e_ml --mtbf 20 --q 0.15 --c1 0.3 --r1 0.3 \\
        --ckpt-cost 1.5 --recovery 1.5 --profile paper_ml --jsonl run.jsonl
    PYTHONPATH=src python -m repro.launch.train --smoke   # CI leg
"""
from __future__ import annotations

import argparse
import json

from ..core.failures import PROCESSES
from ..core.optimal import STRATEGIES


def build_parser() -> argparse.ArgumentParser:
    """CLI (kept separate so tests can parse without building jax state)."""
    from ..ft.run import PROFILES, RunSpec
    d = RunSpec()
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=d.arch)
    ap.add_argument("--reduce", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--layers", type=int, default=d.layers)
    ap.add_argument("--d-model", type=int, default=d.d_model)
    ap.add_argument("--steps", type=int, default=d.total_steps)
    ap.add_argument("--batch", type=int, default=d.batch)
    ap.add_argument("--seq", type=int, default=d.seq)
    ap.add_argument("--lr", type=float, default=d.lr)
    ap.add_argument("--strategy", default="algo_t",
                    choices=list(STRATEGIES) + ["algo_t_ml", "algo_e_ml",
                                                "fixed"])
    ap.add_argument("--mtbf", type=float, default=float("inf"),
                    help="platform MTBF in (sim) seconds; inf = no failures")
    ap.add_argument("--process", default="exponential",
                    choices=sorted(PROCESSES),
                    help="inter-failure renewal process")
    ap.add_argument("--process-param", type=float, default=None,
                    help="shape (weibull) / sigma (lognormal)")
    ap.add_argument("--ckpt-cost", type=float, default=d.C_s,
                    help="deep (PFS) checkpoint cost C2 in sim seconds")
    ap.add_argument("--recovery", type=float, default=d.R_s,
                    help="deep recovery cost R2 in sim seconds")
    ap.add_argument("--downtime", type=float, default=d.D_s,
                    help="downtime D (D2) in sim seconds")
    ap.add_argument("--c1", type=float, default=None,
                    help="buddy checkpoint cost C1 (default: = C2)")
    ap.add_argument("--r1", type=float, default=None,
                    help="buddy recovery cost R1 (default: = R2)")
    ap.add_argument("--q", type=float, default=d.q,
                    help="P[failure also loses the buddy copy]")
    ap.add_argument("--omega", type=float, default=d.omega,
                    help="checkpoint overlap factor")
    ap.add_argument("--pfs-every", type=int, default=None,
                    help="deep-write cadence m (default: policy-chosen)")
    ap.add_argument("--buddy", action=argparse.BooleanOptionalAction,
                    default=True, help="in-memory buddy replica level")
    ap.add_argument("--inject-failures", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="inject failures (needs a finite --mtbf)")
    ap.add_argument("--compress", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="int8 blockwise checkpoint compression")
    ap.add_argument("--profile", default="paper",
                    choices=sorted(PROFILES))
    ap.add_argument("--sim-step-seconds", type=float, default=1.0,
                    help="virtual seconds per step (<= 0: real wall time)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--jsonl", default=None,
                    help="write per-step/-event metrics to this jsonl file")
    ap.add_argument("--quiet", action=argparse.BooleanOptionalAction,
                    default=False, help="suppress per-event stdout metrics")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="short self-checking run (used by CI)")
    return ap


def spec_from_args(args) -> "RunSpec":
    from ..ft.run import RunSpec
    pk = {}
    if args.process == "weibull" and args.process_param is not None:
        pk["shape"] = args.process_param
    if args.process == "lognormal" and args.process_param is not None:
        pk["sigma"] = args.process_param
    return RunSpec(
        arch=args.arch, reduce=args.reduce, layers=args.layers,
        d_model=args.d_model, batch=args.batch, seq=args.seq, lr=args.lr,
        seed=args.seed, total_steps=args.steps,
        strategy=args.strategy, pfs_every=args.pfs_every,
        use_buddy=args.buddy,
        step_s=(args.sim_step_seconds if args.sim_step_seconds > 0
                else None),
        mu_s=args.mtbf if args.inject_failures else float("inf"),
        C_s=args.ckpt_cost, R_s=args.recovery, D_s=args.downtime,
        C1_s=args.c1, R1_s=args.r1, q=args.q, omega=args.omega,
        process=args.process, process_kwargs=pk,
        profile=args.profile, ckpt_dir=args.ckpt_dir,
        compress=args.compress)


def _make_tracker(args):
    from ..ft.tracker import (CompositeTracker, JsonlTracker, NullTracker,
                              StdoutTracker)
    backends = []
    if args.jsonl:
        backends.append(JsonlTracker(args.jsonl))
    if not args.quiet:
        backends.append(StdoutTracker(kinds=("failure", "summary")))
    if not backends:
        return NullTracker()
    return backends[0] if len(backends) == 1 else CompositeTracker(*backends)


def _smoke():
    """CI leg: a short multilevel scaled-time run must finish all steps and
    land measured wall/energy near the model's prediction."""
    from ..ft.run import RunSpec, execute

    spec = RunSpec(arch="starcoder2-3b", layers=1, d_model=32, n_heads=2,
                   batch=2, seq=16, total_steps=120, step_s=1.0,
                   strategy="algo_t_ml", mu_s=15.0, C_s=1.5, R_s=1.5,
                   D_s=0.2, C1_s=0.3, R1_s=0.3, D1_s=0.1, q=0.15,
                   profile="paper_ml", seed=3)
    rep = execute(spec)
    if rep["final_step"] != spec.total_steps:
        raise SystemExit(f"FAIL: stopped at step {rep['final_step']}")
    print(f"PASS completed {rep['final_step']} steps with "
          f"{rep['n_failures']} failures ({rep['n_rollbacks']} rollbacks)")
    pred = rep["predicted"]
    for key in ("wall_ratio", "energy_ratio"):
        r = pred[key]
        if not 0.7 < r < 1.3:
            raise SystemExit(f"FAIL: {key} {r:.3f} outside [0.7, 1.3]")
    print(f"PASS measured/predicted wall {pred['wall_ratio']:.3f}, "
          f"energy {pred['energy_ratio']:.3f} (single seed, loose gate)")
    op = rep["operating_point"]
    if op["deep_every"] < 1 or op["period_steps"] < 1:
        raise SystemExit(f"FAIL: degenerate operating point {op}")
    print(f"PASS policy chose T={op['period_solved_s']:.2f}s, "
          f"m={op['deep_every']}, k={op['period_steps']} steps")
    return rep


def main(argv=None):
    from ..ft.run import execute

    args = build_parser().parse_args(argv)
    if args.smoke:
        return _smoke()
    spec = spec_from_args(args)
    report = execute(spec, tracker=_make_tracker(args))
    if report["losses"]:
        report["losses"] = [report["losses"][0], report["losses"][-1]]
    print(json.dumps(report, indent=1, default=str))
    return report


if __name__ == "__main__":
    main()
