"""Fault-tolerant training driver (the paper's technique end-to-end).

Builds an architecture (full or reduced), wires the FT trainer with the
checkpoint-period policy, failure injection and energy metering, runs, and
prints the measured-vs-predicted time/energy report.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --reduce \\
        --steps 300 --strategy algo_e --mtbf 120
"""
from __future__ import annotations

import argparse
import json
import tempfile

import jax

from ..configs import get_config, reduced
from ..core.policy import CheckpointPolicy, PolicyConfig
from ..data import for_arch
from ..ckpt import CheckpointManager, ManagerConfig, ShardedStore, StoreConfig
from ..energy import EnergyMeter, PAPER_EXASCALE_PROFILE, \
    TPU_V5E_HOST_PROFILE
from ..ft import (FailureInjector, FailureModel, FaultTolerantTrainer,
                  TrainerConfig)
from ..models import build
from ..optim import adamw


def make_trainer(args) -> FaultTolerantTrainer:
    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg, n_layers=args.layers, d_model=args.d_model,
                      n_heads=4)
    model = build(cfg)
    ocfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=20,
                             total_steps=args.steps)
    params = model.init(jax.random.key(args.seed))
    opt = adamw.init_state(params, ocfg)
    n_params = model.param_count()
    print(f"arch={cfg.name} params={n_params:,} "
          f"({n_params * 4 / 2**20:.0f} MiB f32)")

    profile = (PAPER_EXASCALE_PROFILE if args.profile == "paper"
               else TPU_V5E_HOST_PROFILE)
    policy = CheckpointPolicy(
        PolicyConfig(strategy=args.strategy, C_s=1.0, R_s=1.0, D_s=args.downtime,
                     mu_s=args.mtbf, omega=0.5),
        profile.power_params())
    store = ShardedStore(StoreConfig(root=args.ckpt_dir,
                                     compress=args.compress))
    manager = CheckpointManager(store, policy,
                                ManagerConfig(async_write=True))
    meter = EnergyMeter(profile)
    injector = FailureInjector(FailureModel(
        mu_s=args.mtbf if args.inject_failures else float("inf"),
        downtime_s=args.downtime, seed=args.seed))
    data = for_arch(cfg, batch=args.batch, seq_len=args.seq, seed=args.seed)
    step_fn = jax.jit(model.make_train_step(ocfg))
    return FaultTolerantTrainer(
        train_step=step_fn, state=(params, opt), data=data, policy=policy,
        manager=manager, meter=meter, failures=injector,
        config=TrainerConfig(total_steps=args.steps,
                             sim_seconds_per_step=args.sim_step_seconds))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--reduce", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--strategy", default="algo_t",
                    choices=["algo_t", "algo_e", "young", "daly",
                             "msk_energy", "fixed"])
    ap.add_argument("--mtbf", type=float, default=120.0,
                    help="platform MTBF in (sim) seconds")
    ap.add_argument("--downtime", type=float, default=1.0)
    ap.add_argument("--inject-failures", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="int8 blockwise checkpoint compression")
    ap.add_argument("--profile", default="paper", choices=["paper", "v5e"])
    ap.add_argument("--sim-step-seconds", type=float, default=1.0,
                    help="virtual seconds per step (None=wall)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.ckpt_dir is None:
        args.ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")

    trainer = make_trainer(args)
    report = trainer.run()
    report["losses"] = [report["losses"][0], report["losses"][-1]]
    print(json.dumps(report, indent=1, default=str))
    return report


if __name__ == "__main__":
    main()
