"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches JAX device state.  The dry-run forces 512
host devices via XLA_FLAGS before any JAX import; smoke tests and benchmarks
see the real single device.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5 exposes explicit axis types on the mesh
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto on every axis
    AxisType = None


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(n_devices: Optional[int] = None, *,
                   multi_pod: bool = False) -> Mesh:
    """A small mesh matching whatever host devices exist (unit tests)."""
    n = n_devices or len(jax.devices())
    if multi_pod:
        assert n % 2 == 0
        per_pod = n // 2
        d = _best_split(per_pod)
        return _mesh((2, d, per_pod // d), ("pod", "data", "model"))
    d = _best_split(n)
    return _mesh((d, n // d), ("data", "model"))


def _best_split(n: int) -> int:
    r = int(math.sqrt(n))
    while n % r:
        r -= 1
    return r


def _mesh(shape, axes) -> Mesh:
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)}; the "
            f"dry-run entry point must set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            f"BEFORE importing jax.")
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             devices=devs[:need],
                             axis_types=(AxisType.Auto,) * len(shape))
    return jax.make_mesh(shape, axes, devices=devs[:need])


#: TPU v5e hardware constants used by the roofline analysis (per chip).
TPU_V5E = {
    "peak_bf16_flops": 197e12,       # FLOP/s
    "hbm_bandwidth": 819e9,          # B/s
    "ici_link_bandwidth": 50e9,      # B/s per link
    "hbm_bytes": 16 * 2**30,         # 16 GiB
}
