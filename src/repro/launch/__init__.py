"""Launchers: mesh construction, multi-pod dry-run, training/serving drivers.

NOTE: ``dryrun`` is intentionally NOT imported here — it mutates XLA_FLAGS at
import time and must only be imported as the program entry point.
"""
from .mesh import make_production_mesh, make_test_mesh, TPU_V5E
