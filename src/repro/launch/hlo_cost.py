"""Loop-aware cost analysis of optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` visits every op ONCE — while-loop bodies
(jax.lax.scan over layers, microbatches, attention blocks...) are NOT
multiplied by their trip counts, undercounting FLOPs/bytes/collectives by
orders of magnitude for scanned models.  This walker parses the HLO module,
recovers loop trip counts from the loop-condition constants, and accumulates:

  * flops            — 2*M*N*K for every dot (batch dims included), x trips
  * hbm_bytes        — operand+result bytes of top-level ops per computation
                       (fusions counted as single ops = their HBM interface),
                       x trips
  * collective bytes — result bytes per collective kind, x trips

Heuristics (documented limits):
  * elementwise/transcendental FLOPs are ignored (dots dominate);
  * trip count = the unique scalar s32 constant in the loop condition
    (jax-lowered scans compare an induction variable against it);
  * bytes do not model buffer reuse within a computation.
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Dict, List, Optional

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((?:[^()]|\([^)]*\))*\)\s*->", )
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_ATTR_RE = re.compile(r"(\w+)=%?([\w\.\-]+)")


def _shapes_of(type_str: str) -> List[tuple]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, shape in _shapes_of(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    kind: str
    rest: str          # operand list + attributes (raw)

    @property
    def operands(self) -> List[str]:
        # operands live before the first "),": cut at the closing paren depth
        depth = 1
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    head = self.rest[:i]
                    break
        else:
            head = self.rest
        return _OPERAND_RE.findall(head)

    @property
    def attrs(self) -> dict:
        return dict(_ATTR_RE.findall(self.rest))


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    symtab: Dict[str, str]      # op name -> type string


@dataclasses.dataclass
class CostVec:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Optional[Counter] = None
    coll_counts: Optional[Counter] = None

    def __post_init__(self):
        self.coll_bytes = self.coll_bytes or Counter()
        self.coll_counts = self.coll_counts or Counter()

    def __iadd__(self, other: "CostVec"):
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        self.coll_bytes.update(other.coll_bytes)
        self.coll_counts.update(other.coll_counts)
        return self

    def scaled(self, k: float) -> "CostVec":
        return CostVec(self.flops * k, self.hbm_bytes * k,
                       Counter({a: b * k for a, b in self.coll_bytes.items()}),
                       Counter({a: b * k for a, b in
                                self.coll_counts.items()}))


def parse_module(txt: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in txt.splitlines():
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(name=m.group(2), ops=[], symtab={})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(name=m.group(1), type_str=m.group(2), kind=m.group(3),
                    rest=m.group(4))
            cur.ops.append(op)
            cur.symtab[op.name] = op.type_str
    return comps


def _dot_flops(op: Op, symtab: Dict[str, str]) -> float:
    shapes = _shapes_of(op.type_str)
    if not shapes:
        return 0.0
    out_elems = 1
    for d in shapes[0][1]:
        out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contract = 1
    if m:
        lhs_name = op.operands[0] if op.operands else None
        lhs_type = symtab.get(lhs_name, "")
        lhs_shapes = _shapes_of(lhs_type)
        if lhs_shapes:
            lhs_shape = lhs_shapes[0][1]
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(lhs_shape):
                    contract *= lhs_shape[idx]
    return 2.0 * out_elems * contract


def _trip_count(cond: Computation) -> int:
    consts = [int(v) for v in re.findall(r"s32\[\]\s+constant\((\d+)\)",
                                         "\n".join(o.type_str + " constant(" +
                                                   "" for o in []))]
    # simpler: scan raw ops for s32[] constant(N)
    consts = []
    for op in cond.ops:
        if op.kind == "constant" and op.type_str.startswith("s32[]"):
            m = re.match(r"\s*(\d+)", op.rest)
            if m:
                consts.append(int(m.group(1)))
    if not consts:
        return 1
    return max(consts)


def analyze(txt: str) -> CostVec:
    comps = parse_module(txt)
    entry = None
    for line in txt.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line.strip())
            if m:
                entry = m.group(2)
            break
    if entry is None or entry not in comps:
        # fall back: the largest computation
        entry = max(comps, key=lambda c: len(comps[c].ops))

    memo: Dict[str, CostVec] = {}

    def flops_only(cname: str) -> float:
        """dot flops of a computation including nested calls (no trip x)."""
        c = comps.get(cname)
        if c is None:
            return 0.0
        total = 0.0
        for op in c.ops:
            if op.kind == "dot":
                total += _dot_flops(op, c.symtab)
            elif op.kind in ("fusion", "call"):
                t = op.attrs.get("calls") or op.attrs.get("to_apply")
                if t and t != cname:
                    total += flops_only(t)
        return total

    _TRANSPARENT = ("bitcast", "bitcast-convert", "reshape", "copy",
                    "transpose")

    def _param_touch_bytes(comp: Computation, param_index: int,
                           full_bytes: int) -> float:
        """Bytes a fusion actually reads from its param: when the parameter
        (followed through bitcast/reshape aliases) is consumed ONLY by
        (dynamic-)slice / dynamic-update-slice ops, charge the slice sizes —
        the idiom of scan-stacked weights and residual accumulators — else
        the full operand."""
        pname = None
        for o in comp.ops:
            if o.kind == "parameter" and \
                    (o.rest or "").strip().startswith(f"{param_index})"):
                pname = o.name
                break
        if pname is None:
            return full_bytes
        alias = {pname}
        for o in comp.ops:     # ops are in definition order
            if o.kind in _TRANSPARENT and any(x in alias
                                              for x in o.operands):
                alias.add(o.name)
        touched = 0
        only_slices = True
        for o in comp.ops:
            if o.name in alias:
                continue
            if any(x in alias for x in o.operands):
                if o.kind in ("dynamic-slice", "slice"):
                    touched += _bytes_of(o.type_str)
                elif o.kind == "dynamic-update-slice":
                    # read+write of the inserted slice only
                    upd = o.operands[1] if len(o.operands) > 1 else None
                    touched += 2 * _bytes_of(comp.symtab.get(upd, ""))
                else:
                    only_slices = False
                    break
        return touched if (only_slices and touched) else full_bytes

    _PURE_CONVERT = frozenset(("parameter", "constant", "convert", "bitcast",
                               "bitcast-convert", "copy", "reshape",
                               "transpose", "broadcast",
                               "get-tuple-element", "tuple"))

    def _is_pure_convert(comp: Optional[Computation]) -> bool:
        """Fusions that only change dtype/layout: CPU bf16-dot legalization
        artifacts — native (free) on the TPU target, charged 0."""
        if comp is None:
            return False
        kinds = {o.kind for o in comp.ops}
        return "convert" in kinds and kinds <= _PURE_CONVERT

    def _fusion_result_bytes(comp: Optional[Computation],
                             full_bytes: int) -> float:
        """A fusion whose root is a dynamic-update-slice writes only the
        inserted slice (in-place buffer semantics), not the whole result."""
        if comp is None:
            return full_bytes
        dus = [o for o in comp.ops if o.kind == "dynamic-update-slice"]
        if not dus:
            return full_bytes
        upd_bytes = sum(_bytes_of(comp.symtab.get(
            o.operands[1] if len(o.operands) > 1 else "", "")) for o in dus)
        return min(full_bytes, upd_bytes) if upd_bytes else full_bytes

    def walk(cname: str) -> CostVec:
        if cname in memo:
            return memo[cname]
        memo[cname] = CostVec()      # cycle guard
        c = comps.get(cname)
        if c is None:
            return memo[cname]
        cost = CostVec()
        for op in c.ops:
            if op.kind == "dot":
                cost.flops += _dot_flops(op, c.symtab)
                cost.hbm_bytes += _bytes_of(op.type_str) + sum(
                    _bytes_of(c.symtab.get(o, "")) for o in op.operands)
            elif op.kind == "while":
                body = op.attrs.get("body")
                cond = op.attrs.get("condition")
                trips = _trip_count(comps[cond]) if cond in comps else 1
                inner = walk(body) if body else CostVec()
                cost += inner.scaled(max(trips, 1))
            elif op.kind in ("fusion", "call"):
                t = op.attrs.get("calls") or op.attrs.get("to_apply")
                # fusion HBM interface: result + what it actually READS of
                # each operand (slice-only params charge slice bytes; a
                # DUS-rooted fusion writes only the inserted slice)
                tc = comps.get(t) if t else None
                if not _is_pure_convert(tc):
                    cost.hbm_bytes += _fusion_result_bytes(
                        tc, _bytes_of(op.type_str))
                    for i, o in enumerate(op.operands):
                        full = _bytes_of(c.symtab.get(o, ""))
                        if tc is not None:
                            cost.hbm_bytes += _param_touch_bytes(tc, i, full)
                        else:
                            cost.hbm_bytes += full
                if t:
                    inner = walk(t)
                    cost.flops += inner.flops
                    cost.coll_bytes.update(inner.coll_bytes)
                    cost.coll_counts.update(inner.coll_counts)
            elif op.kind == "conditional":
                for t in re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                    r"true_computation=%?([\w\.\-]+)|"
                                    r"false_computation=%?([\w\.\-]+))",
                                    op.rest):
                    for name in t:
                        for b in name.split(","):
                            b = b.strip().lstrip("%")
                            if b:
                                cost += walk(b)
            elif op.kind in _COLLECTIVES:
                b = _bytes_of(op.type_str)
                cost.coll_bytes[op.kind] += b
                cost.coll_counts[op.kind] += 1
                cost.hbm_bytes += 2 * b
            elif op.kind == "dynamic-slice":
                cost.hbm_bytes += 2 * _bytes_of(op.type_str)
            elif op.kind == "dynamic-update-slice":
                upd = op.operands[1] if len(op.operands) > 1 else None
                cost.hbm_bytes += 2 * _bytes_of(c.symtab.get(upd, ""))
            elif op.kind in ("gather", "slice"):
                cost.hbm_bytes += 2 * _bytes_of(op.type_str)
            elif op.kind == "scatter":
                upd = op.operands[2] if len(op.operands) > 2 else None
                cost.hbm_bytes += 2 * _bytes_of(c.symtab.get(upd, ""))
            elif op.kind == "copy":
                # while-carry copies are CPU-backend double buffering (TPU
                # buffer assignment aliases loop carries in place): skip
                pass
            elif op.kind in ("sort", "concatenate", "convert", "transpose",
                             "reduce", "pad"):
                cost.hbm_bytes += _bytes_of(op.type_str) + sum(
                    _bytes_of(c.symtab.get(o, "")) for o in op.operands)
        memo[cname] = cost
        return cost

    return walk(entry)


def analyze_compiled(fn, *args, **kwargs) -> CostVec:
    """Walk the optimized HLO of ``fn`` compiled for ``*args``.

    Convenience wrapper for live programs (the roofline bench points it at
    the sweep/engine grid functions): jit -> lower -> compile -> as_text,
    then :func:`analyze` on the resulting post-optimization module.  ``fn``
    may already be jitted (``jax.jit`` of a jitted fn is a no-op wrapper).
    The jax import stays local — everything else in this module is pure
    stdlib text analysis and must stay importable without jax.
    """
    import jax
    compiled = jax.jit(fn).lower(*args, **kwargs).compile()
    return analyze(compiled.as_text())
