"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and extract roofline inputs.

MUST be the very first lines — before ANY other import (jax locks the device
count on first init):
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse            # noqa: E402
import dataclasses         # noqa: E402
import json                # noqa: E402
import re                  # noqa: E402
import time                # noqa: E402
import traceback           # noqa: E402
from collections import Counter    # noqa: E402
from pathlib import Path   # noqa: E402

import jax                 # noqa: E402

from ..configs import ALL_ARCHS, get_config           # noqa: E402
from ..configs.base import SHAPES, ArchConfig, ShapeConfig  # noqa: E402
from ..models import build, input_specs               # noqa: E402
from ..models.spec import abstract_tree               # noqa: E402
from ..optim import adamw                             # noqa: E402
from ..parallel import sharding as shd                # noqa: E402
from .mesh import make_production_mesh, TPU_V5E       # noqa: E402
from . import hlo_cost                                # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" \
    / "dryrun"

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches every `dtype[d0,d1,...]` group in an HLO result type
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\([^)]*\)|\w+\[[\d,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective result bytes (per-device) summed from optimized HLO."""
    out = Counter()
    counts = Counter()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        kind = m.group(2)
        if kind.endswith("-done"):
            continue
        out[kind] += _type_bytes(m.group(1))
        counts[kind] += 1
    return {"bytes_by_type": dict(out), "counts_by_type": dict(counts),
            "total_bytes": int(sum(out.values()))}


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def production_config(name: str, *, serving: bool = False) -> ArchConfig:
    """Arch config with production numerics: padded heads for TP=16; serving
    casts parameters to bf16 (halves weight memory, standard practice)."""
    cfg = dataclasses.replace(get_config(name), head_pad_multiple=16,
                              param_dtype="bfloat16")
    if cfg.name == "llama4-scout-17b-a16e":
        # top-1 routing: per-row capacity MoE (GShard groups = rows) is the
        # production path — the dense all-experts path computes 16x the
        # active FLOPs and its transients do not fit HBM at train_4k.
        # Exception: prefill_32k uses the dense path — the capacity combine
        # needs (B,E,C,d)-scale buffers that the CPU backend's bf16-matmul
        # legalization inflates to f32; with no optimizer state resident the
        # dense path fits comfortably (documented in EXPERIMENTS.md §Perf).
        cfg = dataclasses.replace(cfg, moe_impl="capacity")
    if serving:
        # int8 KV cache: halves cache memory vs bf16 (standard serving
        # practice) and keeps the cache out of XLA-CPU's bf16->f32 float
        # normalization of while-loop carries.
        cfg = dataclasses.replace(cfg, remat="none", kv_cache_dtype="int8")
    return cfg


def _dp_size(mesh) -> int:
    n = 1
    for ax in ("pod", "data"):
        n *= mesh.shape.get(ax, 1)
    return n


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """(callable, example_args, donate) for one dry-run cell."""
    model = build(cfg)
    pspec = model.param_spec()
    params_abs = abstract_tree(pspec, mesh)

    if shape.kind == "train":
        # >100B params on 16 GiB chips: bf16-native params without an f32
        # master copy (Gopher-style) — the f32 master alone would be 2 GiB+
        # per chip.  Smaller archs keep the f32 master.
        opt_cfg = adamw.AdamWConfig(
            factored_second_moment=True, momentum_dtype="bfloat16",
            master_weights=cfg.param_count() < 100e9)
        opt_abs = abstract_tree(adamw.state_spec(pspec, opt_cfg), mesh)
        batch_abs = input_specs(cfg, shape, mesh)
        dp = _dp_size(mesh)
        k = max(1, shape.global_batch
                // (dp * cfg.microbatch_rows_per_device))
        step = model.make_train_step(
            opt_cfg, microbatches=k,
            accum_dtype="bfloat16" if k >= 8 else "float32")
        return step, (params_abs, opt_abs, batch_abs), (0, 1)

    if shape.kind == "prefill":
        batch_abs = input_specs(cfg, shape, mesh)

        def prefill(params, batch):
            return model.prefill(params, batch, max_cache_seq=shape.seq_len)
        return prefill, (params_abs, batch_abs), ()

    # decode
    inp = input_specs(cfg, shape, mesh)

    def serve_step(params, cache, token):
        return model.decode_step(params, cache, token)
    return serve_step, (params_abs, inp["cache"], inp["token"]), (1,)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             save: bool = True) -> dict:
    """Lower+compile one cell; returns the roofline-input record."""
    shape = SHAPES[shape_name]
    cfg = production_config(arch, serving=shape.kind != "train")
    if arch == "llama4-scout-17b-a16e" and shape_name == "prefill_32k":
        # waves must keep the per-wave batch divisible by the DP degree
        dp = 32 if multi_pod else 16
        waves = 2 if (shape.global_batch // 2) % dp == 0 else 1
        cfg = dataclasses.replace(cfg, moe_impl="dense", prefill_waves=waves)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = len(mesh.devices.reshape(-1))
    t0 = time.time()

    fn, args, donate = build_cell(cfg, shape, mesh)
    with shd.use_mesh(mesh):
        jitted = jax.jit(fn, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        txt = compiled.as_text()
    coll = collective_bytes(txt)
    walked = hlo_cost.analyze(txt)

    model = build(cfg)
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "param_count": model.param_count(),
        "active_param_count": cfg.active_param_count(),
        # XLA cost_analysis (loop bodies counted ONCE — kept for reference)
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_accessed_per_device": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
        "collectives": coll,
        # loop-aware HLO walk (launch/hlo_cost.py) — the roofline inputs
        "walked": {
            "flops_per_device": walked.flops,
            "hbm_bytes_per_device": walked.hbm_bytes,
            "coll_bytes_by_type": dict(walked.coll_bytes),
            "coll_counts_by_type": dict(walked.coll_counts),
            "coll_bytes_total": float(sum(walked.coll_bytes.values())),
        },
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_est": (ma.argument_size_in_bytes
                               + ma.output_size_in_bytes
                               + ma.temp_size_in_bytes
                               - ma.alias_size_in_bytes),
        },
        "hbm_per_chip": TPU_V5E["hbm_bytes"],
        "timings_s": {"lower": round(t_lower, 2),
                      "compile": round(t_compile, 2)},
    }
    record["fits_hbm"] = bool(
        record["memory"]["peak_bytes_est"] <= TPU_V5E["hbm_bytes"])
    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        path = RESULTS_DIR / f"{arch}__{shape_name}__{record['mesh']}.json"
        path.write_text(json.dumps(record, indent=1))
    return record


def all_cells(multi_pod: bool = False):
    for cfg in ALL_ARCHS:
        for shape in cfg.applicable_shapes():
            yield cfg.name, shape.name, multi_pod


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one architecture id")
    ap.add_argument("--shape", default=None, help="one shape name")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 (512 chips) instead of 16x16")
    ap.add_argument("--all", action="store_true",
                    help="every applicable (arch x shape) on this mesh")
    ap.add_argument("--both-meshes", action="store_true",
                    help="with --all: run single-pod AND multi-pod")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--print-hlo-stats", action="store_true")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        cells += list(all_cells(multi_pod=args.multi_pod or False))
        if args.both_meshes:
            cells += list(all_cells(multi_pod=True))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape, args.multi_pod)]

    failures = []
    for arch, shape, mp in cells:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        out = RESULTS_DIR / f"{arch}__{shape}__{mesh_name}.json"
        if args.skip_existing and out.exists():
            print(f"[skip] {arch} x {shape} x {mesh_name}")
            continue
        try:
            t0 = time.time()
            rec = run_cell(arch, shape, multi_pod=mp)
            print(f"[ok]   {arch} x {shape} x {mesh_name}: "
                  f"flops/dev={rec['flops_per_device']:.3e} "
                  f"coll={rec['collectives']['total_bytes']:.3e}B "
                  f"peak={rec['memory']['peak_bytes_est']/2**30:.2f}GiB "
                  f"fits={rec['fits_hbm']} ({time.time()-t0:.0f}s)")
        except Exception as e:   # noqa: BLE001 — report and continue
            failures.append((arch, shape, mesh_name, repr(e)))
            print(f"[FAIL] {arch} x {shape} x {mesh_name}: {e!r}")
            traceback.print_exc(limit=3)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nAll dry-run cells compiled successfully.")


if __name__ == "__main__":
    main()
