"""Attention: GQA projections + memory-bounded softmax attention.

Layout decisions (TPU/GSPMD, see DESIGN.md):

* **Flat padded heads.** q/k/v use a flat head axis padded to the TP multiple
  (``cfg.head_pad_multiple``, 16 on the production mesh) so the head axis
  always shards evenly (JAX rejects uneven shardings).  Padded heads carry
  zero projections and are output-masked, so they are exactly inert; the
  waste is visible in the roofline useful-FLOP ratio (deepseek 56->64,
  llama4 40->48, starcoder2 24->32, whisper 6->16).
* **KV stored un-expanded** ``(B, S, Hkv, Dh)`` (replicated over model — kv
  heads are few), expanded on the fly to the padded flat layout, sharded.
* **Banded attention** for sliding-window / chunked-local masks: scan over q
  blocks, each attending one statically-sliced KV band -> O(S*band) FLOPs and
  O(qb*band) memory.
* **Online-softmax attention** (flash-style running max/denominator scan over
  KV blocks) for full attention -> O(qb*kb) memory at O(S^2) FLOPs.
* **Decode** uses the grouped (un-expanded) einsum against a KV cache whose
  sequence axis is sharded over "model" (flash-decoding: GSPMD turns the
  softmax reductions into cross-device collectives).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import constrain
from .layers import rope
from .spec import ParamSpec

NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# Parameter spec
# ---------------------------------------------------------------------------

def padded_heads(cfg) -> int:
    m = getattr(cfg, "head_pad_multiple", 1) or 1
    return ((cfg.n_heads + m - 1) // m) * m


def attn_spec(cfg, cross: bool = False) -> dict:
    d, dh = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = padded_heads(cfg), cfg.n_kv_heads
    dt = cfg.param_dtype
    return {
        "wq": ParamSpec((d, hq, dh), ("embed", "heads", "head_dim"), dt),
        "wk": ParamSpec((d, hkv, dh), ("embed", "kv_heads", "head_dim"), dt),
        "wv": ParamSpec((d, hkv, dh), ("embed", "kv_heads", "head_dim"), dt),
        "wo": ParamSpec((hq, dh, d), ("heads", "head_dim", "embed"), dt),
    }


def _head_map(cfg) -> jnp.ndarray:
    """flat (padded) q-head index -> kv head index (pads clamp to last)."""
    g = cfg.n_heads // cfg.n_kv_heads
    idx = jnp.arange(padded_heads(cfg)) // g
    return jnp.minimum(idx, cfg.n_kv_heads - 1)


def _head_mask(cfg) -> jnp.ndarray:
    return (jnp.arange(padded_heads(cfg)) < cfg.n_heads)


def expand_kv(cfg, kv: jax.Array) -> jax.Array:
    """(B, S, Hkv, Dh) -> (B, S, Hq_pad, Dh) via per-head gather."""
    out = jnp.take(kv, _head_map(cfg), axis=2)
    return constrain(out, ("batch", "seq", "heads", "head_dim"))


# ---------------------------------------------------------------------------
# Core attention math (flat layout: q/k/v all (B, S, H, Dh))
# ---------------------------------------------------------------------------

def _block_mask(mode: str, jq: jax.Array, jk: jax.Array, window: int,
                chunk: int) -> jax.Array:
    """(len(jq), len(jk)) boolean allow-mask from absolute positions."""
    q = jq[:, None]
    k = jk[None, :]
    if mode == "bidir":
        return jnp.ones((jq.shape[0], jk.shape[0]), dtype=bool)
    m = k <= q                                   # causal
    if mode == "sliding":
        m &= k > q - window
    elif mode == "chunked":
        m &= (k // chunk) == (q // chunk)
    return m


def _largest_divisor_leq(n: int, cap: int) -> int:
    d = min(n, cap)
    while n % d:
        d -= 1
    return d


def attention_online(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     mode: str = "causal", window: int = 0, chunk: int = 0,
                     q_offset: int = 0, qb: int = 512,
                     kb: int = 1024) -> jax.Array:
    """Flash-style online-softmax attention, O(qb*kb) live score memory:
    outer scan over q blocks, inner scan over KV blocks with running
    max/denominator.  Block sizes snap to divisors of the (possibly
    non-power-of-2) sequence lengths (whisper 1500 frames, VLM 4096+256).

    q: (B, Sq, H, Dh); k/v: (B, Skv, H, Dh).  Returns (B, Sq, H, Dh).
    """
    B, Sq, H, Dh = q.shape
    Skv = k.shape[1]
    kb = _largest_divisor_leq(Skv, kb)
    qb = _largest_divisor_leq(Sq, qb)
    nk = Skv // kb
    nq = Sq // qb
    scale = Dh ** -0.5

    # operands stay in input dtype with f32 einsum accumulation: whole-tensor
    # f32 converts inside the scan get hoisted by XLA into full K/V copies
    qbl = (q * jnp.asarray(scale, q.dtype)).reshape(
        B, nq, qb, H, Dh).transpose(1, 0, 3, 2, 4)          # (nq,B,H,qb,Dh)
    kbl = k.reshape(B, nk, kb, H, Dh).transpose(1, 0, 3, 2, 4)
    vbl = v.reshape(B, nk, kb, H, Dh).transpose(1, 0, 3, 2, 4)

    def q_block(_, xs):
        i, qf = xs                                          # qf (B,H,qb,Dh)
        jq = q_offset + i * qb + jnp.arange(qb)

        def kv_step(carry, ys):
            m, l, acc = carry
            kj, vj, j = ys
            jk = j * kb + jnp.arange(kb)
            s = jnp.einsum("bhqd,bhkd->bhqk", qf, kj,
                           preferred_element_type=jnp.float32)
            allow = _block_mask(mode, jq, jk, window, chunk)
            s = jnp.where(allow[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(allow[None, None], p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, qb), jnp.float32)
        a0 = jnp.zeros((B, H, qb, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kbl, vbl, jnp.arange(nk)))
        return None, acc / jnp.maximum(l, 1e-30)[..., None]

    _, blocks = jax.lax.scan(q_block, None, (jnp.arange(nq), qbl))
    # blocks: (nq, B, H, qb, Dh) -> (B, Sq, H, Dh)
    out = blocks.transpose(1, 0, 3, 2, 4).reshape(B, Sq, H, Dh)
    return out.astype(q.dtype)


def attention_banded(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     mode: str, window: int = 0, chunk: int = 0,
                     qb: int = 512) -> jax.Array:
    """Banded attention for sliding/chunked masks: scan over q blocks, one
    statically-sliced KV band per block -> O(S*band) FLOPs.

    q: (B, Sq, H, Dh); k/v: (B, Skv, H, Dh) with Skv == Sq (self-attention).
    """
    import math as _math
    B, Sq, H, Dh = q.shape
    Skv = k.shape[1]
    qb = _math.gcd(min(qb, Sq), Sq)
    if mode == "chunked":
        qb = _math.gcd(qb, chunk)       # q blocks must not straddle chunks
        band = min(chunk, Skv)
    elif mode == "sliding":
        band = min(window + qb, Skv)
    else:
        raise ValueError(mode)
    nq = Sq // qb
    scale = Dh ** -0.5

    qbl = q.reshape(B, nq, qb, H, Dh).transpose(1, 0, 3, 2, 4)  # (nq,B,H,qb,Dh)
    kf = k.transpose(0, 2, 1, 3)   # (B,H,Skv,Dh)
    vf = v.transpose(0, 2, 1, 3)

    def block(i, qi):
        q_start = i * qb
        if mode == "sliding":
            start = jnp.clip(q_start + qb - band, 0, Skv - band)
        else:  # chunked: the band is the chunk containing this q block
            start = jnp.clip((q_start // max(chunk, 1)) * max(chunk, 1),
                             0, Skv - band)
        ki = jax.lax.dynamic_slice_in_dim(kf, start, band, axis=2)
        vi = jax.lax.dynamic_slice_in_dim(vf, start, band, axis=2)
        jq = q_start + jnp.arange(qb)
        jk = start + jnp.arange(band)
        s = jnp.einsum("bhqd,bhkd->bhqk", qi * jnp.asarray(scale, qi.dtype),
                       ki, preferred_element_type=jnp.float32)
        allow = _block_mask(mode, jq, jk, window, chunk)
        s = jnp.where(allow[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", p.astype(vi.dtype), vi,
                          preferred_element_type=jnp.float32)

    def step(_, xs):
        i, qi = xs
        return None, block(i, qi)

    _, blocks = jax.lax.scan(step, None, (jnp.arange(nq), qbl))
    # blocks: (nq, B, H, qb, Dh) -> (B, Sq, H, Dh)
    out = blocks.transpose(1, 0, 3, 2, 4).reshape(B, Sq, H, Dh)
    return out.astype(q.dtype)


def attention(q, k, v, *, mode: str, window: int = 0, chunk: int = 0,
              q_offset: int = 0) -> jax.Array:
    """Dispatch: banded for sliding/chunked (when the band is a real subset),
    online-softmax otherwise."""
    Skv = k.shape[1]
    if mode == "sliding" and window < Skv:
        return attention_banded(q, k, v, mode="sliding", window=window)
    if mode == "chunked" and chunk < Skv:
        return attention_banded(q, k, v, mode="chunked", chunk=chunk)
    eff = "bidir" if mode == "bidir" else "causal"
    return attention_online(q, k, v, mode=eff, q_offset=q_offset)


# ---------------------------------------------------------------------------
# Decode (single new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention(cfg, q1: jax.Array, ck: jax.Array, cv: jax.Array,
                     slot_pos: jax.Array, pos: jax.Array, *,
                     mode: str, window: int = 0, chunk: int = 0) -> jax.Array:
    """q1: (B, 1, Hq_pad, Dh); cache ck/cv: (B, Sc, Hkv, Dh);
    slot_pos: (Sc,) absolute position per cache slot (-1 = empty);
    pos: scalar current position.  Returns (B, 1, Hq_pad, Dh).

    Grouped einsum (no KV expansion — decode FLOPs are tiny, cache memory is
    not).  With the cache sequence sharded over "model", GSPMD lowers the max
    / sum reductions to cross-device collectives = flash-decoding.
    """
    B, _, Hq, Dh = q1.shape
    Hkv = ck.shape[2]
    G = Hq // Hkv if Hq % Hkv == 0 else None
    scale = Dh ** -0.5
    allow = (slot_pos >= 0) & (slot_pos <= pos)
    if mode == "sliding":
        allow &= slot_pos > pos - window
    elif mode == "chunked":
        allow &= (slot_pos // chunk) == (pos // chunk)

    if G is not None:
        qg = q1.reshape(B, Hkv, G, Dh) * jnp.asarray(scale, q1.dtype)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, ck,
                       preferred_element_type=jnp.float32)
        s = jnp.where(allow[None, None, None], s, NEG_INF)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        p = jnp.where(allow[None, None, None], p, 0.0)
        l = p.sum(axis=-1, keepdims=True)
        o = jnp.einsum("bkgs,bskd->bkgd", p.astype(cv.dtype), cv,
                       preferred_element_type=jnp.float32)
        out = (o / jnp.maximum(l, 1e-30)).reshape(B, 1, Hq, Dh)
    else:
        kmap = _head_map(cfg)
        ke = jnp.take(ck, kmap, axis=2)       # (B,Sc,Hq,Dh)
        ve = jnp.take(cv, kmap, axis=2)
        qf = q1[:, 0] * jnp.asarray(scale, q1.dtype)
        s = jnp.einsum("bhd,bshd->bhs", qf, ke,
                       preferred_element_type=jnp.float32)
        s = jnp.where(allow[None, None], s, NEG_INF)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.where(allow[None, None], jnp.exp(s - m), 0.0)
        l = p.sum(axis=-1, keepdims=True)
        o = jnp.einsum("bhs,bshd->bhd", p.astype(ve.dtype), ve,
                       preferred_element_type=jnp.float32)
        out = (o / jnp.maximum(l, 1e-30))[:, None]
    return out.astype(q1.dtype)


# ---------------------------------------------------------------------------
# Full multi-head layer (projections + rope + core + output)
# ---------------------------------------------------------------------------

class KVCacheLayer(NamedTuple):
    k: jax.Array          # (B, Sc, Hkv, Dh)
    v: jax.Array
    # slot_pos & pos live once per cache, not per layer


def project_qkv(cfg, p: dict, x: jax.Array, positions, *,
                use_rope: bool, compute_dtype):
    """x: (B,S,d) -> q (B,S,Hq_pad,Dh), k/v (B,S,Hkv,Dh)."""
    cd = compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", "seq", "heads", "head_dim"))
    return q, k, v


def output_proj(cfg, p: dict, out: jax.Array, compute_dtype) -> jax.Array:
    out = out * _head_mask(cfg)[None, None, :, None].astype(out.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(compute_dtype))
    return constrain(y, ("batch", "seq", "act_embed"))


def self_attention(cfg, p: dict, x: jax.Array, positions, *,
                   mode: str, use_rope: bool, compute_dtype,
                   window: int = 0, chunk: int = 0):
    """Training/prefill self-attention.  Returns (y, (k, v)) — the raw KV for
    cache construction during prefill."""
    q, k, v = project_qkv(cfg, p, x, positions, use_rope=use_rope,
                          compute_dtype=compute_dtype)
    ke, ve = expand_kv(cfg, k), expand_kv(cfg, v)
    out = attention(q, ke, ve, mode=mode, window=window, chunk=chunk)
    return output_proj(cfg, p, out, compute_dtype), (k, v)


def cross_kv(cfg, p: dict, enc_out: jax.Array, compute_dtype):
    """Project encoder output (B, F, d) to un-expanded cross K/V."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(compute_dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(compute_dtype))
    return k, v


def cross_attention(cfg, p: dict, x: jax.Array, enc_out: jax.Array,
                    compute_dtype):
    """Decoder->encoder attention (whisper).  Returns (y, (k, v)) with the
    un-expanded cross K/V for cache construction."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(compute_dtype))
    q = constrain(q, ("batch", "seq", "heads", "head_dim"))
    k, v = cross_kv(cfg, p, enc_out, compute_dtype)
    out = attention(q, expand_kv(cfg, k), expand_kv(cfg, v), mode="bidir")
    return output_proj(cfg, p, out, compute_dtype), (k, v)
