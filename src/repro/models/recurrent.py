"""Recurrent sequence-mixing blocks: RG-LRU (RecurrentGemma), mLSTM and sLSTM
(xLSTM).  All recurrences run in float32; linear recurrences use
``jax.lax.associative_scan`` (log-depth), the non-linear sLSTM uses
``lax.scan``; mLSTM uses the chunkwise-parallel form (quadratic inside a
chunk, recurrent across chunks) so training memory stays bounded.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..parallel.sharding import constrain
from .layers import act_fn
from .spec import ParamSpec

LRU_C = 8.0          # RG-LRU decay exponent constant (RecurrentGemma)


# ===========================================================================
# RG-LRU
# ===========================================================================

def _lru_blocks(cfg):
    """Block-diagonal gate structure (RecurrentGemma: per-head blocks).
    Blocks align with the model-axis sharding of the LRU width, keeping the
    gate einsums device-local (a dense W x W gate would all-gather the full
    (B, S, W) activation every recurrent layer)."""
    w = cfg.lru_width or cfg.d_model
    nb = cfg.n_heads
    while w % nb:
        nb //= 2
    return nb, w // nb


def rglru_spec(cfg) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    cw = cfg.conv_width
    dt = cfg.param_dtype
    nb, wb = _lru_blocks(cfg)
    return {
        "in_x": ParamSpec((d, w), ("embed", "lru"), dt),
        "in_y": ParamSpec((d, w), ("embed", "lru"), dt),
        "conv_w": ParamSpec((cw, w), ("conv", "lru"), dt),
        "conv_b": ParamSpec((w,), ("lru",), dt, init="zeros"),
        "gate_a": ParamSpec((nb, wb, wb), ("lru_blocks", None, None), dt),
        "gate_a_b": ParamSpec((w,), ("lru",), dt, init="zeros"),
        "gate_x": ParamSpec((nb, wb, wb), ("lru_blocks", None, None), dt),
        "gate_x_b": ParamSpec((w,), ("lru",), dt, init="zeros"),
        "lamb": ParamSpec((w,), ("lru",), dt, init="lambda_lru"),
        "out": ParamSpec((w, d), ("lru", "embed"), dt),
    }


class RGLRUState(NamedTuple):
    h: jax.Array          # (B, w) recurrent state, f32
    conv: jax.Array       # (B, conv_width - 1, w) conv tail


def rglru_zero_state(cfg, batch: int, dtype=jnp.float32) -> RGLRUState:
    w = cfg.lru_width or cfg.d_model
    return RGLRUState(h=jnp.zeros((batch, w), dtype),
                      conv=jnp.zeros((batch, cfg.conv_width - 1, w), dtype))


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: Optional[jax.Array] = None):
    """Depthwise causal conv along time.  x: (B, S, w); w: (cw, w)."""
    cw = w.shape[0]
    if tail is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)           # (B, S+cw-1, w)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None]
              for i in range(cw))
    new_tail = xp[:, -(cw - 1):] if cw > 1 else None
    return out + b[None, None], new_tail


def _rglru_core(p, xw: jax.Array, h0: jax.Array):
    """The RG-LRU recurrence.  xw: (B, S, w) f32; h0: (B, w) f32.

    Gates are block-diagonal per head (RecurrentGemma), computed with a
    batched per-block einsum — fully local when blocks align with the
    model-axis sharding of W."""
    B, S, W = xw.shape
    nb, wb, _ = p["gate_a"].shape
    x4 = constrain(xw.reshape(B, S, nb, wb),
                   ("batch", "seq", "lru_blocks", None))
    r = jax.nn.sigmoid(
        jnp.einsum("bshw,hwv->bshv", x4,
                   p["gate_a"].astype(jnp.float32)).reshape(B, S, W)
        + p["gate_a_b"].astype(jnp.float32))
    i = jax.nn.sigmoid(
        jnp.einsum("bshw,hwv->bshv", x4,
                   p["gate_x"].astype(jnp.float32)).reshape(B, S, W)
        + p["gate_x_b"].astype(jnp.float32))
    log_a = -LRU_C * jax.nn.softplus(p["lamb"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_x = i * xw
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * gated_x

    # h_t = a_t h_{t-1} + b_t  via associative scan over time, seeded with h0
    # by folding h0 into the first step: b_0' = a_0 h0 + b_0.
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def rglru_block(cfg, p: dict, x: jax.Array, compute_dtype,
                state: Optional[RGLRUState] = None):
    """Full RG-LRU temporal block: in-proj, causal conv, recurrence, gated out.

    x: (B, S, d).  Returns (y, new_state).
    """
    B, S, d = x.shape
    cd = compute_dtype
    y_branch = act_fn("gelu")(jnp.einsum("bsd,dw->bsw", x,
                                         p["in_y"].astype(cd)))
    xw = jnp.einsum("bsd,dw->bsw", x, p["in_x"].astype(cd))
    xw = constrain(xw, ("batch", "seq", "lru"))
    tail = state.conv if state is not None else None
    xw, new_tail = _causal_conv(xw, p["conv_w"].astype(cd),
                                p["conv_b"].astype(cd), tail)
    h0 = (state.h if state is not None
          else jnp.zeros((B, xw.shape[-1]), jnp.float32))
    h, h_last = _rglru_core(p, xw.astype(jnp.float32), h0)
    h = constrain(h.astype(cd), ("batch", "seq", "lru"))
    out = jnp.einsum("bsw,wd->bsd", h * y_branch, p["out"].astype(cd))
    new_state = RGLRUState(
        h=h_last,
        conv=(new_tail.astype(jnp.float32) if new_tail is not None
              else jnp.zeros((B, 0, xw.shape[-1]), jnp.float32)))
    return constrain(out, ("batch", "seq", "act_embed")), new_state


# ===========================================================================
# mLSTM (chunkwise-parallel matrix memory)
# ===========================================================================

def mlstm_spec(cfg) -> dict:
    d = cfg.d_model
    m = 2 * d                      # up-projection factor 2 (xLSTM)
    h = cfg.n_heads
    dt = cfg.param_dtype
    return {
        "up": ParamSpec((d, m), ("embed", "lru"), dt),
        "wq": ParamSpec((m, m), ("lru", None), dt),
        "wk": ParamSpec((m, m), ("lru", None), dt),
        "wv": ParamSpec((m, m), ("lru", None), dt),
        "w_if": ParamSpec((d, 2 * h), ("embed", None), dt),
        "b_if": ParamSpec((2 * h,), (None,), dt, init="zeros"),
        "w_o": ParamSpec((d, m), ("embed", "lru"), dt),
        "down": ParamSpec((m, d), ("lru", "embed"), dt),
    }


class MLSTMState(NamedTuple):
    C: jax.Array     # (B, H, Dh, Dh) matrix memory, f32
    n: jax.Array     # (B, H, Dh) normalizer, f32
    m: jax.Array     # (B, H) running max exponent, f32


def mlstm_zero_state(cfg, batch: int) -> MLSTMState:
    h = cfg.n_heads
    dh = 2 * cfg.d_model // h
    return MLSTMState(C=jnp.zeros((batch, h, dh, dh), jnp.float32),
                      n=jnp.zeros((batch, h, dh), jnp.float32),
                      m=jnp.zeros((batch, h), jnp.float32))


def _mlstm_chunk(q, k, v, li, lf, state: MLSTMState):
    """One chunk of the stabilized chunkwise mLSTM.

    q,k,v: (B,H,L,Dh) f32; li,lf: (B,H,L) f32 (log input gate, log forget).
    """
    B, H, L, Dh = q.shape
    C0, n0, m0 = state
    b = jnp.cumsum(lf, axis=-1)                      # (B,H,L) inclusive
    F = b[..., -1]                                   # (B,H)

    # per-position stabilizer
    intra_exp = b[..., :, None] - b[..., None, :] + li[..., None, :]
    causal = jnp.tril(jnp.ones((L, L), bool))
    intra_exp = jnp.where(causal, intra_exp, -jnp.inf)
    m_intra = intra_exp.max(axis=-1)                 # (B,H,L)
    m_inter = m0[..., None] + b                      # (B,H,L)
    m_t = jnp.maximum(m_inter, m_intra)
    m_t = jnp.maximum(m_t, -1e30)

    g_inter = jnp.exp(m_inter - m_t)                 # (B,H,L)
    w_intra = jnp.exp(intra_exp - m_t[..., None])
    w_intra = jnp.where(causal, w_intra, 0.0)

    scores = jnp.einsum("bhld,bhsd->bhls", q, k) * w_intra
    h_num = (g_inter[..., None] * jnp.einsum("bhld,bhde->bhle", q, C0)
             + jnp.einsum("bhls,bhsd->bhld", scores, v))
    n_t = (g_inter * jnp.einsum("bhld,bhd->bhl", q, n0)
           + scores.sum(axis=-1))
    denom = jnp.maximum(jnp.abs(n_t), jnp.exp(-m_t))
    h_out = h_num / denom[..., None]

    # state update to end of chunk
    s_exp = F[..., None] - b + li                    # (B,H,L)
    m_next = jnp.maximum(m0 + F, s_exp.max(axis=-1))
    decay_old = jnp.exp(m0 + F - m_next)
    w_new = jnp.exp(s_exp - m_next[..., None])       # (B,H,L)
    C1 = (decay_old[..., None, None] * C0
          + jnp.einsum("bhl,bhld,bhle->bhde", w_new, k, v))
    n1 = decay_old[..., None] * n0 + jnp.einsum("bhl,bhld->bhd", w_new, k)
    return h_out, MLSTMState(C=C1, n=n1, m=m_next)


def mlstm_block(cfg, p: dict, x: jax.Array, compute_dtype,
                state: Optional[MLSTMState] = None):
    """x: (B, S, d) -> (y, new_state).  S must divide by cfg.mlstm_chunk (or
    be smaller)."""
    B, S, d = x.shape
    cd = compute_dtype
    H = cfg.n_heads
    m = 2 * d
    Dh = m // H
    xm = jnp.einsum("bsd,dm->bsm", x, p["up"].astype(cd))
    xm = constrain(xm, ("batch", "seq", "lru"))

    def heads(w):
        y = jnp.einsum("bsm,mn->bsn", xm, w.astype(cd))
        return y.reshape(B, S, H, Dh).transpose(0, 2, 1, 3).astype(jnp.float32)

    q = heads(p["wq"]) * (Dh ** -0.5)
    k = heads(p["wk"]) * (Dh ** -0.5)
    v = heads(p["wv"])
    gif = jnp.einsum("bsd,dg->bsg", x.astype(jnp.float32),
                     p["w_if"].astype(jnp.float32)) + p["b_if"].astype(
                         jnp.float32)
    li = gif[..., :H].transpose(0, 2, 1)             # (B,H,S) log input gate
    lf = jax.nn.log_sigmoid(gif[..., H:]).transpose(0, 2, 1)

    st = state if state is not None else mlstm_zero_state(cfg, B)
    L = min(cfg.mlstm_chunk, S)
    if S % L:
        L = S
    n_chunks = S // L

    if n_chunks == 1:
        h_out, st = _mlstm_chunk(q, k, v, li, lf, st)
    else:
        def split(t):
            return t.reshape(B, H, n_chunks, L, *t.shape[3:]).transpose(
                2, 0, 1, 3, *range(4, t.ndim + 1))
        qs, ks, vs = split(q), split(k), split(v)
        lis = li.reshape(B, H, n_chunks, L).transpose(2, 0, 1, 3)
        lfs = lf.reshape(B, H, n_chunks, L).transpose(2, 0, 1, 3)

        chunk_fn = jax.checkpoint(_mlstm_chunk)

        def step(carry, xs):
            qi, ki, vi, lii, lfi = xs
            h, new = chunk_fn(qi, ki, vi, lii, lfi, carry)
            return new, h

        st, hs = jax.lax.scan(step, st, (qs, ks, vs, lis, lfs))
        h_out = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, Dh)

    h_seq = h_out.transpose(0, 2, 1, 3).reshape(B, S, m).astype(cd)
    o = jax.nn.sigmoid(jnp.einsum("bsd,dm->bsm", x, p["w_o"].astype(cd)))
    y = jnp.einsum("bsm,md->bsd", h_seq * o, p["down"].astype(cd))
    return constrain(y, ("batch", "seq", "act_embed")), st


# ===========================================================================
# sLSTM (scalar memory, exponential gating; sequential scan)
# ===========================================================================

def slstm_spec(cfg) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    dt = cfg.param_dtype
    f = cfg.d_ff if cfg.d_ff else ((4 * d // 3 + 127) // 128) * 128
    return {
        "w": ParamSpec((d, 4 * d), ("embed", "lru"), dt),       # z,i,f,o
        "r": ParamSpec((h, dh, 4 * dh), (None, None, None), dt),
        "b": ParamSpec((4 * d,), ("lru",), dt, init="zeros"),
        "ffn_g": ParamSpec((d, f), ("embed", "mlp"), dt),
        "ffn_u": ParamSpec((d, f), ("embed", "mlp"), dt),
        "ffn_d": ParamSpec((f, d), ("mlp", "embed"), dt),
    }


class SLSTMState(NamedTuple):
    c: jax.Array     # (B, d) cell, f32
    n: jax.Array     # (B, d) normalizer, f32
    m: jax.Array     # (B, d) stabilizer, f32
    h: jax.Array     # (B, d) hidden, f32


def slstm_zero_state(cfg, batch: int) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z, m=z, h=z)


def _slstm_step(cfg, p, state: SLSTMState, wx_t: jax.Array):
    """wx_t: (B, 4d) precomputed input projection at time t."""
    B = wx_t.shape[0]
    d = cfg.d_model
    H = cfg.n_heads
    Dh = d // H
    c, n, m, h = state
    # recurrent projection, block-diagonal per head
    hh = h.reshape(B, H, Dh)
    rec = jnp.einsum("bhd,hde->bhe", hh,
                     p["r"].astype(jnp.float32)).reshape(B, 4 * d)
    pre = wx_t + rec
    z_, i_, f_, o_ = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z_)
    o = jax.nn.sigmoid(o_)
    # stabilized exponential gating
    log_f = jax.nn.log_sigmoid(f_)
    m_new = jnp.maximum(log_f + m, i_)
    i = jnp.exp(i_ - m_new)
    f = jnp.exp(log_f + m - m_new)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return SLSTMState(c=c_new, n=n_new, m=m_new, h=h_new)


def slstm_block(cfg, p: dict, x: jax.Array, compute_dtype,
                state: Optional[SLSTMState] = None):
    """x: (B, S, d) -> (y, new_state)."""
    B, S, d = x.shape
    cd = compute_dtype
    wx = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                    p["w"].astype(jnp.float32)) + p["b"].astype(jnp.float32)
    st = state if state is not None else slstm_zero_state(cfg, B)

    step_fn = jax.checkpoint(lambda carry, wx_t: _slstm_step(cfg, p, carry,
                                                             wx_t))

    def step(carry, wx_t):
        new = step_fn(carry, wx_t)
        return new, new.h

    st, hs = jax.lax.scan(step, st, wx.transpose(1, 0, 2))
    h_seq = hs.transpose(1, 0, 2).astype(cd)         # (B, S, d)
    a = act_fn(cfg.act)
    g = jnp.einsum("bsd,df->bsf", h_seq, p["ffn_g"].astype(cd))
    u = jnp.einsum("bsd,df->bsf", h_seq, p["ffn_u"].astype(cd))
    y = jnp.einsum("bsf,fd->bsd", a(g) * u, p["ffn_d"].astype(cd))
    return constrain(y, ("batch", "seq", "act_embed")), st
