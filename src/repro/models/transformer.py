"""Model assembly: decoder-only LMs (dense / MoE / SSM / hybrid), the whisper
encoder-decoder, and VLM early-fusion — all driven by :class:`ArchConfig`.

Layers are grouped into repeating **super-blocks** (e.g. recurrentgemma's
(rglru, rglru, attn)) and scanned with stacked parameters; a non-dividing
tail is unrolled.  The same stacks drive ``forward`` (train), ``prefill``
(returns a KV/state cache) and ``decode_step`` (one token against the cache,
scanned over layers).

KV caches are ring buffers of per-kind size (full context for full attention,
``window`` for sliding, ``chunk`` for local-chunked) with absolute slot
positions; the cache sequence axis carries the logical name ``kv_seq_mp``
(model-sharded => GSPMD flash-decoding).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..parallel.sharding import constrain
from . import attention as attn
from . import moe as moe_mod
from . import recurrent as rec
from .layers import (apply_norm, norm_spec, mlp_spec, apply_mlp, embed_spec,
                     embed_lookup, unembed, cross_entropy,
                     sinusoidal_positions)
from .spec import ParamSpec, is_spec


def _kv_quant(k: jax.Array):
    """Per-(batch, slot, head) absmax int8 quantization of K/V."""
    scale = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _kv_dequant(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(dtype) * scale[..., None].astype(dtype))

ATTN_KINDS = ("attn", "sliding", "chunked", "global_nope", "xattn", "enc")


def ffn_kind(kind: str) -> str:
    """'moe:chunked' -> 'moe';  'attn' -> 'attn'."""
    return kind.split(":")[0]


def attn_kind(kind: str) -> str:
    """'moe:chunked' -> 'chunked';  'moe' -> 'moe'."""
    return kind.split(":")[-1]


# ---------------------------------------------------------------------------
# Super-block structure
# ---------------------------------------------------------------------------

def super_block(cfg):
    """(pattern, n_repeat, tail_kinds) for the decoder stack."""
    if cfg.block_pattern:
        pat = tuple(cfg.block_pattern)
        pat = tuple("sliding" if (k == "attn" and cfg.attention == "sliding")
                    else k for k in pat)
        n = cfg.n_layers // len(pat)
        tail = tuple(pat[i] for i in range(cfg.n_layers - n * len(pat)))
        return pat, n, tail
    if cfg.attention == "chunked_global" and cfg.global_every:
        g = cfg.global_every
        pre = "moe:" if cfg.n_experts else ""
        pat = tuple([pre + "chunked"] * (g - 1) + [pre + "global_nope"])
        n = cfg.n_layers // g
        tail = tuple(pat[i] for i in range(cfg.n_layers - n * g))
        return pat, n, tail
    if cfg.is_encoder_decoder:
        return ("xattn",), cfg.n_layers, ()
    kind = ("moe" if cfg.n_experts else
            ("sliding" if cfg.attention == "sliding" else "attn"))
    return (kind,), cfg.n_layers, ()


def _kind_spec(cfg, kind: str) -> dict:
    kind = ffn_kind(kind)
    d, dt = cfg.d_model, cfg.param_dtype
    nk = cfg.norm
    if kind in ("attn", "sliding", "chunked", "global_nope", "enc"):
        return {"ln1": norm_spec(d, nk),
                "attn": attn.attn_spec(cfg),
                "ln2": norm_spec(d, nk),
                "mlp": mlp_spec(d, cfg.d_ff, cfg.mlp, dt)}
    if kind == "xattn":
        return {"ln1": norm_spec(d, nk),
                "attn": attn.attn_spec(cfg),
                "lnx": norm_spec(d, nk),
                "xattn": attn.attn_spec(cfg, cross=True),
                "ln2": norm_spec(d, nk),
                "mlp": mlp_spec(d, cfg.d_ff, cfg.mlp, dt)}
    if kind == "moe":
        return {"ln1": norm_spec(d, nk),
                "attn": attn.attn_spec(cfg),
                "ln2": norm_spec(d, nk),
                "moe": moe_mod.moe_spec(cfg)}
    if kind == "rglru":
        return {"ln1": norm_spec(d, nk),
                "rglru": rec.rglru_spec(cfg),
                "ln2": norm_spec(d, nk),
                "mlp": mlp_spec(d, cfg.d_ff, cfg.mlp, dt)}
    if kind == "mlstm":
        return {"ln1": norm_spec(d, nk), "mlstm": rec.mlstm_spec(cfg)}
    if kind == "slstm":
        return {"ln1": norm_spec(d, nk), "slstm": rec.slstm_spec(cfg)}
    raise ValueError(kind)


def _stack(spec_tree, n: int):
    def add(s: ParamSpec):
        return ParamSpec((n,) + s.shape, ("layers",) + s.logical, s.dtype,
                         s.init, fan_axis=-2 if len(s.shape) >= 2 else -1)
    return jax.tree.map(add, spec_tree, is_leaf=is_spec)


def model_spec(cfg) -> dict:
    """Full parameter tree of ParamSpec leaves."""
    d, dt = cfg.d_model, cfg.param_dtype
    pat, n, tail = super_block(cfg)
    spec: dict = {
        "embed": embed_spec(cfg.padded_vocab(), d, dt),
        "final_norm": norm_spec(d, cfg.norm),
        "stages": tuple(_stack(_kind_spec(cfg, k), n) for k in pat),
        "tail": tuple(_kind_spec(cfg, k) for k in tail),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = ParamSpec((d, cfg.padded_vocab()),
                                    ("embed", "vocab"), dt)
    if cfg.is_encoder_decoder:
        spec["encoder"] = {
            "stage": _stack(_kind_spec(cfg, "enc"), cfg.n_encoder_layers),
            "final_norm": norm_spec(d, cfg.norm),
        }
    return spec


# ---------------------------------------------------------------------------
# Per-kind forward (full-sequence mode: train / prefill)
# ---------------------------------------------------------------------------

def _cache_len(cfg, kind: str, max_seq: int) -> int:
    kind = attn_kind(kind)
    if kind == "sliding":
        return min(cfg.window, max_seq)
    if kind == "chunked":
        return min(cfg.chunk, max_seq)
    return max_seq


def _attn_mode(kind: str) -> tuple:
    """kind -> (mode, use_rope_default)"""
    kind = attn_kind(kind)
    return {
        "attn": ("causal", True),
        "moe": ("causal", True),            # MoE blocks use standard attention
        "sliding": ("sliding", True),
        "chunked": ("chunked", True),
        "global_nope": ("causal", False),   # llama4 NoPE global layers
        "enc": ("bidir", False),
        "xattn": ("causal", False),         # whisper: sinusoidal, not rope
    }[kind]


def apply_layer_full(cfg, kind: str, p: dict, x: jax.Array,
                     positions: jax.Array, *, collect_cache: bool,
                     max_seq: int, enc_kv=None):
    """One block over the full sequence.  Returns (x, cache_entry)."""
    cd = jnp.dtype(cfg.compute_dtype)
    S = x.shape[1]
    cache = None
    if kind in ("mlstm", "slstm", "rglru"):
        h = apply_norm(p["ln1"], x, cfg.norm)
        if kind == "rglru":
            y, state = rec.rglru_block(cfg, p["rglru"], h, cd)
            x = x + y
            h2 = apply_norm(p["ln2"], x, cfg.norm)
            x = x + apply_mlp(p["mlp"], h2, cfg.mlp, cfg.act, cd)
        elif kind == "mlstm":
            y, state = rec.mlstm_block(cfg, p["mlstm"], h, cd)
            x = x + y
        else:
            y, state = rec.slstm_block(cfg, p["slstm"], h, cd)
            x = x + y
        if collect_cache:
            cache = state
        return x, cache

    mode, use_rope = _attn_mode(kind)
    if cfg.is_encoder_decoder:
        use_rope = False
    h = apply_norm(p["ln1"], x, cfg.norm)
    y, (k, v) = attn.self_attention(
        cfg, p["attn"], h, positions, mode=mode, use_rope=use_rope,
        compute_dtype=cd, window=cfg.window, chunk=cfg.chunk)
    x = x + y
    cross = None
    if kind == "xattn":
        hx = apply_norm(p["lnx"], x, cfg.norm)
        y, cross = attn.cross_attention(cfg, p["xattn"], hx, enc_kv, cd)
        x = x + y
    h2 = apply_norm(p["ln2"], x, cfg.norm)
    if ffn_kind(kind) == "moe":
        x = x + moe_mod.moe_ffn(cfg, p["moe"], h2, cd)
    else:
        x = x + apply_mlp(p["mlp"], h2, cfg.mlp, cfg.act, cd)

    if collect_cache and kind != "enc":
        Sc = _cache_len(cfg, kind, max_seq)
        kc = _to_cache(k, Sc)
        vc = _to_cache(v, Sc)
        kc = constrain(kc, ("batch", "kv_seq_mp", "kv_heads", "head_dim"))
        vc = constrain(vc, ("batch", "kv_seq_mp", "kv_heads", "head_dim"))
        if cfg.kv_cache_dtype == "int8":
            kc, ks = _kv_quant(kc)
            vc, vs = _kv_quant(vc)
            entry = {"k": kc, "k_scale": ks, "v": vc, "v_scale": vs}
        else:
            entry = {"k": kc, "v": vc}
        if kind == "xattn":
            entry["xk"], entry["xv"] = cross
        cache = entry
    return x, cache


def _to_cache(k: jax.Array, Sc: int) -> jax.Array:
    """Lay out prefilled K/V (B, S, H, Dh) as a ring buffer of length Sc
    where absolute position p sits at slot p % Sc."""
    S = k.shape[1]
    if S >= Sc:
        return jnp.roll(k[:, -Sc:], (S - Sc) % Sc, axis=1)
    pad = jnp.zeros((k.shape[0], Sc - S) + k.shape[2:], k.dtype)
    return jnp.concatenate([k, pad], axis=1)


# ---------------------------------------------------------------------------
# Full-sequence stack (train / prefill)
# ---------------------------------------------------------------------------

def _run_stack(cfg, params, x, positions, *, collect_cache: bool,
               max_seq: int, enc_kv=None):
    pat, n, tail = super_block(cfg)
    caches = {"stages": [], "tail": []}
    # remat grouping: checkpoint every g super-blocks instead of every one —
    # divides the saved-activation stack by g at the cost of recompute depth.
    g = max(1, getattr(cfg, "remat_group", 1))
    if n % g:
        g = 1

    def _layer(kind):
        def f(xh, psl):
            return apply_layer_full(
                cfg, kind, psl, xh, positions,
                collect_cache=collect_cache, max_seq=max_seq, enc_kv=enc_kv)
        # multi-kind super-blocks: checkpoint each layer so the backward
        # recompute working set stays at ONE layer, not the whole pattern.
        if cfg.remat == "full" and len(pat) > 1:
            f = jax.checkpoint(f)
        return f

    layer_fns = [_layer(k) for k in pat]

    def body_one(xh, stage_slices):
        entries = []
        for fn, psl in zip(layer_fns, stage_slices):
            xh, entry = fn(xh, psl)
            entries.append(entry)
        return xh, tuple(entries)

    if g == 1:
        body = body_one
        xs = params["stages"]
    else:
        # two-level (sqrt) remat: inner per-super-block checkpoints keep the
        # backward recompute working set at ONE block while the outer
        # checkpoint divides the saved-activation stack by g.
        inner = jax.checkpoint(body_one) if cfg.remat == "full" else body_one

        def body(xh, grouped):
            outs = []
            for i in range(g):
                sl = jax.tree.map(lambda a, i=i: a[i], grouped)
                xh, ent = inner(xh, sl)
                outs.append(ent)
            stacked = (jax.tree.map(lambda *ys: jnp.stack(ys), *outs)
                       if collect_cache else tuple(outs))
            return xh, stacked
        xs = jax.tree.map(
            lambda a: a.reshape((n // g, g) + a.shape[1:]), params["stages"])

    if cfg.remat == "full":
        body = jax.checkpoint(body)

    x, stage_caches = jax.lax.scan(body, x, xs)
    if g > 1 and collect_cache:
        # (n/g, g, ...) -> (n, ...)
        stage_caches = jax.tree.map(
            lambda a: a.reshape((n,) + a.shape[2:]), stage_caches)
    caches["stages"] = stage_caches       # leaves have leading (n,) axis

    for kind, psl in zip(tail, params["tail"]):
        x, entry = apply_layer_full(cfg, kind, psl, x, positions,
                                    collect_cache=collect_cache,
                                    max_seq=max_seq, enc_kv=enc_kv)
        caches["tail"].append(entry)
    caches["tail"] = tuple(caches["tail"])
    return x, caches


def _run_encoder(cfg, params, frames: jax.Array):
    """whisper encoder over stub frame embeddings (B, F, d)."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(cd)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(cd)[None]
    positions = jnp.arange(x.shape[1])

    def body(xh, psl):
        xh, _ = apply_layer_full(cfg, "enc", psl, xh, positions,
                                 collect_cache=False, max_seq=x.shape[1])
        return xh, None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"]["stage"])
    return apply_norm(params["encoder"]["final_norm"], x, cfg.norm)


# ---------------------------------------------------------------------------
# Public entry points: forward (logits), loss, prefill, decode
# ---------------------------------------------------------------------------

def _embed_inputs(cfg, params, tokens, prefix=None):
    cd = jnp.dtype(cfg.compute_dtype)
    x = embed_lookup(params["embed"], tokens, cd)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cd)
    if prefix is not None:
        x = jnp.concatenate([prefix.astype(cd), x], axis=1)
        x = constrain(x, ("batch", "seq", "act_embed"))
    return x


def forward(cfg, params, tokens, *, prefix=None, frames=None,
            collect_cache: bool = False, max_cache_seq: Optional[int] = None):
    """Full-sequence forward.  Returns (logits, cache_or_None).

    tokens: (B, S) int32; prefix: (B, P, d) early-fusion embeddings (vlm);
    frames: (B, F, d) stub audio frame embeddings (whisper).
    """
    cd = jnp.dtype(cfg.compute_dtype)
    enc_kv = None
    if cfg.is_encoder_decoder:
        assert frames is not None
        enc_out = _run_encoder(cfg, params, frames)
        # cross-attention K/V computed once per layer inside blocks would
        # re-project per layer; whisper shares the encoder output, so we
        # pre-project per *layer stack* lazily inside apply via enc_out.
        enc_kv = enc_out

    x = _embed_inputs(cfg, params, tokens, prefix)
    if cfg.is_encoder_decoder:
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(cd)[None]
    S = x.shape[1]
    positions = jnp.arange(S)
    max_seq = max_cache_seq or S

    x, caches = _run_stack(cfg, params, x, positions,
                           collect_cache=collect_cache, max_seq=max_seq,
                           enc_kv=enc_kv)
    if collect_cache:
        # serving prefill: only the next-token logits are needed
        x = x[:, -1:]
    x = apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x, cd, transpose=True)
    else:
        logits = unembed(params["lm_head"], x, cd, transpose=False)
    if not collect_cache:
        return logits, None
    cache = {"layers": caches, "pos": jnp.asarray(S, jnp.int32),
             "slot_pos": _prefill_slot_pos(cfg, S, max_seq)}
    return logits, cache


def _prefill_slot_pos(cfg, S: int, max_seq: int) -> dict:
    """Absolute slot positions per distinct cache length (-1 = empty)."""
    pat, _, tail = super_block(cfg)
    out = {}
    for kind in {attn_kind(k) for k in set(pat) | set(tail)}:
        if kind in ("mlstm", "slstm", "rglru", "enc"):
            continue
        Sc = _cache_len(cfg, kind, max_seq)
        if S >= Sc:
            pos = jnp.arange(S - Sc, S, dtype=jnp.int32)
            out[kind] = jnp.roll(pos, (S - Sc) % Sc)
        else:
            out[kind] = jnp.concatenate(
                [jnp.arange(S, dtype=jnp.int32),
                 jnp.full((Sc - S,), -1, jnp.int32)])
    return out


def loss_fn(cfg, params, batch) -> jax.Array:
    """Mean next-token cross-entropy; prefix/frames handled per family."""
    logits, _ = forward(cfg, params, batch["tokens"],
                        prefix=batch.get("prefix"),
                        frames=batch.get("frames"))
    labels = batch["labels"]
    if "prefix" in batch and batch["prefix"] is not None:
        P = batch["prefix"].shape[1]
        logits = logits[:, P:]
    mask = (labels >= 0)
    labels = jnp.maximum(labels, 0)
    return cross_entropy(logits, labels, mask, real_vocab=cfg.vocab_size)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def apply_layer_decode(cfg, kind: str, p: dict, x: jax.Array, entry,
                       slot_pos, pos, enc_out=None):
    """One block for a single token.  x: (B, 1, d).  Returns (x, new_entry)."""
    cd = jnp.dtype(cfg.compute_dtype)
    if kind in ("mlstm", "slstm", "rglru"):
        h = apply_norm(p["ln1"], x, cfg.norm)
        if kind == "rglru":
            y, state = rec.rglru_block(cfg, p["rglru"], h, cd, state=entry)
            x = x + y
            h2 = apply_norm(p["ln2"], x, cfg.norm)
            x = x + apply_mlp(p["mlp"], h2, cfg.mlp, cfg.act, cd)
        elif kind == "mlstm":
            y, state = rec.mlstm_block(cfg, p["mlstm"], h, cd, state=entry)
            x = x + y
        else:
            y, state = rec.slstm_block(cfg, p["slstm"], h, cd, state=entry)
            x = x + y
        return x, state

    mode, use_rope = _attn_mode(kind)
    if cfg.is_encoder_decoder:
        use_rope = False
    h = apply_norm(p["ln1"], x, cfg.norm)
    q, k1, v1 = attn.project_qkv(cfg, p["attn"], h, pos[None],
                                 use_rope=use_rope, compute_dtype=cd)
    Sc = entry["k"].shape[1]
    slot = pos % Sc
    new_entry = dict(entry)
    if cfg.kv_cache_dtype == "int8":
        k1q, k1s = _kv_quant(k1)
        v1q, v1s = _kv_quant(v1)
        ck = jax.lax.dynamic_update_slice_in_dim(entry["k"], k1q, slot,
                                                 axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(entry["v"], v1q, slot,
                                                 axis=1)
        ks = jax.lax.dynamic_update_slice_in_dim(entry["k_scale"], k1s,
                                                 slot, axis=1)
        vs = jax.lax.dynamic_update_slice_in_dim(entry["v_scale"], v1s,
                                                 slot, axis=1)
        new_entry["k_scale"], new_entry["v_scale"] = ks, vs
        ck_c = _kv_dequant(ck, ks, cd)
        cv_c = _kv_dequant(cv, vs, cd)
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(entry["k"], k1, slot,
                                                 axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(entry["v"], v1, slot,
                                                 axis=1)
        ck_c, cv_c = ck, cv
    sp = slot_pos[attn_kind(kind)]
    out = attn.decode_attention(cfg, q, ck_c, cv_c, sp, pos, mode=mode,
                                window=cfg.window, chunk=cfg.chunk)
    x = x + attn.output_proj(cfg, p["attn"], out, cd)
    if kind == "xattn":
        hx = apply_norm(p["lnx"], x, cfg.norm)
        qx = jnp.einsum("bsd,dhk->bshk", hx, p["xattn"]["wq"].astype(cd))
        xk, xv = entry["xk"], entry["xv"]
        F = xk.shape[1]
        o = attn.decode_attention(cfg, qx, xk, xv,
                                  jnp.arange(F, dtype=jnp.int32),
                                  jnp.asarray(F, jnp.int32), mode="bidir")
        x = x + attn.output_proj(cfg, p["xattn"], o, cd)
    h2 = apply_norm(p["ln2"], x, cfg.norm)
    if ffn_kind(kind) == "moe":
        x = x + moe_mod.moe_ffn(cfg, p["moe"], h2, cd)
    else:
        x = x + apply_mlp(p["mlp"], h2, cfg.mlp, cfg.act, cd)
    new_entry["k"], new_entry["v"] = ck, cv
    return x, new_entry


def decode_step(cfg, params, cache, token):
    """token: (B, 1) int32.  Returns (logits (B, 1, V), new_cache)."""
    cd = jnp.dtype(cfg.compute_dtype)
    pat, n, tail = super_block(cfg)
    pos = cache["pos"]
    # Mark the current slot BEFORE attention so the new token attends itself.
    slot_pos = {k: v.at[pos % v.shape[0]].set(pos)
                for k, v in cache["slot_pos"].items()}
    x = _embed_inputs(cfg, params, token)
    if cfg.is_encoder_decoder:
        x = x + sinusoidal_positions(1, cfg.d_model, offset=pos).astype(cd)

    def body(xh, xs):
        stage_slices, cache_slices = xs
        new_entries = []
        for kind, psl, ent in zip(pat, stage_slices, cache_slices):
            xh, ne = apply_layer_decode(cfg, kind, psl, xh, ent, slot_pos,
                                        pos)
            new_entries.append(ne)
        return xh, tuple(new_entries)

    x, new_stage_cache = jax.lax.scan(
        body, x, (params["stages"], cache["layers"]["stages"]))

    new_tail = []
    for kind, psl, ent in zip(tail, params["tail"],
                              cache["layers"]["tail"]):
        x, ne = apply_layer_decode(cfg, kind, psl, x, ent, slot_pos, pos)
        new_tail.append(ne)

    x = apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x, cd, transpose=True)
    else:
        logits = unembed(params["lm_head"], x, cd, transpose=False)

    new_cache = dict(cache)
    new_cache["layers"] = {"stages": new_stage_cache,
                           "tail": tuple(new_tail)}
    new_cache["pos"] = pos + 1
    new_cache["slot_pos"] = slot_pos
    return logits, new_cache


# ---------------------------------------------------------------------------
# Abstract cache (for dry-run decode without a prefill pass)
# ---------------------------------------------------------------------------

def cache_spec(cfg, batch: int, max_seq: int) -> dict:
    """Tree of ParamSpec describing the decode cache (ShapeDtypeStruct-able)."""
    pat, n, tail = super_block(cfg)
    cd = cfg.compute_dtype
    dh = cfg.resolved_head_dim

    def kv_entry(kind, stacked_n):
        Sc = _cache_len(cfg, kind, max_seq)
        lead = (stacked_n,) if stacked_n is not None else ()
        lg = ("layers",) if stacked_n is not None else ()
        kvd = "int8" if cfg.kv_cache_dtype == "int8" else cd
        e = {"k": ParamSpec(lead + (batch, Sc, cfg.n_kv_heads, dh),
                            lg + ("batch", "kv_seq_mp", "kv_heads",
                                  "head_dim"), kvd),
             "v": ParamSpec(lead + (batch, Sc, cfg.n_kv_heads, dh),
                            lg + ("batch", "kv_seq_mp", "kv_heads",
                                  "head_dim"), kvd)}
        if cfg.kv_cache_dtype == "int8":
            e["k_scale"] = ParamSpec(
                lead + (batch, Sc, cfg.n_kv_heads),
                lg + ("batch", "kv_seq_mp", "kv_heads"), "bfloat16")
            e["v_scale"] = ParamSpec(
                lead + (batch, Sc, cfg.n_kv_heads),
                lg + ("batch", "kv_seq_mp", "kv_heads"), "bfloat16")
        if kind == "xattn":
            F = cfg.encoder_seq
            e["xk"] = ParamSpec(lead + (batch, F, cfg.n_kv_heads, dh),
                                lg + ("batch", None, "kv_heads", "head_dim"),
                                cd)
            e["xv"] = ParamSpec(lead + (batch, F, cfg.n_kv_heads, dh),
                                lg + ("batch", None, "kv_heads", "head_dim"),
                                cd)
        return e

    def state_entry(kind, stacked_n):
        lead = (stacked_n,) if stacked_n is not None else ()
        lg = ("layers",) if stacked_n is not None else ()
        if kind == "rglru":
            w = cfg.lru_width or cfg.d_model
            return rec.RGLRUState(
                h=ParamSpec(lead + (batch, w), lg + ("batch", "lru"),
                            "float32"),
                conv=ParamSpec(lead + (batch, cfg.conv_width - 1, w),
                               lg + ("batch", None, "lru"), "float32"))
        if kind == "mlstm":
            h = cfg.n_heads
            dhh = 2 * cfg.d_model // h
            return rec.MLSTMState(
                C=ParamSpec(lead + (batch, h, dhh, dhh),
                            lg + ("batch", "heads", None, None), "float32"),
                n=ParamSpec(lead + (batch, h, dhh),
                            lg + ("batch", "heads", None), "float32"),
                m=ParamSpec(lead + (batch, h), lg + ("batch", "heads"),
                            "float32"))
        if kind == "slstm":
            d = cfg.d_model
            z = lambda: ParamSpec(lead + (batch, d), lg + ("batch", "lru"),
                                  "float32")
            return rec.SLSTMState(c=z(), n=z(), m=z(), h=z())
        raise ValueError(kind)

    def entry(kind, stacked_n):
        if ffn_kind(kind) in ("mlstm", "slstm", "rglru"):
            return state_entry(kind, stacked_n)
        return kv_entry(kind, stacked_n)

    slot_pos = {}
    for kind in {attn_kind(k) for k in set(pat) | set(tail)}:
        if kind in ("mlstm", "slstm", "rglru", "enc"):
            continue
        Sc = _cache_len(cfg, kind, max_seq)
        slot_pos[kind] = ParamSpec((Sc,), (None,), "int32")

    cache = {
        "layers": {
            "stages": tuple(entry(k, n) for k in pat),
            "tail": tuple(entry(k, None) for k in tail),
        },
        "pos": ParamSpec((), (), "int32"),
        "slot_pos": slot_pos,
    }
    return cache
