"""Mixture-of-Experts FFN with expert parallelism over the "model" axis.

Two implementations (``cfg.moe_impl``):

* ``dense`` — every (local) expert processes every token; the top-k combine
  weights zero out non-selected experts.  GSPMD-clean: experts shard over
  "model" (EP), each device computes only its local experts and the final
  combine is a partial sum -> all-reduce.  FLOP overhead = n_experts / top_k
  on the expert matmuls (visible in the roofline useful-FLOP ratio).  Token
  chunking bounds the (E_local, B, Sc, d_ff) transient.

* ``capacity`` — GShard-style fixed-capacity gather: each expert processes at
  most C = tokens * top_k / E * capacity_factor tokens, gathered by top-C
  routing score.  Active-FLOPs only (the beyond-paper §Perf optimization);
  over-capacity tokens are dropped (standard), under-capacity slots padded.

Router always computes in float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.sharding import constrain
from .layers import act_fn
from .spec import ParamSpec


def moe_spec(cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.param_dtype
    spec = {
        # router stays replicated: it is tiny (d x E) and sharding its
        # contracting dim forces an f32 reshard of the full activation
        "router": ParamSpec((d, e), (None, None), dt),
        "wg": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp"), dt),
        "wu": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp"), dt),
        "wd": ParamSpec((e, f, d), ("experts", "expert_mlp", "embed"), dt),
    }
    if cfg.shared_expert:
        spec["shared"] = {
            "wg": ParamSpec((d, f), ("embed", "mlp"), dt),
            "wu": ParamSpec((d, f), ("embed", "mlp"), dt),
            "wd": ParamSpec((f, d), ("mlp", "embed"), dt),
        }
    return spec


def _router(cfg, p, x):
    """Top-k routing.  Returns combine weights (B, S, E) in f32.

    x stays in compute dtype (upcasting the full activation costs a
    param-d-sized f32 buffer per layer); the einsum accumulates in f32.
    """
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    if cfg.top_k >= cfg.n_experts:
        return probs
    vals, idx = jax.lax.top_k(probs, cfg.top_k)          # (B,S,k)
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    combine = jnp.zeros_like(probs)
    combine = jnp.put_along_axis(combine, idx, vals, axis=-1,
                                 inplace=False)
    return combine


def _glu(cfg, wg, wu, wd, x, combine, compute_dtype):
    """Experts einsum: x (B,Sc,d), combine (B,Sc,E) -> (B,Sc,d)."""
    a = act_fn(cfg.act)
    g = jnp.einsum("bsd,edf->ebsf", x, wg.astype(compute_dtype))
    u = jnp.einsum("bsd,edf->ebsf", x, wu.astype(compute_dtype))
    h = a(g) * u
    h = h * combine.transpose(2, 0, 1)[..., None].astype(compute_dtype)
    return jnp.einsum("ebsf,efd->bsd", h, wd.astype(compute_dtype))


def moe_dense(cfg, p: dict, x: jax.Array, compute_dtype,
              token_chunk: int = 1024) -> jax.Array:
    """Dense-compute MoE with sequence chunking.  x: (B, S, d)."""
    B, S, d = x.shape
    decode = S == 1
    if decode:
        # weight-stationary decode: activations are tiny (B tokens) while the
        # FSDP-sharded expert weights are huge — replicating x lets GSPMD
        # keep weights in place and psum the (E,B,1,f) partials instead of
        # all-gathering full f32 expert matrices every layer.
        x = constrain(x, (None, "seq", "act_embed"))
    combine = _router(cfg, p, x)
    sc = min(token_chunk, S)
    if S % sc:
        sc = S
    n = S // sc

    if n == 1:
        y = _glu(cfg, p["wg"], p["wu"], p["wd"], x, combine, compute_dtype)
    else:
        xs = x.reshape(B, n, sc, d).transpose(1, 0, 2, 3)
        cs = combine.reshape(B, n, sc, cfg.n_experts).transpose(1, 0, 2, 3)

        def step(_, xc):
            xi, ci = xc
            return None, _glu(cfg, p["wg"], p["wu"], p["wd"], xi, ci,
                              compute_dtype)

        _, ys = jax.lax.scan(step, None, (xs, cs))
        y = ys.transpose(1, 0, 2, 3).reshape(B, S, d)

    if cfg.shared_expert:
        sp = p["shared"]
        a = act_fn(cfg.act)
        h = a(jnp.einsum("bsd,df->bsf", x, sp["wg"].astype(compute_dtype))) \
            * jnp.einsum("bsd,df->bsf", x, sp["wu"].astype(compute_dtype))
        h = constrain(h, ("batch", "seq", "mlp"))
        y = y + jnp.einsum("bsf,fd->bsd", h, sp["wd"].astype(compute_dtype))
    if decode:
        y = constrain(y, ("batch", "seq", "act_embed"))
    return constrain(y, ("batch", "seq", "act_embed"))


def moe_capacity(cfg, p: dict, x: jax.Array, compute_dtype,
                 capacity_factor: float = 1.25) -> jax.Array:
    """Fixed-capacity expert-parallel MoE (active FLOPs only).

    GShard-style with **groups = batch rows**: each row selects its top-C
    tokens per expert along the (un-sharded) sequence axis, so every gather
    and scatter is device-local under GSPMD (batch stays data-sharded, the
    expert axis stays model-sharded).  Over-capacity tokens are dropped
    (standard); the combine weight re-weights survivors.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    combine = _router(cfg, p, x)                          # (B, S, E) f32

    C = int(S * k / E * capacity_factor)
    C = min(max(C, 1), S)

    # Per-(row, expert) top-C token selection by combine weight.
    scores = combine.transpose(0, 2, 1)                   # (B, E, S)
    top_w, top_idx = jax.lax.top_k(scores, C)             # (B, E, C)
    # flat gather along S (no (B,E,S,d) operand broadcast under GSPMD)
    gathered = jnp.take_along_axis(
        x, top_idx.reshape(B, E * C)[..., None], axis=1)  # (B, E*C, d)
    gathered = gathered.reshape(B, E, C, d)
    gathered = constrain(gathered, ("batch", "experts", None, "act_embed"))

    a = act_fn(cfg.act)

    def expert_glu(xc, wc):
        g = jnp.einsum("becd,edf->becf", xc, p["wg"].astype(compute_dtype))
        u = jnp.einsum("becd,edf->becf", xc, p["wu"].astype(compute_dtype))
        h = (a(g) * u) * wc[..., None].astype(compute_dtype)
        return jnp.einsum("becf,efd->becd", h, p["wd"].astype(compute_dtype))

    cc = 512                      # capacity chunk bounds einsum transients
    if C > cc and C % cc == 0:
        nc = C // cc
        xs = (gathered.reshape(B, E, nc, cc, d).transpose(2, 0, 1, 3, 4),
              top_w.reshape(B, E, nc, cc).transpose(2, 0, 1, 3))

        def step(_, xc):
            return None, expert_glu(*xc)

        _, outs = jax.lax.scan(step, None, xs)
        out = outs.transpose(1, 2, 0, 3, 4).reshape(B, E, C, d)
    else:
        out = expert_glu(gathered, top_w)

    idx_flat = top_idx.reshape(B, E * C)
    vals = out.reshape(B, E * C, d)
    if cfg.remat == "none":
        # Serving: reshard the (small) slot values from expert-sharded to
        # replicated with a bf16 all-gather BEFORE the combine — otherwise
        # GSPMD implements the cross-expert combine as a full-activation f32
        # all-reduce (2x bytes, f32 buffers).  In training the gather's
        # backward doubles live memory, so the combine stays expert-sharded.
        vals = constrain(vals, ("batch", None, "act_embed"))
    if k == 1:
        # top-1: every token occupies at most one NONZERO-weight slot —
        # combine by INVERSE GATHER instead of scatter-add (bf16
        # scatter-adds get upcast to f32 and the EP partial sums all-reduce
        # full f32 activations; the int32 inverse-index scatter is 1000x
        # smaller).  Zero-weight slots (capacity padding of other experts)
        # are dropped from the inverse.
        idx_inv = jnp.where(top_w.reshape(B, E * C) > 0, idx_flat, S)
        inv = jax.vmap(lambda idxb: jnp.full((S,), -1, jnp.int32)
                       .at[idxb].max(jnp.arange(E * C, dtype=jnp.int32),
                                     mode="drop")
                       )(idx_inv)
        sel = inv >= 0
        y = jnp.take_along_axis(
            vals, jnp.maximum(inv, 0)[..., None], axis=1)
        y = jnp.where(sel[..., None], y, jnp.zeros((), compute_dtype))
    else:
        # top-k: batched scatter-add (vmap keeps the batch dim aligned under
        # GSPMD)
        y = jax.vmap(lambda idxb, valsb: jnp.zeros(
            (S, d), compute_dtype).at[idxb].add(valsb))(idx_flat, vals)

    if cfg.shared_expert:
        sp = p["shared"]
        h = a(jnp.einsum("bsd,df->bsf", x, sp["wg"].astype(compute_dtype))) \
            * jnp.einsum("bsd,df->bsf", x, sp["wu"].astype(compute_dtype))
        h = constrain(h, ("batch", "seq", "mlp"))
        y = y + jnp.einsum("bsf,fd->bsd", h, sp["wd"].astype(compute_dtype))
    return constrain(y, ("batch", "seq", "act_embed"))


def moe_ffn(cfg, p: dict, x: jax.Array, compute_dtype) -> jax.Array:
    impl = getattr(cfg, "moe_impl", "dense")
    # decode (S == 1): the dense path is exact and trivially cheap
    if impl == "capacity" and x.shape[1] > 1:
        return moe_capacity(cfg, p, x, compute_dtype)
    return moe_dense(cfg, p, x, compute_dtype)
