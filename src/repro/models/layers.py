"""Shared neural-net layers (pure functional JAX, explicit dtypes)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..parallel.sharding import constrain
from .spec import ParamSpec


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def norm_spec(d: int, kind: str) -> dict:
    if kind == "rmsnorm":
        return {"scale": ParamSpec((d,), ("act_embed",), init="ones")}
    return {"scale": ParamSpec((d,), ("act_embed",), init="ones"),
            "bias": ParamSpec((d,), ("act_embed",), init="zeros")}


def apply_norm(p: dict, x: jax.Array, kind: str, eps: float = 1e-6):
    """Norms with f32 *reductions* but elementwise math in the input dtype.

    Deliberately avoids converting the whole activation to f32: a full-tensor
    convert directly on remat-saved activations gets hoisted out of XLA's
    backward loop, materializing an f32 copy of every layer's saved input
    (n_layers x B x S x d) — observed 2x activation-memory blowup.
    """
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                       keepdims=True)
        inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
        return x * inv * p["scale"].astype(x.dtype)
    mean32 = jnp.mean(x.astype(jnp.float32), axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True) - jnp.square(mean32)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return ((x - mean32.astype(x.dtype)) * inv * p["scale"].astype(x.dtype)
            + p["bias"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# MLP (dense 2-matrix or GLU 3-matrix)
# ---------------------------------------------------------------------------

def mlp_spec(d: int, f: int, kind: str, dtype: str,
             mlp_axis: str = "mlp") -> dict:
    if kind == "glu":
        return {
            "wg": ParamSpec((d, f), ("embed", mlp_axis), dtype),
            "wu": ParamSpec((d, f), ("embed", mlp_axis), dtype),
            "wd": ParamSpec((f, d), (mlp_axis, "embed"), dtype),
        }
    return {
        "wi": ParamSpec((d, f), ("embed", mlp_axis), dtype),
        "wo": ParamSpec((f, d), (mlp_axis, "embed"), dtype),
    }


def apply_mlp(p: dict, x: jax.Array, kind: str, act: str,
              compute_dtype) -> jax.Array:
    a = act_fn(act)
    decode = x.shape[1] == 1
    if decode:
        # weight-stationary decode (see moe_dense): replicate the token so
        # the FSDP-sharded weights are not all-gathered per layer
        x = constrain(x, (None, "seq", "act_embed"))
    if kind == "glu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(compute_dtype))
        u = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(compute_dtype))
        h = a(g) * u
        h = constrain(h, ("batch", "seq", "mlp"))
        out = jnp.einsum("bsf,fd->bsd", h, p["wd"].astype(compute_dtype))
        return constrain(out, ("batch", "seq", "act_embed")) if decode \
            else out
    h = a(jnp.einsum("bsd,df->bsf", x, p["wi"].astype(compute_dtype)))
    h = constrain(h, ("batch", "seq", "mlp"))
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(compute_dtype))
    return constrain(out, ("batch", "seq", "act_embed")) if decode else out


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh) or (..., S, Hkv, G, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = (1.0 / theta) ** (jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    # broadcast over head axes between S and Dh
    extra = x.ndim - ang.ndim - 1
    for _ in range(extra):
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int, offset=0) -> jax.Array:
    """Classic transformer sinusoidal embeddings (whisper encoder/decoder)."""
    pos = (jnp.arange(seq, dtype=jnp.float32) + offset)[:, None]
    half = d // 2
    freqs = (1.0 / 10_000.0) ** (jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_spec(vocab: int, d: int, dtype: str) -> ParamSpec:
    # d stays UNSHARDED: with vocab over "model" and d over "data", the
    # token-lookup gather conflicts with batch-over-"data" and GSPMD
    # replicates the batch with f32 partial sums (full-activation buffers).
    # vocab-over-"model" alone keeps the lookup local-ish and the tied
    # unembed einsum vocab-sharded.
    return ParamSpec((vocab, d), ("vocab", None), dtype, init="normal")


def embed_lookup(table: jax.Array, tokens: jax.Array,
                 compute_dtype) -> jax.Array:
    out = jnp.take(table, tokens, axis=0).astype(compute_dtype)
    return constrain(out, ("batch", "seq", "act_embed"))


def unembed(table_or_head: jax.Array, x: jax.Array, compute_dtype,
            transpose: bool) -> jax.Array:
    """Logits = x @ W^T (tied) or x @ W (untied head)."""
    w = table_or_head.astype(compute_dtype)
    if transpose:
        logits = jnp.einsum("bsd,vd->bsv", x, w)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, w)
    return constrain(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None,
                  real_vocab: Optional[int] = None) -> jax.Array:
    """Mean token cross-entropy in f32; positions of padded vocab masked.

    Written with iota comparisons (no gathers / slice-updates over the vocab
    axis) so a model-sharded vocab stays sharded — GSPMD reduces with a small
    (B, S) all-reduce instead of all-gathering full-vocab logits.
    """
    lf = logits.astype(jnp.float32)
    vpos = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    if real_vocab is not None and real_vocab < lf.shape[-1]:
        lf = jnp.where(vpos < real_vocab, lf, -1e30)
    lse = jax.nn.logsumexp(lf, axis=-1)
    sel = vpos == labels[..., None].astype(jnp.int32)
    picked = jnp.sum(jnp.where(sel, lf, 0.0), axis=-1)
    nll = lse - picked
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
