"""Parameter specification trees: shapes + logical sharding axes + init.

Every model declares its parameters as a tree of :class:`ParamSpec`; from it
we derive (a) materialized parameters for smoke tests / real training,
(b) ``ShapeDtypeStruct`` stand-ins with ``NamedSharding`` for the multi-pod
dry-run (no allocation), and (c) exact parameter counts for roofline
MODEL_FLOPS.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel import sharding as shd


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    logical: tuple                  # logical axis name (or None) per dim
    dtype: str = "float32"
    init: str = "fan_in"            # fan_in | zeros | ones | normal | lambda_lru
    fan_axis: int = -2              # which axis is fan-in for scaled init

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_size(spec_tree) -> int:
    return sum(s.size for s in jax.tree.leaves(
        spec_tree, is_leaf=is_spec) if is_spec(s))


def abstract_tree(spec_tree, mesh=None, rules=None):
    """ShapeDtypeStruct tree (with shardings when a mesh is given)."""
    def mk(s: ParamSpec):
        if mesh is None:
            return jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype))
        return jax.ShapeDtypeStruct(
            s.shape, jnp.dtype(s.dtype),
            sharding=shd.named_sharding(s.logical, mesh, rules, s.shape))
    return jax.tree.map(mk, spec_tree, is_leaf=is_spec)


def shardings_tree(spec_tree, mesh, rules=None):
    return jax.tree.map(
        lambda s: shd.named_sharding(s.logical, mesh, rules, s.shape),
        spec_tree, is_leaf=is_spec)


def pspecs_tree(spec_tree, mesh, rules=None):
    return jax.tree.map(
        lambda s: shd.resolve_pspec(s.logical, mesh, rules, s.shape),
        spec_tree, is_leaf=is_spec)


def _init_leaf(key, s: ParamSpec):
    dt = jnp.dtype(s.dtype)
    if s.init == "zeros":
        return jnp.zeros(s.shape, dt)
    if s.init == "ones":
        return jnp.ones(s.shape, dt)
    if s.init == "lambda_lru":
        # RG-LRU Lambda parametrization: a = sigmoid(L)^(c r); init so decay
        # a^c is in [0.9, 0.999] (RecurrentGemma appendix).
        u = jax.random.uniform(key, s.shape, jnp.float32, 0.9, 0.999)
        c = 8.0
        val = jnp.log(jnp.expm1(-jnp.log(u) / c))  # softplus^-1(-log(u)/c)
        return val.astype(dt)
    if s.init == "normal":
        return (0.02 * jax.random.normal(key, s.shape, jnp.float32)).astype(dt)
    # fan_in scaled truncated normal
    fan = s.shape[s.fan_axis] if s.shape else 1
    scale = 1.0 / math.sqrt(max(fan, 1))
    w = jax.random.truncated_normal(key, -2.0, 2.0, s.shape, jnp.float32)
    return (w * scale).astype(dt)


def init_tree(spec_tree, key):
    """Materialize parameters (smoke tests / real training runs)."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)
