"""Model zoo public API.

``Model`` bundles an ArchConfig with spec/init/step functions; ``input_specs``
produces ShapeDtypeStruct stand-ins (with shardings when a mesh is given) for
every (arch x shape) dry-run cell.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from ..optim import adamw
from ..parallel import sharding as shd
from . import spec as spec_mod
from . import transformer as tfm
from .spec import ParamSpec, abstract_tree, init_tree, shardings_tree, \
    tree_size, is_spec


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ---- parameters --------------------------------------------------------
    def param_spec(self):
        return tfm.model_spec(self.cfg)

    def init(self, key):
        return init_tree(self.param_spec(), key)

    def param_count(self) -> int:
        return tree_size(self.param_spec())

    # ---- pure model fns ----------------------------------------------------
    def loss(self, params, batch):
        return tfm.loss_fn(self.cfg, params, batch)

    def forward(self, params, tokens, **kw):
        return tfm.forward(self.cfg, params, tokens, **kw)

    def prefill(self, params, batch, max_cache_seq: Optional[int] = None):
        """Serving prefill.  With cfg.prefill_waves > 1 the request batch is
        processed in sequential waves (bounds live activation memory; the
        caches are merged along the batch axis afterwards)."""
        waves = max(1, getattr(self.cfg, "prefill_waves", 1))
        B = batch["tokens"].shape[0]
        if waves == 1 or B % waves:
            return tfm.forward(self.cfg, params, batch["tokens"],
                               prefix=batch.get("prefix"),
                               frames=batch.get("frames"),
                               collect_cache=True,
                               max_cache_seq=max_cache_seq)

        bw = B // waves
        waved = jax.tree.map(
            lambda x: x.reshape((waves, bw) + x.shape[1:]), batch)

        def one_wave(_, wb):
            lg, cache = tfm.forward(self.cfg, params, wb["tokens"],
                                    prefix=wb.get("prefix"),
                                    frames=wb.get("frames"),
                                    collect_cache=True,
                                    max_cache_seq=max_cache_seq)
            return None, (lg, cache)

        _, (logits, caches) = jax.lax.scan(one_wave, None, waved)
        # merge the wave axis back into each leaf's batch axis, guided by the
        # cache spec's logical axis names
        spec = tfm.cache_spec(self.cfg, bw, max_cache_seq
                              or batch["tokens"].shape[1])

        def merge(s, leaf):
            if "batch" not in s.logical:
                return jax.tree.map(lambda x: x[0], leaf)
            bi = s.logical.index("batch")
            out = jnp.moveaxis(leaf, 0, bi)
            return out.reshape(out.shape[:bi] + (waves * bw,)
                               + out.shape[bi + 2:])

        cache = jax.tree.map(merge, spec, caches,
                             is_leaf=lambda x: is_spec(x))
        logits = logits.reshape((B,) + logits.shape[2:])
        return logits, cache

    def decode_step(self, params, cache, token):
        return tfm.decode_step(self.cfg, params, cache, token)

    def cache_spec(self, batch: int, max_seq: int):
        return tfm.cache_spec(self.cfg, batch, max_seq)

    # ---- training step (with AdamW) ----------------------------------------
    def make_train_step(self, opt_cfg: adamw.AdamWConfig,
                        microbatches: int = 1,
                        accum_dtype: str = "float32"):
        """Train step with optional gradient accumulation: the global batch is
        split into ``microbatches`` sequential micro-steps, bounding live
        activations to one microbatch — required to fit the larger archs'
        train_4k cells in HBM.  ``accum_dtype="bfloat16"`` halves the
        accumulator (and its while-loop double buffer); fine for <=16
        same-scale summands, used by the production dry-run for the 20B+
        archs."""
        cfg = self.cfg
        k = microbatches
        adt = jnp.dtype(accum_dtype)

        def loss_of(p, b):
            return tfm.loss_fn(cfg, p, b)

        def train_step(params, opt_state, batch):
            if k == 1:
                loss, grads = jax.value_and_grad(loss_of)(params, batch)
            else:
                mbs = jax.tree.map(
                    lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                    batch)
                acc0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, adt), params)

                def micro(acc, mb):
                    l, g = jax.value_and_grad(loss_of)(params, mb)
                    acc = jax.tree.map(
                        lambda a, gi: a + gi.astype(adt), acc, g)
                    return acc, l

                acc, losses = jax.lax.scan(micro, acc0, mbs)
                # stay in accum dtype; the optimizer casts per-leaf (avoids a
                # whole-tree f32 transient)
                grads = jax.tree.map(lambda g_: g_ / k, acc)
                loss = jnp.mean(losses)
            new_params, new_state, metrics = adamw.apply_updates(
                opt_cfg, params, grads, opt_state)
            metrics = dict(metrics, loss=loss)
            return new_params, new_state, metrics

        return train_step


def build(cfg: ArchConfig) -> Model:
    return Model(cfg)


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins per (arch x shape)
# ---------------------------------------------------------------------------

def batch_spec(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ParamSpec tree for one data batch of the given workload shape."""
    B, S = shape.global_batch, shape.seq_len
    out = {
        "tokens": ParamSpec((B, S), ("batch", "seq_sp" if B == 1 else "seq"),
                            "int32"),
        "labels": ParamSpec((B, S), ("batch", "seq_sp" if B == 1 else "seq"),
                            "int32"),
    }
    if cfg.n_prefix_tokens:
        out["prefix"] = ParamSpec((B, cfg.n_prefix_tokens, cfg.d_model),
                                  ("batch", None, "act_embed"), "float32")
    if cfg.is_encoder_decoder:
        out["frames"] = ParamSpec((B, cfg.encoder_seq, cfg.d_model),
                                  ("batch", None, "act_embed"), "float32")
    return out


def decode_input_spec(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    B = shape.global_batch
    out = {"token": ParamSpec((B, 1), ("batch", None), "int32")}
    if B == 1:
        out["token"] = ParamSpec((B, 1), (None, None), "int32")
    return out


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh=None, rules=None):
    """ShapeDtypeStructs (with shardings if mesh given) for the dry-run."""
    if shape.kind in ("train", "prefill"):
        spec = batch_spec(cfg, shape)
    else:
        spec = {
            "cache": tfm.cache_spec(cfg, shape.global_batch, shape.seq_len),
            **decode_input_spec(cfg, shape),
        }
    return abstract_tree(spec, mesh, rules)
