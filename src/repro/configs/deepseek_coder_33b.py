"""DeepSeek-Coder-33B: llama-arch GQA kv=8.  [arXiv:2401.14196]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    attention="full",
    rope_theta=100_000.0,
    norm="rmsnorm",
    act="silu",
    mlp="glu",
    microbatch_rows_per_device=1,
    source="arXiv:2401.14196 (hf)",
))
