"""DBRX-132B: fine-grained MoE, 16 experts top-4.  [hf:databricks/dbrx-base]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,           # GQA
    d_ff=10752,             # per-expert GLU hidden
    vocab_size=100352,
    n_experts=16,
    top_k=4,
    attention="full",
    rope_theta=500_000.0,
    norm="layernorm",
    act="silu",
    mlp="glu",
    microbatch_rows_per_device=1,
    source="hf:databricks/dbrx-base (unverified)",
))
