"""InternVL2-1B: Qwen2-0.5B LM backbone + InternViT frontend STUBBED —
input_specs provides precomputed patch embeddings prepended to the token
sequence (early fusion).  [arXiv:2404.16821]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,       # padded to 151680 for TP sharding
    n_prefix_tokens=256,     # ViT patch embeddings (stub)
    attention="full",
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    act="silu",
    mlp="glu",
    tie_embeddings=True,
    microbatch_rows_per_device=16,
    source="arXiv:2404.16821 (hf)",
))
