"""xLSTM-125M: alternating mLSTM/sLSTM blocks, no separate FFN (d_ff=0,
projections live inside the blocks).  [arXiv:2405.04517]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "slstm"),
    mlstm_chunk=256,
    attention="full",        # unused; recurrence is sub-quadratic
    norm="layernorm",
    act="gelu",
    microbatch_rows_per_device=8,
    source="arXiv:2405.04517 (unverified)",
))
