"""StarCoder2-3B: GQA kv=2, RoPE, sliding-window 4096 attention.
[arXiv:2402.19173]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    attention="sliding",
    window=4096,
    rope_theta=999_999.0,
    norm="layernorm",
    act="gelu",
    mlp="dense",
    microbatch_rows_per_device=4,
    source="arXiv:2402.19173 (hf)",
))
