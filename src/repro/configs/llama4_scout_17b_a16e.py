"""Llama-4 Scout 17B-active/16E: MoE top-1 + shared expert, iRoPE
(3/4 layers chunked-local attention, every 4th layer global NoPE).
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    n_experts=16,
    top_k=1,
    shared_expert=True,
    attention="chunked_global",
    chunk=8192,
    global_every=4,
    rope_theta=500_000.0,
    norm="rmsnorm",
    act="silu",
    mlp="glu",
    microbatch_rows_per_device=1,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (unverified)",
))
