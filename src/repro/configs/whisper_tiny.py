"""Whisper-tiny: encoder-decoder, conv audio frontend STUBBED — input_specs
provides precomputed (batch, 1500, d_model) frame embeddings.  [arXiv:2212.04356]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,              # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,        # padded to 51968 for TP sharding
    is_encoder_decoder=True,
    n_encoder_layers=4,
    encoder_seq=1500,
    attention="full",
    norm="layernorm",
    act="gelu",
    mlp="dense",
    tie_embeddings=True,
    microbatch_rows_per_device=16,
    source="arXiv:2212.04356 (unverified)",
))
