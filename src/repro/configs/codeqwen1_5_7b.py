"""CodeQwen1.5-7B: qwen1.5 arch, full MHA (kv=32).  [hf:Qwen/CodeQwen1.5-7B]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    attention="full",
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    act="silu",
    mlp="glu",
    decode_kv_shard="heads",    # 32 kv heads shard cleanly over model=16
    microbatch_rows_per_device=2,
    source="hf:Qwen/CodeQwen1.5-7B (hf)",
))
