"""Config registry: one module per assigned architecture."""
from .base import (ArchConfig, ShapeConfig, SHAPES, get_config, list_configs,
                   reduced, register)

from . import (dbrx_132b, llama4_scout_17b_a16e, whisper_tiny, xlstm_125m,
               starcoder2_3b, codeqwen1_5_7b, deepseek_coder_33b, granite_20b,
               internvl2_1b, recurrentgemma_9b)

ALL_ARCHS = [
    dbrx_132b.CONFIG,
    llama4_scout_17b_a16e.CONFIG,
    whisper_tiny.CONFIG,
    xlstm_125m.CONFIG,
    starcoder2_3b.CONFIG,
    codeqwen1_5_7b.CONFIG,
    deepseek_coder_33b.CONFIG,
    granite_20b.CONFIG,
    internvl2_1b.CONFIG,
    recurrentgemma_9b.CONFIG,
]
