"""RecurrentGemma-9B: RG-LRU recurrent blocks + local attention (window
2048), repeating pattern (recurrent, recurrent, attention).  [arXiv:2402.19427]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,            # MQA in the local-attention blocks
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    block_pattern=("rglru", "rglru", "attn"),
    lru_width=4096,
    conv_width=4,
    attention="sliding",
    window=2048,
    norm="rmsnorm",
    scale_embed=True,
    act="gelu",
    mlp="glu",
    microbatch_rows_per_device=2,
    source="arXiv:2402.19427 (unverified)",
))
