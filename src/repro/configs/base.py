"""Architecture + shape configuration system.

Every assigned architecture is an :class:`ArchConfig`; every workload shape an
entry of :data:`SHAPES`.  ``applicable_shapes`` encodes the skip rules
(DESIGN.md §4): ``long_500k`` only for sub-quadratic-attention archs; decode
shapes for everything with a decoder (all ten archs here).
"""
from __future__ import annotations

import dataclasses
import math


# ---------------------------------------------------------------------------
# Shapes (LM family: seq_len x global_batch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


# ---------------------------------------------------------------------------
# Architectures
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    # backbone dims
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # attention flavour
    attention: str = "full"          # full | sliding | chunked_global
    window: int = 0                  # sliding-window size (starcoder2, rg local)
    chunk: int = 0                   # local-chunk size (llama4 iRoPE)
    global_every: int = 0            # every k-th layer global (llama4: 4)
    rope_theta: float = 10_000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_impl: str = "dense"          # dense | ragged  (perf-iteration knob)
    shared_expert: bool = False      # llama4: one always-on shared expert
    # recurrent families
    block_pattern: tuple = ()        # e.g. ("rglru","rglru","attn") repeating
    lru_width: int = 0               # RG-LRU state width
    conv_width: int = 4              # temporal conv in recurrent blocks
    mlstm_chunk: int = 256           # chunkwise-parallel mLSTM chunk
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0             # stub frontend output length (1500 frames)
    # vlm prefix (internvl)
    n_prefix_tokens: int = 0         # precomputed patch embeddings, stubbed
    # misc
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    scale_embed: bool = False        # multiply embeddings by sqrt(d) (gemma)
    act: str = "silu"                # silu | gelu
    mlp: str = "glu"                 # glu | dense (2-matrix)
    tie_embeddings: bool = False
    # numerics / implementation
    head_pad_multiple: int = 1       # pad q-head count to this multiple (TP)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"              # full | none  (activation checkpointing)
    remat_group: int = 1             # checkpoint every g super-blocks
    microbatch_rows_per_device: int = 16   # batch rows/device per micro-step
    decode_kv_shard: str = "seq"     # seq | heads  (KV-cache model-axis shard)
    kv_cache_dtype: str = "bfloat16" # bfloat16 | int8 (quantized KV cache)
    prefill_waves: int = 1           # serve prefill in sequential batch waves
    source: str = ""                 # provenance note

    # -- derived -------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_groups(self) -> int:
        assert self.n_heads % max(self.n_kv_heads, 1) == 0
        return self.n_heads // self.n_kv_heads

    def padded_vocab(self, multiple: int = 256) -> int:
        return int(math.ceil(self.vocab_size / multiple) * multiple)

    # -- analytic parameter counts (for roofline MODEL_FLOPS & ckpt bytes) ---
    def _attn_params(self) -> int:
        dh = self.resolved_head_dim
        return (self.d_model * self.n_heads * dh          # wq
                + 2 * self.d_model * self.n_kv_heads * dh  # wk, wv
                + self.n_heads * dh * self.d_model)        # wo

    def _mlp_params(self, d_ff: int) -> int:
        if d_ff == 0:
            return 0
        n_mat = 3 if self.mlp == "glu" else 2
        return n_mat * self.d_model * d_ff

    def _layer_params(self, kind: str) -> int:
        d = self.d_model
        norms = 2 * d
        if kind == "attn":
            body = self._attn_params() + self._mlp_params(self.d_ff)
        elif kind == "moe":
            router = d * self.n_experts
            experts = self.n_experts * self._mlp_params(self.d_ff)
            if self.shared_expert:
                experts += self._mlp_params(self.d_ff)
            body = self._attn_params() + router + experts
        elif kind == "rglru":
            w = self.lru_width or d
            # in/out projections, conv, block-diagonal gates (per head),
            # lambda + gated-mlp block
            body = (2 * d * w + w * d + self.conv_width * w
                    + 2 * w * w // max(self.n_heads, 1)
                    + 2 * w + self._mlp_params(self.d_ff))
        elif kind == "mlstm":
            w = 2 * d   # up-projection factor 2 (xLSTM paper)
            body = (d * 2 * w + w * d        # up (x2), down
                    + 3 * w * w // 1         # q,k,v within inner dim
                    + 3 * w)                 # i,f,o gate projections (scalar per head simplified)
        elif kind == "slstm":
            body = 4 * d * d + 4 * d * d + self._mlp_params(
                int(4 * d / 3) if self.d_ff == 0 else self.d_ff)
        else:
            raise ValueError(kind)
        return body + norms

    def layer_kinds(self) -> list:
        """Per-layer block kind, honoring block_pattern / moe / global_every."""
        kinds = []
        for i in range(self.n_layers):
            if self.block_pattern:
                kinds.append(self.block_pattern[i % len(self.block_pattern)])
            elif self.n_experts:
                kinds.append("moe")
            else:
                kinds.append("attn")
        return kinds

    def param_count(self) -> int:
        emb = self.padded_vocab() * self.d_model
        out = 0 if self.tie_embeddings else emb
        body = sum(self._layer_params(k) for k in self.layer_kinds())
        if self.is_encoder_decoder:
            # encoder stack (self-attn + mlp) + decoder cross-attn extra
            enc = self.n_encoder_layers * self._layer_params("attn")
            cross = self.n_layers * (self._attn_params() + self.d_model)
            body += enc + cross
        return emb + out + body + self.d_model  # final norm

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        expert_p = self._mlp_params(self.d_ff)
        inactive = (self.n_experts - self.top_k) * expert_p * sum(
            1 for k in self.layer_kinds() if k == "moe")
        return full - inactive

    def checkpoint_bytes(self, optimizer_slots: int = 2,
                         param_bytes: int = 4) -> int:
        """Bytes of a full training checkpoint: params + optimizer state.

        Default: fp32 params + 2 AdamW slots (m, v) in fp32.
        """
        return self.param_count() * param_bytes * (1 + optimizer_slots)

    def applicable_shapes(self) -> list:
        names = ["train_4k", "prefill_32k", "decode_32k"]
        if self.supports_long_context():
            names.append("long_500k")
        return [SHAPES[n] for n in names]

    def supports_long_context(self) -> bool:
        """Sub-quadratic attention state -> long_500k runs (DESIGN.md §4)."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.attention == "sliding" and self.window > 0:
            return True
        if self.attention == "chunked_global":
            return True      # llama4: bounded local KV; global layers seq-sharded
        return False


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    # populate registry lazily
    from . import ALL_ARCHS  # noqa: F401  (import side effect registers all)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    from . import ALL_ARCHS  # noqa: F401
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------

def reduced(cfg: ArchConfig, *, n_layers: int = 2, d_model: int = 64,
            n_heads: int = 4, seq_hint: int = 64) -> ArchConfig:
    """A tiny same-family config: few layers, small width, tiny vocab."""
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    if n_heads % n_kv:
        n_kv = 1
    pattern = cfg.block_pattern
    if pattern:
        n_layers = max(n_layers, len(pattern))
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=d_model // n_heads,
        d_ff=0 if cfg.d_ff == 0 else 4 * d_model,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        window=min(cfg.window, seq_hint // 2) if cfg.window else 0,
        chunk=min(cfg.chunk, seq_hint // 2) if cfg.chunk else 0,
        lru_width=d_model if cfg.lru_width else 0,
        mlstm_chunk=16,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 32) if cfg.encoder_seq else 0,
        n_prefix_tokens=min(cfg.n_prefix_tokens, 16) if cfg.n_prefix_tokens else 0,
        remat="none",
    )
