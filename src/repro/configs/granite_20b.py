"""Granite-20B code: MQA (kv=1), llama-arch.  [arXiv:2405.04324]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    attention="full",
    norm="layernorm",
    act="gelu",
    mlp="dense",
    microbatch_rows_per_device=1,
    source="arXiv:2405.04324 (hf)",
))
