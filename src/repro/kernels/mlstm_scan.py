"""Chunkwise-parallel mLSTM Pallas TPU kernel (xLSTM matrix memory).

Within a chunk the recurrence is expressed as MXU matmuls (quadratic in the
chunk length, like flash attention); across chunks the (Dh x Dh) matrix
memory C, normalizer n and max-stabilizer m are carried in VMEM scratch over
the sequential last grid axis.

Grid (B*H, n_chunks); blocks: q/k/v (1, L, Dh), gates (1, L).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, o_ref,
                  C_ref, n_ref, m_ref, *, L: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    q = q_ref[0].astype(jnp.float32)       # (L, Dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    li = li_ref[0].astype(jnp.float32)     # (L,)
    lf = lf_ref[0].astype(jnp.float32)

    C0 = C_ref[...]                        # (Dh, Dh)
    n0 = n_ref[:, 0]                       # (Dh,)   (col 0 holds data)
    m0 = m_ref[0, 0]                       # scalar

    b = jnp.cumsum(lf)                     # (L,)
    F = b[-1]

    intra = b[:, None] - b[None, :] + li[None, :]
    causal = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    intra = jnp.where(causal, intra, NEG_INF)
    m_intra = intra.max(axis=1)
    m_inter = m0 + b
    m_t = jnp.maximum(jnp.maximum(m_inter, m_intra), NEG_INF)

    g_inter = jnp.exp(m_inter - m_t)
    w_intra = jnp.where(causal, jnp.exp(intra - m_t[:, None]), 0.0)

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    scores = scores * w_intra
    h_num = (g_inter[:, None] * jax.lax.dot_general(
                q, C0, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
             + jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    n_t = g_inter * (q @ n0) + scores.sum(axis=1)
    denom = jnp.maximum(jnp.abs(n_t), jnp.exp(-m_t))
    o_ref[0, ...] = (h_num / denom[:, None]).astype(o_ref.dtype)

    # ---- state update to the end of the chunk
    s_exp = F - b + li                     # (L,)
    m_next = jnp.maximum(m0 + F, s_exp.max())
    decay = jnp.exp(m0 + F - m_next)
    w_new = jnp.exp(s_exp - m_next)        # (L,)
    C_ref[...] = decay * C0 + jax.lax.dot_general(
        k * w_new[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_new = decay * n0 + (k * w_new[:, None]).sum(axis=0)
    n_ref[...] = jnp.broadcast_to(n_new[:, None], n_ref.shape)
    m_ref[...] = jnp.broadcast_to(m_next[None, None], m_ref.shape)


def mlstm_scan(q, k, v, li, lf, *, chunk: int = 256,
               interpret: bool = False):
    """q,k,v: (BH, S, Dh) (q,k pre-scaled by Dh^-0.25 each or q by Dh^-0.5);
    li, lf: (BH, S) log input / log forget gates.  Returns (BH, S, Dh)."""
    BH, S, Dh = q.shape
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    grid = (BH, S // L)
    kernel = functools.partial(_mlstm_kernel, L=L)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, Dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, Dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, Dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L), lambda b, c: (b, c)),
            pl.BlockSpec((1, L), lambda b, c: (b, c)),
        ],
        out_specs=pl.BlockSpec((1, L, Dh), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Dh, Dh), jnp.float32),
            pltpu.VMEM((Dh, 128), jnp.float32),
            pltpu.VMEM((8, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, li, lf)
