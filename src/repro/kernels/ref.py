"""Pure-jnp oracles for every Pallas kernel.

Deliberately the SIMPLEST possible implementations (naive materialized
attention, stepwise recurrences) — independent of the chunked/blocked
formulations used by both the models and the kernels, so a test failure
localizes to the optimized code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_ref(q, k, v, *, causal=True, window=0, chunk=0):
    """Naive softmax attention.  q,k,v: (B, H, S, Dh); f32 math."""
    B, H, Sq, Dh = q.shape
    Skv = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (Dh ** -0.5)
    jq = jnp.arange(Sq)[:, None]
    jk = jnp.arange(Skv)[None, :]
    allow = jnp.ones((Sq, Skv), bool)
    if causal:
        allow &= jk <= jq
    if window:
        allow &= jk > jq - window
    if chunk:
        allow &= (jk // chunk) == (jq // chunk)
    s = jnp.where(allow[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(allow[None, None], p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def decode_ref(q1, k, v, *, length):
    """Single-token decode: q1 (B, H, Dh), cache k/v (B, H, S, Dh), attend to
    the first ``length`` positions."""
    s = jnp.einsum("bhd,bhkd->bhk", q1.astype(jnp.float32),
                   k.astype(jnp.float32)) * (q1.shape[-1] ** -0.5)
    mask = jnp.arange(k.shape[2]) < length
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p,
                      v.astype(jnp.float32)).astype(q1.dtype)


# ---------------------------------------------------------------------------
# RG-LRU linear scan:  h_t = a_t * h_{t-1} + b_t
# ---------------------------------------------------------------------------

def rglru_ref(a, b, h0):
    """a, b: (B, S, W) f32; h0: (B, W).  Stepwise lax.scan oracle."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    _, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                         (a.astype(jnp.float32).transpose(1, 0, 2),
                          b.astype(jnp.float32).transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)


# ---------------------------------------------------------------------------
# mLSTM: stepwise stabilized matrix-memory recurrence
# ---------------------------------------------------------------------------

def mlstm_ref(q, k, v, li, lf):
    """q,k,v: (B, H, S, Dh) (q,k pre-scaled); li/lf: (B, H, S) log gates.
    Stepwise oracle of the stabilized mLSTM (xLSTM paper)."""
    B, H, S, Dh = q.shape

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, lit, lft = xs
        m_new = jnp.maximum(lft + m, lit)
        f = jnp.exp(lft + m - m_new)
        i = jnp.exp(lit - m_new)
        C = f[..., None, None] * C + i[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = f[..., None] * n + i[..., None] * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)),
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    C0 = jnp.zeros((B, H, Dh, Dh), jnp.float32)
    n0 = jnp.zeros((B, H, Dh), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    xs = (q.astype(jnp.float32).transpose(2, 0, 1, 3),
          k.astype(jnp.float32).transpose(2, 0, 1, 3),
          v.astype(jnp.float32).transpose(2, 0, 1, 3),
          li.astype(jnp.float32).transpose(2, 0, 1),
          lf.astype(jnp.float32).transpose(2, 0, 1))
    _, hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return hs.transpose(1, 2, 0, 3)       # (B, H, S, Dh)


# ---------------------------------------------------------------------------
# Blockwise int8 quantization (checkpoint compression / grad compression)
# ---------------------------------------------------------------------------

def quant_ref(x, block: int = 128):
    """x: (N, D), D % block == 0.  Returns (int8 vals, f32 scales (N, D/block))."""
    N, D = x.shape
    xb = x.astype(jnp.float32).reshape(N, D // block, block)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(N, D), scale


def dequant_ref(q, scale, block: int = 128, dtype=jnp.float32):
    N, D = q.shape
    xb = q.reshape(N, D // block, block).astype(jnp.float32)
    return (xb * scale[..., None]).reshape(N, D).astype(dtype)
