"""Blockwise int8 quantize/dequantize Pallas TPU kernels.

The paper's central cost parameter is the checkpoint write time C: these
kernels compress checkpoint shards (and, optionally, gradients for
compressed all-reduce) with per-(row, 128-lane-group) absmax scales —
4x smaller payloads at ~0.4% RMS error, directly shrinking C and the I/O
energy term T_io * P_io.

Grid (N/bn, D/bd); each block computes its own scales — embarrassingly
parallel, VPU-only, memory-bound (the roofline is the HBM stream).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE_GROUP = 128


def _quant_kernel(x_ref, q_ref, s_ref, *, bd: int):
    x = x_ref[...].astype(jnp.float32)                # (bn, bd)
    bn = x.shape[0]
    xb = x.reshape(bn, bd // LANE_GROUP, LANE_GROUP)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0     # (bn, groups)
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127)
    q_ref[...] = q.reshape(bn, bd).astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref, *, bd: int):
    q = q_ref[...].astype(jnp.float32)
    bn = q.shape[0]
    qb = q.reshape(bn, bd // LANE_GROUP, LANE_GROUP)
    o_ref[...] = (qb * s_ref[...][..., None]).reshape(bn, bd).astype(
        o_ref.dtype)


def _snap(n: int, cap: int, step: int = 1) -> int:
    """Largest divisor of n that is <= cap and a multiple of step."""
    b = min(cap, n)
    b -= b % step
    while b >= step:
        if n % b == 0:
            return b
        b -= step
    return n


def quantize(x, *, bn: int = 256, bd: int = 512, interpret: bool = False):
    """x: (N, D) with D % 128 == 0.  Returns (int8 (N, D), f32 (N, D/128))."""
    N, D = x.shape
    bn = _snap(N, bn)
    bd = _snap(D, bd, LANE_GROUP)
    assert N % bn == 0 and D % bd == 0 and bd % LANE_GROUP == 0
    grid = (N // bn, D // bd)
    sg = bd // LANE_GROUP
    q, s = pl.pallas_call(
        functools.partial(_quant_kernel, bd=bd),
        grid=grid,
        in_specs=[pl.BlockSpec((bn, bd), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bn, sg), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, D), jnp.int8),
            jax.ShapeDtypeStruct((N, D // LANE_GROUP), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s


def dequantize(q, s, *, dtype=jnp.float32, bn: int = 256, bd: int = 512,
               interpret: bool = False):
    N, D = q.shape
    bn = _snap(N, bn)
    bd = _snap(D, bd, LANE_GROUP)
    assert N % bn == 0 and D % bd == 0 and bd % LANE_GROUP == 0
    grid = (N // bn, D // bd)
    sg = bd // LANE_GROUP
    return pl.pallas_call(
        functools.partial(_dequant_kernel, bd=bd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bn, sg), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, D), dtype),
        interpret=interpret,
    )(q, s)
