"""Flash-decoding Pallas TPU kernel: one query token against a long KV cache.

Grid (B*H, n_kv_blocks) with the KV axis sequential: running max /
denominator / output accumulator live in VMEM scratch, the output tile is
written on the last block.  Cache positions beyond ``length`` are masked
(ring-buffer semantics are resolved by the caller via ``length``).

This is the single-token analogue of ``flash_attention``; on TPU the
per-block work is a (1, kb) x (kb, Dh) MXU matmul pair — bandwidth-bound,
which is exactly why the KV cache is also offered int8-quantized at the
model level.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, kb: int, scale: float):
    j = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0]
    jk = j * kb + jax.lax.broadcasted_iota(jnp.int32, (1, kb), 1)
    allow = jk < length                                   # (1, kb)

    q = q_ref[0].astype(jnp.float32) * scale              # (1, Dh)
    k = k_ref[0].astype(jnp.float32)                      # (kb, Dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, kb)
    s = jnp.where(allow, s, NEG_INF)
    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, s.max())
    p = jnp.where(allow, jnp.exp(s - m_new), 0.0)         # (1, kb)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.broadcast_to(p.sum(), l_ref.shape)
    v = v_ref[0].astype(jnp.float32)                      # (kb, Dh)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (1, Dh)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(j == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[0, 0], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention(q1, k, v, length, *, kb: int = 512,
                     interpret: bool = False):
    """q1: (BH, 1, Dh); cache k/v: (BH, S, Dh); length: () int32 — number of
    valid cache slots.  Returns (BH, 1, Dh)."""
    BH, S, Dh = k.shape
    kb = min(kb, S)
    while S % kb:
        kb //= 2
    grid = (BH, S // kb)
    kernel = functools.partial(_decode_kernel, kb=kb, scale=Dh ** -0.5)
    length = jnp.asarray(length, jnp.int32).reshape(1)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Dh), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, kb, Dh), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, kb, Dh), lambda b, j: (b, j, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, Dh), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, 1, Dh), q1.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 128), jnp.float32),
            pltpu.VMEM((1, 128), jnp.float32),
            pltpu.VMEM((1, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q1, k, v, length)
