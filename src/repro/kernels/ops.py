"""jit'd public wrappers around the Pallas kernels.

Handles layout adaptation (model layouts <-> kernel layouts), padding to
block multiples, and backend dispatch: on CPU the kernels execute in
``interpret=True`` mode (Python emulation — used by all tests); on TPU they
lower to Mosaic.  ``force_interpret`` pins interpret mode for testing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import mlstm_scan as _ml
from . import quant_blockwise as _qb
from . import rglru_scan as _rg


def _interpret(force: bool | None) -> bool:
    if force is not None:
        return force
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Attention (model layout: q/k/v (B, S, H, Dh) flat heads)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("mode", "window", "chunk",
                                             "force_interpret"))
def flash_attention(q, k, v, *, mode: str = "causal", window: int = 0,
                    chunk: int = 0, force_interpret: bool | None = None):
    B, S, H, Dh = q.shape
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, t.shape[1], Dh)
    out = _fa.flash_attention(
        fold(q), fold(k), fold(v), mode=mode, window=window, chunk=chunk,
        qb=min(256, S), kb=min(256, k.shape[1]),
        interpret=_interpret(force_interpret))
    return out.reshape(B, H, S, Dh).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# RG-LRU scan (model layout: a/b (B, S, W), h0 (B, W))
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("force_interpret",))
def rglru_scan(a, b, h0, *, force_interpret: bool | None = None):
    B, S, W = a.shape
    return _rg.rglru_scan(a, b, h0, bb=min(8, B), sb=min(256, S),
                          wb=min(128, W),
                          interpret=_interpret(force_interpret))


# ---------------------------------------------------------------------------
# mLSTM (model layout: q/k/v (B, H, S, Dh); li/lf (B, H, S))
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk", "force_interpret"))
def mlstm_scan(q, k, v, li, lf, *, chunk: int = 256,
               force_interpret: bool | None = None):
    B, H, S, Dh = q.shape
    fold = lambda t: t.reshape(B * H, S, Dh)
    fold2 = lambda t: t.reshape(B * H, S)
    out = _ml.mlstm_scan(fold(q), fold(k), fold(v), fold2(li), fold2(lf),
                         chunk=min(chunk, S),
                         interpret=_interpret(force_interpret))
    return out.reshape(B, H, S, Dh)


# ---------------------------------------------------------------------------
# Blockwise int8 quantization (arbitrary arrays)
# ---------------------------------------------------------------------------

def _pad_of(size: int) -> tuple:
    D = 512 if size >= 512 else 128
    return (-size) % D, D


@functools.partial(jax.jit, static_argnames=("pad", "D", "force_interpret"))
def _quantize_2d(x, *, pad: int, D: int, force_interpret: bool | None):
    flat = x.reshape(-1)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    x2 = flat.reshape(-1, D)
    return _qb.quantize(x2, bn=min(256, x2.shape[0]),
                        interpret=_interpret(force_interpret))


def quantize_array(x, *, force_interpret: bool | None = None):
    """Quantize ANY-shaped array; returns (int8 2-D payload, scales, pad)."""
    pad, D = _pad_of(x.size)
    q, s = _quantize_2d(x, pad=pad, D=D, force_interpret=force_interpret)
    return q, s, pad


@functools.partial(jax.jit, static_argnames=("shape", "dtype", "pad",
                                             "force_interpret"))
def dequantize_array(q, s, *, shape, dtype, pad: int,
                     force_interpret: bool | None = None):
    x2 = _qb.dequantize(q, s, dtype=jnp.dtype(dtype),
                        bn=min(256, q.shape[0]),
                        interpret=_interpret(force_interpret))
    flat = x2.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)
