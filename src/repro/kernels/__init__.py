"""Pallas TPU kernels (validated in interpret mode on CPU):

  flash_attention — online-softmax attention (causal/sliding/chunked/bidir)
  rglru_scan      — RG-LRU linear recurrence (RecurrentGemma)
  mlstm_scan      — chunkwise-parallel mLSTM matrix memory (xLSTM)
  quant_blockwise — int8 blockwise (de)quantization for checkpoint/grad
                    compression (shrinks the paper's C parameter)
  event_sweep     — the sim engine's event-level MC loop as a blocked
                    (points x trials) kernel with all-done early exit
                    (``engine_kind="pallas"``; oracle = the lax.scan
                    engine itself, pinned bit-for-bit in f64)

Each kernel has a pure-jnp oracle in ``ref.py`` and a jit'd public wrapper in
``ops.py``; ``event_sweep`` lives in its own module and is reached through
``sim.engine.simulate_trajectories(engine_kind="pallas")``.
"""
from . import ops, ref
