"""Pallas TPU kernels (validated in interpret mode on CPU):

  flash_attention — online-softmax attention (causal/sliding/chunked/bidir)
  rglru_scan      — RG-LRU linear recurrence (RecurrentGemma)
  mlstm_scan      — chunkwise-parallel mLSTM matrix memory (xLSTM)
  quant_blockwise — int8 blockwise (de)quantization for checkpoint/grad
                    compression (shrinks the paper's C parameter)

Each kernel has a pure-jnp oracle in ``ref.py`` and a jit'd public wrapper in
``ops.py``.
"""
from . import ops, ref
