"""RG-LRU linear-recurrence Pallas TPU kernel:  h_t = a_t * h_{t-1} + b_t.

The recurrence is memory-bound VPU work (no MXU): the kernel streams
time-blocks through VMEM while the carry state h lives in a VMEM scratch
across the sequential last grid axis.

Grid (n_batch_blocks, n_width_blocks, n_time_blocks); blocks (bb, sb, wb)
with wb a lane multiple (128) and bb x sb sized to keep the working set
(2 input blocks + 1 output block + carry) within VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, h_ref, *, sb: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)     # (bb, sb, wb)
    b = b_ref[...].astype(jnp.float32)
    h = h_ref[...]                         # (bb, wb)

    def step(s, h):
        h = a[:, s, :] * h + b[:, s, :]
        o_ref[:, s, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, sb, step, h)
    h_ref[...] = h


def rglru_scan(a, b, h0, *, bb: int = 8, sb: int = 256, wb: int = 128,
               interpret: bool = False):
    """a, b: (B, S, W); h0: (B, W).  Returns h: (B, S, W) (same dtype as b).

    Linear scan with per-timestep decay — the RG-LRU inner loop
    (RecurrentGemma) after gates/projections are computed by XLA.
    """
    B, S, W = a.shape
    bb = min(bb, B)
    sb = min(sb, S)
    wb = min(wb, W)
    assert B % bb == 0 and S % sb == 0 and W % wb == 0, (a.shape, bb, sb, wb)
    grid = (B // bb, W // wb, S // sb)     # time last = sequential carry
    kernel = functools.partial(_rglru_kernel, sb=sb)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, sb, wb), lambda i, w, t: (i, t, w)),
            pl.BlockSpec((bb, sb, wb), lambda i, w, t: (i, t, w)),
            pl.BlockSpec((bb, wb), lambda i, w, t: (i, w)),
        ],
        out_specs=pl.BlockSpec((bb, sb, wb), lambda i, w, t: (i, t, w)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), b.dtype),
        scratch_shapes=[pltpu.VMEM((bb, wb), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
