"""Pallas kernel for the event-level MC sweep (``engine_kind="pallas"``).

The sim engine's fast path (``sim/engine.py::_run_one_event``) is a
``lax.scan`` with one iteration per FAILURE, double-vmapped over
(grid points x trials).  This kernel is the accelerator-native port:

* grid = ``(points/bp, trials/bt)`` blocks; each block owns its
  ``(bp, bt)`` tile of trajectory state in registers/VMEM and streams the
  failure-gap schedule ``(bp, bt, F)`` through VMEM, one gap slab per
  loop iteration via a dynamic slice on the capacity axis.
* the closed-form between-failure arithmetic is kept TERM-FOR-TERM from
  ``_run_one_event`` (same expressions, same parenthesization, same
  select ordering), so in f64 the kernel is bit-identical to the scan —
  the dyadic-schedule parity tests assert exactly that.
* the gap index needs no per-lane gather: an ACTIVE (not-done) lane at
  loop iteration ``i`` has seen exactly ``i`` failures (any earlier
  completion freezes the lane through the done-select), so
  ``n_fail == i`` and one uniform slab load per iteration serves every
  active lane; done lanes read a stale slab and discard it in the same
  select the scan kernel uses.
* unlike the fixed-length scan, the loop is a ``while_loop`` that exits
  as soon as every lane in the block is done.  Post-completion
  iterations are identities under the done-select, so the exit is
  bit-exact — it only skips the power-of-two padding tail the scan
  kernel burns through, which is where the speedup on CPU interpret
  mode comes from (BENCH_sweep.json ``pallas_event_engine``).

Precision follows the engine's :class:`~repro.sim.precision
.PrecisionPolicy`: under ``f64`` the state updates are the scan
kernel's verbatim; under a compensated policy every running-sum state
(wall, committed, work, io, down) becomes a Neumaier pair ``(s, c)``
(``sim/precision.py::comp_add``), branch contributions are formed as
increments and selected BEFORE accumulation, and the remaining-work
read uses the corrected ``committed + c`` — the parity gates in
tests/test_pallas_engine.py bound the result against the f64 oracle.

On CPU the wrapper falls back to ``pallas_call(..., interpret=True)``
(traced to plain XLA ops, jit-compatible) so tier-1 parity runs
everywhere; on TPU it lowers to Mosaic.  The full capacity axis rides
in one block — at the default tile ``8 x 128`` lanes an f32 schedule
budget of F = 4096 gaps is ~16 MiB of VMEM; shrink ``block_trials``
for fatter schedules.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from ..sim.precision import comp_add

#: work-completion slack — MUST match sim/engine.py::_EPS term-for-term.
_EPS = 1e-12


def _interpret(force: bool | None) -> bool:
    if force is not None:
        return force
    return jax.default_backend() != "tpu"


def _event_kernel(T_ref, C_ref, R_ref, D_ref, O_ref, TB_ref, gaps_ref,
                  wall_ref, work_ref, io_ref, down_ref, nfail_ref,
                  nckpt_ref, trunc_ref, ginf_ref, *, n_steps: int,
                  n_gaps: int, compensated: bool):
    f = gaps_ref.dtype
    zero = jnp.zeros((), f)
    one = jnp.ones((), f)
    bp, bt = wall_ref.shape

    T = T_ref[...]                       # (bp, 1) — broadcasts over trials
    C = C_ref[...]
    R = R_ref[...]
    D = D_ref[...]
    omega = O_ref[...]
    T_base = TB_ref[...]
    Tc = T - C                           # compute-segment length
    w = T - (one - omega) * C            # work committed per full period
    omega_safe = jnp.where(omega > zero, omega, one)

    fz = jnp.zeros((bp, bt), f)
    iz = jnp.zeros((bp, bt), jnp.int32)
    bz = jnp.zeros((bp, bt), jnp.bool_)
    # state = (i, wall, committed, work_exec, io_time, down_time,
    #          n_fail, n_ckpt, used_inf, done [, 5 Neumaier c-terms])
    state = (jnp.zeros((), jnp.int32), fz, fz, fz, fz, fz, iz, iz, bz, bz)
    if compensated:
        state = state + (fz, fz, fz, fz, fz)

    def cond(state):
        return (state[0] < n_steps) & jnp.logical_not(jnp.all(state[9]))

    def body(state):
        (i, wall, committed, work_exec, io_time, down_time,
         n_fail, n_ckpt, used_inf, done) = state[:10]
        if compensated:
            c_wall, c_comm, c_work, c_io, c_down = state[10:]

        # Uniform slab read: active lanes have n_fail == i (see module
        # docstring), so one dynamic slice on the capacity axis replaces
        # the scan kernel's per-lane gather; past-the-schedule reads are
        # inf == "no more failures", flagging exhaustion.
        in_range = i < n_gaps
        gi = jnp.minimum(i, n_gaps - 1)
        slab = pl.load(gaps_ref, (slice(None), slice(None),
                                  pl.dslice(gi, 1)))[:, :, 0]
        g = jnp.where(in_range, slab, jnp.asarray(jnp.inf, f))

        # ---- closed-form completion time from this segment start ----
        # (verbatim from sim/engine.py::_run_one_event)
        committed_true = committed + c_comm if compensated else committed
        rem = T_base - committed_true
        j = jnp.maximum(jnp.floor((rem - _EPS) / w), zero)
        r = rem - j * w
        rr = r - Tc
        t_in = jnp.where(rr > zero, Tc + rr / omega_safe, r)
        t_fin = j * T + t_in
        complete = t_fin < g

        # ---- branch B geometry: failure at s = g after segment start ----
        s = jnp.where(jnp.isfinite(g), g, zero)
        k = jnp.floor(s / T)
        k = jnp.where((k > zero) & (k * T >= s), k - one, k)
        u = s - k * T
        uc = u - Tc

        def sel(a_val, b_val):
            return jnp.where(complete, a_val, b_val)

        keep = lambda old, upd: jnp.where(done, old, upd)

        if not compensated:
            wall_a = wall + t_fin
            work_a = work_exec + rem
            io_a = io_time + j * C + jnp.maximum(rr, zero) / omega_safe
            work_b = work_exec + k * w + jnp.where(uc > zero,
                                                   Tc + omega * uc, u)
            io_b = io_time + k * C + jnp.maximum(uc, zero) + R
            wall_b = (wall + s) + D + R
            committed_b = jnp.where(k >= one,
                                    committed + (k - one) * w + Tc,
                                    committed)
            new = (sel(wall_a, wall_b),
                   sel(committed, committed_b),
                   sel(work_a, work_b),
                   sel(io_a, io_b),
                   sel(down_time, down_time + D),
                   sel(n_fail, n_fail + 1).astype(jnp.int32),
                   (n_ckpt + sel(j, k).astype(jnp.int32)).astype(jnp.int32),
                   jnp.logical_or(used_inf, ~in_range),
                   jnp.logical_or(done, complete))
            return (i + 1,) + tuple(
                keep(o, u_) for o, u_ in zip(state[1:10], new))

        # Compensated policy: form each branch's CONTRIBUTION, select it,
        # then fold it into the Neumaier pair; the done-select freezes
        # both pair members, preserving the s + c invariant lane-by-lane.
        inc_wall = sel(t_fin, s + D + R)
        inc_comm = sel(zero, jnp.where(k >= one, (k - one) * w + Tc, zero))
        inc_work = sel(rem, k * w + jnp.where(uc > zero,
                                              Tc + omega * uc, u))
        inc_io = sel(j * C + jnp.maximum(rr, zero) / omega_safe,
                     k * C + jnp.maximum(uc, zero) + R)
        inc_down = sel(zero, D)
        pairs = [comp_add(s_, c_, x_) for s_, c_, x_ in (
            (wall, c_wall, inc_wall), (committed, c_comm, inc_comm),
            (work_exec, c_work, inc_work), (io_time, c_io, inc_io),
            (down_time, c_down, inc_down))]
        new = tuple(p[0] for p in pairs) + (
            sel(n_fail, n_fail + 1).astype(jnp.int32),
            (n_ckpt + sel(j, k).astype(jnp.int32)).astype(jnp.int32),
            jnp.logical_or(used_inf, ~in_range),
            jnp.logical_or(done, complete))
        new_c = tuple(p[1] for p in pairs)
        return ((i + 1,)
                + tuple(keep(o, u_) for o, u_ in zip(state[1:10], new))
                + tuple(keep(o, u_) for o, u_ in zip(state[10:], new_c)))

    state = lax.while_loop(cond, body, state)
    (_, wall, committed, work_exec, io_time, down_time,
     n_fail, n_ckpt, used_inf, done) = state[:10]
    if compensated:
        c_wall, c_comm, c_work, c_io, c_down = state[10:]
        wall = wall + c_wall
        work_exec = work_exec + c_work
        io_time = io_time + c_io
        down_time = down_time + c_down
    wall_ref[...] = wall
    work_ref[...] = work_exec
    io_ref[...] = io_time
    down_ref[...] = down_time
    nfail_ref[...] = n_fail
    nckpt_ref[...] = n_ckpt
    trunc_ref[...] = ~done
    ginf_ref[...] = used_inf


def event_sweep(T, C, R, D, omega, T_base, gaps, *, n_steps: int,
                dtype="float64", compensated: bool = False,
                block_points: int = 8, block_trials: int = 128,
                force_interpret: bool | None = None) -> dict:
    """Run the event kernel over a ``(B,) x (B, N, F)`` workload.

    ``T``/``C``/``R``/``D``/``omega``/``T_base``: per-grid-point scalars,
    shape ``(B,)``; ``gaps``: failure schedules ``(B, N, F)``.  Returns
    the engine's per-trajectory output dict, shape ``(B, N)`` per key
    (floats delivered in f64 like the scan kernels, whatever the compute
    ``dtype``; cast back happens under the caller's x64 context).

    Inputs are padded to block multiples by edge replication — replica
    lanes complete exactly like the originals, so the all-done early
    exit still fires; their outputs are sliced off.
    """
    dt = jnp.dtype(dtype)
    gaps = jnp.asarray(gaps, dt)
    B, N, F = gaps.shape
    bp = min(int(block_points), B)  # reprolint: disable=RPL004 (keyword-only static Python int by contract — block shapes must be concrete to build the pallas grid)
    bt = min(int(block_trials), N)  # reprolint: disable=RPL004 (keyword-only static Python int by contract — block shapes must be concrete to build the pallas grid)
    Bp = -(-B // bp) * bp
    Np = -(-N // bt) * bt
    col = lambda x: jnp.pad(jnp.asarray(x, dt).reshape(B, 1),
                            ((0, Bp - B), (0, 0)), mode="edge")
    gaps = jnp.pad(gaps, ((0, Bp - B), (0, Np - N), (0, 0)), mode="edge")

    kernel = functools.partial(_event_kernel, n_steps=int(n_steps),  # reprolint: disable=RPL004 (static loop bound — the while_loop's worst-case trip count is baked into the kernel)
                               n_gaps=F, compensated=bool(compensated))
    pspec = pl.BlockSpec((bp, 1), lambda i, j: (i, 0))
    ospec = pl.BlockSpec((bp, bt), lambda i, j: (i, j))
    oshape = lambda d: jax.ShapeDtypeStruct((Bp, Np), d)
    outs = pl.pallas_call(
        kernel,
        grid=(Bp // bp, Np // bt),
        in_specs=[pspec] * 6 + [pl.BlockSpec((bp, bt, F),
                                             lambda i, j: (i, j, 0))],
        out_specs=[ospec] * 8,
        out_shape=[oshape(dt)] * 4 + [oshape(jnp.int32)] * 2
                  + [oshape(jnp.bool_)] * 2,
        interpret=_interpret(force_interpret),
    )(col(T), col(C), col(R), col(D), col(omega), col(T_base), gaps)
    wall, work, io, down, n_fail, n_ckpt, trunc, ginf = (
        o[:B, :N] for o in outs)
    as_f64 = lambda x: jnp.asarray(x, jnp.float64)
    return {"wall_time": as_f64(wall), "work_executed": as_f64(work),
            "io_time": as_f64(io), "down_time": as_f64(down),
            "n_failures": n_fail, "n_checkpoints": n_ckpt,
            "truncated": trunc, "gaps_exhausted": ginf}
