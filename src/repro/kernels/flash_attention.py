"""Flash attention Pallas TPU kernel: VMEM-tiled online-softmax.

Grid (B*H, n_q_blocks, n_kv_blocks); the last grid axis is sequential on TPU,
so the running max / denominator / output accumulator live in VMEM scratch
across KV blocks and the output tile is written once on the final block.

Block shapes are MXU/VPU-aligned: q/k tiles (qb, dh) with dh a multiple of
128 and qb a multiple of 8 (f32 sublanes); masks built from iota.

Supports causal, sliding-window, chunked-local and bidirectional masks —
the same semantics as ``repro.models.attention`` (this kernel is the TPU hot
path for train/prefill attention; XLA einsums remain the GSPMD dry-run path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _mask(mode: str, jq, jk, window: int, chunk: int):
    q = jq[:, None]
    k = jk[None, :]
    if mode == "bidir":
        return jnp.ones((jq.shape[0], jk.shape[0]), bool)
    m = k <= q
    if mode == "sliding":
        m &= k > q - window
    elif mode == "chunked":
        m &= (k // chunk) == (q // chunk)
    return m


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  mode: str, window: int, chunk: int, qb: int, kb: int,
                  scale: float):
    i = pl.program_id(1)
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    jq = i * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)[:, 0]
    jk = j * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)[0, :]
    allow = _mask(mode, jq, jk, window, chunk)

    # Skip fully-masked blocks (free in interpret mode; on TPU this saves the
    # MXU work for out-of-band tiles — the FLOP win of banded attention).
    if mode == "bidir":
        run = j >= 0
    else:
        run = j * kb <= i * qb + (qb - 1)          # at/below the diagonal
        if mode == "sliding":
            run &= j * kb + kb > i * qb - window   # inside the band
        elif mode == "chunked":
            run &= (j * kb) // chunk == (i * qb + qb - 1) // chunk

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # (qb, dh)
        k = k_ref[0].astype(jnp.float32)                  # (kb, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = jnp.where(allow, s, NEG_INF)                  # (qb, kb)
        m_prev = m_ref[:, 0]                              # (qb,)
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(allow, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = (l_ref[...] * corr[:, None]
                      + jnp.broadcast_to(p.sum(axis=1)[:, None],
                                         l_ref.shape))
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)

    @pl.when(j == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, mode: str = "causal", window: int = 0,
                    chunk: int = 0, qb: int = 256, kb: int = 256,
                    interpret: bool = False):
    """q,k,v: (BH, S, Dh) flat-head layout.  Returns (BH, Sq, Dh)."""
    BH, Sq, Dh = q.shape
    Skv = k.shape[1]
    qb = min(qb, Sq)
    kb = min(kb, Skv)
    assert Sq % qb == 0 and Skv % kb == 0, (Sq, qb, Skv, kb)
    if mode == "chunked":
        assert chunk % kb == 0 or chunk >= Skv
    grid = (BH, Sq // qb, Skv // kb)
    kernel = functools.partial(
        _flash_kernel, mode=mode, window=window, chunk=chunk, qb=qb, kb=kb,
        scale=Dh ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qb, Dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kb, Dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kb, Dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, Dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, 128), jnp.float32),   # running max (col 0 used)
            pltpu.VMEM((qb, 128), jnp.float32),   # running denominator
            pltpu.VMEM((qb, Dh), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
