from .synthetic import SyntheticLM, DataConfig, for_arch
