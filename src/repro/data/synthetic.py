"""Deterministic synthetic LM data pipeline with checkpointable state.

Batches are a pure function of (seed, step): after a failure + restore the
iterator resumes from the checkpointed step and reproduces the exact token
stream — required for bit-exact resume tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    n_prefix_tokens: int = 0
    prefix_dim: int = 0
    encoder_seq: int = 0
    encoder_dim: int = 0


class SyntheticLM:
    """Zipf-ish token stream; next-token labels; optional stub modalities."""

    def __init__(self, cfg: DataConfig, step: int = 0):
        self.cfg = cfg
        self.step = step

    # --- checkpointable state -------------------------------------------
    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def restore(self, state: dict) -> None:
        assert state["seed"] == self.cfg.seed, "data seed mismatch"
        self.step = int(state["step"])

    # --- batches -----------------------------------------------------------
    def _tokens(self, rng: np.random.Generator, shape) -> np.ndarray:
        # Zipf-like marginal over the vocab (heavier head than uniform).
        u = rng.random(shape)
        z = (self.cfg.vocab_size ** u - 1.0) / (self.cfg.vocab_size - 1.0)
        return np.minimum((z * self.cfg.vocab_size).astype(np.int32),
                          self.cfg.vocab_size - 1)

    def peek(self, step: Optional[int] = None) -> dict:
        c = self.cfg
        s = self.step if step is None else step
        # reprolint: disable=RPL001 (host-side data pipeline: the stream is a pure function of (config seed, step), reconstructible at any step for resume)
        rng = np.random.default_rng((c.seed << 20) ^ s)
        toks = self._tokens(rng, (c.batch, c.seq_len + 1))
        batch = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }
        if c.n_prefix_tokens:
            batch["prefix"] = jnp.asarray(
                0.02 * rng.standard_normal(
                    (c.batch, c.n_prefix_tokens, c.prefix_dim)),
                dtype=jnp.float32)
        if c.encoder_seq:
            batch["frames"] = jnp.asarray(
                0.02 * rng.standard_normal(
                    (c.batch, c.encoder_seq, c.encoder_dim)),
                dtype=jnp.float32)
        return batch

    def __next__(self) -> dict:
        b = self.peek()
        self.step += 1
        return b

    def __iter__(self):
        return self


def for_arch(arch_cfg, batch: int, seq_len: int, seed: int = 0) -> SyntheticLM:
    return SyntheticLM(DataConfig(
        vocab_size=arch_cfg.vocab_size, batch=batch, seq_len=seq_len,
        seed=seed,
        n_prefix_tokens=arch_cfg.n_prefix_tokens,
        prefix_dim=arch_cfg.d_model if arch_cfg.n_prefix_tokens else 0,
        encoder_seq=arch_cfg.encoder_seq,
        encoder_dim=arch_cfg.d_model if arch_cfg.encoder_seq else 0,
    ))
