"""reprolint: contract-enforcing static analysis for the determinism stack.

The repo's headline guarantees — bit-exact no-op perf knobs, the ≤tol
certified serving contract, 1-4% predicted-vs-measured runtime — rest on
invariants that used to live only in docstrings: f64 everywhere in the
model/solver subsystems, threefry-keyed randomness, bounded registered
caches of compiled callables, no host synchronization inside jitted hot
paths, no Python control flow on traced values.  ``reprolint`` makes
those invariants machine-checkable as named, suppressible rules:

RPL001  unseeded / host randomness outside the approved seeded-RNG sites
RPL002  unbounded caches, or bounded caches invisible to ``cache_stats()``
RPL003  dtype-contract violations in the f64 subsystems (sim/core/serve)
RPL004  host synchronization reachable from jitted entry points
RPL005  Python branching on traced values inside ``lax.scan`` bodies
RPL006  suppression hygiene (unused or undocumented suppressions)

Run it::

    python -m repro.lint                # whole repo, exit 1 on violations
    python -m repro.lint src/repro/sim  # specific paths
    python -m repro.lint --list-suppressions

Suppress a deliberate exception *with a reason* (the reason is mandatory;
an undocumented suppression is itself a violation)::

    rng = np.random.default_rng(seed)  # reprolint: disable=RPL001 (legacy oracle stream)

The package is pure stdlib (``ast`` only) — no jax import — so the CI
lint job runs it without installing the numeric stack.  See
docs/contracts.md for the contract each rule enforces.
"""
from .context import Diagnostic, ModuleInfo, RepoContext, Suppression
from .engine import LintResult, run_lint
from .rules import ALL_RULES

__all__ = ["Diagnostic", "ModuleInfo", "RepoContext", "Suppression",
           "LintResult", "run_lint", "ALL_RULES"]
