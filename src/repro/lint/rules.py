"""The reprolint rules (RPL001–RPL005).

Each rule is a callable ``rule(ctx) -> List[Diagnostic]`` over a parsed
:class:`~repro.lint.context.RepoContext`.  RPL006 (suppression hygiene)
is not here — it runs in the engine after suppressions are applied,
because "unused" is only knowable post-suppression.

All name resolution goes through each module's recorded import aliases,
so ``import numpy as np`` / ``from jax import numpy as jnp`` /
``from . import engine as _engine`` all resolve to their canonical
dotted paths before matching.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Tuple

from .context import Diagnostic, ModuleInfo, RepoContext

# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def resolve(info: ModuleInfo, node: ast.AST) -> Optional[str]:
    """Canonical dotted path with the leading alias expanded.

    ``np.random.seed`` -> ``numpy.random.seed`` when ``np`` was imported
    as numpy; a from-imported name resolves to ``module.name``.
    """
    dotted = dotted_name(node)
    if dotted is None:
        return None
    head, _, rest = dotted.partition(".")
    if head in info.import_aliases:
        base = info.import_aliases[head]
        return f"{base}.{rest}" if rest else base
    if head in info.from_imports:
        mod, orig = info.from_imports[head]
        base = f"{mod}.{orig}" if mod else orig
        return f"{base}.{rest}" if rest else base
    return dotted


def _diag(info: ModuleInfo, node: ast.AST, code: str, msg: str) -> Diagnostic:
    return Diagnostic(info.rel, getattr(node, "lineno", 1),
                      getattr(node, "col_offset", 0), code, msg)


# ---------------------------------------------------------------------------
# RPL001 — unseeded / host randomness
# ---------------------------------------------------------------------------

#: files allowed to construct *seeded* host RNGs (the approved seeded-RNG
#: sites from the issue: failure injection, the load generator, and the
#: engine's host presampling fallbacks).
RPL001_ALLOWLIST = (
    "src/repro/ft/failures.py",
    "src/repro/serve/loadgen.py",
    "src/repro/sim/engine.py",
)

#: numpy.random attributes that are seeded-RNG *constructors*, not
#: global-state draws.
_NP_RNG_CONSTRUCTORS = {
    "default_rng", "Generator", "SeedSequence", "PCG64", "Philox",
    "MT19937", "RandomState", "BitGenerator",
}

#: call targets whose argument position is a *seed* — time.time() inside
#: one of these is nondeterministic seeding.
_SEED_SINKS = ("default_rng", "seed", "PRNGKey", "SeedSequence",
               "RandomState", "key")


def _time_call_inside(info: ModuleInfo, node: ast.Call) -> Optional[ast.Call]:
    for arg in list(node.args) + [kw.value for kw in node.keywords]:
        for sub in ast.walk(arg):
            if isinstance(sub, ast.Call):
                r = resolve(info, sub.func)
                if r in ("time.time", "time.time_ns", "time.monotonic",
                         "time.monotonic_ns"):
                    return sub
    return None


def rule_rpl001(ctx: RepoContext) -> List[Diagnostic]:
    out = []
    for info in ctx.modules:
        in_src = info.rel.startswith("src/")
        allowed_seeded = (not in_src) or info.rel in RPL001_ALLOWLIST
        for node in ast.walk(info.tree):
            if not isinstance(node, ast.Call):
                continue
            r = resolve(info, node.func)
            if r is None:
                continue
            tail = r.rsplit(".", 1)[-1]
            if tail in _SEED_SINKS:
                t = _time_call_inside(info, node)
                if t is not None:
                    out.append(_diag(
                        info, t, "RPL001",
                        "wall-clock time used as an RNG seed — "
                        "nondeterministic across runs; thread an explicit "
                        "seed instead"))
                    continue
            if r.startswith("numpy.random."):
                attr = r[len("numpy.random."):].split(".")[0]
                if attr not in _NP_RNG_CONSTRUCTORS:
                    out.append(_diag(
                        info, node, "RPL001",
                        f"global-state numpy RNG call np.random.{attr}() — "
                        "use jax.random with a threaded key, or a seeded "
                        "np.random.default_rng at an approved site"))
                elif attr in ("default_rng", "RandomState"):
                    if not node.args and not node.keywords:
                        out.append(_diag(
                            info, node, "RPL001",
                            f"unseeded np.random.{attr}() draws entropy "
                            "from the OS — pass an explicit seed"))
                    elif not allowed_seeded:
                        out.append(_diag(
                            info, node, "RPL001",
                            "seeded host RNG constructed outside the "
                            "approved sites (ft/failures.py, "
                            "serve/loadgen.py, sim/engine.py) — library "
                            "code must use jax.random keys"))
            elif r.split(".")[0] == "random" and (
                    "random" in info.import_aliases
                    or "random" == info.from_imports.get(
                        r.split(".")[-1], ("",))[0]):
                out.append(_diag(
                    info, node, "RPL001",
                    f"stdlib random call {r}() uses hidden global state — "
                    "use jax.random with a threaded key"))
            elif (info.from_imports.get(r.split(".")[0], ("",))[0]
                  == "random"):
                out.append(_diag(
                    info, node, "RPL001",
                    f"stdlib random call {r}() uses hidden global state — "
                    "use jax.random with a threaded key"))
    return out


# ---------------------------------------------------------------------------
# RPL002 — unbounded / unregistered caches
# ---------------------------------------------------------------------------


def _is_lru_cache(info: ModuleInfo, node: ast.AST) -> Optional[str]:
    """Resolve a decorator/call to 'lru_cache' or 'cache', else None."""
    target = node.func if isinstance(node, ast.Call) else node
    r = resolve(info, target)
    if r in ("functools.lru_cache", "lru_cache"):
        return "lru_cache"
    if r in ("functools.cache", "cache") and r.startswith("functools"):
        return "cache"
    return None


def rule_rpl002(ctx: RepoContext) -> List[Diagnostic]:
    out = []
    for info in ctx.modules:
        for node in ast.walk(info.tree):
            # functools.cache / lru_cache(maxsize=None): unbounded.
            kind = _is_lru_cache(info, node) if isinstance(
                node, (ast.Call, ast.Attribute, ast.Name)) else None
            if kind == "cache":
                out.append(_diag(
                    info, node, "RPL002",
                    "functools.cache is unbounded — use "
                    "functools.lru_cache with an explicit maxsize"))
            elif kind == "lru_cache" and isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg == "maxsize" and isinstance(
                            kw.value, ast.Constant) and kw.value.value is None:
                        out.append(_diag(
                            info, node, "RPL002",
                            "lru_cache(maxsize=None) is unbounded — compiled"
                            "-callable caches must be bounded (and visible "
                            "to cache_stats() where applicable)"))
            # LRUCache(...) without name=: invisible to cache_stats().
            if isinstance(node, ast.Call):
                r = resolve(info, node.func)
                if r is not None and r.rsplit(".", 1)[-1] == "LRUCache":
                    if not any(kw.arg == "name" for kw in node.keywords):
                        out.append(_diag(
                            info, node, "RPL002",
                            "LRUCache constructed without name= — it will "
                            "not register with the cache_stats() registry"))
        # module-level dict caches (`_FOO_CACHE = {}` and friends).
        for stmt in info.tree.body:
            target = None
            if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)):
                target, value = stmt.targets[0], stmt.value
            elif (isinstance(stmt, ast.AnnAssign)
                  and isinstance(stmt.target, ast.Name)
                  and stmt.value is not None):
                target, value = stmt.target, stmt.value
            if target is None or "cache" not in target.id.lower():
                continue
            is_dict = isinstance(value, ast.Dict) or (
                isinstance(value, ast.Call)
                and resolve(info, value.func) in ("dict", "builtins.dict"))
            if is_dict and info.rel.startswith("src/"):
                out.append(_diag(
                    info, stmt, "RPL002",
                    f"module-level dict cache '{target.id}' is unbounded "
                    "and invisible to cache_stats() — use "
                    "repro.sim.dispatch.LRUCache(maxsize, name=...)"))
    return out


# ---------------------------------------------------------------------------
# RPL003 — dtype contract in the f64 subsystems
# ---------------------------------------------------------------------------

RPL003_SUBSYSTEMS = ("src/repro/sim/", "src/repro/core/", "src/repro/serve/")

#: the ONE home for reduced-precision dtypes inside the f64 subsystems:
#: the PrecisionPolicy module.  Everything else must route through a
#: policy (``sim.dispatch.resolve_precision``), so the float32 checks are
#: waived here — the explicit-dtype constructor check still applies.
RPL003_PRECISION_MODULES = ("src/repro/sim/precision.py",)

#: constructors whose dtype must be explicit in the f64 subsystems, with
#: the positional index a dtype may legally occupy.
_DTYPE_CTORS = {"zeros": 1, "ones": 1, "arange": 3, "asarray": 1}


def _is_jnp_path(resolved: str, ctor: str) -> bool:
    return (resolved == f"jax.numpy.{ctor}"
            or resolved.endswith(f".jnp.{ctor}")
            or resolved == f"jnp.{ctor}")


def rule_rpl003(ctx: RepoContext) -> List[Diagnostic]:
    out = []
    for info in ctx.modules:
        if not info.rel.startswith(RPL003_SUBSYSTEMS):
            continue
        policy_module = info.rel in RPL003_PRECISION_MODULES
        for node in ast.walk(info.tree):
            if isinstance(node, ast.Call):
                r = resolve(info, node.func)
                if r is None:
                    continue
                ctor = r.rsplit(".", 1)[-1]
                if ctor in _DTYPE_CTORS and _is_jnp_path(r, ctor):
                    has_kw = any(kw.arg == "dtype" for kw in node.keywords)
                    has_pos = len(node.args) > _DTYPE_CTORS[ctor]
                    if not (has_kw or has_pos):
                        out.append(_diag(
                            info, node, "RPL003",
                            f"jnp.{ctor}() without an explicit dtype in an "
                            "f64 subsystem — pass dtype=jnp.float64 (or the "
                            "intended integer/bool dtype)"))
            if policy_module:
                continue
            if (isinstance(node, ast.Attribute) and node.attr == "float32"
                    and resolve(info, node) is not None
                    and resolve(info, node).split(".")[0] in (
                        "jax", "numpy", "jnp", "np")):
                out.append(_diag(
                    info, node, "RPL003",
                    "float32 dtype in an f64 subsystem — the model/solver "
                    "stack is f64-everywhere (docs/contracts.md); reduced "
                    "precision must route through a PrecisionPolicy "
                    "(repro.sim.precision)"))
            if isinstance(node, ast.Constant) and node.value == "float32":
                out.append(_diag(
                    info, node, "RPL003",
                    "'float32' dtype string in an f64 subsystem — route "
                    "through a PrecisionPolicy (repro.sim.precision)"))
    return out


# ---------------------------------------------------------------------------
# RPL005 — Python branching on traced values inside scan bodies
# ---------------------------------------------------------------------------

_STATIC_ATTRS = {"shape", "dtype", "ndim", "size"}
_STATIC_FUNCS = {"len", "isinstance", "type"}


def _dynamic_ref(node: ast.AST, tainted: set) -> bool:
    """Does ``node`` touch a tainted name outside static accessors?"""
    if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
        return False
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Name) and f.id in _STATIC_FUNCS:
            return False
    if isinstance(node, ast.Name):
        return node.id in tainted
    return any(_dynamic_ref(c, tainted) for c in ast.iter_child_nodes(node))


def _taint(fn: ast.AST) -> set:
    """Parameters of a scan body plus names derived from them."""
    args = fn.args
    tainted = {a.arg for a in (
        args.posonlyargs + args.args + args.kwonlyargs)}
    for a in (args.vararg, args.kwarg):
        if a is not None:
            tainted.add(a.arg)
    for _ in range(2):  # tiny fixed-point for chained assignments
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and _dynamic_ref(
                    node.value, tainted):
                for t in node.targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            tainted.add(n.id)
    return tainted


def _scan_bodies(info: ModuleInfo) -> List[Tuple[ast.AST, ast.AST]]:
    """(scan-call, body FunctionDef) pairs resolvable in this module."""
    defs: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(info.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, []).append(node)
    out = []
    for node in ast.walk(info.tree):
        if not isinstance(node, ast.Call):
            continue
        r = resolve(info, node.func)
        if r not in ("jax.lax.scan", "lax.scan"):
            continue
        if not node.args:
            continue
        body = node.args[0]
        if isinstance(body, ast.Call):  # functools.partial(step, ...)
            body = body.args[0] if body.args else None
        if isinstance(body, ast.Name) and body.id in defs:
            for d in defs[body.id]:
                out.append((node, d))
    return out


def rule_rpl005(ctx: RepoContext) -> List[Diagnostic]:
    out = []
    for info in ctx.modules:
        seen = set()
        for _, body in _scan_bodies(info):
            if id(body) in seen:
                continue
            seen.add(id(body))
            tainted = _taint(body)
            for node in ast.walk(body):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)) and node is not body:
                    continue  # nested defs judged via their own scan calls
                if isinstance(node, (ast.If, ast.While)) and _dynamic_ref(
                        node.test, tainted):
                    kw = "while" if isinstance(node, ast.While) else "if"
                    out.append(_diag(
                        info, node, "RPL005",
                        f"Python `{kw}` on a traced value inside the scan "
                        f"body '{body.name}' — tracing freezes one branch; "
                        "use jnp.where / lax.cond / lax.select"))
    return out


# ---------------------------------------------------------------------------

from .hotpath import rule_rpl004  # noqa: E402  (cycle-free, kept adjacent)

ALL_RULES: Sequence = (rule_rpl001, rule_rpl002, rule_rpl003,
                       rule_rpl004, rule_rpl005)
