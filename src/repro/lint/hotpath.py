"""RPL004 — host synchronization reachable from jitted entry points.

Builds a conservative call graph rooted at the repo's jit sites:

* defs decorated with ``@jit`` / ``@jax.jit`` /
  ``@functools.partial(jax.jit, ...)``,
* plain names passed to ``jax.jit(f)`` / ``shard_map(f, ...)``,
* ``build=`` keyword values handed to the dispatch layer
  (``sim/dispatch.py`` jits them): a Name roots that def, a factory
  call roots the factory's *nested* defs (the returned closures), and
  a lambda contributes the calls in its body,
* ``target=`` keyword values handed to ``threading.Thread`` (the ckpt
  flush controller and the serve worker spawn these): the worker body
  runs concurrently with traced steps, so a host sync inside it is the
  same device-contention bug as in a jitted body.  ``self.X`` targets
  resolve against the flat class-method index.

Reachability then closes over plain-name calls (local defs, nested
defs, from-imports, module-alias attribute calls) and over the
registry-dict pattern (``_KERNELS = {"step": _run_one, ...}`` — any
reference to the dict name pulls in every member).  Inside reachable
function bodies, host-sync operations — ``.item()``, ``.tolist()``,
``.block_until_ready()``, ``float()``/``int()`` on non-static values,
``np.asarray``/``np.array`` — are flagged: each one forces a device →
host transfer (or a trace error) in the middle of a compiled hot path.

Parameters named in a jit's ``static_argnames`` are exempt from the
``float()``/``int()`` check — they are Python values at trace time.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .context import Diagnostic, ModuleInfo, RepoContext

#: (module, function-name) — a node in the call graph.  Nested defs get
#: a dotted function name ("outer.inner").
Node = Tuple[str, str]

_JIT_NAMES = {"jax.jit", "jit", "jax.experimental.shard_map.shard_map",
              "shard_map"}


def _resolve(info: ModuleInfo, node: ast.AST) -> Optional[str]:
    from .rules import resolve
    return resolve(info, node)


def _is_jit_ref(info: ModuleInfo, node: ast.AST) -> bool:
    r = _resolve(info, node)
    return r is not None and (r in _JIT_NAMES or r.endswith(".shard_map"))


def _is_thread_ref(info: ModuleInfo, node: ast.AST) -> bool:
    r = _resolve(info, node)
    return r is not None and (r == "Thread" or r.endswith(".Thread"))


def _static_argnames(call: ast.Call) -> Set[str]:
    """Constant static_argnames from functools.partial(jax.jit, ...)."""
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            names = set()
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and isinstance(
                        sub.value, str):
                    names.add(sub.value)
            return names
    return set()


class _Module:
    """Per-module function table with dotted names for nested defs."""

    def __init__(self, info: ModuleInfo):
        self.info = info
        self.functions: Dict[str, ast.AST] = {}
        self.parent: Dict[str, Optional[str]] = {}
        self._index(info.tree.body, prefix="")

    def _index(self, body, prefix: str) -> None:
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                name = f"{prefix}{node.name}"
                self.functions[name] = node
                self.parent[name] = prefix[:-1] if prefix else None
                self._index(node.body, prefix=f"{name}.")
            elif isinstance(node, (ast.If, ast.Try, ast.With, ast.For,
                                   ast.While, ast.ClassDef)):
                # functions defined under module-level control flow (or in
                # classes) still participate, with a flat name.
                self._index(node.body, prefix=prefix)

    def children(self, name: str) -> List[str]:
        dot = f"{name}."
        return [n for n in self.functions
                if n.startswith(dot) and "." not in n[len(dot):]]


class CallGraph:
    def __init__(self, ctx: RepoContext):
        self.ctx = ctx
        # src modules keyed by dotted name; tests/benchmarks/examples by
        # repo-relative path (they can still root jits and call into src).
        self.mods: Dict[str, _Module] = {
            (info.module or info.rel): _Module(info)
            for info in ctx.modules}
        self.static_args: Dict[Node, Set[str]] = {}
        self.roots = self._find_roots()
        self.reachable = self._walk(self.roots)

    # -- roots ---------------------------------------------------------------

    def _root_from_expr(self, mod: _Module, scope: str,
                        expr: ast.AST, roots: Set[Node],
                        factory_call: bool = False) -> None:
        """Interpret a value handed to jit/shard_map/build=."""
        info = mod.info
        if isinstance(expr, ast.Name):
            name = self._lookup(mod, scope, expr.id)
            if name is not None:
                if factory_call:
                    owner = self.mods.get(name[0])
                    if owner is not None:
                        roots.update((name[0], c)
                                     for c in owner.children(name[1]))
                        # the factory body itself runs on host, but the
                        # closures it returns capture registry members
                        # (``kernel = _KERNELS[kind]``) — those members
                        # run in-trace, so they root too.
                        fbody = owner.functions[name[1]]
                        oinfo = owner.info
                        for sub in ast.walk(fbody):
                            if isinstance(sub, ast.Name):
                                for m in oinfo.registries.get(sub.id, ()):
                                    tgt = self._lookup(owner, name[1], m)
                                    if tgt is not None:
                                        roots.add(tgt)
                else:
                    roots.add(name)
        elif isinstance(expr, ast.Lambda):
            # a lambda body cannot contain statements; root the plain-name
            # functions it calls instead.
            for sub in ast.walk(expr.body):
                if isinstance(sub, ast.Call) and isinstance(
                        sub.func, ast.Name):
                    self._root_from_expr(mod, scope, sub.func, roots)
        elif isinstance(expr, ast.Call):
            # jit(shard_map(f, ...)) unwraps; build=_factory(...) roots the
            # factory's nested defs (its returned closures).
            if _is_jit_ref(info, expr.func):
                if expr.args:
                    self._root_from_expr(mod, scope, expr.args[0], roots)
            elif isinstance(expr.func, ast.Name):
                self._root_from_expr(mod, scope, expr.func, roots,
                                     factory_call=True)

    def _root_thread_target(self, mod: _Module, scope: str,
                            expr: ast.AST, roots: Set[Node]) -> None:
        """Root a ``threading.Thread(target=...)`` worker body.  The
        common repo shape is ``target=self._run`` — class methods are
        indexed flat, so the bare attribute name resolves directly."""
        if (isinstance(expr, ast.Attribute)
                and isinstance(expr.value, ast.Name)
                and expr.value.id == "self"
                and expr.attr in mod.functions):
            roots.add((mod.info.module or mod.info.rel, expr.attr))
        else:
            self._root_from_expr(mod, scope, expr, roots)

    def _find_roots(self) -> Set[Node]:
        roots: Set[Node] = set()
        for mname, mod in self.mods.items():
            info = mod.info
            for fname, fn in mod.functions.items():
                scope = mod.parent[fname] or ""
                for dec in fn.decorator_list:
                    statics: Set[str] = set()
                    is_jit = _is_jit_ref(info, dec)
                    if isinstance(dec, ast.Call):
                        if _is_jit_ref(info, dec.func):
                            is_jit = True
                            statics = _static_argnames(dec)
                        elif (_resolve(info, dec.func)
                              == "functools.partial" and dec.args
                              and _is_jit_ref(info, dec.args[0])):
                            is_jit = True
                            statics = _static_argnames(dec)
                    if is_jit:
                        roots.add((mname, fname))
                        self.static_args[(mname, fname)] = statics
            # jit/shard_map/build= call sites, resolved in their
            # lexical scope: module level plus each function's body
            # (so ``jax.jit(run_grid)`` inside a maker finds the
            # nested ``run_grid``).
            sites = [("", info.tree)] + [
                (fname, fn) for fname, fn in mod.functions.items()]
            for scope, tree in sites:
                for node in ast.walk(tree):
                    if not isinstance(node, ast.Call):
                        continue
                    if _is_jit_ref(info, node.func) and node.args:
                        self._root_from_expr(mod, scope, node.args[0],
                                             roots)
                    for kw in node.keywords:
                        if kw.arg == "build":
                            self._root_from_expr(mod, scope, kw.value,
                                                 roots)
                        elif (kw.arg == "target"
                              and _is_thread_ref(info, node.func)):
                            self._root_thread_target(mod, scope, kw.value,
                                                     roots)
        return roots

    # -- reachability --------------------------------------------------------

    def _lookup(self, mod: _Module, scope: str, name: str) -> Optional[Node]:
        """Resolve a plain name in ``scope`` to a call-graph node."""
        # innermost enclosing def first, then module level
        prefix = scope
        while True:
            cand = f"{prefix}.{name}" if prefix else name
            if cand in mod.functions:
                return (mod.info.module or mod.info.rel, cand)
            if not prefix:
                break
            prefix = mod.parent.get(prefix) or ""
        # from-imports into another linted module
        tgt = mod.info.from_imports.get(name)
        if tgt:
            tmod, tname = tgt
            other = self.mods.get(tmod)
            if other and tname in other.functions:
                return (tmod, tname)
        return None

    def _walk(self, roots: Set[Node]) -> Set[Node]:
        seen: Set[Node] = set()
        work = [r for r in roots]
        while work:
            node = work.pop()
            if node in seen:
                continue
            mname, fname = node
            mod = self.mods.get(mname)
            if mod is None or fname not in mod.functions:
                continue
            seen.add(node)
            info, fn = mod.info, mod.functions[fname]
            # nested defs of a reachable function run in-trace (scan
            # bodies, local closures)
            work.extend((mname, c) for c in mod.children(fname))
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Call):
                    if isinstance(sub.func, ast.Name):
                        tgt = self._lookup(mod, fname, sub.func.id)
                        if tgt:
                            work.append(tgt)
                    elif isinstance(sub.func, ast.Attribute) and isinstance(
                            sub.func.value, ast.Name):
                        # alias.attr(...) across modules
                        base = info.import_aliases.get(sub.func.value.id)
                        other = self.mods.get(base) if base else None
                        if other and sub.func.attr in other.functions:
                            work.append((base, sub.func.attr))
                elif isinstance(sub, ast.Name):
                    members = info.registries.get(sub.id)
                    if members:
                        for m in members:
                            tgt = self._lookup(mod, "", m)
                            if tgt:
                                work.append(tgt)
        return seen


# ---------------------------------------------------------------------------
# the rule
# ---------------------------------------------------------------------------

_SYNC_METHODS = {"item", "tolist", "block_until_ready"}


def rule_rpl004(ctx: RepoContext) -> List[Diagnostic]:
    graph = CallGraph(ctx)
    out: List[Diagnostic] = []
    for mname, fname in sorted(graph.reachable):
        mod = graph.mods[mname]
        info, fn = mod.info, mod.functions[fname]
        statics = graph.static_args.get((mname, fname), set())
        own_nested = {mod.functions[c] for c in mod.children(fname)}
        for sub in ast.walk(fn):
            if sub in own_nested or (
                    isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and sub is not fn):
                continue  # nested defs are reported as their own nodes
            if not isinstance(sub, ast.Call):
                continue
            f = sub.func
            if isinstance(f, ast.Attribute) and f.attr in _SYNC_METHODS:
                out.append(Diagnostic(
                    info.rel, sub.lineno, sub.col_offset, "RPL004",
                    f".{f.attr}() in '{fname}' (reachable from a jitted "
                    "entry point) forces a device->host sync — keep "
                    "reductions on device and sync once at the boundary"))
            elif isinstance(f, ast.Name) and f.id in ("float", "int"):
                arg = sub.args[0] if sub.args else None
                if isinstance(arg, ast.Constant):
                    continue
                if isinstance(arg, ast.Name) and arg.id in statics:
                    continue  # static_argnames are Python values at trace
                out.append(Diagnostic(
                    info.rel, sub.lineno, sub.col_offset, "RPL004",
                    f"{f.id}() on a possibly-traced value in '{fname}' "
                    "(reachable from a jitted entry point) — this is a "
                    "host sync or a trace error; use jnp casts"))
            else:
                r = _resolve(info, f)
                if r in ("numpy.asarray", "numpy.array"):
                    out.append(Diagnostic(
                        info.rel, sub.lineno, sub.col_offset, "RPL004",
                        f"np.{r.rsplit('.', 1)[-1]}() in '{fname}' "
                        "(reachable from a jitted entry point) pulls the "
                        "operand to host memory — use jnp.asarray"))
    return out
