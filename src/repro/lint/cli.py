"""``python -m repro.lint`` — the reprolint command line."""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import Optional, Sequence

from .engine import run_lint


def _repo_root(start: Path) -> Path:
    """Nearest ancestor containing pyproject.toml (else cwd)."""
    for p in [start] + list(start.parents):
        if (p / "pyproject.toml").exists():
            return p
    return start


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="Contract-enforcing static analysis for the repo "
                    "(rules RPL001-RPL006; see docs/contracts.md).")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files or directories to lint (default: "
                         "src tests benchmarks examples under the repo "
                         "root)")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: auto-detect pyproject.toml)")
    ap.add_argument("--select", default=None,
                    help="comma-separated RPL codes to report "
                         "(e.g. RPL003,RPL004)")
    ap.add_argument("--list-suppressions", action="store_true",
                    help="print every suppression comment and exit 0")
    ap.add_argument("--statistics", action="store_true",
                    help="print per-rule violation counts")
    args = ap.parse_args(argv)

    root = (args.root or _repo_root(Path.cwd())).resolve()
    select = args.select.split(",") if args.select else None
    result = run_lint(root, paths=args.paths or None, select=select)

    if args.list_suppressions:
        for s in result.suppressions:
            reason = f" ({s.reason})" if s.reason else "  [NO REASON]"
            kind = "disable-file" if s.file_level else "disable"
            print(f"{s.path}:{s.line}: {kind}={','.join(s.codes)}{reason}")
        print(f"{len(result.suppressions)} suppression(s)")
        return 0

    for d in result.diagnostics:
        print(d.render())
    if args.statistics:
        counts: dict = {}
        for d in result.diagnostics:
            counts[d.code] = counts.get(d.code, 0) + 1
        for code in sorted(counts):
            print(f"{code}: {counts[code]}")
    n = len(result.diagnostics)
    if n:
        print(f"reprolint: {n} violation(s), "
              f"{result.suppressed} suppressed", file=sys.stderr)
        return 1
    print(f"reprolint: clean ({result.suppressed} suppressed, "
          f"{len(result.suppressions)} suppression comment(s))")
    return 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
