"""Rule execution, suppression application, and RPL006 hygiene."""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Iterable, List, Optional, Sequence

from .context import Diagnostic, RepoContext, Suppression
from .rules import ALL_RULES


@dataclasses.dataclass
class LintResult:
    diagnostics: List[Diagnostic]     # post-suppression, sorted
    suppressions: List[Suppression]   # every suppression comment found
    suppressed: int                   # diagnostics masked by suppressions

    @property
    def ok(self) -> bool:
        return not self.diagnostics

    def used_suppressions(self) -> List[Suppression]:
        return [s for s in self.suppressions if s.used]


def run_lint(root: Path, paths: Optional[Iterable[Path]] = None,
             select: Optional[Sequence[str]] = None,
             rules: Sequence = ALL_RULES) -> LintResult:
    """Lint ``root`` (or explicit ``paths``) and apply suppressions.

    ``select`` restricts reporting to the given RPL codes (RPL006 and
    RPL999 are always implied members of their own selection).
    """
    ctx = RepoContext(root, paths=paths)
    raw: List[Diagnostic] = list(ctx.errors)
    for rule in rules:
        raw.extend(rule(ctx))
    # rules may traverse overlapping node sets (decorator Call vs its
    # Attribute func); report each location/code once.
    raw = sorted(set(raw), key=lambda d: (d.path, d.line, d.col, d.code))

    by_path = {info.rel: info.suppressions for info in ctx.modules}
    kept: List[Diagnostic] = []
    suppressed = 0
    for d in raw:
        masked = False
        for s in by_path.get(d.path, ()):
            if s.covers(d.code, d.line):
                s.used = True
                masked = True
        if masked:
            suppressed += 1
        else:
            kept.append(d)

    # RPL006: suppression hygiene.  A suppression must both (a) mask a
    # real diagnostic and (b) carry a reason — otherwise it is itself a
    # violation, so the documented-suppression budget polices itself.
    all_supp = [s for info in ctx.modules for s in info.suppressions]
    hygiene: List[Diagnostic] = []
    for s in all_supp:
        if not s.used:
            hygiene.append(Diagnostic(
                s.path, s.line, 0, "RPL006",
                f"unused suppression for {','.join(s.codes)} — remove it "
                "(nothing at this site triggers the rule any more)"))
        if not s.reason:
            hygiene.append(Diagnostic(
                s.path, s.line, 0, "RPL006",
                "suppression without a reason — write "
                "`# reprolint: disable=RPLxxx (why this is deliberate)`"))
    # RPL006 findings are themselves suppressible through the same
    # mechanism (a second suppression on the same line covering RPL006).
    for d in hygiene:
        masked = False
        for s in by_path.get(d.path, ()):
            if d.code in s.codes and (s.file_level or s.line == d.line):
                s.used = True
                masked = True
        if masked:
            suppressed += 1
        else:
            kept.append(d)

    if select:
        allowed = set(select)
        kept = [d for d in kept if d.code in allowed]
    kept.sort(key=lambda d: (d.path, d.line, d.col, d.code))
    return LintResult(diagnostics=kept, suppressions=all_supp,
                      suppressed=suppressed)
