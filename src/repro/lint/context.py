"""File loading, suppression parsing, and per-module AST facts.

A :class:`RepoContext` parses every Python file under the lint roots once
and exposes :class:`ModuleInfo` objects the rules consume.  Everything
here is pure stdlib ``ast`` — importing the linted code (and hence jax)
is deliberately impossible, so the linter runs in the dependency-free CI
lint job and can never be confused by import-time side effects.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

#: directories searched when no explicit paths are given (issue contract:
#: the determinism rules police the library AND its consumers).
DEFAULT_ROOTS = ("src", "tests", "benchmarks", "examples")

#: path fragments excluded from linting.  The lint fixtures contain
#: deliberate violations (they are the rules' positive tests) and must
#: never make the repo-clean gate fail.
DEFAULT_EXCLUDES = ("__pycache__", "tests/fixtures/lint")

#: suppression comments: kind (``disable`` / ``disable-file``), a
#: comma-separated code list, and an optional parenthesised reason.
#: Only real COMMENT tokens are scanned (docstrings showing the syntax
#: as an example never count).
_SUPPRESS_RE = re.compile(
    r"#\s*reprolint:\s*(?P<kind>disable|disable-file)\s*=\s*"
    r"(?P<codes>RPL\d{3}(?:\s*,\s*RPL\d{3})*)\s*"
    r"(?:\((?P<reason>.*)\))?")


@dataclasses.dataclass
class Suppression:
    """One ``# reprolint: disable=...`` comment."""

    path: str                 # repo-relative posix path
    line: int                 # 1-based line the comment sits on
    codes: Tuple[str, ...]
    reason: str               # "" when undocumented (RPL006 flags that)
    file_level: bool          # disable-file= applies to the whole module
    used: bool = False        # did it actually mask a diagnostic?

    def covers(self, code: str, line: int) -> bool:
        if code not in self.codes:
            return False
        if self.file_level:
            return True
        # Same line, or an own-line comment directly above the violation.
        return line in (self.line, self.line + 1)


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One rule violation at a source location."""

    path: str
    line: int
    col: int
    code: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"


class ModuleInfo:
    """Parsed facts about one Python file."""

    def __init__(self, path: Path, rel: str, source: str):
        self.path = path
        self.rel = rel                    # repo-relative posix path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=rel)
        self.module = self._module_name(rel)
        self.suppressions = self._parse_suppressions()
        # alias -> absolute module name, for ``import numpy as np`` and
        # ``from . import dispatch as _dispatch`` alike.
        self.import_aliases: Dict[str, str] = {}
        # local name -> (module, original name) for from-imports.
        self.from_imports: Dict[str, Tuple[str, str]] = {}
        self._collect_imports()
        # module-level function defs by name (class methods excluded —
        # the conservative call graph resolves plain-name calls only).
        self.top_functions: Dict[str, ast.AST] = {
            n.name: n for n in self.tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        # module-level dict registries whose values are local functions
        # (the ``_KERNELS = {"step": _run_one, ...}`` pattern): name ->
        # member function names.
        self.registries: Dict[str, List[str]] = self._collect_registries()

    # -- construction helpers ------------------------------------------------

    @staticmethod
    def _module_name(rel: str) -> Optional[str]:
        """Dotted module name for files under src/ (None elsewhere)."""
        if not rel.startswith("src/"):
            return None
        parts = Path(rel[len("src/"):]).with_suffix("").parts
        if parts and parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)

    def _parse_suppressions(self) -> List[Suppression]:
        out = []
        try:
            tokens = tokenize.generate_tokens(
                io.StringIO(self.source).readline)
            comments = [(t.start[0], t.string) for t in tokens
                        if t.type == tokenize.COMMENT]
        except tokenize.TokenError:
            comments = []
        for i, text in comments:
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            codes = tuple(c.strip() for c in m.group("codes").split(","))
            out.append(Suppression(
                path=self.rel, line=i, codes=codes,
                reason=(m.group("reason") or "").strip(),
                file_level=m.group("kind") == "disable-file"))
        return out

    def _collect_imports(self) -> None:
        pkg_parts = self.module.split(".")[:-1] if self.module else []
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.import_aliases[a.asname or a.name.split(".")[0]] = \
                        a.name
            elif isinstance(node, ast.ImportFrom):
                base = node.module or ""
                if node.level:   # relative: resolve against this package
                    up = pkg_parts[:len(pkg_parts) - (node.level - 1)]
                    base = ".".join(up + ([node.module] if node.module
                                          else []))
                for a in node.names:
                    local = a.asname or a.name
                    # ``from . import dispatch as _dispatch`` binds a
                    # MODULE; ``from ..core.failures import as_process``
                    # binds a name inside one.  Record both views — the
                    # call-graph resolver checks module aliases first.
                    self.import_aliases.setdefault(
                        local, f"{base}.{a.name}" if base else a.name)
                    self.from_imports[local] = (base, a.name)

    def _collect_registries(self) -> Dict[str, List[str]]:
        out: Dict[str, List[str]] = {}
        for node in self.tree.body:
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Dict)):
                continue
            members = [v.id for v in node.value.values
                       if isinstance(v, ast.Name)]
            if members:
                out[node.targets[0].id] = members
        return out


class RepoContext:
    """Every linted module, plus cross-module lookup for the call graph."""

    def __init__(self, root: Path, paths: Optional[Iterable[Path]] = None,
                 excludes: Tuple[str, ...] = DEFAULT_EXCLUDES):
        self.root = Path(root).resolve()
        self.excludes = excludes
        self.modules: List[ModuleInfo] = []
        self.by_module: Dict[str, ModuleInfo] = {}
        self.errors: List[Diagnostic] = []
        for f in sorted(self._files(paths)):
            rel = f.relative_to(self.root).as_posix()
            try:
                info = ModuleInfo(f, rel, f.read_text())
            except (SyntaxError, UnicodeDecodeError) as e:
                line = getattr(e, "lineno", 1) or 1
                self.errors.append(Diagnostic(
                    rel, line, 0, "RPL999", f"unparseable file: {e}"))
                continue
            self.modules.append(info)
            if info.module:
                self.by_module[info.module] = info

    def _files(self, paths: Optional[Iterable[Path]]) -> List[Path]:
        explicit = paths is not None
        if explicit:
            roots = [Path(p).resolve() for p in paths]
        else:
            roots = [self.root / r for r in DEFAULT_ROOTS]
        out = []
        for r in roots:
            if r.is_file() and r.suffix == ".py":
                out.append(r)
                continue
            for f in sorted(r.rglob("*.py")):
                rel = f.resolve().relative_to(self.root).as_posix()
                # Explicit paths bypass the fixture exclusion (that is how
                # the rule tests lint the fixtures on purpose); nothing
                # ever lints __pycache__.
                skip = ("__pycache__",) if explicit else self.excludes
                if any(x in rel for x in skip):
                    continue
                out.append(f.resolve())
        return out
