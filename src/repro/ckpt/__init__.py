from .store import (ShardedStore, StoreConfig, FaultPlan, FlushAborted,
                    TransientIOError, FAULT_POINTS)
from .manager import (CheckpointManager, ManagerConfig, BuddyReplica,
                      FlushController)
