from .store import ShardedStore, StoreConfig
from .manager import CheckpointManager, ManagerConfig, BuddyReplica
