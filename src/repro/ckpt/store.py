"""Sharded checkpoint store with manifest, checksums and atomic commit.

Layout (one directory per generation):

    <root>/step_000123/
        shard_00000.npz         one file per host shard (flat leaf arrays)
        manifest.json           written LAST -> commit point (atomic rename)

A checkpoint is valid iff its manifest exists and every shard checksum
matches.  Two generations are retained; ``latest()`` falls back one
generation when validation fails (torn writes, injected corruption).

Optional int8 blockwise compression (``compress=True``) uses the
``quant_blockwise`` kernel — ~4x smaller payloads for f32 state, directly
shrinking the paper's C parameter (lossy: bounded by absmax/127 per block;
applied to every leaf EXCEPT ones whose path matches ``no_compress``).
"""
from __future__ import annotations

import dataclasses
import json
import time
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from ..kernels import ops as kops


def _flatten(tree) -> list:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).view(np.uint8).reshape(-1))


@dataclasses.dataclass
class StoreConfig:
    root: str
    retain: int = 2
    compress: bool = False
    # leaf indices are compared against this predicate via their tree path
    no_compress_paths: tuple = ("step",)


class ShardedStore:
    """Host-sharded on-disk checkpoint store (single-host simulation keeps
    one shard; the format is per-host shard files + a manifest)."""

    def __init__(self, config: StoreConfig, n_shards: int = 1):
        self.cfg = config
        self.root = Path(config.root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.n_shards = n_shards

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, shard_id: int = 0,
             extra_meta: Optional[dict] = None) -> dict:
        """Write one generation (blocking).  Returns timing/size metadata."""
        t0 = time.perf_counter()
        leaves, treedef = jax.tree.flatten(tree)
        gen = self.root / f"step_{step:09d}"
        gen.mkdir(parents=True, exist_ok=True)

        arrays = {}
        meta_leaves = []
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            entry = {"index": i, "dtype": str(arr.dtype),
                     "shape": list(arr.shape), "compressed": False}
            if (self.cfg.compress and arr.dtype in (np.float32,)
                    and arr.size >= 4096):
                q, s, pad = kops.quantize_array(jax.numpy.asarray(arr))
                arrays[f"leaf_{i}_q"] = np.asarray(q)
                arrays[f"leaf_{i}_s"] = np.asarray(s)
                entry.update(compressed=True, pad=int(pad))
            else:
                arrays[f"leaf_{i}"] = arr
            meta_leaves.append(entry)

        shard_path = gen / f"shard_{shard_id:05d}.npz"
        tmp = shard_path.with_suffix(".npz.tmp")
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        tmp.rename(shard_path)

        checksum = _crc(np.frombuffer(shard_path.read_bytes(),
                                      dtype=np.uint8))
        manifest = {
            "step": step,
            "created": time.time(),
            "treedef": str(treedef),
            "leaves": meta_leaves,
            "shards": {str(shard_id): {"file": shard_path.name,
                                       "crc32": checksum}},
            "extra": extra_meta or {},
        }
        mtmp = gen / "manifest.json.tmp"
        mtmp.write_text(json.dumps(manifest))
        mtmp.rename(gen / "manifest.json")       # commit point

        self._gc()
        dt = time.perf_counter() - t0
        bytes_written = shard_path.stat().st_size
        return {"duration_s": dt, "bytes": bytes_written, "step": step,
                "path": str(gen)}

    # ---------------------------------------------------------------- restore
    def generations(self) -> list:
        gens = sorted(p for p in self.root.glob("step_*") if p.is_dir())
        return gens

    def validate(self, gen: Path) -> bool:
        man = gen / "manifest.json"
        if not man.exists():
            return False
        try:
            manifest = json.loads(man.read_text())
            for sid, info in manifest["shards"].items():
                p = gen / info["file"]
                if not p.exists():
                    return False
                crc = _crc(np.frombuffer(p.read_bytes(), dtype=np.uint8))
                if crc != info["crc32"]:
                    return False
            return True
        except (json.JSONDecodeError, KeyError):
            return False

    def latest(self) -> Optional[Path]:
        """Newest VALID generation (falls back across torn/corrupt ones)."""
        for gen in reversed(self.generations()):
            if self.validate(gen):
                return gen
        return None

    def restore(self, like_tree: Any, gen: Optional[Path] = None,
                *, shard_id: int = 0):
        """Load into the structure (and shardings) of ``like_tree``.

        Returns (tree, step) or (None, None) when no valid checkpoint exists.
        """
        gen = gen or self.latest()
        if gen is None:
            return None, None
        manifest = json.loads((gen / "manifest.json").read_text())
        data = np.load(gen / manifest["shards"][str(shard_id)]["file"])
        leaves_like, treedef = jax.tree.flatten(like_tree)
        out = []
        for entry, like in zip(manifest["leaves"], leaves_like):
            i = entry["index"]
            if entry["compressed"]:
                q = jax.numpy.asarray(data[f"leaf_{i}_q"])
                s = jax.numpy.asarray(data[f"leaf_{i}_s"])
                arr = kops.dequantize_array(
                    q, s, shape=tuple(entry["shape"]),
                    dtype=entry["dtype"], pad=entry["pad"])
            else:
                arr = jax.numpy.asarray(data[f"leaf_{i}"])
            if hasattr(like, "sharding") and like.sharding is not None:
                arr = jax.device_put(arr, like.sharding)
            out.append(arr)
        return jax.tree.unflatten(treedef, out), manifest["step"]

    # --------------------------------------------------------------------- gc
    def _gc(self):
        gens = self.generations()
        # keep the newest `retain` COMMITTED generations
        committed = [g for g in gens if (g / "manifest.json").exists()]
        for g in committed[:-self.cfg.retain]:
            for p in sorted(g.glob("**/*"), reverse=True):
                p.unlink()
            g.rmdir()
