"""Sharded checkpoint store with manifest, checksums and atomic commit.

Layout (one directory per generation):

    <root>/step_000123/
        shard_00000.npz         one file per host shard (flat leaf arrays)
        manifest.json           written LAST -> commit point (atomic rename)

A checkpoint is valid iff its manifest exists and every shard checksum
matches.  Two generations are retained; ``latest()`` falls back one
generation when validation fails (torn writes, injected corruption).

Interruptible writes: ``save`` streams the shard payload in chunks and
checks an optional ``abort`` event between chunks, so an in-flight deep
flush can be cancelled mid-write by the failure path (it raises
:class:`FlushAborted`; the torn generation it leaves behind has no
manifest, is invisible to ``latest()``, and is reclaimed by ``_gc`` /
``invalidate``).

Fault injection: a :class:`FaultPlan` attached as ``store.fault_plan``
scripts one IO failure mode at one named fault point — a stall, a torn
write after N bytes, silent checksum corruption, a burst of retryable
:class:`TransientIOError`, or a hard ``IOError``.  The checkpoint
manager's flush controller consults the same plan at its own points
(``buddy_push``, ``retry_backoff``, ``snapshot``).

Optional int8 blockwise compression (``compress=True``) uses the
``quant_blockwise`` kernel — ~4x smaller payloads for f32 state, directly
shrinking the paper's C parameter (lossy: bounded by absmax/127 per block;
applied to every leaf EXCEPT ones whose path matches ``no_compress``).
"""
from __future__ import annotations

import dataclasses
import io
import json
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from ..kernels import ops as kops


class FlushAborted(RuntimeError):
    """An in-flight write was cancelled via its ``abort`` event (the
    failure-interrupt path of an asynchronous deep flush)."""


class TransientIOError(IOError):
    """Injected retryable IO failure (``FaultPlan(kind="transient")``);
    the flush controller's bounded retry loop absorbs these."""


#: the named points a :class:`FaultPlan` can arm.  The first four live in
#: ``ShardedStore.save``; the manager consults the rest.
FAULT_POINTS = ("snapshot", "shard_write", "shard_rename",
                "manifest_commit", "buddy_push", "retry_backoff")

#: shard payload streaming quantum — abort/fault checks happen between
#: chunks, bounding how stale an interrupt can get mid-write.
_CHUNK = 1 << 16


@dataclasses.dataclass
class FaultPlan:
    """One scripted IO fault: ``kind`` at ``fail_at``, ``max_triggers``
    times (transient bursts are bounded by ``transient_errors`` instead).

    Kinds: ``"error"`` raises a hard ``IOError``; ``"transient"`` raises
    :class:`TransientIOError` for the next ``transient_errors`` visits;
    ``"stall"`` sleeps ``stall_s`` (abort-interruptible); ``"torn"``
    truncates the shard write after ``torn_after_bytes``; ``"corrupt"``
    flips a byte of the committed shard after its checksum is recorded.
    """

    fail_at: str = "shard_write"
    kind: str = "error"
    stall_s: float = 0.05
    torn_after_bytes: int = 256
    transient_errors: int = 1
    max_triggers: int = 1
    fired: int = 0

    _KINDS = ("error", "transient", "stall", "torn", "corrupt")

    def __post_init__(self):
        if self.fail_at not in FAULT_POINTS:
            raise ValueError(f"fail_at must be one of {FAULT_POINTS}, "
                             f"got {self.fail_at!r}")
        if self.kind not in self._KINDS:
            raise ValueError(f"kind must be one of {self._KINDS}, "
                             f"got {self.kind!r}")

    def take(self, point: str,
             abort: Optional[threading.Event] = None) -> Optional["FaultPlan"]:
        """Consult the plan at a fault point.

        Returns ``None`` when the plan does not fire here (wrong point or
        budget exhausted); raises for the error kinds; returns ``self``
        for the caller-cooperative kinds (``torn``/``corrupt``) and after
        a completed ``stall``.
        """
        if point != self.fail_at:
            return None
        if self.kind == "transient":
            if self.transient_errors <= 0:
                return None
            self.transient_errors -= 1
            self.fired += 1
            raise TransientIOError(
                f"injected transient IO failure at {point}")
        if self.fired >= self.max_triggers:
            return None
        self.fired += 1
        if self.kind == "error":
            raise IOError(f"injected IO failure at {point}")
        if self.kind == "stall":
            if abort is not None:
                if abort.wait(self.stall_s):
                    raise FlushAborted(
                        f"aborted during injected stall at {point}")
            else:
                time.sleep(self.stall_s)
        return self


def _flatten(tree) -> list:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).view(np.uint8).reshape(-1))


@dataclasses.dataclass
class StoreConfig:
    root: str
    retain: int = 2
    compress: bool = False
    # leaf indices are compared against this predicate via their tree path
    no_compress_paths: tuple = ("step",)


class ShardedStore:
    """Host-sharded on-disk checkpoint store (single-host simulation keeps
    one shard; the format is per-host shard files + a manifest)."""

    def __init__(self, config: StoreConfig, n_shards: int = 1):
        self.cfg = config
        self.root = Path(config.root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.n_shards = n_shards
        #: mutable injection hook; set a :class:`FaultPlan` to script the
        #: next IO failure, clear to heal the store.
        self.fault_plan: Optional[FaultPlan] = None

    def fault(self, point: str,
              abort: Optional[threading.Event] = None
              ) -> Optional[FaultPlan]:
        """Consult the injection plan at a named fault point (no-op
        without one) — also called by the manager for its points."""
        if self.fault_plan is None:
            return None
        return self.fault_plan.take(point, abort=abort)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, shard_id: int = 0,
             extra_meta: Optional[dict] = None,
             abort: Optional[threading.Event] = None) -> dict:
        """Write one generation (blocking).  Returns timing/size metadata.

        ``abort``: optional event checked between payload chunks; when it
        fires mid-write the save raises :class:`FlushAborted`, leaving at
        most an uncommitted (manifest-less) generation behind.
        """
        t0 = time.perf_counter()
        leaves, treedef = jax.tree.flatten(tree)
        gen = self.root / f"step_{step:09d}"
        gen.mkdir(parents=True, exist_ok=True)

        arrays = {}
        meta_leaves = []
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            entry = {"index": i, "dtype": str(arr.dtype),
                     "shape": list(arr.shape), "compressed": False}
            if (self.cfg.compress and arr.dtype in (np.float32,)
                    and arr.size >= 4096):
                q, s, pad = kops.quantize_array(jax.numpy.asarray(arr))
                arrays[f"leaf_{i}_q"] = np.asarray(q)
                arrays[f"leaf_{i}_s"] = np.asarray(s)
                entry.update(compressed=True, pad=int(pad))
            else:
                arrays[f"leaf_{i}"] = arr
            meta_leaves.append(entry)

        shard_path = gen / f"shard_{shard_id:05d}.npz"
        tmp = shard_path.with_suffix(".npz.tmp")
        buf = io.BytesIO()
        np.savez(buf, **arrays)
        payload = buf.getvalue()

        fired = self.fault("shard_write", abort)
        torn_at = (fired.torn_after_bytes
                   if fired is not None and fired.kind == "torn" else None)
        with open(tmp, "wb") as f:
            written = 0
            for off in range(0, len(payload), _CHUNK):
                if abort is not None and abort.is_set():
                    raise FlushAborted(
                        f"flush of step {step} aborted mid-write "
                        f"({written}/{len(payload)} bytes)")
                chunk = payload[off:off + _CHUNK]
                if torn_at is not None and written + len(chunk) > torn_at:
                    f.write(chunk[:max(0, torn_at - written)])
                    f.flush()
                    raise IOError(f"injected torn write after "
                                  f"{torn_at} bytes")
                f.write(chunk)
                written += len(chunk)
        self.fault("shard_rename", abort)
        tmp.rename(shard_path)

        checksum = _crc(np.frombuffer(shard_path.read_bytes(),
                                      dtype=np.uint8))
        manifest = {
            "step": step,
            "created": time.time(),
            "treedef": str(treedef),
            "leaves": meta_leaves,
            "shards": {str(shard_id): {"file": shard_path.name,
                                       "crc32": checksum}},
            "extra": extra_meta or {},
        }
        if abort is not None and abort.is_set():
            raise FlushAborted(f"flush of step {step} aborted before commit")
        fired = self.fault("manifest_commit", abort)
        mtmp = gen / "manifest.json.tmp"
        mtmp.write_text(json.dumps(manifest))
        mtmp.rename(gen / "manifest.json")       # commit point
        if fired is not None and fired.kind == "corrupt":
            # flip one byte AFTER the checksum was recorded: the
            # generation commits but fails CRC validation (the silent-
            # corruption model ``latest()`` must fall back across).
            with open(shard_path, "r+b") as f:
                b = f.read(1)
                f.seek(0)
                f.write(bytes([b[0] ^ 0xFF]))

        self._gc()
        dt = time.perf_counter() - t0
        bytes_written = shard_path.stat().st_size
        return {"duration_s": dt, "bytes": bytes_written, "step": step,
                "path": str(gen)}

    # ---------------------------------------------------------------- restore
    def generations(self) -> list:
        gens = sorted(p for p in self.root.glob("step_*") if p.is_dir())
        return gens

    def validate(self, gen: Path) -> bool:
        man = gen / "manifest.json"
        if not man.exists():
            return False
        try:
            manifest = json.loads(man.read_text())
            for sid, info in manifest["shards"].items():
                p = gen / info["file"]
                if not p.exists():
                    return False
                crc = _crc(np.frombuffer(p.read_bytes(), dtype=np.uint8))
                if crc != info["crc32"]:
                    return False
            return True
        except (json.JSONDecodeError, KeyError):
            return False

    def latest(self) -> Optional[Path]:
        """Newest VALID generation (falls back across torn/corrupt ones)."""
        for gen in reversed(self.generations()):
            if self.validate(gen):
                return gen
        return None

    def restore(self, like_tree: Any, gen: Optional[Path] = None,
                *, shard_id: int = 0):
        """Load into the structure (and shardings) of ``like_tree``.

        Returns (tree, step) or (None, None) when no valid checkpoint exists.
        """
        gen = gen or self.latest()
        if gen is None:
            return None, None
        manifest = json.loads((gen / "manifest.json").read_text())
        data = np.load(gen / manifest["shards"][str(shard_id)]["file"])
        leaves_like, treedef = jax.tree.flatten(like_tree)
        out = []
        for entry, like in zip(manifest["leaves"], leaves_like):
            i = entry["index"]
            if entry["compressed"]:
                q = jax.numpy.asarray(data[f"leaf_{i}_q"])
                s = jax.numpy.asarray(data[f"leaf_{i}_s"])
                arr = kops.dequantize_array(
                    q, s, shape=tuple(entry["shape"]),
                    dtype=entry["dtype"], pad=entry["pad"])
            else:
                arr = jax.numpy.asarray(data[f"leaf_{i}"])
            if hasattr(like, "sharding") and like.sharding is not None:
                arr = jax.device_put(arr, like.sharding)
            out.append(arr)
        return jax.tree.unflatten(treedef, out), manifest["step"]

    # --------------------------------------------------------------------- gc
    def invalidate(self, step: int) -> bool:
        """Delete the (possibly torn) generation of ``step`` — the
        discard half of a failure-interrupted flush.  Returns whether a
        generation directory existed."""
        gen = self.root / f"step_{step:09d}"
        if not gen.exists():
            return False
        self._rmgen(gen)
        return True

    @staticmethod
    def _rmgen(gen: Path):
        for p in sorted(gen.glob("**/*"), reverse=True):
            p.unlink()
        gen.rmdir()

    def _gc(self):
        gens = self.generations()
        # keep the newest `retain` COMMITTED generations ...
        committed = [g for g in gens if (g / "manifest.json").exists()]
        drop = set(committed[:-self.cfg.retain])
        if committed:
            # ... and reclaim UNCOMMITTED generations strictly older than
            # the newest committed one: those are torn leftovers of
            # aborted/failed flushes that will never commit.  Newer
            # uncommitted directories may be a flush in flight — kept.
            # (step_%09d zero-padding makes name order step order.)
            newest = committed[-1].name
            seen = set(committed)
            drop.update(g for g in gens
                        if g not in seen and g.name < newest)
        for g in sorted(drop):
            self._rmgen(g)
