"""Checkpoint manager: non-blocking (paper's omega) policy-driven checkpoints.

Pipeline per checkpoint:
  1. **snapshot** — device->host copy of the training state (this is the only
     part that stalls the accelerator; with double buffering it overlaps the
     next step's compute, giving omega close to 1 for the write phase);
  2. **write** — a background thread serializes the snapshot through the
     sharded store (manifest/checksum/atomic commit);
  3. **buddy** — optionally push the shard to an in-memory buddy replica
     (paper refs [12,14]: pair nodes so any single loss is recoverable
     without touching slow storage).

The manager feeds *measurements* back into the CheckpointPolicy: C (write
duration), omega (overlap efficiency), and exposes maybe_checkpoint(step) as
the single integration point for the trainer.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from ..core.policy import CheckpointPolicy
from .store import ShardedStore


class BuddyReplica:
    """In-memory replica of a partner's latest shard (simulated pairing)."""

    def __init__(self):
        self._data: Optional[tuple] = None     # (step, leaves)
        self._lock = threading.Lock()

    def push(self, step: int, tree: Any) -> None:
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(x) for x in leaves]
        with self._lock:
            self._data = (step, host, treedef)

    def restore(self, like_tree: Any):
        with self._lock:
            if self._data is None:
                return None, None
            step, host, treedef = self._data
        likes = jax.tree.leaves(like_tree)
        out = []
        for arr, like in zip(host, likes):
            a = jax.numpy.asarray(arr)
            if hasattr(like, "sharding") and like.sharding is not None:
                a = jax.device_put(a, like.sharding)
            out.append(a)
        return jax.tree.unflatten(treedef, out), step


@dataclasses.dataclass
class ManagerConfig:
    async_write: bool = True
    use_buddy: bool = True
    #: deep-storage cadence (the model's ``m``): every checkpoint pushes to
    #: the buddy replica, every ``pfs_every``-th also writes the sharded
    #: (PFS) store.  1 = every checkpoint goes deep (single-level behavior).
    pfs_every: int = 1


class CheckpointManager:
    def __init__(self, store: ShardedStore, policy: CheckpointPolicy,
                 config: ManagerConfig = ManagerConfig()):
        if config.pfs_every < 1:
            raise ValueError(f"pfs_every must be >= 1, got {config.pfs_every}")
        if config.pfs_every > 1 and not config.use_buddy:
            raise ValueError("pfs_every > 1 needs the buddy level enabled "
                             "(buddy-only checkpoints would protect nothing)")
        self.store = store
        self.policy = policy
        self.cfg = config
        self.buddy = BuddyReplica() if config.use_buddy else None
        self._writer: Optional[threading.Thread] = None
        self._last_ckpt_step: Optional[int] = None
        self._n_ckpts = 0                # schedule position (the model's k)
        self._pending_meta: dict = {}
        self._lock = threading.Lock()
        self.stats: list = []

    # ------------------------------------------------------------------ write
    def _write(self, step: int, host_tree, t_snapshot: float,
               deep: bool = True):
        t0 = time.perf_counter()
        meta = self.store.save(step, host_tree) if deep else None
        if self.buddy is not None:
            self.buddy.push(step, host_tree)
        t_write = time.perf_counter() - t0
        C = t_snapshot + t_write
        with self._lock:
            self.stats.append({"step": step, "snapshot_s": t_snapshot,
                               "write_s": t_write, "C_s": C,
                               "level": 2 if deep else 1,
                               "bytes": meta["bytes"] if deep else 0})
        # omega: only the snapshot stalls compute; the write overlaps.
        omega = t_write / C if C > 0 else 0.0
        self.policy.observe_checkpoint(duration_s=C,
                                       slowdown_work_fraction=omega)

    def checkpoint(self, step: int, state: Any, *, block: bool = False,
                   deep: Optional[bool] = None):
        """Snapshot now; write in the background (non-blocking checkpoints).

        ``deep`` forces/suppresses the deep (PFS) write; by default the
        ``pfs_every`` schedule decides: checkpoints 0, m, 2m, ... go deep,
        the rest are buddy-only (the model's every-m-th cadence).
        """
        if deep is None:
            deep = self._n_ckpts % self.cfg.pfs_every == 0
        if not deep and self.buddy is None:
            raise ValueError("deep=False without a buddy level would "
                             "persist nothing (same invariant as the "
                             "pfs_every > 1 config guard)")
        self._n_ckpts += 1
        self.wait()                      # one in-flight write at a time
        t0 = time.perf_counter()
        host = jax.tree.map(lambda x: np.asarray(x), state)   # device->host
        t_snapshot = time.perf_counter() - t0
        self._last_ckpt_step = step
        if self.cfg.async_write and not block:
            self._writer = threading.Thread(
                target=self._write, args=(step, host, t_snapshot, deep),
                daemon=True)
            self._writer.start()
        else:
            self._write(step, host, t_snapshot, deep)

    def maybe_checkpoint(self, step: int, state: Any) -> bool:
        """Policy-driven: checkpoint when period_steps have elapsed (deep
        vs buddy-only decided by the ``pfs_every`` schedule)."""
        period = self.policy.period_steps()
        last = self._last_ckpt_step
        if last is not None and step - last < period:
            return False
        self.checkpoint(step, state)
        return True

    def wait(self):
        if self._writer is not None and self._writer.is_alive():
            self._writer.join()
        self._writer = None

    # ---------------------------------------------------------------- restore
    def restore(self, like_tree: Any):
        """Deepest *surviving* level wins by recency: the newest of (valid
        store generation, buddy replica).  With ``pfs_every > 1`` the buddy
        usually holds a fresher state than the last PFS write; ties prefer
        the store (it survives process loss, the buddy does not)."""
        self.wait()
        s_tree, s_step = self.store.restore(like_tree)
        b_tree, b_step = (self.buddy.restore(like_tree)
                          if self.buddy is not None else (None, None))
        if b_tree is not None and (s_tree is None or b_step > s_step):
            return b_tree, b_step, "buddy"
        if s_tree is not None:
            return s_tree, s_step, "store"
        return None, None, "none"

    @property
    def measured_C_s(self) -> Optional[float]:
        with self._lock:
            if not self.stats:
                return None
            return float(np.mean([s["C_s"] for s in self.stats[-5:]]))
