"""Checkpoint manager: non-blocking (paper's omega) policy-driven checkpoints.

Pipeline per checkpoint (the VELOC shape):
  1. **snapshot** — device->host copy of the training state (this is the only
     part that stalls the accelerator; with double buffering it overlaps the
     next step's compute, giving omega close to 1 for the write phase);
  2. **buddy** — push the shard to an in-memory buddy replica on the critical
     path (paper refs [12,14]: the fast local write that makes any single
     loss recoverable without touching slow storage);
  3. **flush** — a :class:`FlushController`-owned background thread streams
     the snapshot through the sharded store (manifest/checksum/atomic
     commit) with bounded retry/backoff.  The flush is *interruptible*: the
     failure path calls :meth:`CheckpointManager.discard_in_flight`, which
     aborts the write thread mid-chunk, rejects the torn generation, and
     reverts the buddy to its previous buffer — the model's
     failure-during-flush semantics (the in-flight generation is lost,
     restore falls back one level/generation).

Graceful degradation: after ``degrade_after`` CONSECUTIVE deep-flush IO
failures (aborts from failure interrupts do not count) the manager flips
to buddy-only operation, raises an alarm, and tells the policy the deep
tier is gone (``policy.set_deep_available(False)`` — the period re-solves
at the degraded tier).  While degraded, every ``heal_every``-th scheduled
checkpoint probes the deep store; one success heals and re-enables it.

The manager feeds *measurements* back into the CheckpointPolicy: C (write
duration), omega (overlap efficiency), and exposes maybe_checkpoint(step) as
the single integration point for the trainer.

Two-level cadence: every checkpoint pushes to the buddy replica, every
``m``-th also writes the sharded (PFS) store.  ``m`` comes from
``ManagerConfig.pfs_every`` when hand-set, or — the model-driven path —
from ``policy.deep_every()`` when ``pfs_every`` is None, so the joint
``(T, m)`` solvers choose both the period and the deepening cadence.

Scaled-time runs set ``virtual_C1_s`` / ``virtual_C2_s``: the write still
happens for real (restores must work), but the *reported* duration — what
the policy estimates from and what the trainer charges to its virtual
clock — is the configured per-level cost, so the run's checkpoint
parameters are exactly the scenario's.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

from ..core.policy import CheckpointPolicy
from .store import FlushAborted, ShardedStore


class BuddyReplica:
    """In-memory replica of a partner's latest shard (simulated pairing).

    Double-buffered: ``push`` keeps the previous generation around so a
    failure-interrupted checkpoint can ``revert`` to it — the buddy-level
    half of the model's in-flight-generation loss.
    """

    def __init__(self):
        self._data: Optional[tuple] = None     # (step, leaves, treedef)
        self._prev: Optional[tuple] = None
        self._lock = threading.Lock()

    def push(self, step: int, tree: Any) -> None:
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(x) for x in leaves]
        with self._lock:
            self._prev = self._data
            self._data = (step, host, treedef)

    def revert(self, step: int) -> bool:
        """Discard the ``step`` generation (if it is the newest), falling
        back to the previous buffer.  Returns whether anything changed."""
        with self._lock:
            if self._data is not None and self._data[0] == step:
                self._data, self._prev = self._prev, None
                return True
            return False

    def clear(self) -> None:
        """Drop the replica (a *hard* failure: both buddies lost)."""
        with self._lock:
            self._data = None
            self._prev = None

    def restore(self, like_tree: Any):
        with self._lock:
            if self._data is None:
                return None, None
            step, host, treedef = self._data
        likes = jax.tree.leaves(like_tree)
        out = []
        for arr, like in zip(host, likes):
            a = jax.numpy.asarray(arr)
            if hasattr(like, "sharding") and like.sharding is not None:
                a = jax.device_put(a, like.sharding)
            out.append(a)
        return jax.tree.unflatten(treedef, out), step


@dataclasses.dataclass
class ManagerConfig:
    async_write: bool = True
    use_buddy: bool = True
    #: deep-storage cadence (the model's ``m``): every checkpoint pushes to
    #: the buddy replica, every ``pfs_every``-th also writes the sharded
    #: (PFS) store.  1 = every checkpoint goes deep (single-level behavior).
    #: None = ask the policy (``policy.deep_every()``, the joint (T, m)
    #: solver's m) before each checkpoint.
    pfs_every: Optional[int] = 1
    #: scaled-time overrides: report these as the per-level checkpoint
    #: durations instead of the measured wall time (None = measure).  When
    #: set, the measured overlap fraction is *not* reported either — the
    #: policy keeps its configured omega prior, as the scenario intends.
    virtual_C1_s: Optional[float] = None
    virtual_C2_s: Optional[float] = None
    #: flush controller: retry a failed deep write this many times with
    #: linear backoff, under an optional wall-clock deadline per flush.
    flush_retries: int = 2
    flush_backoff_s: float = 0.01
    flush_deadline_s: Optional[float] = None
    #: graceful degradation: this many CONSECUTIVE failed deep flushes
    #: (IO failures — failure-interrupt aborts do not count) flip the
    #: manager to buddy-only and re-solve the policy at the degraded
    #: tier.  0 disables degradation.
    degrade_after: int = 3
    #: while degraded, every N-th scheduled checkpoint probes the deep
    #: store; a success heals (0 = never probe, degradation is final).
    heal_every: int = 4


class FlushController:
    """Owns the asynchronous deep-flush thread.

    Replaces the old join-before-snapshot drain: the checkpoint path
    still serializes flushes (``wait`` before a new submit), but the
    FAILURE path can now ``abort()`` an in-flight write — the abort event
    is checked between payload chunks inside ``ShardedStore.save`` and
    interrupts retry backoffs — instead of blocking behind it.

    Each flush is one ``write(abort)`` callable run with bounded
    retry/backoff (linear, ``backoff_s * attempt``) under an optional
    deadline.  Completion is reported through ``on_done(step, outcome,
    payload)`` with outcome ``"ok"`` / ``"failed"`` / ``"aborted"``.
    """

    def __init__(self, store: ShardedStore, retries: int = 2,
                 backoff_s: float = 0.01,
                 deadline_s: Optional[float] = None):
        self.store = store
        self.retries = retries
        self.backoff_s = backoff_s
        self.deadline_s = deadline_s
        self._thread: Optional[threading.Thread] = None
        self._abort = threading.Event()
        self.inflight_step: Optional[int] = None

    @property
    def busy(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def submit(self, step: int, write, on_done) -> None:
        """Start ``write`` in the background (drains any previous flush
        first — one in-flight write at a time)."""
        self.wait()
        self._abort = threading.Event()
        self.inflight_step = step
        self._thread = threading.Thread(
            target=self._run, args=(step, write, self._abort, on_done),
            daemon=True)
        self._thread.start()

    def run_sync(self, step: int, write, on_done) -> None:
        """Blocking flush through the same retry/deadline machinery."""
        self.wait()
        self._abort = threading.Event()
        self.inflight_step = step
        self._run(step, write, self._abort, on_done)

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Drain the in-flight flush (checkpoint-path barrier; the
        failure path uses :meth:`abort` instead).  True when idle."""
        t = self._thread
        if t is not None and t.is_alive():
            t.join(timeout)
            if t.is_alive():
                return False
        self._thread = None
        return True

    def abort(self) -> bool:
        """Interrupt the in-flight flush (failure path).  Returns whether
        a live write was actually aborted."""
        t = self._thread
        if t is None or not t.is_alive():
            self._thread = None
            return False
        self._abort.set()
        t.join()
        self._thread = None
        return True

    def _run(self, step, write, abort, on_done):
        deadline = (None if self.deadline_s is None
                    else time.monotonic() + self.deadline_s)
        attempt = 0
        try:
            while True:
                try:
                    on_done(step, "ok", write(abort))
                    return
                except FlushAborted as e:
                    on_done(step, "aborted", e)
                    return
                except OSError as e:
                    attempt += 1
                    if (attempt > self.retries
                            or (deadline is not None
                                and time.monotonic() >= deadline)):
                        on_done(step, "failed", e)
                        return
                    try:
                        self.store.fault("retry_backoff", abort)
                    except FlushAborted as e2:
                        on_done(step, "aborted", e2)
                        return
                    except OSError as e2:
                        on_done(step, "failed", e2)
                        return
                    if abort.wait(self.backoff_s * attempt):
                        on_done(step, "aborted", e)
                        return
        finally:
            self.inflight_step = None


class CheckpointManager:
    def __init__(self, store: ShardedStore, policy: CheckpointPolicy,
                 config: Optional[ManagerConfig] = None,
                 on_alarm=None):
        # NOTE: default must be built per instance — a dataclass instance
        # as a parameter default would be SHARED across managers.
        config = ManagerConfig() if config is None else config
        if config.pfs_every is not None and config.pfs_every < 1:
            raise ValueError(f"pfs_every must be >= 1, got {config.pfs_every}")
        if (config.pfs_every or 1) > 1 and not config.use_buddy:
            raise ValueError("pfs_every > 1 needs the buddy level enabled "
                             "(buddy-only checkpoints would protect nothing)")
        self.store = store
        self.policy = policy
        self.cfg = config
        self.buddy = BuddyReplica() if config.use_buddy else None
        self.flush = FlushController(store, retries=config.flush_retries,
                                     backoff_s=config.flush_backoff_s,
                                     deadline_s=config.flush_deadline_s)
        self.on_alarm = on_alarm         # callable(dict) | None
        self.alarms: list = []
        self.degraded = False
        self.flush_errors: list = []
        self.buddy_push_failures = 0
        self._flush_failures = 0         # consecutive, IO-failure only
        self._ckpts_while_degraded = 0
        self._last_ckpt_step: Optional[int] = None
        self._n_ckpts = 0                # schedule position (the model's k)
        self._ckpt_pos: dict = {}        # step -> schedule ordinal
        self._lock = threading.Lock()
        self.stats: list = []

    # -------------------------------------------------------------- schedule
    def deep_every(self) -> int:
        """The effective m: the config's when hand-set, else the policy's
        (clamped to 1 when there is no buddy level to carry the cheap
        checkpoints)."""
        m = self.cfg.pfs_every
        if m is None:
            m = max(1, int(self.policy.deep_every()))
        return m if self.buddy is not None else 1

    # ------------------------------------------------------------------ write
    def _record(self, step: int, level: int, t_snapshot: float,
                t_write: float, n_bytes: int):
        measured = t_snapshot + t_write
        virt = (self.cfg.virtual_C2_s if level >= 2
                else self.cfg.virtual_C1_s)
        C = measured if virt is None else virt
        with self._lock:
            self.stats.append({"step": step, "snapshot_s": t_snapshot,
                               "write_s": t_write, "measured_s": measured,
                               "C_s": C, "level": level,
                               "bytes": n_bytes})
        # omega: only the snapshot stalls compute; the write overlaps.  In
        # scaled time the measured split is meaningless — keep the prior.
        omega = None if virt is not None else (
            t_write / measured if measured > 0 else 0.0)
        self.policy.observe_checkpoint(duration_s=C,
                                       slowdown_work_fraction=omega,
                                       level=level)

    def _alarm(self, kind: str, step: int, **extra):
        alarm = {"kind": kind, "step": step, **extra}
        self.alarms.append(alarm)
        if self.on_alarm is not None:
            self.on_alarm(alarm)

    def _flush_done(self, step: int, outcome: str, payload,
                    t_snapshot: float):
        """Flush-thread completion: record + drive the degrade/heal FSM."""
        if outcome == "ok":
            meta, t_write = payload
            self._record(step, 2, t_snapshot, t_write, meta["bytes"])
            self._flush_failures = 0
            if self.degraded:
                self.degraded = False
                self._ckpts_while_degraded = 0
                self._alarm("pfs_healed", step)
                self.policy.set_deep_available(True)
        elif outcome == "failed":
            self.flush_errors.append({"step": step, "error": repr(payload)})
            self._flush_failures += 1
            if (not self.degraded and self.buddy is not None
                    and self.cfg.degrade_after > 0
                    and self._flush_failures >= self.cfg.degrade_after):
                self.degraded = True
                self._ckpts_while_degraded = 0
                self._alarm("pfs_degraded", step,
                            consecutive_failures=self._flush_failures)
                self.policy.set_deep_available(False)
        # "aborted": a failure interrupt, not a store problem — it neither
        # records a checkpoint nor counts toward degradation.

    def checkpoint(self, step: int, state: Any, *, block: bool = False,
                   deep: Optional[bool] = None) -> int:
        """Snapshot + buddy push now; deep flush in the background.

        ``deep`` forces/suppresses the deep (PFS) write; by default the
        ``deep_every()`` schedule decides: checkpoints 0, m, 2m, ... go
        deep, the rest are buddy-only (the model's every-m-th cadence).
        While degraded, scheduled deep writes downgrade to buddy-only
        except the periodic heal probe.  Returns the level written
        (2 = deep, 1 = buddy-only).
        """
        if deep is None:
            deep = self._n_ckpts % self.deep_every() == 0
            if deep and self.degraded and self.buddy is not None:
                self._ckpts_while_degraded += 1
                deep = (self.cfg.heal_every > 0
                        and self._ckpts_while_degraded
                        % self.cfg.heal_every == 0)
        if not deep and self.buddy is None:
            raise ValueError("deep=False without a buddy level would "
                             "persist nothing (same invariant as the "
                             "pfs_every > 1 config guard)")
        self._ckpt_pos[step] = self._n_ckpts
        self._n_ckpts += 1
        if deep:
            self.flush.wait()            # one in-flight deep write at a time
        self.store.fault("snapshot")
        t0 = time.perf_counter()
        host = jax.tree.map(lambda x: np.asarray(x), state)   # device->host
        t_snapshot = time.perf_counter() - t0
        self._last_ckpt_step = step
        t_push = 0.0
        if self.buddy is not None:
            # VELOC local write: on the critical path, before the flush.
            t1 = time.perf_counter()
            try:
                self.store.fault("buddy_push")
                self.buddy.push(step, host)
            except OSError:
                self.buddy_push_failures += 1
            t_push = time.perf_counter() - t1
        if not deep:
            self._record(step, 1, t_snapshot, t_push, 0)
            return 1

        def write(abort):
            tw = time.perf_counter()
            meta = self.store.save(step, host, abort=abort)
            return meta, time.perf_counter() - tw

        def done(s, outcome, payload):
            self._flush_done(s, outcome, payload, t_snapshot)

        if self.cfg.async_write and not block:
            self.flush.submit(step, write, done)
        else:
            self.flush.run_sync(step, write, done)
        return 2

    def due(self, step: int) -> int:
        """0 when the period has not elapsed, else the level the next
        checkpoint WOULD write (2 = deep, 1 = buddy-only) — without
        writing anything.  Lets the trainer price the write (and model a
        failure interrupting it) before committing.  Degradation-aware:
        while buddy-only, scheduled deep writes report as level 1 except
        the upcoming heal probe."""
        period = self.policy.period_steps()
        last = self._last_ckpt_step
        if last is not None and step - last < period:
            return 0
        deep = self._n_ckpts % self.deep_every() == 0
        if deep and self.degraded and self.buddy is not None:
            deep = (self.cfg.heal_every > 0
                    and (self._ckpts_while_degraded + 1)
                    % self.cfg.heal_every == 0)
        return 2 if deep else 1

    def expected_virtual_cost(self, level: int) -> Optional[float]:
        """The scaled-time override for a write at ``level`` (None =
        measured mode)."""
        return (self.cfg.virtual_C2_s if level >= 2
                else self.cfg.virtual_C1_s)

    def expected_cost(self, level: int) -> Optional[float]:
        """The cost a write at ``level`` will report: the virtual override
        in scaled time, else the recent measured mean (None before any)."""
        virt = self.expected_virtual_cost(level)
        return virt if virt is not None else self.measured_C_s

    def maybe_checkpoint(self, step: int, state: Any) -> int:
        """Policy-driven: checkpoint when period_steps have elapsed.

        Returns 0 when skipped, else the level written (2 = deep, 1 =
        buddy-only) — falsy/truthy compatible with the old bool API.
        """
        if not self.due(step):
            return 0
        return self.checkpoint(step, state)

    def wait(self):
        """Drain the in-flight deep flush (checkpoint-path barrier; the
        failure path uses :meth:`discard_in_flight` instead of waiting)."""
        self.flush.wait()

    def discard_in_flight(self, step: int, level: int) -> bool:
        """Failure-interrupt of the in-flight checkpoint of ``step``:
        abort the flush thread if it is still writing, reject the torn
        (or raced-to-commit) generation, and fall the buddy back to its
        previous buffer — the model's flush-window loss, made mechanical.

        The abort does NOT count toward degradation (it is a failure
        interrupt, not a store fault).  Returns whether a live write was
        actually aborted mid-flight.
        """
        aborted = False
        if level >= 2:
            aborted = self.flush.abort()
            # invalidate regardless of the real-time race: the virtual
            # clock says this generation was lost, so a write that
            # happened to commit must be rejected too (determinism of the
            # rollback-identity property does not depend on thread
            # timing).
            self.store.invalidate(step)
        if self.buddy is not None:
            self.buddy.revert(step)
        return aborted

    def drop_buddy(self) -> None:
        """Simulate a hard failure: the buddy copy is lost too, so the next
        restore must fall back to the deep (PFS) level."""
        if self.buddy is not None:
            self.buddy.clear()

    # ---------------------------------------------------------------- restore
    def restore(self, like_tree: Any):
        """Deepest *surviving* level wins by recency: the newest of (valid
        store generation, buddy replica).  With ``pfs_every > 1`` the buddy
        usually holds a fresher state than the last PFS write; ties prefer
        the store (it survives process loss, the buddy does not).

        A store-sourced restore reseeds the buddy replica: after a hard
        failure the replacement pair starts protected again, so later
        buddy-only checkpoints have a level to deepen from.
        """
        self.wait()
        s_tree, s_step = self.store.restore(like_tree)
        b_tree, b_step = (self.buddy.restore(like_tree)
                          if self.buddy is not None else (None, None))
        if b_tree is not None and (s_tree is None or b_step > s_step):
            self._rewind_to(b_step)
            return b_tree, b_step, "buddy"
        if s_tree is not None:
            if self.buddy is not None:
                self.buddy.push(s_step, s_tree)
            self._rewind_to(s_step)
            return s_tree, s_step, "store"
        return None, None, "none"

    def _rewind_to(self, step: int) -> None:
        """Re-anchor the schedule at a restored checkpoint: checkpoints for
        the redone span must be re-taken (``_last_ckpt_step`` rolls back —
        otherwise a second failure during the redo re-loses everything),
        and the deep-every cadence resumes from the restored checkpoint's
        ordinal so the superperiod structure survives rollbacks."""
        self._last_ckpt_step = step
        pos = self._ckpt_pos.get(step)
        if pos is not None:
            self._n_ckpts = pos + 1

    @property
    def measured_C_s(self) -> Optional[float]:
        with self._lock:
            if not self.stats:
                return None
            return float(np.mean([s["C_s"] for s in self.stats[-5:]]))

    def last_checkpoint(self) -> Optional[dict]:
        """The most recent completed write's stats entry (level, C_s, ...)."""
        self.wait()
        with self._lock:
            return dict(self.stats[-1]) if self.stats else None
