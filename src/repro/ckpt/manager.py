"""Checkpoint manager: non-blocking (paper's omega) policy-driven checkpoints.

Pipeline per checkpoint:
  1. **snapshot** — device->host copy of the training state (this is the only
     part that stalls the accelerator; with double buffering it overlaps the
     next step's compute, giving omega close to 1 for the write phase);
  2. **write** — a background thread serializes the snapshot through the
     sharded store (manifest/checksum/atomic commit);
  3. **buddy** — optionally push the shard to an in-memory buddy replica
     (paper refs [12,14]: pair nodes so any single loss is recoverable
     without touching slow storage).

The manager feeds *measurements* back into the CheckpointPolicy: C (write
duration), omega (overlap efficiency), and exposes maybe_checkpoint(step) as
the single integration point for the trainer.

Two-level cadence: every checkpoint pushes to the buddy replica, every
``m``-th also writes the sharded (PFS) store.  ``m`` comes from
``ManagerConfig.pfs_every`` when hand-set, or — the model-driven path —
from ``policy.deep_every()`` when ``pfs_every`` is None, so the joint
``(T, m)`` solvers choose both the period and the deepening cadence.

Scaled-time runs set ``virtual_C1_s`` / ``virtual_C2_s``: the write still
happens for real (restores must work), but the *reported* duration — what
the policy estimates from and what the trainer charges to its virtual
clock — is the configured per-level cost, so the run's checkpoint
parameters are exactly the scenario's.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

from ..core.policy import CheckpointPolicy
from .store import ShardedStore


class BuddyReplica:
    """In-memory replica of a partner's latest shard (simulated pairing)."""

    def __init__(self):
        self._data: Optional[tuple] = None     # (step, leaves)
        self._lock = threading.Lock()

    def push(self, step: int, tree: Any) -> None:
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(x) for x in leaves]
        with self._lock:
            self._data = (step, host, treedef)

    def clear(self) -> None:
        """Drop the replica (a *hard* failure: both buddies lost)."""
        with self._lock:
            self._data = None

    def restore(self, like_tree: Any):
        with self._lock:
            if self._data is None:
                return None, None
            step, host, treedef = self._data
        likes = jax.tree.leaves(like_tree)
        out = []
        for arr, like in zip(host, likes):
            a = jax.numpy.asarray(arr)
            if hasattr(like, "sharding") and like.sharding is not None:
                a = jax.device_put(a, like.sharding)
            out.append(a)
        return jax.tree.unflatten(treedef, out), step


@dataclasses.dataclass
class ManagerConfig:
    async_write: bool = True
    use_buddy: bool = True
    #: deep-storage cadence (the model's ``m``): every checkpoint pushes to
    #: the buddy replica, every ``pfs_every``-th also writes the sharded
    #: (PFS) store.  1 = every checkpoint goes deep (single-level behavior).
    #: None = ask the policy (``policy.deep_every()``, the joint (T, m)
    #: solver's m) before each checkpoint.
    pfs_every: Optional[int] = 1
    #: scaled-time overrides: report these as the per-level checkpoint
    #: durations instead of the measured wall time (None = measure).  When
    #: set, the measured overlap fraction is *not* reported either — the
    #: policy keeps its configured omega prior, as the scenario intends.
    virtual_C1_s: Optional[float] = None
    virtual_C2_s: Optional[float] = None


class CheckpointManager:
    def __init__(self, store: ShardedStore, policy: CheckpointPolicy,
                 config: ManagerConfig = ManagerConfig()):
        if config.pfs_every is not None and config.pfs_every < 1:
            raise ValueError(f"pfs_every must be >= 1, got {config.pfs_every}")
        if (config.pfs_every or 1) > 1 and not config.use_buddy:
            raise ValueError("pfs_every > 1 needs the buddy level enabled "
                             "(buddy-only checkpoints would protect nothing)")
        self.store = store
        self.policy = policy
        self.cfg = config
        self.buddy = BuddyReplica() if config.use_buddy else None
        self._writer: Optional[threading.Thread] = None
        self._last_ckpt_step: Optional[int] = None
        self._n_ckpts = 0                # schedule position (the model's k)
        self._ckpt_pos: dict = {}        # step -> schedule ordinal
        self._lock = threading.Lock()
        self.stats: list = []

    # -------------------------------------------------------------- schedule
    def deep_every(self) -> int:
        """The effective m: the config's when hand-set, else the policy's
        (clamped to 1 when there is no buddy level to carry the cheap
        checkpoints)."""
        m = self.cfg.pfs_every
        if m is None:
            m = max(1, int(self.policy.deep_every()))
        return m if self.buddy is not None else 1

    # ------------------------------------------------------------------ write
    def _write(self, step: int, host_tree, t_snapshot: float,
               deep: bool = True):
        t0 = time.perf_counter()
        meta = self.store.save(step, host_tree) if deep else None
        if self.buddy is not None:
            self.buddy.push(step, host_tree)
        t_write = time.perf_counter() - t0
        measured = t_snapshot + t_write
        virt = self.cfg.virtual_C2_s if deep else self.cfg.virtual_C1_s
        C = measured if virt is None else virt
        with self._lock:
            self.stats.append({"step": step, "snapshot_s": t_snapshot,
                               "write_s": t_write, "measured_s": measured,
                               "C_s": C, "level": 2 if deep else 1,
                               "bytes": meta["bytes"] if deep else 0})
        # omega: only the snapshot stalls compute; the write overlaps.  In
        # scaled time the measured split is meaningless — keep the prior.
        omega = None if virt is not None else (
            t_write / measured if measured > 0 else 0.0)
        self.policy.observe_checkpoint(duration_s=C,
                                       slowdown_work_fraction=omega,
                                       level=2 if deep else 1)

    def checkpoint(self, step: int, state: Any, *, block: bool = False,
                   deep: Optional[bool] = None) -> int:
        """Snapshot now; write in the background (non-blocking checkpoints).

        ``deep`` forces/suppresses the deep (PFS) write; by default the
        ``deep_every()`` schedule decides: checkpoints 0, m, 2m, ... go
        deep, the rest are buddy-only (the model's every-m-th cadence).
        Returns the level written (2 = deep, 1 = buddy-only).
        """
        if deep is None:
            deep = self._n_ckpts % self.deep_every() == 0
        if not deep and self.buddy is None:
            raise ValueError("deep=False without a buddy level would "
                             "persist nothing (same invariant as the "
                             "pfs_every > 1 config guard)")
        self._ckpt_pos[step] = self._n_ckpts
        self._n_ckpts += 1
        self.wait()                      # one in-flight write at a time
        t0 = time.perf_counter()
        host = jax.tree.map(lambda x: np.asarray(x), state)   # device->host
        t_snapshot = time.perf_counter() - t0
        self._last_ckpt_step = step
        if self.cfg.async_write and not block:
            self._writer = threading.Thread(
                target=self._write, args=(step, host, t_snapshot, deep),
                daemon=True)
            self._writer.start()
        else:
            self._write(step, host, t_snapshot, deep)
        return 2 if deep else 1

    def due(self, step: int) -> int:
        """0 when the period has not elapsed, else the level the next
        checkpoint WOULD write (2 = deep, 1 = buddy-only) — without
        writing anything.  Lets the trainer price the write (and model a
        failure interrupting it) before committing."""
        period = self.policy.period_steps()
        last = self._last_ckpt_step
        if last is not None and step - last < period:
            return 0
        return 2 if self._n_ckpts % self.deep_every() == 0 else 1

    def expected_cost(self, level: int) -> Optional[float]:
        """The cost a write at ``level`` will report: the virtual override
        in scaled time, else the recent measured mean (None before any)."""
        virt = (self.cfg.virtual_C2_s if level >= 2
                else self.cfg.virtual_C1_s)
        return virt if virt is not None else self.measured_C_s

    def maybe_checkpoint(self, step: int, state: Any) -> int:
        """Policy-driven: checkpoint when period_steps have elapsed.

        Returns 0 when skipped, else the level written (2 = deep, 1 =
        buddy-only) — falsy/truthy compatible with the old bool API.
        """
        if not self.due(step):
            return 0
        return self.checkpoint(step, state)

    def wait(self):
        if self._writer is not None and self._writer.is_alive():
            self._writer.join()
        self._writer = None

    def drop_buddy(self) -> None:
        """Simulate a hard failure: the buddy copy is lost too, so the next
        restore must fall back to the deep (PFS) level."""
        self.wait()                      # don't race an in-flight push
        if self.buddy is not None:
            self.buddy.clear()

    # ---------------------------------------------------------------- restore
    def restore(self, like_tree: Any):
        """Deepest *surviving* level wins by recency: the newest of (valid
        store generation, buddy replica).  With ``pfs_every > 1`` the buddy
        usually holds a fresher state than the last PFS write; ties prefer
        the store (it survives process loss, the buddy does not).

        A store-sourced restore reseeds the buddy replica: after a hard
        failure the replacement pair starts protected again, so later
        buddy-only checkpoints have a level to deepen from.
        """
        self.wait()
        s_tree, s_step = self.store.restore(like_tree)
        b_tree, b_step = (self.buddy.restore(like_tree)
                          if self.buddy is not None else (None, None))
        if b_tree is not None and (s_tree is None or b_step > s_step):
            self._rewind_to(b_step)
            return b_tree, b_step, "buddy"
        if s_tree is not None:
            if self.buddy is not None:
                self.buddy.push(s_step, s_tree)
            self._rewind_to(s_step)
            return s_tree, s_step, "store"
        return None, None, "none"

    def _rewind_to(self, step: int) -> None:
        """Re-anchor the schedule at a restored checkpoint: checkpoints for
        the redone span must be re-taken (``_last_ckpt_step`` rolls back —
        otherwise a second failure during the redo re-loses everything),
        and the deep-every cadence resumes from the restored checkpoint's
        ordinal so the superperiod structure survives rollbacks."""
        self._last_ckpt_step = step
        pos = self._ckpt_pos.get(step)
        if pos is not None:
            self._n_ckpts = pos + 1

    @property
    def measured_C_s(self) -> Optional[float]:
        with self._lock:
            if not self.stats:
                return None
            return float(np.mean([s["C_s"] for s in self.stats[-5:]]))

    def last_checkpoint(self) -> Optional[dict]:
        """The most recent completed write's stats entry (level, C_s, ...)."""
        self.wait()
        with self._lock:
            return dict(self.stats[-1]) if self.stats else None
