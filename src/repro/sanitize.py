"""Compile/leak sanitizer tier: mechanical backstops for the
pow2-bucketing / LRU discipline the dispatch stack enforces by hand.

Two gates, both driven from the canonical workloads below (small fig2,
multilevel, and advisor sweeps — the same code paths the committed
benchmarks exercise):

* **Recompilation budget** — every workload is run from a cold jit
  cache under ``jax.log_compiles`` and the number of compiled programs
  is counted (the WARNING-level ``Compiling <name> ...`` records jax
  emits while the flag is on).  The count must stay within the budget
  committed in ``BENCH_sweep.json`` under the ``recompile_budget`` key.
  A shape-unbucketed code path (the seed-era per-point pattern) shows
  up as one program per grid point and blows the budget immediately.

* **Leak check** — the same workloads run under ``jax.checking_leaks``,
  which raises if a traced value escapes its trace (the failure mode
  that turns pure solver code into silent nondeterminism).

Budgets carry slack of ``max(4, 25%)`` over the measured count so
jax-version drift across the CI matrix does not trip the gate, while a
per-point compile explosion (O(grid size) programs) still does.

Regenerate the committed budgets after a deliberate compile-behavior
change (new kernel, different bucketing) the same way the bench
baseline is regenerated::

    PYTHONPATH=src python -m repro.sanitize --write

and commit the resulting ``BENCH_sweep.json``.  ``python -m
repro.sanitize`` alone measures and checks against the committed
budgets (exit 1 on breach) — the pytest tier
(``tests/test_sanitizers.py``, marker ``sanitizer``) asserts the same
thing per-workload, plus leak-cleanliness.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import logging
import math
import sys
from pathlib import Path
from typing import Callable, Dict, Iterator, Optional

BENCH_PATH = Path(__file__).resolve().parents[2] / "BENCH_sweep.json"
BUDGET_KEY = "recompile_budget"

#: loggers that emit the ``Compiling <name> ...`` records across the
#: supported jax range (0.4.x logs from the pxla interpreter; keep the
#: dispatch logger too for older/newer layouts).
_COMPILE_LOGGERS = ("jax._src.interpreters.pxla", "jax._src.dispatch")


class CompileCounter(logging.Handler):
    """Counts jax compile events while ``jax.log_compiles`` is on."""

    def __init__(self) -> None:
        super().__init__(level=logging.WARNING)
        self.count = 0
        self.names = []

    def emit(self, record: logging.LogRecord) -> None:
        msg = record.getMessage()
        if msg.startswith("Compiling "):
            self.count += 1
            self.names.append(msg.split(" ", 2)[1])


@contextlib.contextmanager
def count_compiles() -> Iterator[CompileCounter]:
    """Context manager counting compiled programs inside the block."""
    import jax

    counter = CompileCounter()
    loggers = [logging.getLogger(n) for n in _COMPILE_LOGGERS]
    # the counter is the only consumer: stop the log_compiles record
    # flood from propagating to the root handlers while we count.
    prev = [lg.propagate for lg in loggers]
    for lg in loggers:
        lg.addHandler(counter)
        lg.propagate = False
    try:
        with jax.log_compiles(True):
            yield counter
    finally:
        for lg, p in zip(loggers, prev):
            lg.removeHandler(counter)
            lg.propagate = p


# ---------------------------------------------------------------------------
# canonical workloads
# ---------------------------------------------------------------------------


def _run_fig2_small() -> None:
    from repro.sim import sweep_mu_rho_grid

    sweep_mu_rho_grid([120.0, 300.0, 600.0], [1.0, 2.5, 5.0])


def _run_multilevel_small() -> None:
    from repro.sim import buddy_ratio_grid, evaluate_multilevel_grid

    grid = buddy_ratio_grid([0.1, 0.5], [0.05, 0.2], mu_min=300.0)
    evaluate_multilevel_grid(grid, m_values=(1, 2, 3, 4))


def _run_advisor_batch() -> None:
    from repro.serve.loadgen import synthetic_requests
    from repro.serve.service import AdvisorService

    svc = AdvisorService(cache_name=None)
    svc.advise_many(synthetic_requests(12, seed=0, repeat_frac=0.25))


#: name -> zero-arg canonical workload.  These are the sweeps the
#: committed benchmarks gate; keeping the sanitizer on the same paths
#: means a bucketing regression fails both tiers for the same reason.
CANONICAL_WORKLOADS: Dict[str, Callable[[], None]] = {
    "fig2_small": _run_fig2_small,
    "multilevel_small": _run_multilevel_small,
    "advisor_batch": _run_advisor_batch,
}


def measure_workload(fn: Callable[[], None], clear: bool = True) -> int:
    """Compiled-program count for one cold run of ``fn``.

    ``clear=True`` resets the jit caches first, so the count is the
    workload's full compile footprint regardless of what ran earlier in
    the process (the committed budgets assume this).
    """
    import jax

    if clear:
        jax.clear_caches()
    with count_compiles() as counter:
        fn()
    return counter.count


def run_leak_checked(fn: Callable[[], None]) -> None:
    """Run a workload under ``jax.checking_leaks`` (raises on leaks)."""
    import jax

    with jax.checking_leaks():
        fn()


# ---------------------------------------------------------------------------
# budgets
# ---------------------------------------------------------------------------


class RecompileBudgetError(AssertionError):
    """A workload compiled more programs than its committed budget."""


def _slack(measured: int) -> int:
    return max(4, math.ceil(0.25 * measured))


def load_budgets(path: Path = BENCH_PATH) -> Optional[Dict]:
    try:
        with open(path) as f:
            return json.load(f).get(BUDGET_KEY)
    except (OSError, json.JSONDecodeError):
        return None


def recompile_gate(name: str, measured: int,
                   budgets: Optional[Dict] = None,
                   path: Path = BENCH_PATH) -> None:
    """Raise :class:`RecompileBudgetError` if ``measured`` breaches the
    committed budget for workload ``name`` (no-op when no budget is
    committed — the pytest tier skips in that case instead)."""
    if budgets is None:
        budgets = load_budgets(path)
    entry = (budgets or {}).get(name)
    if entry is None:
        return
    if measured > entry["budget"]:
        raise RecompileBudgetError(
            f"{name}: compiled {measured} programs, budget is "
            f"{entry['budget']} (measured {entry['measured']} at commit "
            "time). A new shape reached the jit cache per grid point or "
            "per request — check pow2 bucketing / static-argument "
            "hygiene, or regenerate via `python -m repro.sanitize "
            "--write` if the change is deliberate.")


def measure_all(clear: bool = True) -> Dict[str, int]:
    return {name: measure_workload(fn, clear=clear)
            for name, fn in CANONICAL_WORKLOADS.items()}


def write_budgets(measured: Dict[str, int],
                  path: Path = BENCH_PATH) -> Dict:
    """Fold measured counts into ``BENCH_sweep.json`` (other keys kept)."""
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError):
        payload = {}
    payload[BUDGET_KEY] = {
        "unit": "compiled programs per cold canonical workload",
        **{name: {"measured": n, "budget": n + _slack(n)}
           for name, n in measured.items()},
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return payload[BUDGET_KEY]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sanitize",
        description="Measure canonical-workload compile counts and "
                    "check (or --write) the committed recompile budget.")
    ap.add_argument("--write", action="store_true",
                    help="regenerate the recompile_budget entry in "
                         "BENCH_sweep.json from this run")
    ap.add_argument("--path", type=Path, default=BENCH_PATH)
    args = ap.parse_args(argv)

    measured = measure_all()
    for name, n in measured.items():
        print(f"{name}: {n} compiled programs")
    if args.write:
        entry = write_budgets(measured, path=args.path)
        print(f"wrote {BUDGET_KEY} to {args.path}: "
              f"{json.dumps(entry, indent=2)}")
        return 0
    budgets = load_budgets(args.path)
    if budgets is None:
        print(f"no {BUDGET_KEY} committed in {args.path}; run with "
              "--write to create it", file=sys.stderr)
        return 1
    failed = False
    for name, n in measured.items():
        try:
            recompile_gate(name, n, budgets)
        except RecompileBudgetError as e:
            print(f"FAIL {e}", file=sys.stderr)
            failed = True
    print("recompile budget:", "BREACHED" if failed else "ok")
    return 1 if failed else 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
