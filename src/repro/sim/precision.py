"""Per-backend precision policy for the sweep/engine stack.

The repo's model/solver subsystems are f64-everywhere by contract
(reprolint RPL003, docs/contracts.md) — the right default on CPU, where
x64 is native.  Accelerators are a different trade: GPUs pay 2-64x for
f64 and TPUs have no native f64 at all, so the accelerator-native paths
(the Pallas event kernel, the policy-routed model sweeps) compute in f32
with COMPENSATED accumulation and are gated against the f64 oracle.

:class:`PrecisionPolicy` names one point in that trade:

``f64``
    Compute dtype float64, plain accumulation.  The oracle, and the
    default wherever the backend is CPU.  Selecting it explicitly is a
    bit-exact no-op (tests/test_pallas_engine.py).

``compensated_f32``
    Compute dtype float32 with Neumaier (two-sum) compensated
    accumulation for every running sum (the engine's wall/work/io/down/
    committed accumulators, the model sweep's energy-term sum).  The
    default on GPU/TPU backends.  Documented tolerances versus the f64
    oracle (asserted per scenario family by the parity gates):

    * objectives at the served optimum: ``objective_tol`` (1e-6
      relative) — near an argmin the objective is locally quadratic, so
      a relative period error ``dT/T`` costs only ``O((dT/T)^2)`` in
      objective; f32 solvers land the period within ~1e-4, leaving
      orders of magnitude of headroom.
    * the argmin itself: ``argmin_rtol`` (1e-2 relative) — a flat-valley
      bound, NOT f32 resolution: the argmin wanders long before the
      objective moves (a ``dT/T`` of 1e-2 costs only ``O(1e-4)``
      relative in objective, and the measured objective error at the
      f32 argmin is ~1e-8 across the scenario families, so the parity
      gates re-evaluate the f32 argmin in f64 and hold THAT to
      ``objective_tol`` — the argmin gate is the loose outer fence).

This module is the ONE place in ``sim/`` where float32 references are
legal (reprolint RPL003 exempts it); everything else must route through
a :class:`PrecisionPolicy`.  Policies resolve per call site via
``sim.dispatch.resolve_precision`` (explicit argument > DispatchConfig
field > ``$REPRO_PRECISION`` > backend default).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """One named precision trade (see module docstring).

    ``dtype`` is a dtype NAME (hashable — policies ride in jit/dispatch
    cache keys); ``compensated`` turns every policy-routed running sum
    into a Neumaier compensated sum; ``objective_tol``/``argmin_rtol``
    are the documented parity tolerances versus the f64 oracle (0.0 for
    the oracle itself).  The advisor folds ``objective_tol`` into its
    certified degradation bound, so serving under a reduced-precision
    policy tightens certification instead of silently eroding it.
    """

    name: str
    dtype: str
    compensated: bool
    objective_tol: float
    argmin_rtol: float

    @property
    def exact(self) -> bool:
        """True for the f64 oracle policy (plain accumulation)."""
        return self.dtype == "float64" and not self.compensated

    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    def cast(self, x):
        """``x`` as a jax array of the policy's compute dtype."""
        return jnp.asarray(x, dtype=self.jnp_dtype())


F64 = PrecisionPolicy(name="f64", dtype="float64", compensated=False,
                      objective_tol=0.0, argmin_rtol=0.0)
COMPENSATED_F32 = PrecisionPolicy(name="compensated_f32", dtype="float32",
                                  compensated=True, objective_tol=1e-6,
                                  argmin_rtol=1e-2)

#: registry of named policies (``resolve`` accepts these names).
POLICIES = {p.name: p for p in (F64, COMPENSATED_F32)}


def default_policy(platform: str | None = None) -> PrecisionPolicy:
    """The backend's default policy: f64 on CPU, compensated f32 on
    accelerators (``platform`` = a jax platform name; None = the
    process default backend)."""
    plat = platform if platform is not None else jax.default_backend()
    return F64 if plat == "cpu" else COMPENSATED_F32


def resolve(policy) -> PrecisionPolicy:
    """Coerce ``policy`` (None / name / :class:`PrecisionPolicy`) to a
    policy; None means the current default backend's policy."""
    if policy is None:
        return default_policy()
    if isinstance(policy, str):
        try:
            return POLICIES[policy]
        except KeyError:
            raise ValueError(
                f"unknown precision policy {policy!r}; "
                f"one of {sorted(POLICIES)}") from None
    if isinstance(policy, PrecisionPolicy):
        return policy
    raise TypeError(f"expected a PrecisionPolicy, name, or None; "
                    f"got {type(policy).__name__}")


# ---------------------------------------------------------------------------
# Compensated accumulation (Neumaier / two-sum)
# ---------------------------------------------------------------------------

def two_sum(a, b):
    """Knuth's exact two-sum: ``(s, err)`` with ``a + b == s + err``
    exactly in the working precision (no magnitude ordering assumed).
    XLA preserves IEEE semantics (no reassociation), so the error term
    survives compilation."""
    s = a + b
    bb = s - a
    err = (a - (s - bb)) + (b - bb)
    return s, err


def comp_add(s, c, x):
    """One Neumaier step: add ``x`` to the compensated pair ``(s, c)``.

    The invariant is ``true_sum ~= s + c`` — read the corrected value
    with ``s + c`` (or keep the pair and keep adding).  Unlike classic
    Kahan the compensation is a plain accumulator, so applying a
    ``where``-select to both members of the pair preserves the invariant
    lane-by-lane (what the engine's done-lane masking needs).
    """
    s2, err = two_sum(s, x)
    return s2, c + err


def compensated_sum(terms):
    """Neumaier sum of a sequence of (broadcast-compatible) arrays."""
    terms = list(terms)
    s = terms[0]
    c = jnp.zeros_like(s)
    for t in terms[1:]:
        s, c = comp_add(s, c, t)
    return s + c


# ---------------------------------------------------------------------------
# Trace-time policy context
# ---------------------------------------------------------------------------
#
# The batched model sweeps share one algebra (sim/sweep.py) between the
# f64 oracle and the reduced-precision policies; the policy build wraps
# the traced core in ``trace_policy`` so policy-aware reductions
# (``psum``) pick the compensated form WITHOUT threading a policy
# argument through every closed-form helper.  The context only matters
# at trace time (jit tracing runs the Python body synchronously);
# compiled programs bake the choice in, and the dispatch runner cache
# keys include the policy name so programs never cross policies.

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_precision_policy", default=F64)


def active_policy() -> PrecisionPolicy:
    """The policy in effect for the current (trace) context."""
    return _ACTIVE.get()


@contextlib.contextmanager
def trace_policy(policy: PrecisionPolicy):
    """Set the active policy for the duration of a trace."""
    token = _ACTIVE.set(policy)
    try:
        yield policy
    finally:
        _ACTIVE.reset(token)


def psum(terms):
    """Policy-aware sum of a term sequence.

    Under the f64 oracle (the default context) this is the plain
    left-associated chain ``t0 + t1 + ...`` — bit-identical to writing
    the ``+`` chain inline, so wrapping an existing sum is a no-op.
    Under a compensated policy it is a Neumaier sum.  Works on numpy
    operands too (the serve certificate sweeps evaluate the same
    closed forms eagerly on host arrays).
    """
    terms = list(terms)
    if _ACTIVE.get().compensated:
        return compensated_sum(terms)
    s = terms[0]
    for t in terms[1:]:
        s = s + t
    return s
