"""Scenario catalog + parameter grids for the batched simulation layers.

A :class:`Scenario` is one named (checkpoint, power) operating point — the
paper's figure setups, the Exascale §4 scenarios, and per-architecture
instantiations from ``repro.configs`` all live in one registry instead of
ad-hoc helper functions scattered over ``core.params`` and the benchmarks.

A :class:`ParamGrid` is the struct-of-arrays form the vectorized engine and
sweep consume: every resilience/power parameter as a broadcast ``float64``
array of a common shape, so a whole (scenario x parameter) grid is evaluated
in a few jitted calls.

Registering a new scenario::

    @register_scenario("my_platform")
    def my_platform(mu_min: float = 600.0) -> Scenario:
        ck = CheckpointParams(C=2.0, R=2.0, D=0.5, mu=mu_min, omega=0.25)
        pw = PowerParams.from_ratios(alpha=0.8, beta=4.0)
        return Scenario(name="my_platform", ckpt=ck, power=pw)

    get_scenario("my_platform", mu_min=120.0)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Sequence

import numpy as np

from ..core.params import (CheckpointParams, PowerParams,
                           EXASCALE_POWER_RHO55, EXASCALE_POWER_RHO7,
                           MU_IND_JAGUAR_MIN)


# ---------------------------------------------------------------------------
# Scenario: one named operating point
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    ckpt: CheckpointParams
    power: PowerParams
    T_base: float = 1.0
    description: str = ""


_REGISTRY: Dict[str, Callable[..., Scenario]] = {}


def register_scenario(name: str):
    """Decorator: register a named Scenario constructor."""
    def deco(fn: Callable[..., Scenario]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_scenario(name: str, **kwargs) -> Scenario:
    try:
        ctor = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"one of {sorted(_REGISTRY)}") from None
    return ctor(**kwargs)


def list_scenarios() -> dict:
    """name -> first docstring line of each registered constructor."""
    return {n: (fn.__doc__ or "").strip().splitlines()[0] if fn.__doc__ else ""
            for n, fn in sorted(_REGISTRY.items())}


# -- the paper's figure setups ----------------------------------------------

@register_scenario("fig12")
def fig12(mu_min: float = 300.0, rho: float = 5.5,
          alpha: float = 1.0) -> Scenario:
    """Figures 1-2: C=R=10 min, D=1 min, omega=1/2; power from target rho."""
    ck = CheckpointParams(C=10.0, R=10.0, D=1.0, mu=mu_min, omega=0.5)
    pw = PowerParams.from_rho(rho=rho, alpha=alpha)
    return Scenario(name=f"fig12(mu={mu_min:g},rho={rho:g})", ckpt=ck,
                    power=pw, description="paper Figures 1-2 setup")


@register_scenario("fig3")
def fig3(n_nodes: float = 1.0e6, rho: float = 5.5) -> Scenario:
    """Figure 3: C=R=1 min, D=0.1 min, omega=1/2, mu=120 min @ 1e6 nodes."""
    mu = 120.0 * (1.0e6 / float(n_nodes))
    ck = CheckpointParams(C=1.0, R=1.0, D=0.1, mu=mu, omega=0.5)
    pw = EXASCALE_POWER_RHO55 if abs(rho - 5.5) < 1e-9 else (
        EXASCALE_POWER_RHO7 if abs(rho - 7.0) < 1e-9
        else PowerParams.from_rho(rho=rho, alpha=1.0))
    return Scenario(name=f"fig3(N={n_nodes:g},rho={rho:g})", ckpt=ck,
                    power=pw, description="paper Figure 3 scalability setup")


# -- §4 Exascale operating points -------------------------------------------

@register_scenario("exascale_rho55")
def exascale_rho55(mu_min: float = 300.0) -> Scenario:
    """Exascale scenario #1: 20 mW/node, half static (rho = 5.5)."""
    ck = CheckpointParams(C=10.0, R=10.0, D=1.0, mu=mu_min, omega=0.5)
    return Scenario(name=f"exascale_rho55(mu={mu_min:g})", ckpt=ck,
                    power=EXASCALE_POWER_RHO55,
                    description="paper §4 Exascale power scenario, rho=5.5")


@register_scenario("exascale_rho7")
def exascale_rho7(mu_min: float = 300.0) -> Scenario:
    """Exascale scenario #2: P_static = 5 mW, same overheads (rho = 7)."""
    ck = CheckpointParams(C=10.0, R=10.0, D=1.0, mu=mu_min, omega=0.5)
    return Scenario(name=f"exascale_rho7(mu={mu_min:g})", ckpt=ck,
                    power=EXASCALE_POWER_RHO7,
                    description="paper §4 Exascale power scenario, rho=7")


@register_scenario("jaguar")
def jaguar(n_nodes: int = 45208, C: float = 10.0, R: float = 10.0,
           D: float = 1.0, omega: float = 0.5) -> Scenario:
    """Jaguar-derived platform: mu_ind ~ 125 years, mu = mu_ind / N."""
    ck = CheckpointParams(C=C, R=R, D=D,
                         mu=MU_IND_JAGUAR_MIN / float(n_nodes), omega=omega)
    return Scenario(name=f"jaguar(N={n_nodes})", ckpt=ck,
                    power=EXASCALE_POWER_RHO55,
                    description="Jaguar per-proc MTBF scaled to N units")


# -- per-architecture instantiation (production mesh) ------------------------

#: optimizer state = bf16 params + bf16 momentum + f32 master copy.
STATE_BYTES_PER_PARAM = 2 + 2 + 4


def _arch_checkpoint_seconds(arch: str, hosts: int, bw: float) -> float:
    from ..configs import get_config
    from ..models import build
    n = build(get_config(arch)).param_count()
    return n * STATE_BYTES_PER_PARAM / (hosts * bw)


@register_scenario("arch")
def arch(arch: str = "dbrx-132b", hosts: int = 64, bw: float = 8e9,
         n_nodes: int = 256, D_s: float = 60.0, omega: float = 0.5,
         profile: str = "paper") -> Scenario:
    """One production architecture: C from checkpoint bytes / host I/O bw."""
    from ..energy import PAPER_EXASCALE_PROFILE, TPU_V5E_HOST_PROFILE
    mu_ind_s = 125.0 * 365 * 24 * 3600          # Jaguar-derived per-unit MTBF
    C = _arch_checkpoint_seconds(arch, hosts, bw)
    ck = CheckpointParams(C=C, R=C, D=D_s, mu=mu_ind_s / n_nodes, omega=omega)
    pw = (PAPER_EXASCALE_PROFILE if profile == "paper"
          else TPU_V5E_HOST_PROFILE).power_params()
    return Scenario(name=f"arch({arch})", ckpt=ck, power=pw,
                    description=f"{arch} on the production mesh "
                                f"({hosts} hosts @ {bw:g} B/s)")


# ---------------------------------------------------------------------------
# ParamGrid: struct-of-arrays parameter batches
# ---------------------------------------------------------------------------

_FIELDS = ("C", "R", "D", "mu", "omega",
           "P_static", "P_cal", "P_io", "P_down")


@dataclasses.dataclass(frozen=True)
class ParamGrid:
    """Broadcast float64 arrays of checkpoint + power parameters.

    All nine fields share one shape after construction; the batched engine
    and sweep treat the leading axes as the parameter batch.
    """

    C: np.ndarray
    R: np.ndarray
    D: np.ndarray
    mu: np.ndarray
    omega: np.ndarray
    P_static: np.ndarray
    P_cal: np.ndarray
    P_io: np.ndarray
    P_down: np.ndarray

    def __post_init__(self):
        arrs = np.broadcast_arrays(*(np.asarray(getattr(self, f),
                                                dtype=np.float64)
                                     for f in _FIELDS))
        for f, a in zip(_FIELDS, arrs):
            object.__setattr__(self, f, np.ascontiguousarray(a))

    # -- shape plumbing ------------------------------------------------------
    @property
    def shape(self) -> tuple:
        return self.C.shape

    @property
    def size(self) -> int:
        return self.C.size

    def ravel(self) -> "ParamGrid":
        return ParamGrid(**{f: getattr(self, f).ravel() for f in _FIELDS})

    def reshape(self, shape) -> "ParamGrid":
        return ParamGrid(**{f: getattr(self, f).reshape(shape)
                            for f in _FIELDS})

    def fields(self) -> dict:
        """Dict-of-arrays view (a jit-friendly pytree)."""
        return {f: getattr(self, f) for f in _FIELDS}

    # -- derived (paper §3) --------------------------------------------------
    @property
    def a(self) -> np.ndarray:
        return (1.0 - self.omega) * self.C

    @property
    def b(self) -> np.ndarray:
        return 1.0 - (self.D + self.R + self.omega * self.C) / self.mu

    def period_bounds(self) -> tuple:
        """(lo, hi) of the raw valid-period interval per grid point."""
        return np.maximum(self.a, self.C), 2.0 * self.mu * self.b

    def valid(self) -> np.ndarray:
        """Non-degenerate mask — mirrors ``tradeoff.evaluate``'s guard."""
        lo, hi = self.period_bounds()
        return hi > lo * (1.0 + 1e-9)

    @property
    def rho(self) -> np.ndarray:
        return (self.P_static + self.P_io) / (self.P_static + self.P_cal)

    # -- object views --------------------------------------------------------
    def ckpt_at(self, idx) -> CheckpointParams:
        return CheckpointParams(C=float(self.C[idx]), R=float(self.R[idx]),
                                D=float(self.D[idx]), mu=float(self.mu[idx]),
                                omega=float(self.omega[idx]))

    def power_at(self, idx) -> PowerParams:
        return PowerParams(P_static=float(self.P_static[idx]),
                           P_cal=float(self.P_cal[idx]),
                           P_io=float(self.P_io[idx]),
                           P_down=float(self.P_down[idx]))

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_params(cls, ckpt: CheckpointParams,
                    power: PowerParams) -> "ParamGrid":
        return cls(C=ckpt.C, R=ckpt.R, D=ckpt.D, mu=ckpt.mu, omega=ckpt.omega,
                   P_static=power.P_static, P_cal=power.P_cal,
                   P_io=power.P_io, P_down=power.P_down)


def grid_from_scenarios(scens: Iterable[Scenario]) -> ParamGrid:
    """Stack scenarios along one leading axis (shape ``(len(scens),)``)."""
    scens = list(scens)
    return ParamGrid(
        C=[s.ckpt.C for s in scens], R=[s.ckpt.R for s in scens],
        D=[s.ckpt.D for s in scens], mu=[s.ckpt.mu for s in scens],
        omega=[s.ckpt.omega for s in scens],
        P_static=[s.power.P_static for s in scens],
        P_cal=[s.power.P_cal for s in scens],
        P_io=[s.power.P_io for s in scens],
        P_down=[s.power.P_down for s in scens])


def product_grid(ckpts: Sequence[CheckpointParams],
                 powers: Sequence[PowerParams]) -> ParamGrid:
    """Outer product grid of shape ``(len(ckpts), len(powers))``."""
    col = lambda xs: np.asarray(xs, dtype=np.float64)[:, None]
    row = lambda xs: np.asarray(xs, dtype=np.float64)[None, :]
    return ParamGrid(
        C=col([c.C for c in ckpts]), R=col([c.R for c in ckpts]),
        D=col([c.D for c in ckpts]), mu=col([c.mu for c in ckpts]),
        omega=col([c.omega for c in ckpts]),
        P_static=row([p.P_static for p in powers]),
        P_cal=row([p.P_cal for p in powers]),
        P_io=row([p.P_io for p in powers]),
        P_down=row([p.P_down for p in powers]))


def mu_rho_grid(mus: Sequence[float], rhos: Sequence[float],
                alpha: float = 1.0) -> ParamGrid:
    """Figures 1-2 grid: fig12 resilience x powers at target rho values."""
    ckpts = [get_scenario("fig12", mu_min=float(m)).ckpt for m in mus]
    powers = [PowerParams.from_rho(rho=float(r), alpha=alpha) for r in rhos]
    return product_grid(ckpts, powers)


def nodes_grid(n_nodes: Sequence[float], power: PowerParams) -> ParamGrid:
    """Figure 3 grid: scalability in N at one power scenario (1-D)."""
    ckpts = [get_scenario("fig3", n_nodes=float(n)).ckpt for n in n_nodes]
    return product_grid(ckpts, [power]).reshape((len(ckpts),))


def arch_grid(archs: Sequence[str] | None = None, **kwargs) -> ParamGrid:
    """All (or the named) production architectures as one 1-D grid."""
    if archs is None:
        from ..configs import ALL_ARCHS
        archs = [c.name for c in ALL_ARCHS]
    return grid_from_scenarios(get_scenario("arch", arch=a, **kwargs)
                               for a in archs)
