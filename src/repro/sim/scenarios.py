"""Scenario catalog + parameter grids for the batched simulation layers.

A :class:`Scenario` is one named (checkpoint, power) operating point — the
paper's figure setups, the Exascale §4 scenarios, and per-architecture
instantiations from ``repro.configs`` all live in one registry instead of
ad-hoc helper functions scattered over ``core.params`` and the benchmarks.

A :class:`ParamGrid` is the struct-of-arrays form the vectorized engine and
sweep consume: every resilience/power parameter as a broadcast ``float64``
array of a common shape, so a whole (scenario x parameter) grid is evaluated
in a few jitted calls.

Registering a new scenario::

    @register_scenario("my_platform")
    def my_platform(mu_min: float = 600.0) -> Scenario:
        ck = CheckpointParams(C=2.0, R=2.0, D=0.5, mu=mu_min, omega=0.25)
        pw = PowerParams.from_ratios(alpha=0.8, beta=4.0)
        return Scenario(name="my_platform", ckpt=ck, power=pw)

    get_scenario("my_platform", mu_min=120.0)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

from ..core.failures import (FailureProcess, Weibull, as_process,
                             get_process)
from ..core.params import (CheckpointParams, MultilevelCheckpointParams,
                           MultilevelPowerParams, PowerParams,
                           EXASCALE_POWER_RHO55, EXASCALE_POWER_RHO7,
                           EXASCALE_ML_POWER, MU_IND_JAGUAR_MIN)


# ---------------------------------------------------------------------------
# Scenario: one named operating point
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    ckpt: CheckpointParams
    power: PowerParams
    T_base: float = 1.0
    description: str = ""
    #: inter-failure distribution; None = the paper's exponential process.
    process: Optional[FailureProcess] = None


_REGISTRY: Dict[str, Callable[..., Scenario]] = {}


def register_scenario(name: str):
    """Decorator: register a named Scenario constructor."""
    def deco(fn: Callable[..., Scenario]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_scenario(name: str, **kwargs) -> Scenario:
    try:
        ctor = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"one of {sorted(_REGISTRY)}") from None
    return ctor(**kwargs)


def list_scenarios() -> dict:
    """name -> first docstring line of each registered constructor."""
    return {n: (fn.__doc__ or "").strip().splitlines()[0] if fn.__doc__ else ""
            for n, fn in sorted(_REGISTRY.items())}


# -- the paper's figure setups ----------------------------------------------

@register_scenario("fig12")
def fig12(mu_min: float = 300.0, rho: float = 5.5,
          alpha: float = 1.0) -> Scenario:
    """Figures 1-2: C=R=10 min, D=1 min, omega=1/2; power from target rho."""
    ck = CheckpointParams(C=10.0, R=10.0, D=1.0, mu=mu_min, omega=0.5)
    pw = PowerParams.from_rho(rho=rho, alpha=alpha)
    return Scenario(name=f"fig12(mu={mu_min:g},rho={rho:g})", ckpt=ck,
                    power=pw, description="paper Figures 1-2 setup")


@register_scenario("fig3")
def fig3(n_nodes: float = 1.0e6, rho: float = 5.5) -> Scenario:
    """Figure 3: C=R=1 min, D=0.1 min, omega=1/2, mu=120 min @ 1e6 nodes."""
    mu = 120.0 * (1.0e6 / float(n_nodes))
    ck = CheckpointParams(C=1.0, R=1.0, D=0.1, mu=mu, omega=0.5)
    pw = EXASCALE_POWER_RHO55 if abs(rho - 5.5) < 1e-9 else (
        EXASCALE_POWER_RHO7 if abs(rho - 7.0) < 1e-9
        else PowerParams.from_rho(rho=rho, alpha=1.0))
    return Scenario(name=f"fig3(N={n_nodes:g},rho={rho:g})", ckpt=ck,
                    power=pw, description="paper Figure 3 scalability setup")


# -- §4 Exascale operating points -------------------------------------------

@register_scenario("exascale_rho55")
def exascale_rho55(mu_min: float = 300.0) -> Scenario:
    """Exascale scenario #1: 20 mW/node, half static (rho = 5.5)."""
    ck = CheckpointParams(C=10.0, R=10.0, D=1.0, mu=mu_min, omega=0.5)
    return Scenario(name=f"exascale_rho55(mu={mu_min:g})", ckpt=ck,
                    power=EXASCALE_POWER_RHO55,
                    description="paper §4 Exascale power scenario, rho=5.5")


@register_scenario("exascale_rho7")
def exascale_rho7(mu_min: float = 300.0) -> Scenario:
    """Exascale scenario #2: P_static = 5 mW, same overheads (rho = 7)."""
    ck = CheckpointParams(C=10.0, R=10.0, D=1.0, mu=mu_min, omega=0.5)
    return Scenario(name=f"exascale_rho7(mu={mu_min:g})", ckpt=ck,
                    power=EXASCALE_POWER_RHO7,
                    description="paper §4 Exascale power scenario, rho=7")


@register_scenario("jaguar")
def jaguar(n_nodes: int = 45208, C: float = 10.0, R: float = 10.0,
           D: float = 1.0, omega: float = 0.5) -> Scenario:
    """Jaguar-derived platform: mu_ind ~ 125 years, mu = mu_ind / N."""
    ck = CheckpointParams(C=C, R=R, D=D,
                         mu=MU_IND_JAGUAR_MIN / float(n_nodes), omega=omega)
    return Scenario(name=f"jaguar(N={n_nodes})", ckpt=ck,
                    power=EXASCALE_POWER_RHO55,
                    description="Jaguar per-proc MTBF scaled to N units")


# -- robustness family: realistic (non-exponential) failure processes --------

@register_scenario("robustness")
def robustness(base: str = "exascale_rho55", process: str = "weibull",
               shape: float = 0.7, sigma: float = 1.0,
               trace=None, **base_kwargs) -> Scenario:
    """Any registered scenario under a non-exponential failure process.

    ``process`` is one of ``repro.core.failures.PROCESSES``
    (weibull/lognormal/trace/exponential); the process targets the base
    scenario's platform MTBF, so results isolate the *shape* of the
    inter-failure distribution from its mean.
    """
    sc = get_scenario(base, **base_kwargs)
    if process == "weibull":
        proc: FailureProcess = get_process("weibull", shape=shape)
        tag = f"weibull(k={shape:g})"
    elif process == "lognormal":
        proc = get_process("lognormal", sigma=sigma)
        tag = f"lognormal(sigma={sigma:g})"
    elif process == "trace":
        if trace is None:
            raise ValueError("process='trace' needs trace=[gaps...]")
        proc = get_process("trace", gaps=tuple(trace))
        tag = f"trace(n={len(proc.gaps)})"
    else:
        proc = as_process(process)
        tag = proc.name
    return Scenario(name=f"robustness[{sc.name}, {tag}]", ckpt=sc.ckpt,
                    power=sc.power, T_base=sc.T_base, process=proc,
                    description=f"{sc.description or sc.name} under "
                                f"{tag} failures")


def robustness_grid(shapes: Sequence[float], mu_mins: Sequence[float],
                    base: str = "exascale_rho55",
                    ) -> Tuple[ParamGrid, Weibull]:
    """Weibull-shape x platform-MTBF grid over an exascale scenario family.

    Returns the ``(len(shapes), len(mu_mins))`` :class:`ParamGrid` plus the
    matching :class:`~repro.core.failures.Weibull` process whose ``shape``
    array broadcasts over the grid (one k per row) — the pair
    ``sim.evaluate_robustness_grid`` consumes.
    """
    scens = [get_scenario(base, mu_min=float(m)) for m in mu_mins]
    row = grid_from_scenarios(scens)
    grid = ParamGrid(**{f: np.broadcast_to(getattr(row, f),
                                           (len(shapes), len(mu_mins)))
                        for f in _FIELDS})
    shape_arr = np.broadcast_to(
        np.asarray(shapes, dtype=np.float64)[:, None], grid.shape)
    return grid, Weibull(shape=shape_arr)


# -- per-architecture instantiation (production mesh) ------------------------

#: optimizer state = bf16 params + bf16 momentum + f32 master copy.
STATE_BYTES_PER_PARAM = 2 + 2 + 4


def _arch_checkpoint_seconds(arch: str, hosts: int, bw: float) -> float:
    from ..configs import get_config
    from ..models import build
    n = build(get_config(arch)).param_count()
    return n * STATE_BYTES_PER_PARAM / (hosts * bw)


@register_scenario("arch")
def arch(arch: str = "dbrx-132b", hosts: int = 64, bw: float = 8e9,
         n_nodes: int = 256, D_s: float = 60.0, omega: float = 0.5,
         profile: str = "paper") -> Scenario:
    """One production architecture: C from checkpoint bytes / host I/O bw."""
    from ..energy import PAPER_EXASCALE_PROFILE, TPU_V5E_HOST_PROFILE
    mu_ind_s = 125.0 * 365 * 24 * 3600          # Jaguar-derived per-unit MTBF
    C = _arch_checkpoint_seconds(arch, hosts, bw)
    ck = CheckpointParams(C=C, R=C, D=D_s, mu=mu_ind_s / n_nodes, omega=omega)
    pw = (PAPER_EXASCALE_PROFILE if profile == "paper"
          else TPU_V5E_HOST_PROFILE).power_params()
    return Scenario(name=f"arch({arch})", ckpt=ck, power=pw,
                    description=f"{arch} on the production mesh "
                                f"({hosts} hosts @ {bw:g} B/s)")


# -- multilevel (buddy + PFS) scenario family --------------------------------

@dataclasses.dataclass(frozen=True)
class MultilevelScenario:
    """One named two-level operating point (buddy + PFS)."""

    name: str
    ckpt: MultilevelCheckpointParams
    power: MultilevelPowerParams
    T_base: float = 1.0
    description: str = ""


@register_scenario("multilevel_exascale")
def multilevel_exascale(mu_min: float = 300.0, buddy_ratio: float = 0.1,
                        q: float = 0.1, C_pfs: float = 10.0,
                        P_io1: float = 20.0) -> MultilevelScenario:
    """Exascale two-level: buddy RAM checkpoints at ``buddy_ratio * C_PFS``."""
    C1 = buddy_ratio * C_pfs
    ck = MultilevelCheckpointParams(C1=C1, R1=C1, C2=C_pfs, R2=C_pfs,
                                    D1=0.5, D2=1.0, mu=mu_min, q=q,
                                    omega=0.5)
    pw = MultilevelPowerParams(P_static=10.0, P_cal=10.0, P_io1=P_io1,
                               P_io2=100.0)
    return MultilevelScenario(
        name=f"multilevel_exascale(mu={mu_min:g},ratio={buddy_ratio:g},"
             f"q={q:g})",
        ckpt=ck, power=pw,
        description="Exascale buddy+PFS hierarchy (VELOC-style)")


@register_scenario("multilevel_fig12")
def multilevel_fig12(mu_min: float = 300.0, buddy_ratio: float = 0.1,
                     q: float = 0.1) -> MultilevelScenario:
    """Figures 1-2 resilience setup lifted to two levels (C2=R2=10, D2=1)."""
    ck = MultilevelCheckpointParams(
        C1=10.0 * buddy_ratio, R1=10.0 * buddy_ratio, C2=10.0, R2=10.0,
        D1=1.0, D2=1.0, mu=mu_min, q=q, omega=0.5)
    return MultilevelScenario(
        name=f"multilevel_fig12(mu={mu_min:g})", ckpt=ck,
        power=EXASCALE_ML_POWER,
        description="paper Fig. 1-2 setup with a buddy fast level")


@register_scenario("multilevel_arch")
def multilevel_arch(arch: str = "dbrx-132b", hosts: int = 64,
                    pfs_bw: float = 8e9, buddy_bw: float = 80e9,
                    n_nodes: int = 256, D_s: float = 60.0,
                    omega: float = 0.5, q: float = 0.05,
                    ) -> MultilevelScenario:
    """One production architecture, two-level: C1 from NIC RAM-to-RAM buddy
    bandwidth, C2 from PFS bandwidth; hard failures need a node swap-in."""
    mu_ind_s = 125.0 * 365 * 24 * 3600
    C2 = _arch_checkpoint_seconds(arch, hosts, pfs_bw)
    C1 = _arch_checkpoint_seconds(arch, hosts, buddy_bw)
    ck = MultilevelCheckpointParams(C1=C1, R1=C1, C2=C2, R2=C2,
                                    D1=D_s / 10.0, D2=D_s,
                                    mu=mu_ind_s / n_nodes, q=q, omega=omega)
    from ..energy import PAPER_EXASCALE_PROFILE
    base = PAPER_EXASCALE_PROFILE.power_params()
    pw = MultilevelPowerParams(P_static=base.P_static, P_cal=base.P_cal,
                               P_io1=0.2 * base.P_io, P_io2=base.P_io,
                               P_down=base.P_down)
    return MultilevelScenario(
        name=f"multilevel_arch({arch})", ckpt=ck, power=pw,
        description=f"{arch} with buddy NIC level ({buddy_bw:g} B/s) over "
                    f"PFS ({pfs_bw:g} B/s)")


# ---------------------------------------------------------------------------
# ParamGrid: struct-of-arrays parameter batches
# ---------------------------------------------------------------------------

_FIELDS = ("C", "R", "D", "mu", "omega",
           "P_static", "P_cal", "P_io", "P_down")


@dataclasses.dataclass(frozen=True)
class ParamGrid:
    """Broadcast float64 arrays of checkpoint + power parameters.

    All nine fields share one shape after construction; the batched engine
    and sweep treat the leading axes as the parameter batch.
    """

    C: np.ndarray
    R: np.ndarray
    D: np.ndarray
    mu: np.ndarray
    omega: np.ndarray
    P_static: np.ndarray
    P_cal: np.ndarray
    P_io: np.ndarray
    P_down: np.ndarray

    def __post_init__(self):
        arrs = np.broadcast_arrays(*(np.asarray(getattr(self, f),
                                                dtype=np.float64)
                                     for f in _FIELDS))
        for f, a in zip(_FIELDS, arrs):
            object.__setattr__(self, f, np.ascontiguousarray(a))

    # -- shape plumbing ------------------------------------------------------
    @property
    def shape(self) -> tuple:
        return self.C.shape

    @property
    def size(self) -> int:
        return self.C.size

    def ravel(self) -> "ParamGrid":
        return ParamGrid(**{f: getattr(self, f).ravel() for f in _FIELDS})

    def reshape(self, shape) -> "ParamGrid":
        return ParamGrid(**{f: getattr(self, f).reshape(shape)
                            for f in _FIELDS})

    def fields(self) -> dict:
        """Dict-of-arrays view (a jit-friendly pytree)."""
        return {f: getattr(self, f) for f in _FIELDS}

    # -- derived (paper §3) --------------------------------------------------
    @property
    def a(self) -> np.ndarray:
        return (1.0 - self.omega) * self.C

    @property
    def b(self) -> np.ndarray:
        return 1.0 - (self.D + self.R + self.omega * self.C) / self.mu

    def period_bounds(self) -> tuple:
        """(lo, hi) of the raw valid-period interval per grid point."""
        return np.maximum(self.a, self.C), 2.0 * self.mu * self.b

    def valid(self) -> np.ndarray:
        """Non-degenerate mask — mirrors ``tradeoff.evaluate``'s guard."""
        lo, hi = self.period_bounds()
        return hi > lo * (1.0 + 1e-9)

    @property
    def rho(self) -> np.ndarray:
        return (self.P_static + self.P_io) / (self.P_static + self.P_cal)

    # -- object views --------------------------------------------------------
    def ckpt_at(self, idx) -> CheckpointParams:
        return CheckpointParams(C=float(self.C[idx]), R=float(self.R[idx]),
                                D=float(self.D[idx]), mu=float(self.mu[idx]),
                                omega=float(self.omega[idx]))

    def power_at(self, idx) -> PowerParams:
        return PowerParams(P_static=float(self.P_static[idx]),
                           P_cal=float(self.P_cal[idx]),
                           P_io=float(self.P_io[idx]),
                           P_down=float(self.P_down[idx]))

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_params(cls, ckpt: CheckpointParams,
                    power: PowerParams) -> "ParamGrid":
        return cls(C=ckpt.C, R=ckpt.R, D=ckpt.D, mu=ckpt.mu, omega=ckpt.omega,
                   P_static=power.P_static, P_cal=power.P_cal,
                   P_io=power.P_io, P_down=power.P_down)


def grid_from_scenarios(scens: Iterable[Scenario]) -> ParamGrid:
    """Stack scenarios along one leading axis (shape ``(len(scens),)``)."""
    scens = list(scens)
    return ParamGrid(
        C=[s.ckpt.C for s in scens], R=[s.ckpt.R for s in scens],
        D=[s.ckpt.D for s in scens], mu=[s.ckpt.mu for s in scens],
        omega=[s.ckpt.omega for s in scens],
        P_static=[s.power.P_static for s in scens],
        P_cal=[s.power.P_cal for s in scens],
        P_io=[s.power.P_io for s in scens],
        P_down=[s.power.P_down for s in scens])


def product_grid(ckpts: Sequence[CheckpointParams],
                 powers: Sequence[PowerParams]) -> ParamGrid:
    """Outer product grid of shape ``(len(ckpts), len(powers))``."""
    col = lambda xs: np.asarray(xs, dtype=np.float64)[:, None]
    row = lambda xs: np.asarray(xs, dtype=np.float64)[None, :]
    return ParamGrid(
        C=col([c.C for c in ckpts]), R=col([c.R for c in ckpts]),
        D=col([c.D for c in ckpts]), mu=col([c.mu for c in ckpts]),
        omega=col([c.omega for c in ckpts]),
        P_static=row([p.P_static for p in powers]),
        P_cal=row([p.P_cal for p in powers]),
        P_io=row([p.P_io for p in powers]),
        P_down=row([p.P_down for p in powers]))


def mu_rho_grid(mus: Sequence[float], rhos: Sequence[float],
                alpha: float = 1.0) -> ParamGrid:
    """Figures 1-2 grid: fig12 resilience x powers at target rho values."""
    ckpts = [get_scenario("fig12", mu_min=float(m)).ckpt for m in mus]
    powers = [PowerParams.from_rho(rho=float(r), alpha=alpha) for r in rhos]
    return product_grid(ckpts, powers)


def nodes_grid(n_nodes: Sequence[float], power: PowerParams) -> ParamGrid:
    """Figure 3 grid: scalability in N at one power scenario (1-D)."""
    ckpts = [get_scenario("fig3", n_nodes=float(n)).ckpt for n in n_nodes]
    return product_grid(ckpts, [power]).reshape((len(ckpts),))


def arch_grid(archs: Sequence[str] | None = None, **kwargs) -> ParamGrid:
    """All (or the named) production architectures as one 1-D grid."""
    if archs is None:
        from ..configs import ALL_ARCHS
        archs = [c.name for c in ALL_ARCHS]
    return grid_from_scenarios(get_scenario("arch", arch=a, **kwargs)
                               for a in archs)


# ---------------------------------------------------------------------------
# MultilevelParamGrid: struct-of-arrays two-level parameter batches
# ---------------------------------------------------------------------------

_ML_FIELDS = ("C1", "R1", "D1", "C2", "R2", "D2", "mu", "omega", "q",
              "P_static", "P_cal", "P_io1", "P_io2", "P_down",
              "omega1", "omega2")


@dataclasses.dataclass(frozen=True)
class MultilevelParamGrid:
    """Broadcast float64 arrays of two-level checkpoint + power parameters.

    Same plumbing as :class:`ParamGrid`, with per-level (C_k, R_k, D_k,
    P_io_k) fields plus the buddy-loss probability ``q``.  ``m`` stays a
    decision variable handled by the solvers/engine, not a grid field.
    ``omega1``/``omega2`` are the per-level overlap factors (buddy write /
    deep flush); either defaults to ``omega`` when omitted, and wherever
    they are equal the derived quantities evaluate the exact shared-omega
    expressions (bit-for-bit with the pre-async grid).
    """

    C1: np.ndarray
    R1: np.ndarray
    D1: np.ndarray
    C2: np.ndarray
    R2: np.ndarray
    D2: np.ndarray
    mu: np.ndarray
    omega: np.ndarray
    q: np.ndarray
    P_static: np.ndarray
    P_cal: np.ndarray
    P_io1: np.ndarray
    P_io2: np.ndarray
    P_down: np.ndarray
    omega1: Optional[np.ndarray] = None
    omega2: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.omega1 is None:
            object.__setattr__(self, "omega1", self.omega)
        if self.omega2 is None:
            object.__setattr__(self, "omega2", self.omega)
        arrs = np.broadcast_arrays(*(np.asarray(getattr(self, f),
                                                dtype=np.float64)
                                     for f in _ML_FIELDS))
        for f, a in zip(_ML_FIELDS, arrs):
            object.__setattr__(self, f, np.ascontiguousarray(a))

    # -- shape plumbing ------------------------------------------------------
    @property
    def shape(self) -> tuple:
        return self.C1.shape

    @property
    def size(self) -> int:
        return self.C1.size

    def ravel(self) -> "MultilevelParamGrid":
        return MultilevelParamGrid(**{f: getattr(self, f).ravel()
                                      for f in _ML_FIELDS})

    def reshape(self, shape) -> "MultilevelParamGrid":
        return MultilevelParamGrid(**{f: getattr(self, f).reshape(shape)
                                      for f in _ML_FIELDS})

    def fields(self) -> dict:
        return {f: getattr(self, f) for f in _ML_FIELDS}

    # -- per-m derived (multilevel §3.1 analogue) ---------------------------
    def C_mean(self, m) -> np.ndarray:
        return ((m - 1) * self.C1 + self.C2) / m

    def _shared_omega(self) -> np.ndarray:
        return self.omega1 == self.omega2

    def C_omega_mean(self, m) -> np.ndarray:
        per = ((m - 1) * self.omega1 * self.C1
               + self.omega2 * self.C2) / m
        return np.where(self._shared_omega(),
                        self.omega1 * self.C_mean(m), per)

    def a(self, m) -> np.ndarray:
        per = ((m - 1) * (1.0 - self.omega1) * self.C1
               + (1.0 - self.omega2) * self.C2) / m
        return np.where(self._shared_omega(),
                        (1.0 - self.omega1) * self.C_mean(m), per)

    def b(self, m) -> np.ndarray:
        soft = self.D1 + self.R1 + self.C_omega_mean(m)
        hard = self.D2 + self.R2 + self.omega2 * self.C2
        return 1.0 - (soft + self.q * (hard - soft)) / self.mu

    def mu_eff(self, m) -> np.ndarray:
        return self.mu / (1.0 + self.q * (m - 1))

    def period_bounds(self, m) -> tuple:
        lo = np.maximum(np.maximum(self.a(m), self.C1), self.C2)
        return lo, 2.0 * self.mu_eff(m) * self.b(m)

    def valid(self, m) -> np.ndarray:
        lo, hi = self.period_bounds(m)
        return hi > lo * (1.0 + 1e-9)

    # -- object views --------------------------------------------------------
    def ckpt_at(self, idx) -> MultilevelCheckpointParams:
        return MultilevelCheckpointParams(
            C1=float(self.C1[idx]), R1=float(self.R1[idx]),
            C2=float(self.C2[idx]), R2=float(self.R2[idx]),
            D1=float(self.D1[idx]), D2=float(self.D2[idx]),
            mu=float(self.mu[idx]), q=float(self.q[idx]),
            omega=float(self.omega[idx]),
            omega1=float(self.omega1[idx]),
            omega2=float(self.omega2[idx]))

    def power_at(self, idx) -> MultilevelPowerParams:
        return MultilevelPowerParams(
            P_static=float(self.P_static[idx]),
            P_cal=float(self.P_cal[idx]), P_io1=float(self.P_io1[idx]),
            P_io2=float(self.P_io2[idx]), P_down=float(self.P_down[idx]))

    # -- constructors / conversions -----------------------------------------
    @classmethod
    def from_params(cls, ckpt: MultilevelCheckpointParams,
                    power: MultilevelPowerParams) -> "MultilevelParamGrid":
        return cls(C1=ckpt.C1, R1=ckpt.R1, D1=ckpt.D1, C2=ckpt.C2,
                   R2=ckpt.R2, D2=ckpt.D2, mu=ckpt.mu, omega=ckpt.omega,
                   q=ckpt.q, P_static=power.P_static, P_cal=power.P_cal,
                   P_io1=power.P_io1, P_io2=power.P_io2,
                   P_down=power.P_down, omega1=ckpt.w1, omega2=ckpt.w2)

    @classmethod
    def from_single_level(cls, grid: ParamGrid,
                          q=0.0) -> "MultilevelParamGrid":
        """Degenerate lift of a single-level grid (C1=C2 etc.) — the exact
        m=1 reduction construction used by the parity tests."""
        return cls(C1=grid.C, R1=grid.R, D1=grid.D, C2=grid.C, R2=grid.R,
                   D2=grid.D, mu=grid.mu, omega=grid.omega, q=q,
                   P_static=grid.P_static, P_cal=grid.P_cal,
                   P_io1=grid.P_io, P_io2=grid.P_io, P_down=grid.P_down)

    def single_level(self) -> ParamGrid:
        """The PFS-only comparator grid (C=C2, R=R2, D=D2, P_io=P_io2,
        at the deep level's overlap factor)."""
        return ParamGrid(C=self.C2, R=self.R2, D=self.D2, mu=self.mu,
                         omega=self.omega2, P_static=self.P_static,
                         P_cal=self.P_cal, P_io=self.P_io2,
                         P_down=self.P_down)


def multilevel_grid_from_scenarios(
        scens: Iterable[MultilevelScenario]) -> MultilevelParamGrid:
    """Stack two-level scenarios along one leading axis."""
    scens = list(scens)
    return MultilevelParamGrid(
        **{f: [getattr(s.ckpt, f) for s in scens]
           for f in ("C1", "R1", "D1", "C2", "R2", "D2", "mu", "omega", "q")},
        omega1=[s.ckpt.w1 for s in scens],
        omega2=[s.ckpt.w2 for s in scens],
        **{f: [getattr(s.power, f) for s in scens]
           for f in ("P_static", "P_cal", "P_io1", "P_io2", "P_down")})


def buddy_ratio_grid(ratios: Sequence[float], qs: Sequence[float],
                     mu_min: float = 300.0, **kwargs) -> MultilevelParamGrid:
    """Figure 4 grid: Exascale buddy-cost ratio x buddy-loss probability."""
    rows = []
    for r in ratios:
        rows.append(multilevel_grid_from_scenarios(
            get_scenario("multilevel_exascale", mu_min=mu_min,
                         buddy_ratio=float(r), q=float(q), **kwargs)
            for q in qs))
    return MultilevelParamGrid(
        **{f: np.stack([getattr(g, f) for g in rows]) for f in _ML_FIELDS})


def multilevel_arch_grid(archs: Sequence[str] | None = None,
                         **kwargs) -> MultilevelParamGrid:
    """All (or the named) production architectures, two-level, 1-D."""
    if archs is None:
        from ..configs import ALL_ARCHS
        archs = [c.name for c in ALL_ARCHS]
    return multilevel_grid_from_scenarios(
        get_scenario("multilevel_arch", arch=a, **kwargs) for a in archs)
