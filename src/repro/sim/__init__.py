"""Vectorized simulation + sweep subsystem.

Layers:
  scenarios — declarative catalog of platform/power scenarios and the
              struct-of-arrays :class:`ParamGrid` the batched layers consume.
  engine    — the Monte-Carlo trajectory loop as a fixed-shape ``jax.lax.scan``
              phase machine, vmapped over trials and parameter batches.
  sweep     — batched closed-form model + period solvers (AlgoT/AlgoE/Young/
              Daly/MSK) evaluated for a whole grid in a few jitted calls.
  dispatch  — the sharded, memory-bounded execution layer every grid entry
              point routes through: multi-device grid sharding (1-D sweep
              mesh), streaming chunker bounded by a device-memory budget,
              and bounded compiled-runner caches.  All knobs are pure
              performance knobs — a fixed seed's results never change.
  precision — per-backend :class:`PrecisionPolicy` (f64 oracle on CPU,
              compensated f32 on accelerators) with documented parity
              tolerances; resolved per call via ``resolve_precision``.
  cache     — persistent XLA compilation-cache wiring (cold-start compile
              paid once per machine, not once per process); auto-enabled
              when ``$REPRO_COMPILE_CACHE`` is set.

The scalar ``repro.core.simulator.simulate_once`` remains the reference
oracle; ``tests/test_sim_engine.py`` pins the batched engine to it
trajectory-for-trajectory, and ``tests/test_dispatch.py`` pins the
sharded/chunked execution paths to the single-device single-chunk results
bit-for-bit.
"""
from .cache import (enable_compile_cache, maybe_enable_from_env,
                    active_cache_dir)
from .dispatch import (DispatchConfig, default_config, sweep_mesh,
                       cache_stats, reset_cache_stats,
                       BackendInfo, backend_info, resolve_precision)
from .precision import PrecisionPolicy, F64, COMPENSATED_F32
from .scenarios import (ParamGrid, Scenario, MultilevelParamGrid,
                        MultilevelScenario, get_scenario, list_scenarios,
                        register_scenario, mu_rho_grid, nodes_grid,
                        product_grid, arch_grid, grid_from_scenarios,
                        multilevel_grid_from_scenarios, buddy_ratio_grid,
                        multilevel_arch_grid, robustness_grid)
from .engine import (TrajectoryBatch, MultilevelTrajectoryBatch,
                     ScheduledRNG, simulate_trajectories,
                     simulate_candidates, simulate_grid,
                     simulate_trajectories_ml, simulate_grid_ml,
                     presample_gaps, presample_gaps_device,
                     presample_failures, fail_capacity_points,
                     step_budget_points)
from .sweep import (GridResult, MultilevelGridResult, RobustnessResult,
                    evaluate_grid, evaluate_multilevel_grid,
                    evaluate_robustness_grid, evaluate_periods_grid,
                    sweep_weibull_shapes,
                    golden_section_batched,
                    t_opt_time_batched, t_opt_energy_batched,
                    t_young_batched, t_daly_batched, t_msk_energy_batched,
                    time_final_batched, energy_final_batched,
                    ml_time_final_batched, ml_energy_final_batched,
                    sweep_rho_grid, sweep_mu_rho_grid, sweep_nodes_grid)

# Persistent compile cache: opt-in via $REPRO_COMPILE_CACHE (no-op
# otherwise; see sim/cache.py).
maybe_enable_from_env()
