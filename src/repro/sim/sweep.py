"""Batched closed-form model + period solvers over a :class:`ParamGrid`.

Vectorized (leading-batch-axes) counterparts of ``core.model`` and
``core.optimal``: the §3.1/§3.2 expectations, the golden-section minimizer,
the AlgoT closed form, the AlgoE quadratic root (corrected coefficients from
``optimal.derived_coefficients``, vectorized), and the Young/Daly/MSK
baselines — all evaluated for a whole grid in a few jitted float64 calls.

Root-selection semantics match the fixed scalar solver: E' = Q/K with K > 0
on the valid interval, so the energy *minimum* is the root of the quadratic
Q where Q' > 0; any point where that root is missing, complex, or outside
the bracket — or where its energy is beaten by the batched golden-section
argmin — falls back to the numeric result elementwise.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

try:  # newer jax re-exports the x64 context at top level
    from jax import enable_x64
except ImportError:
    from jax.experimental import enable_x64

from ..core.params import PowerParams
from . import dispatch as _dispatch
from . import precision as _precision
from . import scenarios
from .scenarios import MultilevelParamGrid, ParamGrid

_GOLDEN = (math.sqrt(5.0) - 1.0) / 2.0

#: conservative device-memory estimate per grid point of the closed-form
#: model sweep (the stacked golden-section state plus its elementwise
#: temporaries measure ~0.5 KiB/point; 8x headroom keeps the chunker's
#: budget promise honest).  Feeds dispatch.chunk_plan.
_MODEL_BYTES_PER_POINT = 4096

#: per-(grid point, candidate cadence) estimate for the multilevel sweep
#: (same stacked loops, per m plus the by_m output block).
_ML_BYTES_PER_POINT_M = 2048

#: every model-sweep dispatch shape is padded to a multiple of this lane
#: count.  XLA:CPU contracts the dense elementwise math differently at
#: small/ragged batch extents (unrolling + scalar remainder lanes flip
#: ~1-ulp roundings), so a fixed quantum is what makes chunk size, shard
#: count, and memory budget bit-exact no-ops for the model paths.
_MODEL_PAD_QUANTUM = 64

# p: dict of broadcastable jnp float64 arrays with the ParamGrid field names.


def _ab(p):
    a = (1.0 - p["omega"]) * p["C"]
    b = 1.0 - (p["D"] + p["R"] + p["omega"] * p["C"]) / p["mu"]
    return a, b


def time_final_batched(T, p, T_base=1.0):
    """§3.1: T_final = T_base * T / ((T-a)(b - T/2mu)), elementwise."""
    a, b = _ab(p)
    return T_base * T / ((T - a) * (b - T / (2.0 * p["mu"])))


def _re_exec(T, p):
    C, omega = p["C"], p["omega"]
    return (omega * C + (T**2 - C**2) / (2.0 * T)
            + omega * C**2 / (2.0 * T))


def _io_per_failure(T, p):
    return p["R"] + p["C"]**2 / (2.0 * T)


def energy_final_batched(T, p, T_base=1.0):
    """§3.2: E_final = T_cal P_cal + T_io P_io + T_down P_down + Tf P_static."""
    C, omega = p["C"], p["omega"]
    Tf = time_final_batched(T, p, T_base)
    nf = Tf / p["mu"]
    T_cal = T_base + nf * _re_exec(T, p)
    T_io = T_base * C / (T - (1.0 - omega) * C) + nf * _io_per_failure(T, p)
    T_down = nf * p["D"]
    # Policy-aware sum: the plain left-associated chain under the f64
    # oracle (bit-identical to inlining the +s), Neumaier-compensated
    # under a reduced-precision policy (sim/precision.py).
    return _precision.psum((T_cal * p["P_cal"], T_io * p["P_io"],
                            T_down * p["P_down"], Tf * p["P_static"]))


def _bracket(p):
    """Shrunk (lo, hi) per grid point, mirroring ``optimal._bracket``.

    Degenerate points (hi0 <= lo0) get a harmless placeholder bracket; the
    caller masks them out via ``valid``.
    """
    a, b = _ab(p)
    lo0 = jnp.maximum(a, p["C"])
    hi0 = 2.0 * p["mu"] * b
    valid = hi0 > lo0 * (1.0 + 1e-9)
    hi0 = jnp.where(valid, hi0, 2.0 * lo0 + 1.0)
    span = hi0 - lo0
    return lo0 + 1e-9 * span + 1e-12, hi0 - 1e-9 * span, valid


def golden_section_batched(f: Callable, lo, hi, iters: int = 40):
    """Elementwise golden-section argmin of ``f`` on [lo, hi].

    Branchless (``jnp.where``) form of ``optimal.golden_section`` carrying
    the two interior function values, so each iteration costs ONE batched
    evaluation of ``f`` — the loop is sequential, so per-step cost is what
    dominates on small grids.
    """
    a, b = lo, hi
    c = b - _GOLDEN * (b - a)
    d = a + _GOLDEN * (b - a)
    fc, fd = f(c), f(d)

    def body(_, st):
        a, b, c, d, fc, fd = st
        left = fc < fd
        a2 = jnp.where(left, a, c)
        b2 = jnp.where(left, d, b)
        new = jnp.where(left, b2 - _GOLDEN * (b2 - a2),
                        a2 + _GOLDEN * (b2 - a2))
        fnew = f(new)
        c2 = jnp.where(left, new, d)
        fc2 = jnp.where(left, fnew, fd)
        d2 = jnp.where(left, c, new)
        fd2 = jnp.where(left, fc, fnew)
        return (a2, b2, c2, d2, fc2, fd2)

    a, b, _, _, _, _ = lax.fori_loop(0, iters, body, (a, b, c, d, fc, fd))
    return 0.5 * (a + b)


# ---------------------------------------------------------------------------
# Period solvers
# ---------------------------------------------------------------------------

def _t_opt_time_from(p, t_num):
    """AlgoT closed form, falling back to the supplied numeric argmin."""
    a, b = _ab(p)
    lo, hi, _ = _bracket(p)
    val = 2.0 * a * b * p["mu"]
    t_closed = jnp.clip(jnp.sqrt(jnp.maximum(val, 0.0)), lo, hi)
    return jnp.where(val > 0.0, t_closed, t_num)


def t_opt_time_batched(p, T_base=1.0):
    """AlgoT, Eq. (1) closed form; numeric fallback where it degenerates.

    Degenerate grid points (no valid period: the scalar solver raises)
    return NaN — the elementwise analogue of that error.
    """
    lo, hi, valid = _bracket(p)
    t_num = golden_section_batched(
        lambda t: time_final_batched(t, p, T_base), lo, hi)
    return jnp.where(valid, _t_opt_time_from(p, t_num), jnp.nan)


def _energy_quadratic(p):
    """Vectorized corrected coefficients (``optimal.derived_coefficients``)."""
    a, b = _ab(p)
    C, mu, omega = p["C"], p["mu"], p["omega"]
    al = p["P_cal"] / p["P_static"]
    be = p["P_io"] / p["P_static"]
    ga = p["P_down"] / p["P_static"]
    P = al * omega * C + be * p["R"] + ga * p["D"]
    Q = (be - al * (1.0 - omega)) * C**2
    c2 = (1.0 / (2.0 * mu) + P / (2.0 * mu**2) + al * b / (2.0 * mu)
          + (al * a - be * C) / (4.0 * mu**2))
    c1 = (be * C - al * a) * b / mu + Q / (2.0 * mu**2)
    c0 = (-a * b * (P + mu) / mu - be * C * b**2
          - Q * (b / (2.0 * mu) + a / (4.0 * mu**2)))
    return c2, c1, c0


def _t_opt_energy_from(p, T_base, t_num):
    """AlgoE quadratic root, guarded by the supplied numeric argmin."""
    lo, hi, _ = _bracket(p)
    c2, c1, c0 = _energy_quadratic(p)

    disc = c1**2 - 4.0 * c2 * c0
    sq = jnp.sqrt(jnp.maximum(disc, 0.0))
    safe_c2 = jnp.where(jnp.abs(c2) > 1e-300, c2, 1.0)
    r1 = (-c1 - sq) / (2.0 * safe_c2)
    r2 = (-c1 + sq) / (2.0 * safe_c2)
    safe_c1 = jnp.where(jnp.abs(c1) > 1e-300, c1, 1.0)
    rlin = -c0 / safe_c1

    def is_min_root(r):
        # E'' sign at a root of E' equals the sign of Q' (K > 0 in-bracket).
        return ((disc >= 0.0) & (jnp.abs(c2) > 1e-300)
                & (r > lo) & (r < hi) & (2.0 * c2 * r + c1 > 0.0))

    lin_ok = (jnp.abs(c2) <= 1e-300) & (jnp.abs(c1) > 1e-300) \
        & (rlin > lo) & (rlin < hi) & (c1 > 0.0)

    t_root = jnp.where(is_min_root(r1), r1,
                       jnp.where(is_min_root(r2), r2,
                                 jnp.where(lin_ok, rlin, t_num)))
    # Safeguard: never return a root whose energy loses to the numeric argmin.
    e_root = energy_final_batched(t_root, p, T_base)
    e_num = energy_final_batched(t_num, p, T_base)
    return jnp.where(e_root <= e_num * (1.0 + 1e-9), t_root, t_num)


def t_opt_energy_batched(p, T_base=1.0):
    """AlgoE: minimum-branch quadratic root, numeric fallback elementwise.

    Degenerate grid points (no valid period) return NaN.
    """
    lo, hi, valid = _bracket(p)
    t_num = golden_section_batched(
        lambda t: energy_final_batched(t, p, T_base), lo, hi)
    return jnp.where(valid, _t_opt_energy_from(p, T_base, t_num), jnp.nan)


def t_young_batched(p):
    return jnp.sqrt(2.0 * p["C"] * p["mu"]) + p["C"]


def t_daly_batched(p):
    return jnp.sqrt(2.0 * p["C"] * (p["mu"] + p["D"] + p["R"])) + p["C"]


def _msk_energy(T, p0, T_base=1.0):
    """MSK objective on the omega=0 parameter set (paper §3.2 side note)."""
    C, R = p0["C"], p0["R"]
    Tf = time_final_batched(T, p0, T_base)
    nf = Tf / p0["mu"]
    T_cal = T_base + nf * (T - 2.0 * C) / 2.0
    T_io = T_base * C / (T - C) + nf * (R + C)
    T_down = nf * p0["D"]
    return _precision.psum((T_cal * p0["P_cal"], T_io * p0["P_io"],
                            T_down * p0["P_down"], Tf * p0["P_static"]))


def _msk_setup(p):
    """(omega=0 params, lo, hi, valid) for the MSK numeric argmin."""
    p0 = dict(p)
    p0["omega"] = jnp.zeros_like(p["omega"])
    lo, hi, valid = _bracket(p0)
    return p0, jnp.maximum(lo, 2.0 * p0["C"] + 1e-12), hi, valid


def t_msk_energy_batched(p, T_base=1.0):
    """MSK energy-optimal period; degenerate points return NaN."""
    p0, lo, hi, valid = _msk_setup(p)
    t = golden_section_batched(lambda t: _msk_energy(t, p0, T_base), lo, hi)
    return jnp.where(valid, t, jnp.nan)


# ---------------------------------------------------------------------------
# Grid evaluation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GridResult:
    """Periods/ratios for a whole grid; arrays of ``grid.shape``.

    Degenerate points (``~valid``: C of the order of the MTBF, no usable
    period) carry T_time = T_energy = C and ratios of exactly 1.0, matching
    the scalar ``tradeoff.evaluate`` convention; their Tf_*/E_* are NaN.
    """

    grid: ParamGrid
    T_base: float
    T_time: np.ndarray           # AlgoT period
    T_energy: np.ndarray         # AlgoE period
    T_young: np.ndarray
    T_daly: np.ndarray
    T_msk: np.ndarray
    Tf_time: np.ndarray          # T_final at the AlgoT period
    Tf_energy: np.ndarray        # T_final at the AlgoE period
    E_time: np.ndarray           # E_final at the AlgoT period
    E_energy: np.ndarray         # E_final at the AlgoE period
    time_ratio: np.ndarray       # Tf_energy / Tf_time  (>= 1, "loss")
    energy_ratio: np.ndarray     # E_time / E_energy    (>= 1, "gain")
    valid: np.ndarray

    @property
    def energy_saving(self) -> np.ndarray:
        return 1.0 - 1.0 / self.energy_ratio

    @property
    def time_overhead(self) -> np.ndarray:
        return self.time_ratio - 1.0


_FIELD_ORDER = ("C", "R", "D", "mu", "omega",
                "P_static", "P_cal", "P_io", "P_down")
_OUT_ORDER = ("T_time", "T_energy", "T_young", "T_daly", "T_msk",
              "Tf_time", "Tf_energy", "E_time", "E_energy",
              "time_ratio", "energy_ratio", "valid")


def _evaluate_core(P, T_base):
    # P is one stacked (9, N) array — a single host->device transfer and a
    # single dispatch beat nine tiny ones on small grids.  Jitted (and
    # sharded/chunked) by the dispatch layer, not here.
    p = dict(zip(_FIELD_ORDER, P))
    lo, hi, valid = _bracket(p)
    p0, lo_m, hi_m, _ = _msk_setup(p)

    # The three numeric argmins (AlgoT fallback, AlgoE guard, MSK) share ONE
    # golden-section loop over a stacked leading axis: the loop is sequential
    # and dispatch-bound on small grids, so fusing it is a ~3x win there.
    sel = jnp.arange(3, dtype=jnp.int32).reshape((3,) + (1,) * lo.ndim)

    def objective(t):
        return jnp.where(sel == 0, time_final_batched(t, p, T_base),
                         jnp.where(sel == 1,
                                   energy_final_batched(t, p, T_base),
                                   _msk_energy(t, p0, T_base)))

    t_num = golden_section_batched(objective,
                                   jnp.stack([lo, lo, lo_m]),
                                   jnp.stack([hi, hi, hi_m]))
    Tt = _t_opt_time_from(p, t_num[0])
    Te = _t_opt_energy_from(p, T_base, t_num[1])
    Ty = t_young_batched(p)
    Td = t_daly_batched(p)
    Tm = t_num[2]
    Tf_t = time_final_batched(Tt, p, T_base)
    Tf_e = time_final_batched(Te, p, T_base)
    E_t = energy_final_batched(Tt, p, T_base)
    E_e = energy_final_batched(Te, p, T_base)
    nan = jnp.full_like(Tt, jnp.nan)
    C = p["C"]
    one = jnp.ones_like(Tt)
    return jnp.stack([jnp.where(valid, Tt, C),
                      jnp.where(valid, Te, C),
                      Ty, Td,
                      jnp.where(valid, Tm, C),
                      jnp.where(valid, Tf_t, nan),
                      jnp.where(valid, Tf_e, nan),
                      jnp.where(valid, E_t, nan),
                      jnp.where(valid, E_e, nan),
                      jnp.where(valid, Tf_e / Tf_t, one),
                      jnp.where(valid, E_t / E_e, one),
                      valid.astype(C.dtype)])


def _policy_build(core, policy):
    """Policy-routed variant of a stacked model core: inputs cast to the
    policy's compute dtype, the trace runs under the policy context (so
    the energy-term sums go through ``precision.psum`` compensated), and
    outputs are cast back to f64 for the host-side layers.  Only built
    for non-exact policies — the f64 oracle keeps the original build and
    its bit-identical compiled program."""
    def build(*args):
        with _precision.trace_policy(policy):
            out = core(*(policy.cast(a) for a in args))
        return jax.tree.map(lambda a: jnp.asarray(a, jnp.float64), out)
    return build


def _policy_key(key: tuple, policy) -> tuple:
    """Runner-cache key for a policy-routed build: the f64 oracle keeps
    its historical key; other policies never share a compiled program."""
    return key if policy is None or policy.exact else key + (policy.name,)


def evaluate_grid(grid: ParamGrid, T_base: float = 1.0,
                  dispatch=None, precision=None) -> GridResult:
    """Periods + time/energy ratios for every grid point.

    Routed through :mod:`repro.sim.dispatch`: the grid axis is sharded
    across the local devices and chunked to the configured device-memory
    budget (``dispatch`` is a :class:`~repro.sim.dispatch.DispatchConfig`;
    None = environment defaults), so a 10^6-point dense grid streams in
    bounded memory.  The computation is elementwise per grid point —
    chunk size and shard count are bit-exact no-ops on the results.

    ``precision`` selects the :class:`~repro.sim.precision
    .PrecisionPolicy` (a policy, a name, or None = config/env/backend
    default — f64 on CPU): the f64 oracle path is untouched; a reduced-
    precision policy computes in its dtype with compensated energy sums
    and lands within the policy's documented tolerance of the oracle
    (tests/test_pallas_engine.py parity gates).
    """
    pol = _dispatch.resolve_precision(dispatch, precision)
    flat = grid.ravel()
    P = np.stack([getattr(flat, f) for f in _FIELD_ORDER])
    raw = _dispatch.run(
        key=_policy_key(("evaluate_core",), pol),
        build=(_evaluate_core if pol.exact
               else _policy_build(_evaluate_core, pol)),
        args=(P, np.float64(T_base)), in_axes=(1, None), out_axes=1,
        size=flat.size, per_point_bytes=_MODEL_BYTES_PER_POINT,
        config=dispatch, quantum=_MODEL_PAD_QUANTUM)
    out = {k: raw[i].reshape(grid.shape) for i, k in enumerate(_OUT_ORDER)}
    out["valid"] = out["valid"] > 0.5
    return GridResult(grid=grid, T_base=float(T_base), **out)


# ---------------------------------------------------------------------------
# Multilevel (buddy + PFS) batched model + joint (T, m) solvers
# ---------------------------------------------------------------------------
#
# p: dict of broadcastable jnp float64 arrays with MultilevelParamGrid field
# names; m: a float array broadcasting against them (the solvers put the
# candidate cadences on a leading axis and argmin over it).

def _where(cond, a, b):
    """Namespace-dispatching ``where``: jnp only when an operand is a jax
    value (traced or device), numpy otherwise.  The ``ml_*_batched``
    entry points are also called EAGERLY on host scalars (the serve
    layer's certificate sweeps); an unconditional ``jnp.where`` there
    would pull the whole expression onto the jax eager path and compile
    one tiny program per arithmetic op (caught by the sanitizer tier's
    recompile budget)."""
    import jax
    if any(isinstance(x, jax.Array) for x in (cond, a, b)):
        return jnp.where(cond, a, b)
    return np.where(cond, a, b)


def _ml_omega_terms(p, m):
    """(w1, w2, Cw, S2, S2w): per-level overlap aggregates.

    Where the two overlap factors coincide the shared-omega expressions
    are evaluated verbatim (bit-for-bit with both the pre-async batched
    forms and the scalar ``MultilevelCheckpointParams`` branches).
    ``omega1``/``omega2`` fall back to the shared ``omega`` when a plain
    param dict omits them (the public ``ml_*_batched`` entry points
    accept both spellings).
    """
    C1, C2 = p["C1"], p["C2"]
    w1 = p.get("omega1", p["omega"])
    w2 = p.get("omega2", p["omega"])
    shared = w1 == w2
    Cb = ((m - 1.0) * C1 + C2) / m
    S2 = ((m - 1.0) * C1**2 + C2**2) / m
    Cw = _where(shared, w1 * Cb,
                ((m - 1.0) * w1 * C1 + w2 * C2) / m)
    S2w = _where(shared, w1 * S2,
                 ((m - 1.0) * w1 * C1**2 + w2 * C2**2) / m)
    return w1, w2, Cw, S2, S2w


def _ml_derived(p, m):
    """(C_mean, a_m, b_m, mu_m) of the multilevel §3.1 analogue."""
    Cb = ((m - 1.0) * p["C1"] + p["C2"]) / m
    w1, w2, Cw, _, _ = _ml_omega_terms(p, m)
    a = _where(w1 == w2, (1.0 - w1) * Cb,
               ((m - 1.0) * (1.0 - w1) * p["C1"]
                + (1.0 - w2) * p["C2"]) / m)
    soft = p["D1"] + p["R1"] + Cw
    hard = p["D2"] + p["R2"] + w2 * p["C2"]
    b = 1.0 - (soft + p["q"] * (hard - soft)) / p["mu"]
    mu_m = p["mu"] / (1.0 + p["q"] * (m - 1.0))
    return Cb, a, b, mu_m


def ml_time_final_batched(T, m, p, T_base=1.0):
    """Two-level expected makespan, elementwise (period T, deep every m)."""
    _, a, b, mu_m = _ml_derived(p, m)
    return T_base * T / ((T - a) * (b - T / (2.0 * mu_m)))


def ml_energy_final_batched(T, m, p, T_base=1.0):
    """Two-level E_final with per-level I/O powers, elementwise."""
    C1, R1, D1 = p["C1"], p["R1"], p["D1"]
    C2, R2, D2 = p["C2"], p["R2"], p["D2"]
    q = p["q"]
    Cb, a, b, mu_m = _ml_derived(p, m)
    w1, w2, Cw, S2, S2w = _ml_omega_terms(p, m)

    Tf = T_base * T / ((T - a) * (b - T / (2.0 * mu_m)))
    nf = Tf / p["mu"]
    Ew = (T**2 - S2) / (2.0 * T) + S2w / (2.0 * T)
    w_soft = Cw + Ew
    w_hard = w2 * C2 + (m - 1.0) * (T - (1.0 - w1) * C1) / 2.0 + Ew
    T_cal = T_base + nf * (w_soft + q * (w_hard - w_soft))

    ck_io1 = T_base * ((m - 1.0) * C1 / m) / (T - a)
    ck_io2 = T_base * (C2 / m) / (T - a)
    io1_pf = ((m - 1.0) / m) * C1**2 / (2.0 * T) + (1.0 - q) * R1 \
        + q * (m - 1.0) * C1 / 2.0
    io2_pf = C2**2 / (2.0 * m * T) + q * R2
    T_down = nf * (D1 + q * (D2 - D1))
    return _precision.psum((T_cal * p["P_cal"],
                            (ck_io1 + nf * io1_pf) * p["P_io1"],
                            (ck_io2 + nf * io2_pf) * p["P_io2"],
                            T_down * p["P_down"], Tf * p["P_static"]))


def _ml_bracket(p, m):
    """Shrunk (lo, hi, valid) per (m, grid point)."""
    _, a, b, mu_m = _ml_derived(p, m)
    lo0 = jnp.maximum(jnp.maximum(a, p["C1"]), p["C2"])
    hi0 = 2.0 * mu_m * b
    valid = hi0 > lo0 * (1.0 + 1e-9)
    hi0 = jnp.where(valid, hi0, 2.0 * lo0 + 1.0)
    span = hi0 - lo0
    return lo0 + 1e-9 * span + 1e-12, hi0 - 1e-9 * span, valid


def _ml_energy_prime_batched(T, m, p, T_base=1.0):
    """Analytic two-level dE/dT (W normal form, mirrors core.model)."""
    C1, C2 = p["C1"], p["C2"]
    q = p["q"]
    Pc, P1, P2, Pd = p["P_cal"], p["P_io1"], p["P_io2"], p["P_down"]
    Cb, a, b, mu_m = _ml_derived(p, m)
    w1, w2, Cw, S2, S2w = _ml_omega_terms(p, m)

    W0 = (Pc * (Cw + q * (w2 * C2 - Cw
                          - (m - 1.0) * (1.0 - w1) * C1 / 2.0))
          + P1 * ((1.0 - q) * p["R1"] + q * (m - 1.0) * C1 / 2.0)
          + P2 * q * p["R2"]
          + Pd * (p["D1"] + q * (p["D2"] - p["D1"])))
    W1 = Pc * (1.0 + q * (m - 1.0)) / 2.0
    Wm = (Pc * (S2w - S2) / 2.0
          + P1 * (m - 1.0) * C1**2 / (2.0 * m)
          + P2 * C2**2 / (2.0 * m))
    J = P1 * (m - 1.0) * C1 / m + P2 * C2 / m

    Tf = T_base * T / ((T - a) * (b - T / (2.0 * mu_m)))
    Tfp = T_base * (-a * b + T**2 / (2.0 * mu_m)) \
        / ((T - a) ** 2 * (b - T / (2.0 * mu_m)) ** 2)
    W = W0 + W1 * T + Wm / T
    Wp = W1 - Wm / T**2
    return (p["P_static"] * Tfp + Tfp / p["mu"] * W + Tf / p["mu"] * Wp
            - J * T_base / (T - a) ** 2)


def _ml_quadratic(p, m, lo, hi, T_base):
    """(c2, c1, c0, quad_ok) of Q_m = K_m * E' by 3-point Newton
    interpolation of the analytic product + vectorized 4th-point check."""
    _, a, b, mu_m = _ml_derived(p, m)

    def Q(t):
        K = (t - a) ** 2 * (b - t / (2.0 * mu_m)) ** 2 \
            / (p["P_static"] * T_base)
        return K * _ml_energy_prime_batched(t, m, p, T_base)

    span = hi - lo
    t1, t2, t3 = lo + 0.2 * span, lo + 0.45 * span, lo + 0.7 * span
    q1, q2, q3 = Q(t1), Q(t2), Q(t3)
    d1 = (q2 - q1) / (t2 - t1)
    d2 = (q3 - q2) / (t3 - t2)
    c2 = (d2 - d1) / (t3 - t1)
    c1 = d1 - c2 * (t1 + t2)
    c0 = q1 - t1 * (d1 - c2 * t2)

    t4 = lo + 0.9 * span
    q4 = Q(t4)
    q4_poly = c2 * t4**2 + c1 * t4 + c0
    scale = jnp.maximum(jnp.maximum(jnp.abs(q4), jnp.abs(q4_poly)),
                        jnp.maximum(jnp.abs(c0), 1e-300))
    quad_ok = jnp.abs(q4 - q4_poly) <= 1e-6 * scale
    return c2, c1, c0, quad_ok


def _t_opt_time_ml_from(p, m, t_num):
    """Per-m AlgoT closed form, numeric fallback where it degenerates."""
    _, a, b, mu_m = _ml_derived(p, m)
    lo, hi, _ = _ml_bracket(p, m)
    val = 2.0 * a * b * mu_m
    t_closed = jnp.clip(jnp.sqrt(jnp.maximum(val, 0.0)), lo, hi)
    return jnp.where(val > 0.0, t_closed, t_num)


def _t_opt_energy_ml_from(p, m, T_base, t_num):
    """Per-m AlgoE quadratic root with the scalar solver's guard semantics."""
    lo, hi, _ = _ml_bracket(p, m)
    c2, c1, c0, quad_ok = _ml_quadratic(p, m, lo, hi, T_base)

    disc = c1**2 - 4.0 * c2 * c0
    sq = jnp.sqrt(jnp.maximum(disc, 0.0))
    safe_c2 = jnp.where(jnp.abs(c2) > 1e-300, c2, 1.0)
    r1 = (-c1 - sq) / (2.0 * safe_c2)
    r2 = (-c1 + sq) / (2.0 * safe_c2)
    safe_c1 = jnp.where(jnp.abs(c1) > 1e-300, c1, 1.0)
    rlin = -c0 / safe_c1

    def is_min_root(r):
        return (quad_ok & (disc >= 0.0) & (jnp.abs(c2) > 1e-300)
                & (r > lo) & (r < hi) & (2.0 * c2 * r + c1 > 0.0))

    lin_ok = quad_ok & (jnp.abs(c2) <= 1e-300) & (jnp.abs(c1) > 1e-300) \
        & (rlin > lo) & (rlin < hi) & (c1 > 0.0)

    t_root = jnp.where(is_min_root(r1), r1,
                       jnp.where(is_min_root(r2), r2,
                                 jnp.where(lin_ok, rlin, t_num)))
    e_root = ml_energy_final_batched(t_root, m, p, T_base)
    e_num = ml_energy_final_batched(t_num, m, p, T_base)
    return jnp.where(e_root <= e_num * (1.0 + 1e-9), t_root, t_num)


@dataclasses.dataclass(frozen=True)
class MultilevelGridResult:
    """Jointly optimal (T, m) per grid point, plus per-m curves.

    Scalar-per-point arrays have ``grid.shape``; the ``*_by_m`` arrays carry
    a leading axis over ``m_values``.  Degenerate points (no valid period at
    any m) follow the ``GridResult`` convention: periods C2, m 1, ratios
    exactly 1.0, Tf/E NaN.
    """

    grid: MultilevelParamGrid
    m_values: tuple
    T_base: float
    T_time: np.ndarray           # AlgoT period
    m_time: np.ndarray           # AlgoT deep-checkpoint cadence (int)
    T_energy: np.ndarray         # AlgoE period
    m_energy: np.ndarray         # (int)
    Tf_time: np.ndarray
    Tf_energy: np.ndarray
    E_time: np.ndarray
    E_energy: np.ndarray
    time_ratio: np.ndarray       # Tf_energy / Tf_time  (>= 1, "loss")
    energy_ratio: np.ndarray     # E_time / E_energy    (>= 1, "gain")
    time_vs_single: np.ndarray   # Tf(AlgoT, 2-level) / Tf(AlgoT, PFS-only)
    energy_vs_single: np.ndarray  # E(AlgoE, 2-level) / E(AlgoE, PFS-only)
    T_time_by_m: np.ndarray      # (M,) + grid.shape
    Tf_by_m: np.ndarray
    T_energy_by_m: np.ndarray
    E_by_m: np.ndarray
    valid_by_m: np.ndarray
    valid: np.ndarray

    @property
    def energy_saving(self) -> np.ndarray:
        return 1.0 - 1.0 / self.energy_ratio

    @property
    def time_overhead(self) -> np.ndarray:
        return self.time_ratio - 1.0

    def point_at(self, idx):
        """Scalar :class:`core.tradeoff.MultilevelTradeoffPoint` view."""
        from ..core.tradeoff import MultilevelTradeoffPoint
        return MultilevelTradeoffPoint(
            ckpt=self.grid.ckpt_at(idx), power=self.grid.power_at(idx),
            T_time=float(self.T_time[idx]), m_time=int(self.m_time[idx]),
            T_energy=float(self.T_energy[idx]),
            m_energy=int(self.m_energy[idx]),
            time_ratio=float(self.time_ratio[idx]),
            energy_ratio=float(self.energy_ratio[idx]),
            time_vs_single=float(self.time_vs_single[idx]),
            energy_vs_single=float(self.energy_vs_single[idx]))


_ML_FIELD_ORDER = ("C1", "R1", "D1", "C2", "R2", "D2", "mu", "omega", "q",
                   "P_static", "P_cal", "P_io1", "P_io2", "P_down",
                   "omega1", "omega2")
_ML_OUT_ORDER = ("T_time", "m_time", "T_energy", "m_energy",
                 "Tf_time", "Tf_energy", "E_time", "E_energy",
                 "time_ratio", "energy_ratio",
                 "time_vs_single", "energy_vs_single", "valid")


def _evaluate_ml_core(P, T_base, m_values, m_max=None):
    # P: one stacked (16, N) array; m_values: static tuple of cadences
    # (closed over by the dispatch build — one compiled program per
    # distinct tuple, exactly like the old static_argnums jit).
    # m_max: optional traced (N,) per-point cadence cap — candidates with
    # mv > m_max are masked invalid for that point, so heterogeneous
    # cadence budgets (the advisor's admission batches) share ONE
    # compiled program over the union candidate set.
    p = dict(zip(_ML_FIELD_ORDER, P))
    mv = jnp.asarray(m_values, P.dtype).reshape((-1, 1))     # (M, 1)
    lo, hi, valid_m = _ml_bracket(p, mv)                     # (M, N)
    if m_max is not None:
        valid_m = valid_m & (mv <= m_max[None, :])

    # The per-m time and energy numeric argmins share ONE golden-section
    # loop over a stacked leading axis (same dispatch-bound rationale as
    # the single-level _evaluate_core).
    sel = jnp.arange(2, dtype=jnp.int32).reshape((2, 1, 1))

    def objective(t):
        return jnp.where(sel == 0, ml_time_final_batched(t, mv, p, T_base),
                         ml_energy_final_batched(t, mv, p, T_base))

    t_num = golden_section_batched(objective,
                                   jnp.broadcast_to(lo, (2,) + lo.shape),
                                   jnp.broadcast_to(hi, (2,) + hi.shape))
    Tt_m = _t_opt_time_ml_from(p, mv, t_num[0])              # (M, N)
    Te_m = _t_opt_energy_ml_from(p, mv, T_base, t_num[1])
    Tf_m = ml_time_final_batched(Tt_m, mv, p, T_base)
    E_m = ml_energy_final_batched(Te_m, mv, p, T_base)

    inf = jnp.inf
    i_t = jnp.argmin(jnp.where(valid_m, Tf_m, inf), axis=0)  # (N,)
    i_e = jnp.argmin(jnp.where(valid_m, E_m, inf), axis=0)
    take = lambda arr, i: jnp.take_along_axis(arr, i[None, :], axis=0)[0]
    m_arr = jnp.asarray(m_values, P.dtype)
    T_time, m_time = take(Tt_m, i_t), m_arr[i_t]
    T_energy, m_energy = take(Te_m, i_e), m_arr[i_e]
    Tf_time, E_energy = take(Tf_m, i_t), take(E_m, i_e)
    # Cross metrics at the jointly-optimal operating points.
    Tf_energy = ml_time_final_batched(T_energy, m_energy, p, T_base)
    E_time = ml_energy_final_batched(T_time, m_time, p, T_base)

    # PFS-only single-level comparator on the same grid (C2/R2/D2/P_io2,
    # at the deep level's overlap factor — mirrors grid.single_level()).
    p_sl = {"C": p["C2"], "R": p["R2"], "D": p["D2"], "mu": p["mu"],
            "omega": p["omega2"], "P_static": p["P_static"],
            "P_cal": p["P_cal"], "P_io": p["P_io2"], "P_down": p["P_down"]}
    lo_s, hi_s, valid_s = _bracket(p_sl)
    sel_s = jnp.arange(2, dtype=jnp.int32).reshape((2, 1))

    def objective_s(t):
        return jnp.where(sel_s == 0, time_final_batched(t, p_sl, T_base),
                         energy_final_batched(t, p_sl, T_base))

    t_num_s = golden_section_batched(objective_s,
                                     jnp.stack([lo_s, lo_s]),
                                     jnp.stack([hi_s, hi_s]))
    Tt_s = _t_opt_time_from(p_sl, t_num_s[0])
    Te_s = _t_opt_energy_from(p_sl, T_base, t_num_s[1])
    Tf_s = time_final_batched(Tt_s, p_sl, T_base)
    E_s = energy_final_batched(Te_s, p_sl, T_base)

    valid = jnp.any(valid_m, axis=0)
    nan = jnp.full_like(T_time, jnp.nan)
    one = jnp.ones_like(T_time)
    C2 = p["C2"]
    scalars = jnp.stack([
        jnp.where(valid, T_time, C2),
        jnp.where(valid, m_time, 1.0),
        jnp.where(valid, T_energy, C2),
        jnp.where(valid, m_energy, 1.0),
        jnp.where(valid, Tf_time, nan),
        jnp.where(valid, Tf_energy, nan),
        jnp.where(valid, E_time, nan),
        jnp.where(valid, E_energy, nan),
        jnp.where(valid, Tf_energy / Tf_time, one),
        jnp.where(valid, E_time / E_energy, one),
        # vs-single ratios are meaningless when the PFS-only comparator has
        # no valid period at all (exactly the regime where the buddy level
        # rescues an otherwise infeasible platform): report NaN there.
        jnp.where(valid, jnp.where(valid_s, Tf_time / Tf_s, nan), one),
        jnp.where(valid, jnp.where(valid_s, E_energy / E_s, nan), one),
        valid.astype(C2.dtype)])
    by_m = jnp.stack([Tt_m, jnp.where(valid_m, Tf_m, jnp.nan),
                      Te_m, jnp.where(valid_m, E_m, jnp.nan),
                      valid_m.astype(C2.dtype)])
    return scalars, by_m


def evaluate_multilevel_grid(grid: MultilevelParamGrid,
                             m_values: Sequence[int] = tuple(range(1, 13)),
                             T_base: float = 1.0,
                             dispatch=None, m_max=None,
                             precision=None) -> MultilevelGridResult:
    """Jointly optimal (T, m) + ratios for every grid point.

    ``m_values`` is the candidate set of deep-checkpoint cadences (static:
    one compiled program per distinct tuple).  The grid axis routes
    through :mod:`repro.sim.dispatch` (sharding + memory-bounded
    chunking; ``dispatch`` is its config, None = environment defaults).

    ``m_max`` (optional) caps the cadence PER GRID POINT: an integer array
    broadcastable to ``grid.shape``; candidates ``m > m_max[point]`` are
    masked invalid for that point only.  This is the heterogeneous-request
    assembly hook: requests with different cadence budgets batch into one
    call over the union candidate set instead of one compiled program per
    distinct budget.  ``m_max=None`` keeps the unmasked program and its
    results bit-for-bit.

    ``precision`` routes the sweep through a
    :class:`~repro.sim.precision.PrecisionPolicy` exactly like
    :func:`evaluate_grid` (f64 oracle untouched; reduced-precision
    within documented tolerance).
    """
    pol = _dispatch.resolve_precision(dispatch, precision)
    m_values = tuple(int(m) for m in m_values)
    if not m_values or min(m_values) < 1:
        raise ValueError(f"m_values must be positive ints, got {m_values}")
    flat = grid.ravel()
    P = np.stack([getattr(flat, f) for f in _ML_FIELD_ORDER])
    if m_max is None:
        core = lambda P_, tb: _evaluate_ml_core(P_, tb, m_values)
        scalars, by_m = _dispatch.run(
            key=_policy_key(("evaluate_ml_core", m_values), pol),
            build=core if pol.exact else _policy_build(core, pol),
            args=(P, np.float64(T_base)), in_axes=(1, None), out_axes=(1, 2),
            size=flat.size,
            per_point_bytes=_ML_BYTES_PER_POINT_M * len(m_values),
            config=dispatch, quantum=_MODEL_PAD_QUANTUM)
    else:
        mm = np.broadcast_to(np.asarray(m_max, dtype=np.float64),
                             grid.shape).ravel()
        core = lambda P_, tb, mm_: _evaluate_ml_core(P_, tb, m_values, mm_)
        scalars, by_m = _dispatch.run(
            key=_policy_key(("evaluate_ml_core_masked", m_values), pol),
            build=core if pol.exact else _policy_build(core, pol),
            args=(P, np.float64(T_base), mm), in_axes=(1, None, 0),
            out_axes=(1, 2), size=flat.size,
            per_point_bytes=_ML_BYTES_PER_POINT_M * len(m_values),
            config=dispatch, quantum=_MODEL_PAD_QUANTUM)
    out = {k: scalars[i].reshape(grid.shape)
           for i, k in enumerate(_ML_OUT_ORDER)}
    out["valid"] = out["valid"] > 0.5
    out["m_time"] = np.where(out["valid"], out["m_time"], 1).astype(np.int64)
    out["m_energy"] = np.where(out["valid"], out["m_energy"],
                               1).astype(np.int64)
    M = len(m_values)
    shp = (M,) + grid.shape
    return MultilevelGridResult(
        grid=grid, m_values=m_values, T_base=float(T_base),
        T_time_by_m=by_m[0].reshape(shp), Tf_by_m=by_m[1].reshape(shp),
        T_energy_by_m=by_m[2].reshape(shp), E_by_m=by_m[3].reshape(shp),
        valid_by_m=by_m[4].reshape(shp) > 0.5, **out)


# ---------------------------------------------------------------------------
# Robustness: exponential-assumption periods under realistic failures
# ---------------------------------------------------------------------------
#
# No closed form exists for non-exponential processes, so the grid solver is
# Monte-Carlo: one pre-sampled schedule set per grid point (common random
# numbers) is parked on device once and reused for every candidate period,
# the argmin is localized by batched coarse-to-fine refinement (one
# candidate-vmapped engine call scores ALL candidates for every grid point
# at once; the big gap arrays are shared via in_axes=None, never tiled),
# and every reported period — the process optimum, the
# exponential-closed-form AlgoT/AlgoE, Young, Daly — is evaluated on the
# *same* schedules so the penalties are CRN-paired.

@dataclasses.dataclass(frozen=True)
class RobustnessResult:
    """Per-grid-point periods and CRN penalties; arrays of ``grid.shape``.

    ``*_penalty_*`` are ratios >= ~1: wall time (or energy) at the
    exponential-assumption period divided by its value at the MC
    process-optimal period, under the non-exponential process.
    """

    grid: ParamGrid
    process: object                # FailureProcess
    T_base: np.ndarray             # per-point simulated work (grid.shape)
    n_trials: int
    T_exp_time: np.ndarray         # AlgoT closed form (exponential model)
    T_exp_energy: np.ndarray       # AlgoE quadratic root
    T_young: np.ndarray
    T_daly: np.ndarray
    T_mc_time: np.ndarray          # process-optimal (MC surrogate)
    T_mc_energy: np.ndarray
    eval_periods: np.ndarray       # (6,) + grid.shape: the periods actually
                                   # scored, order [mc_t, mc_e, algoT,
                                   # algoE, young, daly] (clipped into the
                                   # safe range) — feed to
                                   # evaluate_periods_grid for independent-
                                   # seed validation
    wall_mc: np.ndarray            # E[T_final] at T_mc_time
    energy_mc: np.ndarray          # E[E_final] at T_mc_energy
    wall_mc_se: np.ndarray
    energy_mc_se: np.ndarray
    time_penalty_exp: np.ndarray
    energy_penalty_exp: np.ndarray
    time_penalty_young: np.ndarray
    time_penalty_daly: np.ndarray
    energy_penalty_young: np.ndarray
    energy_penalty_daly: np.ndarray
    valid: np.ndarray


def _flat_tbase(T_base, grid: ParamGrid) -> np.ndarray:
    """Per-point T_base as a flat (grid.size,) array, accepting a scalar,
    an already-flat vector, or a grid-shaped array."""
    arr = np.asarray(T_base, dtype=np.float64)
    if arr.shape == grid.shape:
        return arr.ravel().copy()
    return np.broadcast_to(arr, (grid.size,)).copy()


def _mc_eval(T_cand, flat: ParamGrid, T_base, gaps, n_steps=None,
             engine_kind: Optional[str] = None, dispatch=None):
    """Engine means over trials for candidate periods ``T_cand`` of shape
    ``(M, B)`` against the flat grid (B,), in ONE candidate-vmapped engine
    call (the gap schedules — the big arrays — are shared across the
    candidate axis via ``in_axes=None``, never tiled or re-transferred)."""
    from . import engine as _engine
    T_cand = np.atleast_2d(np.asarray(T_cand, dtype=np.float64))
    tb = _engine.simulate_candidates(T_cand, flat, T_base, gaps=gaps,
                                     n_steps=n_steps,
                                     engine_kind=engine_kind,
                                     dispatch=dispatch)
    if tb.truncated.any():
        raise RuntimeError("robustness sweep: scan budget exceeded — "
                           "candidate period too close to a bracket "
                           "edge")
    if tb.gaps_exhausted.any():
        raise RuntimeError("robustness sweep: failure schedule "
                           "exhausted — increase n_trials capacity "
                           "margins")
    n = tb.wall_time.shape[-1]
    se = lambda a: a.std(axis=-1, ddof=1) / math.sqrt(n)
    return (tb.wall_time.mean(axis=-1), tb.energy.mean(axis=-1),
            se(tb.wall_time), se(tb.energy))


def evaluate_robustness_grid(grid: ParamGrid, process,
                             T_base: Optional[float] = None,
                             n_trials: int = 160, seed: int = 0,
                             n_candidates: int = 13, rounds: int = 3,
                             engine_kind: Optional[str] = None,
                             dispatch=None) -> RobustnessResult:
    """MC robustness evaluation of a whole grid under ``process``.

    Each refinement round scores ``n_candidates`` periods in one
    candidate-vmapped engine call (every candidate x grid point at once);
    a final pass scores the six reported periods (MC-time, MC-energy,
    AlgoT, AlgoE, Young, Daly) on the same CRN schedules, which are
    host-sampled once (replayable) and then device-resident for every
    call.  Use :func:`evaluate_periods_grid` with a different ``seed`` to
    re-validate the reported optima on independent randomness (the
    benchmark's 2% gate).
    """
    from ..core.failures import as_process
    from . import engine as _engine
    process = as_process(process)
    engine_kind = _engine.resolve_engine_kind(engine_kind)
    res = evaluate_grid(grid, T_base=1.0, dispatch=dispatch)
    if not res.valid.all():
        raise ValueError("robustness sweep: grid contains degenerate points "
                         "(no valid period); filter them first")
    flat = grid.ravel()
    B = flat.size

    Tt = np.asarray(res.T_time, dtype=np.float64).ravel()
    Te = np.asarray(res.T_energy, dtype=np.float64).ravel()
    Ty = np.asarray(res.T_young, dtype=np.float64).ravel()
    Td = np.asarray(res.T_daly, dtype=np.float64).ravel()

    lo0, hi0 = flat.period_bounds()
    # Search well clear of the bracket edges, where E[T_final] (and with it
    # the scan/schedule budgets) diverges; the optimum sits near the
    # exponential T* for every renewal process with the same mean.
    lo = np.maximum(lo0 * 1.02, Tt / 6.0)
    hi = np.minimum(lo0 + 0.75 * (hi0 - lo0), Tt * 6.0)
    if T_base is None:
        # Per grid point: enough periods and failures to average over.
        T_base = np.maximum(30.0 * Tt, 10.0 * flat.mu)
    T_base = _flat_tbase(T_base, grid)
    probes = lo[None, :] * (hi / lo)[None, :] ** np.linspace(
        0.0, 1.0, 9)[:, None]
    cap = _engine.default_fail_capacity(probes, flat, T_base,
                                       process=process)
    n_steps = (None if engine_kind in _engine._EVENT_LIKE else
               _engine.default_step_budget(probes, flat, T_base,
                                           process=process))
    gaps = _engine.presample_gaps(flat, n_trials, cap, seed=seed,
                                  process=process)
    with enable_x64():
        # device-resident once, reused below
        gaps = jnp.asarray(gaps, dtype=jnp.float64)

    # Coarse-to-fine localization of both argmins (batched over the grid).
    frac = np.linspace(0.0, 1.0, n_candidates)[:, None]
    xs_t = lo[None, :] * (hi / lo)[None, :] ** frac     # geometric first pass
    xs_e = xs_t

    def shrink(xs, ys):
        i = np.argmin(ys, axis=0)
        lo2 = xs[np.maximum(i - 1, 0), np.arange(B)]
        hi2 = xs[np.minimum(i + 1, n_candidates - 1), np.arange(B)]
        return lo2[None, :] + (hi2 - lo2)[None, :] * frac

    def score(xs_time, xs_energy):
        # One engine pass returns BOTH objectives, so identical candidate
        # sets (the shared first round) are simulated only once.
        wall_t, energy_t, _, _ = _mc_eval(xs_time, flat, T_base, gaps,
                                          n_steps, engine_kind, dispatch)
        if xs_energy is xs_time:
            return wall_t, energy_t
        _, energy_e, _, _ = _mc_eval(xs_energy, flat, T_base, gaps, n_steps,
                                     engine_kind, dispatch)
        return wall_t, energy_e

    for _ in range(rounds):
        wall_t, energy_e = score(xs_t, xs_e)
        xs_t = shrink(xs_t, wall_t)
        xs_e = shrink(xs_e, energy_e)
    wall_t, energy_e = score(xs_t, xs_e)
    T_mc_t = xs_t[np.argmin(wall_t, axis=0), np.arange(B)]
    T_mc_e = xs_e[np.argmin(energy_e, axis=0), np.arange(B)]

    # Score all six reported periods on the same schedules (CRN-paired).
    cands = np.clip(np.stack([T_mc_t, T_mc_e, Tt, Te, Ty, Td]),
                    lo[None, :], hi[None, :])
    wall, energy, wall_se, energy_se = _mc_eval(cands, flat, T_base, gaps,
                                                n_steps, engine_kind,
                                                dispatch)
    shp = grid.shape
    r = lambda a: np.asarray(a, dtype=np.float64).reshape(shp)
    return RobustnessResult(
        grid=grid, process=process, T_base=r(T_base),
        n_trials=int(n_trials),
        T_exp_time=r(Tt), T_exp_energy=r(Te), T_young=r(Ty), T_daly=r(Td),
        T_mc_time=r(T_mc_t), T_mc_energy=r(T_mc_e),
        eval_periods=cands.reshape((6,) + shp),
        wall_mc=r(wall[0]), energy_mc=r(energy[1]),
        wall_mc_se=r(wall_se[0]), energy_mc_se=r(energy_se[1]),
        time_penalty_exp=r(wall[2] / wall[0]),
        energy_penalty_exp=r(energy[3] / energy[1]),
        time_penalty_young=r(wall[4] / wall[0]),
        time_penalty_daly=r(wall[5] / wall[0]),
        energy_penalty_young=r(energy[4] / energy[1]),
        energy_penalty_daly=r(energy[5] / energy[1]),
        valid=np.asarray(res.valid).copy())


def evaluate_periods_grid(grid: ParamGrid, process, periods,
                          T_base, n_trials: int = 160, seed: int = 0,
                          engine_kind: Optional[str] = None, dispatch=None):
    """MC means at given candidate periods under ``process`` (CRN-shared
    across candidates, independent across seeds).

    ``periods`` has shape ``(M,) + grid.shape``; returns a dict of
    ``wall`` / ``energy`` (+ ``_se``) arrays of the same shape.  This is the
    independent-validation entry: score ``RobustnessResult.eval_periods``
    with a fresh ``seed`` and compare the derived penalties.
    """
    from ..core.failures import as_process
    from . import engine as _engine
    process = as_process(process)
    engine_kind = _engine.resolve_engine_kind(engine_kind)
    flat = grid.ravel()
    B = flat.size
    P = np.asarray(periods, dtype=np.float64).reshape((-1, B))
    T_base = _flat_tbase(T_base, grid)
    cap = _engine.default_fail_capacity(P, flat, T_base, process=process)
    n_steps = (None if engine_kind in _engine._EVENT_LIKE else
               _engine.default_step_budget(P, flat, T_base,
                                           process=process))
    gaps = _engine.presample_gaps(flat, n_trials, cap, seed=seed,
                                  process=process)
    wall, energy, wall_se, energy_se = _mc_eval(P, flat, T_base, gaps,
                                                n_steps, engine_kind,
                                                dispatch)
    shp = (P.shape[0],) + grid.shape
    return {"wall": wall.reshape(shp), "energy": energy.reshape(shp),
            "wall_se": wall_se.reshape(shp),
            "energy_se": energy_se.reshape(shp)}


def sweep_weibull_shapes(shapes: Sequence[float], mu_minutes: Sequence[float],
                         base: str = "exascale_rho55",
                         **kwargs) -> RobustnessResult:
    """Weibull shape x exascale-platform MTBF robustness sweep (the
    fig5 benchmark's entry point)."""
    grid, process = scenarios.robustness_grid(shapes, mu_minutes, base=base)
    return evaluate_robustness_grid(grid, process, **kwargs)


# ---------------------------------------------------------------------------
# Figure-level conveniences
# ---------------------------------------------------------------------------

def sweep_rho_grid(rhos: Sequence[float], mu_minutes: float,
                   alpha: float = 1.0) -> GridResult:
    """Figure 1: rho swept at one MTBF (grid shape ``(1, len(rhos))``)."""
    return evaluate_grid(scenarios.mu_rho_grid([mu_minutes], rhos, alpha))


def sweep_mu_rho_grid(mus: Sequence[float], rhos: Sequence[float],
                      alpha: float = 1.0) -> GridResult:
    """Figure 2: the (mu x rho) ratio surfaces in one call."""
    return evaluate_grid(scenarios.mu_rho_grid(mus, rhos, alpha))


def sweep_nodes_grid(n_nodes: Sequence[float],
                     power: PowerParams) -> GridResult:
    """Figure 3: scalability in N at one power scenario."""
    return evaluate_grid(scenarios.nodes_grid(n_nodes, power))
