"""Batched closed-form model + period solvers over a :class:`ParamGrid`.

Vectorized (leading-batch-axes) counterparts of ``core.model`` and
``core.optimal``: the §3.1/§3.2 expectations, the golden-section minimizer,
the AlgoT closed form, the AlgoE quadratic root (corrected coefficients from
``optimal.derived_coefficients``, vectorized), and the Young/Daly/MSK
baselines — all evaluated for a whole grid in a few jitted float64 calls.

Root-selection semantics match the fixed scalar solver: E' = Q/K with K > 0
on the valid interval, so the energy *minimum* is the root of the quadratic
Q where Q' > 0; any point where that root is missing, complex, or outside
the bracket — or where its energy is beaten by the batched golden-section
argmin — falls back to the numeric result elementwise.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

try:  # newer jax re-exports the x64 context at top level
    from jax import enable_x64
except ImportError:
    from jax.experimental import enable_x64

from ..core.params import PowerParams
from . import scenarios
from .scenarios import ParamGrid

_GOLDEN = (math.sqrt(5.0) - 1.0) / 2.0

# p: dict of broadcastable jnp float64 arrays with the ParamGrid field names.


def _ab(p):
    a = (1.0 - p["omega"]) * p["C"]
    b = 1.0 - (p["D"] + p["R"] + p["omega"] * p["C"]) / p["mu"]
    return a, b


def time_final_batched(T, p, T_base=1.0):
    """§3.1: T_final = T_base * T / ((T-a)(b - T/2mu)), elementwise."""
    a, b = _ab(p)
    return T_base * T / ((T - a) * (b - T / (2.0 * p["mu"])))


def _re_exec(T, p):
    C, omega = p["C"], p["omega"]
    return (omega * C + (T**2 - C**2) / (2.0 * T)
            + omega * C**2 / (2.0 * T))


def _io_per_failure(T, p):
    return p["R"] + p["C"]**2 / (2.0 * T)


def energy_final_batched(T, p, T_base=1.0):
    """§3.2: E_final = T_cal P_cal + T_io P_io + T_down P_down + Tf P_static."""
    C, omega = p["C"], p["omega"]
    Tf = time_final_batched(T, p, T_base)
    nf = Tf / p["mu"]
    T_cal = T_base + nf * _re_exec(T, p)
    T_io = T_base * C / (T - (1.0 - omega) * C) + nf * _io_per_failure(T, p)
    T_down = nf * p["D"]
    return (T_cal * p["P_cal"] + T_io * p["P_io"]
            + T_down * p["P_down"] + Tf * p["P_static"])


def _bracket(p):
    """Shrunk (lo, hi) per grid point, mirroring ``optimal._bracket``.

    Degenerate points (hi0 <= lo0) get a harmless placeholder bracket; the
    caller masks them out via ``valid``.
    """
    a, b = _ab(p)
    lo0 = jnp.maximum(a, p["C"])
    hi0 = 2.0 * p["mu"] * b
    valid = hi0 > lo0 * (1.0 + 1e-9)
    hi0 = jnp.where(valid, hi0, 2.0 * lo0 + 1.0)
    span = hi0 - lo0
    return lo0 + 1e-9 * span + 1e-12, hi0 - 1e-9 * span, valid


def golden_section_batched(f: Callable, lo, hi, iters: int = 40):
    """Elementwise golden-section argmin of ``f`` on [lo, hi].

    Branchless (``jnp.where``) form of ``optimal.golden_section`` carrying
    the two interior function values, so each iteration costs ONE batched
    evaluation of ``f`` — the loop is sequential, so per-step cost is what
    dominates on small grids.
    """
    a, b = lo, hi
    c = b - _GOLDEN * (b - a)
    d = a + _GOLDEN * (b - a)
    fc, fd = f(c), f(d)

    def body(_, st):
        a, b, c, d, fc, fd = st
        left = fc < fd
        a2 = jnp.where(left, a, c)
        b2 = jnp.where(left, d, b)
        new = jnp.where(left, b2 - _GOLDEN * (b2 - a2),
                        a2 + _GOLDEN * (b2 - a2))
        fnew = f(new)
        c2 = jnp.where(left, new, d)
        fc2 = jnp.where(left, fnew, fd)
        d2 = jnp.where(left, c, new)
        fd2 = jnp.where(left, fc, fnew)
        return (a2, b2, c2, d2, fc2, fd2)

    a, b, _, _, _, _ = lax.fori_loop(0, iters, body, (a, b, c, d, fc, fd))
    return 0.5 * (a + b)


# ---------------------------------------------------------------------------
# Period solvers
# ---------------------------------------------------------------------------

def _t_opt_time_from(p, t_num):
    """AlgoT closed form, falling back to the supplied numeric argmin."""
    a, b = _ab(p)
    lo, hi, _ = _bracket(p)
    val = 2.0 * a * b * p["mu"]
    t_closed = jnp.clip(jnp.sqrt(jnp.maximum(val, 0.0)), lo, hi)
    return jnp.where(val > 0.0, t_closed, t_num)


def t_opt_time_batched(p, T_base=1.0):
    """AlgoT, Eq. (1) closed form; numeric fallback where it degenerates.

    Degenerate grid points (no valid period: the scalar solver raises)
    return NaN — the elementwise analogue of that error.
    """
    lo, hi, valid = _bracket(p)
    t_num = golden_section_batched(
        lambda t: time_final_batched(t, p, T_base), lo, hi)
    return jnp.where(valid, _t_opt_time_from(p, t_num), jnp.nan)


def _energy_quadratic(p):
    """Vectorized corrected coefficients (``optimal.derived_coefficients``)."""
    a, b = _ab(p)
    C, mu, omega = p["C"], p["mu"], p["omega"]
    al = p["P_cal"] / p["P_static"]
    be = p["P_io"] / p["P_static"]
    ga = p["P_down"] / p["P_static"]
    P = al * omega * C + be * p["R"] + ga * p["D"]
    Q = (be - al * (1.0 - omega)) * C**2
    c2 = (1.0 / (2.0 * mu) + P / (2.0 * mu**2) + al * b / (2.0 * mu)
          + (al * a - be * C) / (4.0 * mu**2))
    c1 = (be * C - al * a) * b / mu + Q / (2.0 * mu**2)
    c0 = (-a * b * (P + mu) / mu - be * C * b**2
          - Q * (b / (2.0 * mu) + a / (4.0 * mu**2)))
    return c2, c1, c0


def _t_opt_energy_from(p, T_base, t_num):
    """AlgoE quadratic root, guarded by the supplied numeric argmin."""
    lo, hi, _ = _bracket(p)
    c2, c1, c0 = _energy_quadratic(p)

    disc = c1**2 - 4.0 * c2 * c0
    sq = jnp.sqrt(jnp.maximum(disc, 0.0))
    safe_c2 = jnp.where(jnp.abs(c2) > 1e-300, c2, 1.0)
    r1 = (-c1 - sq) / (2.0 * safe_c2)
    r2 = (-c1 + sq) / (2.0 * safe_c2)
    safe_c1 = jnp.where(jnp.abs(c1) > 1e-300, c1, 1.0)
    rlin = -c0 / safe_c1

    def is_min_root(r):
        # E'' sign at a root of E' equals the sign of Q' (K > 0 in-bracket).
        return ((disc >= 0.0) & (jnp.abs(c2) > 1e-300)
                & (r > lo) & (r < hi) & (2.0 * c2 * r + c1 > 0.0))

    lin_ok = (jnp.abs(c2) <= 1e-300) & (jnp.abs(c1) > 1e-300) \
        & (rlin > lo) & (rlin < hi) & (c1 > 0.0)

    t_root = jnp.where(is_min_root(r1), r1,
                       jnp.where(is_min_root(r2), r2,
                                 jnp.where(lin_ok, rlin, t_num)))
    # Safeguard: never return a root whose energy loses to the numeric argmin.
    e_root = energy_final_batched(t_root, p, T_base)
    e_num = energy_final_batched(t_num, p, T_base)
    return jnp.where(e_root <= e_num * (1.0 + 1e-9), t_root, t_num)


def t_opt_energy_batched(p, T_base=1.0):
    """AlgoE: minimum-branch quadratic root, numeric fallback elementwise.

    Degenerate grid points (no valid period) return NaN.
    """
    lo, hi, valid = _bracket(p)
    t_num = golden_section_batched(
        lambda t: energy_final_batched(t, p, T_base), lo, hi)
    return jnp.where(valid, _t_opt_energy_from(p, T_base, t_num), jnp.nan)


def t_young_batched(p):
    return jnp.sqrt(2.0 * p["C"] * p["mu"]) + p["C"]


def t_daly_batched(p):
    return jnp.sqrt(2.0 * p["C"] * (p["mu"] + p["D"] + p["R"])) + p["C"]


def _msk_energy(T, p0, T_base=1.0):
    """MSK objective on the omega=0 parameter set (paper §3.2 side note)."""
    C, R = p0["C"], p0["R"]
    Tf = time_final_batched(T, p0, T_base)
    nf = Tf / p0["mu"]
    T_cal = T_base + nf * (T - 2.0 * C) / 2.0
    T_io = T_base * C / (T - C) + nf * (R + C)
    T_down = nf * p0["D"]
    return (T_cal * p0["P_cal"] + T_io * p0["P_io"]
            + T_down * p0["P_down"] + Tf * p0["P_static"])


def _msk_setup(p):
    """(omega=0 params, lo, hi, valid) for the MSK numeric argmin."""
    p0 = dict(p)
    p0["omega"] = jnp.zeros_like(p["omega"])
    lo, hi, valid = _bracket(p0)
    return p0, jnp.maximum(lo, 2.0 * p0["C"] + 1e-12), hi, valid


def t_msk_energy_batched(p, T_base=1.0):
    """MSK energy-optimal period; degenerate points return NaN."""
    p0, lo, hi, valid = _msk_setup(p)
    t = golden_section_batched(lambda t: _msk_energy(t, p0, T_base), lo, hi)
    return jnp.where(valid, t, jnp.nan)


# ---------------------------------------------------------------------------
# Grid evaluation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GridResult:
    """Periods/ratios for a whole grid; arrays of ``grid.shape``.

    Degenerate points (``~valid``: C of the order of the MTBF, no usable
    period) carry T_time = T_energy = C and ratios of exactly 1.0, matching
    the scalar ``tradeoff.evaluate`` convention; their Tf_*/E_* are NaN.
    """

    grid: ParamGrid
    T_base: float
    T_time: np.ndarray           # AlgoT period
    T_energy: np.ndarray         # AlgoE period
    T_young: np.ndarray
    T_daly: np.ndarray
    T_msk: np.ndarray
    Tf_time: np.ndarray          # T_final at the AlgoT period
    Tf_energy: np.ndarray        # T_final at the AlgoE period
    E_time: np.ndarray           # E_final at the AlgoT period
    E_energy: np.ndarray         # E_final at the AlgoE period
    time_ratio: np.ndarray       # Tf_energy / Tf_time  (>= 1, "loss")
    energy_ratio: np.ndarray     # E_time / E_energy    (>= 1, "gain")
    valid: np.ndarray

    @property
    def energy_saving(self) -> np.ndarray:
        return 1.0 - 1.0 / self.energy_ratio

    @property
    def time_overhead(self) -> np.ndarray:
        return self.time_ratio - 1.0


_FIELD_ORDER = ("C", "R", "D", "mu", "omega",
                "P_static", "P_cal", "P_io", "P_down")
_OUT_ORDER = ("T_time", "T_energy", "T_young", "T_daly", "T_msk",
              "Tf_time", "Tf_energy", "E_time", "E_energy",
              "time_ratio", "energy_ratio", "valid")


@jax.jit
def _evaluate_core(P, T_base):
    # P is one stacked (9, N) array — a single host->device transfer and a
    # single dispatch beat nine tiny ones on small grids.
    p = dict(zip(_FIELD_ORDER, P))
    lo, hi, valid = _bracket(p)
    p0, lo_m, hi_m, _ = _msk_setup(p)

    # The three numeric argmins (AlgoT fallback, AlgoE guard, MSK) share ONE
    # golden-section loop over a stacked leading axis: the loop is sequential
    # and dispatch-bound on small grids, so fusing it is a ~3x win there.
    sel = jnp.arange(3).reshape((3,) + (1,) * lo.ndim)

    def objective(t):
        return jnp.where(sel == 0, time_final_batched(t, p, T_base),
                         jnp.where(sel == 1,
                                   energy_final_batched(t, p, T_base),
                                   _msk_energy(t, p0, T_base)))

    t_num = golden_section_batched(objective,
                                   jnp.stack([lo, lo, lo_m]),
                                   jnp.stack([hi, hi, hi_m]))
    Tt = _t_opt_time_from(p, t_num[0])
    Te = _t_opt_energy_from(p, T_base, t_num[1])
    Ty = t_young_batched(p)
    Td = t_daly_batched(p)
    Tm = t_num[2]
    Tf_t = time_final_batched(Tt, p, T_base)
    Tf_e = time_final_batched(Te, p, T_base)
    E_t = energy_final_batched(Tt, p, T_base)
    E_e = energy_final_batched(Te, p, T_base)
    nan = jnp.full_like(Tt, jnp.nan)
    C = p["C"]
    one = jnp.ones_like(Tt)
    return jnp.stack([jnp.where(valid, Tt, C),
                      jnp.where(valid, Te, C),
                      Ty, Td,
                      jnp.where(valid, Tm, C),
                      jnp.where(valid, Tf_t, nan),
                      jnp.where(valid, Tf_e, nan),
                      jnp.where(valid, E_t, nan),
                      jnp.where(valid, E_e, nan),
                      jnp.where(valid, Tf_e / Tf_t, one),
                      jnp.where(valid, E_t / E_e, one),
                      valid.astype(C.dtype)])


def evaluate_grid(grid: ParamGrid, T_base: float = 1.0) -> GridResult:
    """Periods + time/energy ratios for every grid point, in one jitted call."""
    flat = grid.ravel()
    P = np.stack([getattr(flat, f) for f in _FIELD_ORDER])
    with enable_x64():
        raw = np.asarray(_evaluate_core(
            jnp.asarray(P, dtype=jnp.float64),
            jnp.asarray(float(T_base), jnp.float64)))
    out = {k: raw[i].reshape(grid.shape) for i, k in enumerate(_OUT_ORDER)}
    out["valid"] = out["valid"] > 0.5
    return GridResult(grid=grid, T_base=float(T_base), **out)


# ---------------------------------------------------------------------------
# Figure-level conveniences
# ---------------------------------------------------------------------------

def sweep_rho_grid(rhos: Sequence[float], mu_minutes: float,
                   alpha: float = 1.0) -> GridResult:
    """Figure 1: rho swept at one MTBF (grid shape ``(1, len(rhos))``)."""
    return evaluate_grid(scenarios.mu_rho_grid([mu_minutes], rhos, alpha))


def sweep_mu_rho_grid(mus: Sequence[float], rhos: Sequence[float],
                      alpha: float = 1.0) -> GridResult:
    """Figure 2: the (mu x rho) ratio surfaces in one call."""
    return evaluate_grid(scenarios.mu_rho_grid(mus, rhos, alpha))


def sweep_nodes_grid(n_nodes: Sequence[float],
                     power: PowerParams) -> GridResult:
    """Figure 3: scalability in N at one power scenario."""
    return evaluate_grid(scenarios.nodes_grid(n_nodes, power))
