"""Batched Monte-Carlo trajectory engine (``jax.lax.scan`` machines).

The scalar event loop of ``repro.core.simulator.simulate_once`` rewritten as
fixed-shape scans so they can be ``vmap``-ed over trials and again over
parameter batches, and jitted in float64 (under the local ``enable_x64``
context — global JAX dtype state is untouched).

Two interchangeable kernels implement the same trajectory semantics
(``engine_kind=`` selects; see docs/simulation.md "Engine architecture"):

``event`` (default)
    One scan iteration per FAILURE.  Between consecutive failures the
    trajectory is closed-form — completed periods are an integer division
    of the inter-failure gap against the period, and the committed work,
    checkpoint I/O and wasted partial segment all follow arithmetically —
    so the scan length is the failure-schedule capacity (~ E[#failures]
    x gap-cv^2), not the per-phase event count.  For heavy-tailed
    (Weibull k < 1 / log-normal) processes this is 30-100x fewer
    iterations than the step machine, which is what made the PR-3 Weibull
    path ~3x SLOWER than the scalar oracle (BENCH_sweep.json's 0.32x).

``step``
    One scan iteration per phase segment or failure, mirroring the scalar
    loop body branch-for-branch — the original machine, kept as a
    cross-check and as the bit-level twin of the scalar oracle.

Both kernels consume the same pre-sampled gap schedules and produce
identical trajectories (exactly identical — not just statistically — when
every quantity is binary-representable, e.g. the dyadic-schedule parity
tests; within ~1e-13 relative rounding noise otherwise).  One caveat: a
gap landing EXACTLY on a period boundary in exact arithmetic (``g`` an
exact multiple of ``T`` with non-dyadic values — probability zero for
continuous processes, constructible with synthetic schedules) is a
genuine tie between "checkpoint committed" and "failure first"; the
event kernel resolves it by the documented failure-wins-ties rule in
exact arithmetic, while the step kernel's float-accumulated clock falls
on whichever side its rounding lands — the two may then differ by one
period's worth of committed work for that stretch.

Scan-state layout of the STEP kernel (one trajectory; all scalars):

    wall        f64  wall-clock time
    committed   f64  work protected by the last COMPLETED checkpoint
    live        f64  work executed since the last rollback point
    work_exec   f64  total CPU work executed (incl. re-execution)
    io_time     f64  cumulative I/O-active time (ckpt writes + recoveries)
    down_time   f64  cumulative downtime
    next_fail   f64  absolute time of the next failure
    phase_left  f64  time remaining in the current phase
    snapshot    f64  work value being written by the in-flight checkpoint
    phase       i32  0 = compute (rate 1), 1 = checkpoint (rate omega)
    n_fail      i32  failures so far
    n_ckpt      i32  committed checkpoints so far
    fail_idx    i32  next index into the pre-sampled failure-gap array
    done        bool trajectory reached T_base work

One scan step processes one *event* (phase-segment completion or failure),
mirroring the scalar loop body branch-for-branch; steps after ``done`` are
no-ops.  Checkpoint-commit semantics follow the paper: a checkpoint commits
the state as of the *beginning* of its phase, so the omega*C work done
concurrently is only protected by the NEXT completed checkpoint.

Failure times are consumed from a per-trajectory array of gaps.  Feeding
the same gaps to the scalar oracle via :class:`ScheduledRNG` reproduces
trajectories bit-for-bit — the parity tests rely on this.

Schedules come from one of two samplers: :func:`presample_gaps` (host
numpy, the CRN solvers' replayable schedules) or — the default
auto-sampling path — per-(grid point, trial) folded threefry keys fed to
``FailureProcess.traced_sampler`` *inside* each dispatch chunk, so the
``(B, n_trials, capacity)`` tensor never exists on the host, never pays a
per-call host->device transfer, and (because every (point, trial) pair
owns its key and the sampling capacity is the grid-wide max, a
partition-independent quantity) is bit-identical under every way of
cutting the work.  Budgets are per-grid-point and bucketed to powers of
two (:func:`fail_capacity_points` / :func:`step_budget_points`): mixed-mu
grids are dispatched bucket by bucket so cheap points no longer pay the
most fragile point's scan length.

Every single-level jitted call routes through :mod:`repro.sim.dispatch` —
multi-device grid-axis sharding over the 1-D sweep mesh, streaming chunks
bounded by a device-memory budget, trial-axis blocking, and LRU-bounded
compiled-runner caches.  All dispatch knobs are pure performance knobs:
chunk size, shard count, memory budget, budget bucketing, and
``engine_kind`` never change a fixed seed's results
(tests/test_dispatch.py).  The bulk :func:`presample_gaps_device` sampler
(single key, whole grid) is kept for direct use and CRN-style workflows.
The multilevel engine (:func:`simulate_trajectories_ml`) remains a
single-shot dispatch — its model-grid counterpart
``sweep.evaluate_multilevel_grid`` IS dispatch-routed, and its runner
cache is LRU-bounded like the rest.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

try:  # newer jax re-exports the x64 context at top level
    from jax import enable_x64
except ImportError:
    from jax.experimental import enable_x64

from ..core.failures import as_process
from . import dispatch as _dispatch
from . import precision as _precision
from .scenarios import MultilevelParamGrid, ParamGrid

COMPUTE, CHECKPOINT = 0, 1

#: work-completion slack, identical to the scalar simulator's epsilon.
_EPS = 1e-12


class ScheduledRNG:
    """np.random.Generator stand-in replaying a fixed gap schedule.

    ``simulate_once(..., rng=ScheduledRNG(gaps))`` consumes exactly the
    pre-sampled inter-failure gaps the batched engine was given, enabling
    trajectory-for-trajectory parity checks — for *any* distribution the
    gaps were drawn from, since the schedule replays verbatim.

    Contract: the ``scale`` argument of :meth:`exponential` is deliberately
    **ignored** — the replayed gaps are already in wall-clock units (they
    were pre-scaled when sampled), and re-scaling them here would silently
    double-apply mu.  On exhaustion the draw is ``inf`` ("no more
    failures") and :attr:`exhausted` is set; the scalar simulator raises on
    that flag, mirroring the batched engine's ``gaps_exhausted`` error.
    """

    #: marks this rng as a schedule replay for ``core.simulator`` dispatch.
    replays_schedule = True

    def __init__(self, gaps):
        self._gaps = [float(g) for g in np.asarray(gaps).ravel()]
        self._i = 0
        self.exhausted = False

    def exponential(self, scale: float = 1.0) -> float:
        if self._i >= len(self._gaps):
            self.exhausted = True
            return math.inf          # schedule exhausted: no more failures
        g = self._gaps[self._i]
        self._i += 1
        return g


@dataclasses.dataclass(frozen=True)
class TrajectoryBatch:
    """Per-trajectory outputs, shape ``grid.shape + (n_trials,)``."""

    wall_time: np.ndarray        # paper's T_final
    energy: np.ndarray           # paper's E_final
    work_executed: np.ndarray    # paper's T_cal
    io_time: np.ndarray          # paper's T_io
    down_time: np.ndarray        # paper's T_down
    n_failures: np.ndarray
    n_checkpoints: np.ndarray
    truncated: np.ndarray        # scan budget exhausted before completion
    gaps_exhausted: np.ndarray   # failure schedule ran dry (tail simulated
                                 # as failure-free -> potentially biased)


def _run_one(T, C, R, D, omega, T_base, gaps, n_steps):
    """One trajectory: scalar parameter tracers + a (F,) gap vector.

    Failure times come entirely from ``gaps`` (pre-sampled with scale mu
    outside the scan), so mu itself never enters the kernel.
    """
    f64 = gaps.dtype
    n_gaps = gaps.shape[0]

    init = (jnp.zeros((), f64),            # wall
            jnp.zeros((), f64),            # committed
            jnp.zeros((), f64),            # live
            jnp.zeros((), f64),            # work_exec
            jnp.zeros((), f64),            # io_time
            jnp.zeros((), f64),            # down_time
            gaps[0],                       # next_fail
            T - C,                         # phase_left
            jnp.zeros((), f64),            # snapshot
            jnp.zeros((), jnp.int32),      # phase = COMPUTE
            jnp.zeros((), jnp.int32),      # n_fail
            jnp.zeros((), jnp.int32),      # n_ckpt
            jnp.ones((), jnp.int32),       # fail_idx (gaps[0] consumed)
            jnp.zeros((), jnp.bool_))      # done

    def step(carry, _):
        (wall, committed, live, work_exec, io_time, down_time,
         next_fail, phase_left, snapshot, phase,
         n_fail, n_ckpt, fail_idx, done) = carry

        in_ckpt = phase == CHECKPOINT
        rate = jnp.where(in_ckpt, omega, 1.0)
        t_done = jnp.where(rate > 0.0,
                           (T_base - live) / jnp.where(rate > 0.0, rate, 1.0),
                           jnp.inf)
        t_next = jnp.minimum(phase_left, t_done)
        no_fail = wall + t_next < next_fail

        # ---- branch A: the phase segment completes without failure ----
        wall_a = wall + t_next
        live_a = live + rate * t_next
        work_a = work_exec + rate * t_next
        io_a = io_time + jnp.where(in_ckpt, t_next, 0.0)
        left_a = phase_left - t_next
        finished = live_a >= T_base - _EPS
        boundary = jnp.logical_and(~finished, left_a <= _EPS)
        start_ckpt = jnp.logical_and(boundary, ~in_ckpt)
        end_ckpt = jnp.logical_and(boundary, in_ckpt)
        phase_a = jnp.where(start_ckpt, CHECKPOINT,
                            jnp.where(end_ckpt, COMPUTE, phase))
        left_a = jnp.where(start_ckpt, C, jnp.where(end_ckpt, T - C, left_a))
        snapshot_a = jnp.where(start_ckpt, live_a, snapshot)
        committed_a = jnp.where(end_ckpt, snapshot, committed)
        n_ckpt_a = n_ckpt + end_ckpt.astype(jnp.int32)

        # ---- branch B: a failure strikes mid-segment ----
        dt = next_fail - wall
        work_b = work_exec + rate * dt
        io_b = io_time + jnp.where(in_ckpt, dt, 0.0) + R
        wall_b = next_fail + D + R
        down_b = down_time + D
        gap = jnp.where(fail_idx < n_gaps,
                        gaps[jnp.minimum(fail_idx, n_gaps - 1)], jnp.inf)
        next_fail_b = wall_b + gap

        def sel(a_val, b_val):
            return jnp.where(no_fail, a_val, b_val)

        new = (sel(wall_a, wall_b),
               sel(committed_a, committed),
               sel(live_a, committed),          # failure rolls back to commit
               sel(work_a, work_b),
               sel(io_a, io_b),
               sel(down_time, down_b),
               sel(next_fail, next_fail_b),
               sel(left_a, T - C),
               sel(snapshot_a, snapshot),
               sel(phase_a, COMPUTE).astype(jnp.int32),
               sel(n_fail, n_fail + 1).astype(jnp.int32),
               sel(n_ckpt_a, n_ckpt).astype(jnp.int32),
               sel(fail_idx, fail_idx + 1).astype(jnp.int32),
               jnp.logical_or(done, jnp.logical_and(no_fail, finished)))

        keep = lambda old, upd: jnp.where(done, old, upd)
        return tuple(keep(o, u) for o, u in zip(carry, new)), None

    final, _ = lax.scan(step, init, None, length=n_steps)
    (wall, _committed, _live, work_exec, io_time, down_time,
     _nf, _pl, _snap, _phase, n_fail, n_ckpt, fail_idx, done) = final
    return {"wall_time": wall, "work_executed": work_exec,
            "io_time": io_time, "down_time": down_time,
            "n_failures": n_fail, "n_checkpoints": n_ckpt,
            "truncated": ~done,
            # fail_idx > n_gaps means an inf gap was drawn at some point,
            # i.e. part of the trajectory ran under "no more failures".
            "gaps_exhausted": fail_idx > n_gaps}


def _run_one_event(T, C, R, D, omega, T_base, gaps, n_steps):
    """One trajectory, one scan iteration per FAILURE (the fast kernel).

    Between consecutive failures the machine is deterministic, so the whole
    inter-failure stretch collapses to closed form.  With work-per-period
    ``w = T - (1-omega)C`` and remaining work ``rem``, completion from a
    segment start (t = 0 at the end of the previous recovery, live ==
    committed, compute phase) takes

        j    = floor((rem - eps) / w)          full periods, then
        r    = rem - j*w                       work in the finishing period,
        t_in = r                 if r <= T-C   (finishes mid-compute)
               T-C + (r-(T-C))/omega otherwise (mid-checkpoint),

    i.e. ``t_fin = j*T + t_in``.  A failure at gap ``g`` wins iff
    ``t_fin >= g`` (ties go to the failure, matching the step kernel's
    strict ``wall + t_next < next_fail``); it lands in period ``k+1`` with
    ``k = #{i >= 1 : i*T < g}`` completed checkpoints, at in-period offset
    ``u = g - k*T`` (compute if ``u <= T-C``, else mid-checkpoint), from
    which the executed work, wasted checkpoint I/O and new committed value
    follow directly.  Every arithmetic expression mirrors a step-kernel
    accumulation term-for-term, so the two kernels agree exactly whenever
    the quantities involved are exactly representable (the dyadic parity
    tests) and to rounding noise otherwise — except for the
    exact-period-boundary tie described in the module docstring, where
    this kernel applies failure-wins-ties in exact arithmetic (``k*T >= g``
    leaves the boundary checkpoint uncommitted) and the step kernel's
    accumulated clock resolves the tie by its own rounding.

    The ``eps`` in ``j`` reproduces the step kernel's completion slack
    (``live >= T_base - eps``): finishing exactly at a checkpoint boundary
    does NOT count that final checkpoint.
    """
    f64 = gaps.dtype
    n_gaps = gaps.shape[0]
    Tc = T - C                          # compute-segment length
    w = T - (1.0 - omega) * C           # work committed per full period
    omega_safe = jnp.where(omega > 0.0, omega, 1.0)

    init = (jnp.zeros((), f64),         # wall
            jnp.zeros((), f64),         # committed
            jnp.zeros((), f64),         # work_exec
            jnp.zeros((), f64),         # io_time
            jnp.zeros((), f64),         # down_time
            jnp.zeros((), jnp.int32),   # n_fail
            jnp.zeros((), jnp.int32),   # n_ckpt
            jnp.zeros((), jnp.bool_),   # used_inf (schedule ran dry)
            jnp.zeros((), jnp.bool_))   # done

    def step(carry, _):
        (wall, committed, work_exec, io_time, down_time,
         n_fail, n_ckpt, used_inf, done) = carry

        # One gap per inter-failure stretch, exactly like the step kernel's
        # one-draw-per-stretch accounting (the initial draw + one per
        # failure); reading past the schedule yields inf == "no more
        # failures" and flags exhaustion.
        in_range = n_fail < n_gaps
        g = jnp.where(in_range, gaps[jnp.minimum(n_fail, n_gaps - 1)],
                      jnp.inf)

        # ---- closed-form completion time from this segment start ----
        rem = T_base - committed
        j = jnp.maximum(jnp.floor((rem - _EPS) / w), 0.0)
        r = rem - j * w                 # work inside the finishing period
        rr = r - Tc                     # its checkpoint-phase share (if > 0)
        t_in = jnp.where(rr > 0.0, Tc + rr / omega_safe, r)
        t_fin = j * T + t_in
        complete = t_fin < g

        # ---- branch A: completes before the next failure ----
        wall_a = wall + t_fin
        work_a = work_exec + rem
        io_a = io_time + j * C + jnp.maximum(rr, 0.0) / omega_safe

        # ---- branch B: failure at s = g after the segment start ----
        s = jnp.where(jnp.isfinite(g), g, 0.0)
        k = jnp.floor(s / T)
        # floor(s/T) can land ON k*T (exact-boundary failure: the
        # checkpoint ending at the failure instant does NOT commit) or one
        # above it (quotient rounded up); both correct downward.
        k = jnp.where((k > 0.0) & (k * T >= s), k - 1.0, k)
        u = s - k * T                   # offset inside the failing period
        uc = u - Tc                     # its checkpoint-phase share (if > 0)
        work_b = work_exec + k * w + jnp.where(uc > 0.0,
                                               Tc + omega * uc, u)
        io_b = io_time + k * C + jnp.maximum(uc, 0.0) + R
        wall_b = (wall + s) + D + R
        committed_b = jnp.where(k >= 1.0,
                                committed + (k - 1.0) * w + Tc, committed)

        def sel(a_val, b_val):
            return jnp.where(complete, a_val, b_val)

        new = (sel(wall_a, wall_b),
               sel(committed, committed_b),
               sel(work_a, work_b),
               sel(io_a, io_b),
               sel(down_time, down_time + D),
               sel(n_fail, n_fail + 1).astype(jnp.int32),
               (n_ckpt + sel(j, k).astype(jnp.int32)).astype(jnp.int32),
               jnp.logical_or(used_inf, ~in_range),
               jnp.logical_or(done, complete))

        keep = lambda old, upd: jnp.where(done, old, upd)
        return tuple(keep(o, u) for o, u in zip(carry, new)), None

    final, _ = lax.scan(step, init, None, length=n_steps)
    (wall, _committed, work_exec, io_time, down_time,
     n_fail, n_ckpt, used_inf, done) = final
    return {"wall_time": wall, "work_executed": work_exec,
            "io_time": io_time, "down_time": down_time,
            "n_failures": n_fail, "n_checkpoints": n_ckpt,
            "truncated": ~done,
            "gaps_exhausted": used_inf}


#: kernel registry: engine_kind -> per-trajectory scan.
_KERNELS = {"step": _run_one, "event": _run_one_event}

#: kinds that implement the EVENT-level trajectory semantics (one
#: iteration per failure) and share the event kernel's budget algebra.
#: ``"pallas"`` is the accelerator-native port of the event kernel
#: (kernels/event_sweep.py): bit-identical to ``"event"`` under the f64
#: policy, within the policy's documented tolerance otherwise.
_EVENT_LIKE = ("event", "pallas")

#: every selectable engine kind.
_ENGINE_KINDS = ("event", "pallas", "step")


def resolve_engine_kind(engine_kind: Optional[str] = None) -> str:
    """Resolve an ``engine_kind`` argument: None defers to
    ``$REPRO_ENGINE_KIND`` (the CI pallas-interpret leg forces the
    Pallas engine this way) and then to the ``"event"`` default;
    explicit kinds pass through.  Raises on unknown kinds."""
    if engine_kind is None:
        engine_kind = os.environ.get("REPRO_ENGINE_KIND", "").strip() \
            or "event"
    if engine_kind not in _ENGINE_KINDS:
        raise ValueError(f"unknown engine_kind {engine_kind!r}; "
                         f"one of {sorted(_ENGINE_KINDS)}")
    return engine_kind


def _engine_policy(engine_kind: str, cfg, precision):
    """The PrecisionPolicy an engine dispatch runs under — only the
    Pallas kernel is policy-aware (it is the accelerator path); the scan
    kernels ARE the f64 oracle and ignore the policy by design."""
    if engine_kind != "pallas":
        return None
    return _dispatch.resolve_precision(cfg, precision)


def _kind_token(kind: str, policy) -> object:
    """Runner-cache key component for (kind, policy): plain kinds keep
    their historical string token (compile-cache continuity); the
    policy-aware pallas kind never shares a compiled runner across
    policies."""
    return kind if policy is None else (kind, policy.name)


def _grid_fn(n_steps: int, kind: str, policy=None):
    """The unjitted (grid x trials) runner of one kernel — shared by the
    plain and the candidate-axis runners.  Scan kinds double-vmap the
    per-trajectory kernel; the pallas kind hands the whole chunk to the
    blocked Pallas kernel (interpret mode off-TPU)."""
    if kind == "pallas":
        from ..kernels import event_sweep as _es
        pol = policy if policy is not None else _precision.F64

        def run_grid(T, C, R, D, omega, T_base, gaps):
            return _es.event_sweep(T, C, R, D, omega, T_base, gaps,
                                   n_steps=n_steps, dtype=pol.dtype,
                                   compensated=pol.compensated)
        return run_grid
    kernel = _KERNELS[kind]

    def run_grid(T, C, R, D, omega, T_base, gaps):
        def one(t, c, r, d, o, tb, g):
            return kernel(t, c, r, d, o, tb, g, n_steps)
        over_trials = jax.vmap(one, in_axes=(None,) * 6 + (0,))
        over_grid = jax.vmap(over_trials, in_axes=(0,) * 6 + (0,))
        return over_grid(T, C, R, D, omega, T_base, gaps)
    return run_grid


def _cand_fn(n_steps: int, kind: str, policy=None):
    """Candidate-axis runner: run the grid runner once per candidate
    period with everything else held fixed — the gap schedules are
    SHARED across candidates, never tiled or re-transferred.  Scan kinds
    vmap the candidate axis; the pallas kind serializes it with
    ``lax.map`` (one pallas_call per candidate — batching a pallas_call
    under vmap has no kernel-level batching rule to win anything)."""
    run_grid = _grid_fn(n_steps, kind, policy)

    if kind == "pallas":
        def run_cands(T2, C, R, D, omega, T_base, gaps):
            return lax.map(
                lambda t: run_grid(t, C, R, D, omega, T_base, gaps), T2)
        return run_cands

    def run_cands(T2, C, R, D, omega, T_base, gaps):
        return jax.vmap(run_grid, in_axes=(0,) + (None,) * 6)(
            T2, C, R, D, omega, T_base, gaps)
    return run_cands


# ---------------------------------------------------------------------------
# Budget estimation
# ---------------------------------------------------------------------------

def _expected_failures(T, grid: ParamGrid, T_base) -> np.ndarray:
    """E[#failures] from the closed-form model, clipped to be usable even
    slightly outside the model's validity range."""
    a, b = grid.a, grid.b
    denom = (T - a) * (b - T / (2.0 * grid.mu))
    with np.errstate(divide="ignore", invalid="ignore"):
        tf = np.where(denom > 1e-12, T_base * T / denom, np.inf)
    # Divergent/degenerate points: fall back to a crude geometric bound.
    tf = np.where(np.isfinite(tf) & (tf > 0), tf, 50.0 * T_base)
    return tf / grid.mu


def _process_cv_points(process, size: int) -> np.ndarray:
    """Per-raveled-grid-point gap CV (shape ``(size,)``); 1.0 where the
    process declares no spread.  Array-valued shape parameters give each
    point ITS OWN margin instead of the grid-wide worst case."""
    if process is None:
        return np.ones(size, dtype=np.float64)
    cv = np.asarray(as_process(process).ravel().gap_cv(), dtype=np.float64)
    return np.broadcast_to(cv.ravel() if cv.ndim else cv, (size,))


def _pow2(n) -> np.ndarray:
    """Elementwise next power of two (>= 1), as int64."""
    n = np.maximum(np.asarray(n), 1).astype(np.int64)
    flat = np.array([1 << (int(v) - 1).bit_length() for v in n.ravel()],
                    dtype=np.int64)
    return flat.reshape(n.shape)


def _per_point(arr, size: int) -> np.ndarray:
    """Collapse a budget estimate to one value per raveled grid point.

    Candidate-period probe stacks (shape ``(..., size)``) reduce by max
    over their leading axes; anything not aligned with the grid (scalars,
    probe vectors over a size-1 grid) collapses to the overall max.
    """
    arr = np.asarray(arr, dtype=np.float64)
    if arr.ndim >= 1 and arr.shape[-1] == size:
        if arr.ndim > 1:
            arr = arr.max(axis=tuple(range(arr.ndim - 1)))
        return arr
    return np.broadcast_to(arr.max() if arr.ndim else arr, (size,))


def fail_capacity_points(T, grid: ParamGrid, T_base,
                         process=None) -> np.ndarray:
    """Per-grid-point schedule capacity (mean + 10 sigma margin), bucketed
    to powers of two; shape ``(grid.size,)``.

    For non-exponential processes both the expected count (clustered short
    gaps inflate rollbacks, hence wall time) and the count fluctuation
    (renewal CLT: var ~ nf * cv^2) scale with the gap CV.  Power-of-two
    bucketing keeps the number of distinct compiled programs O(log) while
    letting mixed-mu grids pay only their own point's budget (the engine
    dispatches one call per bucket) instead of the grid-wide worst case.
    """
    cv = np.maximum(1.0, _process_cv_points(process, grid.size))
    nf = _expected_failures(T, grid, T_base) * cv * cv
    cap = np.ceil(nf + 10.0 * cv * np.sqrt(nf + 1.0) + 10.0)
    return _pow2(_per_point(cap, grid.size))


def default_fail_capacity(T, grid: ParamGrid, T_base,
                          process=None) -> int:
    """Grid-wide schedule capacity: the worst point's bucketed budget (the
    shared-schedule callers — CRN solvers, explicit ``gaps=`` paths)."""
    return int(np.max(fail_capacity_points(T, grid, T_base,
                                           process=process)))


def step_budget_points(T, grid: ParamGrid, T_base,
                       process=None) -> np.ndarray:
    """Per-grid-point STEP-kernel scan length (expected events with a 2x +
    fluctuation margin), bucketed to powers of two; shape ``(grid.size,)``.

    This is the budget the event kernel exists to avoid: per failure it
    pays ~2 T/(T-a) phase events of re-execution, so heavy-tailed
    processes (cv > 1) inflate it by cv^2 TWICE — once through the failure
    count and once through the margin.
    """
    cv = np.maximum(1.0, _process_cv_points(process, grid.size))
    work_per_period = np.maximum(T - grid.a, 1e-9)
    periods = T_base / work_per_period
    nf = _expected_failures(T, grid, T_base) * cv * cv
    # Each failure costs one event plus re-execution of at most one period
    # of work (2 phase events per period, +2 for the partial segments).
    per_fail = 2.0 * np.maximum(T / work_per_period, 1.0) + 4.0
    events = 2.0 * periods + 2.0 + nf * per_fail
    margin = 10.0 * cv * np.sqrt(nf + 1.0) * per_fail
    steps = np.ceil(2.0 * events + margin + 64.0)
    return _pow2(_per_point(steps, grid.size))


def default_step_budget(T, grid: ParamGrid, T_base, process=None) -> int:
    """Grid-wide step-kernel scan length: the worst point's bucketed
    budget (shared-schedule callers)."""
    return int(np.max(step_budget_points(T, grid, T_base, process=process)))


def presample_gaps(grid: ParamGrid, n_trials: int, capacity: int,
                   seed: int = 0, process=None) -> np.ndarray:
    """Inter-failure gaps, shape ``(B, n_trials, capacity)``.

    ``process`` selects the distribution (None = exponential; an
    ``Exponential()`` instance reproduces the None path bit-for-bit).  The
    process's own mean, if unset, is the grid's per-point mu; array-valued
    shape parameters broadcast over the raveled grid (``process.ravel()``
    is applied to match ``grid.ravel()``).
    """
    rng = np.random.default_rng(seed)
    mu = grid.ravel().mu[:, None, None]
    size = (grid.size, n_trials, capacity)
    if process is None:
        return rng.exponential(scale=mu, size=size)
    return np.asarray(process.ravel().sample(rng, size=size, mean=mu),
                      dtype=np.float64)


#: bound on cached compiled device samplers.  A long-lived sweep service
#: touches a new (process identity, sample size) pair per distinct grid,
#: and an unbounded dict would leak one compiled callable per pair
#: forever; the LRU evicts the least recently used sampler instead —
#: eviction only forces a recompile on the next use, never changes
#: results (tested in tests/test_dispatch.py).
DEVICE_SAMPLER_CACHE_SIZE = 32

#: compiled device samplers, keyed by (process identity, sample size).
_DEVICE_SAMPLERS = _dispatch.LRUCache(DEVICE_SAMPLER_CACHE_SIZE,
                                      name="engine.device_samplers")


def presample_gaps_device(grid: ParamGrid, n_trials: int, capacity: int,
                          seed: int = 0, process=None):
    """Inter-failure gaps sampled ON DEVICE, shape ``(B, n_trials, capacity)``.

    jax-native counterpart of :func:`presample_gaps`: threefry streams and
    the processes' inverse-CDF transforms (``FailureProcess.sample_gaps``),
    jitted, float64 — the schedule never exists on the host and no
    host->device transfer happens.  Deterministic in ``seed``; NOT the
    same stream as the numpy sampler, only the same distribution.

    Raises ``NotImplementedError`` for processes without a device sampler —
    callers fall back to :func:`presample_gaps`.
    """
    proc = as_process(process).ravel()
    flat = grid.ravel()
    size = (flat.size, int(n_trials), int(capacity))
    tok = (proc.cache_token(), size)
    fn = _DEVICE_SAMPLERS.get(tok)
    with enable_x64():
        key = jax.random.PRNGKey(int(seed))
        mean = jnp.asarray(flat.mu, dtype=jnp.float64)[:, None, None]
        if fn is None:
            fn = jax.jit(lambda k, m: proc.sample_gaps(k, size, mean=m))
            out = fn(key, mean)     # NotImplementedError escapes un-cached
            _DEVICE_SAMPLERS.put(tok, fn)
            return out
        return fn(key, mean)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def _normalize_gaps(gaps, size: int):
    """Normalize a caller-supplied schedule to ``(size, n_trials, F)``.

    Accepts numpy or device (jnp) arrays; device arrays stay on device
    (the CRN solvers keep their schedules resident and reuse them across
    calls without re-transferring).
    """
    xp = jnp if isinstance(gaps, jnp.ndarray) else np
    if xp is np:
        gaps = np.asarray(gaps, dtype=np.float64)
    if gaps.ndim == 1:
        gaps = gaps[None, None, :]
    if gaps.ndim == 2:
        gaps = gaps[None, :, :]
    return xp.broadcast_to(gaps, (size, gaps.shape[-2], gaps.shape[-1]))


def _scan_len(n: int) -> int:
    """Bucket a static scan length up to a power of two: extra steps are
    no-ops for both kernels, and bucketing keeps the jit cache at O(log)
    distinct programs instead of one compile per distinct value."""
    return 1 << (max(int(n), 1) - 1).bit_length()


def _as_f64_gaps(gaps):
    """Coerce a schedule to f64 (a device schedule built OUTSIDE an x64
    context arrives as float32 and would abort the scan with an opaque
    carry-dtype error); device arrays stay on device."""
    if isinstance(gaps, jnp.ndarray):
        if gaps.dtype == jnp.float64:
            return gaps
        with enable_x64():        # upcasting outside x64 silently truncates
            return jnp.asarray(gaps, dtype=jnp.float64)
    return np.asarray(gaps, dtype=np.float64)


def _trial_chunk(n_trials: int, capacity: int, ndev: int, cfg) -> int:
    """Trials per dispatch: all of them, unless even one grid chunk row
    per device at the full trial count would blow the memory budget —
    then the trials axis streams in blocks (an outer host loop; the MC
    reductions happen host-side on the reassembled arrays, so the block
    size never changes results)."""
    per_trial = 8 * (capacity + 32)
    budget = _dispatch.resolve(cfg).budget()
    if ndev * n_trials * per_trial <= budget:
        return n_trials
    return max(1, min(n_trials, budget // (ndev * per_trial)))


def _dispatch_explicit(T_arr, flat: ParamGrid, Tb_arr, gaps, n_steps: int,
                       kind: str, cfg, policy=None) -> dict:
    """Explicit-schedule engine dispatch over a flat grid: the grid axis
    is chunked/sharded by :mod:`.dispatch`, the trials axis streamed in
    memory-bounded blocks; returns numpy ``(B, n_trials)`` per key."""
    B = flat.size
    gaps = _as_f64_gaps(gaps)
    n_trials, cap = int(gaps.shape[-2]), int(gaps.shape[-1])
    ndev = _dispatch.effective_devices(cfg)
    tc = _trial_chunk(n_trials, cap, ndev, cfg)
    parts = []
    for t0 in range(0, n_trials, tc):
        g = gaps[:, t0:t0 + tc, :]
        parts.append(_dispatch.run(
            key=("explicit", int(n_steps), _kind_token(kind, policy)),
            build=_grid_fn(int(n_steps), kind, policy),
            args=(T_arr, flat.C, flat.R, flat.D, flat.omega, Tb_arr, g),
            in_axes=(0,) * 7, out_axes=0, size=B,
            per_point_bytes=8 * min(tc, n_trials) * (cap + 32),
            config=cfg))
    if len(parts) == 1:
        return parts[0]
    return {k: np.concatenate([p[k] for p in parts], axis=1)
            for k in parts[0]}


def _sampled_build(proc_fn, cap_sample: int, cap_used: int,
                   n_steps: int, kind: str, policy=None):
    """Fused sample-then-simulate chunk kernel (the auto-sampling path).

    Point ``i``/trial ``t`` draws its schedule from the folded key
    ``fold_in(fold_in(key, i), t)`` at the partition-independent
    ``cap_sample`` (the grid-wide max capacity) and slices to this
    bucket's ``cap_used`` — so bucketing, chunking, sharding, and trial
    blocking are all pure performance knobs for a fixed seed.  The
    ``(chunk, trials, cap)`` schedule tensor only ever exists inside this
    jitted call.  The pallas kind samples through the SAME folded keys
    and then hands the materialized chunk schedule to the blocked
    kernel, so its draws are bit-identical to the scan kinds'.
    """
    if kind == "pallas":
        run_grid = _grid_fn(n_steps, kind, policy)

        def build(T, C, R, D, omega, Tb, mean, idx, t_idx, key, *params):
            def sample_point(m, i, *pp):
                kp = jax.random.fold_in(key, i)

                def sample_trial(ti):
                    return proc_fn(jax.random.fold_in(kp, ti),
                                   (cap_sample,), m, pp)
                return jax.vmap(sample_trial)(t_idx)
            gaps = jax.vmap(sample_point)(mean, idx, *params)
            return run_grid(T, C, R, D, omega, Tb, gaps[:, :, :cap_used])
        return build
    kernel = _KERNELS[kind]

    def build(T, C, R, D, omega, Tb, mean, idx, t_idx, key, *params):
        def per_point(t, c, r, d, o, tb, m, i, *pp):
            kp = jax.random.fold_in(key, i)

            def per_trial(ti):
                kt = jax.random.fold_in(kp, ti)
                g = proc_fn(kt, (cap_sample,), m, pp)
                return kernel(t, c, r, d, o, tb, g[:cap_used], n_steps)
            return jax.vmap(per_trial)(t_idx)
        return jax.vmap(per_point)(T, C, R, D, omega, Tb, mean, idx,
                                   *params)
    return build


def _bulk_schedule(flat: ParamGrid, n_trials: int, capacity: int,
                   seed: int, process):
    """Whole-grid auto-sampled schedule for processes WITHOUT a traced
    sampler: bulk device sampling (``FailureProcess.sample_gaps``) when
    the process has it, host numpy otherwise — the compatibility tiers
    below the fused pointwise path."""
    try:
        return presample_gaps_device(flat, n_trials, capacity, seed=seed,
                                     process=process)
    except NotImplementedError:
        return presample_gaps(flat, n_trials, capacity, seed=seed,
                              process=process)


def _sampler_inputs(proc, flat: ParamGrid, seed: int):
    """(token, per-point parameter arrays, sampler fn, per-point means,
    global indices, base key) of the pointwise auto-sampling contract."""
    token, params, fn = proc.traced_sampler()
    size = flat.size
    mean_arr = np.broadcast_to(
        np.asarray(proc.resolve_mean(flat.mu), dtype=np.float64), (size,))
    params_b = tuple(np.broadcast_to(np.asarray(p, dtype=np.float64),
                                     (size,)) for p in params)
    idx_all = np.arange(size, dtype=np.uint32)
    with enable_x64():
        key = jax.random.PRNGKey(int(seed))
    return token, params_b, fn, mean_arr, idx_all, key


def _assemble_batch(out: dict, grid: ParamGrid, n_trials: int,
                    lead: tuple = ()) -> TrajectoryBatch:
    """Reshape flat engine outputs to ``lead + grid.shape + (n_trials,)``
    and attach the energy integral (``lead`` is the candidate axis of
    :func:`simulate_candidates`)."""
    shp = lead + grid.shape + (n_trials,)
    bc = lambda x: x.reshape((1,) * len(lead) + grid.shape + (1,))
    wall = out["wall_time"].reshape(shp)
    work = out["work_executed"].reshape(shp)
    io = out["io_time"].reshape(shp)
    down = out["down_time"].reshape(shp)
    energy = (bc(grid.P_static) * wall + bc(grid.P_cal) * work
              + bc(grid.P_io) * io + bc(grid.P_down) * down)
    return TrajectoryBatch(
        wall_time=wall, energy=energy, work_executed=work, io_time=io,
        down_time=down,
        n_failures=out["n_failures"].reshape(shp),
        n_checkpoints=out["n_checkpoints"].reshape(shp),
        truncated=out["truncated"].reshape(shp),
        gaps_exhausted=out["gaps_exhausted"].reshape(shp))


def simulate_trajectories(T, grid: ParamGrid, T_base: float = 1.0,
                          n_trials: int = 200, seed: int = 0,
                          gaps: Optional[np.ndarray] = None,
                          n_steps: Optional[int] = None,
                          process=None,
                          engine_kind: Optional[str] = None,
                          dispatch=None,
                          precision=None) -> TrajectoryBatch:
    """Simulate every (grid point x trial) trajectory in a few jitted calls.

    ``T`` broadcasts against ``grid.shape``.  ``gaps`` (grid.size, n_trials,
    F) overrides the pre-sampled failure schedule — pass the same schedule to
    the scalar oracle via :class:`ScheduledRNG` (or ``simulate_once(gaps=)``)
    for parity checks.  ``process`` (a
    :class:`repro.core.failures.FailureProcess`) selects the inter-failure
    distribution when the schedule is auto-sampled — on device via the
    process's jax sampler when it has one; the scans themselves are
    distribution-agnostic (they only consume gaps).

    ``engine_kind`` selects the kernel: ``"event"`` (default, one scan
    iteration per failure — the fast path), ``"pallas"`` (the
    accelerator-native Pallas port of the event kernel —
    ``kernels/event_sweep.py``; interpret mode off-TPU, precision per
    the resolved :class:`~repro.sim.precision.PrecisionPolicy`
    ``precision``, bit-identical to ``"event"`` under the f64 policy),
    or ``"step"`` (one iteration per phase event — the scalar oracle's
    bit-level twin, kept as a cross-check).  None defers to
    ``$REPRO_ENGINE_KIND`` and then ``"event"``.  When the schedule is
    auto-sampled, grid points are dispatched in power-of-two budget
    buckets so mixed-mu grids don't pay the worst point's scan length
    everywhere.

    Every jitted call routes through :mod:`repro.sim.dispatch`
    (``dispatch`` is its :class:`~repro.sim.dispatch.DispatchConfig`; None
    = environment defaults): the grid axis is sharded across the local
    devices and chunked to a device-memory budget, and the trials axis
    streams in memory-bounded blocks.  Auto-sampled schedules are drawn
    inside each chunk from per-(grid point, trial) folded keys at the
    grid-wide capacity, so sharding/chunking/budget knobs — like the
    budget-bucketing knobs above — never change a fixed seed's results.
    """
    engine_kind = resolve_engine_kind(engine_kind)
    flat = grid.ravel()
    T_arr = np.broadcast_to(np.asarray(T, dtype=np.float64),
                            grid.shape).ravel()
    Tb_arr = np.broadcast_to(np.asarray(T_base, dtype=np.float64),
                             grid.shape).ravel()
    if np.any(T_arr <= (1.0 - flat.omega) * flat.C):
        raise ValueError("period too short: no work progress per period")
    cfg = _dispatch.resolve(dispatch)
    pol = _engine_policy(engine_kind, cfg, precision)

    if gaps is not None:
        # Shared-schedule path (parity / CRN): one budget, grid chunked.
        gaps = _normalize_gaps(gaps, flat.size)
        n_trials = int(gaps.shape[-2])
        if n_steps is None:
            # The event kernel executes (#failures + 1 completion) steps,
            # and a schedule of F gaps admits at most F failures.
            n_steps = (_scan_len(gaps.shape[-1]) + 1
                       if engine_kind in _EVENT_LIKE else
                       default_step_budget(T_arr, flat, Tb_arr,
                                           process=process))
        else:
            n_steps = _scan_len(n_steps)
        out = _dispatch_explicit(T_arr, flat, Tb_arr, gaps, int(n_steps),
                                 engine_kind, cfg, policy=pol)
        return _assemble_batch(out, grid, n_trials)

    # Auto-sampled path: per-point budgets, one dispatch per pow2 bucket.
    # Point i / trial t samples its schedule from the folded key
    # fold_in(fold_in(PRNGKey(seed), i), t) at the grid-wide max capacity
    # (partition-independent), sliced to the bucket's capacity — the
    # randomness of a fixed seed depends only on (seed, process, capacity
    # estimate); n_steps, engine_kind, bucket membership, chunk size,
    # shard count, and memory budget never change the sampled failure
    # times.
    caps = fail_capacity_points(T_arr, flat, Tb_arr, process=process)
    if n_steps is not None:
        budgets = np.full(flat.size, _scan_len(n_steps), dtype=np.int64)
    elif engine_kind in _EVENT_LIKE:
        budgets = caps + 1
    else:
        budgets = step_budget_points(T_arr, flat, Tb_arr, process=process)
    cap_sample = int(np.max(caps))
    proc = as_process(process).ravel()
    try:
        token, params_b, proc_fn, mean_arr, idx_all, key = \
            _sampler_inputs(proc, flat, seed)
        g_full = None
    except NotImplementedError:
        # Processes without a traced-parameter sampler fall back to ONE
        # full-grid schedule at the max capacity, sliced per bucket (and
        # per chunk by the dispatcher) — the same partition-independent
        # contract as the fused path.  Prefer the bulk device sampler
        # (``sample_gaps`` — the PR-4 extension point custom processes
        # may already implement) so their draws stay on device; host
        # numpy is the last-resort gate.  Note the bulk tensor is
        # grid-wide, so the memory-bounded-chunking promise only holds
        # for processes with a traced sampler.
        g_full = _bulk_schedule(flat, n_trials, cap_sample, seed, process)

    ndev = _dispatch.effective_devices(cfg)
    tc = _trial_chunk(n_trials, cap_sample, ndev, cfg)
    acc: dict = {}
    for b in np.unique(budgets):
        idx = np.nonzero(budgets == b)[0]
        sub = ParamGrid(**{f: v[idx] for f, v in flat.fields().items()})
        cap = int(np.max(caps[idx]))
        if g_full is not None:
            with enable_x64():   # gathering a f64 device array needs x64
                g = g_full[idx, :, :cap]
            out = _dispatch_explicit(T_arr[idx], sub, Tb_arr[idx], g,
                                     int(b), engine_kind, cfg, policy=pol)
            _scatter(acc, out, flat.size, n_trials, idx, slice(None))
            continue
        for t0 in range(0, n_trials, tc):
            t_idx = np.arange(t0, min(t0 + tc, n_trials), dtype=np.uint32)
            out = _dispatch.run(
                key=("sampled", token, cap_sample, cap, int(b),
                     _kind_token(engine_kind, pol), len(params_b)),
                build=_sampled_build(proc_fn, cap_sample, cap, int(b),
                                     engine_kind, policy=pol),
                args=(T_arr[idx], sub.C, sub.R, sub.D, sub.omega,
                      Tb_arr[idx], mean_arr[idx], idx_all[idx], t_idx,
                      key) + tuple(p[idx] for p in params_b),
                in_axes=(0,) * 8 + (None, None) + (0,) * len(params_b),
                out_axes=0, size=len(idx),
                per_point_bytes=8 * len(t_idx) * (cap_sample + 32),
                config=cfg)
            _scatter(acc, out, flat.size, n_trials, idx,
                     slice(t0, t0 + len(t_idx)))
    return _assemble_batch(acc, grid, n_trials)


def _scatter(acc: dict, out: dict, size: int, n_trials: int, idx,
             t_slice) -> None:
    """Write one (bucket x trial-block) result into the full-grid
    accumulator (allocating it on first use)."""
    for k, v in out.items():
        if k not in acc:
            acc[k] = np.empty((size, n_trials), dtype=v.dtype)
        acc[k][idx, t_slice] = v


def _cand_sampled_build(proc_fn, cap_sample: int, n_steps: int, kind: str,
                        policy=None):
    """Fused sample-then-candidate-vmap chunk kernel: the schedule is
    drawn once per chunk from the pointwise folded keys and SHARED across
    the candidate axis (``in_axes=None``) — CRN by construction, never
    tiled, and partition-independent like :func:`_sampled_build`.  The
    pallas kind serializes the candidate axis with ``lax.map``
    (see :func:`_cand_fn`); the schedule is still drawn once."""
    run_grid = _grid_fn(n_steps, kind, policy)

    def build(T2, C, R, D, omega, Tb, mean, idx, t_idx, key, *params):
        def sample_point(m, i, *pp):
            kp = jax.random.fold_in(key, i)

            def sample_trial(ti):
                return proc_fn(jax.random.fold_in(kp, ti), (cap_sample,),
                               m, pp)
            return jax.vmap(sample_trial)(t_idx)
        gaps = jax.vmap(sample_point)(mean, idx, *params)
        if kind == "pallas":
            return lax.map(
                lambda t: run_grid(t, C, R, D, omega, Tb, gaps), T2)
        return jax.vmap(run_grid, in_axes=(0,) + (None,) * 6)(
            T2, C, R, D, omega, Tb, gaps)
    return build


def _cand_axis(M: int, B: int) -> str:
    """Which axis the candidate dispatch shards/chunks over: the grid
    axis normally; the candidate axis for single-point grids (the
    MCSurrogate shape, where the grid axis has nothing to split)."""
    return "cand" if B == 1 and M > 1 else "grid"


def simulate_candidates(T_cand, grid: ParamGrid, T_base: float = 1.0,
                        n_trials: int = 200, seed: int = 0,
                        gaps: Optional[np.ndarray] = None,
                        n_steps: Optional[int] = None, process=None,
                        engine_kind: Optional[str] = None,
                        dispatch=None,
                        precision=None) -> TrajectoryBatch:
    """Simulate M candidate periods against ONE shared set of failure
    schedules (the CRN solvers' hot path).

    ``T_cand`` has shape ``(M,) + grid.shape`` (or ``(M,)``, one period per
    candidate for the whole grid).  The candidate axis is a ``vmap`` with
    ``in_axes=None`` on the schedules and parameters — the big
    ``(B, n_trials, capacity)`` gap tensor is shared across candidates,
    never tiled, materialized M times, or re-transferred.  Outputs carry a
    leading ``(M,)`` axis over ``grid.shape + (n_trials,)``.

    With ``gaps=None`` one schedule set is auto-sampled (pointwise folded
    keys, device sampler when available) and shared by every candidate —
    common random numbers by construction.  Calls route through
    :mod:`repro.sim.dispatch` (sharding + memory-bounded chunking over
    the grid axis — or over the candidate axis for single-point grids,
    where the schedules are replicated instead of split); the dispatch
    knobs never change a fixed seed's results.
    """
    engine_kind = resolve_engine_kind(engine_kind)
    flat = grid.ravel()
    T2 = np.asarray(T_cand, dtype=np.float64)
    M = T2.shape[0]
    if T2.ndim == 1:
        T2 = T2.reshape((M,) + (1,) * max(len(grid.shape), 1))
    T2 = np.broadcast_to(T2, (M,) + grid.shape).reshape(M, flat.size)
    Tb_arr = np.broadcast_to(np.asarray(T_base, dtype=np.float64),
                             grid.shape).ravel()
    if np.any(T2 <= (1.0 - flat.omega) * flat.C):
        raise ValueError("period too short: no work progress per period")
    cfg = _dispatch.resolve(dispatch)
    pol = _engine_policy(engine_kind, cfg, precision)
    B = flat.size
    axis = _cand_axis(M, B)

    if gaps is None:
        cap = default_fail_capacity(T2, flat, Tb_arr, process=process)
        if n_steps is None:
            ns = (_scan_len(cap) + 1 if engine_kind in _EVENT_LIKE else
                  default_step_budget(T2, flat, Tb_arr, process=process))
        else:
            ns = _scan_len(n_steps)
        proc = as_process(process).ravel()
        try:
            token, params_b, proc_fn, mean_arr, idx_all, key = \
                _sampler_inputs(proc, flat, seed)
        except NotImplementedError:
            # sample_gaps-only processes keep their bulk device draws
            # (PR-4 contract); host numpy is the last-resort gate.
            gaps = _bulk_schedule(flat, n_trials, cap, seed, process)
        else:
            out = _dispatch_cands(
                ("cand_sampled", token, cap, int(ns),
                 _kind_token(engine_kind, pol), len(params_b)),
                _cand_sampled_build(proc_fn, cap, int(ns), engine_kind,
                                    policy=pol),
                T2, flat, Tb_arr, axis, cfg, n_trials, cap,
                sampler_args=(mean_arr, idx_all, key, params_b))
            return _assemble_batch(out, grid, n_trials, lead=(M,))

    gaps = _normalize_gaps(gaps, flat.size)
    n_trials = int(gaps.shape[-2])
    if n_steps is None:
        n_steps = (_scan_len(gaps.shape[-1]) + 1
                   if engine_kind in _EVENT_LIKE else
                   default_step_budget(T2, flat, Tb_arr, process=process))
    else:
        n_steps = _scan_len(n_steps)
    out = _dispatch_cands(
        ("cand_explicit", int(n_steps), _kind_token(engine_kind, pol)),
        _cand_fn(int(n_steps), engine_kind, policy=pol),
        T2, flat, Tb_arr, axis, cfg, n_trials, int(gaps.shape[-1]),
        gaps=gaps)
    return _assemble_batch(out, grid, n_trials, lead=(M,))


def _dispatch_cands(key, build, T2, flat: ParamGrid, Tb_arr, axis: str,
                    cfg, n_trials: int, cap: int, gaps=None,
                    sampler_args=None) -> dict:
    """Route a candidate-vmap runner through the dispatcher.

    ``axis="grid"`` shards/chunks the grid axis (candidate axis rides
    whole, schedules split with their grid points); ``axis="cand"``
    shards/chunks the candidate axis (schedules replicated — the B == 1
    solver shape).  The trials axis streams in memory-bounded blocks on
    both schedule paths (explicit schedules are sliced; auto-sampled
    blocks re-derive their per-(point, trial) folded keys, so blocking
    is bit-exact).
    """
    M, B = T2.shape
    ndev = _dispatch.effective_devices(cfg)
    grid_args = (flat.C, flat.R, flat.D, flat.omega, Tb_arr)
    sampled = sampler_args is not None
    if not sampled:
        gaps = _as_f64_gaps(gaps)
    # Trials stream in memory-bounded blocks on BOTH schedule paths.  On
    # the candidate axis the (B, trials-block, cap) schedule is
    # replicated per device (ndev-independent, hence the 1), so the
    # block length is what bounds it; on the grid axis each point owns
    # its schedule slice plus M candidates' worth of live carries.
    tc = _trial_chunk(n_trials,
                      B * cap if axis == "cand" else cap + 32 * M,
                      1 if axis == "cand" else ndev, cfg)
    parts = []
    for t0 in range(0, n_trials, tc):
        t1 = min(t0 + tc, n_trials)
        if sampled:
            mean_arr, idx_all, base_key, params_b = sampler_args
            t_idx = np.arange(t0, t1, dtype=np.uint32)
            args = (T2,) + grid_args + (mean_arr, idx_all, t_idx,
                                        base_key) + tuple(params_b)
            cand_axes = (0,) + (None,) * (len(args) - 1)
            grid_axes = ((1,) + (0,) * 5 + (0, 0, None, None)
                         + (0,) * len(params_b))
        else:
            args = (T2,) + grid_args + (gaps[:, t0:t1, :],)
            cand_axes = (0,) + (None,) * 6
            grid_axes = (1,) + (0,) * 6
        if axis == "cand":
            out = _dispatch.run(
                key=key + ("cand",), build=build, args=args,
                in_axes=cand_axes, out_axes=0, size=M,
                per_point_bytes=8 * B * (t1 - t0) * 48, config=cfg)
        else:
            out = _dispatch.run(
                key=key + ("grid",), build=build, args=args,
                in_axes=grid_axes, out_axes=1, size=B,
                per_point_bytes=8 * (t1 - t0) * (cap + 32 * M),
                config=cfg)
        parts.append(out)
    if len(parts) == 1:
        return parts[0]
    return {k: np.concatenate([p[k] for p in parts], axis=-1)
            for k in parts[0]}


# ---------------------------------------------------------------------------
# Multilevel (buddy + PFS) phase machine
# ---------------------------------------------------------------------------
#
# The superperiod structure: periods 0..m-2 end with a buddy checkpoint
# (cost C1, commits level 1), period m-1 with a deep checkpoint (cost C2,
# commits BOTH levels).  Each pre-sampled failure carries a boolean "hard"
# flag (buddy copy lost, probability q): a soft failure rolls back to the
# last committed level-1 state and resumes the period schedule where that
# commit left it; a hard failure rolls back to the last deep commit and
# restarts the superperiod at period 0 (re-executing the intermediate buddy
# checkpoints on the way — their I/O is naturally re-counted).
#
# With m = 1 and degenerate levels (C1=C2, R1=R2, D1=D2) every arithmetic
# expression below matches the single-level ``_run_one`` operation-for-
# operation, so the scalar ``simulate_once`` oracle is reproduced
# bit-for-bit — the parity tests rely on this.

@dataclasses.dataclass(frozen=True)
class MultilevelTrajectoryBatch:
    """Per-trajectory outputs, shape ``grid.shape + (n_trials,)``."""

    wall_time: np.ndarray
    energy: np.ndarray
    work_executed: np.ndarray
    io1_time: np.ndarray         # buddy-level I/O (writes + soft recoveries)
    io2_time: np.ndarray         # deep-level I/O (writes + hard recoveries)
    down_time: np.ndarray
    n_failures: np.ndarray
    n_hard_failures: np.ndarray
    n_ckpt1: np.ndarray          # committed buddy checkpoints
    n_ckpt2: np.ndarray          # committed deep checkpoints
    truncated: np.ndarray
    gaps_exhausted: np.ndarray


def _run_one_ml(T, m, C1, C2, R1, R2, D1, D2, omega1, omega2, T_base,
                gaps, hard, n_steps):
    """One two-level trajectory; ``hard[i]`` is the level-loss flag of the
    i-th failure.  Mirrors ``_run_one`` branch-for-branch.

    ``omega1``/``omega2`` are the per-level overlap rates (buddy write /
    deep flush).  The commit-at-end-of-checkpoint-phase semantics below
    ARE the hazard-during-flush model: work performed at rate ``omega2``
    during a deep write belongs to an uncommitted in-flight generation, so
    a failure inside the flush window rolls back to the previous surviving
    level and re-executes it.  With ``omega1 == omega2`` the select is
    value-transparent and the pre-async trajectories are reproduced
    bit-for-bit."""
    f64 = gaps.dtype
    n_gaps = gaps.shape[0]
    C_first = jnp.where(m > 1, C1, C2)      # period 0 is deep only when m=1

    init = (jnp.zeros((), f64),            # wall
            jnp.zeros((), f64),            # committed1
            jnp.zeros((), f64),            # committed2
            jnp.zeros((), f64),            # live
            jnp.zeros((), f64),            # work_exec
            jnp.zeros((), f64),            # io1_time
            jnp.zeros((), f64),            # io2_time
            jnp.zeros((), f64),            # down_time
            gaps[0],                       # next_fail
            T - C_first,                   # phase_left
            jnp.zeros((), f64),            # snapshot
            jnp.zeros((), jnp.int32),      # phase = COMPUTE
            jnp.zeros((), jnp.int32),      # k: period index in superperiod
            jnp.zeros((), jnp.int32),      # resume_k: soft-rollback restart
            jnp.zeros((), jnp.int32),      # n_fail
            jnp.zeros((), jnp.int32),      # n_hard
            jnp.zeros((), jnp.int32),      # n_ckpt1
            jnp.zeros((), jnp.int32),      # n_ckpt2
            jnp.ones((), jnp.int32),       # fail_idx (gaps[0] consumed)
            jnp.zeros((), jnp.bool_))      # done

    def step(carry, _):
        (wall, committed1, committed2, live, work_exec, io1_time, io2_time,
         down_time, next_fail, phase_left, snapshot, phase, k, resume_k,
         n_fail, n_hard, n_ckpt1, n_ckpt2, fail_idx, done) = carry

        is_deep = k == m - 1
        Ck = jnp.where(is_deep, C2, C1)
        in_ckpt = phase == CHECKPOINT
        omega_k = jnp.where(is_deep, omega2, omega1)
        rate = jnp.where(in_ckpt, omega_k, 1.0)
        t_done = jnp.where(rate > 0.0,
                           (T_base - live) / jnp.where(rate > 0.0, rate, 1.0),
                           jnp.inf)
        t_next = jnp.minimum(phase_left, t_done)
        no_fail = wall + t_next < next_fail

        # ---- branch A: the phase segment completes without failure ----
        wall_a = wall + t_next
        live_a = live + rate * t_next
        work_a = work_exec + rate * t_next
        io1_a = io1_time + jnp.where(in_ckpt & ~is_deep, t_next, 0.0)
        io2_a = io2_time + jnp.where(in_ckpt & is_deep, t_next, 0.0)
        left_a = phase_left - t_next
        finished = live_a >= T_base - _EPS
        boundary = jnp.logical_and(~finished, left_a <= _EPS)
        start_ckpt = jnp.logical_and(boundary, ~in_ckpt)
        end_ckpt = jnp.logical_and(boundary, in_ckpt)
        phase_a = jnp.where(start_ckpt, CHECKPOINT,
                            jnp.where(end_ckpt, COMPUTE, phase))
        k_next = jnp.where(k + 1 >= m, 0, k + 1)
        C_next = jnp.where(k_next == m - 1, C2, C1)
        left_a = jnp.where(start_ckpt, Ck,
                           jnp.where(end_ckpt, T - C_next, left_a))
        snapshot_a = jnp.where(start_ckpt, live_a, snapshot)
        committed1_a = jnp.where(end_ckpt, snapshot, committed1)
        committed2_a = jnp.where(jnp.logical_and(end_ckpt, is_deep),
                                 snapshot, committed2)
        k_a = jnp.where(end_ckpt, k_next, k)
        resume_k_a = jnp.where(end_ckpt, k_next, resume_k)
        n_ckpt1_a = n_ckpt1 + jnp.logical_and(end_ckpt,
                                              ~is_deep).astype(jnp.int32)
        n_ckpt2_a = n_ckpt2 + jnp.logical_and(end_ckpt,
                                              is_deep).astype(jnp.int32)

        # ---- branch B: a failure strikes mid-segment ----
        hard_f = hard[jnp.minimum(n_fail, n_gaps - 1)]
        dt = next_fail - wall
        work_b = work_exec + rate * dt
        io1_b = io1_time + jnp.where(in_ckpt & ~is_deep, dt, 0.0) \
            + jnp.where(hard_f, 0.0, R1)
        io2_b = io2_time + jnp.where(in_ckpt & is_deep, dt, 0.0) \
            + jnp.where(hard_f, R2, 0.0)
        D_sel = jnp.where(hard_f, D2, D1)
        R_sel = jnp.where(hard_f, R2, R1)
        wall_b = next_fail + D_sel + R_sel
        down_b = down_time + D_sel
        gap = jnp.where(fail_idx < n_gaps,
                        gaps[jnp.minimum(fail_idx, n_gaps - 1)], jnp.inf)
        next_fail_b = wall_b + gap
        committed1_b = jnp.where(hard_f, committed2, committed1)
        k_b = jnp.where(hard_f, 0, resume_k)
        left_b = T - jnp.where(k_b == m - 1, C2, C1)

        def sel(a_val, b_val):
            return jnp.where(no_fail, a_val, b_val)

        new = (sel(wall_a, wall_b),
               sel(committed1_a, committed1_b),
               sel(committed2_a, committed2),
               sel(live_a, committed1_b),      # rollback to surviving level
               sel(work_a, work_b),
               sel(io1_a, io1_b),
               sel(io2_a, io2_b),
               sel(down_time, down_b),
               sel(next_fail, next_fail_b),
               sel(left_a, left_b),
               sel(snapshot_a, snapshot),
               sel(phase_a, COMPUTE).astype(jnp.int32),
               sel(k_a, k_b).astype(jnp.int32),
               sel(resume_k_a, k_b).astype(jnp.int32),
               sel(n_fail, n_fail + 1).astype(jnp.int32),
               sel(n_hard, n_hard + hard_f.astype(jnp.int32)
                   ).astype(jnp.int32),
               sel(n_ckpt1_a, n_ckpt1).astype(jnp.int32),
               sel(n_ckpt2_a, n_ckpt2).astype(jnp.int32),
               sel(fail_idx, fail_idx + 1).astype(jnp.int32),
               jnp.logical_or(done, jnp.logical_and(no_fail, finished)))

        keep = lambda old, upd: jnp.where(done, old, upd)
        return tuple(keep(o, u) for o, u in zip(carry, new)), None

    final, _ = lax.scan(step, init, None, length=n_steps)
    (wall, _c1, _c2, _live, work_exec, io1_time, io2_time, down_time,
     _nf, _pl, _snap, _phase, _k, _rk, n_fail, n_hard, n_ckpt1, n_ckpt2,
     fail_idx, done) = final
    return {"wall_time": wall, "work_executed": work_exec,
            "io1_time": io1_time, "io2_time": io2_time,
            "down_time": down_time, "n_failures": n_fail,
            "n_hard_failures": n_hard, "n_ckpt1": n_ckpt1,
            "n_ckpt2": n_ckpt2, "truncated": ~done,
            "gaps_exhausted": fail_idx > n_gaps}


def _make_runner_ml(n_steps: int):
    def run_grid(T, m, C1, C2, R1, R2, D1, D2, omega1, omega2, T_base,
                 gaps, hard):
        def one(t, mm, c1, c2, r1, r2, d1, d2, o1, o2, tb, g, h):
            return _run_one_ml(t, mm, c1, c2, r1, r2, d1, d2, o1, o2, tb,
                               g, h, n_steps)
        over_trials = jax.vmap(one, in_axes=(None,) * 11 + (0, 0))
        over_grid = jax.vmap(over_trials, in_axes=(0,) * 11 + (0, 0))
        return over_grid(T, m, C1, C2, R1, R2, D1, D2, omega1, omega2,
                         T_base, gaps, hard)
    return jax.jit(run_grid)


#: multilevel runners, LRU-bounded like every other compiled-callable
#: cache in this module (eviction recompiles, never changes results).
_ML_RUNNERS = _dispatch.LRUCache(_dispatch.RUNNER_CACHE_SIZE,
                                 name="engine.ml_runners")


def _runner_ml(n_steps: int):
    fn = _ML_RUNNERS.get(n_steps)
    if fn is None:
        fn = _make_runner_ml(n_steps)
        _ML_RUNNERS.put(n_steps, fn)
    return fn


def _expected_failures_ml(T, m, grid: MultilevelParamGrid,
                          T_base) -> np.ndarray:
    """E[#failures] from the two-level closed form, clipped like the
    single-level estimator."""
    a, b, mu_m = grid.a(m), grid.b(m), grid.mu_eff(m)
    denom = (T - a) * (b - T / (2.0 * mu_m))
    with np.errstate(divide="ignore", invalid="ignore"):
        tf = np.where(denom > 1e-12, T_base * T / denom, np.inf)
    tf = np.where(np.isfinite(tf) & (tf > 0), tf, 50.0 * T_base)
    return tf / grid.mu


def default_fail_capacity_ml(T, m, grid: MultilevelParamGrid, T_base) -> int:
    """Pre-sampled failures per trajectory: mean + 10 sigma margin."""
    nf = _expected_failures_ml(T, m, grid, T_base)
    return int(np.max(np.ceil(nf + 10.0 * np.sqrt(nf + 1.0) + 10.0)))


def default_step_budget_ml(T, m, grid: MultilevelParamGrid, T_base) -> int:
    """Scan length: a hard failure re-executes up to a whole superperiod
    (m periods, 2 events each), so the per-failure margin scales with m."""
    work_per_period = np.maximum(T - grid.a(m), 1e-9)
    periods = T_base / work_per_period
    nf = _expected_failures_ml(T, m, grid, T_base)
    per_fail = 2.0 * np.maximum(m * T / work_per_period, 1.0) + 4.0
    events = 2.0 * periods + 2.0 + nf * per_fail
    margin = 10.0 * np.sqrt(nf + 1.0) * per_fail
    return int(np.max(np.ceil(2.0 * events + margin + 64.0)))


def presample_failures(grid: MultilevelParamGrid, n_trials: int,
                       capacity: int, seed: int = 0):
    """(gaps, hard): exponential(mu) inter-failure gaps and Bernoulli(q)
    level-loss flags, each of shape ``(B, n_trials, capacity)``."""
    rng = np.random.default_rng(seed)
    flat = grid.ravel()
    mu = flat.mu[:, None, None]
    gaps = rng.exponential(scale=mu, size=(grid.size, n_trials, capacity))
    hard = rng.random(size=(grid.size, n_trials, capacity)) \
        < flat.q[:, None, None]
    return gaps, hard


def _broadcast_schedule(arr, size, dtype):
    arr = np.asarray(arr, dtype=dtype)
    if arr.ndim == 1:
        arr = arr[None, None, :]
    if arr.ndim == 2:
        arr = arr[None, :, :]
    return np.broadcast_to(arr, (size, arr.shape[-2], arr.shape[-1]))


def simulate_trajectories_ml(T, m, grid: MultilevelParamGrid,
                             T_base: float = 1.0, n_trials: int = 200,
                             seed: int = 0,
                             gaps: Optional[np.ndarray] = None,
                             hard: Optional[np.ndarray] = None,
                             n_steps: Optional[int] = None,
                             ) -> MultilevelTrajectoryBatch:
    """Simulate every two-level (grid point x trial) trajectory in one
    jitted call.  ``T`` and ``m`` broadcast against ``grid.shape``; ``gaps``
    and ``hard`` override the pre-sampled failure schedule (pass the same
    gaps to the scalar oracle via :class:`ScheduledRNG` for parity checks).
    """
    flat = grid.ravel()
    T_arr = np.broadcast_to(np.asarray(T, dtype=np.float64),
                            grid.shape).ravel()
    m_arr = np.broadcast_to(np.asarray(m, dtype=np.int32),
                            grid.shape).ravel()
    Tb_arr = np.broadcast_to(np.asarray(T_base, dtype=np.float64),
                             grid.shape).ravel()
    if np.any(m_arr < 1):
        raise ValueError("deep-checkpoint cadence m must be >= 1")
    if np.any(T_arr < np.maximum(flat.C1, flat.C2)):
        raise ValueError("period too short: T must cover the checkpoint")
    if np.any(T_arr <= flat.a(m_arr)):
        raise ValueError("period too short: no work progress per period")

    if gaps is None or hard is None:
        cap = default_fail_capacity_ml(T_arr, m_arr, flat, Tb_arr)
        g, h = presample_failures(flat, n_trials, cap, seed=seed)
        gaps = g if gaps is None else gaps
        hard = h if hard is None else hard
    gaps = _broadcast_schedule(gaps, flat.size, np.float64)
    hard = _broadcast_schedule(hard, flat.size, np.bool_)
    if gaps.shape != hard.shape:
        raise ValueError(f"gaps {gaps.shape} and hard flags {hard.shape} "
                         f"schedules disagree")
    n_trials = gaps.shape[-2]
    if n_steps is None:
        n_steps = default_step_budget_ml(T_arr, m_arr, flat, Tb_arr)
    n_steps = 1 << (max(int(n_steps), 1) - 1).bit_length()

    with enable_x64():
        f64 = jnp.float64
        out = _runner_ml(int(n_steps))(
            jnp.asarray(T_arr, dtype=f64),
            jnp.asarray(m_arr, dtype=jnp.int32),
            jnp.asarray(flat.C1, dtype=f64),
            jnp.asarray(flat.C2, dtype=f64),
            jnp.asarray(flat.R1, dtype=f64),
            jnp.asarray(flat.R2, dtype=f64),
            jnp.asarray(flat.D1, dtype=f64),
            jnp.asarray(flat.D2, dtype=f64),
            jnp.asarray(flat.omega1, dtype=f64),
            jnp.asarray(flat.omega2, dtype=f64),
            jnp.asarray(Tb_arr, dtype=f64),
            jnp.asarray(gaps, dtype=f64),
            jnp.asarray(hard, dtype=jnp.bool_))
        out = {k: np.asarray(v) for k, v in out.items()}

    shp = grid.shape + (n_trials,)
    bc = lambda x: x.reshape(grid.shape + (1,))
    wall = out["wall_time"].reshape(shp)
    work = out["work_executed"].reshape(shp)
    io1 = out["io1_time"].reshape(shp)
    io2 = out["io2_time"].reshape(shp)
    down = out["down_time"].reshape(shp)
    energy = (bc(grid.P_static) * wall + bc(grid.P_cal) * work
              + bc(grid.P_io1) * io1 + bc(grid.P_io2) * io2
              + bc(grid.P_down) * down)
    return MultilevelTrajectoryBatch(
        wall_time=wall, energy=energy, work_executed=work,
        io1_time=io1, io2_time=io2, down_time=down,
        n_failures=out["n_failures"].reshape(shp),
        n_hard_failures=out["n_hard_failures"].reshape(shp),
        n_ckpt1=out["n_ckpt1"].reshape(shp),
        n_ckpt2=out["n_ckpt2"].reshape(shp),
        truncated=out["truncated"].reshape(shp),
        gaps_exhausted=out["gaps_exhausted"].reshape(shp))


def simulate_grid_ml(T, m, grid: MultilevelParamGrid, T_base: float = 1.0,
                     n_trials: int = 200, seed: int = 0,
                     gaps: Optional[np.ndarray] = None,
                     hard: Optional[np.ndarray] = None,
                     n_steps: Optional[int] = None) -> dict:
    """Mean/SE summaries of the two-level Monte-Carlo (validates the
    multilevel closed forms; raises on truncation/schedule exhaustion)."""
    tb = simulate_trajectories_ml(T, m, grid, T_base, n_trials=n_trials,
                                  seed=seed, gaps=gaps, hard=hard,
                                  n_steps=n_steps)
    if np.any(tb.truncated):
        raise RuntimeError(
            f"{int(tb.truncated.sum())} trajectories exceeded the scan "
            f"budget; pass a larger n_steps (check params)")
    if np.any(tb.gaps_exhausted):
        raise RuntimeError(
            f"{int(tb.gaps_exhausted.sum())} trajectories exhausted their "
            f"failure schedule (tail simulated failure-free); pass gaps/"
            f"hard arrays with larger capacity")
    out = {}
    n = tb.wall_time.shape[-1]
    for key, arr in (("T_final", tb.wall_time), ("E_final", tb.energy),
                     ("T_cal", tb.work_executed), ("T_io1", tb.io1_time),
                     ("T_io2", tb.io2_time), ("T_down", tb.down_time),
                     ("n_failures", tb.n_failures.astype(np.float64)),
                     ("n_hard", tb.n_hard_failures.astype(np.float64))):
        out[key] = arr.mean(axis=-1)
        out[key + "_se"] = arr.std(axis=-1, ddof=1) / math.sqrt(n)
    return out


def simulate_grid(T, grid: ParamGrid, T_base: float = 1.0,
                  n_trials: int = 200, seed: int = 0,
                  gaps: Optional[np.ndarray] = None,
                  n_steps: Optional[int] = None,
                  process=None) -> dict:
    """Batched analogue of ``core.simulator.simulate``: mean/SE summaries.

    Returns a dict of arrays of ``grid.shape`` with the same keys as the
    scalar ``simulate`` ("T_final", "T_final_se", "E_final", ...).
    """
    tb = simulate_trajectories(T, grid, T_base, n_trials=n_trials, seed=seed,
                               gaps=gaps, n_steps=n_steps, process=process)
    if np.any(tb.truncated):
        raise RuntimeError(
            f"{int(tb.truncated.sum())} trajectories exceeded the scan "
            f"budget; pass a larger n_steps (check params)")
    if np.any(tb.gaps_exhausted):
        raise RuntimeError(
            f"{int(tb.gaps_exhausted.sum())} trajectories exhausted their "
            f"failure schedule (tail simulated failure-free); pass a gaps "
            f"array with larger capacity")
    out = {}
    n = tb.wall_time.shape[-1]
    for key, arr in (("T_final", tb.wall_time), ("E_final", tb.energy),
                     ("T_cal", tb.work_executed), ("T_io", tb.io_time),
                     ("T_down", tb.down_time),
                     ("n_failures", tb.n_failures.astype(np.float64))):
        out[key] = arr.mean(axis=-1)
        out[key + "_se"] = arr.std(axis=-1, ddof=1) / math.sqrt(n)
    return out
