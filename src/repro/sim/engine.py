"""Batched Monte-Carlo trajectory engine (``jax.lax.scan`` phase machine).

The scalar event loop of ``repro.core.simulator.simulate_once`` rewritten as
a fixed-shape scan so it can be ``vmap``-ed over trials and again over
parameter batches, and jitted in float64 (under the local ``enable_x64``
context — global JAX dtype state is untouched).

Scan-state layout (one trajectory; all scalars):

    wall        f64  wall-clock time
    committed   f64  work protected by the last COMPLETED checkpoint
    live        f64  work executed since the last rollback point
    work_exec   f64  total CPU work executed (incl. re-execution)
    io_time     f64  cumulative I/O-active time (ckpt writes + recoveries)
    down_time   f64  cumulative downtime
    next_fail   f64  absolute time of the next failure
    phase_left  f64  time remaining in the current phase
    snapshot    f64  work value being written by the in-flight checkpoint
    phase       i32  0 = compute (rate 1), 1 = checkpoint (rate omega)
    n_fail      i32  failures so far
    n_ckpt      i32  committed checkpoints so far
    fail_idx    i32  next index into the pre-sampled failure-gap array
    done        bool trajectory reached T_base work

One scan step processes one *event* (phase-segment completion or failure),
mirroring the scalar loop body branch-for-branch; steps after ``done`` are
no-ops.  Checkpoint-commit semantics follow the paper: a checkpoint commits
the state as of the *beginning* of its phase, so the omega*C work done
concurrently is only protected by the NEXT completed checkpoint.

Failure times are consumed from a per-trajectory array of exponential gaps
(pre-sampled outside the scan).  Feeding the same gaps to the scalar oracle
via :class:`ScheduledRNG` reproduces trajectories bit-for-bit — the parity
tests rely on this.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

try:  # newer jax re-exports the x64 context at top level
    from jax import enable_x64
except ImportError:
    from jax.experimental import enable_x64

from .scenarios import MultilevelParamGrid, ParamGrid

COMPUTE, CHECKPOINT = 0, 1

#: work-completion slack, identical to the scalar simulator's epsilon.
_EPS = 1e-12


class ScheduledRNG:
    """np.random.Generator stand-in replaying a fixed gap schedule.

    ``simulate_once(..., rng=ScheduledRNG(gaps))`` consumes exactly the
    pre-sampled inter-failure gaps the batched engine was given, enabling
    trajectory-for-trajectory parity checks — for *any* distribution the
    gaps were drawn from, since the schedule replays verbatim.

    Contract: the ``scale`` argument of :meth:`exponential` is deliberately
    **ignored** — the replayed gaps are already in wall-clock units (they
    were pre-scaled when sampled), and re-scaling them here would silently
    double-apply mu.  On exhaustion the draw is ``inf`` ("no more
    failures") and :attr:`exhausted` is set; the scalar simulator raises on
    that flag, mirroring the batched engine's ``gaps_exhausted`` error.
    """

    #: marks this rng as a schedule replay for ``core.simulator`` dispatch.
    replays_schedule = True

    def __init__(self, gaps):
        self._gaps = [float(g) for g in np.asarray(gaps).ravel()]
        self._i = 0
        self.exhausted = False

    def exponential(self, scale: float = 1.0) -> float:
        if self._i >= len(self._gaps):
            self.exhausted = True
            return math.inf          # schedule exhausted: no more failures
        g = self._gaps[self._i]
        self._i += 1
        return g


@dataclasses.dataclass(frozen=True)
class TrajectoryBatch:
    """Per-trajectory outputs, shape ``grid.shape + (n_trials,)``."""

    wall_time: np.ndarray        # paper's T_final
    energy: np.ndarray           # paper's E_final
    work_executed: np.ndarray    # paper's T_cal
    io_time: np.ndarray          # paper's T_io
    down_time: np.ndarray        # paper's T_down
    n_failures: np.ndarray
    n_checkpoints: np.ndarray
    truncated: np.ndarray        # scan budget exhausted before completion
    gaps_exhausted: np.ndarray   # failure schedule ran dry (tail simulated
                                 # as failure-free -> potentially biased)


def _run_one(T, C, R, D, omega, T_base, gaps, n_steps):
    """One trajectory: scalar parameter tracers + a (F,) gap vector.

    Failure times come entirely from ``gaps`` (pre-sampled with scale mu
    outside the scan), so mu itself never enters the kernel.
    """
    f64 = gaps.dtype
    n_gaps = gaps.shape[0]

    init = (jnp.zeros((), f64),            # wall
            jnp.zeros((), f64),            # committed
            jnp.zeros((), f64),            # live
            jnp.zeros((), f64),            # work_exec
            jnp.zeros((), f64),            # io_time
            jnp.zeros((), f64),            # down_time
            gaps[0],                       # next_fail
            T - C,                         # phase_left
            jnp.zeros((), f64),            # snapshot
            jnp.zeros((), jnp.int32),      # phase = COMPUTE
            jnp.zeros((), jnp.int32),      # n_fail
            jnp.zeros((), jnp.int32),      # n_ckpt
            jnp.ones((), jnp.int32),       # fail_idx (gaps[0] consumed)
            jnp.zeros((), jnp.bool_))      # done

    def step(carry, _):
        (wall, committed, live, work_exec, io_time, down_time,
         next_fail, phase_left, snapshot, phase,
         n_fail, n_ckpt, fail_idx, done) = carry

        in_ckpt = phase == CHECKPOINT
        rate = jnp.where(in_ckpt, omega, 1.0)
        t_done = jnp.where(rate > 0.0,
                           (T_base - live) / jnp.where(rate > 0.0, rate, 1.0),
                           jnp.inf)
        t_next = jnp.minimum(phase_left, t_done)
        no_fail = wall + t_next < next_fail

        # ---- branch A: the phase segment completes without failure ----
        wall_a = wall + t_next
        live_a = live + rate * t_next
        work_a = work_exec + rate * t_next
        io_a = io_time + jnp.where(in_ckpt, t_next, 0.0)
        left_a = phase_left - t_next
        finished = live_a >= T_base - _EPS
        boundary = jnp.logical_and(~finished, left_a <= _EPS)
        start_ckpt = jnp.logical_and(boundary, ~in_ckpt)
        end_ckpt = jnp.logical_and(boundary, in_ckpt)
        phase_a = jnp.where(start_ckpt, CHECKPOINT,
                            jnp.where(end_ckpt, COMPUTE, phase))
        left_a = jnp.where(start_ckpt, C, jnp.where(end_ckpt, T - C, left_a))
        snapshot_a = jnp.where(start_ckpt, live_a, snapshot)
        committed_a = jnp.where(end_ckpt, snapshot, committed)
        n_ckpt_a = n_ckpt + end_ckpt.astype(jnp.int32)

        # ---- branch B: a failure strikes mid-segment ----
        dt = next_fail - wall
        work_b = work_exec + rate * dt
        io_b = io_time + jnp.where(in_ckpt, dt, 0.0) + R
        wall_b = next_fail + D + R
        down_b = down_time + D
        gap = jnp.where(fail_idx < n_gaps,
                        gaps[jnp.minimum(fail_idx, n_gaps - 1)], jnp.inf)
        next_fail_b = wall_b + gap

        def sel(a_val, b_val):
            return jnp.where(no_fail, a_val, b_val)

        new = (sel(wall_a, wall_b),
               sel(committed_a, committed),
               sel(live_a, committed),          # failure rolls back to commit
               sel(work_a, work_b),
               sel(io_a, io_b),
               sel(down_time, down_b),
               sel(next_fail, next_fail_b),
               sel(left_a, T - C),
               sel(snapshot_a, snapshot),
               sel(phase_a, COMPUTE).astype(jnp.int32),
               sel(n_fail, n_fail + 1).astype(jnp.int32),
               sel(n_ckpt_a, n_ckpt).astype(jnp.int32),
               sel(fail_idx, fail_idx + 1).astype(jnp.int32),
               jnp.logical_or(done, jnp.logical_and(no_fail, finished)))

        keep = lambda old, upd: jnp.where(done, old, upd)
        return tuple(keep(o, u) for o, u in zip(carry, new)), None

    final, _ = lax.scan(step, init, None, length=n_steps)
    (wall, _committed, _live, work_exec, io_time, down_time,
     _nf, _pl, _snap, _phase, n_fail, n_ckpt, fail_idx, done) = final
    return {"wall_time": wall, "work_executed": work_exec,
            "io_time": io_time, "down_time": down_time,
            "n_failures": n_fail, "n_checkpoints": n_ckpt,
            "truncated": ~done,
            # fail_idx > n_gaps means an inf gap was drawn at some point,
            # i.e. part of the trajectory ran under "no more failures".
            "gaps_exhausted": fail_idx > n_gaps}


def _make_runner(n_steps: int):
    def run_grid(T, C, R, D, omega, T_base, gaps):
        def one(t, c, r, d, o, tb, g):
            return _run_one(t, c, r, d, o, tb, g, n_steps)
        over_trials = jax.vmap(one, in_axes=(None,) * 6 + (0,))
        over_grid = jax.vmap(over_trials, in_axes=(0,) * 6 + (0,))
        return over_grid(T, C, R, D, omega, T_base, gaps)
    return jax.jit(run_grid)


_RUNNERS: dict = {}


def _runner(n_steps: int):
    if n_steps not in _RUNNERS:
        _RUNNERS[n_steps] = _make_runner(n_steps)
    return _RUNNERS[n_steps]


# ---------------------------------------------------------------------------
# Budget estimation
# ---------------------------------------------------------------------------

def _expected_failures(T, grid: ParamGrid, T_base) -> np.ndarray:
    """E[#failures] from the closed-form model, clipped to be usable even
    slightly outside the model's validity range."""
    a, b = grid.a, grid.b
    denom = (T - a) * (b - T / (2.0 * grid.mu))
    with np.errstate(divide="ignore", invalid="ignore"):
        tf = np.where(denom > 1e-12, T_base * T / denom, np.inf)
    # Divergent/degenerate points: fall back to a crude geometric bound.
    tf = np.where(np.isfinite(tf) & (tf > 0), tf, 50.0 * T_base)
    return tf / grid.mu


def _process_cv(process) -> float:
    """Worst-case gap coefficient of variation of a failure process (1.0
    for exponential / None) — scales the schedule-size safety margins."""
    if process is None:
        return 1.0
    return float(np.max(np.asarray(process.gap_cv(), dtype=np.float64)))


def default_fail_capacity(T, grid: ParamGrid, T_base,
                          process=None) -> int:
    """Pre-sampled gaps per trajectory: mean + 10 sigma margin.

    For non-exponential processes both the expected count (clustered short
    gaps inflate rollbacks, hence wall time) and the count fluctuation
    (renewal CLT: var ~ nf * cv^2) scale with the gap CV.
    """
    cv = max(1.0, _process_cv(process))
    nf = _expected_failures(T, grid, T_base) * cv * cv
    return int(np.max(np.ceil(nf + 10.0 * cv * np.sqrt(nf + 1.0) + 10.0)))


def default_step_budget(T, grid: ParamGrid, T_base, process=None) -> int:
    """Scan length: expected events with a 2x + fluctuation margin."""
    cv = max(1.0, _process_cv(process))
    work_per_period = np.maximum(T - grid.a, 1e-9)
    periods = T_base / work_per_period
    nf = _expected_failures(T, grid, T_base) * cv * cv
    # Each failure costs one event plus re-execution of at most one period
    # of work (2 phase events per period, +2 for the partial segments).
    per_fail = 2.0 * np.maximum(T / work_per_period, 1.0) + 4.0
    events = 2.0 * periods + 2.0 + nf * per_fail
    margin = 10.0 * cv * np.sqrt(nf + 1.0) * per_fail
    return int(np.max(np.ceil(2.0 * events + margin + 64.0)))


def presample_gaps(grid: ParamGrid, n_trials: int, capacity: int,
                   seed: int = 0, process=None) -> np.ndarray:
    """Inter-failure gaps, shape ``(B, n_trials, capacity)``.

    ``process`` selects the distribution (None = exponential; an
    ``Exponential()`` instance reproduces the None path bit-for-bit).  The
    process's own mean, if unset, is the grid's per-point mu; array-valued
    shape parameters broadcast over the raveled grid (``process.ravel()``
    is applied to match ``grid.ravel()``).
    """
    rng = np.random.default_rng(seed)
    mu = grid.ravel().mu[:, None, None]
    size = (grid.size, n_trials, capacity)
    if process is None:
        return rng.exponential(scale=mu, size=size)
    return np.asarray(process.ravel().sample(rng, size=size, mean=mu),
                      dtype=np.float64)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def simulate_trajectories(T, grid: ParamGrid, T_base: float = 1.0,
                          n_trials: int = 200, seed: int = 0,
                          gaps: Optional[np.ndarray] = None,
                          n_steps: Optional[int] = None,
                          process=None) -> TrajectoryBatch:
    """Simulate every (grid point x trial) trajectory in one jitted call.

    ``T`` broadcasts against ``grid.shape``.  ``gaps`` (grid.size, n_trials,
    F) overrides the pre-sampled failure schedule — pass the same schedule to
    the scalar oracle via :class:`ScheduledRNG` for parity checks.
    ``process`` (a :class:`repro.core.failures.FailureProcess`) selects the
    inter-failure distribution when the schedule is auto-sampled; the scan
    itself is distribution-agnostic (it only consumes gaps).
    """
    flat = grid.ravel()
    T_arr = np.broadcast_to(np.asarray(T, dtype=np.float64),
                            grid.shape).ravel()
    Tb_arr = np.broadcast_to(np.asarray(T_base, dtype=np.float64),
                             grid.shape).ravel()
    if np.any(T_arr <= (1.0 - flat.omega) * flat.C):
        raise ValueError("period too short: no work progress per period")

    if gaps is None:
        cap = default_fail_capacity(T_arr, flat, Tb_arr, process=process)
        gaps = presample_gaps(flat, n_trials, cap, seed=seed,
                              process=process)
    else:
        gaps = np.asarray(gaps, dtype=np.float64)
        if gaps.ndim == 1:
            gaps = gaps[None, None, :]
        if gaps.ndim == 2:
            gaps = gaps[None, :, :]
        want = (flat.size, gaps.shape[-2], gaps.shape[-1])
        gaps = np.broadcast_to(gaps, want)
        n_trials = gaps.shape[-2]
    if n_steps is None:
        n_steps = default_step_budget(T_arr, flat, Tb_arr, process=process)
    # Round the (static) scan length up to a power of two: extra steps are
    # no-ops, and bucketing keeps the jit cache at O(log) distinct programs
    # instead of one recompile per distinct parameter set.
    n_steps = 1 << (max(int(n_steps), 1) - 1).bit_length()

    with enable_x64():
        out = _runner(int(n_steps))(
            jnp.asarray(T_arr), jnp.asarray(flat.C), jnp.asarray(flat.R),
            jnp.asarray(flat.D), jnp.asarray(flat.omega),
            jnp.asarray(Tb_arr), jnp.asarray(gaps))
        out = {k: np.asarray(v) for k, v in out.items()}

    shp = grid.shape + (n_trials,)
    bc = lambda x: x.reshape(grid.shape + (1,))
    wall = out["wall_time"].reshape(shp)
    work = out["work_executed"].reshape(shp)
    io = out["io_time"].reshape(shp)
    down = out["down_time"].reshape(shp)
    energy = (bc(grid.P_static) * wall + bc(grid.P_cal) * work
              + bc(grid.P_io) * io + bc(grid.P_down) * down)
    return TrajectoryBatch(
        wall_time=wall, energy=energy, work_executed=work, io_time=io,
        down_time=down,
        n_failures=out["n_failures"].reshape(shp),
        n_checkpoints=out["n_checkpoints"].reshape(shp),
        truncated=out["truncated"].reshape(shp),
        gaps_exhausted=out["gaps_exhausted"].reshape(shp))


# ---------------------------------------------------------------------------
# Multilevel (buddy + PFS) phase machine
# ---------------------------------------------------------------------------
#
# The superperiod structure: periods 0..m-2 end with a buddy checkpoint
# (cost C1, commits level 1), period m-1 with a deep checkpoint (cost C2,
# commits BOTH levels).  Each pre-sampled failure carries a boolean "hard"
# flag (buddy copy lost, probability q): a soft failure rolls back to the
# last committed level-1 state and resumes the period schedule where that
# commit left it; a hard failure rolls back to the last deep commit and
# restarts the superperiod at period 0 (re-executing the intermediate buddy
# checkpoints on the way — their I/O is naturally re-counted).
#
# With m = 1 and degenerate levels (C1=C2, R1=R2, D1=D2) every arithmetic
# expression below matches the single-level ``_run_one`` operation-for-
# operation, so the scalar ``simulate_once`` oracle is reproduced
# bit-for-bit — the parity tests rely on this.

@dataclasses.dataclass(frozen=True)
class MultilevelTrajectoryBatch:
    """Per-trajectory outputs, shape ``grid.shape + (n_trials,)``."""

    wall_time: np.ndarray
    energy: np.ndarray
    work_executed: np.ndarray
    io1_time: np.ndarray         # buddy-level I/O (writes + soft recoveries)
    io2_time: np.ndarray         # deep-level I/O (writes + hard recoveries)
    down_time: np.ndarray
    n_failures: np.ndarray
    n_hard_failures: np.ndarray
    n_ckpt1: np.ndarray          # committed buddy checkpoints
    n_ckpt2: np.ndarray          # committed deep checkpoints
    truncated: np.ndarray
    gaps_exhausted: np.ndarray


def _run_one_ml(T, m, C1, C2, R1, R2, D1, D2, omega, T_base,
                gaps, hard, n_steps):
    """One two-level trajectory; ``hard[i]`` is the level-loss flag of the
    i-th failure.  Mirrors ``_run_one`` branch-for-branch."""
    f64 = gaps.dtype
    n_gaps = gaps.shape[0]
    C_first = jnp.where(m > 1, C1, C2)      # period 0 is deep only when m=1

    init = (jnp.zeros((), f64),            # wall
            jnp.zeros((), f64),            # committed1
            jnp.zeros((), f64),            # committed2
            jnp.zeros((), f64),            # live
            jnp.zeros((), f64),            # work_exec
            jnp.zeros((), f64),            # io1_time
            jnp.zeros((), f64),            # io2_time
            jnp.zeros((), f64),            # down_time
            gaps[0],                       # next_fail
            T - C_first,                   # phase_left
            jnp.zeros((), f64),            # snapshot
            jnp.zeros((), jnp.int32),      # phase = COMPUTE
            jnp.zeros((), jnp.int32),      # k: period index in superperiod
            jnp.zeros((), jnp.int32),      # resume_k: soft-rollback restart
            jnp.zeros((), jnp.int32),      # n_fail
            jnp.zeros((), jnp.int32),      # n_hard
            jnp.zeros((), jnp.int32),      # n_ckpt1
            jnp.zeros((), jnp.int32),      # n_ckpt2
            jnp.ones((), jnp.int32),       # fail_idx (gaps[0] consumed)
            jnp.zeros((), jnp.bool_))      # done

    def step(carry, _):
        (wall, committed1, committed2, live, work_exec, io1_time, io2_time,
         down_time, next_fail, phase_left, snapshot, phase, k, resume_k,
         n_fail, n_hard, n_ckpt1, n_ckpt2, fail_idx, done) = carry

        is_deep = k == m - 1
        Ck = jnp.where(is_deep, C2, C1)
        in_ckpt = phase == CHECKPOINT
        rate = jnp.where(in_ckpt, omega, 1.0)
        t_done = jnp.where(rate > 0.0,
                           (T_base - live) / jnp.where(rate > 0.0, rate, 1.0),
                           jnp.inf)
        t_next = jnp.minimum(phase_left, t_done)
        no_fail = wall + t_next < next_fail

        # ---- branch A: the phase segment completes without failure ----
        wall_a = wall + t_next
        live_a = live + rate * t_next
        work_a = work_exec + rate * t_next
        io1_a = io1_time + jnp.where(in_ckpt & ~is_deep, t_next, 0.0)
        io2_a = io2_time + jnp.where(in_ckpt & is_deep, t_next, 0.0)
        left_a = phase_left - t_next
        finished = live_a >= T_base - _EPS
        boundary = jnp.logical_and(~finished, left_a <= _EPS)
        start_ckpt = jnp.logical_and(boundary, ~in_ckpt)
        end_ckpt = jnp.logical_and(boundary, in_ckpt)
        phase_a = jnp.where(start_ckpt, CHECKPOINT,
                            jnp.where(end_ckpt, COMPUTE, phase))
        k_next = jnp.where(k + 1 >= m, 0, k + 1)
        C_next = jnp.where(k_next == m - 1, C2, C1)
        left_a = jnp.where(start_ckpt, Ck,
                           jnp.where(end_ckpt, T - C_next, left_a))
        snapshot_a = jnp.where(start_ckpt, live_a, snapshot)
        committed1_a = jnp.where(end_ckpt, snapshot, committed1)
        committed2_a = jnp.where(jnp.logical_and(end_ckpt, is_deep),
                                 snapshot, committed2)
        k_a = jnp.where(end_ckpt, k_next, k)
        resume_k_a = jnp.where(end_ckpt, k_next, resume_k)
        n_ckpt1_a = n_ckpt1 + jnp.logical_and(end_ckpt,
                                              ~is_deep).astype(jnp.int32)
        n_ckpt2_a = n_ckpt2 + jnp.logical_and(end_ckpt,
                                              is_deep).astype(jnp.int32)

        # ---- branch B: a failure strikes mid-segment ----
        hard_f = hard[jnp.minimum(n_fail, n_gaps - 1)]
        dt = next_fail - wall
        work_b = work_exec + rate * dt
        io1_b = io1_time + jnp.where(in_ckpt & ~is_deep, dt, 0.0) \
            + jnp.where(hard_f, 0.0, R1)
        io2_b = io2_time + jnp.where(in_ckpt & is_deep, dt, 0.0) \
            + jnp.where(hard_f, R2, 0.0)
        D_sel = jnp.where(hard_f, D2, D1)
        R_sel = jnp.where(hard_f, R2, R1)
        wall_b = next_fail + D_sel + R_sel
        down_b = down_time + D_sel
        gap = jnp.where(fail_idx < n_gaps,
                        gaps[jnp.minimum(fail_idx, n_gaps - 1)], jnp.inf)
        next_fail_b = wall_b + gap
        committed1_b = jnp.where(hard_f, committed2, committed1)
        k_b = jnp.where(hard_f, 0, resume_k)
        left_b = T - jnp.where(k_b == m - 1, C2, C1)

        def sel(a_val, b_val):
            return jnp.where(no_fail, a_val, b_val)

        new = (sel(wall_a, wall_b),
               sel(committed1_a, committed1_b),
               sel(committed2_a, committed2),
               sel(live_a, committed1_b),      # rollback to surviving level
               sel(work_a, work_b),
               sel(io1_a, io1_b),
               sel(io2_a, io2_b),
               sel(down_time, down_b),
               sel(next_fail, next_fail_b),
               sel(left_a, left_b),
               sel(snapshot_a, snapshot),
               sel(phase_a, COMPUTE).astype(jnp.int32),
               sel(k_a, k_b).astype(jnp.int32),
               sel(resume_k_a, k_b).astype(jnp.int32),
               sel(n_fail, n_fail + 1).astype(jnp.int32),
               sel(n_hard, n_hard + hard_f.astype(jnp.int32)
                   ).astype(jnp.int32),
               sel(n_ckpt1_a, n_ckpt1).astype(jnp.int32),
               sel(n_ckpt2_a, n_ckpt2).astype(jnp.int32),
               sel(fail_idx, fail_idx + 1).astype(jnp.int32),
               jnp.logical_or(done, jnp.logical_and(no_fail, finished)))

        keep = lambda old, upd: jnp.where(done, old, upd)
        return tuple(keep(o, u) for o, u in zip(carry, new)), None

    final, _ = lax.scan(step, init, None, length=n_steps)
    (wall, _c1, _c2, _live, work_exec, io1_time, io2_time, down_time,
     _nf, _pl, _snap, _phase, _k, _rk, n_fail, n_hard, n_ckpt1, n_ckpt2,
     fail_idx, done) = final
    return {"wall_time": wall, "work_executed": work_exec,
            "io1_time": io1_time, "io2_time": io2_time,
            "down_time": down_time, "n_failures": n_fail,
            "n_hard_failures": n_hard, "n_ckpt1": n_ckpt1,
            "n_ckpt2": n_ckpt2, "truncated": ~done,
            "gaps_exhausted": fail_idx > n_gaps}


def _make_runner_ml(n_steps: int):
    def run_grid(T, m, C1, C2, R1, R2, D1, D2, omega, T_base, gaps, hard):
        def one(t, mm, c1, c2, r1, r2, d1, d2, o, tb, g, h):
            return _run_one_ml(t, mm, c1, c2, r1, r2, d1, d2, o, tb, g, h,
                               n_steps)
        over_trials = jax.vmap(one, in_axes=(None,) * 10 + (0, 0))
        over_grid = jax.vmap(over_trials, in_axes=(0,) * 10 + (0, 0))
        return over_grid(T, m, C1, C2, R1, R2, D1, D2, omega, T_base,
                         gaps, hard)
    return jax.jit(run_grid)


_ML_RUNNERS: dict = {}


def _runner_ml(n_steps: int):
    if n_steps not in _ML_RUNNERS:
        _ML_RUNNERS[n_steps] = _make_runner_ml(n_steps)
    return _ML_RUNNERS[n_steps]


def _expected_failures_ml(T, m, grid: MultilevelParamGrid,
                          T_base) -> np.ndarray:
    """E[#failures] from the two-level closed form, clipped like the
    single-level estimator."""
    a, b, mu_m = grid.a(m), grid.b(m), grid.mu_eff(m)
    denom = (T - a) * (b - T / (2.0 * mu_m))
    with np.errstate(divide="ignore", invalid="ignore"):
        tf = np.where(denom > 1e-12, T_base * T / denom, np.inf)
    tf = np.where(np.isfinite(tf) & (tf > 0), tf, 50.0 * T_base)
    return tf / grid.mu


def default_fail_capacity_ml(T, m, grid: MultilevelParamGrid, T_base) -> int:
    """Pre-sampled failures per trajectory: mean + 10 sigma margin."""
    nf = _expected_failures_ml(T, m, grid, T_base)
    return int(np.max(np.ceil(nf + 10.0 * np.sqrt(nf + 1.0) + 10.0)))


def default_step_budget_ml(T, m, grid: MultilevelParamGrid, T_base) -> int:
    """Scan length: a hard failure re-executes up to a whole superperiod
    (m periods, 2 events each), so the per-failure margin scales with m."""
    work_per_period = np.maximum(T - grid.a(m), 1e-9)
    periods = T_base / work_per_period
    nf = _expected_failures_ml(T, m, grid, T_base)
    per_fail = 2.0 * np.maximum(m * T / work_per_period, 1.0) + 4.0
    events = 2.0 * periods + 2.0 + nf * per_fail
    margin = 10.0 * np.sqrt(nf + 1.0) * per_fail
    return int(np.max(np.ceil(2.0 * events + margin + 64.0)))


def presample_failures(grid: MultilevelParamGrid, n_trials: int,
                       capacity: int, seed: int = 0):
    """(gaps, hard): exponential(mu) inter-failure gaps and Bernoulli(q)
    level-loss flags, each of shape ``(B, n_trials, capacity)``."""
    rng = np.random.default_rng(seed)
    flat = grid.ravel()
    mu = flat.mu[:, None, None]
    gaps = rng.exponential(scale=mu, size=(grid.size, n_trials, capacity))
    hard = rng.random(size=(grid.size, n_trials, capacity)) \
        < flat.q[:, None, None]
    return gaps, hard


def _broadcast_schedule(arr, size, dtype):
    arr = np.asarray(arr, dtype=dtype)
    if arr.ndim == 1:
        arr = arr[None, None, :]
    if arr.ndim == 2:
        arr = arr[None, :, :]
    return np.broadcast_to(arr, (size, arr.shape[-2], arr.shape[-1]))


def simulate_trajectories_ml(T, m, grid: MultilevelParamGrid,
                             T_base: float = 1.0, n_trials: int = 200,
                             seed: int = 0,
                             gaps: Optional[np.ndarray] = None,
                             hard: Optional[np.ndarray] = None,
                             n_steps: Optional[int] = None,
                             ) -> MultilevelTrajectoryBatch:
    """Simulate every two-level (grid point x trial) trajectory in one
    jitted call.  ``T`` and ``m`` broadcast against ``grid.shape``; ``gaps``
    and ``hard`` override the pre-sampled failure schedule (pass the same
    gaps to the scalar oracle via :class:`ScheduledRNG` for parity checks).
    """
    flat = grid.ravel()
    T_arr = np.broadcast_to(np.asarray(T, dtype=np.float64),
                            grid.shape).ravel()
    m_arr = np.broadcast_to(np.asarray(m, dtype=np.int32),
                            grid.shape).ravel()
    Tb_arr = np.broadcast_to(np.asarray(T_base, dtype=np.float64),
                             grid.shape).ravel()
    if np.any(m_arr < 1):
        raise ValueError("deep-checkpoint cadence m must be >= 1")
    if np.any(T_arr < np.maximum(flat.C1, flat.C2)):
        raise ValueError("period too short: T must cover the checkpoint")
    if np.any(T_arr <= (1.0 - flat.omega) * flat.C_mean(m_arr)):
        raise ValueError("period too short: no work progress per period")

    if gaps is None or hard is None:
        cap = default_fail_capacity_ml(T_arr, m_arr, flat, Tb_arr)
        g, h = presample_failures(flat, n_trials, cap, seed=seed)
        gaps = g if gaps is None else gaps
        hard = h if hard is None else hard
    gaps = _broadcast_schedule(gaps, flat.size, np.float64)
    hard = _broadcast_schedule(hard, flat.size, np.bool_)
    if gaps.shape != hard.shape:
        raise ValueError(f"gaps {gaps.shape} and hard flags {hard.shape} "
                         f"schedules disagree")
    n_trials = gaps.shape[-2]
    if n_steps is None:
        n_steps = default_step_budget_ml(T_arr, m_arr, flat, Tb_arr)
    n_steps = 1 << (max(int(n_steps), 1) - 1).bit_length()

    with enable_x64():
        out = _runner_ml(int(n_steps))(
            jnp.asarray(T_arr), jnp.asarray(m_arr), jnp.asarray(flat.C1),
            jnp.asarray(flat.C2), jnp.asarray(flat.R1),
            jnp.asarray(flat.R2), jnp.asarray(flat.D1),
            jnp.asarray(flat.D2), jnp.asarray(flat.omega),
            jnp.asarray(Tb_arr), jnp.asarray(gaps), jnp.asarray(hard))
        out = {k: np.asarray(v) for k, v in out.items()}

    shp = grid.shape + (n_trials,)
    bc = lambda x: x.reshape(grid.shape + (1,))
    wall = out["wall_time"].reshape(shp)
    work = out["work_executed"].reshape(shp)
    io1 = out["io1_time"].reshape(shp)
    io2 = out["io2_time"].reshape(shp)
    down = out["down_time"].reshape(shp)
    energy = (bc(grid.P_static) * wall + bc(grid.P_cal) * work
              + bc(grid.P_io1) * io1 + bc(grid.P_io2) * io2
              + bc(grid.P_down) * down)
    return MultilevelTrajectoryBatch(
        wall_time=wall, energy=energy, work_executed=work,
        io1_time=io1, io2_time=io2, down_time=down,
        n_failures=out["n_failures"].reshape(shp),
        n_hard_failures=out["n_hard_failures"].reshape(shp),
        n_ckpt1=out["n_ckpt1"].reshape(shp),
        n_ckpt2=out["n_ckpt2"].reshape(shp),
        truncated=out["truncated"].reshape(shp),
        gaps_exhausted=out["gaps_exhausted"].reshape(shp))


def simulate_grid_ml(T, m, grid: MultilevelParamGrid, T_base: float = 1.0,
                     n_trials: int = 200, seed: int = 0,
                     gaps: Optional[np.ndarray] = None,
                     hard: Optional[np.ndarray] = None,
                     n_steps: Optional[int] = None) -> dict:
    """Mean/SE summaries of the two-level Monte-Carlo (validates the
    multilevel closed forms; raises on truncation/schedule exhaustion)."""
    tb = simulate_trajectories_ml(T, m, grid, T_base, n_trials=n_trials,
                                  seed=seed, gaps=gaps, hard=hard,
                                  n_steps=n_steps)
    if np.any(tb.truncated):
        raise RuntimeError(
            f"{int(tb.truncated.sum())} trajectories exceeded the scan "
            f"budget; pass a larger n_steps (check params)")
    if np.any(tb.gaps_exhausted):
        raise RuntimeError(
            f"{int(tb.gaps_exhausted.sum())} trajectories exhausted their "
            f"failure schedule (tail simulated failure-free); pass gaps/"
            f"hard arrays with larger capacity")
    out = {}
    n = tb.wall_time.shape[-1]
    for key, arr in (("T_final", tb.wall_time), ("E_final", tb.energy),
                     ("T_cal", tb.work_executed), ("T_io1", tb.io1_time),
                     ("T_io2", tb.io2_time), ("T_down", tb.down_time),
                     ("n_failures", tb.n_failures.astype(np.float64)),
                     ("n_hard", tb.n_hard_failures.astype(np.float64))):
        out[key] = arr.mean(axis=-1)
        out[key + "_se"] = arr.std(axis=-1, ddof=1) / math.sqrt(n)
    return out


def simulate_grid(T, grid: ParamGrid, T_base: float = 1.0,
                  n_trials: int = 200, seed: int = 0,
                  gaps: Optional[np.ndarray] = None,
                  n_steps: Optional[int] = None,
                  process=None) -> dict:
    """Batched analogue of ``core.simulator.simulate``: mean/SE summaries.

    Returns a dict of arrays of ``grid.shape`` with the same keys as the
    scalar ``simulate`` ("T_final", "T_final_se", "E_final", ...).
    """
    tb = simulate_trajectories(T, grid, T_base, n_trials=n_trials, seed=seed,
                               gaps=gaps, n_steps=n_steps, process=process)
    if np.any(tb.truncated):
        raise RuntimeError(
            f"{int(tb.truncated.sum())} trajectories exceeded the scan "
            f"budget; pass a larger n_steps (check params)")
    if np.any(tb.gaps_exhausted):
        raise RuntimeError(
            f"{int(tb.gaps_exhausted.sum())} trajectories exhausted their "
            f"failure schedule (tail simulated failure-free); pass a gaps "
            f"array with larger capacity")
    out = {}
    n = tb.wall_time.shape[-1]
    for key, arr in (("T_final", tb.wall_time), ("E_final", tb.energy),
                     ("T_cal", tb.work_executed), ("T_io", tb.io_time),
                     ("T_down", tb.down_time),
                     ("n_failures", tb.n_failures.astype(np.float64))):
        out[key] = arr.mean(axis=-1)
        out[key + "_se"] = arr.std(axis=-1, ddof=1) / math.sqrt(n)
    return out
