"""Persistent XLA compilation cache wiring.

The batched sweeps and the MC engine pay ~1-2 s of XLA compilation per
process (``BENCH_sweep.json``: ``batched_cold_s``), once per compiled
program.  JAX can persist compiled executables on disk
(``jax_compilation_cache_dir``), so the compile cost is paid once per
*machine* instead of once per *process* — the ``cold_start_cached``
benchmark entry gates the resulting cold-start reduction.

Enable it explicitly::

    from repro.sim import enable_compile_cache
    enable_compile_cache("/path/to/cache")     # or no arg: env / default

or via the environment (picked up automatically when ``repro.sim`` is
imported)::

    REPRO_COMPILE_CACHE=/path/to/cache python my_sweep.py

The helper also drops JAX's minimum-compile-time / minimum-entry-size
thresholds so the CPU-sized programs this repo compiles (~0.3-2 s) are
actually cached; on jax versions without those knobs it degrades to just
setting the cache directory.
"""
from __future__ import annotations

import os
from pathlib import Path
from typing import Optional

#: environment variable naming the cache directory.
ENV_VAR = "REPRO_COMPILE_CACHE"

#: fallback directory when enabled explicitly with no path and no env.
DEFAULT_DIR = Path.home() / ".cache" / "repro" / "jax-compile-cache"

_active_dir: Optional[str] = None


def enable_compile_cache(path: Optional[str] = None) -> str:
    """Point JAX at a persistent on-disk compilation cache (idempotent).

    Resolution order: explicit ``path`` > ``$REPRO_COMPILE_CACHE`` >
    ``~/.cache/repro/jax-compile-cache``.  Returns the directory used.
    Safe to call before or after the first jit — only programs compiled
    afterwards are cached.
    """
    global _active_dir
    import jax

    target = str(path or os.environ.get(ENV_VAR) or DEFAULT_DIR)
    Path(target).mkdir(parents=True, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", target)
    for knob, value in (("jax_persistent_cache_min_compile_time_secs", 0.0),
                        ("jax_persistent_cache_min_entry_size_bytes", -1)):
        try:
            jax.config.update(knob, value)
        except (AttributeError, KeyError):   # knob absent on this jax
            pass
    _active_dir = target
    return target


def maybe_enable_from_env() -> Optional[str]:
    """Enable the cache iff ``$REPRO_COMPILE_CACHE`` is set (the
    ``repro.sim`` import hook); returns the directory or None.

    Unlike the explicit :func:`enable_compile_cache` call, a failure here
    (unwritable path, read-only home in a container) degrades to a
    warning — an opt-in performance env var must not turn ``import
    repro.sim`` into a hard crash.
    """
    if not os.environ.get(ENV_VAR):
        return None
    try:
        return enable_compile_cache()
    except OSError as e:
        import warnings
        warnings.warn(f"{ENV_VAR}={os.environ[ENV_VAR]!r} unusable "
                      f"({e}); continuing without a persistent compile "
                      f"cache", RuntimeWarning, stacklevel=2)
        return None


def active_cache_dir() -> Optional[str]:
    """The directory the cache was enabled with, or None."""
    return _active_dir
