"""Sharded, memory-bounded dispatch for grid workloads.

Every batched grid entry point (the model sweeps in ``sim.sweep``, the
Monte-Carlo engine in ``sim.engine``, and through them the MC solvers in
``core.optimal``) routes its jitted calls through :func:`run`, which adds
two orthogonal execution knobs on top of a plain ``jax.jit`` call:

sharding
    A 1-D ``"sweep"`` mesh over the local devices; the designated grid
    axis of every array argument is split across devices with
    ``shard_map`` (the same virtual-device CI recipe as
    ``tests/test_sharded_execution.py``:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).  Grids that
    do not divide the device count are padded by edge replication to a
    shard-divisible size, and the padding is sliced off before any
    caller-side reduction can see it.

chunking
    The grid axis is cut into bounded chunks sized from a device-memory
    budget (``memory_budget_bytes`` / ``per_point_bytes``), results
    accumulating host-side — a dense 10^6-point grid streams through a
    fixed-size device working set instead of materializing everything at
    once.  Chunk shapes are ``ndev * 2^k`` so the jit cache stays at
    O(log) compiled programs.

Both knobs are PURE performance knobs: dispatch itself never touches
randomness, every per-point computation is independent (no cross-point
reductions happen on device), and the MC callers sample their failure
schedules from per-(grid-point, trial) folded keys at a partition-
independent capacity (see ``engine``), so chunk size, shard count, and
memory budget never change a fixed seed's results — chunked == unchunked
and sharded == single-device bit-for-bit (``tests/test_dispatch.py``).

The mesh spans REAL devices: :func:`backend_info` inspects
``jax.devices()`` for the selected platform (``backend``/
``$REPRO_SWEEP_BACKEND``; default = the process default backend) and the
sweep axis shards over those physical devices — GPUs/TPUs when present.
The host-virtual-device path (``XLA_FLAGS=--xla_force_host_platform_
device_count=N``) is still just a CPU backend whose devices happen to be
virtual, so the CI recipe keeps working unchanged; ``backend_info()``
flags it as ``virtual``.

Precision is also a per-backend decision: :func:`resolve_precision`
resolves the :class:`~repro.sim.precision.PrecisionPolicy` a dispatch
runs under (explicit argument > ``DispatchConfig.precision`` >
``$REPRO_PRECISION`` > the backend default — f64 on CPU, compensated
f32 on accelerators; see ``sim/precision.py``).

Configuration resolves from :class:`DispatchConfig` (explicit argument)
or environment variables::

    REPRO_SWEEP_DEVICES    max devices to shard over (1 disables sharding)
    REPRO_SWEEP_MEMORY_MB  device-memory budget per dispatch (default 2048)
    REPRO_SWEEP_CHUNK      explicit grid-axis chunk size (overrides budget)
    REPRO_SWEEP_BACKEND    jax platform for the sweep mesh (cpu/gpu/tpu;
                           default = process default backend)
    REPRO_PRECISION        precision policy name (f64 / compensated_f32;
                           default = the backend's policy)

See docs/simulation.md "Scaling out" and "Accelerator backends and
precision" for the operational recipes.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import math
import os
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # newer jax re-exports the x64 context at top level
    from jax import enable_x64
except ImportError:
    from jax.experimental import enable_x64

try:  # jax >= 0.5 promotes shard_map out of experimental
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map

from . import precision as _precision
# Re-exported so callers configure precision where they configure
# dispatch (the policy is a per-backend execution knob like the mesh).
from .precision import COMPENSATED_F32, F64, PrecisionPolicy  # noqa: F401

#: default device-memory budget per dispatch (bytes).
DEFAULT_MEMORY_BUDGET = 2 << 30

#: mesh axis name of the 1-D sweep mesh.
SWEEP_AXIS = "sweep"

#: bound on cached compiled runners (see :class:`LRUCache`).
RUNNER_CACHE_SIZE = 64


class CacheStats:
    """Hit/miss/eviction counters shared by every bounded cache.

    One instance per :class:`LRUCache`; a cache constructed with a
    ``name`` lands in the module registry so :func:`cache_stats` can
    report every cache in the process (the PR-4/5 compiled-program
    caches and the advisor's fingerprint cache alike) — the benches and
    tests read these instead of guessing at cache behavior from timings.
    """

    __slots__ = ("hits", "misses", "inserts", "evictions")

    def __init__(self):
        self.reset()

    def reset(self):
        self.hits = 0
        self.misses = 0
        self.inserts = 0
        self.evictions = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        n = self.lookups
        return self.hits / n if n else 0.0

    def snapshot(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "lookups": self.lookups, "inserts": self.inserts,
                "evictions": self.evictions, "hit_rate": self.hit_rate}


#: name -> LRUCache for every cache constructed with a ``name``.
# reprolint: disable=RPL002 (this IS the cache_stats() registry: it holds weak references to the bounded LRUCaches themselves, one per name, not compiled callables)
_CACHE_REGISTRY: dict = {}


def cache_stats(reset: bool = False) -> dict:
    """``{cache name: stats snapshot (+ size/maxsize)}`` for every named
    cache in the process; ``reset=True`` zeroes the counters after
    reading (sizes/contents are untouched — stats are observability
    only, never behavior)."""
    out = {}
    for name, cache in sorted(_CACHE_REGISTRY.items()):
        snap = cache.stats.snapshot()
        snap["size"] = len(cache)
        snap["maxsize"] = cache.maxsize
        out[name] = snap
        if reset:
            cache.stats.reset()
    return out


def reset_cache_stats():
    """Zero every named cache's counters (cache contents untouched)."""
    for cache in _CACHE_REGISTRY.values():
        cache.stats.reset()


class LRUCache:
    """Tiny LRU map bounding caches of compiled callables.

    A long-lived sweep service creates one compiled program per distinct
    (semantic key, chunk shape, device count); an unbounded dict leaks
    them forever.  Eviction only drops the *cached callable* — a later
    call with the same key rebuilds and recompiles it, producing
    identical results (tested) at the price of one recompile.

    ``name`` registers the cache (and its :class:`CacheStats`) with
    :func:`cache_stats`; anonymous caches still count, just privately.
    """

    def __init__(self, maxsize: int, name: Optional[str] = None):
        self.maxsize = int(maxsize)
        self.name = name
        self.stats = CacheStats()
        self._d: collections.OrderedDict = collections.OrderedDict()
        if name is not None:
            _CACHE_REGISTRY[name] = self

    def get(self, key):
        try:
            val = self._d.pop(key)
        except KeyError:
            self.stats.misses += 1
            return None
        self.stats.hits += 1
        self._d[key] = val            # re-insert as most recently used
        return val

    def put(self, key, val):
        self._d.pop(key, None)
        self._d[key] = val
        self.stats.inserts += 1
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)
            self.stats.evictions += 1

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key) -> bool:
        return key in self._d

    def clear(self):
        self._d.clear()


@dataclasses.dataclass(frozen=True)
class DispatchConfig:
    """Execution knobs for :func:`run` (all pure performance knobs).

    ``devices`` caps the devices sharded over (None = all local devices);
    ``memory_budget_bytes`` bounds the per-dispatch device working set
    (None = ``$REPRO_SWEEP_MEMORY_MB`` or 2 GiB); ``chunk`` forces an
    explicit grid-axis chunk size (rounded up to a device multiple);
    ``shard=False`` disables the mesh entirely; ``backend`` pins the jax
    platform the sweep mesh spans (None = ``$REPRO_SWEEP_BACKEND`` or
    the process default backend); ``precision`` pins the
    :class:`~repro.sim.precision.PrecisionPolicy` (a policy, a policy
    name, or None = ``$REPRO_PRECISION`` or the backend default —
    see :func:`resolve_precision`).

    On a CPU host every field is a pure performance knob (the CPU
    default policy is the f64 oracle, so ``backend="cpu"`` /
    ``precision="f64"`` are bit-exact no-ops — tested); a reduced-
    precision policy on an accelerator changes results within the
    policy's documented tolerance.
    """

    devices: Optional[int] = None
    memory_budget_bytes: Optional[int] = None
    chunk: Optional[int] = None
    shard: bool = True
    backend: Optional[str] = None
    precision: Optional[object] = None

    def budget(self) -> int:
        if self.memory_budget_bytes is not None:
            return int(self.memory_budget_bytes)
        mb = _env_int("REPRO_SWEEP_MEMORY_MB")
        return mb << 20 if mb else DEFAULT_MEMORY_BUDGET


def _env_int(name: str):
    """Parse an optional integer env knob; a malformed value degrades to
    a warning + default instead of crashing every grid entry point from
    deep inside a sweep (same contract as ``cache.maybe_enable_from_env``
    — opt-in performance knobs must not become hard crashes)."""
    raw = os.environ.get(name)
    if not raw:
        return None
    try:
        return int(raw)
    except ValueError:
        import warnings
        warnings.warn(f"{name}={raw!r} is not an integer; ignoring it",
                      RuntimeWarning, stacklevel=3)
        return None


def default_config() -> DispatchConfig:
    """The environment-driven config (see module docstring)."""
    backend = os.environ.get("REPRO_SWEEP_BACKEND", "").strip().lower()
    return DispatchConfig(devices=_env_int("REPRO_SWEEP_DEVICES"),
                          chunk=_env_int("REPRO_SWEEP_CHUNK"),
                          backend=backend or None)


def resolve(config: Optional[DispatchConfig]) -> DispatchConfig:
    return config if config is not None else default_config()


def _backend_devices(backend: Optional[str] = None) -> list:
    """The jax devices of ``backend`` (a platform name); None = the
    process default platform.  An unavailable platform degrades to a
    warning + default devices — backend selection is an opt-in knob and
    must not turn every sweep into a hard crash on a CPU-only box."""
    if not backend:
        return jax.devices()
    try:
        return jax.devices(backend)
    except RuntimeError:
        import warnings
        warnings.warn(f"backend {backend!r} has no devices here; using "
                      f"the default platform ({jax.default_backend()})",
                      RuntimeWarning, stacklevel=3)
        return jax.devices()


@dataclasses.dataclass(frozen=True)
class BackendInfo:
    """What the sweep mesh actually spans (:func:`backend_info`).

    ``platform`` is the jax platform name (cpu/gpu/tpu), ``device_kind``
    the hardware self-description of device 0 (e.g. "NVIDIA A100-SXM4",
    "TPU v4", "cpu"), ``n_devices`` the devices available on that
    platform, and ``virtual`` flags the host-virtual-device CI recipe
    (multiple XLA "devices" carved out of one CPU host — real sharding
    semantics, no real parallel silicon).
    """

    platform: str
    device_kind: str
    n_devices: int
    virtual: bool


def backend_info(backend: Optional[str] = None) -> BackendInfo:
    """Detect the mesh backend: ``backend`` (a platform name), else
    ``$REPRO_SWEEP_BACKEND``, else the process default platform."""
    if backend is None:
        backend = default_config().backend
    devs = _backend_devices(backend)
    platform = devs[0].platform
    return BackendInfo(
        platform=platform,
        device_kind=str(getattr(devs[0], "device_kind", platform)),
        n_devices=len(devs),
        virtual=platform == "cpu" and len(devs) > 1)


def resolve_precision(config: Optional[DispatchConfig] = None,
                      precision=None) -> PrecisionPolicy:
    """The :class:`PrecisionPolicy` a dispatch runs under.

    Resolution order: explicit ``precision`` argument (a policy or a
    policy name) > ``config.precision`` > ``$REPRO_PRECISION`` > the
    default policy of the mesh backend (f64 on CPU, compensated f32 on
    accelerators).  A malformed env value degrades to a warning + the
    backend default, like every other env knob here.
    """
    if precision is not None:
        return _precision.resolve(precision)
    cfg = resolve(config)
    if cfg.precision is not None:
        return _precision.resolve(cfg.precision)
    env = os.environ.get("REPRO_PRECISION", "").strip()
    if env:
        try:
            return _precision.resolve(env)
        except ValueError:
            import warnings
            warnings.warn(
                f"REPRO_PRECISION={env!r} is not a known policy "
                f"({sorted(_precision.POLICIES)}); using the backend "
                f"default", RuntimeWarning, stacklevel=3)
    return _precision.default_policy(backend_info(cfg.backend).platform)


def effective_devices(config: Optional[DispatchConfig] = None) -> int:
    """Devices the sweep mesh will span under ``config`` (>= 1)."""
    cfg = resolve(config)
    if not cfg.shard:
        return 1
    n = len(_backend_devices(cfg.backend))
    if cfg.devices is not None:
        n = min(n, max(1, int(cfg.devices)))
    return max(1, n)


@functools.lru_cache(maxsize=32)
def sweep_mesh(n_devices: int, backend: Optional[str] = None) -> Mesh:
    """The 1-D ``("sweep",)`` mesh over the first ``n_devices`` devices
    of ``backend`` (None = the process default platform)."""
    return Mesh(np.array(_backend_devices(backend)[:n_devices]),
                (SWEEP_AXIS,))


def _pow2ceil(n: int) -> int:
    return 1 << (max(int(n), 1) - 1).bit_length()


def chunk_plan(size: int, ndev: int, per_point_bytes: int,
               config: Optional[DispatchConfig] = None,
               quantum: int = 1) -> list:
    """Cut a grid axis of ``size`` into ``(start, stop, padded)`` chunks.

    Full chunks share one shape (a pow2 multiple of both the device count
    and ``quantum``, sized from the memory budget); the tail is padded up
    to its own such multiple — O(log) distinct shapes total.  ``padded ==
    stop - start`` whenever no padding is needed (the single-device
    whole-grid fast path compiles at the exact grid size, like a plain
    jit call).

    ``quantum`` forces every dispatched shape to a multiple of a fixed
    lane count.  XLA:CPU's codegen is shape-dependent at small/ragged
    batch extents (loop unrolling and scalar remainder lanes contract
    multiply-adds differently, shifting results by ~1 ulp), so callers
    whose kernels are sensitive to it (the dense elementwise model sweep)
    pin a quantum to make every chunk run the same vectorized loop body —
    that is what upgrades chunk/shard knobs from "approximately neutral"
    to bit-exact no-ops for those paths (tests/test_dispatch.py).
    """
    cfg = resolve(config)
    size = int(size)
    q = math.lcm(max(1, int(ndev)), max(1, int(quantum)))
    if cfg.chunk is not None:
        base = ((max(1, int(cfg.chunk)) + q - 1) // q) * q
    elif per_point_bytes and per_point_bytes > 0:
        target = max(1, cfg.budget() // int(per_point_bytes))
        base = q * max(1, _pow2ceil(target // q + 1) // 2)  # pow2 floor
    else:
        base = ((size + q - 1) // q) * q  # no estimate: one chunk
    if base >= size:
        padded = size if q == 1 else ((size + q - 1) // q) * q
        return [(0, size, padded)]
    plan = []
    for start in range(0, size, base):
        stop = min(start + base, size)
        rem = stop - start
        padded = rem if rem == base else min(base, q * _pow2ceil(
            (rem + q - 1) // q))
        plan.append((start, stop, padded))
    return plan


def _slice_pad(arr, axis: int, start: int, stop: int, padded: int):
    """Slice ``[start:stop)`` along ``axis`` and edge-replicate the last
    element up to ``padded`` (numpy or device arrays; device stays put).

    Padding lanes recompute the final grid point and are sliced off by
    :func:`run` before results reach the caller — never part of any
    reduction.
    """
    xp = jnp if isinstance(arr, jnp.ndarray) else np
    idx = [slice(None)] * arr.ndim
    idx[axis] = slice(start, stop)
    sl = arr[tuple(idx)]
    pad = padded - (stop - start)
    if pad > 0:
        idx[axis] = slice(-1, None)
        tail = xp.repeat(sl[tuple(idx)], pad, axis=axis)
        sl = xp.concatenate([sl, tail], axis=axis)
    return sl


def _out_spec_tree(out_axes):
    """out_axes (int, or a pytree of ints matching the output structure)
    -> shard_map out_specs (a PartitionSpec prefix tree)."""
    spec = lambda a: P(*([None] * int(a) + [SWEEP_AXIS]))
    if isinstance(out_axes, int):
        return spec(out_axes)
    return jax.tree.map(spec, out_axes)


def _freeze(obj):
    """Hashable form of an out_axes pytree for the runner cache key."""
    if isinstance(obj, dict):
        return tuple(sorted((k, _freeze(v)) for k, v in obj.items()))
    if isinstance(obj, (list, tuple)):
        return tuple(_freeze(v) for v in obj)
    return obj


_RUNNERS = LRUCache(RUNNER_CACHE_SIZE, name="dispatch.runners")


def _runner_for(key, build, ndev: int, in_axes: Sequence[Optional[int]],
                out_axes, backend: Optional[str] = None):
    """The compiled runner for ``key`` on ``ndev`` devices: a plain jit of
    ``build`` (single device) or a shard_map over the sweep mesh.

    ``key`` is the caller's semantic identity of ``build`` — it must
    capture everything baked into the closure (kernel, scan length,
    process, capacities).  jit handles per-shape compilation internally,
    so the cache is per (key, ndev, backend), not per chunk shape.
    """
    ck = (key, ndev, backend, tuple(in_axes), _freeze(out_axes))
    fn = _RUNNERS.get(ck)
    if fn is not None:
        return fn
    if ndev == 1:
        fn = jax.jit(build)
    else:
        in_specs = tuple(
            P() if ax is None else P(*([None] * int(ax) + [SWEEP_AXIS]))
            for ax in in_axes)
        fn = jax.jit(shard_map(build, mesh=sweep_mesh(ndev, backend),
                               in_specs=in_specs,
                               out_specs=_out_spec_tree(out_axes),
                               check_rep=False))
    _RUNNERS.put(ck, fn)
    return fn


def run(key, build, args, in_axes: Sequence[Optional[int]], out_axes,
        size: int, per_point_bytes: int = 0,
        config: Optional[DispatchConfig] = None, quantum: int = 1):
    """Dispatch ``build(*args)`` over a grid axis: sharded across the sweep
    mesh, chunked to the memory budget, accumulated host-side.

    ``in_axes[i]`` is the grid-axis position in ``args[i]`` (None =
    broadcast verbatim to every chunk/shard); every marked axis must have
    length ``size``.  ``out_axes`` gives the grid-axis position in the
    outputs (an int for all leaves, or a pytree of ints matching the
    output structure).  ``key`` must uniquely identify the semantics of
    ``build`` (closure contents included) — it keys the compiled-runner
    cache.  Returns host numpy arrays in the output structure, the grid
    axis restored to ``size``.
    """
    cfg = resolve(config)
    ndev = effective_devices(cfg)
    plan = chunk_plan(size, ndev, per_point_bytes, cfg, quantum=quantum)
    runner = _runner_for(key, build, ndev, in_axes, out_axes,
                         backend=cfg.backend)

    with enable_x64():
        # Broadcast args: convert once (device arrays stay put — a parked
        # CRN schedule must not round-trip through the host per chunk).
        const = [None if ax is not None
                 else (a if isinstance(a, jnp.ndarray)
                       # reprolint: disable=RPL003 (deliberately dtype-preserving: broadcast args arrive as f64 grids, int32 m-candidates, or bool masks, and the chunker must not recast any of them)
                       else jnp.asarray(np.asarray(a)))
                 for a, ax in zip(args, in_axes)]
        treedef = None
        flat_axes = None
        bufs = None
        for start, stop, padded in plan:
            chunk_args = [
                const[i] if ax is None
                else _slice_pad(args[i], ax, start, stop, padded)
                for i, ax in enumerate(in_axes)]
            out = runner(*chunk_args)
            leaves, tdef = jax.tree.flatten(out)
            if treedef is None:
                treedef = tdef
                flat_axes = (jax.tree.leaves(out_axes)
                             if not isinstance(out_axes, int)
                             else [out_axes] * len(leaves))
                if len(flat_axes) == 1 and len(leaves) > 1:
                    flat_axes = flat_axes * len(leaves)
                if len(plan) == 1 and padded == size:
                    return tdef.unflatten([np.asarray(v) for v in leaves])
                bufs = []
                for leaf, ax in zip(leaves, flat_axes):
                    shp = list(np.shape(leaf))
                    shp[ax] = size
                    bufs.append(np.empty(shp, dtype=np.asarray(leaf).dtype))
            for leaf, ax, buf in zip(leaves, flat_axes, bufs):
                arr = np.asarray(leaf)
                sel = [slice(None)] * arr.ndim
                sel[ax] = slice(0, stop - start)      # drop padding lanes
                dst = [slice(None)] * arr.ndim
                dst[ax] = slice(start, stop)
                buf[tuple(dst)] = arr[tuple(sel)]
    return treedef.unflatten(bufs)
