"""Phase-based energy accounting (the paper's §2.2 powers, as a runtime).

The trainer tags every wall-clock interval with a :class:`Phase`; the meter
integrates phase durations against a :class:`PowerProfile` and reports both
joules and the paper's normalized parameters (alpha, beta, gamma, rho) so the
analytical optimizer consumes *measured* power numbers.

Overlap semantics follow the paper: during a non-blocking checkpoint both the
CPU (at work-rate omega) and the I/O system draw power, so COMPUTE and
CHECKPOINT_IO intervals may overlap; the static power is paid once on the
wall clock.

Two-level accounting: buddy (level-1) I/O gets its own phases and its own
power (``io_buddy_w``, the multilevel model's P_io1 — NIC + remote RAM,
materially below PFS draw).  ``io_buddy_w=None`` keeps the levels
degenerate (buddy draws PFS power), which preserves the single-level
energy report bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import defaultdict
from typing import Optional

from ..core.params import MultilevelPowerParams, PowerParams


class Phase(enum.Enum):
    COMPUTE = "compute"            # CPU/TPU busy executing work
    CHECKPOINT_IO = "checkpoint_io"  # writing a deep (PFS) checkpoint
    CHECKPOINT_IO_BUDDY = "checkpoint_io_buddy"  # buddy-only write (level 1)
    RECOVERY_IO = "recovery_io"    # reading a deep checkpoint after a failure
    RECOVERY_IO_BUDDY = "recovery_io_buddy"      # buddy read (level 1)
    DOWN = "down"                  # downtime (reboot / spare swap-in)
    IDLE = "idle"                  # static power only


@dataclasses.dataclass(frozen=True)
class PowerProfile:
    """Per-node powers in watts (or any consistent unit)."""

    static_w: float
    compute_w: float     # overhead while computing  (P_cal)
    io_w: float          # overhead during deep checkpoint/recovery I/O (P_io)
    down_w: float = 0.0  # overhead while down (P_down)
    name: str = "custom"
    #: overhead during buddy (level-1) I/O; None = same as io_w (P_io1).
    io_buddy_w: Optional[float] = None

    @property
    def io_buddy_w_eff(self) -> float:
        return self.io_w if self.io_buddy_w is None else self.io_buddy_w

    def power_params(self) -> PowerParams:
        return PowerParams(P_static=self.static_w, P_cal=self.compute_w,
                           P_io=self.io_w, P_down=self.down_w)

    def ml_power_params(self) -> MultilevelPowerParams:
        """Per-level powers for the multilevel (T, m) energy solver."""
        return MultilevelPowerParams(P_static=self.static_w,
                                     P_cal=self.compute_w,
                                     P_io1=self.io_buddy_w_eff,
                                     P_io2=self.io_w, P_down=self.down_w)


#: The paper's Exascale scenario, milliwatts/node (rho = 5.5).
PAPER_EXASCALE_PROFILE = PowerProfile(static_w=10.0, compute_w=10.0,
                                      io_w=100.0, down_w=0.0,
                                      name="paper_exascale_rho5.5")

#: Same scenario with the two-level split of EXASCALE_ML_POWER: buddy I/O
#: (NIC + remote RAM) at 20 mW against the PFS's 100 mW.
PAPER_EXASCALE_ML_PROFILE = PowerProfile(static_w=10.0, compute_w=10.0,
                                         io_w=100.0, down_w=0.0,
                                         io_buddy_w=20.0,
                                         name="paper_exascale_ml")

#: A v5e-host flavored absolute profile (per host: chips + NICs + SSD).
TPU_V5E_HOST_PROFILE = PowerProfile(static_w=240.0, compute_w=560.0,
                                    io_w=160.0, down_w=0.0,
                                    name="tpu_v5e_host")


class EnergyMeter:
    """Integrates phase durations -> joules; paper-compatible breakdown."""

    def __init__(self, profile: PowerProfile):
        self.profile = profile
        self.phase_s: dict = defaultdict(float)
        self.wall_s: float = 0.0

    # -- interval API ---------------------------------------------------------
    def add(self, phase: Phase, seconds: float, *,
            advances_wall: bool = True) -> None:
        """Record an interval.  Overlapped intervals (the omega*C compute
        during a checkpoint) are added with ``advances_wall=False`` so static
        power is not double-counted."""
        if seconds < 0:
            raise ValueError("negative interval")
        self.phase_s[phase] += seconds
        if advances_wall:
            self.wall_s += seconds

    # -- reports --------------------------------------------------------------
    def energy_j(self) -> dict:
        p = self.profile
        e = {
            "static": self.wall_s * p.static_w,
            "compute": self.phase_s[Phase.COMPUTE] * p.compute_w,
            "io": (self.phase_s[Phase.CHECKPOINT_IO]
                   + self.phase_s[Phase.RECOVERY_IO]) * p.io_w,
            "io_buddy": (self.phase_s[Phase.CHECKPOINT_IO_BUDDY]
                         + self.phase_s[Phase.RECOVERY_IO_BUDDY])
            * p.io_buddy_w_eff,
            "down": self.phase_s[Phase.DOWN] * p.down_w,
        }
        e["total"] = sum(e.values())
        return e

    def report(self) -> dict:
        out = {f"T_{k.value}_s": v for k, v in self.phase_s.items()}
        out["T_wall_s"] = self.wall_s
        out.update({f"E_{k}_j": v for k, v in self.energy_j().items()})
        pp = self.profile.power_params()
        out.update({"alpha": pp.alpha, "beta": pp.beta, "gamma": pp.gamma,
                    "rho": pp.rho})
        return out
