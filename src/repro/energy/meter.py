"""Phase-based energy accounting (the paper's §2.2 powers, as a runtime).

The trainer tags every wall-clock interval with a :class:`Phase`; the meter
integrates phase durations against a :class:`PowerProfile` and reports both
joules and the paper's normalized parameters (alpha, beta, gamma, rho) so the
analytical optimizer consumes *measured* power numbers.

Overlap semantics follow the paper: during a non-blocking checkpoint both the
CPU (at work-rate omega) and the I/O system draw power, so COMPUTE and
CHECKPOINT_IO intervals may overlap; the static power is paid once on the
wall clock.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import defaultdict

from ..core.params import PowerParams


class Phase(enum.Enum):
    COMPUTE = "compute"            # CPU/TPU busy executing work
    CHECKPOINT_IO = "checkpoint_io"  # writing a checkpoint
    RECOVERY_IO = "recovery_io"    # reading a checkpoint after a failure
    DOWN = "down"                  # downtime (reboot / spare swap-in)
    IDLE = "idle"                  # static power only


@dataclasses.dataclass(frozen=True)
class PowerProfile:
    """Per-node powers in watts (or any consistent unit)."""

    static_w: float
    compute_w: float     # overhead while computing  (P_cal)
    io_w: float          # overhead during checkpoint/recovery I/O (P_io)
    down_w: float = 0.0  # overhead while down (P_down)
    name: str = "custom"

    def power_params(self) -> PowerParams:
        return PowerParams(P_static=self.static_w, P_cal=self.compute_w,
                           P_io=self.io_w, P_down=self.down_w)


#: The paper's Exascale scenario, milliwatts/node (rho = 5.5).
PAPER_EXASCALE_PROFILE = PowerProfile(static_w=10.0, compute_w=10.0,
                                      io_w=100.0, down_w=0.0,
                                      name="paper_exascale_rho5.5")

#: A v5e-host flavored absolute profile (per host: chips + NICs + SSD).
TPU_V5E_HOST_PROFILE = PowerProfile(static_w=240.0, compute_w=560.0,
                                    io_w=160.0, down_w=0.0,
                                    name="tpu_v5e_host")


class EnergyMeter:
    """Integrates phase durations -> joules; paper-compatible breakdown."""

    def __init__(self, profile: PowerProfile):
        self.profile = profile
        self.phase_s: dict = defaultdict(float)
        self.wall_s: float = 0.0

    # -- interval API ---------------------------------------------------------
    def add(self, phase: Phase, seconds: float, *,
            advances_wall: bool = True) -> None:
        """Record an interval.  Overlapped intervals (the omega*C compute
        during a checkpoint) are added with ``advances_wall=False`` so static
        power is not double-counted."""
        if seconds < 0:
            raise ValueError("negative interval")
        self.phase_s[phase] += seconds
        if advances_wall:
            self.wall_s += seconds

    # -- reports --------------------------------------------------------------
    def energy_j(self) -> dict:
        p = self.profile
        e = {
            "static": self.wall_s * p.static_w,
            "compute": self.phase_s[Phase.COMPUTE] * p.compute_w,
            "io": (self.phase_s[Phase.CHECKPOINT_IO]
                   + self.phase_s[Phase.RECOVERY_IO]) * p.io_w,
            "down": self.phase_s[Phase.DOWN] * p.down_w,
        }
        e["total"] = sum(e.values())
        return e

    def report(self) -> dict:
        out = {f"T_{k.value}_s": v for k, v in self.phase_s.items()}
        out["T_wall_s"] = self.wall_s
        out.update({f"E_{k}_j": v for k, v in self.energy_j().items()})
        pp = self.profile.power_params()
        out.update({"alpha": pp.alpha, "beta": pp.beta, "gamma": pp.gamma,
                    "rho": pp.rho})
        return out
