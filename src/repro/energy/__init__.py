from .meter import EnergyMeter, Phase, PowerProfile, TPU_V5E_HOST_PROFILE, \
    PAPER_EXASCALE_PROFILE, PAPER_EXASCALE_ML_PROFILE
