"""End-to-end driver: train a language model for a few hundred steps under
injected failures, with the paper's checkpoint-period policy closing the
loop (measured C/omega/mu -> AlgoT or AlgoE period -> energy report).

Default is a CPU-sized model; --full-125m trains the real xlstm-125m config
(~180M params; slow on CPU, sized for a real host).

    PYTHONPATH=src python examples/train_fault_tolerant.py --steps 200
    PYTHONPATH=src python examples/train_fault_tolerant.py --strategy algo_e
"""
import sys
from pathlib import Path
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import argparse

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--strategy", default="algo_t")
    ap.add_argument("--mtbf", type=float, default=60.0)
    ap.add_argument("--full-125m", action="store_true")
    args, _ = ap.parse_known_args()

    argv = ["--arch", "xlstm-125m", "--steps", str(args.steps),
            "--strategy", args.strategy, "--mtbf", str(args.mtbf),
            "--inject-failures", "--sim-step-seconds", "1.0"]
    if not args.full_125m:
        argv += ["--reduce", "--layers", "4", "--d-model", "256",
                 "--batch", "8", "--seq", "128"]
    report = train_mod.main(argv)
    e = report["energy"]
    print(f"\nsummary: {report['final_step']} steps, "
          f"{report['n_failures']} failures, "
          f"{report['n_rollbacks']} rollbacks, "
          f"E_total={e['E_total_j']:.0f} J over {e['T_wall_s']:.0f} s")


if __name__ == "__main__":
    main()
