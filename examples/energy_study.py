"""Reproduce the paper's experimental section (Figures 1-3) from the library
API and check its headline claims — sweeps run on the batched ``repro.sim``
subsystem, and the Fig. 1/2 operating point is additionally validated by the
vectorized Monte-Carlo engine against the closed-form expectations.

    PYTHONPATH=src python examples/energy_study.py        (or pip install -e .)
"""
import sys
from pathlib import Path
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import EXASCALE_POWER_RHO7
from repro.core.model import ml_energy_final, ml_time_final
from repro.sim import (MultilevelParamGrid, ParamGrid, buddy_ratio_grid,
                       evaluate_multilevel_grid, get_scenario,
                       list_scenarios, simulate_grid, simulate_grid_ml,
                       sweep_nodes_grid, sweep_rho_grid,
                       sweep_weibull_shapes)
from repro.sim.sweep import evaluate_grid


def main():
    print("== Scenario catalog ==")
    for name, doc in list_scenarios().items():
        print(f"  {name:15s} {doc}")

    print("\n== Figure 1/2 operating point (mu=300 min, rho=5.5) ==")
    sc = get_scenario("exascale_rho55", mu_min=300.0)
    grid = ParamGrid.from_params(sc.ckpt, sc.power).reshape((1,))
    pt = evaluate_grid(grid)
    print(f"energy gain {(pt.energy_ratio[0]-1)*100:.1f}% "
          f"(paper: 'more than 20%'), "
          f"time loss {(pt.time_ratio[0]-1)*100:.1f}% (paper: '~10%')")

    print("\n== Monte-Carlo validation of that point (batched engine) ==")
    T_base = 4000.0
    sim_t = simulate_grid(pt.T_time, grid, T_base, n_trials=300, seed=0)
    sim_e = simulate_grid(pt.T_energy, grid, T_base, n_trials=300, seed=0)
    print(f"  AlgoT: simulated E = {sim_t['E_final'][0]:.0f} "
          f"(model {pt.E_time[0]*T_base:.0f})")
    print(f"  AlgoE: simulated E = {sim_e['E_final'][0]:.0f} "
          f"(model {pt.E_energy[0]*T_base:.0f})")
    print(f"  simulated energy gain: "
          f"{(sim_t['E_final'][0]/sim_e['E_final'][0]-1)*100:.1f}%")

    print("\n== Figure 1: gain vs rho at mu=300 ==")
    rhos = [1, 2, 4, 5.5, 7, 10]
    res = sweep_rho_grid(rhos, 300.0)
    for j, r in enumerate(rhos):
        print(f"  rho={r:5.2f}  e_ratio={res.energy_ratio[0, j]:.3f}  "
              f"t_ratio={res.time_ratio[0, j]:.3f}")

    print("\n== Figure 3: scalability (rho=7) ==")
    ns = [1e5, 1e6, 3e6, 1e7, 1e8]
    res3 = sweep_nodes_grid(ns, EXASCALE_POWER_RHO7)
    for i, n in enumerate(ns):
        print(f"  N={n:9.0e} mu={res3.grid.mu[i]:8.2f} min  "
              f"e_ratio={res3.energy_ratio[i]:.3f}  "
              f"t_ratio={res3.time_ratio[i]:.3f}")
    k = int(np.argmax(res3.energy_ratio))
    print(f"peak gain {(res3.energy_ratio[k]-1)*100:.0f}% at "
          f"{(res3.time_ratio[k]-1)*100:.0f}% overhead "
          f"(paper: 'up to 30% for ~12%'); ratios -> "
          f"{res3.energy_ratio[-1]:.3f}/{res3.time_ratio[-1]:.3f} at 1e8 nodes")

    print("\n== Multilevel (buddy + PFS): joint (T, m) optimization ==")
    ratios, qs = [0.05, 0.1, 0.25], [0.05, 0.2]
    res4 = evaluate_multilevel_grid(buddy_ratio_grid(ratios, qs,
                                                     mu_min=600.0),
                                    m_values=tuple(range(1, 9)))
    for i, r in enumerate(ratios):
        for j, q in enumerate(qs):
            print(f"  C1/C2={r:4.2f} q={q:4.2f}  "
                  f"AlgoT (T={res4.T_time[i, j]:5.1f}, "
                  f"m={int(res4.m_time[i, j])})  "
                  f"AlgoE (T={res4.T_energy[i, j]:5.1f}, "
                  f"m={int(res4.m_energy[i, j])})  "
                  f"time vs PFS-only {res4.time_vs_single[i, j]:.3f}  "
                  f"energy vs PFS-only {res4.energy_vs_single[i, j]:.3f}")

    print("\n== Robustness: what if failures are not exponential? ==")
    # Field studies fit Weibull shape < 1 to HPC failure logs.  How much
    # time/energy do the paper's exponential-optimal periods leave on the
    # table under such a process (same MTBF, different shape)?
    shapes, mus = [0.5, 1.0], [120.0, 300.0]
    rob = sweep_weibull_shapes(shapes, mus, n_trials=96, seed=0)
    for i, k in enumerate(shapes):
        for j, mu in enumerate(mus):
            print(f"  k={k:3.1f} mu={mu:3.0f}  "
                  f"T*_exp={rob.T_exp_time[i, j]:5.1f} -> "
                  f"T*_mc={rob.T_mc_time[i, j]:5.1f}  "
                  f"time penalty {(rob.time_penalty_exp[i, j]-1)*100:4.1f}%  "
                  f"energy penalty "
                  f"{(rob.energy_penalty_exp[i, j]-1)*100:4.1f}%  "
                  f"(Young: {(rob.time_penalty_young[i, j]-1)*100:4.1f}%)")
    print("  (k=1.0 is exponential — the control row; see "
          "docs/simulation.md 'Failure processes')")

    print("\n== Monte-Carlo validation of one two-level point ==")
    sc = get_scenario("multilevel_exascale", mu_min=600.0, buddy_ratio=0.1,
                      q=0.1)
    grid = MultilevelParamGrid.from_params(sc.ckpt, sc.power).reshape((1,))
    one = evaluate_multilevel_grid(grid, m_values=(1, 2, 3, 4))
    T4, m4 = float(one.T_energy[0]), int(one.m_energy[0])
    sim4 = simulate_grid_ml(T4, m4, grid, T_base, n_trials=300, seed=0)
    print(f"  AlgoE (T={T4:.1f}, m={m4}): simulated T_final = "
          f"{sim4['T_final'][0]:.0f} "
          f"(model {float(ml_time_final(T4, m4, sc.ckpt, T_base)):.0f}), "
          f"E = {sim4['E_final'][0]:.0f} "
          f"(model "
          f"{float(ml_energy_final(T4, m4, sc.ckpt, sc.power, T_base)):.0f})")


if __name__ == "__main__":
    main()
