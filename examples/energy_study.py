"""Reproduce the paper's experimental section (Figures 1-3) from the library
API and check its headline claims.

    PYTHONPATH=src python examples/energy_study.py
"""
import sys
from pathlib import Path
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import (sweep_rho, sweep_nodes, fig12_checkpoint, evaluate,
                        EXASCALE_POWER_RHO55, EXASCALE_POWER_RHO7)


def main():
    print("== Figure 1/2 operating point (mu=300 min, rho=5.5) ==")
    pt = evaluate(fig12_checkpoint(300.0), EXASCALE_POWER_RHO55)
    print(f"energy gain {(pt.energy_ratio-1)*100:.1f}% "
          f"(paper: 'more than 20%'), "
          f"time loss {(pt.time_ratio-1)*100:.1f}% (paper: '~10%')")

    print("\n== Figure 1: gain vs rho at mu=300 ==")
    for p in sweep_rho([1, 2, 4, 5.5, 7, 10], 300.0):
        print(f"  rho={p.power.rho:5.2f}  e_ratio={p.energy_ratio:.3f}  "
              f"t_ratio={p.time_ratio:.3f}")

    print("\n== Figure 3: scalability (rho=7) ==")
    ns = [1e5, 1e6, 3e6, 1e7, 1e8]
    pts = sweep_nodes(ns, EXASCALE_POWER_RHO7)
    for n, p in zip(ns, pts):
        print(f"  N={n:9.0e} mu={p.ckpt.mu:8.2f} min  "
              f"e_ratio={p.energy_ratio:.3f}  t_ratio={p.time_ratio:.3f}")
    peak = max(pts, key=lambda p: p.energy_ratio)
    print(f"peak gain {(peak.energy_ratio-1)*100:.0f}% at "
          f"{(peak.time_ratio-1)*100:.0f}% overhead "
          f"(paper: 'up to 30% for ~12%'); ratios -> "
          f"{pts[-1].energy_ratio:.3f}/{pts[-1].time_ratio:.3f} at 1e8 nodes")


if __name__ == "__main__":
    main()
