"""Batched serving example: prefill a batch of prompts and greedy-decode,
with the int8 KV cache and wave-prefill options.

    PYTHONPATH=src python examples/serve_batched.py
    PYTHONPATH=src python examples/serve_batched.py --kv-cache int8 --waves 2
"""
import sys
from pathlib import Path
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch import serve


if __name__ == "__main__":
    serve.main()
