"""Quickstart: the paper's checkpoint time/energy model in five minutes.

Computes the time-optimal (AlgoT) and energy-optimal (AlgoE) checkpoint
periods for an Exascale-like platform, shows the predicted trade-off, and
verifies both against the discrete-event Monte-Carlo simulator.

    PYTHONPATH=src python examples/quickstart.py

This is the single-level model under the paper's exponential failures.  For
the two-level (buddy + PFS) extension — per-level (C_k, R_k, D_k, P_io_k),
joint (T, m) solvers, and the batched Monte-Carlo validation — see the
"Multilevel checkpointing" section of docs/simulation.md and
examples/energy_study.py.  For non-exponential failures (Weibull /
log-normal / trace replay, `repro.core.failures`) and what the closed
forms cost there, see the "Failure processes" section of
docs/simulation.md.
"""
import sys
from pathlib import Path
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (CheckpointParams, EXASCALE_POWER_RHO55,
                        t_opt_time, t_opt_energy, t_young, t_daly,
                        time_final, energy_final, evaluate, simulate)


def main():
    # A platform: 10^6 nodes, per-node MTBF 125 years -> mu = 66 min;
    # checkpoint/recovery 10 min, downtime 1 min, half-overlapped writes.
    ck = CheckpointParams(C=10.0, R=10.0, D=1.0, mu=300.0, omega=0.5)
    pw = EXASCALE_POWER_RHO55          # P_static=10, P_cal=10, P_io=100

    print(f"platform: mu={ck.mu} min, C={ck.C}, R={ck.R}, D={ck.D}, "
          f"omega={ck.omega}; rho={pw.rho}")
    print(f"Young  period: {t_young(ck):7.2f} min")
    print(f"Daly   period: {t_daly(ck):7.2f} min")
    print(f"AlgoT  period: {t_opt_time(ck):7.2f} min   (paper Eq. 1)")
    print(f"AlgoE  period: {t_opt_energy(ck, pw):7.2f} min   "
          f"(positive root of the exact quadratic)")

    pt = evaluate(ck, pw)
    print(f"\npredicted: AlgoE saves {(pt.energy_ratio-1)*100:.1f}% energy "
          f"for {(pt.time_ratio-1)*100:.1f}% extra time")

    # Monte-Carlo check (T_base = 4000 min of work)
    for name, T in (("AlgoT", pt.T_time), ("AlgoE", pt.T_energy)):
        sim = simulate(T, ck, pw, T_base=4000.0, n_trials=200, seed=0)
        print(f"{name}: model T={float(time_final(T, ck, 4000)):8.1f}  "
              f"sim T={sim['T_final']:8.1f}  "
              f"model E={float(energy_final(T, ck, pw, 4000)):9.0f}  "
              f"sim E={sim['E_final']:9.0f}")


if __name__ == "__main__":
    main()
