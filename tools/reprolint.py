#!/usr/bin/env python
"""Shim: run reprolint without installing the package.

Equivalent to ``PYTHONPATH=src python -m repro.lint`` from the repo
root; kept next to the other repo tools so CI and pre-commit hooks can
invoke a stable path.
"""
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.lint.cli import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
