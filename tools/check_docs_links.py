"""Docs link checker: every internal reference must resolve.

Scans ``README.md`` and ``docs/*.md`` (fenced code blocks stripped) for

* markdown links ``[text](target)`` — relative targets must exist, and a
  ``#anchor`` must match a heading in the target file (GitHub slug rules);
* backticked code paths like ``tests/test_advisor.py`` — must exist
  relative to the repo root, ``src/repro/`` (docs refer to modules as
  ``sim/dispatch.py``), or the markdown file's own directory.

ROADMAP.md is deliberately out of scope: it cites files from *related*
repos (Levanter's ``tracker/tracker.py``) that live outside this tree.

Exit status 0 when everything resolves, 1 with a per-reference report
otherwise.  Run from anywhere:

    python tools/check_docs_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

_FENCE = re.compile(r"```.*?```", re.S)
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_TICK = re.compile(r"`([^`\n]+)`")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.M)
#: backticked tokens worth checking: a real path shape AND a doc/code
#: extension (or an explicit trailing slash for directories).  This
#: excludes math (`T/2`), attribute chains (`Params.P_io1/P_io2`), CLI
#: flags (`--x/--no-x`), and bare module refs (`energy/meter`).
_PATH_EXT = (".py", ".md", ".yml", ".yaml", ".json", ".csv", ".toml")


def doc_files(root: Path = ROOT) -> list[Path]:
    return [root / "README.md", *sorted((root / "docs").glob("*.md"))]


def _slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces->dashes."""
    heading = re.sub(r"[`*_]", "", heading.strip())
    heading = re.sub(r"[^\w\s-]", "", heading.lower())
    return re.sub(r"\s+", "-", heading.strip())


def _anchors(md: Path) -> set[str]:
    return {_slug(h) for h in _HEADING.findall(md.read_text())}


def _is_path_token(tok: str) -> bool:
    if "/" not in tok or tok.startswith(("-", "/")):
        return False
    if not re.fullmatch(r"[A-Za-z0-9_.\-/]+", tok):
        return False
    return tok.endswith("/") or tok.endswith(_PATH_EXT)


def _resolve_tick(tok: str, md: Path) -> bool:
    rel = tok.rstrip("/")
    return any((base / rel).exists()
               for base in (ROOT, ROOT / "src" / "repro", md.parent))


def check_file(md: Path) -> list[str]:
    errors = []
    text = _FENCE.sub("", md.read_text())
    try:
        rel = md.relative_to(ROOT)
    except ValueError:        # file under test outside the repo tree
        rel = md.name

    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        dest = (md.parent / path_part).resolve() if path_part else md
        if not dest.exists():
            errors.append(f"{rel}: broken link -> {target}")
            continue
        if anchor and dest.suffix == ".md" and anchor not in _anchors(dest):
            errors.append(f"{rel}: missing anchor -> {target}")

    for tok in _TICK.findall(text):
        if _is_path_token(tok) and not _resolve_tick(tok, md):
            errors.append(f"{rel}: dangling code path -> `{tok}`")
    return errors


def main() -> int:
    errors = []
    for md in doc_files():
        if not md.exists():
            errors.append(f"missing doc file: {md.relative_to(ROOT)}")
            continue
        errors.extend(check_file(md))
    if errors:
        print("\n".join(errors))
        print(f"FAIL: {len(errors)} unresolved doc reference(s)")
        return 1
    n = len(doc_files())
    print(f"PASS: all internal references resolve across {n} doc files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
