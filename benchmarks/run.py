"""Benchmark driver: one entry per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows (one per benchmark).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (fig1_rho_sweep, fig2_mu_rho, fig3_scalability,
                   fig4_multilevel, fig5_robustness, table_baselines,
                   table_simulation, table_arch_periods, bench_kernels,
                   bench_advisor, bench_sweep, roofline)
    modules = [fig1_rho_sweep, fig2_mu_rho, fig3_scalability,
               fig4_multilevel, fig5_robustness, table_baselines,
               table_simulation, table_arch_periods, bench_kernels,
               bench_advisor, bench_sweep, roofline]
    print("name,us_per_call,derived")
    failures = 0
    for m in modules:
        try:
            if m is bench_sweep:
                # Never rewrite the committed CI-gate baseline from the
                # smoke run: earlier benches pre-warm the jit cache (bogus
                # cold timings) and a stray `git commit -a` would ship this
                # machine's numbers.  Standalone bench_sweep regenerates it.
                m.main(["--no-write"])
            else:
                m.main()
        except Exception as e:      # noqa: BLE001 — report all benches
            failures += 1
            print(f"{m.__name__},NaN,FAILED: {e!r}", file=sys.stderr)
            traceback.print_exc(limit=3)
    if failures:
        raise SystemExit(f"{failures} benchmark(s) failed")


if __name__ == "__main__":
    main()
