"""End-to-end runtime validation: the paper's predictions vs an executing
trainer.

For a grid of (strategy, failure process) scenarios, runs the REAL
fault-tolerant trainer — jitted train steps on a reduced model, async
sharded-store checkpoints, buddy replica, policy-driven (T, m) — in
scaled virtual time, and compares measured wall-clock and energy against
the model's ``ml_time_final`` / ``ml_energy_final`` evaluated at the
operating point the run actually executed.  This is the in-process
analogue of the physical measured-energy validation in "Checkpoint and
Restart: An Energy Consumption Characterization in Clusters" (PAPERS.md).

Scenarios cover both halves of the acceptance criterion:
  * single-level (PFS only): AlgoT under exponential and Weibull failures;
  * two-level buddy+PFS with policy-chosen (T, m): ``algo_t_ml`` and
    ``algo_e_ml``, exponential and Weibull, hard-failure probability q.

Each scenario averages ``N_SEEDS`` independent failure schedules; the
mean measured/predicted ratio must stay within ``TOLERANCE`` of 1.0
(documented derivation: docs/training.md, "Validation recipe").

Writes ``benchmarks/results/validate_runtime.csv``.

Standalone:
  python -m benchmarks.validate_runtime
"""
import csv
import time

import numpy as np

from ._util import RESULTS, emit

#: per-scenario mean |ratio - 1| gate (the documented tolerance).
TOLERANCE = 0.10
N_SEEDS = 6
STEPS = 240

_BASE = dict(arch="starcoder2-3b", layers=1, d_model=32, n_heads=2,
             batch=2, seq=16, total_steps=STEPS, step_s=1.0, omega=0.0)

#: single-level world: the paper's one-level model, exercised for real.
_SL = dict(_BASE, mu_s=15.0, C_s=0.5, R_s=0.5, D_s=0.1, use_buddy=False)
#: two-level world: cheap buddy, expensive PFS, 15% hard failures.
_ML = dict(_BASE, mu_s=15.0, C_s=1.5, R_s=1.5, D_s=0.2, C1_s=0.3,
           R1_s=0.3, D1_s=0.1, q=0.15, profile="paper_ml")

_WEIBULL = dict(process="weibull", process_kwargs={"shape": 0.7})

SCENARIOS = [
    ("single_algo_t_exp", dict(_SL, strategy="algo_t")),
    ("single_algo_t_weibull", dict(_SL, strategy="algo_t", **_WEIBULL)),
    ("single_algo_e_exp", dict(_SL, strategy="algo_e")),
    ("ml_algo_t_exp", dict(_ML, strategy="algo_t_ml")),
    ("ml_algo_t_weibull", dict(_ML, strategy="algo_t_ml", **_WEIBULL)),
    ("ml_algo_e_exp", dict(_ML, strategy="algo_e_ml")),
    # Async deep flush (VELOC): omega2 sweeps the in-flight share of the
    # deep write from fully synchronous to fully overlapped; failures
    # inside the flush window abort the write and roll back a
    # generation, and the model's per-level w2 terms must price it.
    # (omega2=0.0 duplicates ml_algo_t_exp by construction and anchors
    # the sweep.)
    ("ml_async_w2_00", dict(_ML, strategy="algo_t_ml", omega2=0.0)),
    ("ml_async_w2_05", dict(_ML, strategy="algo_t_ml", omega2=0.5)),
    ("ml_async_w2_09", dict(_ML, strategy="algo_t_ml", omega2=0.9)),
    ("ml_async_w2_10", dict(_ML, strategy="algo_t_ml", omega2=1.0)),
]


def run_scenario(name: str, kw: dict, n_seeds: int = N_SEEDS) -> dict:
    from repro.ft.run import RunSpec, execute

    wall_r, energy_r, n_failures, ms, aborts = [], [], [], [], []
    for seed in range(n_seeds):
        rep = execute(RunSpec(seed=seed, **kw))
        pred = rep["predicted"]
        wall_r.append(pred["wall_ratio"])
        energy_r.append(pred["energy_ratio"])
        n_failures.append(rep["n_failures"])
        ms.append(pred["m"])
        aborts.append(rep["flush_aborts"])
    return {"scenario": name, "strategy": kw["strategy"],
            "process": kw.get("process", "exponential"),
            "n_seeds": n_seeds,
            "mean_failures": float(np.mean(n_failures)),
            "mean_flush_aborts": float(np.mean(aborts)),
            "m": int(ms[0]),
            "wall_ratio": float(np.mean(wall_r)),
            "wall_ratio_sd": float(np.std(wall_r)),
            "energy_ratio": float(np.mean(energy_r)),
            "energy_ratio_sd": float(np.std(energy_r))}


def run():
    rows = []
    t0 = time.perf_counter()
    for name, kw in SCENARIOS:
        row = run_scenario(name, kw)
        rows.append(row)
        print(f"{name:28s} wall {row['wall_ratio']:.3f}"
              f"+-{row['wall_ratio_sd']:.3f}  "
              f"energy {row['energy_ratio']:.3f}"
              f"+-{row['energy_ratio_sd']:.3f}  "
              f"m={row['m']} fails/run={row['mean_failures']:.1f}")
    elapsed_us = (time.perf_counter() - t0) * 1e6

    out = RESULTS / "validate_runtime.csv"
    with open(out, "w", newline="") as fh:
        w = csv.DictWriter(fh, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    print(f"wrote {out}")

    worst = max(max(abs(r["wall_ratio"] - 1.0), abs(r["energy_ratio"] - 1.0))
                for r in rows)
    emit("validate_runtime", elapsed_us, f"worst_dev={worst:.3f}")
    if worst > TOLERANCE:
        raise SystemExit(
            f"FAIL: worst measured/predicted deviation {worst:.3f} exceeds "
            f"the documented {TOLERANCE:.0%} tolerance")
    print(f"PASS all {len(rows)} scenarios within {TOLERANCE:.0%} "
          f"(worst deviation {worst:.3f})")
    return rows


if __name__ == "__main__":
    run()
