"""Paper Figure 1: time and energy ratios as a function of rho.

C = R = 10 min, D = 1 min, omega = 1/2; one curve per platform MTBF.
Emits CSV rows (mu, rho, energy_ratio, time_ratio) + the paper's headline
check: >20% energy gain at ~10% time loss for (mu=300, rho=5.5).
"""
from ._util import emit, timed, RESULTS


def run():
    from repro.core import sweep_rho, fig12_checkpoint, evaluate
    from repro.core.params import PowerParams
    import numpy as np

    rhos = list(np.linspace(1.0, 10.0, 19))
    rows = []
    for mu in (300.0, 120.0, 60.0, 30.0):
        for pt in sweep_rho(rhos, mu):
            rows.append((mu, pt.power.rho, pt.energy_ratio, pt.time_ratio))
    out = RESULTS / "fig1_rho_sweep.csv"
    with open(out, "w") as f:
        f.write("mu_min,rho,energy_ratio_T_over_E,time_ratio_E_over_T\n")
        for r in rows:
            f.write(",".join(f"{x:.6f}" for x in r) + "\n")
    head = [r for r in rows if r[0] == 300.0 and abs(r[1] - 5.5) < 0.26]
    return out, head[0] if head else rows[0]


def main():
    (out, head), us = timed(run, repeat=1)
    emit("fig1_rho_sweep", us,
         f"mu=300 rho~5.5: e_ratio={head[2]:.3f} t_ratio={head[3]:.3f} -> {out.name}")


if __name__ == "__main__":
    main()
