"""Paper Figure 1: time and energy ratios as a function of rho.

C = R = 10 min, D = 1 min, omega = 1/2; one curve per platform MTBF —
computed as a single batched (mu x rho) grid through ``repro.sim``.
Emits CSV rows (mu, rho, energy_ratio, time_ratio) + the paper's headline
check: >20% energy gain at ~10% time loss for (mu=300, rho=5.5).
"""
from ._util import emit, timed, RESULTS

MUS = [300.0, 120.0, 60.0, 30.0]


def run():
    import numpy as np
    from repro.sim import sweep_mu_rho_grid

    rhos = list(np.linspace(1.0, 10.0, 19))
    res = sweep_mu_rho_grid(MUS, rhos)
    rows = [(mu, float(res.grid.rho[i, j]), float(res.energy_ratio[i, j]),
             float(res.time_ratio[i, j]))
            for i, mu in enumerate(MUS) for j in range(len(rhos))]
    out = RESULTS / "fig1_rho_sweep.csv"
    with open(out, "w") as f:
        f.write("mu_min,rho,energy_ratio_T_over_E,time_ratio_E_over_T\n")
        for r in rows:
            f.write(",".join(f"{x:.6f}" for x in r) + "\n")
    head = [r for r in rows if r[0] == 300.0 and abs(r[1] - 5.5) < 0.26]
    return out, head[0] if head else rows[0]


def main():
    (out, head), us = timed(run, repeat=2)
    emit("fig1_rho_sweep", us,
         f"mu=300 rho~5.5: e_ratio={head[2]:.3f} t_ratio={head[3]:.3f} -> {out.name}")


if __name__ == "__main__":
    main()
