"""Paper Figure 3: time/energy ratios vs number of nodes.

C = R = 1 min, D = 0.1 min, omega = 1/2, mu = 120 min @ 1e6 nodes, ~ 1/N.
Panels (a) rho = 5.5 and (b) rho = 7; the paper's claims: up to ~30% energy
gain at ~12% time overhead, both ratios -> 1 at 1e8 nodes.
"""
from ._util import emit, timed, RESULTS


def run():
    import numpy as np
    from repro.core import sweep_nodes, EXASCALE_POWER_RHO55, \
        EXASCALE_POWER_RHO7

    ns = list(np.logspace(5, 8, 25))
    out = RESULTS / "fig3_scalability.csv"
    best = None
    with open(out, "w") as f:
        f.write("rho,n_nodes,mu_min,energy_ratio,time_ratio\n")
        for rho, pw in ((5.5, EXASCALE_POWER_RHO55),
                        (7.0, EXASCALE_POWER_RHO7)):
            for pt in sweep_nodes(ns, pw):
                n = 120.0 * 1e6 / pt.ckpt.mu
                f.write(f"{rho},{n:.0f},{pt.ckpt.mu:.3f},"
                        f"{pt.energy_ratio:.6f},{pt.time_ratio:.6f}\n")
                if rho == 7.0 and (best is None
                                   or pt.energy_ratio > best.energy_ratio):
                    best = pt
    return out, best


def main():
    (out, best), us = timed(run, repeat=1)
    emit("fig3_scalability", us,
         f"rho=7 peak: e_ratio={best.energy_ratio:.3f} "
         f"t_ratio={best.time_ratio:.3f} at mu={best.ckpt.mu:.0f}min "
         f"-> {out.name}")


if __name__ == "__main__":
    main()
