"""Paper Figure 3: time/energy ratios vs number of nodes.

C = R = 1 min, D = 0.1 min, omega = 1/2, mu = 120 min @ 1e6 nodes, ~ 1/N.
Panels (a) rho = 5.5 and (b) rho = 7 via the batched ``repro.sim`` sweep;
the paper's claims: up to ~30% energy gain at ~12% time overhead, both
ratios -> 1 at 1e8 nodes.
"""
from ._util import emit, timed, RESULTS


def run():
    import numpy as np
    from repro.core import EXASCALE_POWER_RHO55, EXASCALE_POWER_RHO7
    from repro.sim import sweep_nodes_grid

    ns = np.logspace(5, 8, 25)
    out = RESULTS / "fig3_scalability.csv"
    best = None
    with open(out, "w") as f:
        f.write("rho,n_nodes,mu_min,energy_ratio,time_ratio\n")
        for rho, pw in ((5.5, EXASCALE_POWER_RHO55),
                        (7.0, EXASCALE_POWER_RHO7)):
            res = sweep_nodes_grid(ns, pw)
            for i in range(len(ns)):
                mu = res.grid.mu[i]
                f.write(f"{rho},{120.0 * 1e6 / mu:.0f},{mu:.3f},"
                        f"{res.energy_ratio[i]:.6f},{res.time_ratio[i]:.6f}\n")
            if rho == 7.0:
                k = int(np.argmax(res.energy_ratio))
                best = (float(res.energy_ratio[k]), float(res.time_ratio[k]),
                        float(res.grid.mu[k]))
    return out, best


def main():
    (out, best), us = timed(run, repeat=2)
    emit("fig3_scalability",
         us,
         f"rho=7 peak: e_ratio={best[0]:.3f} "
         f"t_ratio={best[1]:.3f} at mu={best[2]:.0f}min "
         f"-> {out.name}")


if __name__ == "__main__":
    main()
