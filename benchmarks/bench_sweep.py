"""Scalar vs batched sweep/engine timings -> BENCH_sweep.json (+ CI gate).

Times the seed per-point loop (``tradeoff.sweep_mu_rho(engine="scalar")``)
against the batched ``repro.sim`` grid evaluation on (a) the seed benchmark
grid and (b) a dense production-resolution grid; the Monte-Carlo engine
entries: the event kernel vs the scalar oracle on the canonical Weibull
workload (``weibull_event_engine``), the Pallas event kernel vs the scan
event engine on the same workload with full bit-parity asserted
(``pallas_event_engine``, gated at its no-regression cap; the raw ratio
and backend ride along ungated), and the warm MC-surrogate solve
step-vs-event (``mc_solver_warm``); the dispatch-layer entries: the
multi-device sharded dense sweep (``sharded_dense_grid``, measured on
virtual CPU devices in a subprocess), the memory-bounded 10^6-point
chunked sweep (``chunked_dense_1m``, asserts chunked == unchunked
bit-for-bit), and the persistent-compile-cache cold start
(``cold_start_cached``, two fresh interpreters against one cache dir);
the async-flush model entry (``async_overlap_collapse``, gated on a
DETERMINISTIC quantity: the collapse of the time overhead above
failure-free execution as the deep-flush overlap ``omega2`` -> 1, pure
model arithmetic so it pins the per-level omega model itself);
and the serving entries from ``bench_advisor``: the micro-batched
512-request advisor burst vs the naive per-request loop (``advisor_rps``,
gated, with open-loop p50/p99 riding along) and the batch-window x
cache-hit-rate open-loop sweep (``advisor_load_regimes``, ungated:
absolute latency is machine-dependent).
``weibull_step_engine_reference`` keeps the RETAINED step kernel's
Weibull-vs-exponential ratio as an ungated-by-design reference — it reads
~0.3x by construction (the cv^2-scaled step budget the event kernel was
built to avoid) and must not trip the gate.  Every run also renders the
warm/cold timings as ``benchmarks/results/bench_sweep_table.md`` (uploaded
as a CI artifact).

The canonical artifact is ``BENCH_sweep.json`` at the repo root — the
committed baseline the CI regression gate compares against.  There is
deliberately no second copy under ``benchmarks/results/``.

Modes:
  python -m benchmarks.bench_sweep           # measure + rewrite the baseline
  python -m benchmarks.bench_sweep --check   # measure, compare the warm
                                             # scalar-vs-batched speedup
                                             # against the committed baseline,
                                             # exit non-zero on a >2x drop
                                             # (machine-normalized; baseline
                                             # file left untouched)

Note: regenerate the committed baseline ONLY with a standalone bench_sweep
run.  ``benchmarks.run`` invokes this module with ``--no-write`` — its jit
cache is pre-warmed by the other figure benches, which would record a
meaninglessly small ``batched_cold_s`` into the baseline.
"""
import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

from ._util import emit

SEED_MUS = [30, 60, 90, 120, 180, 240, 300, 420, 600]
ROOT = Path(__file__).resolve().parents[1]
#: the one canonical timing artifact (committed baseline for --check).
CANONICAL = ROOT / "BENCH_sweep.json"
#: >2x warm-timing slowdown vs the committed baseline fails the CI job.
REGRESSION_FACTOR = 2.0


def _best_of(fn, repeat):
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _time_pair(mus, rhos, scalar_repeat, batched_repeat):
    from repro.core.tradeoff import sweep_mu_rho
    from repro.sim import sweep_mu_rho_grid

    scalar_s = _best_of(lambda: sweep_mu_rho(mus, rhos, engine="scalar"),
                        scalar_repeat)
    t0 = time.perf_counter()
    res = sweep_mu_rho_grid(mus, rhos)
    cold_s = time.perf_counter() - t0
    batched_s = _best_of(lambda: sweep_mu_rho_grid(mus, rhos), batched_repeat)

    # Cross-check the two paths agree before trusting the timing.
    ref = sweep_mu_rho(mus, rhos, engine="scalar")
    err = max(abs(res.energy_ratio[i][j] - ref[i][j].energy_ratio)
              for i in range(len(mus)) for j in range(len(rhos)))
    assert err < 1e-9, f"scalar/batched sweep disagree: {err}"

    return {"n_points": len(mus) * len(rhos),
            "scalar_s": scalar_s,
            "batched_cold_s": cold_s,
            "batched_warm_s": batched_s,
            "speedup_warm": scalar_s / batched_s}


def _weibull_workload(n_points=12, n_trials=128, shape=0.7):
    """The canonical non-exponential engine workload: a mixed-mu exascale
    grid (the regime where cv-scaled step budgets used to blow up)."""
    import numpy as np

    from repro.core import fig12_checkpoint, EXASCALE_POWER_RHO55
    from repro.core.failures import Weibull
    from repro.sim import ParamGrid

    mus = np.linspace(120.0, 600.0, n_points)
    base = ParamGrid.from_params(fig12_checkpoint(300.0),
                                 EXASCALE_POWER_RHO55)
    grid = ParamGrid(**{f: (mus if f == "mu"
                            else np.broadcast_to(v, (n_points,)))
                        for f, v in base.fields().items()})
    return grid, Weibull(shape=shape), 60.0, 1500.0, n_trials


def _time_weibull_step_engine_reference(n_points=12, n_trials=128,
                                        shape=0.7, repeat=5):
    """The RETAINED step kernel's Weibull-vs-exponential ratio (reference).

    Runs ``sim.simulate_trajectories`` with ``engine_kind="step"`` on the
    canonical workload twice — exponential and Weibull auto-sampled
    schedules — and reports the within-run ratio.  It reads ~0.3x BY
    CONSTRUCTION: the step kernel's scan budget scales with the gap cv^2,
    which is exactly the cost the event kernel (the default) erased; the
    entry exists to keep that reference measurable, not to gate it.
    Hence ``"ungated": True`` — ``check_regression`` skips it by design
    (a glance at 0.3x used to read as a live regression of the hot path,
    which it is not; the gated hot-path entries are
    ``weibull_event_engine`` and ``mc_solver_warm``).
    """
    from repro.sim.engine import simulate_trajectories

    grid, proc, T, T_base, n_trials = _weibull_workload(n_points, n_trials,
                                                        shape)

    def run_exp():
        return simulate_trajectories(T, grid, T_base, n_trials=n_trials,
                                     seed=0, engine_kind="step")

    def run_weibull():
        return simulate_trajectories(T, grid, T_base, n_trials=n_trials,
                                     seed=0, process=proc,
                                     engine_kind="step")

    t0 = time.perf_counter()
    run_weibull()
    weibull_cold_s = time.perf_counter() - t0
    run_exp()                              # warm the exponential program too
    weibull_warm_s = _best_of(run_weibull, repeat)
    exp_warm_s = _best_of(run_exp, repeat)
    return {"n_points": n_points, "n_trials": n_trials,
            "weibull_shape": shape,
            "ungated": True,               # reference entry, by design
            "exp_warm_s": exp_warm_s,
            "batched_cold_s": weibull_cold_s,
            "batched_warm_s": weibull_warm_s,
            "speedup_warm": exp_warm_s / weibull_warm_s}


def _time_weibull_event_engine(n_points=12, n_trials=128, shape=0.7,
                               repeat=5):
    """Event engine vs the SCALAR oracle on the Weibull workload.

    This is the PR-4 before/after story: on exactly the 12-point/128-trial
    workload where the step kernel measured 0.32x against the scalar
    per-trajectory loop, the event kernel must win outright
    (``speedup_warm`` = scalar / event-warm; the acceptance floor is 5x).
    """
    from repro.core.simulator import simulate
    from repro.sim.engine import simulate_trajectories

    grid, proc, T, T_base, n_trials = _weibull_workload(n_points, n_trials,
                                                        shape)

    def run_scalar():
        for i in range(grid.size):
            simulate(T, grid.ckpt_at(i), grid.power_at(i), T_base,
                     n_trials=n_trials, seed=0, process=proc)

    def run_event():
        return simulate_trajectories(T, grid, T_base, n_trials=n_trials,
                                     seed=0, process=proc)

    # The step reference entry compiled only step-kernel programs, so the
    # first event call here is an honest cold measurement.
    t0 = time.perf_counter()
    run_event()
    event_cold_s = time.perf_counter() - t0
    event_warm_s = _best_of(run_event, repeat)
    scalar_s = _best_of(run_scalar, 1)     # the python loop needs no warmup
    return {"n_points": grid.size, "n_trials": n_trials,
            "weibull_shape": shape,
            "scalar_s": scalar_s,
            "batched_cold_s": event_cold_s,
            "batched_warm_s": event_warm_s,
            "speedup_warm": scalar_s / event_warm_s}


#: cap on the pallas entry's GATED ratio (same portability argument as
#: ``_SHARDED_GATE_CAP``): the gate asserts "the pallas engine does not
#: regress below the event scan", not this machine's exact margin.
_PALLAS_GATE_CAP = 1.5


def _time_pallas_event_engine(n_points=12, n_trials=128, shape=0.7,
                              repeat=5):
    """Pallas event kernel vs the lax.scan event engine, same workload.

    Both run the identical auto-sampled Weibull schedules (CRN), so the
    run asserts full bit parity before trusting the timing.  On CPU the
    kernel executes via ``pallas_call(..., interpret=True)`` — traced to
    plain XLA ops — and still wins: its all-done early exit skips the
    power-of-two padding tail the scan kernel burns through.  That
    no-regression claim (>= 1.0x, capped at ``_PALLAS_GATE_CAP``) is the
    gated ``speedup_warm``; the RAW ratio rides along ungated as
    ``pallas_speedup`` with the backend/device it was measured on (on an
    accelerator backend the kernel lowers natively and the raw ratio is
    the interesting number).
    """
    import jax

    from repro.sim.engine import simulate_trajectories

    grid, proc, T, T_base, n_trials = _weibull_workload(n_points, n_trials,
                                                        shape)
    run = lambda kind: simulate_trajectories(
        T, grid, T_base, n_trials=n_trials, seed=0, process=proc,
        engine_kind=kind)

    r_event = run("event")                 # warm (or reuse) the scan program
    t0 = time.perf_counter()
    r_pallas = run("pallas")
    pallas_cold_s = time.perf_counter() - t0
    import numpy as np
    for f in ("wall_time", "energy", "n_failures", "n_checkpoints"):
        assert np.array_equal(np.asarray(getattr(r_event, f)),
                              np.asarray(getattr(r_pallas, f))), \
            f"pallas engine diverged from the event scan on {f}"
    event_warm_s = _best_of(lambda: run("event"), repeat)
    pallas_warm_s = _best_of(lambda: run("pallas"), repeat)
    ratio = event_warm_s / pallas_warm_s
    dev = jax.devices()[0]
    return {"n_points": grid.size, "n_trials": n_trials,
            "weibull_shape": shape,
            "backend": jax.default_backend(),
            "device_kind": dev.device_kind,
            "interpret": jax.default_backend() != "tpu",
            "event_warm_s": event_warm_s,
            "batched_cold_s": pallas_cold_s,
            "batched_warm_s": pallas_warm_s,
            "pallas_speedup": ratio,
            "speedup_warm": min(ratio, _PALLAS_GATE_CAP)}


def _time_mc_solver(repeat=3):
    """Warm MC-surrogate solve: event kernel vs the retained step kernel.

    Both solves share the same CRN schedules and converge to the same
    period; the within-run step/event ratio is machine-normalized and
    regresses exactly when the event hot path (candidate-vmap + per-call
    dispatch) loses ground to the step machine.
    """
    from repro.core import fig12_checkpoint, EXASCALE_POWER_RHO55
    from repro.core.failures import Weibull
    from repro.core.optimal import MCSurrogate

    ck = fig12_checkpoint(300.0)
    proc = Weibull(shape=0.7)

    def solve(kind):
        return MCSurrogate(ck, EXASCALE_POWER_RHO55, proc, T_base=1500.0,
                           n_trials=96, seed=0,
                           engine_kind=kind).argmin("time")

    t0 = time.perf_counter()
    t_event = solve("event")
    event_cold_s = time.perf_counter() - t0
    t_step = solve("step")                 # warms the step programs
    # The two kernels share schedules but not arithmetic; a ~1e-13 tie in
    # a golden-section branch can wiggle the argmin, so gate at the MC
    # solvers' own agreement tolerance rather than exact equality.
    assert abs(t_event - t_step) <= 5e-3 * t_step, (t_event, t_step)
    event_warm_s = _best_of(lambda: solve("event"), repeat)
    step_warm_s = _best_of(lambda: solve("step"), repeat)
    return {"n_trials": 96, "weibull_shape": 0.7,
            "step_warm_s": step_warm_s,
            "batched_cold_s": event_cold_s,
            "batched_warm_s": event_warm_s,
            "speedup_warm": step_warm_s / event_warm_s}


#: cap on the sharded entry's GATED ratio: makes the committed baseline
#: machine-portable (see _time_sharded_dense) — raising it requires a
#: baseline machine whose capped value every CI runner can reach half of.
_SHARDED_GATE_CAP = 2.0


#: virtual devices for the sharded bench subprocess: one per core, capped
#: at the acceptance target's 8 (oversubscribing cores with more virtual
#: devices than hardware threads just measures scheduler noise).
def _bench_device_count() -> int:
    return max(1, min(8, os.cpu_count() or 1))


_SHARDED_SCRIPT = r"""
import os, sys, json, time
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=%(ndev)d "
                           + os.environ.get("XLA_FLAGS", ""))
sys.path.insert(0, r"%(src)s")
import numpy as np
import jax
from repro.sim import DispatchConfig, ParamGrid, simulate_trajectories
from repro.core import fig12_checkpoint, EXASCALE_POWER_RHO55
from repro.core.failures import Weibull

B, trials = 512, 128
base = ParamGrid.from_params(fig12_checkpoint(300.0), EXASCALE_POWER_RHO55)
mus = np.linspace(120.0, 600.0, B)
grid = ParamGrid(**{f: (mus if f == "mu" else np.broadcast_to(v, (B,)))
                    for f, v in base.fields().items()})
kw = dict(T_base=1500.0, n_trials=trials, seed=0, process=Weibull(shape=0.7))
single = DispatchConfig(shard=False)
sharded = DispatchConfig()

def best(fn, repeat=5):
    b = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter(); fn(); b = min(b, time.perf_counter() - t0)
    return b

r1 = simulate_trajectories(60.0, grid, dispatch=single, **kw)   # compile
r2 = simulate_trajectories(60.0, grid, dispatch=sharded, **kw)
eq = bool(np.array_equal(r1.wall_time, r2.wall_time)
          and np.array_equal(r1.energy, r2.energy))
single_s = best(lambda: simulate_trajectories(60.0, grid, dispatch=single,
                                              **kw))
sharded_s = best(lambda: simulate_trajectories(60.0, grid, dispatch=sharded,
                                               **kw))
print(json.dumps({"n_devices": jax.device_count(), "bit_equal": eq,
                  "n_points": B, "n_trials": trials,
                  "single_warm_s": single_s, "sharded_warm_s": sharded_s}))
"""


def _time_sharded_dense():
    """Sharded vs single-device dense MC-engine grid sweep on virtual CPU
    devices.

    Runs in a subprocess (the device count must be fixed before jax
    initializes) with one virtual device per core (<= 8) — the scan-heavy
    engine sweep is where device sharding is the real parallelism lever
    (the elementwise model sweep is already saturated by XLA:CPU's
    intra-op threading on a CPU host).  The subprocess asserts sharded ==
    single-device bit parity on the full result.  Note: virtual devices
    SHARE the host's cores (and its intra-op thread pool), so the
    measured speedup tracks physical cores, not the virtual device
    count; dedicated-accelerator hosts see the near-linear version of
    the same dispatch.

    The raw single/sharded ratio scales with the host's PHYSICAL cores
    (and per-unit efficiency falls as units rise), so gating either
    quantity raw against a committed baseline from a different machine
    class can fail CI for core-count reasons alone.  The gated
    ``speedup_warm`` is therefore the raw ratio CAPPED at
    ``_SHARDED_GATE_CAP`` (2.0): any healthy multi-core host clears the
    cap's half-way mark (failing requires sharding to be actively slower
    than single-device — a genuine dispatch-overhead regression), while
    a many-core machine regenerating the baseline can never raise the
    bar above the cap.  The uncapped ratio is recorded as
    ``sharded_speedup`` alongside n_devices/n_cores.
    """
    ndev = _bench_device_count()
    script = _SHARDED_SCRIPT % {"ndev": ndev, "src": str(ROOT / "src")}
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f"sharded bench subprocess failed:\n"
                           f"{out.stderr[-3000:]}")
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["bit_equal"], "sharded sweep diverged from single-device"
    ratio = r["single_warm_s"] / r["sharded_warm_s"]
    return {"n_points": r["n_points"], "n_trials": r["n_trials"],
            "n_devices": r["n_devices"], "n_cores": os.cpu_count(),
            "single_warm_s": r["single_warm_s"],
            "batched_warm_s": r["sharded_warm_s"],
            "sharded_speedup": ratio,
            "speedup_warm": min(ratio, _SHARDED_GATE_CAP)}


def _time_chunked_dense_1m(repeat=2):
    """10^6-point dense sweep, streamed under the 2 GiB memory budget.

    The chunked run (default budget -> two 512k-point chunks at the
    4 KiB/point model estimate) must be bit-identical to the unchunked
    single-dispatch run; the gated ratio unchunked/chunked (~1x) watches
    for chunking overhead creeping in.
    """
    import numpy as np

    from repro.sim import DispatchConfig, evaluate_grid, mu_rho_grid

    grid = mu_rho_grid(list(np.linspace(30.0, 600.0, 1000)),
                       list(np.linspace(1.0, 10.0, 1000)))
    unchunked = DispatchConfig(shard=False, memory_budget_bytes=1 << 40)
    chunked = DispatchConfig(shard=False)    # default 2 GiB budget

    t0 = time.perf_counter()
    ref = evaluate_grid(grid, dispatch=chunked)
    cold_s = time.perf_counter() - t0
    out = evaluate_grid(grid, dispatch=unchunked)
    for f in ("T_time", "T_energy", "time_ratio", "energy_ratio"):
        a, b = np.asarray(getattr(ref, f)), np.asarray(getattr(out, f))
        assert np.array_equal(a, b, equal_nan=True), \
            f"chunked 1M sweep diverged from unchunked on {f}"
    chunked_s = _best_of(lambda: evaluate_grid(grid, dispatch=chunked),
                         repeat)
    unchunked_s = _best_of(lambda: evaluate_grid(grid, dispatch=unchunked),
                           repeat)
    return {"n_points": 1_000_000,
            "memory_budget_bytes": DispatchConfig().budget(),
            "unchunked_warm_s": unchunked_s,
            "batched_cold_s": cold_s,
            "batched_warm_s": chunked_s,
            "speedup_warm": unchunked_s / chunked_s}


_COLD_START_SCRIPT = r"""
import os, sys, time
sys.path.insert(0, r"%(src)s")
import numpy as np
from repro.sim import enable_compile_cache
enable_compile_cache(r"%(cache)s")
from repro.sim import mu_rho_grid, evaluate_grid, ParamGrid, \
    simulate_trajectories
from repro.core import fig12_checkpoint, EXASCALE_POWER_RHO55
from repro.core.failures import Weibull

t0 = time.perf_counter()
evaluate_grid(mu_rho_grid([30, 60, 90, 120, 180, 240, 300, 420, 600],
                          list(np.linspace(1.0, 10.0, 10))))
base = ParamGrid.from_params(fig12_checkpoint(300.0), EXASCALE_POWER_RHO55)
mus = np.linspace(120.0, 600.0, 12)
grid = ParamGrid(**{f: (mus if f == "mu" else np.broadcast_to(v, (12,)))
                    for f, v in base.fields().items()})
simulate_trajectories(60.0, grid, 1500.0, n_trials=128, seed=0,
                      process=Weibull(shape=0.7))
print("COLD_S", time.perf_counter() - t0)
"""


def _time_async_overlap_collapse(repeat=5):
    """Async-flush payoff on the model itself: as the deep-flush overlap
    ``omega2`` -> 1 the PFS write leaves the critical path — the time
    overhead above failure-free execution collapses and the jointly
    time-optimal deep cadence m* drops to 1 (flush every period) — while
    the energy-optimal point barely moves: the I/O energy is paid for
    the full write whether or not it overlaps, so overlap *widens* the
    time-vs-energy tension instead of dissolving it.

    The gated ``speedup_warm`` is the DETERMINISTIC overhead collapse
    ``overhead(omega2=0) / overhead(omega2=1)`` — pure model arithmetic,
    identical on every machine, so this entry pins the per-level omega
    model rather than a timing; the warm solve time rides along for the
    table."""
    from repro.core import model, optimal
    from repro.core.params import (MultilevelCheckpointParams,
                                   MultilevelPowerParams)

    pw = MultilevelPowerParams(P_static=10.0, P_cal=10.0, P_io1=20.0,
                               P_io2=100.0)
    grid = [0.0, 0.5, 0.9, 1.0]

    def solve():
        rows = []
        for w2 in grid:
            ck = MultilevelCheckpointParams(C1=1.0, R1=1.0, C2=10.0,
                                            R2=10.0, D1=0.5, D2=1.0,
                                            mu=300.0, q=0.1, omega=0.0,
                                            omega2=w2)
            T_t, m_t = optimal.t_opt_time_multilevel(ck)
            T_e, m_e = optimal.t_opt_energy_multilevel(ck, pw)
            overhead = float(model.ml_time_final(T_t, m_t, ck)) - 1.0
            e_pen = (float(model.ml_energy_final(T_t, m_t, ck, pw))
                     / float(model.ml_energy_final(T_e, m_e, ck, pw)) - 1.0)
            rows.append((T_t, m_t, T_e, m_e, overhead, e_pen))
        return rows

    warm_s = _best_of(solve, repeat)
    rows = solve()
    overheads = [r[4] for r in rows]
    if not all(b < a for a, b in zip(overheads, overheads[1:])):
        raise AssertionError(
            f"time overhead must fall monotonically as omega2 -> 1, got "
            f"{overheads} (per-level omega model broken?)")
    return {
        "omega2_grid": grid,
        "T_opt_time": [round(r[0], 6) for r in rows],
        "m_opt_time": [r[1] for r in rows],
        "T_opt_energy": [round(r[2], 6) for r in rows],
        "m_opt_energy": [r[3] for r in rows],
        "time_overhead": [round(r[4], 9) for r in rows],
        "energy_penalty_at_time_opt": [round(r[5], 9) for r in rows],
        "batched_warm_s": warm_s,
        "speedup_warm": overheads[0] / overheads[-1],
    }


def _time_cold_start_cached():
    """Persistent-compile-cache cold start: two fresh interpreters, one
    cache directory.

    The first run compiles everything and populates the cache; the second
    pays tracing/lowering but loads the serialized executables.  The
    gated ratio uncached/cached is the once-per-machine-vs-once-per-
    process compile story (``repro.sim.cache``); it is measured entirely
    inside the subprocesses (jax import time excluded).
    """
    def one(cache_dir):
        script = _COLD_START_SCRIPT % {"src": str(ROOT / "src"),
                                       "cache": cache_dir}
        out = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=1200)
        if out.returncode != 0:
            raise RuntimeError(f"cold-start subprocess failed:\n"
                               f"{out.stderr[-3000:]}")
        line = [ln for ln in out.stdout.splitlines()
                if ln.startswith("COLD_S")][-1]
        return float(line.split()[1])

    with tempfile.TemporaryDirectory(prefix="repro-compile-cache-") as d:
        uncached_s = one(d)      # populates the cache
        cached_s = one(d)        # second process: cache hits
    return {"cold_uncached_s": uncached_s,
            "batched_cold_s": cached_s,
            "batched_warm_s": cached_s,
            "speedup_warm": uncached_s / cached_s}


def run(write: bool = True):
    import numpy as np

    seed_grid = _time_pair(SEED_MUS, list(np.linspace(1.0, 10.0, 10)),
                           scalar_repeat=5, batched_repeat=10)
    dense_grid = _time_pair(list(np.linspace(30.0, 600.0, 96)),
                            list(np.linspace(1.0, 10.0, 100)),
                            scalar_repeat=1, batched_repeat=3)
    weibull_step_ref = _time_weibull_step_engine_reference()
    weibull_event_engine = _time_weibull_event_engine()
    pallas_event_engine = _time_pallas_event_engine()
    mc_solver_warm = _time_mc_solver()
    chunked_dense_1m = _time_chunked_dense_1m()
    sharded_dense_grid = _time_sharded_dense()
    cold_start_cached = _time_cold_start_cached()
    async_overlap_collapse = _time_async_overlap_collapse()
    from .bench_advisor import time_advisor_regimes, time_advisor_rps
    advisor_rps = time_advisor_rps()
    advisor_load_regimes = time_advisor_regimes()
    payload = {
        "benchmark": "fig2_mu_rho_sweep",
        "unit": "seconds",
        "fig2_seed_grid": seed_grid,
        "dense_grid": dense_grid,
        "weibull_step_engine_reference": weibull_step_ref,
        "weibull_event_engine": weibull_event_engine,
        "pallas_event_engine": pallas_event_engine,
        "mc_solver_warm": mc_solver_warm,
        "sharded_dense_grid": sharded_dense_grid,
        "chunked_dense_1m": chunked_dense_1m,
        "cold_start_cached": cold_start_cached,
        "async_overlap_collapse": async_overlap_collapse,
        "advisor_rps": advisor_rps,
        "advisor_load_regimes": advisor_load_regimes,
    }
    if write:
        # Carry forward baseline keys owned by other tools (e.g. the
        # recompile_budget entry written by `python -m repro.sanitize
        # --write`) — regenerating the timing baseline must not drop
        # them.
        try:
            with open(CANONICAL) as f:
                prev = json.load(f)
        except (OSError, json.JSONDecodeError):
            prev = {}
        for k, v in prev.items():
            payload.setdefault(k, v)
        with open(CANONICAL, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return payload


def write_timing_table(payload: dict, path=None) -> str:
    """Render the payload as a warm/cold timing table
    (``benchmarks/results/bench_sweep_table.md``, uploaded as a CI
    artifact next to the raw JSON)."""
    from ._util import RESULTS
    if path is None:
        path = RESULTS / "bench_sweep_table.md"
    lines = ["# bench_sweep timings",
             "",
             "| grid | cold (s) | warm (s) | reference (s) | speedup_warm |",
             "|---|---|---|---|---|"]
    for grid, entry in payload.items():
        if not (isinstance(entry, dict) and "speedup_warm" in entry):
            continue
        ref = next((entry[k] for k in ("scalar_s", "exp_warm_s",
                                       "step_warm_s", "event_warm_s",
                                       "single_warm_s",
                                       "unchunked_warm_s",
                                       "cold_uncached_s", "naive_s")
                    if k in entry),
                   float("nan"))
        cold = entry.get("batched_cold_s")
        tag = " (ungated ref)" if entry.get("ungated") else ""
        lines.append(
            f"| {grid}{tag} | {'—' if cold is None else format(cold, '.4g')} "
            f"| {entry['batched_warm_s']:.4g} | {ref:.4g} "
            f"| {entry['speedup_warm']:.2f}x |")
    text = "\n".join(lines) + "\n"
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(text)
    return str(path)


def check_regression(baseline: dict, payload: dict,
                     factor: float = REGRESSION_FACTOR) -> list:
    """Warm-timing regressions of ``payload`` vs ``baseline`` (> factor x).

    The compared quantity is ``speedup_warm`` — the batched path's warm
    speedup over the scalar path *measured in the same run* — so the gate
    is machine-normalized: a CI runner that is uniformly slower than the
    machine that committed the baseline shifts both numerators and
    denominators and passes, while a real batched-path regression drops
    the speedup and fails.  Pure comparison (no timing) so the CI gate
    logic is unit-testable.

    Entries carrying ``"ungated": true`` are reference measurements
    excluded from the gate BY DESIGN (in both directions) — e.g.
    ``weibull_step_engine_reference``, which reads ~0.3x by construction
    because it measures the retained step kernel the event kernel
    replaced.
    """
    def gated(entry) -> bool:
        return (isinstance(entry, dict) and "speedup_warm" in entry
                and not entry.get("ungated"))

    regressions = []
    # The gate set must match in BOTH directions.  A grid the committed
    # baseline gates must be present in the payload — a renamed/dropped
    # bench disables its gate and must fail loudly, not pass silently.
    for grid in sorted(baseline):
        if not gated(baseline[grid]):
            continue
        if not gated(payload.get(grid)):
            regressions.append(
                f"{grid}: present in the committed baseline but missing "
                f"from this run's payload — bench renamed/dropped without "
                f"regenerating BENCH_sweep.json?")
            continue
        base = baseline[grid]["speedup_warm"]
        now = payload[grid]["speedup_warm"]
        if now * factor < base:
            regressions.append(
                f"{grid}: speedup_warm {now:.1f}x is {base / now:.1f}x "
                f"below the baseline {base:.1f}x (limit {factor:g}x)")
    # ...and a gated grid the payload produces must be baselined — an
    # unbaselined bench is an ungated bench, which silently exempts every
    # future regression of that path.
    for grid in sorted(payload):
        if gated(payload[grid]) and not gated(baseline.get(grid)):
            regressions.append(
                f"{grid}: gated entry missing from the committed baseline "
                f"— regenerate BENCH_sweep.json (standalone bench_sweep "
                f"run) to baseline the new bench")
    return regressions


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="compare against the committed baseline instead of "
                         "rewriting it; exit non-zero on regression")
    ap.add_argument("--no-write", action="store_true",
                    help="measure and report only; leave the committed "
                         "baseline untouched (used by benchmarks.run)")
    args = ap.parse_args(argv)

    wrote = not (args.check or args.no_write)
    payload = run(write=wrote)
    table = write_timing_table(payload)
    s, d, ev, mc = (payload["fig2_seed_grid"], payload["dense_grid"],
                    payload["weibull_event_engine"],
                    payload["mc_solver_warm"])
    sh, ch, cc = (payload["sharded_dense_grid"],
                  payload["chunked_dense_1m"],
                  payload["cold_start_cached"])
    ad = payload["advisor_rps"]
    emit("bench_sweep", s["batched_warm_s"] * 1e6,
         f"fig2 {s['n_points']}pts speedup={s['speedup_warm']:.1f}x; "
         f"dense {d['n_points']}pts speedup={d['speedup_warm']:.1f}x; "
         f"event vs scalar={ev['speedup_warm']:.1f}x; "
         f"pallas vs event="
         f"{payload['pallas_event_engine']['speedup_warm']:.2f}x; "
         f"mc solver step/event={mc['speedup_warm']:.1f}x; "
         f"sharded x{sh['n_devices']}dev={sh['speedup_warm']:.2f}x; "
         f"chunked 1M={ch['speedup_warm']:.2f}x; "
         f"cold-start cached={cc['speedup_warm']:.2f}x; "
         f"advisor {ad['rps']:.0f} rps={ad['speedup_warm']:.0f}x "
         + (f"-> BENCH_sweep.json + {table}" if wrote
            else f"-> {table} (baseline untouched)"))

    if args.check:
        baseline = json.loads(CANONICAL.read_text())
        regressions = check_regression(baseline, payload)
        if regressions:
            raise SystemExit("benchmark regression gate FAILED:\n  "
                             + "\n  ".join(regressions))
        print(f"bench_sweep --check OK: warm speedups within "
              f"{REGRESSION_FACTOR:g}x of the committed baseline")


if __name__ == "__main__":
    main()
