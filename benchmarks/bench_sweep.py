"""Scalar vs batched Figure-2 sweep timing -> BENCH_sweep.json.

Times the seed per-point loop (``tradeoff.sweep_mu_rho(engine="scalar")``)
against the batched ``repro.sim`` grid evaluation on (a) the seed benchmark
grid and (b) a dense production-resolution grid, and records the numbers in
``BENCH_sweep.json`` at the repo root (plus a copy under
``benchmarks/results/``).  Acceptance target: >= 10x on the Fig. 2 sweep.
"""
import json
import time
from pathlib import Path

from ._util import emit, RESULTS

SEED_MUS = [30, 60, 90, 120, 180, 240, 300, 420, 600]
ROOT = Path(__file__).resolve().parents[1]


def _best_of(fn, repeat):
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _time_pair(mus, rhos, scalar_repeat, batched_repeat):
    import numpy as np
    from repro.core.tradeoff import sweep_mu_rho
    from repro.sim import sweep_mu_rho_grid

    scalar_s = _best_of(lambda: sweep_mu_rho(mus, rhos, engine="scalar"),
                        scalar_repeat)
    t0 = time.perf_counter()
    res = sweep_mu_rho_grid(mus, rhos)
    cold_s = time.perf_counter() - t0
    batched_s = _best_of(lambda: sweep_mu_rho_grid(mus, rhos), batched_repeat)

    # Cross-check the two paths agree before trusting the timing.
    ref = sweep_mu_rho(mus, rhos, engine="scalar")
    err = max(abs(res.energy_ratio[i][j] - ref[i][j].energy_ratio)
              for i in range(len(mus)) for j in range(len(rhos)))
    assert err < 1e-9, f"scalar/batched sweep disagree: {err}"

    return {"n_points": len(mus) * len(rhos),
            "scalar_s": scalar_s,
            "batched_cold_s": cold_s,
            "batched_warm_s": batched_s,
            "speedup_warm": scalar_s / batched_s}


def run():
    import numpy as np

    seed_grid = _time_pair(SEED_MUS, list(np.linspace(1.0, 10.0, 10)),
                           scalar_repeat=5, batched_repeat=10)
    dense_grid = _time_pair(list(np.linspace(30.0, 600.0, 96)),
                            list(np.linspace(1.0, 10.0, 100)),
                            scalar_repeat=1, batched_repeat=3)
    payload = {
        "benchmark": "fig2_mu_rho_sweep",
        "unit": "seconds",
        "fig2_seed_grid": seed_grid,
        "dense_grid": dense_grid,
    }
    for path in (ROOT / "BENCH_sweep.json", RESULTS / "BENCH_sweep.json"):
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
    return payload


def main():
    payload = run()
    s, d = payload["fig2_seed_grid"], payload["dense_grid"]
    emit("bench_sweep", s["batched_warm_s"] * 1e6,
         f"fig2 {s['n_points']}pts speedup={s['speedup_warm']:.1f}x; "
         f"dense {d['n_points']}pts speedup={d['speedup_warm']:.1f}x "
         f"-> BENCH_sweep.json")


if __name__ == "__main__":
    main()
