"""Scalar vs batched sweep/engine timings -> BENCH_sweep.json (+ CI gate).

Times the seed per-point loop (``tradeoff.sweep_mu_rho(engine="scalar")``)
against the batched ``repro.sim`` grid evaluation on (a) the seed benchmark
grid and (b) a dense production-resolution grid; plus the Monte-Carlo
engine entries: the exponential-vs-Weibull within-engine ratio
(``weibull_engine``), the event kernel vs the scalar oracle on the same
Weibull workload (``weibull_event_engine`` — the PR-4 before/after story
for the committed 0.32x step-kernel entry), and the warm MC-surrogate
solve step-vs-event (``mc_solver_warm``).  Every run also renders the
warm/cold timings as ``benchmarks/results/bench_sweep_table.md`` (uploaded
as a CI artifact).

The canonical artifact is ``BENCH_sweep.json`` at the repo root — the
committed baseline the CI regression gate compares against.  There is
deliberately no second copy under ``benchmarks/results/``.

Modes:
  python -m benchmarks.bench_sweep           # measure + rewrite the baseline
  python -m benchmarks.bench_sweep --check   # measure, compare the warm
                                             # scalar-vs-batched speedup
                                             # against the committed baseline,
                                             # exit non-zero on a >2x drop
                                             # (machine-normalized; baseline
                                             # file left untouched)

Note: regenerate the committed baseline ONLY with a standalone bench_sweep
run.  ``benchmarks.run`` invokes this module with ``--no-write`` — its jit
cache is pre-warmed by the other figure benches, which would record a
meaninglessly small ``batched_cold_s`` into the baseline.
"""
import argparse
import json
import time
from pathlib import Path

from ._util import emit

SEED_MUS = [30, 60, 90, 120, 180, 240, 300, 420, 600]
ROOT = Path(__file__).resolve().parents[1]
#: the one canonical timing artifact (committed baseline for --check).
CANONICAL = ROOT / "BENCH_sweep.json"
#: >2x warm-timing slowdown vs the committed baseline fails the CI job.
REGRESSION_FACTOR = 2.0


def _best_of(fn, repeat):
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _time_pair(mus, rhos, scalar_repeat, batched_repeat):
    from repro.core.tradeoff import sweep_mu_rho
    from repro.sim import sweep_mu_rho_grid

    scalar_s = _best_of(lambda: sweep_mu_rho(mus, rhos, engine="scalar"),
                        scalar_repeat)
    t0 = time.perf_counter()
    res = sweep_mu_rho_grid(mus, rhos)
    cold_s = time.perf_counter() - t0
    batched_s = _best_of(lambda: sweep_mu_rho_grid(mus, rhos), batched_repeat)

    # Cross-check the two paths agree before trusting the timing.
    ref = sweep_mu_rho(mus, rhos, engine="scalar")
    err = max(abs(res.energy_ratio[i][j] - ref[i][j].energy_ratio)
              for i in range(len(mus)) for j in range(len(rhos)))
    assert err < 1e-9, f"scalar/batched sweep disagree: {err}"

    return {"n_points": len(mus) * len(rhos),
            "scalar_s": scalar_s,
            "batched_cold_s": cold_s,
            "batched_warm_s": batched_s,
            "speedup_warm": scalar_s / batched_s}


def _weibull_workload(n_points=12, n_trials=128, shape=0.7):
    """The canonical non-exponential engine workload: a mixed-mu exascale
    grid (the regime where cv-scaled step budgets used to blow up)."""
    import numpy as np

    from repro.core import fig12_checkpoint, EXASCALE_POWER_RHO55
    from repro.core.failures import Weibull
    from repro.sim import ParamGrid

    mus = np.linspace(120.0, 600.0, n_points)
    base = ParamGrid.from_params(fig12_checkpoint(300.0),
                                 EXASCALE_POWER_RHO55)
    grid = ParamGrid(**{f: (mus if f == "mu"
                            else np.broadcast_to(v, (n_points,)))
                        for f, v in base.fields().items()})
    return grid, Weibull(shape=shape), 60.0, 1500.0, n_trials


def _time_weibull_engine(n_points=12, n_trials=128, shape=0.7, repeat=5):
    """Batched NON-exponential engine path vs the batched exponential path.

    Runs ``sim.simulate_trajectories`` (the default event kernel) on the
    same grid/trials twice — once with auto-sampled exponential schedules,
    once with Weibull ones — and reports the within-run ratio.  The ratio
    is what the CI gate watches (via the shared ``speedup_warm`` key): it
    is machine-normalized, and it regresses exactly when the
    non-exponential sampling/budget path bloats relative to the engine's
    baseline cost.  (With the PR-3 step kernel this measured 0.32x — the
    cv^2-scaled step budget made Weibull ~3x slower than exponential; the
    event kernel's scan length scales with the failure count instead.)
    """
    from repro.sim.engine import simulate_trajectories

    grid, proc, T, T_base, n_trials = _weibull_workload(n_points, n_trials,
                                                        shape)

    def run_exp():
        return simulate_trajectories(T, grid, T_base, n_trials=n_trials,
                                     seed=0)

    def run_weibull():
        return simulate_trajectories(T, grid, T_base, n_trials=n_trials,
                                     seed=0, process=proc)

    t0 = time.perf_counter()
    run_weibull()
    weibull_cold_s = time.perf_counter() - t0
    run_exp()                              # warm the exponential program too
    weibull_warm_s = _best_of(run_weibull, repeat)
    exp_warm_s = _best_of(run_exp, repeat)
    return {"n_points": n_points, "n_trials": n_trials,
            "weibull_shape": shape,
            "exp_warm_s": exp_warm_s,
            "batched_cold_s": weibull_cold_s,
            "batched_warm_s": weibull_warm_s,
            # exponential-vs-weibull within-run ratio; gated like the other
            # grids' speedups (a >2x drop = the new path got >2x slower
            # relative to the exponential engine baseline).
            "speedup_warm": exp_warm_s / weibull_warm_s}


def _time_weibull_event_engine(n_points=12, n_trials=128, shape=0.7,
                               repeat=5):
    """Event engine vs the SCALAR oracle on the Weibull workload.

    This is the PR-4 before/after story: on exactly the 12-point/128-trial
    workload where the step kernel measured 0.32x against the scalar
    per-trajectory loop, the event kernel must win outright
    (``speedup_warm`` = scalar / event-warm; the acceptance floor is 5x).
    """
    from repro.core.simulator import simulate
    from repro.sim.engine import simulate_trajectories

    grid, proc, T, T_base, n_trials = _weibull_workload(n_points, n_trials,
                                                        shape)

    def run_scalar():
        for i in range(grid.size):
            simulate(T, grid.ckpt_at(i), grid.power_at(i), T_base,
                     n_trials=n_trials, seed=0, process=proc)

    def run_event():
        return simulate_trajectories(T, grid, T_base, n_trials=n_trials,
                                     seed=0, process=proc)

    # No cold figure here: _time_weibull_engine already compiled these
    # exact programs, so a "cold" measurement in this entry would be
    # warm-started ~30x too fast (weibull_engine.batched_cold_s is the
    # honest compile cost of the same programs).
    run_event()
    event_warm_s = _best_of(run_event, repeat)
    scalar_s = _best_of(run_scalar, 1)     # the python loop needs no warmup
    return {"n_points": grid.size, "n_trials": n_trials,
            "weibull_shape": shape,
            "scalar_s": scalar_s,
            "batched_warm_s": event_warm_s,
            "speedup_warm": scalar_s / event_warm_s}


def _time_mc_solver(repeat=3):
    """Warm MC-surrogate solve: event kernel vs the retained step kernel.

    Both solves share the same CRN schedules and converge to the same
    period; the within-run step/event ratio is machine-normalized and
    regresses exactly when the event hot path (candidate-vmap + per-call
    dispatch) loses ground to the step machine.
    """
    from repro.core import fig12_checkpoint, EXASCALE_POWER_RHO55
    from repro.core.failures import Weibull
    from repro.core.optimal import MCSurrogate

    ck = fig12_checkpoint(300.0)
    proc = Weibull(shape=0.7)

    def solve(kind):
        return MCSurrogate(ck, EXASCALE_POWER_RHO55, proc, T_base=1500.0,
                           n_trials=96, seed=0,
                           engine_kind=kind).argmin("time")

    t0 = time.perf_counter()
    t_event = solve("event")
    event_cold_s = time.perf_counter() - t0
    t_step = solve("step")                 # warms the step programs
    # The two kernels share schedules but not arithmetic; a ~1e-13 tie in
    # a golden-section branch can wiggle the argmin, so gate at the MC
    # solvers' own agreement tolerance rather than exact equality.
    assert abs(t_event - t_step) <= 5e-3 * t_step, (t_event, t_step)
    event_warm_s = _best_of(lambda: solve("event"), repeat)
    step_warm_s = _best_of(lambda: solve("step"), repeat)
    return {"n_trials": 96, "weibull_shape": 0.7,
            "step_warm_s": step_warm_s,
            "batched_cold_s": event_cold_s,
            "batched_warm_s": event_warm_s,
            "speedup_warm": step_warm_s / event_warm_s}


def run(write: bool = True):
    import numpy as np

    seed_grid = _time_pair(SEED_MUS, list(np.linspace(1.0, 10.0, 10)),
                           scalar_repeat=5, batched_repeat=10)
    dense_grid = _time_pair(list(np.linspace(30.0, 600.0, 96)),
                            list(np.linspace(1.0, 10.0, 100)),
                            scalar_repeat=1, batched_repeat=3)
    weibull_engine = _time_weibull_engine()
    weibull_event_engine = _time_weibull_event_engine()
    mc_solver_warm = _time_mc_solver()
    payload = {
        "benchmark": "fig2_mu_rho_sweep",
        "unit": "seconds",
        "fig2_seed_grid": seed_grid,
        "dense_grid": dense_grid,
        "weibull_engine": weibull_engine,
        "weibull_event_engine": weibull_event_engine,
        "mc_solver_warm": mc_solver_warm,
    }
    if write:
        with open(CANONICAL, "w") as f:
            json.dump(payload, f, indent=2)
    return payload


def write_timing_table(payload: dict, path=None) -> str:
    """Render the payload as a warm/cold timing table
    (``benchmarks/results/bench_sweep_table.md``, uploaded as a CI
    artifact next to the raw JSON)."""
    from ._util import RESULTS
    if path is None:
        path = RESULTS / "bench_sweep_table.md"
    lines = ["# bench_sweep timings",
             "",
             "| grid | cold (s) | warm (s) | reference (s) | speedup_warm |",
             "|---|---|---|---|---|"]
    for grid, entry in payload.items():
        if not (isinstance(entry, dict) and "speedup_warm" in entry):
            continue
        ref = next((entry[k] for k in ("scalar_s", "exp_warm_s",
                                       "step_warm_s") if k in entry),
                   float("nan"))
        cold = entry.get("batched_cold_s")
        lines.append(
            f"| {grid} | {'—' if cold is None else format(cold, '.4g')} "
            f"| {entry['batched_warm_s']:.4g} | {ref:.4g} "
            f"| {entry['speedup_warm']:.2f}x |")
    text = "\n".join(lines) + "\n"
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(text)
    return str(path)


def check_regression(baseline: dict, payload: dict,
                     factor: float = REGRESSION_FACTOR) -> list:
    """Warm-timing regressions of ``payload`` vs ``baseline`` (> factor x).

    The compared quantity is ``speedup_warm`` — the batched path's warm
    speedup over the scalar path *measured in the same run* — so the gate
    is machine-normalized: a CI runner that is uniformly slower than the
    machine that committed the baseline shifts both numerators and
    denominators and passes, while a real batched-path regression drops
    the speedup and fails.  Pure comparison (no timing) so the CI gate
    logic is unit-testable.
    """
    def gated(entry) -> bool:
        return isinstance(entry, dict) and "speedup_warm" in entry

    regressions = []
    # The gate set must match in BOTH directions.  A grid the committed
    # baseline gates must be present in the payload — a renamed/dropped
    # bench disables its gate and must fail loudly, not pass silently.
    for grid in sorted(baseline):
        if not gated(baseline[grid]):
            continue
        if not gated(payload.get(grid)):
            regressions.append(
                f"{grid}: present in the committed baseline but missing "
                f"from this run's payload — bench renamed/dropped without "
                f"regenerating BENCH_sweep.json?")
            continue
        base = baseline[grid]["speedup_warm"]
        now = payload[grid]["speedup_warm"]
        if now * factor < base:
            regressions.append(
                f"{grid}: speedup_warm {now:.1f}x is {base / now:.1f}x "
                f"below the baseline {base:.1f}x (limit {factor:g}x)")
    # ...and a gated grid the payload produces must be baselined — an
    # unbaselined bench is an ungated bench, which silently exempts every
    # future regression of that path.
    for grid in sorted(payload):
        if gated(payload[grid]) and not gated(baseline.get(grid)):
            regressions.append(
                f"{grid}: gated entry missing from the committed baseline "
                f"— regenerate BENCH_sweep.json (standalone bench_sweep "
                f"run) to baseline the new bench")
    return regressions


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="compare against the committed baseline instead of "
                         "rewriting it; exit non-zero on regression")
    ap.add_argument("--no-write", action="store_true",
                    help="measure and report only; leave the committed "
                         "baseline untouched (used by benchmarks.run)")
    args = ap.parse_args(argv)

    wrote = not (args.check or args.no_write)
    payload = run(write=wrote)
    table = write_timing_table(payload)
    s, d, w, ev, mc = (payload["fig2_seed_grid"], payload["dense_grid"],
                       payload["weibull_engine"],
                       payload["weibull_event_engine"],
                       payload["mc_solver_warm"])
    emit("bench_sweep", s["batched_warm_s"] * 1e6,
         f"fig2 {s['n_points']}pts speedup={s['speedup_warm']:.1f}x; "
         f"dense {d['n_points']}pts speedup={d['speedup_warm']:.1f}x; "
         f"weibull engine {w['n_points']}x{w['n_trials']} "
         f"exp/weibull={w['speedup_warm']:.2f}x; "
         f"event vs scalar={ev['speedup_warm']:.1f}x; "
         f"mc solver step/event={mc['speedup_warm']:.1f}x "
         + (f"-> BENCH_sweep.json + {table}" if wrote
            else f"-> {table} (baseline untouched)"))

    if args.check:
        baseline = json.loads(CANONICAL.read_text())
        regressions = check_regression(baseline, payload)
        if regressions:
            raise SystemExit("benchmark regression gate FAILED:\n  "
                             + "\n  ".join(regressions))
        print(f"bench_sweep --check OK: warm speedups within "
              f"{REGRESSION_FACTOR:g}x of the committed baseline")


if __name__ == "__main__":
    main()
