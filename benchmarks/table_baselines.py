"""Baseline comparison (paper SS3.2 side note): AlgoT / AlgoE vs Young, Daly
and the Meneses-Sarood-Kale energy model, plus the printed-coefficient
erratum demonstration."""
from ._util import emit, timed, RESULTS


def run():
    from repro.core import (fig12_checkpoint, EXASCALE_POWER_RHO55,
                            EXASCALE_POWER_RHO7, t_opt_time, t_opt_energy,
                            t_young, t_daly, t_msk_energy, time_final,
                            energy_final, energy_quadratic_coefficients,
                            paper_printed_coefficients)
    from repro.core.optimal import derived_coefficients

    rows = []
    for mu in (300.0, 120.0, 60.0):
        ck = fig12_checkpoint(mu)
        pw = EXASCALE_POWER_RHO55
        periods = {
            "algo_t": t_opt_time(ck),
            "algo_e": t_opt_energy(ck, pw),
            "young": t_young(ck),
            "daly": t_daly(ck),
            "msk_energy": t_msk_energy(ck, pw),
        }
        for name, T in periods.items():
            rows.append((mu, name, T, float(time_final(T, ck)),
                         float(energy_final(T, ck, pw))))
    out = RESULTS / "table_baselines.csv"
    with open(out, "w") as f:
        f.write("mu_min,strategy,period_min,T_final_norm,E_final_norm\n")
        for r in rows:
            f.write(f"{r[0]},{r[1]},{r[2]:.4f},{r[3]:.6f},{r[4]:.6f}\n")

    # erratum: paper coefficients wrong when alpha != 1
    ck = fig12_checkpoint(300.0)
    ours = derived_coefficients(ck, EXASCALE_POWER_RHO7)
    paper = paper_printed_coefficients(ck, EXASCALE_POWER_RHO7)
    exact = energy_quadratic_coefficients(ck, EXASCALE_POWER_RHO7)
    err_paper = abs(paper[0] - exact[0]) / abs(exact[0])
    err_ours = abs(ours[0] - exact[0]) / abs(exact[0])
    return out, (err_paper, err_ours)


def main():
    (out, (ep, eo)), us = timed(run, repeat=1)
    emit("table_baselines", us,
         f"erratum@rho7: paper_c2_err={ep:.2%} derived_c2_err={eo:.2e} "
         f"-> {out.name}")


if __name__ == "__main__":
    main()
