"""Advisor serving benchmarks: micro-batched burst + open-loop regimes.

Two measurements, both consumed by ``bench_sweep`` for the committed
``BENCH_sweep.json`` baseline:

``advisor_rps`` (gated)
    A 512-request synthetic burst of DISTINCT single-level platforms,
    answered by one warm ``advise_many`` call — asserted to issue exactly
    ONE dispatched solve and to be bit-identical to the naive
    one-solve-per-request loop it replaces.  The gated ``speedup_warm``
    is naive/batched measured in the same run (machine-normalized, like
    every other gate); the acceptance floor is 20x.  Requests/sec and
    the open-loop p50/p99 ride along in the entry.

``advisor_load_regimes`` (ungated)
    Open-loop load-generator runs across batch-window x workload-repeat
    regimes: requests/sec, p50/p99 latency and fingerprint-cache hit
    rate per regime.  Absolute latencies are machine-dependent, hence no
    gate — the regression story lives in ``advisor_rps``.

Standalone:
  python -m benchmarks.bench_advisor    # measure + print (writes nothing)
"""
import time

from ._util import emit

#: burst size of the gated entry (the acceptance criterion's 512).
BURST = 512
#: (batch_window_s, repeat_frac) grid of the ungated open-loop entry.
REGIMES = ((0.0, 0.0), (0.0, 0.8), (2e-3, 0.0), (2e-3, 0.8))
_REGIME_N = 256
_REGIME_RATE_HZ = 4000.0


def _best_of(fn, repeat):
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _burst_requests():
    from repro.serve import synthetic_requests

    reqs = synthetic_requests(BURST, seed=42, two_tier_frac=0.0,
                              repeat_frac=0.0)
    assert len(reqs) == BURST
    return reqs


def time_advisor_rps(repeat=3):
    """The gated burst entry (see module docstring)."""
    import numpy as np

    from repro.serve import AdvisorService, ThreadedAdvisor, run_open_loop

    reqs = _burst_requests()

    # -- batched: one advise_many call, one dispatched solve ---------------
    svc = AdvisorService(cache_name=None)
    t0 = time.perf_counter()
    batched = svc.advise_many(reqs)
    cold_s = time.perf_counter() - t0
    m = svc.metrics()
    assert m["dispatched_solves"] == 1, \
        f"burst took {m['dispatched_solves']} dispatched solves, wanted 1"

    def batched_once():
        return AdvisorService(cache_name=None).advise_many(reqs)

    batched_s = _best_of(batched_once, repeat)

    # -- naive: one solve per request --------------------------------------
    naive_svc = AdvisorService(cache_name=None)
    naive = [naive_svc.advise(r) for r in reqs]      # also warms the path
    n_naive = naive_svc.metrics()["dispatched_solves"]
    assert n_naive == BURST, f"naive loop solved {n_naive}x, wanted {BURST}"
    for a, b in zip(batched, naive):
        assert a.period == b.period and a.deep_every == b.deep_every \
            and (a.predicted_energy == b.predicted_energy
                 or (np.isnan(a.predicted_energy)
                     and np.isnan(b.predicted_energy))), \
            "batched advisor diverged from the naive per-request loop"

    def naive_once():
        s = AdvisorService(cache_name=None)
        for r in reqs:
            s.advise(r)

    naive_s = _best_of(naive_once, max(1, repeat - 1))

    # -- open-loop latency of the same burst shape -------------------------
    with ThreadedAdvisor(AdvisorService(cache_name=None),
                         batch_window_s=2e-3, max_batch=BURST) as advisor:
        rep = run_open_loop(advisor, reqs, rate_hz=_REGIME_RATE_HZ,
                            warmup=_burst_requests()[:32])

    return {"n_requests": BURST,
            "naive_s": naive_s,
            "batched_cold_s": cold_s,
            "batched_warm_s": batched_s,
            "rps": BURST / batched_s,
            "open_loop_rps": rep.rps,
            "p50_ms": rep.p50_ms,
            "p99_ms": rep.p99_ms,
            "speedup_warm": naive_s / batched_s}


def time_advisor_regimes():
    """The ungated batch-window x cache-hit-rate open-loop sweep."""
    from repro.serve import (AdvisorService, ThreadedAdvisor, run_open_loop,
                             synthetic_requests)

    out = {"n_requests": _REGIME_N, "rate_hz": _REGIME_RATE_HZ,
           "ungated": True}
    for window_s, repeat_frac in REGIMES:
        reqs = synthetic_requests(_REGIME_N, seed=11, two_tier_frac=0.5,
                                  repeat_frac=repeat_frac)
        warm = synthetic_requests(32, seed=12, two_tier_frac=0.5)
        with ThreadedAdvisor(AdvisorService(cache_name=None),
                             batch_window_s=window_s) as advisor:
            rep = run_open_loop(advisor, reqs, rate_hz=_REGIME_RATE_HZ,
                                warmup=warm)
        key = f"window_{window_s * 1e3:g}ms_repeat_{repeat_frac:g}"
        out[key] = {"rps": rep.rps, "p50_ms": rep.p50_ms,
                    "p99_ms": rep.p99_ms, "hit_rate": rep.hit_rate,
                    "mean_window": rep.mean_window}
    return out


def main(argv=None):
    burst = time_advisor_rps()
    regimes = time_advisor_regimes()
    hot = regimes["window_2ms_repeat_0.8"]
    emit("bench_advisor", burst["batched_warm_s"] / BURST * 1e6,
         f"{BURST}-req burst {burst['rps']:.0f} rps "
         f"(speedup vs naive {burst['speedup_warm']:.0f}x); "
         f"open loop p50={burst['p50_ms']:.1f}ms "
         f"p99={burst['p99_ms']:.1f}ms; "
         f"2ms-window repeated workload {hot['rps']:.0f} rps "
         f"@ hit rate {hot['hit_rate']:.0%}")
    return {"advisor_rps": burst, "advisor_load_regimes": regimes}


if __name__ == "__main__":
    main()
