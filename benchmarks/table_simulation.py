"""Monte-Carlo validation of the closed forms: simulate the checkpointed
execution at the paper's scenario and compare E[T], E[E] to the model."""
from ._util import emit, timed, RESULTS


def run():
    from repro.core import (fig12_checkpoint, EXASCALE_POWER_RHO55,
                            t_opt_time, t_opt_energy, simulate, time_final,
                            energy_final)
    ck = fig12_checkpoint(300.0)
    pw = EXASCALE_POWER_RHO55
    rows = []
    for name, T in (("algo_t", t_opt_time(ck)),
                    ("algo_e", t_opt_energy(ck, pw)),
                    ("half_opt", 0.5 * t_opt_time(ck)),
                    ("twice_opt", 2.0 * t_opt_time(ck))):
        sim = simulate(T, ck, pw, T_base=4000.0, n_trials=400, seed=0)
        rows.append((name, T,
                     sim["T_final"], float(time_final(T, ck, 4000.0)),
                     sim["E_final"], float(energy_final(T, ck, pw, 4000.0))))
    out = RESULTS / "table_simulation.csv"
    with open(out, "w") as f:
        f.write("strategy,period,T_sim,T_model,E_sim,E_model\n")
        for r in rows:
            f.write(f"{r[0]},{r[1]:.3f},{r[2]:.2f},{r[3]:.2f},"
                    f"{r[4]:.1f},{r[5]:.1f}\n")
    errs = [abs(r[2] - r[3]) / r[3] for r in rows]
    return out, max(errs)


def main():
    (out, err), us = timed(run, repeat=1)
    emit("table_simulation", us, f"max |T_sim-T_model|/T = {err:.2%} -> {out.name}")


if __name__ == "__main__":
    main()
