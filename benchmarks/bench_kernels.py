"""Kernel microbenchmarks (interpret mode on CPU: correctness-path timing;
the CSV also reports achieved compression ratios / arithmetic sanity).

Besides the stdout rows, every run writes the same rows to
``benchmarks/results/bench_kernels.csv`` (ungated — CI uploads the
results dir as an artifact, so per-machine kernel timings ride along
without gating anything on interpret-mode absolute numbers).
"""
import jax
import jax.numpy as jnp

from ._util import RESULTS, emit, timed


def _emit_row(rows, name, us, derived):
    rows.append((name, us, derived))
    emit(name, us, derived)


def main():
    import numpy as np

    from repro.kernels import ops
    from repro.kernels.event_sweep import event_sweep
    from repro.sim.engine import enable_x64

    rows = []
    key = jax.random.key(0)
    B, S, H, Dh = 2, 512, 4, 128
    q = jax.random.normal(key, (B, S, H, Dh), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(1), (B, S, H, Dh), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (B, S, H, Dh), jnp.bfloat16)

    out, us = timed(lambda: jax.block_until_ready(ops.flash_attention(
        q, k, v, mode="causal", force_interpret=True)))
    flops = 4 * B * H * S * S * Dh / 2
    _emit_row(rows, "flash_attention_512_interp", us,
              f"{flops / (us / 1e6) / 1e9:.2f} GFLOP/s-equiv")

    a = jax.nn.sigmoid(jax.random.normal(key, (4, 1024, 256)))
    b = jax.random.normal(jax.random.key(3), (4, 1024, 256))
    h0 = jnp.zeros((4, 256))
    out, us = timed(lambda: jax.block_until_ready(
        ops.rglru_scan(a, b, h0, force_interpret=True)))
    _emit_row(rows, "rglru_scan_4x1024x256_interp", us,
              f"{a.size * 4 / (us / 1e6) / 1e9:.3f} GB/s-equiv")

    qm = jax.random.normal(key, (2, 2, 512, 128)) * 128 ** -0.5
    km = jax.random.normal(jax.random.key(4), (2, 2, 512, 128)) * 128 ** -0.5
    vm = jax.random.normal(jax.random.key(5), (2, 2, 512, 128))
    li = jax.random.normal(jax.random.key(6), (2, 2, 512))
    lf = jax.nn.log_sigmoid(
        jax.random.normal(jax.random.key(7), (2, 2, 512)) + 2)
    out, us = timed(lambda: jax.block_until_ready(
        ops.mlstm_scan(qm, km, vm, li, lf, chunk=128, force_interpret=True)))
    _emit_row(rows, "mlstm_scan_2x2x512_interp", us, "chunkwise=128")

    x = jax.random.normal(key, (1024, 1024))
    (qq, ss, pad), us = timed(lambda: ops.quantize_array(
        x, force_interpret=True))
    ratio = (qq.nbytes + ss.nbytes) / x.nbytes
    _emit_row(rows, "quant_blockwise_1Melem_interp", us,
              f"payload_ratio={ratio:.3f}")

    # The event-sweep kernel at the canonical engine tile (deterministic
    # synthetic gaps — raw kernel timing, no engine dispatch on top; the
    # gated engine-level comparison lives in bench_sweep).
    Bq, N, F = 16, 128, 32
    with enable_x64():
        gaps = jnp.asarray(
            np.linspace(5.0, 400.0, Bq * N * F).reshape(Bq, N, F))
        col = jnp.asarray(np.full(Bq, 60.0))
        args = (col, col * 0.1, col * 0.05, col * 0.01,
                jnp.zeros_like(col), col * 25.0, gaps)
        run = jax.jit(lambda *a: event_sweep(*a, n_steps=F + 1)["wall_time"])
        jax.block_until_ready(run(*args))           # compile outside timing
        out, us = timed(lambda: jax.block_until_ready(run(*args)))
        _emit_row(rows, "event_sweep_16x128_interp", us,
                  f"{gaps.nbytes / (us / 1e6) / 1e9:.3f} GB/s-equiv")

    csv = RESULTS / "bench_kernels.csv"
    with open(csv, "w") as f:
        f.write("name,us_per_call,derived\n")
        for name, us, derived in rows:
            f.write(f"{name},{us:.1f},{derived}\n")


if __name__ == "__main__":
    main()
