"""Kernel microbenchmarks (interpret mode on CPU: correctness-path timing;
the CSV also reports achieved compression ratios / arithmetic sanity)."""
import jax
import jax.numpy as jnp

from ._util import emit, timed


def main():
    from repro.kernels import ops

    key = jax.random.key(0)
    B, S, H, Dh = 2, 512, 4, 128
    q = jax.random.normal(key, (B, S, H, Dh), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(1), (B, S, H, Dh), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (B, S, H, Dh), jnp.bfloat16)

    out, us = timed(lambda: jax.block_until_ready(ops.flash_attention(
        q, k, v, mode="causal", force_interpret=True)))
    flops = 4 * B * H * S * S * Dh / 2
    emit("flash_attention_512_interp", us, f"{flops/ (us/1e6) / 1e9:.2f} GFLOP/s-equiv")

    a = jax.nn.sigmoid(jax.random.normal(key, (4, 1024, 256)))
    b = jax.random.normal(jax.random.key(3), (4, 1024, 256))
    h0 = jnp.zeros((4, 256))
    out, us = timed(lambda: jax.block_until_ready(
        ops.rglru_scan(a, b, h0, force_interpret=True)))
    emit("rglru_scan_4x1024x256_interp", us,
         f"{a.size * 4 / (us/1e6) / 1e9:.3f} GB/s-equiv")

    qm = jax.random.normal(key, (2, 2, 512, 128)) * 128 ** -0.5
    km = jax.random.normal(jax.random.key(4), (2, 2, 512, 128)) * 128 ** -0.5
    vm = jax.random.normal(jax.random.key(5), (2, 2, 512, 128))
    li = jax.random.normal(jax.random.key(6), (2, 2, 512))
    lf = jax.nn.log_sigmoid(jax.random.normal(jax.random.key(7), (2, 2, 512)) + 2)
    out, us = timed(lambda: jax.block_until_ready(
        ops.mlstm_scan(qm, km, vm, li, lf, chunk=128, force_interpret=True)))
    emit("mlstm_scan_2x2x512_interp", us, "chunkwise=128")

    x = jax.random.normal(key, (1024, 1024))
    (qq, ss, pad), us = timed(lambda: ops.quantize_array(
        x, force_interpret=True))
    ratio = (qq.nbytes + ss.nbytes) / x.nbytes
    emit("quant_blockwise_1Melem_interp", us, f"payload_ratio={ratio:.3f}")


if __name__ == "__main__":
    main()
