"""Paper Figure 2: ratio surfaces over (mu, rho), C=R=10, D=1, omega=1/2.

The whole surface is solved by the batched ``repro.sim`` sweep in one
jitted call (see ``bench_sweep`` for the scalar-vs-batched timing).
"""
from ._util import emit, timed, RESULTS

MUS = [30, 60, 90, 120, 180, 240, 300, 420, 600]


def run():
    import numpy as np
    from repro.sim import sweep_mu_rho_grid

    rhos = list(np.linspace(1.0, 10.0, 10))
    res = sweep_mu_rho_grid(MUS, rhos)
    out = RESULTS / "fig2_mu_rho.csv"
    with open(out, "w") as f:
        f.write("mu_min,rho,energy_ratio,time_ratio\n")
        for i, mu in enumerate(MUS):
            for j, rho in enumerate(res.grid.rho[i]):
                f.write(f"{mu:.1f},{rho:.3f},"
                        f"{res.energy_ratio[i, j]:.6f},"
                        f"{res.time_ratio[i, j]:.6f}\n")
    k = np.unravel_index(np.argmax(res.energy_ratio), res.energy_ratio.shape)
    peak = (MUS[k[0]], float(res.grid.rho[k]), float(res.energy_ratio[k]))
    return out, peak


def main():
    (out, peak), us = timed(run, repeat=2)
    emit("fig2_mu_rho", us,
         f"peak e_ratio={peak[2]:.3f} at mu={peak[0]:.0f} "
         f"rho={peak[1]:.1f} -> {out.name}")


if __name__ == "__main__":
    main()
