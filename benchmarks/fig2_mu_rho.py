"""Paper Figure 2: ratio surfaces over (mu, rho), C=R=10, D=1, omega=1/2."""
from ._util import emit, timed, RESULTS


def run():
    import numpy as np
    from repro.core import sweep_mu_rho

    mus = [30, 60, 90, 120, 180, 240, 300, 420, 600]
    rhos = list(np.linspace(1.0, 10.0, 10))
    grid = sweep_mu_rho(mus, rhos)
    out = RESULTS / "fig2_mu_rho.csv"
    with open(out, "w") as f:
        f.write("mu_min,rho,energy_ratio,time_ratio\n")
        for row in grid:
            for pt in row:
                f.write(f"{pt.ckpt.mu:.1f},{pt.power.rho:.3f},"
                        f"{pt.energy_ratio:.6f},{pt.time_ratio:.6f}\n")
    peak = max((pt for row in grid for pt in row),
               key=lambda p: p.energy_ratio)
    return out, peak


def main():
    (out, peak), us = timed(run, repeat=1)
    emit("fig2_mu_rho", us,
         f"peak e_ratio={peak.energy_ratio:.3f} at mu={peak.ckpt.mu:.0f} "
         f"rho={peak.power.rho:.1f} -> {out.name}")


if __name__ == "__main__":
    main()
