"""Roofline analysis: launch-plan dry-runs AND the live sweep engine.

Two sections, one loop-aware HLO cost model (``repro.launch.hlo_cost``):

1. **Launch plans** — reads benchmarks/results/dryrun/*.json (written by
   ``repro.launch.dryrun``) and derives, per (arch x shape x mesh):

     compute_term    = walked_flops_per_device / peak_flops           [s]
     memory_term     = walked_hbm_bytes_per_device / hbm_bandwidth    [s]
     collective_term = walked_collective_bytes_per_device / link_bw   [s]

   plus the dominant term, MODEL_FLOPS (6*N*D dense / 2*N*D fwd-only),
   the useful-FLOP ratio, and a "what would move the dominant term"
   note.  Emits roofline.csv + roofline.md.

2. **Sweep engine** — compiles the repo's OWN hot programs on this host
   (the batched model-sweep core, the lax.scan event engine, the Pallas
   event kernel in interpret mode) and walks their optimized HLO into
   the same terms against the HOST backend's peaks.  Emits
   roofline_sweep.csv + roofline_sweep.md (committed — the published
   "where does the sweep stack sit" table).  The walker counts dot
   FLOPs only (documented heuristic), and the sweep stack is
   dot-free closed-form arithmetic + gap streaming — so its roofline
   position is memory-side by construction; the table publishes the
   HBM traffic and arithmetic-intensity ceiling that implies.

Peaks come from the per-backend ``PEAKS`` table (keyed by device kind /
platform) and every emitted CSV/markdown records which peaks produced
it; override any of them with ``--peak-flops / --hbm-bw / --link-bw``
(plain floats, e.g. ``--peak-flops 312e12`` for an A100 bf16 TC run).
"""
from __future__ import annotations

import argparse
import dataclasses
import json

from ._util import emit, timed, RESULTS

DRYRUN = RESULTS / "dryrun"


@dataclasses.dataclass(frozen=True)
class Peaks:
    """One backend's roofline ceilings (per device)."""

    flops: float      # peak FLOP/s in the matmul dtype the plan uses
    hbm_bw: float     # HBM (or host DRAM) bandwidth, bytes/s
    link_bw: float    # inter-chip link bandwidth, bytes/s
    source: str       # where the numbers came from (recorded in outputs)

    def replaced(self, peak_flops=None, hbm_bw=None, link_bw=None):
        """CLI overrides: replace any provided ceiling, amend the source."""
        if peak_flops is None and hbm_bw is None and link_bw is None:
            return self
        return Peaks(peak_flops or self.flops, hbm_bw or self.hbm_bw,
                     link_bw or self.link_bw, self.source + " + cli override")


#: per-backend peak table.  Keys are matched (case-insensitively) against
#: the device KIND first (longest match wins — "tpu v4" beats "tpu"),
#: then the platform name.  Sources are deliberately coarse public
#: datasheet numbers: the roofline separates decades, not percent.
PEAKS = {
    "tpu v4": Peaks(275e12, 1228e9, 50e9, "TPU v4 datasheet (bf16)"),
    "tpu v5 lite": Peaks(197e12, 819e9, 50e9, "TPU v5e datasheet (bf16)"),
    "tpu": Peaks(197e12, 819e9, 50e9, "TPU default = v5e class (bf16)"),
    "gpu": Peaks(19.5e12, 1555e9, 300e9, "A100-40GB class (f32 non-TC)"),
    "cpu": Peaks(5e10, 2e10, 1e10,
                 "order-of-magnitude host estimate "
                 "(per-core f64 FMA / DDR stream share)"),
}

#: the launch-plan section models the TPU fleet the plans target,
#: whatever host runs the analysis.
PLAN_BACKEND = "tpu"


def resolve_peaks(device_kind: str = "", platform: str = "",
                  peak_flops=None, hbm_bw=None, link_bw=None) -> Peaks:
    """Pick the peak entry for a backend, longest device-kind key first,
    then platform, then the cpu floor; apply any CLI overrides."""
    kind = (device_kind or "").lower()
    hits = [k for k in PEAKS if k in kind]
    if hits:
        key = max(hits, key=len)
    elif (platform or "").lower() in PEAKS:
        key = platform.lower()
    else:
        key = "cpu"
    return PEAKS[key].replaced(peak_flops, hbm_bw, link_bw)


def host_peaks(peak_flops=None, hbm_bw=None, link_bw=None):
    """Peaks for THIS process's jax backend (the sweep-engine section)."""
    from repro.sim import backend_info
    info = backend_info()
    return info, resolve_peaks(info.device_kind, info.platform,
                               peak_flops, hbm_bw, link_bw)


# ---------------------------------------------------------------------------
# Section 1 — launch-plan dry-runs
# ---------------------------------------------------------------------------

def model_flops_global(rec: dict) -> float:
    """MODEL_FLOPS per the assignment: 6*N*D (train) / 2*N*D (fwd-only)."""
    n_active = rec["active_param_count"]
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 6.0 * n_active * tokens
    if rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * rec["global_batch"]


def bottleneck_note(rec: dict, dom: str) -> str:
    if dom == "compute":
        if rec["arch"].startswith(("dbrx", "llama4")) and \
                rec.get("moe_impl_dense", True):
            return "dense-MoE computes all experts: capacity/a2a EP cuts " \
                   "compute ~E/k"
        return "remat recompute + head padding: selective remat / exact " \
               "head sharding"
    if dom == "memory":
        return "recurrence state streaming: fuse scans (Pallas kernel) / " \
               "larger time blocks in VMEM"
    return "FSDP gathers dominate: overlap with compute, or switch the " \
           "axis to pure DP + ZeRO-1 reduce-scatter"


def load_records():
    recs = []
    for f in sorted(DRYRUN.glob("*.json")):
        r = json.loads(f.read_text())
        if "walked" not in r:
            continue
        recs.append(r)
    return recs


def analyze(rec: dict, peaks: Peaks) -> dict:
    w = rec["walked"]
    chips = rec["n_chips"]
    compute = w["flops_per_device"] / peaks.flops
    memory = w["hbm_bytes_per_device"] / peaks.hbm_bw
    coll = w["coll_bytes_total"] / peaks.link_bw
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dom = max(terms, key=terms.get)
    mf = model_flops_global(rec) / chips
    useful = mf / w["flops_per_device"] if w["flops_per_device"] else 0.0
    bound = max(terms.values())
    mfu_bound = (mf / peaks.flops) / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dom,
        "model_flops_per_dev": mf,
        "useful_flop_ratio": useful,
        "roofline_fraction": mfu_bound,
        "fits_hbm": rec["fits_hbm"],
        "peak_gib": rec["memory"]["peak_bytes_est"] / 2**30,
        "note": bottleneck_note(rec, dom),
    }


def _peaks_line(peaks: Peaks) -> str:
    return (f"peaks: flops={peaks.flops:.4g} hbm_bw={peaks.hbm_bw:.4g} "
            f"link_bw={peaks.link_bw:.4g} ({peaks.source})")


def _peaks_md(lines: list, peaks: Peaks, backend: str):
    lines += [f"Peaks ({backend}): `{peaks.flops:.4g}` FLOP/s, "
              f"`{peaks.hbm_bw:.4g}` B/s HBM, `{peaks.link_bw:.4g}` B/s "
              f"link — {peaks.source}.", ""]
    lines += ["| backend key | peak FLOP/s | HBM B/s | link B/s | source |",
              "|---|---|---|---|---|"]
    for k, p in PEAKS.items():
        lines.append(f"| {k} | {p.flops:.4g} | {p.hbm_bw:.4g} "
                     f"| {p.link_bw:.4g} | {p.source} |")
    lines.append("")


def run(peaks: Peaks):
    recs = load_records()
    rows = [analyze(r, peaks) for r in recs]
    out = RESULTS / "roofline.csv"
    cols = ["arch", "shape", "mesh", "compute_s", "memory_s",
            "collective_s", "dominant", "model_flops_per_dev",
            "useful_flop_ratio", "roofline_fraction", "fits_hbm",
            "peak_gib", "note"]
    with open(out, "w") as f:
        f.write(f"# {_peaks_line(peaks)}\n")
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(
                f"{r[c]:.6g}" if isinstance(r[c], float) else str(r[c])
                for c in cols) + "\n")

    md = RESULTS / "roofline.md"
    lines = ["# Launch-plan roofline", ""]
    _peaks_md(lines, peaks, PLAN_BACKEND)
    lines += ["| arch | shape | mesh | compute s | memory s | coll s | "
              "dominant | useful | roofline frac | fits |",
              "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                     f"{r['compute_s']:.3f} | {r['memory_s']:.3f} | "
                     f"{r['collective_s']:.3f} | {r['dominant']} | "
                     f"{r['useful_flop_ratio']:.2f} | "
                     f"{r['roofline_fraction']:.2f} | "
                     f"{'Y' if r['fits_hbm'] else 'N'} |")
    md.write_text("\n".join(lines) + "\n")
    return out, rows


# ---------------------------------------------------------------------------
# Section 2 — the sweep engine's own programs
# ---------------------------------------------------------------------------

#: sweep-section workload shape: big enough that per-op constants wash
#: out, small enough to compile everywhere in seconds.
_SW_POINTS, _SW_TRIALS, _SW_CAP = 64, 64, 32


def _sweep_workload():
    """Deterministic (no RNG — HLO structure is value-independent) sweep
    and engine inputs at the section's canonical shape."""
    import numpy as np

    from repro.core import EXASCALE_POWER_RHO55, fig12_checkpoint
    from repro.sim import ParamGrid
    from repro.sim.sweep import _FIELD_ORDER

    B, N, F = _SW_POINTS, _SW_TRIALS, _SW_CAP
    mus = np.linspace(120.0, 600.0, B)
    base = ParamGrid.from_params(fig12_checkpoint(300.0),
                                 EXASCALE_POWER_RHO55)
    grid = ParamGrid(**{f: (mus if f == "mu" else np.broadcast_to(v, (B,)))
                        for f, v in base.fields().items()})
    fields = grid.fields()
    P = np.stack([np.asarray(fields[f], dtype=np.float64)
                  for f in _FIELD_ORDER])
    gaps = np.linspace(5.0, 400.0, B * N * F).reshape(B, N, F)
    engine_args = (np.full(B, 60.0), fields["C"], fields["R"], fields["D"],
                   fields["omega"], np.full(B, 1500.0), gaps)
    return P, engine_args


def analyze_sweep_programs(peaks: Peaks) -> list:
    """Compile the sweep stack's hot programs and walk their HLO."""
    from repro.launch.hlo_cost import analyze_compiled
    from repro.sim import engine as _engine
    from repro.sim import sweep as _sweep

    P, engine_args = _sweep_workload()
    n_steps = _SW_CAP + 1                   # event budget = capacity + 1
    programs = [
        ("model_sweep_core",
         f"{_SW_POINTS}-pt grid / AlgoT+AlgoE+Young+Daly+MSK",
         lambda: analyze_compiled(
             lambda p: _sweep._evaluate_core(p, 1.0), P)),
        ("event_engine_scan",
         f"{_SW_POINTS}x{_SW_TRIALS} trajectories / cap {_SW_CAP}",
         lambda: analyze_compiled(
             _engine._grid_fn(n_steps, "event"), *engine_args)),
        ("pallas_event_interpret",
         f"{_SW_POINTS}x{_SW_TRIALS} trajectories / cap {_SW_CAP}",
         lambda: analyze_compiled(
             _engine._grid_fn(n_steps, "pallas"), *engine_args)),
    ]
    rows = []
    with _engine.enable_x64():
        for name, shape, walker in programs:
            cost = walker()
            compute = cost.flops / peaks.flops
            memory = cost.hbm_bytes / peaks.hbm_bw
            terms = {"compute": compute, "memory": memory}
            rows.append({
                "program": name, "shape": shape,
                "dot_flops": cost.flops,
                "hbm_bytes": cost.hbm_bytes,
                "intensity": (cost.flops / cost.hbm_bytes
                              if cost.hbm_bytes else 0.0),
                "compute_s": compute, "memory_s": memory,
                "dominant": max(terms, key=terms.get),
            })
    return rows


def run_sweep_section(peaks: Peaks, backend: str):
    rows = analyze_sweep_programs(peaks)
    out = RESULTS / "roofline_sweep.csv"
    cols = ["program", "shape", "dot_flops", "hbm_bytes", "intensity",
            "compute_s", "memory_s", "dominant"]
    with open(out, "w") as f:
        f.write(f"# backend={backend}; {_peaks_line(peaks)}\n")
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(
                f"{r[c]:.6g}" if isinstance(r[c], float) else str(r[c])
                for c in cols) + "\n")

    md = RESULTS / "roofline_sweep.md"
    lines = ["# Sweep-engine roofline", "",
             "Loop-aware HLO walk (`repro.launch.hlo_cost`) of the sweep "
             "stack's compiled programs on this host.  The walker counts "
             "dot FLOPs only; the sweep stack is dot-free closed-form "
             "arithmetic + gap streaming, so its position on the roofline "
             "is the MEMORY axis — the table publishes the per-dispatch "
             "HBM traffic and the resulting time floor.", ""]
    _peaks_md(lines, peaks, backend)
    lines += ["| program | shape | dot FLOPs | HBM bytes | FLOP/byte | "
              "compute s | memory s | dominant |",
              "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(f"| {r['program']} | {r['shape']} "
                     f"| {r['dot_flops']:.4g} | {r['hbm_bytes']:.4g} "
                     f"| {r['intensity']:.3g} | {r['compute_s']:.3g} "
                     f"| {r['memory_s']:.3g} | {r['dominant']} |")
    lines += ["",
              "The Pallas row is a WORST-CASE bound, not a prediction: the "
              "kernel's all-done early exit is a runtime property the "
              "static walk cannot see (it charges the while loop at its "
              "constant trip count, streaming one full gap slab per "
              "iteration), so the measured win lives in "
              "`BENCH_sweep.json:pallas_event_engine`, not in this table. "
              "What the table DOES pin: every program is memory-side on "
              "every backend in the peaks table — the sweep stack's "
              "ceiling is bandwidth and dispatch, never FLOPs."]
    md.write_text("\n".join(lines) + "\n")
    return out, rows


# ---------------------------------------------------------------------------


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--peak-flops", type=float, default=None,
                    help="override peak FLOP/s for BOTH sections")
    ap.add_argument("--hbm-bw", type=float, default=None,
                    help="override HBM bandwidth (bytes/s)")
    ap.add_argument("--link-bw", type=float, default=None,
                    help="override inter-chip link bandwidth (bytes/s)")
    args = ap.parse_args(argv)
    over = (args.peak_flops, args.hbm_bw, args.link_bw)

    plan_peaks = resolve_peaks(platform=PLAN_BACKEND, peak_flops=over[0],
                               hbm_bw=over[1], link_bw=over[2])
    (out, rows), us = timed(lambda: run(plan_peaks), repeat=1)
    info, hpeaks = host_peaks(*over)
    (sout, srows), sus = timed(
        lambda: run_sweep_section(hpeaks, info.platform), repeat=1)

    n = len(rows)
    sweep_doms = {r["program"]: r["dominant"] for r in srows}
    single = [r for r in rows if r["mesh"] == "pod16x16"]
    if single:
        worst = min(single, key=lambda r: r["roofline_fraction"])
        emit("roofline", us,
             f"{n} cells; worst single-pod fraction: {worst['arch']}x"
             f"{worst['shape']}={worst['roofline_fraction']:.3f} "
             f"-> {out.name}")
    else:
        emit("roofline", us, f"{n} cells (dry-run records pending)")
    emit("roofline_sweep", sus,
         f"{len(srows)} programs on {info.platform} "
         f"({info.device_kind}); dominant: "
         + ", ".join(f"{k}={v}" for k, v in sweep_doms.items())
         + f" -> {sout.name}")


if __name__ == "__main__":
    main()
