"""Roofline analysis over the dry-run results (deliverable g).

Reads benchmarks/results/dryrun/*.json (written by ``repro.launch.dryrun``)
and derives, per (arch x shape x mesh):

  compute_term    = walked_flops_per_device / peak_bf16_flops        [s]
  memory_term     = walked_hbm_bytes_per_device / hbm_bandwidth      [s]
  collective_term = walked_collective_bytes_per_device / link_bw     [s]

plus the dominant term, MODEL_FLOPS (6*N*D dense / 6*N_active*D MoE; 2*N*D
for forward-only kinds), the useful-FLOP ratio MODEL_FLOPS/HLO_FLOPs, and a
one-line "what would move the dominant term" note.  Emits a CSV and a
markdown table for EXPERIMENTS.md.
"""
from __future__ import annotations

import json

from ._util import emit, timed, RESULTS

DRYRUN = RESULTS / "dryrun"

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def model_flops_global(rec: dict) -> float:
    """MODEL_FLOPS per the assignment: 6*N*D (train) / 2*N*D (fwd-only)."""
    n_active = rec["active_param_count"]
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 6.0 * n_active * tokens
    if rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * rec["global_batch"]


def bottleneck_note(rec: dict, dom: str) -> str:
    if dom == "compute":
        if rec["arch"].startswith(("dbrx", "llama4")) and \
                rec.get("moe_impl_dense", True):
            return "dense-MoE computes all experts: capacity/a2a EP cuts " \
                   "compute ~E/k"
        return "remat recompute + head padding: selective remat / exact " \
               "head sharding"
    if dom == "memory":
        return "recurrence state streaming: fuse scans (Pallas kernel) / " \
               "larger time blocks in VMEM"
    return "FSDP gathers dominate: overlap with compute, or switch the " \
           "axis to pure DP + ZeRO-1 reduce-scatter"


def load_records():
    recs = []
    for f in sorted(DRYRUN.glob("*.json")):
        r = json.loads(f.read_text())
        if "walked" not in r:
            continue
        recs.append(r)
    return recs


def analyze(rec: dict) -> dict:
    w = rec["walked"]
    chips = rec["n_chips"]
    compute = w["flops_per_device"] / PEAK_FLOPS
    memory = w["hbm_bytes_per_device"] / HBM_BW
    coll = w["coll_bytes_total"] / LINK_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dom = max(terms, key=terms.get)
    mf = model_flops_global(rec) / chips
    useful = mf / w["flops_per_device"] if w["flops_per_device"] else 0.0
    bound = max(terms.values())
    mfu_bound = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dom,
        "model_flops_per_dev": mf,
        "useful_flop_ratio": useful,
        "roofline_fraction": mfu_bound,
        "fits_hbm": rec["fits_hbm"],
        "peak_gib": rec["memory"]["peak_bytes_est"] / 2**30,
        "note": bottleneck_note(rec, dom),
    }


def run():
    recs = load_records()
    rows = [analyze(r) for r in recs]
    out = RESULTS / "roofline.csv"
    cols = ["arch", "shape", "mesh", "compute_s", "memory_s",
            "collective_s", "dominant", "model_flops_per_dev",
            "useful_flop_ratio", "roofline_fraction", "fits_hbm",
            "peak_gib", "note"]
    with open(out, "w") as f:
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(
                f"{r[c]:.6g}" if isinstance(r[c], float) else str(r[c])
                for c in cols) + "\n")

    md = RESULTS / "roofline.md"
    with open(md, "w") as f:
        f.write("| arch | shape | mesh | compute s | memory s | coll s | "
                "dominant | useful | roofline frac | fits |\n")
        f.write("|---|---|---|---|---|---|---|---|---|---|\n")
        for r in rows:
            f.write(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                    f"{r['compute_s']:.3f} | {r['memory_s']:.3f} | "
                    f"{r['collective_s']:.3f} | {r['dominant']} | "
                    f"{r['useful_flop_ratio']:.2f} | "
                    f"{r['roofline_fraction']:.2f} | "
                    f"{'Y' if r['fits_hbm'] else 'N'} |\n")
    return out, rows


def main():
    (out, rows), us = timed(run, repeat=1)
    n = len(rows)
    single = [r for r in rows if r["mesh"] == "pod16x16"]
    if single:
        worst = min(single, key=lambda r: r["roofline_fraction"])
        emit("roofline", us,
             f"{n} cells; worst single-pod fraction: {worst['arch']}x"
             f"{worst['shape']}={worst['roofline_fraction']:.3f} "
             f"-> {out.name}")
    else:
        emit("roofline", us, f"{n} cells (dry-run records pending)")


if __name__ == "__main__":
    main()
