"""Figure 5 (this reproduction): robustness of the paper's periods to
non-exponential failures.

Sweeps Weibull shape x platform MTBF over the Exascale scenario family and
records, per point, the wall-time / energy penalty of running at the
exponential-assumption periods (the paper's AlgoT / AlgoE closed forms, and
the Young / Daly baselines) instead of the process-optimal period found by
the CRN Monte-Carlo surrogate solver.  Shape 1.0 *is* the exponential
process — the control row that pins the closed forms.

Every reported optimum is MC-validated: all reported periods are re-scored
on an independent seed (CRN within that run), and each reported optimum
must stay within ``VALIDATE_RTOL`` (2%) of the best candidate's objective
there, else the bench fails.  Cross-seed penalty drift is reported
alongside.

Writes ``benchmarks/results/fig5_robustness.csv``.
"""
import csv
import time

import numpy as np

from ._util import emit, RESULTS

SHAPES = [0.5, 0.7, 1.0]
MU_MINS = [120.0, 300.0, 600.0]
#: sized so independent-seed validation noise sits well inside the 2% gate
#: (wall/energy SE ~ 0.3% of the mean at this trial count).
N_TRIALS = 192
#: acceptance gate: independent-seed re-simulation at the reported optima.
VALIDATE_RTOL = 0.02


def run():
    from repro.sim import evaluate_periods_grid, sweep_weibull_shapes

    t0 = time.perf_counter()
    res = sweep_weibull_shapes(SHAPES, MU_MINS, n_trials=N_TRIALS, seed=0)
    elapsed_us = (time.perf_counter() - t0) * 1e6

    # MC validation of the reported optima: re-score all six reported
    # periods on an INDEPENDENT seed; the reported T_mc optima must stay
    # within 2% of the best candidate's objective on that run.  Within one
    # run the candidates share schedules (CRN), so this comparison is tight
    # — unlike cross-seed absolute objectives, which carry ~1% SE each at
    # this trial count and would make a 2% gate a noise gamble.
    chk = evaluate_periods_grid(res.grid, res.process, res.eval_periods,
                                T_base=res.T_base, n_trials=N_TRIALS,
                                seed=1)
    w, e = chk["wall"], chk["energy"]
    worst = max(float(np.max(w[0] / w.min(axis=0))),
                float(np.max(e[1] / e.min(axis=0)))) - 1.0
    if worst > VALIDATE_RTOL:
        raise RuntimeError(
            f"fig5 MC validation FAILED: a reported optimum is "
            f"{worst * 100:.2f}% worse than the best candidate period on an "
            f"independent seed (gate {VALIDATE_RTOL * 100:g}%)")
    # Penalty reproducibility across seeds (reported, not gated: each side
    # carries its own MC noise).
    pen_drift = max(
        float(np.max(np.abs(w[2] / w[0] - res.time_penalty_exp))),
        float(np.max(np.abs(e[3] / e[1] - res.energy_penalty_exp))),
        float(np.max(np.abs(w[4] / w[0] - res.time_penalty_young))),
        float(np.max(np.abs(w[5] / w[0] - res.time_penalty_daly))))

    rows = []
    for i, k in enumerate(SHAPES):
        for j, mu in enumerate(MU_MINS):
            rows.append({
                "weibull_shape": k, "mu_min": mu,
                "T_exp_time": float(res.T_exp_time[i, j]),
                "T_exp_energy": float(res.T_exp_energy[i, j]),
                "T_young": float(res.T_young[i, j]),
                "T_daly": float(res.T_daly[i, j]),
                "T_mc_time": float(res.T_mc_time[i, j]),
                "T_mc_energy": float(res.T_mc_energy[i, j]),
                "time_penalty_exp": float(res.time_penalty_exp[i, j]),
                "energy_penalty_exp": float(res.energy_penalty_exp[i, j]),
                "time_penalty_young": float(res.time_penalty_young[i, j]),
                "time_penalty_daly": float(res.time_penalty_daly[i, j]),
                "energy_penalty_young": float(
                    res.energy_penalty_young[i, j]),
                "energy_penalty_daly": float(res.energy_penalty_daly[i, j]),
            })
    with open(RESULTS / "fig5_robustness.csv", "w", newline="") as f:
        wcsv = csv.DictWriter(f, fieldnames=list(rows[0]))
        wcsv.writeheader()
        wcsv.writerows(rows)
    return res, elapsed_us, worst, pen_drift


def main():
    res, us, worst, pen_drift = run()
    ep = np.asarray(res.energy_penalty_exp)
    i, j = np.unravel_index(np.argmax(ep), ep.shape)
    emit("fig5_robustness", us,
         f"worst exp-assumption energy penalty "
         f"{(ep[i, j] - 1) * 100:.1f}% at k={SHAPES[i]:g} "
         f"mu={MU_MINS[j]:g}min; optima MC-validated within "
         f"{worst * 100:.2f}% (penalty drift {pen_drift * 100:.2f}%) "
         f"-> fig5_robustness.csv")


if __name__ == "__main__":
    main()
