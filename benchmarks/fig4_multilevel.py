"""Figure 4 (this reproduction): multilevel (buddy + PFS) trade-off surfaces.

Sweeps the Exascale two-level scenario family over buddy-cost ratio x
buddy-loss probability, jointly optimizing (T, m) for AlgoT and AlgoE with
the batched ``sim.evaluate_multilevel_grid`` solver, and records:

  * the optimal periods/cadences per point,
  * the time/energy gains of the two-level scheme over the PFS-only
    single-level optimum (the seed model),
  * the AlgoT-vs-AlgoE trade-off on the two-level platform.

Writes ``benchmarks/results/fig4_multilevel.csv`` and emits the warm solver
timing for the whole grid.
"""
import csv

import numpy as np

from ._util import emit, timed, RESULTS

RATIOS = [0.02, 0.05, 0.1, 0.2, 0.4, 1.0]
QS = [0.01, 0.05, 0.1, 0.2, 0.4]
MU_MIN = 300.0
M_VALUES = tuple(range(1, 13))


def run():
    from repro.sim import buddy_ratio_grid, evaluate_multilevel_grid

    grid = buddy_ratio_grid(RATIOS, QS, mu_min=MU_MIN)
    res, us = timed(evaluate_multilevel_grid, grid, m_values=M_VALUES,
                    repeat=3)

    rows = []
    for i, r in enumerate(RATIOS):
        for j, q in enumerate(QS):
            rows.append({
                "buddy_ratio": r, "q": q, "mu_min": MU_MIN,
                "m_time": int(res.m_time[i, j]),
                "T_time": float(res.T_time[i, j]),
                "m_energy": int(res.m_energy[i, j]),
                "T_energy": float(res.T_energy[i, j]),
                "time_ratio": float(res.time_ratio[i, j]),
                "energy_ratio": float(res.energy_ratio[i, j]),
                "time_vs_single": float(res.time_vs_single[i, j]),
                "energy_vs_single": float(res.energy_vs_single[i, j]),
            })
    with open(RESULTS / "fig4_multilevel.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    return res, us


def main():
    res, us = run()
    # Headline: the strongest two-level win on the grid.
    k = np.unravel_index(np.nanargmin(res.energy_vs_single),
                         res.energy_vs_single.shape)
    emit("fig4_multilevel", us,
         f"{len(RATIOS)}x{len(QS)} grid x {len(M_VALUES)} cadences; "
         f"best energy {100 * (1 - res.energy_vs_single[k]):.0f}% below "
         f"PFS-only (ratio={RATIOS[k[0]]:g}, q={QS[k[1]]:g}, "
         f"m*={int(res.m_energy[k])}) -> fig4_multilevel.csv")


if __name__ == "__main__":
    main()
