"""Per-architecture instantiation of the paper's model on the production
mesh: checkpoint bytes -> C, platform MTBF -> optimal periods & predicted
energy gains, for the paper profile and a v5e-host profile."""
from ._util import emit, timed, RESULTS


def run():
    from repro.configs import ALL_ARCHS
    from repro.core import CheckpointParams, t_opt_time, t_opt_energy, \
        evaluate
    from repro.energy import PAPER_EXASCALE_PROFILE, TPU_V5E_HOST_PROFILE
    from repro.models import build

    # I/O model: 64 hosts/pod, 8 GB/s effective per host (buddy/NVMe tier);
    # optimizer state = bf16 params + bf16 m + f32 master (+factored v).
    hosts = 64
    bw = 8e9
    n_nodes = 256                       # chips as failure units
    mu_ind_s = 125.0 * 365 * 24 * 3600  # Jaguar-derived per-unit MTBF
    mu_s = mu_ind_s / n_nodes
    D_s, omega = 60.0, 0.5

    rows = []
    pw = PAPER_EXASCALE_PROFILE.power_params()
    for cfg in ALL_ARCHS:
        n = build(cfg).param_count()
        state_bytes = n * (2 + 2 + 4)   # bf16 p + bf16 m + f32 master
        C = state_bytes / (hosts * bw)
        ck = CheckpointParams(C=C, R=C, D=D_s, mu=mu_s, omega=omega)
        pt = evaluate(ck, pw)
        rows.append((cfg.name, n / 1e9, state_bytes / 2**30, C,
                     pt.T_time, pt.T_energy,
                     pt.energy_ratio, pt.time_ratio))
    out = RESULTS / "table_arch_periods.csv"
    with open(out, "w") as f:
        f.write("arch,params_B,state_GiB,C_s,T_opt_time_s,T_opt_energy_s,"
                "energy_ratio,time_ratio\n")
        for r in rows:
            f.write(f"{r[0]},{r[1]:.2f},{r[2]:.1f},{r[3]:.2f},{r[4]:.1f},"
                    f"{r[5]:.1f},{r[6]:.4f},{r[7]:.4f}\n")
    big = max(rows, key=lambda r: r[3])
    return out, big


def main():
    (out, big), us = timed(run, repeat=1)
    emit("table_arch_periods", us,
         f"largest C: {big[0]} C={big[3]:.1f}s T_opt={big[4]:.0f}s "
         f"-> {out.name}")


if __name__ == "__main__":
    main()
