"""Per-architecture instantiation of the paper's model on the production
mesh, via the ``repro.sim`` scenario catalog: checkpoint bytes -> C,
platform MTBF -> optimal periods & predicted energy gains — the whole
architecture table solved as one batched grid."""
from ._util import emit, timed, RESULTS


def run():
    from repro.configs import ALL_ARCHS
    from repro.sim import arch_grid, evaluate_grid
    from repro.sim.scenarios import STATE_BYTES_PER_PARAM

    hosts, bw = 64, 8e9
    names = [c.name for c in ALL_ARCHS]
    grid = arch_grid(names, hosts=hosts, bw=bw, n_nodes=256, D_s=60.0,
                     omega=0.5, profile="paper")
    res = evaluate_grid(grid)

    rows = []
    for i, name in enumerate(names):
        C = float(grid.C[i])
        state_bytes = C * hosts * bw
        n = state_bytes / STATE_BYTES_PER_PARAM
        rows.append((name, n / 1e9, state_bytes / 2**30, C,
                     float(res.T_time[i]), float(res.T_energy[i]),
                     float(res.energy_ratio[i]), float(res.time_ratio[i])))
    out = RESULTS / "table_arch_periods.csv"
    with open(out, "w") as f:
        f.write("arch,params_B,state_GiB,C_s,T_opt_time_s,T_opt_energy_s,"
                "energy_ratio,time_ratio\n")
        for r in rows:
            f.write(f"{r[0]},{r[1]:.2f},{r[2]:.1f},{r[3]:.2f},{r[4]:.1f},"
                    f"{r[5]:.1f},{r[6]:.4f},{r[7]:.4f}\n")
    big = max(rows, key=lambda r: r[3])
    return out, big


def main():
    (out, big), us = timed(run, repeat=2)
    emit("table_arch_periods", us,
         f"largest C: {big[0]} C={big[3]:.1f}s T_opt={big[4]:.0f}s "
         f"-> {out.name}")


if __name__ == "__main__":
    main()
