"""Shared benchmark utilities."""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

RESULTS = Path(__file__).resolve().parent / "results"
RESULTS.mkdir(exist_ok=True)


def timed(fn, *args, repeat=3, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6   # us


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
