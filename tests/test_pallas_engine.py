"""Pallas event engine + per-backend precision policy.

The contract under test (the accelerator-native sweep engine PR):

- ``engine_kind="pallas"`` (interpret mode on CPU) == the lax.scan event
  engine, field-for-field BIT-FOR-BIT in f64, for every FailureProcess,
  on host-supplied dyadic schedules AND on the auto-sampled device path
  (the pallas sampled-build must fold the identical per-point/per-trial
  keys), plain and candidate-axis;
- the precision/backend knobs are bit-exact no-ops at a fixed seed on
  CPU (``precision="f64"``, ``DispatchConfig(backend="cpu")``,
  ``$REPRO_ENGINE_KIND`` deferral);
- the ``compensated_f32`` policy passes its DOCUMENTED parity gates
  against the f64 oracle per scenario family: objective at the served
  optimum re-evaluated in f64 within ``objective_tol`` (1e-6 rel),
  argmin period within ``argmin_rtol`` (1e-3 rel);
- the advisor threads the policy through its solves and folds
  ``objective_tol`` into every certified bound.
"""
import numpy as np
import pytest

from repro.core import (EXASCALE_POWER_RHO55, Exponential, LogNormal,
                        TraceReplay, Weibull, fig12_checkpoint)
from repro.sim import (COMPENSATED_F32, F64, DispatchConfig, ParamGrid,
                       arch_grid, backend_info, buddy_ratio_grid,
                       evaluate_grid, evaluate_multilevel_grid, mu_rho_grid,
                       resolve_precision, simulate_candidates,
                       simulate_trajectories)
from repro.sim.engine import presample_gaps, resolve_engine_kind
from repro.sim.precision import compensated_sum, resolve, two_sum
from repro.sim.sweep import energy_final_batched, time_final_batched

pytestmark = pytest.mark.pallas

CK = fig12_checkpoint(300.0)
PW = EXASCALE_POWER_RHO55

PROCESSES = [
    Exponential(),
    Weibull(shape=0.6),
    LogNormal(sigma=1.0),
    TraceReplay(gaps=[40.0, 500.0, 120.0, 90.0, 800.0, 33.0]),
]

#: same dyadic rounding grid as test_event_engine (see its docstring).
_DYADIC = 2.0 ** 16

FIELDS = ("wall_time", "energy", "work_executed", "io_time", "down_time",
          "n_failures", "n_checkpoints", "truncated", "gaps_exhausted")


def _dyadic(gaps):
    return np.maximum(np.round(gaps * _DYADIC) / _DYADIC, 1.0 / _DYADIC)


def _assert_bitexact(a_tb, b_tb, msg=""):
    for name in FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a_tb, name)), np.asarray(getattr(b_tb, name)),
            err_msg=f"{msg}/{name}")


class TestPallasScanParity:
    """pallas kernel == event scan, bit-for-bit in f64."""

    @pytest.mark.parametrize("proc", PROCESSES, ids=lambda p: p.name)
    def test_bitexact_on_dyadic_schedule(self, proc):
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        gaps = _dyadic(presample_gaps(grid, 8, 128, seed=9, process=proc))
        ev = simulate_trajectories(60.0, grid, T_base=3000.0, gaps=gaps,
                                   engine_kind="event")
        pl = simulate_trajectories(60.0, grid, T_base=3000.0, gaps=gaps,
                                   engine_kind="pallas")
        assert not ev.truncated.any()
        _assert_bitexact(ev, pl, proc.name)

    @pytest.mark.parametrize("proc", PROCESSES, ids=lambda p: p.name)
    def test_bitexact_on_auto_sampled_path(self, proc):
        """No host schedule: the pallas sampled-build must fold the SAME
        per-point/per-trial threefry keys as the event build."""
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        kw = dict(T_base=3000.0, n_trials=64, seed=11, process=proc)
        ev = simulate_trajectories(60.0, grid, engine_kind="event", **kw)
        pl = simulate_trajectories(60.0, grid, engine_kind="pallas", **kw)
        _assert_bitexact(ev, pl, proc.name)

    def test_parameter_batch_parity(self):
        """Mixed (ckpt, power) batch + per-point dyadic schedules."""
        from repro.sim import get_scenario, grid_from_scenarios
        scens = [get_scenario("fig12", mu_min=120.0),
                 get_scenario("exascale_rho7", mu_min=300.0)]
        grid = grid_from_scenarios(scens)
        rng = np.random.default_rng(5)
        gaps = _dyadic(rng.exponential(1.0, size=(2, 4, 96))
                       * grid.mu[:, None, None])
        T = np.array([40.0, 60.0])
        ev = simulate_trajectories(T, grid, T_base=500.0, gaps=gaps,
                                   engine_kind="event")
        pl = simulate_trajectories(T, grid, T_base=500.0, gaps=gaps,
                                   engine_kind="pallas")
        _assert_bitexact(ev, pl)

    def test_candidates_axis_parity(self):
        """simulate_candidates: the lax.map pallas candidate path shares
        the schedules across candidates exactly like the vmapped scan."""
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        Ts = np.geomspace(30.0, 300.0, 7)
        kw = dict(T_base=1500.0, n_trials=32, seed=2,
                  process=Weibull(shape=0.7))
        ev = simulate_candidates(Ts, grid, engine_kind="event", **kw)
        pl = simulate_candidates(Ts, grid, engine_kind="pallas", **kw)
        _assert_bitexact(ev, pl)

    def test_exhaustion_and_truncation_flags(self):
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        gaps = np.array([50.0, 70.0])       # far too short for T_base=4000
        ev = simulate_trajectories(60.0, grid, T_base=4000.0, gaps=gaps,
                                   engine_kind="event")
        pl = simulate_trajectories(60.0, grid, T_base=4000.0, gaps=gaps,
                                   engine_kind="pallas")
        assert pl.gaps_exhausted.all()
        _assert_bitexact(ev, pl)
        tiny = simulate_trajectories(60.0, grid, T_base=50000.0, n_trials=4,
                                     seed=0, n_steps=2, engine_kind="pallas")
        assert tiny.truncated.any()

    def test_env_var_defers_engine_kind(self, monkeypatch):
        """engine_kind=None resolves through $REPRO_ENGINE_KIND; explicit
        kinds pass through untouched (the CI pallas leg's mechanism)."""
        monkeypatch.setenv("REPRO_ENGINE_KIND", "pallas")
        assert resolve_engine_kind(None) == "pallas"
        assert resolve_engine_kind("event") == "event"
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        kw = dict(T_base=1500.0, n_trials=16, seed=4)
        via_env = simulate_trajectories(60.0, grid, **kw)
        explicit = simulate_trajectories(60.0, grid, engine_kind="pallas",
                                         **kw)
        _assert_bitexact(via_env, explicit)
        monkeypatch.delenv("REPRO_ENGINE_KIND")
        assert resolve_engine_kind(None) == "event"
        with pytest.raises(ValueError, match="engine_kind"):
            resolve_engine_kind("warp")


class TestPrecisionKnobs:
    """Policy resolution + the CPU bit-exact no-op guarantees."""

    def test_cpu_default_is_f64(self):
        assert backend_info().platform == "cpu"
        assert resolve_precision() is F64
        assert F64.exact and not COMPENSATED_F32.exact

    def test_f64_policy_is_bitexact_noop(self):
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        kw = dict(T_base=1500.0, n_trials=32, seed=7,
                  process=Weibull(shape=0.7), engine_kind="pallas")
        _assert_bitexact(simulate_trajectories(60.0, grid, **kw),
                         simulate_trajectories(60.0, grid, precision="f64",
                                               **kw))
        g = mu_rho_grid(mus=(800.0, 2000.0), rhos=(0.5, 1.0))
        a, b = evaluate_grid(g), evaluate_grid(g, precision=F64)
        for f in ("T_time", "T_energy", "E_time", "E_energy", "valid"):
            np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                          np.asarray(getattr(b, f)),
                                          err_msg=f)

    def test_backend_knob_is_bitexact_noop_on_cpu(self):
        g = mu_rho_grid(mus=(800.0, 2000.0), rhos=(0.5, 1.0))
        a = evaluate_grid(g)
        b = evaluate_grid(g, dispatch=DispatchConfig(backend="cpu"))
        for f in ("T_time", "T_energy", "E_time", "E_energy"):
            np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                          np.asarray(getattr(b, f)),
                                          err_msg=f)

    def test_resolution_order(self, monkeypatch):
        # explicit argument beats everything
        cfg = DispatchConfig(precision=F64)
        assert resolve_precision(cfg, COMPENSATED_F32) is COMPENSATED_F32
        # config beats the environment
        monkeypatch.setenv("REPRO_PRECISION", "compensated_f32")
        assert resolve_precision(cfg) is F64
        # environment beats the backend default
        assert resolve_precision() is COMPENSATED_F32
        # bad environment value: warn + fall through to the backend default
        monkeypatch.setenv("REPRO_PRECISION", "float8")
        with pytest.warns(RuntimeWarning, match="REPRO_PRECISION"):
            assert resolve_precision() is F64

    def test_unknown_policy_raises(self):
        with pytest.raises(ValueError, match="float16"):
            resolve("float16")
        with pytest.raises(TypeError):
            resolve(3.14)

    def test_compensated_sum_recovers_cancellation(self):
        """The Neumaier machinery itself: a catastrophic-cancellation sum
        that plain f32 accumulation gets wrong to ~1e-1."""
        import jax.numpy as jnp
        big = np.float32(1e8)
        terms = [jnp.float32(v) for v in (big, 1.0, -big, 1.0)]
        naive = terms[0]
        for t in terms[1:]:
            naive = naive + t
        assert float(naive) != 2.0
        assert float(compensated_sum(terms)) == 2.0
        s, err = two_sum(np.float64(1.0), np.float64(1e-20))
        assert s == 1.0 and err == 1e-20


class TestCompensatedParityGates:
    """compensated_f32 vs the f64 oracle, per scenario family, at the
    policy's DOCUMENTED tolerances."""

    def _gate_single(self, grid):
        pol = COMPENSATED_F32
        r64 = evaluate_grid(grid)
        r32 = evaluate_grid(grid, precision=pol)
        valid = (np.asarray(r64.valid) & np.asarray(r32.valid)).ravel()
        assert valid.any()
        np.testing.assert_array_equal(np.asarray(r64.valid),
                                      np.asarray(r32.valid))
        p = {k: np.asarray(v).ravel()[valid]
             for k, v in grid.ravel().fields().items()}
        for T64, T32, objective in (
                (r64.T_time, r32.T_time, time_final_batched),
                (r64.T_energy, r32.T_energy, energy_final_batched)):
            T64 = np.asarray(T64).ravel()[valid]
            T32 = np.asarray(T32).ravel()[valid]
            # argmin gate: the served period lands in the f64 valley
            np.testing.assert_allclose(T32, T64, rtol=pol.argmin_rtol)
            # objective gate: the f32 period's TRUE (f64-re-evaluated)
            # objective is within objective_tol of the f64 optimum
            f64_at_32 = np.asarray(objective(T32, p, 1.0))
            f64_at_64 = np.asarray(objective(T64, p, 1.0))
            rel = np.abs(f64_at_32 - f64_at_64) / np.abs(f64_at_64)
            assert float(rel.max()) <= pol.objective_tol, rel.max()

    def test_mu_rho_family(self):
        self._gate_single(mu_rho_grid(mus=(600.0, 1200.0, 3600.0),
                                      rhos=(0.5, 1.0, 3.0)))

    def test_arch_catalog_family(self):
        self._gate_single(arch_grid())

    def test_multilevel_family(self):
        pol = COMPENSATED_F32
        grid = buddy_ratio_grid([0.05, 0.2, 1.0], [0.02, 0.1, 0.3],
                                mu_min=300.0)
        m_values = tuple(range(1, 9))
        r64 = evaluate_multilevel_grid(grid, m_values=m_values)
        r32 = evaluate_multilevel_grid(grid, m_values=m_values,
                                       precision=pol)
        for T64, m64, T32, m32 in (
                (r64.T_time, r64.m_time, r32.T_time, r32.m_time),
                (r64.T_energy, r64.m_energy, r32.T_energy, r32.m_energy)):
            np.testing.assert_allclose(np.asarray(T32), np.asarray(T64),
                                       rtol=pol.argmin_rtol)
            # cadence argmins are small integers: near-ties may flip one
            # notch under f32, never more
            assert np.abs(np.asarray(m32, dtype=np.int64)
                          - np.asarray(m64, dtype=np.int64)).max() <= 1
        # objective gate on the f64 per-m tables: the f32-served cadence's
        # f64 objective is within objective_tol of the f64 optimum
        E64 = np.asarray(r64.E_by_m)             # (n_m, ...grid)
        mi64 = np.asarray(r64.m_energy) - m_values[0]
        mi32 = np.asarray(r32.m_energy) - m_values[0]
        at64 = np.take_along_axis(E64, mi64[None], axis=0)[0]
        at32 = np.take_along_axis(E64, mi32[None], axis=0)[0]
        rel = np.abs(at32 - at64) / np.abs(at64)
        # the cadence axis is discrete: a one-notch flip near a tie costs
        # the tie margin, not f32 noise — gate at the policy tol against
        # the CONTINUOUS-period re-evaluation semantics
        assert float(rel.max()) <= 10 * pol.objective_tol, rel.max()

    def test_pallas_compensated_engine_close_to_oracle(self):
        grid = ParamGrid.from_params(CK, PW).reshape((1,))
        kw = dict(T_base=1500.0, n_trials=64, seed=3,
                  process=Weibull(shape=0.7), engine_kind="pallas")
        r64 = simulate_trajectories(60.0, grid, **kw)
        r32 = simulate_trajectories(60.0, grid, precision=COMPENSATED_F32,
                                    **kw)
        np.testing.assert_array_equal(r64.n_failures, r32.n_failures)
        for f in ("wall_time", "energy", "work_executed", "io_time"):
            np.testing.assert_allclose(np.asarray(getattr(r32, f)),
                                       np.asarray(getattr(r64, f)),
                                       rtol=1e-5, err_msg=f)


class TestAdvisorPrecision:
    """The serving layer's policy threading."""

    def _req(self):
        from repro.serve.schema import AdviceRequest, StoreTier
        tier = StoreTier(name="pfs", C=60.0, R=60.0, D=0.0, P_io=10.0)
        return AdviceRequest(mu=3600.0, tiers=(tier,))

    def test_metrics_report_policy(self):
        from repro.serve.service import AdvisorService
        assert AdvisorService().metrics()["precision_policy"] == "f64"
        svc = AdvisorService(precision="compensated_f32")
        assert svc.metrics()["precision_policy"] == "compensated_f32"

    def test_compensated_service_stays_within_gates(self):
        from repro.serve.service import AdvisorService
        req = self._req()
        a64 = AdvisorService().advise(req)
        a32 = AdvisorService(precision=COMPENSATED_F32,
                             cache_name=None).advise(req)
        assert a32.period == pytest.approx(a64.period,
                                           rel=COMPENSATED_F32.argmin_rtol)
        # the certified bound must have absorbed the policy's
        # objective_tol slack on the cached (non-exact) path
        if not a32.exact:
            assert a32.cert_bound >= COMPENSATED_F32.objective_tol
            assert a64.cert_bound < a32.cert_bound
