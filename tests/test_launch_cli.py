"""Parser tests for the training launcher CLI (repro.launch.train).

Mirror of TestServeCLI: boolean flags must be BooleanOptionalAction
(--x / --no-x pairs), choices must track the solver/process registries,
and args must map onto the RunSpec the launcher builds.
"""
import math

import pytest

from repro.launch.train import build_parser, spec_from_args


class TestTrainParser:
    def test_boolean_flags_have_no_variants(self):
        p = build_parser()
        for flag, default in [("reduce", True), ("buddy", True),
                              ("inject-failures", True),
                              ("compress", False), ("quiet", False)]:
            dest = flag.replace("-", "_")
            assert getattr(p.parse_args([]), dest) is default
            assert getattr(p.parse_args([f"--{flag}"]), dest) is True
            assert getattr(p.parse_args([f"--no-{flag}"]), dest) is False

    def test_strategy_choices_include_multilevel(self):
        p = build_parser()
        args = p.parse_args(["--strategy", "algo_e_ml"])
        assert args.strategy == "algo_e_ml"
        with pytest.raises(SystemExit):
            p.parse_args(["--strategy", "not_a_strategy"])

    def test_process_choices_track_registry(self):
        from repro.core.failures import PROCESSES
        p = build_parser()
        for name in PROCESSES:
            if name == "trace":
                continue             # needs a gaps list, not CLI-expressible
            assert p.parse_args(["--process", name]).process == name

    def test_defaults_build_a_failure_free_spec(self):
        spec = spec_from_args(build_parser().parse_args([]))
        assert math.isinf(spec.mu_s)
        assert spec.step_s == 1.0 and spec.scaled_time

    def test_args_map_onto_spec(self):
        argv = ["--strategy", "algo_t_ml", "--mtbf", "20", "--q", "0.15",
                "--ckpt-cost", "1.5", "--c1", "0.3", "--process", "weibull",
                "--process-param", "0.7", "--profile", "paper_ml",
                "--steps", "120", "--no-buddy"]
        spec = spec_from_args(build_parser().parse_args(argv))
        assert spec.strategy == "algo_t_ml" and spec.mu_s == 20.0
        assert spec.q == 0.15 and spec.C_s == 1.5 and spec.C1_s == 0.3
        assert spec.process == "weibull"
        assert spec.process_kwargs == {"shape": 0.7}
        assert spec.profile == "paper_ml" and spec.total_steps == 120
        assert spec.use_buddy is False

    def test_no_inject_failures_disables_injection(self):
        spec = spec_from_args(build_parser().parse_args(
            ["--mtbf", "50", "--no-inject-failures"]))
        assert not spec.inject

    def test_wall_time_mode(self):
        spec = spec_from_args(build_parser().parse_args(
            ["--sim-step-seconds", "0"]))
        assert spec.step_s is None and not spec.scaled_time
